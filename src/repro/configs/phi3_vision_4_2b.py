"""Assigned architecture config — exact values from the public pool."""
from .base import ArchConfig

CONFIG = ArchConfig(
    # [hf:microsoft/Phi-3-vision-128k-instruct] — phi3-mini backbone + CLIP
    # frontend.  CLIP tower is a STUB: input_specs() provides precomputed
    # patch+text embeddings (B, S, d_model).
    name="phi-3-vision-4.2b", family="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab=32064, embed_input=False, rope_theta=1e4,
    notes="patch-embedding stub frontend; full attention (no long_500k)",
)
