"""Assigned architecture config — exact values from the public pool."""
from .base import ArchConfig

CONFIG = ArchConfig(
    # [hf:Qwen/Qwen3-8B family] — qk_norm, GQA.
    name="qwen3-1.7b", family="dense",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8, d_ff=6144,
    vocab=151936, head_dim=128, qk_norm=True, tie_embeddings=True,
    rope_theta=1e6,
)
