"""Assigned architecture config — exact values from the public pool."""
from .base import ArchConfig

CONFIG = ArchConfig(
    # [arXiv:2401.04088; hf] — 8 experts top-2, SWA per assignment.
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
    vocab=32768, head_dim=128, n_experts=8, top_k=2, moe_d_ff=16384,
    window=4096, sub_quadratic=True, rope_theta=1e6,
    notes="SWA window 4096 → long_500k decode runs with bounded cache",
)
