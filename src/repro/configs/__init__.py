"""Config registry: ``--arch <id>`` lookup + input-shape suite."""
from .archs import ARCHS
from .base import SHAPES, ArchConfig, ShapeConfig


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; available: {sorted(SHAPES)}")
    return SHAPES[name]


def cells():
    """All assigned (arch × shape) cells, with long_500k skips applied."""
    out = []
    for a in ARCHS.values():
        for s in SHAPES.values():
            if s.name == "long_500k" and not a.sub_quadratic:
                out.append((a, s, "skip: full attention (DESIGN.md §5)"))
            else:
                out.append((a, s, None))
    return out


__all__ = ["ARCHS", "SHAPES", "ArchConfig", "ShapeConfig", "get_arch",
           "get_shape", "cells"]
