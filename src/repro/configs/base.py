"""Architecture config schema + input-shape suite (assigned cells)."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 → d_model // n_heads
    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    window: int | None = None        # sliding-window attention
    rope_theta: float = 1e4
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    # block structure: repeated pattern of layer kinds
    pattern: tuple[str, ...] = ("attn",)   # attn | mlstm | slstm | rglru
    # embedding / head
    embed_input: bool = True         # False → stub frontend provides embeddings
    tie_embeddings: bool = False
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    act: str = "silu"                # silu | gelu | geglu
    mlp_bias: bool = False
    # capability flags
    sub_quadratic: bool = False      # may run long_500k
    notes: str = ""

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks + head)."""
        d, dh = self.d_model, self.head_dim
        n = 0
        if self.embed_input:
            n += self.vocab * d
        if not self.tie_embeddings:
            n += self.vocab * d
        per_pattern = 0
        for kind in self.pattern:
            if kind == "attn":
                per_pattern += d * dh * (self.n_heads + 2 * self.n_kv_heads)
                per_pattern += self.n_heads * dh * d
            elif kind == "mlstm":
                per_pattern += 4 * d * d + 2 * d * self.n_heads
            elif kind == "slstm":
                per_pattern += 4 * d * d + d * d + self.n_heads * (d // self.n_heads) ** 2 * 4
            elif kind == "rglru":
                per_pattern += 5 * d * d
            if kind in ("attn", "rglru") and self.d_ff:
                mult = 3 if self.act in ("silu", "geglu") else 2
                per_pattern += mult * d * self.d_ff
            if self.is_moe and kind == "attn":
                f = self.moe_d_ff or self.d_ff
                per_pattern += self.n_experts * 3 * d * f + d * self.n_experts
        n += (self.n_layers * per_pattern) // len(self.pattern)
        return n

    def n_active_params(self) -> int:
        """Per-token active parameters (MoE: top_k of n_experts)."""
        if not self.is_moe:
            return self.n_params()
        d = self.d_model
        f = self.moe_d_ff or self.d_ff
        dense_moe = self.n_experts * 3 * d * f
        active_moe = self.top_k * 3 * d * f
        return self.n_params() - self.n_layers * (dense_moe - active_moe)

    def reduced(self, n_layers=2, d_model=64, n_heads=4, n_kv_heads=None,
                vocab=256, d_ff=None, n_experts=None, seq_cap=None) -> "ArchConfig":
        """Small same-family config for CPU smoke tests."""
        nkv = n_kv_heads if n_kv_heads is not None else max(
            1, n_heads * self.n_kv_heads // self.n_heads)
        ne = self.n_experts if n_experts is None else n_experts
        if self.is_moe and n_experts is None:
            ne = min(self.n_experts, 8)
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=max(n_layers, len(self.pattern)),
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=nkv,
            head_dim=d_model // n_heads,
            d_ff=(d_ff if d_ff is not None else (d_model * 4 if self.d_ff else 0)),
            moe_d_ff=(d_model * 2 if self.moe_d_ff else 0),
            n_experts=ne,
            top_k=min(self.top_k, ne) if ne else 0,
            vocab=vocab,
            window=min(self.window, 32) if self.window else None,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
