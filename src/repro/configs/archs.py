"""Registry of the 10 assigned architectures (one module per arch)."""
from __future__ import annotations

from .base import ArchConfig
from .mixtral_8x22b import CONFIG as MIXTRAL_8X22B
from .musicgen_medium import CONFIG as MUSICGEN_MEDIUM
from .phi3_vision_4_2b import CONFIG as PHI3_VISION_4_2B
from .qwen1_5_0_5b import CONFIG as QWEN1_5_0_5B
from .qwen2_0_5b import CONFIG as QWEN2_0_5B
from .qwen3_1_7b import CONFIG as QWEN3_1_7B
from .qwen3_moe_235b_a22b import CONFIG as QWEN3_MOE_235B
from .recurrentgemma_9b import CONFIG as RECURRENTGEMMA_9B
from .starcoder2_7b import CONFIG as STARCODER2_7B
from .xlstm_125m import CONFIG as XLSTM_125M

ARCHS: dict[str, ArchConfig] = {
    c.name: c for c in (
        MUSICGEN_MEDIUM, MIXTRAL_8X22B, QWEN3_MOE_235B, QWEN2_0_5B,
        QWEN3_1_7B, QWEN1_5_0_5B, STARCODER2_7B, XLSTM_125M,
        PHI3_VISION_4_2B, RECURRENTGEMMA_9B,
    )
}
