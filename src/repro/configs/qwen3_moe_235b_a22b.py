"""Assigned architecture config — exact values from the public pool."""
from .base import ArchConfig

CONFIG = ArchConfig(
    # [hf:Qwen/Qwen3-30B-A3B family scaled per assignment] — 128e top-8.
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, d_ff=1536,
    vocab=151936, head_dim=128, n_experts=128, top_k=8, moe_d_ff=1536,
    qk_norm=True, rope_theta=1e6,
    notes="full attention (no long_500k); EP 128/16=8 experts per shard",
)
