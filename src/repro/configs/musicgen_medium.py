"""Assigned architecture config — exact values from the public pool."""
from .base import ArchConfig

CONFIG = ArchConfig(
    # [arXiv:2306.05284; hf] — decoder-only over EnCodec tokens.  Modality
    # frontend (EnCodec + codebook interleaving) is a STUB: input_specs()
    # provides precomputed frame embeddings (B, S, d_model).
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, d_ff=6144,
    vocab=2048, embed_input=False, norm="layernorm", act="gelu",
    notes="frame-embedding stub frontend; full attention (no long_500k)",
)
