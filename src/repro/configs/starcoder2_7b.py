"""Assigned architecture config — exact values from the public pool."""
from .base import ArchConfig

CONFIG = ArchConfig(
    # [arXiv:2402.19173; hf] — GQA, RoPE, layernorm + gelu, biases.
    name="starcoder2-7b", family="dense",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4, d_ff=18432,
    vocab=49152, norm="layernorm", act="gelu", qkv_bias=True, mlp_bias=True,
)
