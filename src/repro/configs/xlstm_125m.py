"""Assigned architecture config — exact values from the public pool."""
from .base import ArchConfig

CONFIG = ArchConfig(
    # [arXiv:2405.04517; unverified] — sLSTM + mLSTM blocks, no FFN (d_ff=0).
    # 12 layers as 2×(5 mLSTM + 1 sLSTM) ≈ the paper's m:s ratio.
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab=50304, pattern=("mlstm", "mlstm", "mlstm", "mlstm", "mlstm", "slstm"),
    sub_quadratic=True, norm="layernorm",
    notes="linear recurrence → long_500k runs; no FFN per assignment",
)
