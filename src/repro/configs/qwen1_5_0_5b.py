"""Assigned architecture config — exact values from the public pool."""
from .base import ArchConfig

CONFIG = ArchConfig(
    # [hf:Qwen/Qwen1.5-0.5B]
    name="qwen1.5-0.5b", family="dense",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=2816,
    vocab=151936, qkv_bias=True, tie_embeddings=True,
)
