"""Assigned architecture config — exact values from the public pool."""
from .base import ArchConfig

CONFIG = ArchConfig(
    # [arXiv:2402.19427; unverified] — RG-LRU + local attention, 1:2 ratio
    # (pattern: two recurrent blocks, then one local-attention block).
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, d_ff=12288,
    vocab=256000, window=2048, act="geglu",
    pattern=("rglru", "rglru", "attn"), sub_quadratic=True,
    notes="38 = 12×(rec,rec,attn) + (rec,rec) remainder; local attn window 2048",
)
