"""Pass 2 — the repo-invariant lint engine.

``ast``-based rules enforcing invariants ruff cannot express:

``raw-collective``
    No raw ``jax.lax`` collective calls outside ``core/nap_collectives.py``.
    Every collective must go through the NAP wrappers so the comm auditor's
    per-strategy signatures stay exhaustive.  Documented exceptions carry an
    inline ``# comm-audit: allow <tag>`` marker (e.g. the flat-psum dot
    products in ``dist_solve.py``) or a module-level
    ``# comm-audit: allow-file raw-collective`` marker (e.g.
    ``train/grad_sync.py``, itself a hierarchical-collective implementation).

``async-blocking``
    No blocking ``AMGService`` / ``Ticket.result`` calls inside ``async def``
    bodies — the deadlock class the serving front-end routes around via
    ``ticket_future`` / ``asyncio.to_thread``.  A nested *sync* ``def``
    (e.g. a done-callback) resets the scope.

``traced-host-call``
    No wall-clock reads or host callbacks inside functions handed to
    ``jax.jit`` / ``shard_map`` / ``vmap`` — they would be baked in at trace
    time (or stall the device stream), silently corrupting measurements.

``frozen-mutation``
    No attribute assignment on frozen-dataclass instances and no
    ``object.__setattr__`` escape hatch outside ``__post_init__`` — state
    evolution must go through ``dataclasses.replace`` so config/plan
    identity stays hashable and cache-safe.

Suppression markers:

* ``# comm-audit: allow <tag>`` on the violating line — documented,
  per-site exception; the tag is the rationale label.
* ``# comm-audit: allow-file <rule>`` anywhere in the module — exempts the
  whole file from that rule.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path

from .records import LintViolation

COLLECTIVE_FNS = frozenset({
    "psum", "psum_scatter", "all_gather", "all_to_all", "ppermute",
    "pshuffle", "pmax", "pmin", "pmean",
})
BLOCKING_METHODS = frozenset({"result", "update_wire", "drain"})
TRACE_WRAPPERS = frozenset({"jit", "shard_map", "smap", "vmap", "pmap"})
HOST_CALLS = frozenset({
    "time.time", "time.perf_counter", "time.monotonic",
    "datetime.now", "datetime.datetime.now", "datetime.utcnow",
    "jax.pure_callback", "jax.experimental.io_callback", "io_callback",
    "jax.debug.callback",
})

_ALLOW_LINE = re.compile(r"#\s*comm-audit:\s*allow\s+(\S+)")
_ALLOW_FILE = re.compile(r"#\s*comm-audit:\s*allow-file\s+(\S+)")


def _dotted(node: ast.AST) -> str | None:
    """``jax.lax.psum`` -> "jax.lax.psum"; None for non-name chains."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _decorator_names(fn: ast.FunctionDef | ast.AsyncFunctionDef) -> set[str]:
    out: set[str] = set()
    for dec in fn.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        name = _dotted(target)
        if name:
            out.add(name.rsplit(".", 1)[-1])
        if isinstance(dec, ast.Call):        # functools.partial(jax.jit, ...)
            for arg in dec.args:
                inner = _dotted(arg)
                if inner:
                    out.add(inner.rsplit(".", 1)[-1])
    return out


def collect_frozen_classes(trees: dict[str, ast.Module]) -> set[str]:
    """Names of every ``@dataclass(frozen=True)`` class across the repo."""
    frozen: set[str] = set()
    for tree in trees.values():
        for node in ast.walk(tree):
            if not isinstance(node, ast.ClassDef):
                continue
            for dec in node.decorator_list:
                if not isinstance(dec, ast.Call):
                    continue
                name = _dotted(dec.func)
                if not name or name.rsplit(".", 1)[-1] != "dataclass":
                    continue
                for kw in dec.keywords:
                    if (kw.arg == "frozen"
                            and isinstance(kw.value, ast.Constant)
                            and kw.value.value is True):
                        frozen.add(node.name)
    return frozen


class _Linter(ast.NodeVisitor):
    def __init__(self, path: str, lines: list[str], frozen: set[str],
                 file_allows: set[str]):
        self.path = path
        self.lines = lines
        self.frozen = frozen
        self.file_allows = file_allows
        self.violations: list[LintViolation] = []
        self._fn_stack: list[str] = []      # "async" | "sync"
        self._traced_names: set[str] = set()
        self._traced_depth = 0
        self._frozen_vars: list[set[str]] = [set()]
        self._in_post_init = False
        self._is_nap_core = path.replace("\\", "/").endswith(
            "core/nap_collectives.py")

    # -- bookkeeping -------------------------------------------------------
    def _allowed(self, rule: str, line: int) -> bool:
        if rule in self.file_allows:
            return True
        text = self.lines[line - 1] if 0 < line <= len(self.lines) else ""
        return bool(_ALLOW_LINE.search(text))

    def _flag(self, rule: str, node: ast.AST, message: str) -> None:
        if not self._allowed(rule, node.lineno):
            self.violations.append(
                LintViolation(rule, self.path, node.lineno, message))

    # -- scopes ------------------------------------------------------------
    def _visit_fn(self, node, kind: str) -> None:
        decos = _decorator_names(node)
        traced = (bool(decos & TRACE_WRAPPERS)
                  or node.name in self._traced_names)
        self._fn_stack.append(kind)
        self._traced_depth += 1 if traced else 0
        frozen_here = set()
        for arg in (node.args.args + node.args.posonlyargs
                    + node.args.kwonlyargs):
            ann = arg.annotation
            name = ann and _dotted(ann)
            if (name and name.rsplit(".", 1)[-1] in self.frozen
                    and arg.arg != "self"):
                frozen_here.add(arg.arg)
        self._frozen_vars.append(frozen_here)
        was_post_init = self._in_post_init
        if node.name == "__post_init__":
            self._in_post_init = True
        self.generic_visit(node)
        self._in_post_init = was_post_init
        self._frozen_vars.pop()
        self._traced_depth -= 1 if traced else 0
        self._fn_stack.pop()

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._visit_fn(node, "sync")

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef) -> None:
        self._visit_fn(node, "async")

    def visit_Module(self, node: ast.Module) -> None:
        # pre-scan: local functions handed to jit/shard_map/vmap are traced
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                name = _dotted(sub.func)
                if name and name.rsplit(".", 1)[-1] in TRACE_WRAPPERS:
                    for arg in sub.args:
                        if isinstance(arg, ast.Name):
                            self._traced_names.add(arg.id)
        self.generic_visit(node)

    # -- rules -------------------------------------------------------------
    def visit_Await(self, node: ast.Await) -> None:
        # an awaited call yields to the event loop — by definition not a
        # blocking call (e.g. `await writer.drain()` on an asyncio stream)
        setattr(node.value, "_awaited", True)
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        name = _dotted(node.func) or ""
        leaf = name.rsplit(".", 1)[-1]

        if (not self._is_nap_core and leaf in COLLECTIVE_FNS
                and (name.startswith("jax.lax.") or name.startswith("lax."))):
            self._flag("raw-collective", node,
                       f"raw `{name}` call — route through "
                       f"repro.core.nap_collectives so the comm auditor's "
                       f"strategy signatures stay exhaustive")

        if (self._fn_stack and self._fn_stack[-1] == "async"
                and not getattr(node, "_awaited", False)):
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr in BLOCKING_METHODS):
                self._flag("async-blocking", node,
                           f"blocking `.{node.func.attr}()` call inside an "
                           f"`async def` body — route through ticket_future "
                           f"/ asyncio.to_thread")
            elif name == "time.sleep":
                self._flag("async-blocking", node,
                           "`time.sleep` inside an `async def` body — use "
                           "`await asyncio.sleep`")

        if self._traced_depth > 0 and (
                name in HOST_CALLS
                or leaf in {"pure_callback", "io_callback"}
                or name.endswith("debug.callback")):
            self._flag("traced-host-call", node,
                       f"`{name}` inside a traced function — host reads are "
                       f"baked in at trace time")

        if (name == "object.__setattr__" and not self._in_post_init):
            self._flag("frozen-mutation", node,
                       "`object.__setattr__` outside `__post_init__` — use "
                       "`dataclasses.replace`")
        self.generic_visit(node)

    def visit_Assign(self, node: ast.Assign) -> None:
        # x = FrozenClass(...) makes x a frozen instance in this scope
        is_frozen_ctor = False
        if isinstance(node.value, ast.Call):
            vname = _dotted(node.value.func) or ""
            if vname.rsplit(".", 1)[-1] in self.frozen:
                is_frozen_ctor = True
        for tgt in node.targets:
            if is_frozen_ctor and isinstance(tgt, ast.Name):
                self._frozen_vars[-1].add(tgt.id)
            if (isinstance(tgt, ast.Attribute)
                    and isinstance(tgt.value, ast.Name)
                    and tgt.value.id in self._frozen_vars[-1]
                    and not self._in_post_init):
                self._flag("frozen-mutation", node,
                           f"assignment to `{tgt.value.id}.{tgt.attr}` on a "
                           f"frozen dataclass — use `dataclasses.replace`")
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        ann = _dotted(node.annotation) or ""
        if (ann.rsplit(".", 1)[-1] in self.frozen
                and isinstance(node.target, ast.Name)):
            self._frozen_vars[-1].add(node.target.id)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        tgt = node.target
        if (isinstance(tgt, ast.Attribute) and isinstance(tgt.value, ast.Name)
                and tgt.value.id in self._frozen_vars[-1]
                and not self._in_post_init):
            self._flag("frozen-mutation", node,
                       f"augmented assignment to `{tgt.value.id}.{tgt.attr}`"
                       f" on a frozen dataclass — use `dataclasses.replace`")
        self.generic_visit(node)


def lint_source(src: str, path: str = "<string>",
                frozen: set[str] | None = None) -> list[LintViolation]:
    """Lint one module's source.  ``frozen`` injects repo-wide frozen-class
    names; when omitted, only classes defined in ``src`` are known."""
    tree = ast.parse(src, filename=path)
    if frozen is None:
        frozen = collect_frozen_classes({path: tree})
    file_allows = set(_ALLOW_FILE.findall(src))
    lines = src.splitlines()
    linter = _Linter(path, lines, frozen, file_allows)
    linter.visit(tree)
    return sorted(linter.violations, key=lambda v: (v.path, v.line, v.rule))


def lint_paths(root: str | Path) -> list[LintViolation]:
    """Lint every ``.py`` module under ``root`` (normally ``src/``), with
    frozen-dataclass names collected repo-wide first so cross-module
    instances are tracked."""
    root = Path(root)
    sources: dict[str, str] = {}
    trees: dict[str, ast.Module] = {}
    for p in sorted(root.rglob("*.py")):
        rel = str(p)
        src = p.read_text()
        sources[rel] = src
        trees[rel] = ast.parse(src, filename=rel)
    frozen = collect_frozen_classes(trees)
    out: list[LintViolation] = []
    for rel, src in sources.items():
        out.extend(lint_source(src, rel, frozen=frozen))
    return out
