"""Machine-readable report assembly for ``python -m repro.analysis``.

One JSON document per run: the comm-audit records (per-program collective
counts vs the model's predicted counts), the setup-phase static-vs-measured
rows, every violation from both passes, and a pass/fail verdict CI keys on.
"""
from __future__ import annotations

import json
from pathlib import Path


def build_report(*, audits=(), audit_violations=(), lint_violations=(),
                 setup_rows=(), meta: dict | None = None) -> dict:
    audits = list(audits)
    audit_violations = list(audit_violations)
    lint_violations = list(lint_violations)
    report = {
        "meta": dict(meta or {}),
        "summary": {
            "programs_audited": len(audits),
            "collectives_seen": sum(a.n_collectives for a in audits),
            "audit_violations": len(audit_violations),
            "lint_violations": len(lint_violations),
            "ok": not audit_violations and not lint_violations,
        },
        "comm_audit": [a.to_dict() for a in audits],
        "setup_audit": list(setup_rows),
        "audit_violations": [v.to_dict() for v in audit_violations],
        "lint": [v.to_dict() for v in lint_violations],
    }
    return report


def write_report(report: dict, path: str | Path) -> None:
    Path(path).write_text(json.dumps(report, indent=2, sort_keys=True) + "\n")


def format_summary(report: dict) -> str:
    """Human-readable tail: the per-program collective-count table (actual
    vs model-predicted) plus every violation, one per line."""
    out = []
    rows = report["comm_audit"]
    if rows:
        out.append(f"{'program':<24s} {'where':<8s} {'collectives':>11s} "
                   f"{'bytes':>12s}  counts (actual | expected)")
        for a in rows:
            where = ""
            if a["level"] is not None:
                where = f"L{a['level']}.{a['op']}"
            counts = " ".join(f"{p}={c}" for p, c in sorted(a["counts"].items()))
            exp = ("(unchecked)" if a["expected"] is None else " ".join(
                f"{p}={c}" for p, c in sorted(a["expected"].items())) or "none")
            mark = "" if a["ok"] else "  <-- VIOLATION"
            out.append(f"{a['program']:<24s} {where:<8s} "
                       f"{a['n_collectives']:>11d} {a['total_bytes']:>12d}  "
                       f"{counts or 'none'} | {exp}{mark}")
    for r in report["setup_audit"]:
        out.append(f"setup L{r['level']} {r['op']:<12s} {r['strategy']:<9s} "
                   f"inter {r['runtime_inter_msgs']}/{r['static_inter_msgs']} "
                   f"intra {r['runtime_intra_msgs']}/{r['static_intra_msgs']} "
                   f"msgs (measured/static)")
    for v in report["audit_violations"]:
        out.append(f"AUDIT  [{v['kind']}] {v['program']}: {v['message']}")
    for v in report["lint"]:
        out.append(f"LINT   {v['path']}:{v['line']}: [{v['rule']}] "
                   f"{v['message']}")
    s = report["summary"]
    out.append(f"analysis: {s['programs_audited']} programs, "
               f"{s['collectives_seen']} collectives, "
               f"{s['audit_violations']} audit + {s['lint_violations']} lint "
               f"violations -> {'OK' if s['ok'] else 'FAIL'}")
    return "\n".join(out)
