"""``python -m repro.analysis`` — run both CI-gated passes and exit 1 on
any violation.

Pass 1 lowers a small Laplace hierarchy onto a (pods × lanes) host-device
mesh (XLA's host-platform device override — tracing is abstract, nothing
needs real accelerators) and audits every compiled fused program: the full
cycle×smoother grid, PCG, the ``*_m`` multi-RHS variants, every per-level
operator apply, and the setup-phase SpGEMM exchanges (a plain and an
aggressive-coarsening run, the latter exercising the distance-2 ``S²``
exchange).  Pass 2 lints ``src/`` with the repo-invariant rule engine.

``--json report.json`` writes the machine-readable report CI archives;
``--lint-only`` skips the (slower) tracing pass.
"""
from __future__ import annotations

import argparse
import os
import sys
from pathlib import Path


def run_comm_audit(n: int, pods: int, lanes: int):
    """Build + audit; returns (audits, violations, setup_rows, meta)."""
    # must precede the first jax import anywhere in the process
    flag = f"--xla_force_host_platform_device_count={pods * lanes}"
    if flag not in os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") + " " + flag).strip()
    import jax

    from ..amg import setup
    from ..amg.dist_setup import dist_setup_partitioned
    from ..amg.dist_solve import DistHierarchy
    from ..amg.problems import laplace_3d
    from .comm_audit import audit_hierarchy, audit_setup

    A = laplace_3d(n)
    h = setup(A, solver="rs", max_coarse=30)     # >= 3 levels: W/F revisit
    dh = DistHierarchy.build(h, pods, lanes)
    audits, violations = audit_hierarchy(dh)

    setup_rows = []
    plv, recs = dist_setup_partitioned(A, pods, lanes, max_coarse=30)
    rows, svio = audit_setup(plv, recs)
    setup_rows += rows
    violations += svio
    plv2, recs2 = dist_setup_partitioned(laplace_3d(6), pods, lanes,
                                         aggressive=True)
    rows2, svio2 = audit_setup(plv2, recs2)
    setup_rows += rows2
    violations += svio2
    if not any(r["op"] == "spgemm_S2" for r in rows2):
        from .records import AuditViolation
        violations.append(AuditViolation(
            "missing-record", "aggressive setup ran but no spgemm_S2 "
            "exchange was audited", program="dist_setup"))

    meta = {"n": n, "pods": pods, "lanes": lanes,
            "levels": len(dh.levels), "jax": jax.__version__,
            "overlap": dh.overlap, "reduce_strategy": dh.reduce_strategy}
    return audits, violations, setup_rows, meta


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="comm audit (pass 1) + repo-invariant lint (pass 2)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the machine-readable JSON report here")
    ap.add_argument("--lint-only", action="store_true",
                    help="skip the jaxpr tracing pass")
    ap.add_argument("--n", type=int, default=8,
                    help="Laplace grid edge for the audited hierarchy")
    ap.add_argument("--pods", type=int, default=2)
    ap.add_argument("--lanes", type=int, default=4)
    args = ap.parse_args(argv)

    from .lint import lint_paths
    from .report import build_report, format_summary, write_report

    src_root = Path(__file__).resolve().parents[2]       # .../src
    lint_violations = lint_paths(src_root)

    audits, violations, setup_rows, meta = [], [], [], {}
    if not args.lint_only:
        audits, violations, setup_rows, meta = run_comm_audit(
            args.n, args.pods, args.lanes)

    report = build_report(audits=audits, audit_violations=violations,
                          lint_violations=lint_violations,
                          setup_rows=setup_rows, meta=meta)
    if args.json:
        write_report(report, args.json)
    print(format_summary(report))
    return 0 if report["summary"]["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
