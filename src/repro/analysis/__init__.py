"""Static-analysis subsystem (CI-gated): the jaxpr-level communication
auditor (Pass 1, :mod:`~repro.analysis.comm_audit`) and the ``ast``-based
repo-invariant lint (Pass 2, :mod:`~repro.analysis.lint`).

Run both with ``python -m repro.analysis [--json report.json]``.
"""
from .comm_audit import (PROGRAM_NAMES, audit_apply, audit_cycle_stats,
                         audit_hierarchy, audit_jaxpr, audit_program,
                         audit_setup)
from .jaxpr_walk import (check_overlap_independence, collect_collectives,
                         collective_signature)
from .lint import lint_paths, lint_source
from .records import AuditViolation, CollectiveRecord, CommAudit, LintViolation
from .report import build_report, format_summary, write_report

__all__ = [
    "PROGRAM_NAMES", "AuditViolation", "CollectiveRecord", "CommAudit",
    "LintViolation", "audit_apply", "audit_cycle_stats", "audit_hierarchy",
    "audit_jaxpr", "audit_program", "audit_setup", "build_report",
    "check_overlap_independence", "collect_collectives",
    "collective_signature", "format_summary", "lint_paths", "lint_source",
    "write_report",
]
