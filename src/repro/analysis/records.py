"""Typed records of the static-analysis subsystem.

Pass 1 (:mod:`repro.analysis.comm_audit`) produces :class:`CommAudit`
records — one per audited program, listing every collective primitive the
traced jaxpr contains as a :class:`CollectiveRecord` — and raises/collects
:class:`AuditViolation` on any mismatch against the expected structure.
Pass 2 (:mod:`repro.analysis.lint`) produces :class:`LintViolation` rows.
Everything is JSON-serializable via ``to_dict`` for the machine-readable
report ``python -m repro.analysis --json`` writes.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class CollectiveRecord:
    """One collective primitive found in a traced program.

    ``primitive`` is the canonical name (``psum`` / ``psum_scatter`` /
    ``all_gather`` / ``all_to_all`` / ``ppermute`` — the jaxpr's
    ``reduce_scatter`` is normalized to ``psum_scatter``); ``bytes`` is the
    static operand payload (operand element count × itemsize), the quantity
    the paper's per-schedule byte counts model.
    """

    primitive: str
    axes: tuple[str, ...]
    operand_shape: tuple[int, ...]
    operand_dtype: str
    out_shape: tuple[int, ...]
    bytes: int
    eqn_index: int               # position in the flattened recursive walk

    def to_dict(self) -> dict:
        return {"primitive": self.primitive, "axes": list(self.axes),
                "operand_shape": list(self.operand_shape),
                "operand_dtype": self.operand_dtype,
                "out_shape": list(self.out_shape),
                "bytes": self.bytes, "eqn_index": self.eqn_index}


class AuditViolation(Exception):
    """A mismatch between a program's lowered collectives and the structure
    the selected strategy predicts.

    Typed (``kind``) and attributed: ``program`` names the audited fused
    program or apply, ``level``/``op`` pin the hierarchy operator when the
    audit runs at per-operator granularity, and ``eqn`` carries the
    offending :class:`CollectiveRecord` (or its repr) when one equation is
    identifiable.
    """

    def __init__(self, kind: str, message: str, *, program: str | None = None,
                 level: int | None = None, op: str | None = None,
                 eqn: object | None = None):
        where = program or ""
        if level is not None:
            where += f" L{level}"
        if op is not None:
            where += f".{op}"
        super().__init__(f"[{kind}] {where.strip()}: {message}"
                         if where.strip() else f"[{kind}] {message}")
        self.kind = kind
        self.message = message
        self.program = program
        self.level = level
        self.op = op
        self.eqn = eqn

    def to_dict(self) -> dict:
        eqn = self.eqn
        if isinstance(eqn, CollectiveRecord):
            eqn = eqn.to_dict()
        elif eqn is not None:
            eqn = str(eqn)
        return {"kind": self.kind, "message": self.message,
                "program": self.program, "level": self.level,
                "op": self.op, "eqn": eqn}


@dataclasses.dataclass
class CommAudit:
    """The audit record of one traced program: every collective found, the
    per-primitive counts, the expected counts (when an expectation applies)
    and any violations raised while checking them."""

    program: str
    records: list[CollectiveRecord]
    counts: dict[str, int]
    expected: dict[str, int] | None = None
    level: int | None = None
    op: str | None = None
    violations: list[AuditViolation] = dataclasses.field(default_factory=list)

    @property
    def n_collectives(self) -> int:
        return len(self.records)

    @property
    def total_bytes(self) -> int:
        return sum(r.bytes for r in self.records)

    @property
    def ok(self) -> bool:
        return not self.violations

    def signature(self) -> tuple[str, ...]:
        """Ordered canonical primitive names, as traced."""
        return tuple(r.primitive for r in self.records)

    def to_dict(self) -> dict:
        return {"program": self.program, "level": self.level, "op": self.op,
                "counts": dict(self.counts),
                "expected": None if self.expected is None
                else dict(self.expected),
                "n_collectives": self.n_collectives,
                "total_bytes": self.total_bytes,
                "ok": self.ok,
                "violations": [v.to_dict() for v in self.violations],
                "records": [r.to_dict() for r in self.records]}


@dataclasses.dataclass(frozen=True)
class LintViolation:
    """One rule violation in one source file (Pass 2)."""

    rule: str
    path: str
    line: int
    message: str

    def to_dict(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "message": self.message}

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"
