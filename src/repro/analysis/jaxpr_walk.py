"""Structural jaxpr traversal: find every collective primitive a traced
program contains, with axes / operand shapes / static byte counts, and
check the overlap dataflow property.

This replaces the fragile ``str(jax.make_jaxpr(...))`` substring checks the
tests used to carry — primitive *reprs* change across JAX versions, but the
primitive *names* and the equation dataflow do not.  Everything here is
version-proofed by duck-typing (an object with ``.eqns`` is a Jaxpr, one
with ``.jaxpr`` is a ClosedJaxpr) rather than by importing jax internals.
"""
from __future__ import annotations

import itertools

import numpy as np

from .records import CollectiveRecord

# jaxpr primitive names that move data between devices.  ``reduce_scatter``
# is what ``jax.lax.psum_scatter`` traces to; it is normalized to the
# canonical ``psum_scatter`` so audit records and the expected-signature
# tables in repro.core.nap_collectives speak one vocabulary.
COLLECTIVE_PRIMS = frozenset({
    "psum", "reduce_scatter", "all_gather", "all_to_all", "ppermute",
    "pmax", "pmin", "pmean",
})
CANONICAL = {"reduce_scatter": "psum_scatter"}

# local contraction work an overlapped exchange can hide behind: the ELL
# gather form ends in a reduce_sum, the BCSR/MXU and dense-factor forms in
# a dot_general
CONTRACTION_PRIMS = frozenset({"reduce_sum", "dot_general"})


def _as_jaxpr(obj):
    """ClosedJaxpr -> Jaxpr (identity on a Jaxpr)."""
    inner = getattr(obj, "jaxpr", None)
    return inner if inner is not None and hasattr(inner, "eqns") else obj


def _sub_jaxprs(params: dict):
    """Every Jaxpr nested in an equation's params (pjit ``jaxpr``,
    shard_map ``jaxpr``, custom-call ``call_jaxpr``, scan ``jaxpr``, lists
    of branches, ...)."""
    for v in params.values():
        items = v if isinstance(v, (list, tuple)) else (v,)
        for u in items:
            j = _as_jaxpr(u)
            if hasattr(j, "eqns"):
                yield j


def _axes_of(eqn) -> tuple[str, ...]:
    """Named mesh axes of one collective equation (``axes`` for psum-family,
    ``axis_name`` for gather/scatter/a2a/ppermute; bare name or tuple)."""
    ax = eqn.params.get("axes", eqn.params.get("axis_name", ()))
    if not isinstance(ax, (list, tuple)):
        ax = (ax,)
    return tuple(str(a) for a in ax)


def _record(eqn, idx: int) -> CollectiveRecord:
    op_aval = next(v.aval for v in eqn.invars if hasattr(v, "aval"))
    out_aval = eqn.outvars[0].aval
    nbytes = int(np.prod(op_aval.shape, dtype=np.int64)
                 * np.dtype(op_aval.dtype).itemsize)
    return CollectiveRecord(
        primitive=CANONICAL.get(eqn.primitive.name, eqn.primitive.name),
        axes=_axes_of(eqn),
        operand_shape=tuple(int(d) for d in op_aval.shape),
        operand_dtype=str(np.dtype(op_aval.dtype)),
        out_shape=tuple(int(d) for d in out_aval.shape),
        bytes=nbytes, eqn_index=idx)


def collect_collectives(jaxpr) -> list[CollectiveRecord]:
    """Every collective primitive in ``jaxpr`` (a Jaxpr or ClosedJaxpr),
    recursing into pjit / shard_map / control-flow sub-jaxprs, in trace
    order."""
    out: list[CollectiveRecord] = []
    counter = itertools.count()

    def walk(jx):
        for eqn in jx.eqns:
            idx = next(counter)
            if eqn.primitive.name in COLLECTIVE_PRIMS:
                out.append(_record(eqn, idx))
            for sub in _sub_jaxprs(eqn.params):
                walk(sub)

    walk(_as_jaxpr(jaxpr))
    return out


def collective_signature(jaxpr) -> tuple[str, ...]:
    """Ordered canonical collective-primitive names of ``jaxpr`` — the
    structural replacement for substring-matching the jaxpr's repr."""
    return tuple(r.primitive for r in collect_collectives(jaxpr))


def _collective_scopes(jaxpr):
    """Yield every (sub)jaxpr that contains a collective equation at its own
    scope — the scopes where the overlap dataflow property is checkable."""
    def walk(jx):
        if any(e.primitive.name in COLLECTIVE_PRIMS for e in jx.eqns):
            yield jx
        for eqn in jx.eqns:
            for sub in _sub_jaxprs(eqn.params):
                yield from walk(sub)

    yield from walk(_as_jaxpr(jaxpr))


def _scope_has_independent_contraction(jx) -> bool:
    """True when some contraction equation in ``jx`` does not transitively
    depend on any collective output.

    In the overlapped apply the exchange is issued first but ``A_on · x``
    consumes only local data, so its contraction is collective-independent;
    in the serial form ``xfull = concat([x, halo])`` taints every
    contraction.  Equations are in topological order in a jaxpr, so one
    forward sweep propagating a taint set decides it.
    """
    tainted: set = set()
    found = False
    for eqn in jx.eqns:
        depends = any((not hasattr(v, "val")) and v in tainted
                      for v in eqn.invars)
        if (eqn.primitive.name in CONTRACTION_PRIMS) and not depends:
            found = True
        if depends or eqn.primitive.name in COLLECTIVE_PRIMS:
            tainted.update(eqn.outvars)
    return found


def check_overlap_independence(jaxpr) -> bool:
    """The tentpole's overlap property: in every scope that communicates,
    at least one local contraction is dataflow-independent of the exchange
    (so XLA is free to run them concurrently).  Vacuously true for a
    collective-free program."""
    return all(_scope_has_independent_contraction(jx)
               for jx in _collective_scopes(jaxpr))
