"""Pass 1 — the communication auditor.

Walks the ClosedJaxpr of every compiled fused program a
:class:`~repro.amg.dist_solve.DistHierarchy` exposes (all cycle×smoother
pairs, PCG, the ``*_m`` multi-RHS variants) plus the per-operator applies,
extracts each collective primitive, and cross-checks against:

* the selected strategy's predicted structure (the per-strategy signature
  tables in :mod:`repro.core.nap_collectives`) — e.g. NAP-3 ``hier_psum``
  must lower to psum_scatter(fast) + psum(slow) + all_gather(fast), a
  ``halo_empty`` level must lower to zero collectives;
* the overlap dataflow property — with ``overlap=True`` the halo exchange
  must be dataflow-independent of the ``A_on`` contraction (checked by a
  taint sweep over the jaxpr's topological equation order);
* :func:`~repro.amg.dist_solve.cycle_comm_stats`' modeled counters — a
  level/op the model says communicates must have a non-empty plan, and vice
  versa;
* the setup-phase SpGEMM exchanges — the *measured* message/byte counters
  each :class:`~repro.amg.dist_setup.SetupCommRecord` carries must equal
  the static :class:`~repro.core.schedules.ScheduleStats` of the schedule
  that was selected and cached for replay.

Any mismatch is a typed :class:`~repro.analysis.records.AuditViolation`
with the offending equation and level/op attribution.
"""
from __future__ import annotations

import math
from collections import Counter

from .jaxpr_walk import check_overlap_independence, collect_collectives
from .records import AuditViolation, CommAudit

#: the fused programs DistHierarchy.programs exposes (single-RHS + _m)
PROGRAM_NAMES = ("resid_norm", "cycle", "vcycle", "pcg_init", "pcg_step",
                 "resid_norm_m", "cycle_m", "vcycle_m", "pcg_init_m",
                 "pcg_step_m")


def _counts(records) -> dict[str, int]:
    return dict(Counter(r.primitive for r in records))


def audit_jaxpr(jaxpr, program: str, *,
                expected_signature: tuple[str, ...] | None = None,
                expected_counts: dict[str, int] | None = None,
                require_overlap: bool = False,
                level: int | None = None, op: str | None = None) -> CommAudit:
    """Audit one traced program against an expected structure.

    ``expected_signature`` checks the *ordered* primitive sequence (the
    per-operator granularity — exact strategy lowering); ``expected_counts``
    checks per-primitive totals (the fused-program granularity, where many
    applies interleave).  ``require_overlap`` additionally demands a
    collective-independent local contraction in every communicating scope.
    """
    records = collect_collectives(jaxpr)
    audit = CommAudit(program=program, records=records,
                      counts=_counts(records), level=level, op=op)
    sig = audit.signature()
    if expected_signature is not None:
        audit.expected = dict(Counter(expected_signature))
        if sig != tuple(expected_signature):
            eqn = next((r for r in records
                        if r.primitive not in expected_signature),
                       records[0] if records else None)
            kind = ("empty-halo-collective" if not expected_signature
                    else "signature-mismatch")
            audit.violations.append(AuditViolation(
                kind,
                f"lowered collectives {list(sig)} != expected "
                f"{list(expected_signature)}",
                program=program, level=level, op=op, eqn=eqn))
    if expected_counts is not None:
        audit.expected = {k: v for k, v in expected_counts.items() if v}
        actual = audit.counts
        if audit.expected != {k: v for k, v in actual.items() if v}:
            prims = sorted(set(audit.expected) | set(actual))
            diff = "; ".join(
                f"{p}: expected {audit.expected.get(p, 0)}, "
                f"got {actual.get(p, 0)}"
                for p in prims
                if audit.expected.get(p, 0) != actual.get(p, 0))
            surplus = next(
                (r for r in records
                 if actual.get(r.primitive, 0)
                 > audit.expected.get(r.primitive, 0)), None)
            audit.violations.append(AuditViolation(
                "count-mismatch", diff, program=program, level=level, op=op,
                eqn=surplus))
    if require_overlap and records and not check_overlap_independence(jaxpr):
        audit.violations.append(AuditViolation(
            "overlap-serialized",
            "every local contraction depends on the halo exchange — the "
            "overlapped apply has been serialized",
            program=program, level=level, op=op))
    return audit


def audit_apply(dh, level: int, op: str = "A",
                overlap: bool | None = None) -> CommAudit:
    """Per-operator audit: one SpMV apply of ``levels[level].<op>`` must
    lower to exactly the selected strategy's ordered halo signature (empty
    for an empty-halo plan), and — when overlapped — keep the on-process
    contraction dataflow-independent of the exchange."""
    overlap = dh.overlap if overlap is None else overlap
    jaxpr = dh.trace_apply(level, op, overlap=overlap)
    return audit_jaxpr(
        jaxpr, f"apply_{op}",
        expected_signature=dh.expected_apply_signature(level, op),
        require_overlap=overlap, level=level, op=op)


def audit_program(dh, name: str, opts=None, k: int = 2,
                  label: str | None = None) -> CommAudit:
    """Fused-program audit: per-primitive collective counts of the traced
    program must equal the counts the cycle structure + selected strategies
    predict (:meth:`DistHierarchy.expected_collectives`).  ``label``
    overrides the record's program name (e.g. ``vcycle[W+chebyshev]``)."""
    jaxpr = dh.trace_program(name, opts, k=k)
    return audit_jaxpr(jaxpr, label or name,
                       expected_counts=dh.expected_collectives(opts, name),
                       require_overlap=dh.overlap)


def audit_cycle_stats(dh, opts=None) -> list[AuditViolation]:
    """Model-vs-static agreement: a (level, op) whose modeled per-cycle
    counters (:func:`cycle_comm_stats`' per-level rows, from the selected
    schedule's :class:`ScheduleStats`) say it communicates must have a
    non-empty halo plan, and vice versa — plus finiteness of the totals."""
    from ..amg.dist_solve import cycle_comm_stats
    out: list[AuditViolation] = []
    stats = cycle_comm_stats(dh, opts)
    for key in ("inter_msgs", "intra_msgs", "inter_bytes", "intra_bytes"):
        if not math.isfinite(stats[key]) or stats[key] < 0:
            out.append(AuditViolation(
                "stats-nonfinite", f"cycle_comm_stats[{key}]={stats[key]}",
                program="cycle_comm_stats"))
    for l, dl in enumerate(dh.levels):
        for stat_key, attr in (("spmv_A", "A"), ("interp", "P"),
                               ("restrict", "R")):
            if stat_key not in dl.comm_stats:
                continue
            dop = getattr(dl, attr)
            if dop is None:
                continue
            row = dl.comm_stats[stat_key]
            modeled_msgs = row["inter_msgs"] + row["intra_msgs"]
            static_empty = dop.plan.total_halo == 0
            if static_empty and modeled_msgs > 0:
                out.append(AuditViolation(
                    "model-static-disagreement",
                    f"model prices {modeled_msgs} msgs/apply but the halo "
                    f"plan is empty", program="cycle_comm_stats",
                    level=l, op=attr))
            if not static_empty and modeled_msgs == 0:
                out.append(AuditViolation(
                    "model-static-disagreement",
                    f"halo plan moves {dop.plan.total_halo} entries but the "
                    f"model prices zero messages",
                    program="cycle_comm_stats", level=l, op=attr))
    return out


def audit_setup(plevels, records) -> tuple[list[dict], list[AuditViolation]]:
    """Setup-phase SpGEMM audit: for every exchange whose schedule was
    cached for replay (:attr:`PartitionedLevel.plans`), the *measured*
    message/byte counters of the executed
    :func:`~repro.core.nap_collectives.matrix_halo_exchange` must equal the
    counts statically derivable from the selected schedule.  Inter-node
    counts come from :class:`~repro.core.schedules.ScheduleStats`; the
    intra count is re-derived with the exchange's own semantics (EVERY
    same-node message — ``ScheduleStats`` deliberately excludes the
    direct on-node messages common to all strategies, paper §3.3).
    Returns (summary rows, violations)."""
    from ..core.schedules import ScheduleStats

    def static_intra(schedule):
        g, topo = schedule.graph, schedule.graph.topo
        cnt = 0
        for _kind, msg in schedule.all_messages():
            if topo.on_same_node(msg.src, msg.dst):
                cnt += 1
        return cnt

    rows: list[dict] = []
    violations: list[AuditViolation] = []
    by_key = {}
    for rec in records:                     # refresh replays: last one wins
        by_key[(rec.level, rec.op)] = rec
    for l, plv in enumerate(plevels):
        for op, (strat, plan) in sorted(plv.plans.items()):
            rec = by_key.get((l, op))
            if rec is None:
                violations.append(AuditViolation(
                    "missing-record",
                    f"schedule cached for {op} but no SetupCommRecord was "
                    f"measured", program="dist_setup", level=l, op=op))
                continue
            st = ScheduleStats.of(plan.schedule)
            row = {"level": l, "op": op, "strategy": strat,
                   "static_inter_msgs": st.inter_msg_count,
                   "runtime_inter_msgs": rec.inter_msgs,
                   "static_intra_msgs": static_intra(plan.schedule),
                   "runtime_intra_msgs": rec.intra_msgs,
                   "static_inter_bytes": st.inter_bytes_total,
                   "runtime_inter_bytes": rec.inter_bytes}
            rows.append(row)
            if rec.strategy != strat:
                violations.append(AuditViolation(
                    "strategy-mismatch",
                    f"record ran {rec.strategy!r} but the cached schedule "
                    f"is {strat!r}", program="dist_setup", level=l, op=op))
            for static, runtime in (("static_inter_msgs",
                                     "runtime_inter_msgs"),
                                    ("static_intra_msgs",
                                     "runtime_intra_msgs")):
                if row[static] != row[runtime]:
                    violations.append(AuditViolation(
                        "setup-count-mismatch",
                        f"{runtime}={row[runtime]} != {static}={row[static]}"
                        f" for the selected {strat} schedule",
                        program="dist_setup", level=l, op=op))
            if not math.isclose(row["static_inter_bytes"],
                                row["runtime_inter_bytes"],
                                rel_tol=1e-9, abs_tol=1e-6):
                violations.append(AuditViolation(
                    "setup-bytes-mismatch",
                    f"measured inter bytes {row['runtime_inter_bytes']} != "
                    f"modeled {row['static_inter_bytes']}",
                    program="dist_setup", level=l, op=op))
    return rows, violations


def audit_hierarchy(dh, *, pairs=None, pair_programs=("vcycle", "vcycle_m"),
                    full_opts=None, k: int = 2,
                    ) -> tuple[list[CommAudit], list[AuditViolation]]:
    """The whole Pass-1 sweep over one lowered hierarchy.

    * every (cycle, smoother) pair in ``pairs`` (default: the full 15-pair
      grid) through ``pair_programs``,
    * the complete program set (PCG included, ``*_m`` variants included)
      for ``full_opts`` (default ``SolveOptions()``),
    * every per-level operator apply (exact ordered strategy signature +
      overlap independence),
    * the modeled-counter agreement of :func:`cycle_comm_stats` per pair.

    Returns ``(audits, violations)`` — ``violations`` aggregates every
    audit's findings plus the stats-agreement findings.
    """
    from ..amg.solve import CYCLES, SMOOTHERS, SolveOptions
    if pairs is None:
        pairs = [(c, s) for c in CYCLES for s in SMOOTHERS]
    full_opts = full_opts or SolveOptions()
    audits: list[CommAudit] = []
    violations: list[AuditViolation] = []
    for cycle, smoother in pairs:
        opts = SolveOptions(cycle=cycle, smoother=smoother)
        for name in pair_programs:
            audits.append(audit_program(
                dh, name, opts, k=k, label=f"{name}[{cycle}+{smoother}]"))
        violations.extend(audit_cycle_stats(dh, opts))
    for name in PROGRAM_NAMES:
        audits.append(audit_program(dh, name, full_opts, k=k))
    for l, dl in enumerate(dh.levels):
        for op in ("A", "P", "R"):
            if getattr(dl, op) is not None:
                audits.append(audit_apply(dh, l, op))
    for a in audits:
        violations.extend(a.violations)
    return audits, violations
