"""Fault-tolerant checkpointing.

Guarantees:
* **Atomicity** — write to ``step_<n>.tmp.<pid>`` then ``os.rename`` (POSIX
  atomic); a crash mid-save never corrupts the latest checkpoint.
* **Auto-resume** — :func:`latest_step` scans the directory; the train loop
  restores and continues (data pipeline is (seed, step)-deterministic).
* **Elastic restore** — arrays are stored as *global* numpy (device arrays
  are gathered via np.asarray); on restore they are re-sharded to whatever
  mesh the new job runs, so restarts may change device count/topology.
* **Async save** — :func:`save_async` snapshots to host memory synchronously
  (cheap) and writes the file in a background thread, overlapping I/O with
  the next training steps; the returned handle joins on the next save to
  preserve ordering.
* **Multi-host** — each host writes ``shard_<host>`` of host-local data
  (here: single host; layout kept host-aware for the real cluster).

Format: one ``.npz`` per checkpoint with path-flattened leaves + a JSON
sidecar carrying the step, pytree structure and user metadata.
"""
from __future__ import annotations

import json
import os
import re
import threading

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype not in (np.float64, np.float32, np.float16, np.int64,
                             np.int32, np.int16, np.int8, np.uint8, np.bool_):
            arr = arr.astype(np.float32)   # bf16/f8 → f32 (lossless upcast)
        out[key] = arr
    return out


def _path_str(entry) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return str(entry.idx)
    return str(entry)


def save(ckpt_dir: str, step: int, tree, metadata: dict | None = None,
         keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    arrays = _flatten_with_paths(tree)
    tmp = os.path.join(ckpt_dir, f"step_{step:09d}.tmp.{os.getpid()}")
    final = os.path.join(ckpt_dir, f"step_{step:09d}.npz")
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    meta = {"step": step, "n_arrays": len(arrays), **(metadata or {})}
    with open(tmp + ".json", "w") as f:
        json.dump(meta, f)
    os.rename(tmp + ".json", final + ".json")
    os.rename(tmp, final)                      # atomic publish
    _gc(ckpt_dir, keep)
    return final


_pending: list[threading.Thread] = []


def save_async(ckpt_dir: str, step: int, tree, metadata: dict | None = None,
               keep: int = 3) -> threading.Thread:
    """Snapshot now (host copy), write in background."""
    for t in list(_pending):                   # ordering barrier
        t.join()
        _pending.remove(t)
    snapshot = _flatten_with_paths(tree)       # device→host copy happens here

    def writer():
        tmp = os.path.join(ckpt_dir, f"step_{step:09d}.tmp.{os.getpid()}")
        final = os.path.join(ckpt_dir, f"step_{step:09d}.npz")
        os.makedirs(ckpt_dir, exist_ok=True)
        with open(tmp, "wb") as f:
            np.savez(f, **snapshot)
        meta = {"step": step, "n_arrays": len(snapshot), **(metadata or {})}
        with open(tmp + ".json", "w") as f:
            json.dump(meta, f)
        os.rename(tmp + ".json", final + ".json")
        os.rename(tmp, final)
        _gc(ckpt_dir, keep)

    t = threading.Thread(target=writer, daemon=True)
    t.start()
    _pending.append(t)
    return t


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := re.fullmatch(r"step_(\d+)\.npz", f))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, template, shardings=None):
    """Rebuild ``template``-structured pytree from disk.

    ``template`` supplies structure + dtypes (e.g. from jax.eval_shape).
    ``shardings`` (optional, same structure or a callable path→sharding)
    re-shards each array onto the current mesh — the elastic-restart path.
    """
    path = os.path.join(ckpt_dir, f"step_{step:09d}.npz")
    data = np.load(path)
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat:
        key = "/".join(_path_str(e) for e in p)
        arr = data[key]
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        if shardings is not None:
            sh = shardings(key) if callable(shardings) else None
            leaves.append(jax.device_put(arr, sh) if sh is not None
                          else jax.device_put(arr))
        else:
            leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, [l for l in leaves])


def _gc(ckpt_dir: str, keep: int):
    steps = sorted([int(m.group(1)) for f in os.listdir(ckpt_dir)
                    if (m := re.fullmatch(r"step_(\d+)\.npz", f))])
    for s in steps[:-keep] if keep else []:
        for suffix in (".npz", ".npz.json"):
            try:
                os.remove(os.path.join(ckpt_dir, f"step_{s:09d}{suffix}"))
            except OSError:
                pass
