"""Strength-of-connection (Algorithm 1, ``strength``).

Classical (Ruge-Stüben) and symmetric (smoothed-aggregation) measures, both
with the paper's strength tolerance default of 0.25.
"""
from __future__ import annotations

import numpy as np

from .csr import CSR


def classical_strength(A: CSR, theta: float = 0.25) -> CSR:
    """S[i,j] = 1 where -a_ij >= theta * max_k(-a_ik)  (negative coupling);
    falls back to |a_ij| for rows with no negative off-diagonals."""
    r = A.rows_expanded()
    offdiag = r != A.indices
    neg = np.where(offdiag, -A.data, -np.inf)
    # per-row max of negative couplings
    rowmax = np.full(A.nrows, -np.inf)
    np.maximum.at(rowmax, r, neg)
    use_abs = ~np.isfinite(rowmax) | (rowmax <= 0)
    absval = np.where(offdiag, np.abs(A.data), -np.inf)
    rowmax_abs = np.full(A.nrows, -np.inf)
    np.maximum.at(rowmax_abs, r, absval)
    thresh = np.where(use_abs, rowmax_abs, rowmax)[r] * theta
    meas = np.where(use_abs[r], np.abs(A.data), -A.data)
    keep = offdiag & (meas >= thresh) & (meas > 0)
    indptr = np.zeros(A.nrows + 1, dtype=np.int64)
    np.cumsum(np.bincount(r[keep], minlength=A.nrows), out=indptr[1:])
    return CSR(A.shape, indptr, A.indices[keep], np.ones(int(keep.sum())))


def symmetric_strength(A: CSR, theta: float = 0.25) -> CSR:
    """SA strength, row-max scaled: |a_ij| >= theta * max_{k≠i} |a_ik|.

    (The textbook √(a_ii·a_jj) scaling empties wide low-magnitude stencils
    such as the 27-point Laplacian at θ=0.25; row-max scaling preserves the
    paper's θ=0.25 semantics across our test problems.)
    """
    r = A.rows_expanded()
    offdiag = r != A.indices
    absval = np.where(offdiag, np.abs(A.data), -np.inf)
    rowmax = np.full(A.nrows, -np.inf)
    np.maximum.at(rowmax, r, absval)
    keep = offdiag & (np.abs(A.data) >= theta * rowmax[r]) & (np.abs(A.data) > 0)
    indptr = np.zeros(A.nrows + 1, dtype=np.int64)
    np.cumsum(np.bincount(r[keep], minlength=A.nrows), out=indptr[1:])
    return CSR(A.shape, indptr, A.indices[keep], np.ones(int(keep.sum())))
