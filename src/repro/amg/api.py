"""Session API: one configurable, cacheable, multi-RHS solver object.

The expensive parts of a node-aware AMG solve — the host ``Hierarchy``
(setup phase), the lowered :class:`~repro.amg.dist_solve.DistHierarchy`
(comm graphs, per-level strategy selection, halo plans) and its compiled
shard_map programs — are built **once** per (matrix fingerprint, config)
and reused across any number of solves, the way a parallel AMG code builds
its MPI communicators once and amortizes them (Bienz et al.'s
communicator-reuse argument for node-aware SpMV).

Surface::

    cfg = AMGConfig(solver="rs", backend="dist", n_pods=2, lanes=4)
    bound = AMGSolver(cfg).setup(A)      # cached per (matrix, config)
    res = bound.solve(b)                 # b: [n] or [n, k] (multi-RHS)
    res = bound.pcg(b, x0=x_warm)
    x = bound.vcycle(b)                  # one preconditioner application

Backends register through :func:`register_backend`; ``"host"`` (numpy
reference) and ``"dist"`` (device-resident fused cycle) ship here, and
future backends (an SA variant, say) plug in without touching call sites.
The cycle shape and smoother live in ``config.opts``
(:class:`~repro.amg.solve.SolveOptions`: V/W/F cycles ×
jacobi/chebyshev/block_jacobi/hybrid_gs) — they are *solve* knobs, so two
configs that differ only there share one hierarchy, one dist lowering, and
differ only in which compiled cycle program runs.
:class:`SolverEngine` drains ``(matrix_id, b)``
requests against the session cache, batching same-matrix right-hand sides
through one multi-RHS device trace — the serving entrypoint behind
``repro.launch.serve --solver amg``.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict

import numpy as np

from .csr import CSR
from .hierarchy import Hierarchy, setup as _hierarchy_setup
from .solve import (MultiSolveResult, SolveOptions, host_pcg, host_solve,
                    host_vcycle)

__all__ = [
    "AMGConfig", "AMGSolver", "BoundSolver", "SolverEngine", "SolveRequest",
    "available_backends", "bind_hierarchy", "clear_sessions",
    "matrix_fingerprint", "register_backend", "session_count",
]

_DTYPES = ("float32", "float64", "bfloat16")


# --------------------------------------------------------------------------
# Configuration
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AMGConfig:
    """Frozen, hashable description of a full solver session: setup knobs,
    smoother options, iteration defaults, and backend/mesh/strategy/kernel
    knobs.  Hashability is what makes it a cache key — two configs that
    compare equal always produce interchangeable solvers."""

    # -- setup phase (Algorithm 1)
    solver: str = "rs"                   # "rs" | "sa"
    theta: float = 0.25
    max_coarse: int = 100
    max_levels: int = 25
    aggressive: bool = False
    prolongation_sweeps: int = 1
    seed: int = 42
    # "host": serial numpy setup; "dist": the partitioned node-aware setup
    # (repro.amg.dist_setup) — levels are born partitioned and only the
    # "dist" solve backend can consume them
    setup_backend: str = "host"
    # -- solve phase (Algorithm 2): cycle shape, smoother, sweep counts
    # (pure solve knobs — sessions differing only here share setup+lowering)
    opts: SolveOptions = dataclasses.field(default_factory=SolveOptions)
    tol: float = 1e-8
    maxiter: int = 100
    pcg_maxiter: int = 200
    # -- backend + mesh + strategy + kernel knobs
    backend: str = "host"                # registry name: "host" | "dist" | …
    n_pods: int = 1
    lanes: int = 1
    strategy: str = "auto"               # "auto" | "standard" | "nap2" | "nap3"
    machine: str = "tpu_v5e"             # repro.core.MACHINES name
    dtype: str = "float32"
    use_kernel: bool | None = None       # None = auto (Pallas ELL on TPU)
    interpret: bool | None = None        # None = auto (interpret off-TPU)
    reduce_strategy: str = "nap3"        # norms/dots: "nap3" | "flat"

    def __post_init__(self):
        if self.dtype not in _DTYPES:
            raise ValueError(f"dtype must be one of {_DTYPES}, "
                             f"got {self.dtype!r}")
        if self.setup_backend not in ("host", "dist"):
            raise ValueError(f"setup_backend must be 'host' or 'dist', "
                             f"got {self.setup_backend!r}")
        if self.setup_backend == "dist" and self.backend != "dist":
            raise ValueError(
                "setup_backend='dist' births partitioned levels that only "
                f"backend='dist' can consume (got backend={self.backend!r})")
        if self.setup_backend == "dist" and self.solver != "rs":
            raise ValueError(
                "setup_backend='dist' supports solver='rs' only "
                f"(got solver={self.solver!r})")
        from ..core import MACHINES
        if self.machine not in MACHINES:
            raise ValueError(f"unknown machine {self.machine!r}; "
                             f"known: {sorted(MACHINES)}")

    def replace(self, **changes) -> "AMGConfig":
        return dataclasses.replace(self, **changes)

    # ------------------------------------------------------------ round-trip
    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)       # recurses into opts
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "AMGConfig":
        d = dict(d)
        opts = d.pop("opts", None)
        if isinstance(opts, dict):
            opts = SolveOptions(**opts)
        return cls(opts=opts or SolveOptions(), **d)

    # ------------------------------------------------------- derived kwargs
    def setup_kwargs(self) -> dict:
        return dict(solver=self.solver, theta=self.theta,
                    max_coarse=self.max_coarse, max_levels=self.max_levels,
                    aggressive=self.aggressive,
                    prolongation_sweeps=self.prolongation_sweeps,
                    seed=self.seed)

    def dist_build_kwargs(self) -> dict:
        """Kwargs for ``DistHierarchy.build`` (resolves machine + dtype)."""
        import jax.numpy as jnp

        from ..core import MACHINES
        dtype = {"float32": jnp.float32, "float64": jnp.float64,
                 "bfloat16": jnp.bfloat16}[self.dtype]
        return dict(n_pods=self.n_pods, lanes=self.lanes,
                    params=MACHINES[self.machine], strategy=self.strategy,
                    dtype=dtype, use_kernel=self.use_kernel,
                    interpret=self.interpret,
                    reduce_strategy=self.reduce_strategy)


def matrix_fingerprint(A: CSR) -> str:
    """Content hash of a CSR matrix — the matrix half of the session key."""
    h = hashlib.sha1()
    h.update(np.asarray(A.shape, dtype=np.int64).tobytes())
    h.update(np.ascontiguousarray(A.indptr).tobytes())
    h.update(np.ascontiguousarray(A.indices).tobytes())
    h.update(np.ascontiguousarray(A.data).tobytes())
    return h.hexdigest()


# --------------------------------------------------------------------------
# Backend registry
# --------------------------------------------------------------------------

_BACKENDS: dict[str, type] = {}


def register_backend(name: str):
    """Class decorator: make a :class:`BoundSolver` subclass reachable as
    ``AMGConfig(backend=name)`` / ``solve(..., backend=name)``."""
    def deco(cls):
        cls.backend_name = name
        _BACKENDS[name] = cls
        return cls
    return deco


def available_backends() -> list[str]:
    return sorted(_BACKENDS)


def backend_class(name: str) -> type:
    try:
        return _BACKENDS[name]
    except KeyError:
        raise ValueError(f"unknown backend {name!r}; registered backends: "
                         f"{available_backends()}") from None


def bind_hierarchy(h: Hierarchy, backend: str = "host", dist=None,
                   opts: SolveOptions | None = None) -> "BoundSolver":
    """Wrap an existing host hierarchy in the named backend's bound solver.

    This is what the free functions ``solve`` / ``pcg`` / ``vcycle`` call;
    ``dist=`` carries the legacy prebuilt-``DistHierarchy``-or-kwargs-dict
    argument (dict kwargs hit the per-hierarchy cache).
    """
    return backend_class(backend).from_hierarchy(h, dist=dist, opts=opts)


# --------------------------------------------------------------------------
# Bound solvers
# --------------------------------------------------------------------------


class BoundSolver:
    """A hierarchy bound to one backend: the object that owns all caching.

    Created by :meth:`AMGSolver.setup` (full session: matrix → hierarchy →
    backend lowering) or :func:`bind_hierarchy` (wrap an existing
    hierarchy).  ``solve``/``pcg`` accept ``b`` of shape ``[n]`` or
    ``[n, k]``; the multi-RHS form returns a
    :class:`~repro.amg.solve.MultiSolveResult`.
    """

    backend_name = "?"

    def __init__(self, config: AMGConfig, hierarchy: Hierarchy | None):
        # ``hierarchy`` is None on the setup_backend="dist" path: the levels
        # were born partitioned and no host Hierarchy ever existed.
        self.config = config
        self.hierarchy = hierarchy

    @classmethod
    def from_hierarchy(cls, h: Hierarchy, dist=None,
                       opts: SolveOptions | None = None) -> "BoundSolver":
        return cls(AMGConfig(backend=cls.backend_name,
                             opts=opts or SolveOptions()), h)

    # ------------------------------------------------------------ properties
    @property
    def A(self) -> CSR:
        if self.hierarchy is None:
            raise ValueError(
                "this solver was set up with setup_backend='dist': levels "
                "are partitioned across the mesh and no global fine-grid "
                "CSR exists")
        return self.hierarchy.levels[0].A

    @property
    def n(self) -> int:
        return self.A.nrows

    @property
    def opts(self) -> SolveOptions:
        return self.config.opts

    def _check_b(self, b) -> np.ndarray:
        b = np.asarray(b, dtype=np.float64)
        if b.ndim not in (1, 2) or b.shape[0] != self.n:
            raise ValueError(f"b must be [{self.n}] or [{self.n}, k], "
                             f"got shape {b.shape}")
        return b

    # -------------------------------------------------------------- methods
    def solve(self, b, *, tol: float | None = None,
              maxiter: int | None = None, x0=None):
        raise NotImplementedError

    def pcg(self, b, *, tol: float | None = None,
            maxiter: int | None = None, x0=None):
        raise NotImplementedError

    def vcycle(self, b, x0=None):
        raise NotImplementedError


@register_backend("host")
class HostBoundSolver(BoundSolver):
    """Reference numpy backend; multi-RHS runs k independent column solves."""

    def _per_column(self, fn, b, x0):
        cols, xs = [], []
        for j in range(b.shape[1]):
            r = fn(b[:, j], None if x0 is None else x0[:, j])
            cols.append(r)
            xs.append(r.x)
        return MultiSolveResult(np.stack(xs, axis=1), cols)

    def solve(self, b, *, tol=None, maxiter=None, x0=None):
        b = self._check_b(b)
        tol = self.config.tol if tol is None else tol
        maxiter = self.config.maxiter if maxiter is None else maxiter
        run = lambda bc, xc: host_solve(self.hierarchy, bc, tol=tol,
                                        maxiter=maxiter, opts=self.opts,
                                        x0=xc)
        if b.ndim == 2:
            return self._per_column(run, b, x0)
        return run(b, x0)

    def pcg(self, b, *, tol=None, maxiter=None, x0=None):
        b = self._check_b(b)
        tol = self.config.tol if tol is None else tol
        maxiter = self.config.pcg_maxiter if maxiter is None else maxiter
        run = lambda bc, xc: host_pcg(self.hierarchy, bc, tol=tol,
                                      maxiter=maxiter, opts=self.opts, x0=xc)
        if b.ndim == 2:
            return self._per_column(run, b, x0)
        return run(b, x0)

    def vcycle(self, b, x0=None):
        b = self._check_b(b)
        if b.ndim == 2:
            x0c = (lambda j: None) if x0 is None else (lambda j: x0[:, j])
            return np.stack([host_vcycle(self.hierarchy, b[:, j], x0c(j),
                                         self.opts)
                             for j in range(b.shape[1])], axis=1)
        return host_vcycle(self.hierarchy, b, x0, self.opts)


@register_backend("dist")
class DistBoundSolver(BoundSolver):
    """Device-resident backend: lazily lowers the hierarchy onto the mesh
    ONCE and reuses the ``DistHierarchy`` (and its compiled programs, cached
    inside it per option set) for every subsequent call."""

    def __init__(self, config: AMGConfig, hierarchy: Hierarchy):
        super().__init__(config, hierarchy)
        self._dist = None

    @classmethod
    def from_hierarchy(cls, h, dist=None, opts=None):
        from .dist_solve import _ensure_dist
        self = cls(AMGConfig(backend=cls.backend_name,
                             opts=opts or SolveOptions()), h)
        self._dist = _ensure_dist(h, dist)     # raises when dist is missing
        return self

    @classmethod
    def from_dist_setup(cls, config: AMGConfig, dh) -> "DistBoundSolver":
        """Bind a hierarchy that was **born partitioned** (the
        ``setup_backend="dist"`` path): there is no host ``Hierarchy``, only
        the already-lowered ``DistHierarchy``."""
        self = cls(config, None)
        self._dist = dh
        return self

    @property
    def n(self) -> int:
        if self.hierarchy is None:
            return self._dist.levels[0].A.row_part.n
        return self.A.nrows

    @property
    def dist_hierarchy(self):
        """The lowered hierarchy; built on first access, then reused.

        The build goes through the per-hierarchy ``dist_cache``, so bound
        solvers that share a hierarchy (configs differing only in iteration
        defaults, say) also share one lowering.
        """
        if self._dist is None:
            from .dist_solve import _ensure_dist
            self._dist = _ensure_dist(self.hierarchy,
                                      self.config.dist_build_kwargs())
        return self._dist

    def solve(self, b, *, tol=None, maxiter=None, x0=None):
        from .dist_solve import dist_solve
        b = self._check_b(b)
        tol = self.config.tol if tol is None else tol
        maxiter = self.config.maxiter if maxiter is None else maxiter
        return dist_solve(self.dist_hierarchy, b, tol=tol, maxiter=maxiter,
                          opts=self.opts, x0=x0)

    def pcg(self, b, *, tol=None, maxiter=None, x0=None):
        from .dist_solve import dist_pcg
        b = self._check_b(b)
        tol = self.config.tol if tol is None else tol
        maxiter = self.config.pcg_maxiter if maxiter is None else maxiter
        return dist_pcg(self.dist_hierarchy, b, tol=tol, maxiter=maxiter,
                        opts=self.opts, x0=x0)

    def vcycle(self, b, x0=None):
        from .dist_solve import dist_vcycle
        if x0 is not None:
            raise ValueError("dist vcycle starts from x=0; x0= is not "
                             "supported on the dist backend")
        return dist_vcycle(self.dist_hierarchy, self._check_b(b), self.opts)


# --------------------------------------------------------------------------
# The session object + cache
# --------------------------------------------------------------------------

SESSION_CACHE_SIZE = 16
_SESSIONS: "OrderedDict[tuple[str, AMGConfig], BoundSolver]" = OrderedDict()
# hierarchies keyed by (matrix fingerprint, setup kwargs) only, so configs
# that differ in solve/backend knobs share one setup (and, through the
# hierarchy's dist_cache, one lowering).  setup_backend="dist" entries hold
# a born-partitioned DistHierarchy instead of a host Hierarchy (keyed with
# the mesh/strategy/dtype knobs the lowering depends on).
_SETUPS: "OrderedDict[tuple, object]" = OrderedDict()


def clear_sessions() -> None:
    _SESSIONS.clear()
    _SETUPS.clear()


def _cache_put(cache: OrderedDict, key, value) -> None:
    """Insert with oldest-first eviction at the shared cache size."""
    cache[key] = value
    while len(cache) > SESSION_CACHE_SIZE:
        cache.popitem(last=False)


def session_count() -> int:
    return len(_SESSIONS)


class AMGSolver:
    """The session entrypoint: ``AMGSolver(config).setup(A)`` returns a
    :class:`BoundSolver` cached per (matrix fingerprint, config) — repeated
    setup of the same matrix under the same config is free, and every solve
    through the bound object reuses the lowered hierarchy and its compiled
    programs.  Configs that differ only in knobs irrelevant to the setup
    phase (tol/maxiter, backend, mesh, …) get distinct bound solvers that
    share ONE host hierarchy."""

    def __init__(self, config: AMGConfig | None = None, **overrides):
        if config is None:
            config = AMGConfig(**overrides)
        elif overrides:
            config = dataclasses.replace(config, **overrides)
        backend_class(config.backend)        # fail fast on unknown backend
        self.config = config

    def setup(self, A: CSR) -> BoundSolver:
        fp = matrix_fingerprint(A)
        key = (fp, self.config)
        bound = _SESSIONS.get(key)
        if bound is not None:
            _SESSIONS.move_to_end(key)
            return bound
        if self.config.setup_backend == "dist":
            bound = self._setup_dist(A, fp)
        else:
            skw = self.config.setup_kwargs()
            skey = (fp, tuple(sorted(skw.items())))
            h = _SETUPS.get(skey)
            if h is None:
                h = _hierarchy_setup(A, **skw)
                _cache_put(_SETUPS, skey, h)
            else:
                _SETUPS.move_to_end(skey)
            bound = backend_class(self.config.backend)(self.config, h)
        _cache_put(_SESSIONS, key, bound)
        return bound

    def _setup_dist(self, A: CSR, fp: str) -> BoundSolver:
        """The setup_backend="dist" path: run the partitioned node-aware
        setup (NAP SpGEMM Galerkin products) and bind the resulting
        DistHierarchy.  Two cache tiers mirror the host path's setup/lower
        split: the partitioned blocks are keyed by the knobs the setup loop
        depends on (setup kwargs + mesh + strategy + machine), the lowered
        DistHierarchy additionally by the pure lowering knobs — so configs
        differing only in dtype/kernel/reduce knobs re-lower but never
        re-run the setup loop, and solve-knob-only changes share both."""
        c = self.config
        base = (fp, tuple(sorted(c.setup_kwargs().items())),
                c.n_pods, c.lanes, c.strategy, c.machine)
        skey = base + ("dist_lowered", c.dtype, c.use_kernel, c.interpret,
                       c.reduce_strategy)
        dh = _SETUPS.get(skey)
        if dh is None:
            pkey = base + ("dist_partitioned",)
            cached = _SETUPS.get(pkey)
            if cached is None:
                from ..core import MACHINES
                from .dist_setup import dist_setup_partitioned
                plevels, records = dist_setup_partitioned(
                    A, c.n_pods, c.lanes, params=MACHINES[c.machine],
                    strategy=c.strategy, **c.setup_kwargs())
                _cache_put(_SETUPS, pkey, (plevels, records))
            else:
                plevels, records = cached
                _SETUPS.move_to_end(pkey)
            from .dist_solve import DistHierarchy
            bk = c.dist_build_kwargs()
            dh = DistHierarchy.from_partitioned(
                plevels, bk.pop("n_pods"), bk.pop("lanes"),
                setup_records=records, **bk)
            _cache_put(_SETUPS, skey, dh)
        else:
            _SETUPS.move_to_end(skey)
        return backend_class(c.backend).from_dist_setup(c, dh)


# --------------------------------------------------------------------------
# Serving: drain (matrix_id, b) requests against the session cache
# --------------------------------------------------------------------------


@dataclasses.dataclass
class SolveRequest:
    rid: int
    matrix_id: str
    b: np.ndarray
    method: str = "solve"        # "solve" | "pcg"


class SolverEngine:
    """Request-draining solver service (the serving story's first step).

    Matrices are registered once under an id; submitted requests are grouped
    by (matrix_id, method) and same-matrix right-hand sides are stacked into
    ``[n, k]`` batches (up to ``max_rhs``) so one multi-RHS V-cycle trace
    serves the whole group.  The underlying :class:`AMGSolver` session cache
    means the hierarchy — and on the dist backend the lowered
    ``DistHierarchy`` + compiled programs — is built once per matrix.
    """

    def __init__(self, config: AMGConfig | None = None, max_rhs: int = 8):
        self.solver = AMGSolver(config or AMGConfig())
        self.max_rhs = max(1, int(max_rhs))
        self._matrices: dict[str, CSR] = {}
        self._bound: dict[str, BoundSolver] = {}
        self._queue: list[SolveRequest] = []
        self.stats = {"requests": 0, "batches": 0, "batched_rhs": 0,
                      "setups": 0, "unconverged": 0}
        # per-request {"converged", "iterations"} from the latest run()
        self.diagnostics: dict[int, dict] = {}

    def add_matrix(self, matrix_id: str, A: CSR) -> None:
        self._matrices[matrix_id] = A

    def bound_for(self, matrix_id: str) -> BoundSolver:
        bound = self._bound.get(matrix_id)
        if bound is None:
            try:
                A = self._matrices[matrix_id]
            except KeyError:
                raise KeyError(f"unknown matrix_id {matrix_id!r}; "
                               f"registered: {sorted(self._matrices)}") \
                    from None
            bound = self.solver.setup(A)
            self._bound[matrix_id] = bound
            self.stats["setups"] += 1
        return bound

    def submit(self, req: SolveRequest) -> None:
        if req.matrix_id not in self._matrices:
            raise KeyError(f"unknown matrix_id {req.matrix_id!r}; "
                           f"registered: {sorted(self._matrices)}")
        if req.method not in ("solve", "pcg"):
            raise ValueError(f"unknown method {req.method!r}")
        b = np.asarray(req.b, dtype=np.float64)
        n = self._matrices[req.matrix_id].nrows
        if b.shape != (n,):
            raise ValueError(f"request {req.rid}: b must be [{n}], "
                             f"got {b.shape}")
        self._queue.append(req)
        self.stats["requests"] += 1

    def _record(self, rid: int, result) -> None:
        self.diagnostics[rid] = {"converged": result.converged,
                                 "iterations": result.iterations}
        if not result.converged:
            self.stats["unconverged"] += 1

    def run(self) -> dict[int, np.ndarray]:
        """Drain the queue; returns {rid: x}.  Per-request convergence
        status lands in :attr:`diagnostics` (and ``stats["unconverged"]``)
        — an x returned for an unconverged solve is best-effort."""
        out: dict[int, np.ndarray] = {}
        self.diagnostics = {}
        groups: dict[tuple[str, str], list[SolveRequest]] = {}
        for req in self._queue:
            groups.setdefault((req.matrix_id, req.method), []).append(req)
        self._queue.clear()
        for (mid, method), reqs in groups.items():
            bound = self.bound_for(mid)
            fn = bound.solve if method == "solve" else bound.pcg
            for i in range(0, len(reqs), self.max_rhs):
                chunk = reqs[i: i + self.max_rhs]
                if len(chunk) == 1:
                    res = fn(chunk[0].b)
                    out[chunk[0].rid] = np.asarray(res.x)
                    self._record(chunk[0].rid, res)
                else:
                    B = np.stack([r.b for r in chunk], axis=1)
                    res = fn(B)
                    for j, r in enumerate(chunk):
                        out[r.rid] = np.asarray(res.x[:, j])
                        self._record(r.rid, res.columns[j])
                    self.stats["batched_rhs"] += len(chunk)
                self.stats["batches"] += 1
        return out
