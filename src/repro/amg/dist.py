"""Distributed view of an AMG hierarchy: communication graphs per level and
operation, strategy selection (paper §4), and modeled phase costs.

This is the glue between :mod:`repro.amg` (numerics) and :mod:`repro.core`
(the paper's node-aware schedules + max-rate models).  Everything here is
host-side analysis (numpy only); the execution of the same selections lives
in :mod:`repro.amg.dist_solve` (solve phase: :func:`vector_comm_graph` /
:func:`rect_vector_graph` per level and per operator {A, P, R} feed
:func:`repro.core.selector.select` before compiling the fused V-cycle) and
:mod:`repro.amg.dist_setup` (setup phase: :func:`matrix_comm_graph` is the
schedule source for the NAP matrix-row exchanges of the Galerkin SpGEMMs
A·P and Pᵀ·(AP)).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core import (CommGraph, MachineParams, Partition, ScheduleStats,
                    Selection, Topology, build, select)
from .csr import CSR
from .hierarchy import Hierarchy

MATRIX_ROW_HEADER = 16.0  # bytes: global row id + length
MATRIX_ENTRY = 12.0       # bytes per nonzero: col (int32) + value (fp64)


def row_partition(A: CSR, topo: Topology) -> Partition:
    return Partition.balanced(A.nrows, topo)


def vector_comm_graph(A: CSR, part: Partition) -> CommGraph:
    """SpMV A·x pattern: off-process columns of each rank's rows (Fig. 6)."""
    offp = []
    for p in range(part.topo.n_procs):
        lo, hi = part.local_range(p)
        offp.append(A.offproc_columns(lo, hi, lo, hi))
    return CommGraph.from_offproc_columns(part, offp)


def matrix_comm_graph(A: CSR, B: CSR, part: Partition,
                      b_part: Partition | None = None) -> CommGraph:
    """SpGEMM A·B pattern: rows of B for off-process columns of A (Fig. 7).

    ``part`` partitions the rows of A; ``b_part`` partitions the rows of B
    (i.e. the column space of A) and defaults to ``part`` — the A·P case,
    where P's rows follow A's row partition.  For Pᵀ·(AP) pass the coarse
    partition as ``part`` and the fine partition as ``b_part``.

    Returned graph: ``partition`` is ``b_part`` and ``need[p]`` holds global
    *row indices of B* — the columns of rank p's rows of A that fall outside
    p's owned B-row range ``b_part.local_range(p)``.  ``weights[i]`` is the
    byte size of B row i when it is communicated once
    (``MATRIX_ENTRY·nnz(row) + MATRIX_ROW_HEADER``), so the §3 schedules and
    max-rate models price whole-row transfers, matching the paper's
    observation that matrix communication "retains the same communication
    pattern as vectors, but requires entire rows".
    """
    b_part = b_part or part
    weights = (np.diff(B.indptr) * MATRIX_ENTRY + MATRIX_ROW_HEADER).astype(np.float64)
    offp = []
    for p in range(part.topo.n_procs):
        rlo, rhi = part.local_range(p)        # rank p's rows of A
        blo, bhi = b_part.local_range(p)      # rank p's rows of B
        offp.append(A.offproc_columns(blo, bhi, rlo, rhi))
    return CommGraph(partition=b_part, need=offp, weights=weights)


@dataclasses.dataclass
class OpComm:
    """One communicating operation at one level."""
    level: int
    op: str                  # "spmv_A", "restrict", "interp", "spgemm_AP", "spgemm_PtAP"
    graph: CommGraph
    selection: Selection

    @property
    def strategy(self) -> str:
        return self.selection.strategy


def analyze_hierarchy(h: Hierarchy, topo: Topology, params: MachineParams,
                      strategies=("standard", "nap2", "nap3")) -> list[OpComm]:
    """Build comm graphs + select strategies for every op at every level.

    Ops per level ℓ (paper Figs. 14/15):
      solve phase : spmv_A (A_ℓ·x, also every smoother sweep),
                    restrict (Pᵀ·r), interp (P·e)
      setup phase : spgemm_AP (A_ℓ·P_ℓ), spgemm_PtAP (Pᵀ·(AP))
    """
    out: list[OpComm] = []
    for l, lv in enumerate(h.levels):
        part = row_partition(lv.A, topo)
        g = vector_comm_graph(lv.A, part)
        out.append(OpComm(l, "spmv_A", g, select(g, params, strategies)))
        if lv.P is None:
            continue
        # interp P·e: vector comm of coarse vector e (columns of P off-proc)
        cpart = Partition.balanced(lv.P.ncols, topo)
        gp = rect_vector_graph(lv.P, part, cpart)
        out.append(OpComm(l, "interp", gp, select(gp, params, strategies)))
        # restrict Pᵀ·r: vector comm of fine vector r
        rpart = part
        gr = rect_vector_graph(lv.R, cpart, rpart)
        out.append(OpComm(l, "restrict", gr, select(gr, params, strategies)))
        # setup SpGEMMs
        gap = matrix_comm_graph(lv.A, lv.P, part)
        out.append(OpComm(l, "spgemm_AP", gap, select(gap, params, strategies)))
        if lv.AP is not None:
            # Pᵀ·(AP): communicate rows of AP for off-proc cols of Pᵀ
            gpt = matrix_comm_graph(lv.R, lv.AP, cpart, b_part=rpart)
            out.append(OpComm(l, "spgemm_PtAP", gpt, select(gpt, params, strategies)))
    return out


def schedule_comm_stats(graph: CommGraph, strategy: str) -> dict:
    """Modeled message/byte totals of executing ``strategy`` on ``graph``
    once — the per-matvec communication cost the cycle-shape accounting of
    :func:`repro.amg.dist_solve.cycle_comm_stats` multiplies by per-level
    visit counts (W/F-cycles revisit exactly the coarse levels where the
    NAP strategies aggregate small inter-node messages)."""
    st = ScheduleStats.of(build(strategy, graph))
    return {"inter_msgs": int(st.inter_msg_count),
            "inter_bytes": float(st.inter_bytes_total),
            "intra_msgs": int(st.intra_msg_count),
            "intra_bytes": float(st.intra_bytes_total)}


def rect_vector_graph(M: CSR, row_part: Partition, col_part: Partition) -> CommGraph:
    """Vector comm for y = M·x where rows of M follow row_part and x follows
    col_part (rectangular operators P and R)."""
    offp = []
    for p in range(row_part.topo.n_procs):
        rlo, rhi = row_part.local_range(p)
        clo, chi = col_part.local_range(p)
        offp.append(M.offproc_columns(clo, chi, rlo, rhi))
    return CommGraph.from_offproc_columns(col_part, offp)


def phase_costs(ops: list[OpComm], n_levels: int):
    """Aggregate modeled comm seconds per level for solve/setup phases, per
    strategy and for the model-selected mix (Figs. 2/4/14/15).

    An op whose selection was run over a strategy subset simply contributes
    nothing to the strategies it never modeled (the column stays a partial
    sum) — a missing entry must not poison the whole level with ``inf``.
    """
    solve_ops = ("spmv_A", "restrict", "interp")
    out = {"solve": {}, "setup": {}}
    for phase, opset in (("solve", solve_ops), ("setup", ("spgemm_AP", "spgemm_PtAP"))):
        per_level = {}
        for l in range(n_levels):
            row = {"standard": 0.0, "nap2": 0.0, "nap3": 0.0, "selected": 0.0}
            for oc in ops:
                if oc.level != l or oc.op not in opset:
                    continue
                for s in ("standard", "nap2", "nap3"):
                    t = oc.selection.times.get(s)
                    if t is not None and np.isfinite(t):
                        row[s] += t
                row["selected"] += oc.selection.modeled_time
            per_level[l] = row
        out[phase] = per_level
    return out
