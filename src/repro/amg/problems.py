"""Test problems mirroring the paper's systems (MFEM-built in the paper;
stencil-built stand-ins here, with matching character).

* :func:`laplace_3d`       — 27-point FEM-style 3D Laplacian (Example 2.1).
* :func:`grad_div_3d`      — 3-component coupled vector system with a mass
  term (the MFEM Grad-Div system's character: vector dofs, strong coupling,
  ~40 nnz/row).
* :func:`dpg_laplace_3d`   — very dense rows (~100+ nnz/row on modest n),
  matching the DPG system's extreme density (104.5M nnz on 131k rows).
* :func:`rotated_anisotropic_2d` — 9-point FD rotated anisotropic diffusion
  (the Fig. 21 system).
"""
from __future__ import annotations

import numpy as np

from .csr import CSR


def _grid_ids(*dims):
    grids = np.meshgrid(*[np.arange(d) for d in dims], indexing="ij")
    return [g.ravel() for g in grids]


def stencil_grid(stencil: np.ndarray, dims: tuple[int, ...]) -> CSR:
    """Assemble a matrix from an arbitrary odd-shaped stencil on a regular
    grid with homogeneous Dirichlet truncation (PyAMG-style)."""
    stencil = np.asarray(stencil, dtype=np.float64)
    nd = stencil.ndim
    assert len(dims) == nd
    n = int(np.prod(dims))
    centers = [(s - 1) // 2 for s in stencil.shape]
    coords = _grid_ids(*dims)
    rows_all, cols_all, vals_all = [], [], []
    it = np.ndindex(*stencil.shape)
    strides = np.cumprod([1] + list(dims[::-1]))[::-1][1:]  # row-major strides
    for off in it:
        v = stencil[off]
        if v == 0.0:
            continue
        d = [o - c for o, c in zip(off, centers)]
        mask = np.ones(n, dtype=bool)
        col = np.zeros(n, dtype=np.int64)
        for axis in range(nd):
            ci = coords[axis] + d[axis]
            mask &= (ci >= 0) & (ci < dims[axis])
            col += np.where(mask, ci, 0) * strides[axis]
        rows = np.flatnonzero(mask)
        rows_all.append(rows)
        cols_all.append(col[rows])
        vals_all.append(np.full(rows.size, v))
    return CSR.from_coo(np.concatenate(rows_all), np.concatenate(cols_all),
                        np.concatenate(vals_all), (n, n))


def laplace_3d(nx: int, ny: int | None = None, nz: int | None = None) -> CSR:
    """27-point 3D Laplacian (trilinear FEM stencil)."""
    ny = ny or nx
    nz = nz or nx
    st = -np.ones((3, 3, 3))
    st[1, 1, 1] = 26.0
    return stencil_grid(st, (nx, ny, nz))


def laplace_3d_7pt(nx: int, ny: int | None = None, nz: int | None = None) -> CSR:
    ny = ny or nx
    nz = nz or nx
    st = np.zeros((3, 3, 3))
    st[1, 1, 1] = 6.0
    for d in ((0, 1, 1), (2, 1, 1), (1, 0, 1), (1, 2, 1), (1, 1, 0), (1, 1, 2)):
        st[d] = -1.0
    return stencil_grid(st, (nx, ny, nz))


def grad_div_3d(nx: int, alpha: float = 1.0, beta: float = 1.0) -> CSR:
    """-∇(α ∇·F) + βF character: 3 coupled components on a 3D grid.

    Each component carries a 27-pt operator plus a mass term; components are
    coupled through mixed-difference blocks (the grad-div cross terms).
    """
    n = nx ** 3
    K = laplace_3d(nx)
    # mass term on the diagonal
    comp = K.add(CSR.eye(n, value=beta * 8.0))
    # cross-component coupling: forward/backward difference pattern
    st = np.zeros((3, 3, 3))
    st[0, 1, 1], st[2, 1, 1] = -0.5 * alpha, 0.5 * alpha
    st[1, 0, 1], st[1, 2, 1] = -0.5 * alpha, 0.5 * alpha
    Cx = stencil_grid(st, (nx, nx, nx))
    rows, cols, vals = [], [], []

    def place(block: CSR, bi: int, bj: int):
        rows.append(block.rows_expanded() + bi * n)
        cols.append(block.indices + bj * n)
        vals.append(block.data)

    for c in range(3):
        place(comp, c, c)
    for (bi, bj) in ((0, 1), (1, 2), (0, 2)):
        place(Cx, bi, bj)
        place(Cx.T, bj, bi)
    return CSR.from_coo(np.concatenate(rows), np.concatenate(cols),
                        np.concatenate(vals), (3 * n, 3 * n))


def dpg_laplace_3d(nx: int, bandwidth: int = 60, seed: int = 0) -> CSR:
    """DPG-character system: modest rows, very dense (~2·bandwidth nnz/row),
    SPD via diagonal dominance.  The paper's DPG system has ~800 nnz/row."""
    n = nx ** 3
    rng = np.random.default_rng(seed)
    base = laplace_3d_7pt(nx)
    rows, cols, vals = [base.rows_expanded()], [base.indices], [base.data]
    # add dense local coupling bands (graph distance in lexicographic order)
    r = np.arange(n, dtype=np.int64)
    for k in range(2, bandwidth, 3):
        mask = r + k < n
        rr = r[mask]
        cc = rr + k
        vv = -np.abs(rng.standard_normal(rr.size)) * (0.5 / k)
        rows += [rr, cc]
        cols += [cc, rr]
        vals += [vv, vv]
    A = CSR.from_coo(np.concatenate(rows), np.concatenate(cols),
                     np.concatenate(vals), (n, n))
    # enforce diagonal dominance -> SPD, AMG-amenable
    d = A.diagonal()
    rowabs = np.zeros(n)
    np.add.at(rowabs, A.rows_expanded(), np.abs(A.data))
    rowabs -= np.abs(d)  # sum of |off-diagonals| per row
    D = CSR.from_diag(rowabs * 1.05 - d + 1.0)
    return A.add(D)


def rotated_anisotropic_2d(nx: int, eps: float = 0.001, theta: float = np.pi / 4) -> CSR:
    """FD discretization of rotated anisotropic diffusion (Fig. 21 system)."""
    c, s = np.cos(theta), np.sin(theta)
    cxx = c * c + eps * s * s
    cyy = s * s + eps * c * c
    cxy = 2 * (1 - eps) * c * s
    st = np.array([
        [-0.25 * cxy - 0.0, -cyy, 0.25 * cxy],
        [-cxx, 2 * cxx + 2 * cyy, -cxx],
        [0.25 * cxy, -cyy, -0.25 * cxy - 0.0],
    ])
    return stencil_grid(st, (nx, nx))


PROBLEMS = {
    "laplace3d": lambda n=24: laplace_3d(n),
    "laplace3d_7pt": lambda n=24: laplace_3d_7pt(n),
    "graddiv": lambda n=14: grad_div_3d(n),
    "dpg": lambda n=12: dpg_laplace_3d(n),
    "rot_aniso2d": lambda n=64: rotated_anisotropic_2d(n),
}
