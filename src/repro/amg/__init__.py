"""Algebraic multigrid substrate (setup + solve + serving sessions).

The front door is the **session API** of :mod:`repro.amg.api`::

    from repro.amg import AMGConfig, AMGSolver

    cfg = AMGConfig(solver="rs", backend="dist", n_pods=2, lanes=4)
    bound = AMGSolver(cfg).setup(A)     # hierarchy + lowering, cached
    res = bound.solve(b)                # b: [n] or [n, k] multi-RHS
    res = bound.pcg(b, x0=x_warm)

``AMGConfig`` is frozen and hashable; ``AMGSolver(config).setup(A)`` returns
a ``BoundSolver`` cached per (matrix fingerprint, config), so the expensive
node-aware setup — the host ``Hierarchy``, the lowered ``DistHierarchy``
(per-level {A, P, R} comm graphs + standard/NAP-2/NAP-3 strategy selection
from the paper's performance models + halo plans), and its compiled fused
V-cycle/PCG shard_map programs — is built once and reused across solves.
Backends plug in through :func:`~repro.amg.api.register_backend`
(``"host"`` = reference numpy, ``"dist"`` = device-resident fused cycle).
The cycle shape and smoother are ``SolveOptions`` knobs
(``cycle="V"|"W"|"F"``, ``smoother="jacobi" | "chebyshev" |
"block_jacobi" | "hybrid_gs" | "hybrid_gs_sym"``): W/F coarse revisits
unroll at trace time so every combination still runs as ONE jitted
shard_map program, configs differing only in these knobs share one
hierarchy and one lowering, and the symmetric hybrid-GS sweep gives PCG
an SPD preconditioner on every backend.

**Serving** is :class:`~repro.amg.api.AMGService`: ticketed async
admission (``submit() -> Ticket``; ``ticket.result()`` blocks), a
coalescing window that stacks same-(matrix, knobs) right-hand sides from
*separate submission bursts* into one multi-RHS device trace, per-request
:class:`~repro.amg.api.RequestOptions`, priority classes with
starvation-free aging, and a versioned wire codec (matrices registered by
content fingerprint, requests as schema-tagged payloads) so the whole
service can be driven over a byte transport — ``repro.launch.serve
--solver amg --wire``.  Sessions live in an instantiable
:class:`~repro.amg.api.SessionStore` with pluggable LRU / TTL /
cost-aware bytes-budget eviction and hit/evict/setup-cost accounting.

**Streaming sessions**: matrices that drift in value but keep their
sparsity pattern (time-stepping, Newton linearizations) go through
``bound.update(A_new)`` / ``AMGService.update`` — a value-only refresh
that re-runs the Galerkin products numerically onto the frozen level
patterns, reusing every selected NAP schedule, halo plan and compiled
program, and escalates to a full node-aware re-setup when the
:class:`~repro.amg.api.RefreshPolicy` detects convergence regression or
the pattern changes.

``AMGConfig(setup_backend="dist", backend="dist")`` additionally runs the
**setup phase** partitioned (:mod:`repro.amg.dist_setup`): the Galerkin
SpGEMMs A·P and Pᵀ·(AP) exchange off-process CSR rows under model-selected
standard/NAP-2/NAP-3 schedules and every level is born partitioned — no
host gather/re-scatter between setup and solve.

The classic free functions remain as thin wrappers over that API:
``setup(A)`` builds a host ``Hierarchy`` (Algorithm 1), and
``solve``/``pcg``/``vcycle`` accept ``backend="host"|"dist"`` plus the
legacy ``dist=`` argument (a prebuilt ``DistHierarchy`` or a build-kwargs
dict, now cached per hierarchy).  ``DistHierarchy`` is exported lazily so
numpy-only users never import JAX.
"""
from .api import (AMGConfig, AMGService, AMGSolver, BoundSolver,
                  PatternMismatch, RefreshPolicy, RequestOptions,
                  ServiceReport, SessionStore, Ticket, available_backends,
                  register_backend)
from .csr import CSR
from .hierarchy import Hierarchy, Level, setup
from .solve import (MultiSolveResult, SolveOptions, SolveResult, pcg, solve,
                    vcycle)

__all__ = ["CSR", "Hierarchy", "Level", "setup", "SolveOptions", "SolveResult",
           "MultiSolveResult", "pcg", "solve", "vcycle", "AMGConfig",
           "AMGService", "AMGSolver", "BoundSolver", "PatternMismatch",
           "RefreshPolicy", "RequestOptions", "ServiceReport",
           "SessionStore", "Ticket",
           "available_backends", "register_backend", "DistHierarchy"]

# NOTE: the distributed setup entrypoint is deliberately NOT re-exported
# here — a lazy ``dist_setup`` attribute would collide with the
# ``repro.amg.dist_setup`` submodule name and get rebound to the module by
# the import system.  Import it as ``from repro.amg.dist_setup import
# dist_setup`` (or go through ``AMGConfig(setup_backend="dist")``).


def __getattr__(name):
    if name == "DistHierarchy":          # lazy: pulls in jax
        from .dist_solve import DistHierarchy
        return DistHierarchy
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
