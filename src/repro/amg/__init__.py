"""Algebraic multigrid substrate (setup + solve).

Host side (pure numpy): CSR kernels, setup (Algorithm 1), the reference
V-cycle / stationary / PCG solvers (Algorithm 2), and the distributed
communication analysis of :mod:`repro.amg.dist`.

Device side: :class:`~repro.amg.dist_solve.DistHierarchy` lowers a hierarchy
onto a (pods × lanes) mesh — per level, each of {A, P, R} gets its own
communication graph, a strategy (standard/NAP-2/NAP-3) chosen from the
paper's performance models, and a halo plan — and ``solve``/``pcg`` with
``backend="dist"`` run the whole V-cycle as one jitted shard_map program.
``DistHierarchy`` is exported lazily so numpy-only users never import JAX.
"""
from .csr import CSR
from .hierarchy import Hierarchy, Level, setup
from .solve import SolveOptions, SolveResult, pcg, solve, vcycle

__all__ = ["CSR", "Hierarchy", "Level", "setup", "SolveOptions", "SolveResult",
           "pcg", "solve", "vcycle", "DistHierarchy"]


def __getattr__(name):
    if name == "DistHierarchy":          # lazy: pulls in jax
        from .dist_solve import DistHierarchy
        return DistHierarchy
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
