"""Algebraic multigrid substrate (setup + solve), pure numpy host-side, with
distributed communication analysis via :mod:`repro.core`."""
from .csr import CSR
from .hierarchy import Hierarchy, Level, setup
from .solve import SolveOptions, SolveResult, pcg, solve, vcycle

__all__ = ["CSR", "Hierarchy", "Level", "setup", "SolveOptions", "SolveResult",
           "pcg", "solve", "vcycle"]
