"""AMG solve phase (Algorithm 2): V/W/F-cycles, stand-alone iteration, PCG.

The smoother is SpMV-based, so every relaxation sweep, residual,
restriction and interpolation reuses the level's communication pattern —
the operations whose strategy the paper's models select.  The cycle shape
and smoother are both :class:`SolveOptions` knobs; together they span the
communication scenarios the strategy selection is benchmarked on:

======== =================================================================
knob     choices
======== =================================================================
cycle    ``"V"`` one coarse visit per level;
         ``"W"`` two recursive visits (coarse levels visited 2^ℓ times —
         where NAP-2/NAP-3 aggregate the many small inter-node messages);
         ``"F"`` an F-recursion followed by a V-recursion (ℓ+1 visits of
         level ℓ).
smoother ``"jacobi"`` weighted point Jacobi (1 SpMV/sweep);
         ``"chebyshev"`` degree-d polynomial (d SpMVs/sweep);
         ``"block_jacobi"`` per-block diagonal inverses of size
         ``block_size`` (1 SpMV/sweep, denser local update);
         ``"hybrid_gs"`` hybrid Gauss-Seidel — exact forward GS within a
         row part, Jacobi across parts with lagged (halo'd) off-part
         values (1 SpMV/sweep);
         ``"hybrid_gs_sym"`` the symmetric sweep (forward + backward,
         2 SpMVs/sweep) — a symmetric smoother, so the cycle is an SPD
         preconditioner for PCG with every backend.
======== =================================================================

The block smoothers' iterations depend on the row partition: the dist
backend always uses its device partition, and the host reference mimics a
``smoother_parts``-way balanced partition (set it to the device count for
bit-identical host↔dist smoothing; the default 1 gives the classical
serial smoother).

This module owns the **host** (numpy) implementations plus the result
containers.  The public free functions ``vcycle`` / ``solve`` / ``pcg`` are
thin wrappers over the session API of :mod:`repro.amg.api`: they bind the
hierarchy to the requested backend through the backend registry and delegate,
so they share the same caching and multi-RHS semantics as
``AMGSolver(config).setup(A)``:

* ``backend="host"`` — the reference numpy implementation below.
* ``backend="dist"`` — the device-resident path
  (:mod:`repro.amg.dist_solve`): the whole V-cycle runs as one jitted
  shard_map program over a (pods × lanes) mesh, every matvec using the
  level's model-selected node-aware strategy.  Pass ``dist=`` either a
  prebuilt :class:`~repro.amg.dist_solve.DistHierarchy` (reused across
  calls) or a dict of ``DistHierarchy.build`` kwargs
  (e.g. ``dict(n_pods=2, lanes=4)``) — dict kwargs hit a per-hierarchy
  cache, so repeated calls reuse one ``DistHierarchy`` instead of
  rebuilding it.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .csr import CSR
from .hierarchy import Hierarchy, Level
from .smoothers import (balanced_offsets, block_diag_inv, block_jacobi,
                        chebyshev, hybrid_gs, hybrid_gs_sym, jacobi)

CYCLES = ("V", "W", "F")
SMOOTHERS = ("jacobi", "chebyshev", "block_jacobi", "hybrid_gs",
             "hybrid_gs_sym")
# recursive coarse visits per cycle shape: each child runs at level+1,
# warm-started from the previous child's result
CYCLE_CHILDREN = {"V": ("V",), "W": ("W", "W"), "F": ("F", "V")}


@dataclasses.dataclass(frozen=True)
class SolveOptions:
    """Cycle-shape + smoother options.  Frozen (hashable) so it can key
    program caches and live inside a hashable
    :class:`~repro.amg.api.AMGConfig` — two configs differing only in these
    knobs share one hierarchy and one dist lowering, and differ only in
    which compiled cycle program runs (see the module docstring's table)."""

    smoother: str = "jacobi"       # see SMOOTHERS
    presweeps: int = 1
    postsweeps: int = 1
    omega: float = 2.0 / 3.0
    cheby_degree: int = 2
    cycle: str = "V"               # see CYCLES
    block_size: int = 4            # block_jacobi: diagonal block size
    smoother_parts: int = 1        # host row parts for the block smoothers

    def __post_init__(self):
        if self.cycle not in CYCLES:
            raise ValueError(f"cycle must be one of {CYCLES}, "
                             f"got {self.cycle!r}")
        if self.smoother not in SMOOTHERS:
            raise ValueError(f"smoother must be one of {SMOOTHERS}, "
                             f"got {self.smoother!r}")
        if self.block_size < 1 or self.smoother_parts < 1:
            raise ValueError("block_size and smoother_parts must be >= 1")

    def spmvs_per_sweep(self) -> int:
        """SpMVs one relaxation sweep costs (the comm-count multiplier)."""
        if self.smoother == "chebyshev":
            return self.cheby_degree
        return 2 if self.smoother == "hybrid_gs_sym" else 1


def _relax(A: CSR, x, b, opts: SolveOptions, sweeps: int,
           level: Level | None = None):
    """One relaxation call; ``level`` carries the per-level smoother cache
    (block-diagonal inverses extracted once and reused every sweep)."""
    if sweeps == 0:
        return x
    if opts.smoother == "jacobi":
        return jacobi(A, x, b, omega=opts.omega, iterations=sweeps)
    if opts.smoother == "block_jacobi":
        key = ("bdinv", opts.block_size, opts.smoother_parts)
        binv = level.smoother_cache.get(key) if level is not None else None
        if binv is None:
            binv = block_diag_inv(A, opts.block_size, opts.smoother_parts)
            if level is not None:
                level.smoother_cache[key] = binv
        return block_jacobi(A, x, b, opts.block_size, omega=opts.omega,
                            iterations=sweeps, binv=binv)
    if opts.smoother in ("hybrid_gs", "hybrid_gs_sym"):
        bounds = balanced_offsets(A.nrows, opts.smoother_parts)
        fn = hybrid_gs if opts.smoother == "hybrid_gs" else hybrid_gs_sym
        return fn(A, x, b, boundaries=bounds, iterations=sweeps)
    return chebyshev(A, x, b, degree=opts.cheby_degree * sweeps)


@dataclasses.dataclass
class SolveResult:
    x: np.ndarray
    residuals: list[float]
    iterations: int
    converged: bool

    @property
    def avg_conv_factor(self) -> float:
        r = self.residuals
        if len(r) < 2 or r[0] == 0:
            return 1.0
        return (r[-1] / r[0]) ** (1.0 / (len(r) - 1))


@dataclasses.dataclass
class MultiSolveResult:
    """Result of a multi-RHS solve: ``x`` is ``[n, k]``, one
    :class:`SolveResult` per right-hand-side column."""

    x: np.ndarray
    columns: list[SolveResult]

    @property
    def n_rhs(self) -> int:
        return len(self.columns)

    @property
    def iterations(self) -> int:
        return max((c.iterations for c in self.columns), default=0)

    @property
    def converged(self) -> bool:
        return all(c.converged for c in self.columns)


# --------------------------------------------------------------------------
# Host (numpy) backend implementations
# --------------------------------------------------------------------------


def host_cycle(h: Hierarchy, b: np.ndarray, x: np.ndarray | None = None,
               opts: SolveOptions | None = None, level: int = 0,
               shape: str | None = None) -> np.ndarray:
    """One multigrid cycle (Algorithm 2) on the host.

    ``shape`` defaults to ``opts.cycle``; W/F shapes revisit the coarse
    grids per :data:`CYCLE_CHILDREN`, each child warm-started from the
    previous child's coarse solution.
    """
    opts = opts or SolveOptions()
    shape = shape or opts.cycle
    lv = h.levels[level]
    if x is None:
        x = np.zeros_like(b)
    if level == h.n_levels - 1:                       # coarsest: direct solve
        return np.linalg.lstsq(lv.A.to_dense(), b, rcond=None)[0]
    x = _relax(lv.A, x, b, opts, opts.presweeps, lv)  # pre-relaxation
    r = b - lv.A.matvec(x)                            # residual
    rc = lv.R.matvec(r)                               # restrict
    ec = None
    for child in CYCLE_CHILDREN[shape]:               # coarse-grid solve(s)
        ec = host_cycle(h, rc, ec, opts, level + 1, shape=child)
    x = x + lv.P.matvec(ec)                           # interpolate + correct
    x = _relax(lv.A, x, b, opts, opts.postsweeps, lv)  # post-relaxation
    return x


# backward-compat name (one cycle of whatever shape ``opts`` selects)
host_vcycle = host_cycle


def level_visits(n_levels: int, cycle: str) -> list[int]:
    """How many times each level is visited by ONE cycle of the given shape
    (V: once; W: 2^ℓ; F: ℓ+1) — the multiplier on each level's per-visit
    communication, which is what makes W/F-cycles coarse-level heavy."""
    visits = [0] * n_levels

    def rec(lvl: int, shape: str) -> None:
        visits[lvl] += 1
        if lvl == n_levels - 1:
            return
        for child in CYCLE_CHILDREN[shape]:
            rec(lvl + 1, child)

    rec(0, cycle)
    return visits


def host_solve(h: Hierarchy, b: np.ndarray, tol: float = 1e-8,
               maxiter: int = 100, opts: SolveOptions | None = None,
               x0: np.ndarray | None = None) -> SolveResult:
    """Stationary AMG iteration: x <- x + V(A, b - Ax)."""
    A = h.levels[0].A
    x = np.zeros_like(b) if x0 is None else x0.copy()
    nb = float(np.linalg.norm(b)) or 1.0
    res = [float(np.linalg.norm(b - A.matvec(x)))]
    for it in range(maxiter):
        if res[-1] / nb < tol:
            return SolveResult(x, res, it, True)
        x = host_cycle(h, b, x, opts)
        res.append(float(np.linalg.norm(b - A.matvec(x))))
    return SolveResult(x, res, maxiter, res[-1] / nb < tol)


def host_pcg(h: Hierarchy, b: np.ndarray, tol: float = 1e-8,
             maxiter: int = 200, opts: SolveOptions | None = None,
             x0: np.ndarray | None = None) -> SolveResult:
    """AMG-preconditioned conjugate gradients (optionally warm-started).

    The precondition/update body lives once inside the loop (it used to be
    duplicated ahead of it), so cycle-shape changes land in one place.
    """
    A = h.levels[0].A
    x = np.zeros_like(b) if x0 is None else x0.copy()
    r = b - A.matvec(x) if x0 is not None else b.copy()
    nb = float(np.linalg.norm(b)) or 1.0
    res = [float(np.linalg.norm(r))]
    p = None
    rz = 1.0
    for it in range(maxiter):
        if res[-1] / nb < tol:
            return SolveResult(x, res, it, True)
        z = host_cycle(h, r, None, opts)         # precondition (one cycle)
        rz_new = float(r @ z)
        p = z if p is None else z + (rz_new / rz) * p
        rz = rz_new
        Ap = A.matvec(p)
        alpha = rz / float(p @ Ap)
        x += alpha * p
        r -= alpha * Ap
        res.append(float(np.linalg.norm(r)))
    return SolveResult(x, res, maxiter, res[-1] / nb < tol)


# --------------------------------------------------------------------------
# Public free functions: thin wrappers over the session API backend registry
# --------------------------------------------------------------------------


def _bound(h: Hierarchy, backend: str, dist, opts):
    from .api import bind_hierarchy
    return bind_hierarchy(h, backend=backend, dist=dist, opts=opts)


def _request(method: str, tol, maxiter, x0):
    # all three call surfaces (these wrappers, AMGService.submit, wire
    # requests) funnel per-request knobs through one RequestOptions
    from .api.config import RequestOptions
    return RequestOptions(method=method, tol=tol, maxiter=maxiter, x0=x0)


def vcycle(h: Hierarchy, b: np.ndarray, x: np.ndarray | None = None,
           opts: SolveOptions | None = None, level: int = 0,
           backend: str = "host", dist=None) -> np.ndarray:
    """One cycle (Algorithm 2) of the shape ``opts.cycle`` selects."""
    if backend == "host":
        return host_cycle(h, b, x, opts, level)
    if level != 0:
        raise ValueError(f"backend={backend!r} vcycle starts at level 0")
    return _bound(h, backend, dist, opts).vcycle(b, x0=x)


def solve(h: Hierarchy, b: np.ndarray, tol: float = 1e-8, maxiter: int = 100,
          opts: SolveOptions | None = None, x0: np.ndarray | None = None,
          backend: str = "host", dist=None):
    """Stationary AMG iteration: x <- x + cycle(A, b - Ax).

    ``b`` may be ``[n]`` (→ :class:`SolveResult`) or ``[n, k]``
    (→ :class:`MultiSolveResult`, the k systems solved together).
    """
    return _bound(h, backend, dist, opts).run(
        b, _request("solve", tol, maxiter, x0))


def pcg(h: Hierarchy, b: np.ndarray, tol: float = 1e-8, maxiter: int = 200,
        opts: SolveOptions | None = None, x0: np.ndarray | None = None,
        backend: str = "host", dist=None):
    """AMG-preconditioned conjugate gradients (``x0=`` warm start supported
    on every backend; ``b`` may be ``[n]`` or ``[n, k]``)."""
    return _bound(h, backend, dist, opts).run(
        b, _request("pcg", tol, maxiter, x0))
