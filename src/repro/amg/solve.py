"""AMG solve phase (Algorithm 2): V-cycle, stand-alone iteration and PCG.

The smoother is SpMV-based (Jacobi/Chebyshev), so every relaxation sweep,
residual, restriction and interpolation reuses the level's communication
pattern — the operations whose strategy the paper's models select.

Two backends share this API:

* ``backend="host"`` — the reference numpy implementation below.
* ``backend="dist"`` — the device-resident path
  (:mod:`repro.amg.dist_solve`): the whole V-cycle runs as one jitted
  shard_map program over a (pods × lanes) mesh, every matvec using the
  level's model-selected node-aware strategy.  Pass ``dist=`` either a
  prebuilt :class:`~repro.amg.dist_solve.DistHierarchy` (reused across
  calls) or a dict of ``DistHierarchy.build`` kwargs
  (e.g. ``dict(n_pods=2, lanes=4)``).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .csr import CSR
from .hierarchy import Hierarchy
from .smoothers import chebyshev, jacobi


@dataclasses.dataclass
class SolveOptions:
    smoother: str = "jacobi"       # "jacobi" | "chebyshev"
    presweeps: int = 1
    postsweeps: int = 1
    omega: float = 2.0 / 3.0
    cheby_degree: int = 2


def _relax(A: CSR, x, b, opts: SolveOptions, sweeps: int):
    if sweeps == 0:
        return x
    if opts.smoother == "jacobi":
        return jacobi(A, x, b, omega=opts.omega, iterations=sweeps)
    return chebyshev(A, x, b, degree=opts.cheby_degree * sweeps)


def _dist_hierarchy(h, dist):
    from .dist_solve import _ensure_dist
    return _ensure_dist(h, dist)


def vcycle(h: Hierarchy, b: np.ndarray, x: np.ndarray | None = None,
           opts: SolveOptions | None = None, level: int = 0,
           backend: str = "host", dist=None) -> np.ndarray:
    """One V(pre,post)-cycle (Algorithm 2)."""
    opts = opts or SolveOptions()
    if backend == "dist":
        from .dist_solve import dist_vcycle
        if x is not None or level != 0:
            raise ValueError("dist vcycle starts from x=0 at level 0")
        return dist_vcycle(_dist_hierarchy(h, dist), b, opts)
    if backend != "host":
        raise ValueError(f"unknown backend {backend!r}")
    lv = h.levels[level]
    if x is None:
        x = np.zeros_like(b)
    if level == h.n_levels - 1:                       # coarsest: direct solve
        return np.linalg.lstsq(lv.A.to_dense(), b, rcond=None)[0]
    x = _relax(lv.A, x, b, opts, opts.presweeps)      # pre-relaxation
    r = b - lv.A.matvec(x)                            # residual
    rc = lv.R.matvec(r)                               # restrict
    ec = vcycle(h, rc, None, opts, level + 1)         # coarse-grid solve
    x = x + lv.P.matvec(ec)                           # interpolate + correct
    x = _relax(lv.A, x, b, opts, opts.postsweeps)     # post-relaxation
    return x


@dataclasses.dataclass
class SolveResult:
    x: np.ndarray
    residuals: list[float]
    iterations: int
    converged: bool

    @property
    def avg_conv_factor(self) -> float:
        r = self.residuals
        if len(r) < 2 or r[0] == 0:
            return 1.0
        return (r[-1] / r[0]) ** (1.0 / (len(r) - 1))


def solve(h: Hierarchy, b: np.ndarray, tol: float = 1e-8, maxiter: int = 100,
          opts: SolveOptions | None = None, x0: np.ndarray | None = None,
          backend: str = "host", dist=None) -> SolveResult:
    """Stationary AMG iteration: x <- x + V(A, b - Ax)."""
    if backend == "dist":
        from .dist_solve import dist_solve
        return dist_solve(_dist_hierarchy(h, dist), b, tol=tol,
                          maxiter=maxiter, opts=opts, x0=x0)
    if backend != "host":
        raise ValueError(f"unknown backend {backend!r}")
    A = h.levels[0].A
    x = np.zeros_like(b) if x0 is None else x0.copy()
    nb = float(np.linalg.norm(b)) or 1.0
    res = [float(np.linalg.norm(b - A.matvec(x)))]
    for it in range(maxiter):
        if res[-1] / nb < tol:
            return SolveResult(x, res, it, True)
        x = vcycle(h, b, x, opts)
        res.append(float(np.linalg.norm(b - A.matvec(x))))
    return SolveResult(x, res, maxiter, res[-1] / nb < tol)


def pcg(h: Hierarchy, b: np.ndarray, tol: float = 1e-8, maxiter: int = 200,
        opts: SolveOptions | None = None,
        backend: str = "host", dist=None) -> SolveResult:
    """AMG-preconditioned conjugate gradients."""
    if backend == "dist":
        from .dist_solve import dist_pcg
        return dist_pcg(_dist_hierarchy(h, dist), b, tol=tol,
                        maxiter=maxiter, opts=opts)
    if backend != "host":
        raise ValueError(f"unknown backend {backend!r}")
    A = h.levels[0].A
    x = np.zeros_like(b)
    r = b.copy()
    z = vcycle(h, r, None, opts)
    p = z.copy()
    rz = float(r @ z)
    nb = float(np.linalg.norm(b)) or 1.0
    res = [float(np.linalg.norm(r))]
    for it in range(maxiter):
        if res[-1] / nb < tol:
            return SolveResult(x, res, it, True)
        Ap = A.matvec(p)
        alpha = rz / float(p @ Ap)
        x += alpha * p
        r -= alpha * Ap
        res.append(float(np.linalg.norm(r)))
        z = vcycle(h, r, None, opts)
        rz_new = float(r @ z)
        p = z + (rz_new / rz) * p
        rz = rz_new
    return SolveResult(x, res, maxiter, res[-1] / nb < tol)
