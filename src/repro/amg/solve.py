"""AMG solve phase (Algorithm 2): V-cycle, stand-alone iteration and PCG.

The smoother is SpMV-based (Jacobi/Chebyshev), so every relaxation sweep,
residual, restriction and interpolation reuses the level's communication
pattern — the operations whose strategy the paper's models select.

This module owns the **host** (numpy) implementations plus the result
containers.  The public free functions ``vcycle`` / ``solve`` / ``pcg`` are
thin wrappers over the session API of :mod:`repro.amg.api`: they bind the
hierarchy to the requested backend through the backend registry and delegate,
so they share the same caching and multi-RHS semantics as
``AMGSolver(config).setup(A)``:

* ``backend="host"`` — the reference numpy implementation below.
* ``backend="dist"`` — the device-resident path
  (:mod:`repro.amg.dist_solve`): the whole V-cycle runs as one jitted
  shard_map program over a (pods × lanes) mesh, every matvec using the
  level's model-selected node-aware strategy.  Pass ``dist=`` either a
  prebuilt :class:`~repro.amg.dist_solve.DistHierarchy` (reused across
  calls) or a dict of ``DistHierarchy.build`` kwargs
  (e.g. ``dict(n_pods=2, lanes=4)``) — dict kwargs hit a per-hierarchy
  cache, so repeated calls reuse one ``DistHierarchy`` instead of
  rebuilding it.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .csr import CSR
from .hierarchy import Hierarchy
from .smoothers import chebyshev, jacobi


@dataclasses.dataclass(frozen=True)
class SolveOptions:
    """Smoother options.  Frozen (hashable) so it can key program caches and
    live inside a hashable :class:`~repro.amg.api.AMGConfig`."""

    smoother: str = "jacobi"       # "jacobi" | "chebyshev"
    presweeps: int = 1
    postsweeps: int = 1
    omega: float = 2.0 / 3.0
    cheby_degree: int = 2


def _relax(A: CSR, x, b, opts: SolveOptions, sweeps: int):
    if sweeps == 0:
        return x
    if opts.smoother == "jacobi":
        return jacobi(A, x, b, omega=opts.omega, iterations=sweeps)
    return chebyshev(A, x, b, degree=opts.cheby_degree * sweeps)


@dataclasses.dataclass
class SolveResult:
    x: np.ndarray
    residuals: list[float]
    iterations: int
    converged: bool

    @property
    def avg_conv_factor(self) -> float:
        r = self.residuals
        if len(r) < 2 or r[0] == 0:
            return 1.0
        return (r[-1] / r[0]) ** (1.0 / (len(r) - 1))


@dataclasses.dataclass
class MultiSolveResult:
    """Result of a multi-RHS solve: ``x`` is ``[n, k]``, one
    :class:`SolveResult` per right-hand-side column."""

    x: np.ndarray
    columns: list[SolveResult]

    @property
    def n_rhs(self) -> int:
        return len(self.columns)

    @property
    def iterations(self) -> int:
        return max((c.iterations for c in self.columns), default=0)

    @property
    def converged(self) -> bool:
        return all(c.converged for c in self.columns)


# --------------------------------------------------------------------------
# Host (numpy) backend implementations
# --------------------------------------------------------------------------


def host_vcycle(h: Hierarchy, b: np.ndarray, x: np.ndarray | None = None,
                opts: SolveOptions | None = None, level: int = 0) -> np.ndarray:
    """One V(pre,post)-cycle (Algorithm 2) on the host."""
    opts = opts or SolveOptions()
    lv = h.levels[level]
    if x is None:
        x = np.zeros_like(b)
    if level == h.n_levels - 1:                       # coarsest: direct solve
        return np.linalg.lstsq(lv.A.to_dense(), b, rcond=None)[0]
    x = _relax(lv.A, x, b, opts, opts.presweeps)      # pre-relaxation
    r = b - lv.A.matvec(x)                            # residual
    rc = lv.R.matvec(r)                               # restrict
    ec = host_vcycle(h, rc, None, opts, level + 1)    # coarse-grid solve
    x = x + lv.P.matvec(ec)                           # interpolate + correct
    x = _relax(lv.A, x, b, opts, opts.postsweeps)     # post-relaxation
    return x


def host_solve(h: Hierarchy, b: np.ndarray, tol: float = 1e-8,
               maxiter: int = 100, opts: SolveOptions | None = None,
               x0: np.ndarray | None = None) -> SolveResult:
    """Stationary AMG iteration: x <- x + V(A, b - Ax)."""
    A = h.levels[0].A
    x = np.zeros_like(b) if x0 is None else x0.copy()
    nb = float(np.linalg.norm(b)) or 1.0
    res = [float(np.linalg.norm(b - A.matvec(x)))]
    for it in range(maxiter):
        if res[-1] / nb < tol:
            return SolveResult(x, res, it, True)
        x = host_vcycle(h, b, x, opts)
        res.append(float(np.linalg.norm(b - A.matvec(x))))
    return SolveResult(x, res, maxiter, res[-1] / nb < tol)


def host_pcg(h: Hierarchy, b: np.ndarray, tol: float = 1e-8,
             maxiter: int = 200, opts: SolveOptions | None = None,
             x0: np.ndarray | None = None) -> SolveResult:
    """AMG-preconditioned conjugate gradients (optionally warm-started)."""
    A = h.levels[0].A
    x = np.zeros_like(b) if x0 is None else x0.copy()
    r = b - A.matvec(x) if x0 is not None else b.copy()
    z = host_vcycle(h, r, None, opts)
    p = z.copy()
    rz = float(r @ z)
    nb = float(np.linalg.norm(b)) or 1.0
    res = [float(np.linalg.norm(r))]
    for it in range(maxiter):
        if res[-1] / nb < tol:
            return SolveResult(x, res, it, True)
        Ap = A.matvec(p)
        alpha = rz / float(p @ Ap)
        x += alpha * p
        r -= alpha * Ap
        res.append(float(np.linalg.norm(r)))
        z = host_vcycle(h, r, None, opts)
        rz_new = float(r @ z)
        p = z + (rz_new / rz) * p
        rz = rz_new
    return SolveResult(x, res, maxiter, res[-1] / nb < tol)


# --------------------------------------------------------------------------
# Public free functions: thin wrappers over the session API backend registry
# --------------------------------------------------------------------------


def _bound(h: Hierarchy, backend: str, dist, opts):
    from .api import bind_hierarchy
    return bind_hierarchy(h, backend=backend, dist=dist, opts=opts)


def vcycle(h: Hierarchy, b: np.ndarray, x: np.ndarray | None = None,
           opts: SolveOptions | None = None, level: int = 0,
           backend: str = "host", dist=None) -> np.ndarray:
    """One V(pre,post)-cycle (Algorithm 2)."""
    if backend == "host":
        return host_vcycle(h, b, x, opts, level)
    if level != 0:
        raise ValueError(f"backend={backend!r} vcycle starts at level 0")
    return _bound(h, backend, dist, opts).vcycle(b, x0=x)


def solve(h: Hierarchy, b: np.ndarray, tol: float = 1e-8, maxiter: int = 100,
          opts: SolveOptions | None = None, x0: np.ndarray | None = None,
          backend: str = "host", dist=None):
    """Stationary AMG iteration: x <- x + V(A, b - Ax).

    ``b`` may be ``[n]`` (→ :class:`SolveResult`) or ``[n, k]``
    (→ :class:`MultiSolveResult`, the k systems solved together).
    """
    return _bound(h, backend, dist, opts).solve(b, tol=tol, maxiter=maxiter,
                                                x0=x0)


def pcg(h: Hierarchy, b: np.ndarray, tol: float = 1e-8, maxiter: int = 200,
        opts: SolveOptions | None = None, x0: np.ndarray | None = None,
        backend: str = "host", dist=None):
    """AMG-preconditioned conjugate gradients (``x0=`` warm start supported
    on every backend; ``b`` may be ``[n]`` or ``[n, k]``)."""
    return _bound(h, backend, dist, opts).pcg(b, tol=tol, maxiter=maxiter,
                                              x0=x0)
