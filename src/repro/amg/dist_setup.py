"""Distributed node-aware AMG **setup phase** (paper Figs. 14/15 executed).

The paper's headline claim covers both phases of AMG: the setup-phase
SpGEMMs — ``AP_ℓ = A_ℓ·P_ℓ`` and ``A_{ℓ+1} = Pᵀ_ℓ·(AP_ℓ)`` — dominate
communication on coarse levels, and the same three-step node-aware
restructuring that speeds up vector halos applies to matrix-row exchange.
This module runs Algorithm 1 **partitioned from the start**: the fine-grid
matrix is split into per-rank row blocks once, every stage operates on
blocks, and the hierarchy that comes out is *born partitioned* — it is
lowered straight onto the device mesh by
:meth:`~repro.amg.dist_solve.DistHierarchy.from_partitioned` with no host
gather/re-scatter between setup and solve.

Per level ℓ:

* **strength** — row-local; :func:`~repro.amg.hierarchy.strength_stage`
  runs unchanged on each rank's block (a row's pattern depends only on
  that row).
* **splitting** — the PMIS iteration re-run per-partition: the strength
  transpose arrives through a transpose exchange, and each round's
  unassigned/new-C indicators move through vector halo gathers
  (:func:`_dist_pmis` reproduces :func:`repro.amg.splitting.pmis`
  bit-for-bit).  Aggressive (distance-2) coarsening squares the strength
  graph with the same NAP matrix-row exchange as the Galerkin products.
* **interpolation** — per-block :func:`~repro.amg.interpolation.
  direct_interpolation`, with C/F status and the fine→coarse map for halo
  columns supplied by vector gathers.
* **Galerkin products** — the tentpole: :func:`~repro.amg.dist.
  matrix_comm_graph` (indices = rows of B, weights = per-row bytes) feeds
  :func:`repro.core.selector.select`, and the winning standard/NAP-2/NAP-3
  schedule is *executed* as a rank-faithful CSR-row exchange
  (:func:`~repro.core.nap_collectives.matrix_halo_exchange`) before each
  rank's local SpGEMM.  Modeled times and measured message/byte counts are
  recorded per (level, op) in :class:`SetupCommRecord`.

Matrix representation: "global indexing, local storage" — each rank holds a
*global-shape* CSR containing only its own rows (:class:`BlockMatrix`), so
column ids never need remapping, every stage kernel is reused verbatim, and
no global CSR of any level operator is ever assembled (the sole exceptions:
the input fine-grid matrix, which the caller hands us, and the coarsest
level's tiny dense pseudo-inverse shared with the host-lowered path).

Entry points: :func:`dist_setup_partitioned` (numpy-only loop → blocks +
records, usable without any device mesh) and :func:`dist_setup`
(→ :class:`~repro.amg.dist_solve.DistHierarchy`, the
``AMGConfig(setup_backend="dist")`` path).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from ..core import MachineParams, Partition, Topology, select
from ..core.nap_collectives import (MatrixHaloPlan, build_matrix_halo_plan,
                                    matrix_halo_exchange)
from ..core.perf_model import TPU_V5E
from .csr import CSR
from .dist import matrix_comm_graph
from .hierarchy import strength_stage
from .splitting import CPOINT, FPOINT, UNASSIGNED, _drop_diag

SETUP_STRATEGIES = ("standard", "nap2", "nap3")


# --------------------------------------------------------------------------
# Block representation: global indexing, local storage
# --------------------------------------------------------------------------


def _global_shape_block(M: CSR, lo: int, hi: int) -> CSR:
    """Rows ``[lo, hi)`` of ``M`` as a global-shape CSR (other rows empty)."""
    sl = slice(int(M.indptr[lo]), int(M.indptr[hi]))
    indptr = np.zeros(M.nrows + 1, dtype=np.int64)
    indptr[lo + 1: hi + 1] = M.indptr[lo + 1: hi + 1] - M.indptr[lo]
    indptr[hi + 1:] = indptr[hi]
    return CSR(M.shape, indptr, M.indices[sl].copy(), M.data[sl].copy())


class BlockMatrix:
    """A row-partitioned matrix that never exists as one global CSR.

    ``blocks[d]`` is a global-shape CSR holding exactly rank d's rows of the
    partition (global column ids, empty remote rows).  Implements the subset
    of the :class:`~repro.amg.csr.CSR` protocol the analysis and lowering
    layers consume (``offproc_columns``, ``submatrix_rows``, ``indptr``,
    ``diagonal``, ``matvec``, ``to_dense``), each dispatching to — or
    reducing over — the per-rank blocks, so :func:`~repro.amg.dist.
    matrix_comm_graph`, :func:`~repro.amg.dist.rect_vector_graph` and
    :func:`~repro.amg.dist_solve.DistHierarchy.from_partitioned` work on it
    unchanged.
    """

    def __init__(self, blocks: list[CSR], part: Partition):
        assert len(blocks) == part.topo.n_procs
        self.blocks = blocks
        self.part = part
        self.shape = blocks[0].shape

    @property
    def nrows(self) -> int:
        return self.shape[0]

    @property
    def ncols(self) -> int:
        return self.shape[1]

    @property
    def nnz(self) -> int:
        return sum(b.nnz for b in self.blocks)

    @property
    def indptr(self) -> np.ndarray:
        # disjoint row sets ⇒ the union's indptr is the sum of the blocks'
        # (cumsum is linear in the per-row counts)
        out = np.zeros(self.nrows + 1, dtype=np.int64)
        for b in self.blocks:
            out += b.indptr
        return out

    def _owner_of_range(self, row_lo: int, row_hi: int) -> int:
        d = int(self.part.owner_of_rows(np.asarray([row_lo]))[0])
        lo, hi = self.part.local_range(d)
        assert lo <= row_lo and row_hi <= hi, \
            f"rows [{row_lo},{row_hi}) cross rank boundaries"
        return d

    def offproc_columns(self, lo: int, hi: int, row_lo: int,
                        row_hi: int) -> np.ndarray:
        if row_lo == row_hi:
            return np.zeros(0, dtype=np.int64)
        d = self._owner_of_range(row_lo, row_hi)
        return self.blocks[d].offproc_columns(lo, hi, row_lo, row_hi)

    def submatrix_rows(self, row_lo: int, row_hi: int) -> CSR:
        if row_lo == row_hi:
            return CSR((0, self.ncols), np.zeros(1, dtype=np.int64),
                       np.zeros(0, dtype=np.int64), np.zeros(0))
        d = self._owner_of_range(row_lo, row_hi)
        return self.blocks[d].submatrix_rows(row_lo, row_hi)

    def diagonal(self) -> np.ndarray:
        out = np.zeros(min(self.shape))
        for b in self.blocks:
            out += b.diagonal()
        return out

    def matvec(self, x: np.ndarray) -> np.ndarray:
        out = None
        for b in self.blocks:
            y = b.matvec(x)
            out = y if out is None else out + y
        return out

    def to_dense(self) -> np.ndarray:
        # only legitimate for the tiny coarsest level (dense pinv solve)
        out = np.zeros(self.shape)
        for b in self.blocks:
            out += b.to_dense()
        return out


def split_rows(A: CSR, part: Partition) -> BlockMatrix:
    """Partition a global CSR into per-rank row blocks (the fine-grid entry
    point — the one place a global level matrix is read)."""
    blocks = [_global_shape_block(A, *part.local_range(d))
              for d in range(part.topo.n_procs)]
    return BlockMatrix(blocks, part)


def transpose_blocks(M: BlockMatrix, out_part: Partition) -> BlockMatrix:
    """Rows of ``Mᵀ``, partitioned by ``out_part`` — the transpose exchange.

    Each source rank hands the entries of its rows, grouped by column owner,
    to that column's owner; concatenating contributions in rank order (==
    global row order) reproduces the host ``CSR.T`` per row exactly (sorted
    column ids, identical values).
    """
    D = out_part.topo.n_procs
    t = [blk.transpose() for blk in M.blocks]       # per-source, global rows
    out_blocks = []
    for r in range(D):
        lo, hi = out_part.local_range(r)
        acc = None
        for s in range(D):
            piece = _global_shape_block(t[s], lo, hi)
            if piece.nnz == 0 and acc is not None:
                continue
            acc = piece if acc is None else acc.add(piece)
        out_blocks.append(acc)
    return BlockMatrix(out_blocks, out_part)


def _rows_to_block(rows: dict[int, tuple[np.ndarray, np.ndarray]],
                   shape: tuple[int, int]) -> CSR:
    """Received halo rows ({global row: (cols, vals)}) as a global-shape CSR."""
    n = shape[0]
    indptr = np.zeros(n + 1, dtype=np.int64)
    if not rows:
        return CSR(shape, indptr, np.zeros(0, dtype=np.int64), np.zeros(0))
    idx = np.fromiter(sorted(rows), dtype=np.int64, count=len(rows))
    cols = np.concatenate([rows[int(i)][0] for i in idx])
    vals = np.concatenate([rows[int(i)][1] for i in idx])
    counts = np.zeros(n, dtype=np.int64)
    counts[idx] = [rows[int(i)][0].size for i in idx]
    np.cumsum(counts, out=indptr[1:])
    return CSR(shape, indptr, cols.astype(np.int64), vals.astype(np.float64))


def _gather(parts: list[np.ndarray], part: Partition,
            idx: np.ndarray) -> np.ndarray:
    """Vector halo gather: values of global indices ``idx`` from their
    owners' local slices (the setup phase's auxiliary vector communication —
    status/weight indicators, fine→coarse maps)."""
    out = np.empty(idx.shape, dtype=parts[0].dtype if parts else np.float64)
    if idx.size == 0:
        return out
    owners = part.owner_of_rows(idx)
    for o in np.unique(owners):
        o = int(o)
        lo, _ = part.local_range(o)
        m = owners == o
        out[m] = parts[o][idx[m] - lo]
    return out


# --------------------------------------------------------------------------
# The NAP matrix-row exchange + partitioned SpGEMM
# --------------------------------------------------------------------------


@dataclasses.dataclass
class SetupCommRecord:
    """One setup-phase SpGEMM's communication: what the model chose and what
    the exchange measured (the per-level modeled-vs-measured benchmark row)."""

    level: int
    op: str                      # "spgemm_AP" | "spgemm_PtAP" | "spgemm_S2"
    strategy: str
    modeled: dict[str, float]    # modeled seconds per strategy ({} if forced)
    inter_msgs: int = 0
    inter_bytes: float = 0.0
    intra_msgs: int = 0
    intra_bytes: float = 0.0
    seconds: float = 0.0         # measured wall time of the row exchange
    n_halo_rows: int = 0         # total B rows communicated (all ranks)
    # on/off split of the local products: C_on = A·B_local runs while the
    # row exchange is in flight, C_off = A·B_halo lands after it
    on_nnz: int = 0              # nnz of all ranks' C_on
    off_nnz: int = 0             # nnz of all ranks' C_off
    on_seconds: float = 0.0      # measured wall time of the C_on products
    off_seconds: float = 0.0     # measured wall time of C_off + merge

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def dist_spgemm(Ab: BlockMatrix, Bb: BlockMatrix, *,
                params: MachineParams = TPU_V5E, strategy: str = "auto",
                strategies: tuple[str, ...] = SETUP_STRATEGIES,
                op: str = "spgemm", level: int = 0,
                records: list | None = None,
                plan_cache: dict | None = None) -> BlockMatrix:
    """``C = A·B`` with A, B and C row-partitioned.

    Overlapped structure: each rank's on-process product ``C_on = A·B_local``
    needs no remote data, so it runs *before* the halo rows land (an MPI
    code posts the sends, multiplies, then waits); the off-process
    correction ``C_off = A·B_halo`` and the merge follow the exchange.
    ``B_local`` and the halo rows are row-disjoint, so
    ``C_on + C_off == A·(B_local + B_halo)`` with the same sparsity pattern
    (values reassociated within fp round-off).

    ``plan_cache`` (keyed by ``op``) makes the product replayable for
    streaming value refreshes: on a miss the comm graph is built and the
    strategy selected as usual, then ``(strategy, plan)`` is stored; on a
    hit both are reused verbatim — no comm-graph rebuild, no model
    re-selection — which is sound exactly when the operand sparsity
    patterns are frozen (the plan is a pure function of them).
    """
    cached = plan_cache.get(op) if plan_cache is not None else None
    if cached is not None:
        strat, plan = cached
        times = {}
    else:
        g = matrix_comm_graph(Ab, Bb, Ab.part, b_part=Bb.part)
        if strategy == "auto":
            sel = select(g, params, strategies)
            strat, times = sel.strategy, dict(sel.times)
            plan = MatrixHaloPlan(strat, g, sel.schedule)
        else:
            strat, times = strategy, {}
            plan = build_matrix_halo_plan(g, strat)
        if plan_cache is not None:
            plan_cache[op] = (strat, plan)

    def get_row(rank: int, i: int):
        blk = Bb.blocks[rank]
        sl = slice(int(blk.indptr[i]), int(blk.indptr[i + 1]))
        return blk.indices[sl], blk.data[sl]

    D = Ab.part.topo.n_procs
    t0 = time.perf_counter()
    on_blocks = [Ab.blocks[d].spgemm(Bb.blocks[d]) for d in range(D)]
    on_seconds = time.perf_counter() - t0
    res = matrix_halo_exchange(plan, get_row)
    t0 = time.perf_counter()
    out_blocks = []
    off_nnz = 0
    for d in range(D):
        halo = _rows_to_block(res.halo[d], Bb.shape)
        if halo.nnz:
            C_off = Ab.blocks[d].spgemm(halo)
            off_nnz += C_off.nnz
            out_blocks.append(on_blocks[d].add(C_off))
        else:
            out_blocks.append(on_blocks[d])
    off_seconds = time.perf_counter() - t0
    if records is not None:
        records.append(SetupCommRecord(
            level=level, op=op, strategy=strat, modeled=times,
            inter_msgs=res.inter_msgs, inter_bytes=res.inter_bytes,
            intra_msgs=res.intra_msgs, intra_bytes=res.intra_bytes,
            seconds=res.seconds,
            n_halo_rows=sum(len(h) for h in res.halo),
            on_nnz=sum(b.nnz for b in on_blocks), off_nnz=off_nnz,
            on_seconds=on_seconds, off_seconds=off_seconds))
    return BlockMatrix(out_blocks, Ab.part)


# --------------------------------------------------------------------------
# Partitioned PMIS splitting (bit-for-bit the host iteration)
# --------------------------------------------------------------------------


def _sym_graph_blocks(Sb: BlockMatrix, Stb: BlockMatrix) -> BlockMatrix:
    """Per-rank ``drop_diag(S + Sᵀ)`` — the host ``_sym_graph`` on blocks."""
    return BlockMatrix([_drop_diag(s.add(t))
                        for s, t in zip(Sb.blocks, Stb.blocks)], Sb.part)


def _dist_pmis(Gb: BlockMatrix, w_parts: list[np.ndarray],
               part: Partition) -> list[np.ndarray]:
    """PMIS on a partitioned (symmetric) strength graph.

    Mirrors :func:`repro.amg.splitting.pmis` exactly: per-rank full-length
    scratch vectors hold only local + halo entries (everything a rank's rows
    reference), refreshed each round by vector halo gathers; the numeric-tie
    fallback is a global arg-max reduction.  G's symmetry is what lets the
    "neighbors of new C points" update run with forward gathers only.
    """
    from .splitting import _row_max

    D = part.topo.n_procs
    n = Gb.nrows
    ranges = [part.local_range(d) for d in range(D)]
    need = [Gb.blocks[d].offproc_columns(*ranges[d], *ranges[d])
            for d in range(D)]
    # static: w at local + halo positions
    w_full = []
    for d in range(D):
        lo, hi = ranges[d]
        wf = np.zeros(n)
        wf[lo:hi] = w_parts[d]
        wf[need[d]] = _gather(w_parts, part, need[d])
        w_full.append(wf)
    status = []
    for d in range(D):
        lo, hi = ranges[d]
        st = np.full(hi - lo, UNASSIGNED, dtype=np.int64)
        st[np.diff(Gb.blocks[d].indptr)[lo:hi] == 0] = FPOINT  # isolated
        status.append(st)

    while any((st == UNASSIGNED).any() for st in status):
        unass_parts = [(st == UNASSIGNED) for st in status]
        new_c_parts = []
        for d in range(D):
            lo, hi = ranges[d]
            uf = np.zeros(n, dtype=bool)
            uf[lo:hi] = unass_parts[d]
            uf[need[d]] = _gather(unass_parts, part, need[d])
            nb_max = _row_max(Gb.blocks[d], w_full[d], uf)[lo:hi]
            new_c_parts.append(unass_parts[d] & (w_full[d][lo:hi] > nb_max))
        if not any(nc.any() for nc in new_c_parts):
            # numeric tie safety: global arg-max over unassigned (first
            # occurrence in global row order, as the host fallback picks)
            best_val, best = -np.inf, None
            for d in range(D):
                lo, _ = ranges[d]
                idx = np.flatnonzero(unass_parts[d])
                if idx.size == 0:
                    continue
                j = idx[np.argmax(w_parts[d][idx])]
                if w_parts[d][j] > best_val:
                    best_val, best = w_parts[d][j], (d, j)
            d, j = best
            new_c_parts[d][j] = True
        for d in range(D):
            status[d][new_c_parts[d]] = CPOINT
        for d in range(D):
            lo, hi = ranges[d]
            cf = np.zeros(n, dtype=bool)
            cf[lo:hi] = new_c_parts[d]
            cf[need[d]] = _gather(new_c_parts, part, need[d])
            blk = Gb.blocks[d]
            r = blk.rows_expanded()
            touched = np.zeros(n, dtype=bool)
            touched[r[cf[blk.indices]]] = True   # rows with a new-C neighbor
            upd = (status[d] == UNASSIGNED) & touched[lo:hi]
            status[d][upd] = FPOINT
    return status


# --------------------------------------------------------------------------
# The partitioned setup loop (Algorithm 1 over blocks)
# --------------------------------------------------------------------------


@dataclasses.dataclass
class PartitionedLevel:
    """Mirror of :class:`~repro.amg.hierarchy.Level` with every operator a
    :class:`BlockMatrix` — a level that was born partitioned."""

    A: BlockMatrix
    P: BlockMatrix | None = None
    R: BlockMatrix | None = None
    AP: BlockMatrix | None = None
    setup_seconds: float = 0.0
    # NAP schedules of this level's Galerkin row exchanges, keyed by op
    # ("spgemm_AP"/"spgemm_PtAP" → (strategy, MatrixHaloPlan)) — retained
    # so streaming value refreshes replay the products through the
    # already-selected schedules without rebuilding any comm graph
    plans: dict = dataclasses.field(default_factory=dict, repr=False,
                                    compare=False)


def dist_setup_partitioned(
        A: CSR, n_pods: int, lanes: int, *, solver: str = "rs",
        theta: float = 0.25, max_coarse: int = 100, max_levels: int = 25,
        aggressive: bool = False, prolongation_sweeps: int = 1,
        seed: int = 42, params: MachineParams = TPU_V5E,
        strategy: str = "auto",
        strategies: tuple[str, ...] = SETUP_STRATEGIES,
) -> tuple[list[PartitionedLevel], list[SetupCommRecord]]:
    """Algorithm 1, partitioned end-to-end (numpy only — no device mesh).

    Returns the per-level blocks plus one :class:`SetupCommRecord` per
    executed SpGEMM row exchange.  Matches :func:`repro.amg.hierarchy.setup`
    sparsity and values exactly (same kernels, same per-row arithmetic).
    """
    from .interpolation import direct_interpolation

    if solver != "rs":
        raise ValueError(
            f"setup_backend='dist' supports solver='rs' (got {solver!r}); "
            "SA's MIS-2 aggregation has order-dependent host semantics — "
            "use the host setup for 'sa'")
    topo = Topology(n_nodes=n_pods, ppn=lanes)
    D = topo.n_procs
    part0 = Partition.balanced(A.nrows, topo)
    plevels = [PartitionedLevel(A=split_rows(A, part0))]
    records: list[SetupCommRecord] = []
    l = 0
    while plevels[l].A.nrows > max_coarse and l + 1 < max_levels:
        t0 = time.perf_counter()
        Ab = plevels[l].A
        part = Ab.part
        n = Ab.nrows
        ranges = [part.local_range(d) for d in range(D)]
        # -- strength: row-local, exact per block
        Sb = BlockMatrix([strength_stage(blk, solver, theta)
                          for blk in Ab.blocks], part)
        # -- splitting: symmetrize (transpose exchange), optional distance-2
        #    squaring (NAP matrix-row exchange), then the partitioned PMIS
        Stb = transpose_blocks(Sb, part)
        Gb = _sym_graph_blocks(Sb, Stb)
        if aggressive:
            GG = dist_spgemm(Gb, Gb, params=params, strategy=strategy,
                             strategies=strategies, op="spgemm_S2",
                             level=l, records=records,
                             plan_cache=plevels[l].plans)
            Gb = _sym_graph_blocks(GG, transpose_blocks(GG, part))
        # w = (#strong transpose connections) + replicated random tiebreak —
        # every rank draws the same deterministic stream, as an SPMD code
        # would, so the splitting matches the host bit-for-bit
        rng_w = np.random.default_rng(seed + l).random(n)
        w_parts = [np.diff(Stb.blocks[d].indptr)[lo:hi].astype(np.float64)
                   + rng_w[lo:hi] for d, (lo, hi) in enumerate(ranges)]
        status = _dist_pmis(Gb, w_parts, part)
        n_c = sum(int((st == CPOINT).sum()) for st in status)
        if n_c in (0, n):
            break  # coarsening stalled
        # -- interpolation: per-block direct interpolation; C/F status and
        #    the fine→coarse map at halo columns come from vector gathers
        c_counts = [int((st == CPOINT).sum()) for st in status]
        c_offsets = np.concatenate([[0], np.cumsum(c_counts)])[:-1]
        cmap_parts = [np.cumsum(st == CPOINT) - 1 + c_offsets[d]
                      for d, st in enumerate(status)]
        P_blocks = []
        for d, (lo, hi) in enumerate(ranges):
            halo = Sb.blocks[d].offproc_columns(lo, hi, lo, hi)
            row_status = np.full(n, FPOINT, dtype=np.int64)
            row_status[lo:hi] = status[d]
            col_status = np.full(n, FPOINT, dtype=np.int64)
            col_status[lo:hi] = status[d]
            col_status[halo] = _gather(status, part, halo)
            col_cmap = np.zeros(n, dtype=np.int64)
            col_cmap[lo:hi] = cmap_parts[d]
            col_cmap[halo] = _gather(cmap_parts, part, halo)
            P_blocks.append(direct_interpolation(
                Ab.blocks[d], Sb.blocks[d], row_status,
                col_status=col_status, cmap=col_cmap, nc=n_c))
        Pb = BlockMatrix(P_blocks, part)
        cpart = Partition.balanced(n_c, topo)
        Rb = transpose_blocks(Pb, cpart)
        # -- Galerkin triple product: the two NAP matrix-row exchanges
        APb = dist_spgemm(Ab, Pb, params=params, strategy=strategy,
                          strategies=strategies, op="spgemm_AP",
                          level=l, records=records,
                          plan_cache=plevels[l].plans)
        Acb = dist_spgemm(Rb, APb, params=params, strategy=strategy,
                          strategies=strategies, op="spgemm_PtAP",
                          level=l, records=records,
                          plan_cache=plevels[l].plans)
        Acb = BlockMatrix([blk.prune(1e-14) for blk in Acb.blocks], cpart)
        plevels[l].P, plevels[l].R, plevels[l].AP = Pb, Rb, APb
        plevels[l].setup_seconds = time.perf_counter() - t0
        plevels.append(PartitionedLevel(A=Acb))
        # the stall check above guarantees 0 < n_c < n, so the Galerkin
        # coarse grid strictly shrinks — no host-style no-progress pop
        l += 1
    return plevels, records


def refresh_partitioned_values(
        plevels: list[PartitionedLevel], A_new: CSR, *,
        records: list | None = None) -> None:
    """Value-only refresh of a born-partitioned hierarchy onto ``A_new``.

    The caller guarantees ``A_new`` shares the fine level's sparsity
    pattern.  Everything structural is frozen — splittings, interpolation
    operators (values included), comm graphs and the per-level NAP
    schedules cached in :attr:`PartitionedLevel.plans` — and only the
    Galerkin products are replayed numerically: the row exchanges run
    through the already-selected :class:`MatrixHaloPlan` s, and each
    coarse product is projected onto the next level's frozen (pruned)
    pattern so every downstream lowering stays valid.
    """
    from .hierarchy import project_pattern_values

    fine = plevels[0].A
    new_blocks = split_rows(A_new, fine.part)
    for old, new in zip(fine.blocks, new_blocks.blocks):
        if old.data.shape != new.data.shape:
            raise ValueError(f"value refresh needs {old.data.shape[0]} "
                             f"values per block, got {new.data.shape[0]}")
        old.data[...] = new.data
    for l, (plv, nxt) in enumerate(zip(plevels[:-1], plevels[1:])):
        APb = dist_spgemm(plv.A, plv.P, op="spgemm_AP", level=l,
                          records=records, plan_cache=plv.plans)
        Acb = dist_spgemm(plv.R, APb, op="spgemm_PtAP", level=l,
                          records=records, plan_cache=plv.plans)
        for old, new in zip(plv.AP.blocks, APb.blocks):
            old.data[...] = project_pattern_values(
                new, old.indptr, old.indices, old.nrows, old.ncols)
        for old, new in zip(nxt.A.blocks, Acb.blocks):
            old.data[...] = project_pattern_values(
                new, old.indptr, old.indices, old.nrows, old.ncols)


def dist_setup(A: CSR, n_pods: int = 1, lanes: int = 1, *,
               solver: str = "rs", theta: float = 0.25,
               max_coarse: int = 100, max_levels: int = 25,
               aggressive: bool = False, prolongation_sweeps: int = 1,
               seed: int = 42, params: MachineParams = TPU_V5E,
               strategy: str = "auto",
               strategies: tuple[str, ...] = SETUP_STRATEGIES,
               dtype=None, mesh=None, use_kernel: bool | None = None,
               interpret: bool | None = None,
               reduce_strategy: str = "nap3"):
    """Partitioned setup → :class:`~repro.amg.dist_solve.DistHierarchy`.

    The whole pipeline from the partitioned fine-grid A to the lowered,
    solvable hierarchy runs without ever assembling a level operator on the
    host; per-level setup-phase strategy selections land in the hierarchy's
    ``selection_table()`` / ``setup_records``.
    """
    import jax.numpy as jnp

    from .dist_solve import DistHierarchy

    plevels, records = dist_setup_partitioned(
        A, n_pods, lanes, solver=solver, theta=theta, max_coarse=max_coarse,
        max_levels=max_levels, aggressive=aggressive,
        prolongation_sweeps=prolongation_sweeps, seed=seed, params=params,
        strategy=strategy, strategies=strategies)
    return DistHierarchy.from_partitioned(
        plevels, n_pods, lanes, setup_records=records, params=params,
        strategy=strategy, dtype=jnp.float32 if dtype is None else dtype,
        mesh=mesh, use_kernel=use_kernel, interpret=interpret,
        reduce_strategy=reduce_strategy)
