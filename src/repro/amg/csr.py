"""Minimal-but-fast CSR sparse matrix in pure numpy (no scipy in container).

Implements exactly what AMG needs: SpMV, SpGEMM (vectorized Gustavson via
expand/coalesce), transpose, diagonal extraction, pruning, and converters.
All index arrays are int64; values float64.

Also holds the :class:`BCSR` block layout (dense ``bs×bs`` blocks in a
block-ELL arrangement) and :func:`csr_to_bcsr` — the host-side lowering the
MXU-blocked Pallas kernel (:mod:`repro.kernels.spmv.bcsr`) consumes.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CSR:
    shape: tuple[int, int]
    indptr: np.ndarray   # (nrows+1,) int64
    indices: np.ndarray  # (nnz,)    int64, column ids (sorted per row)
    data: np.ndarray     # (nnz,)    float64

    # ------------------------------------------------------------ constructors
    @staticmethod
    def from_coo(rows, cols, vals, shape) -> "CSR":
        rows = np.asarray(rows, dtype=np.int64)
        cols = np.asarray(cols, dtype=np.int64)
        vals = np.asarray(vals, dtype=np.float64)
        nrows, ncols = shape
        if rows.size:
            key = rows * ncols + cols
            order = np.argsort(key, kind="stable")
            key, vals = key[order], vals[order]
            uniq, inv = np.unique(key, return_inverse=True)
            summed = np.bincount(inv, weights=vals, minlength=uniq.size)
            rows_u = (uniq // ncols).astype(np.int64)
            cols_u = (uniq % ncols).astype(np.int64)
        else:
            rows_u = cols_u = np.zeros(0, dtype=np.int64)
            summed = np.zeros(0, dtype=np.float64)
        indptr = np.zeros(nrows + 1, dtype=np.int64)
        np.cumsum(np.bincount(rows_u, minlength=nrows), out=indptr[1:])
        return CSR(shape=(nrows, ncols), indptr=indptr, indices=cols_u, data=summed)

    @staticmethod
    def from_dense(M) -> "CSR":
        M = np.asarray(M, dtype=np.float64)
        rows, cols = np.nonzero(M)
        return CSR.from_coo(rows, cols, M[rows, cols], M.shape)

    @staticmethod
    def eye(n, value: float = 1.0) -> "CSR":
        return CSR(shape=(n, n),
                   indptr=np.arange(n + 1, dtype=np.int64),
                   indices=np.arange(n, dtype=np.int64),
                   data=np.full(n, value, dtype=np.float64))

    @staticmethod
    def from_diag(d) -> "CSR":
        d = np.asarray(d, dtype=np.float64)
        return CSR(shape=(d.size, d.size),
                   indptr=np.arange(d.size + 1, dtype=np.int64),
                   indices=np.arange(d.size, dtype=np.int64),
                   data=d.copy())

    # ---------------------------------------------------------------- basics
    @property
    def nnz(self) -> int:
        return int(self.indices.size)

    @property
    def nrows(self) -> int:
        return self.shape[0]

    @property
    def ncols(self) -> int:
        return self.shape[1]

    def row_lengths(self) -> np.ndarray:
        return np.diff(self.indptr)

    def rows_expanded(self) -> np.ndarray:
        """Row id of every stored nonzero, shape (nnz,)."""
        return np.repeat(np.arange(self.nrows, dtype=np.int64), self.row_lengths())

    def to_dense(self) -> np.ndarray:
        M = np.zeros(self.shape)
        M[self.rows_expanded(), self.indices] = self.data
        return M

    def copy(self) -> "CSR":
        return CSR(self.shape, self.indptr.copy(), self.indices.copy(), self.data.copy())

    def diagonal(self) -> np.ndarray:
        d = np.zeros(min(self.shape))
        r = self.rows_expanded()
        mask = (r == self.indices) & (r < d.size)
        d[r[mask]] = self.data[mask]
        return d

    # ------------------------------------------------------------------- ops
    def matvec(self, x: np.ndarray) -> np.ndarray:
        x = np.asarray(x)
        prod = self.data * x[self.indices]
        out = np.zeros(self.nrows, dtype=np.result_type(self.data, x))
        np.add.at(out, self.rows_expanded(), prod)
        return out

    def __matmul__(self, other):
        if isinstance(other, CSR):
            return self.spgemm(other)
        return self.matvec(other)

    def transpose(self) -> "CSR":
        order = np.argsort(self.indices, kind="stable")
        rows_t = self.indices[order]
        cols_t = self.rows_expanded()[order]
        indptr = np.zeros(self.ncols + 1, dtype=np.int64)
        np.cumsum(np.bincount(rows_t, minlength=self.ncols), out=indptr[1:])
        return CSR(shape=(self.ncols, self.nrows), indptr=indptr,
                   indices=cols_t, data=self.data[order])

    @property
    def T(self) -> "CSR":
        return self.transpose()

    def spgemm(self, B: "CSR") -> "CSR":
        """C = self @ B — vectorized expand + coalesce (Gustavson order)."""
        A = self
        if A.ncols != B.nrows:
            raise ValueError(f"shape mismatch {A.shape} @ {B.shape}")
        lens = B.indptr[A.indices + 1] - B.indptr[A.indices]     # per A-nnz
        total = int(lens.sum())
        if total == 0:
            return CSR.from_coo([], [], [], (A.nrows, B.ncols))
        starts = B.indptr[A.indices]
        # positions into B's arrays for every expanded term
        cum = np.cumsum(lens) - lens
        offs = np.arange(total, dtype=np.int64) - np.repeat(cum, lens)
        pos = np.repeat(starts, lens) + offs
        out_rows = np.repeat(A.rows_expanded(), lens)
        out_cols = B.indices[pos]
        out_vals = np.repeat(A.data, lens) * B.data[pos]
        return CSR.from_coo(out_rows, out_cols, out_vals, (A.nrows, B.ncols))

    def scale_rows(self, d: np.ndarray) -> "CSR":
        out = self.copy()
        out.data = out.data * np.asarray(d)[out.rows_expanded()]
        return out

    def scale_cols(self, d: np.ndarray) -> "CSR":
        out = self.copy()
        out.data = out.data * np.asarray(d)[out.indices]
        return out

    def add(self, B: "CSR", alpha: float = 1.0, beta: float = 1.0) -> "CSR":
        if self.shape != B.shape:
            raise ValueError("shape mismatch in add")
        rows = np.concatenate([self.rows_expanded(), B.rows_expanded()])
        cols = np.concatenate([self.indices, B.indices])
        vals = np.concatenate([alpha * self.data, beta * B.data])
        return CSR.from_coo(rows, cols, vals, self.shape)

    def prune(self, tol: float = 0.0) -> "CSR":
        """Drop entries with |value| <= tol (keeps explicit diagonal)."""
        r = self.rows_expanded()
        keep = (np.abs(self.data) > tol) | (r == self.indices)
        indptr = np.zeros(self.nrows + 1, dtype=np.int64)
        np.cumsum(np.bincount(r[keep], minlength=self.nrows), out=indptr[1:])
        return CSR(self.shape, indptr, self.indices[keep], self.data[keep])

    def offproc_columns(self, lo: int, hi: int, row_lo: int, row_hi: int) -> np.ndarray:
        """Unique column ids outside [lo,hi) among rows [row_lo,row_hi)."""
        sl = slice(self.indptr[row_lo], self.indptr[row_hi])
        cols = self.indices[sl]
        return np.unique(cols[(cols < lo) | (cols >= hi)])

    def submatrix_rows(self, row_lo: int, row_hi: int) -> "CSR":
        sl = slice(int(self.indptr[row_lo]), int(self.indptr[row_hi]))
        indptr = (self.indptr[row_lo:row_hi + 1] - self.indptr[row_lo]).astype(np.int64)
        return CSR((row_hi - row_lo, self.ncols), indptr,
                   self.indices[sl].copy(), self.data[sl].copy())


# --------------------------------------------------------------------------
# BCSR: dense bs×bs blocks in a block-ELL layout (the MXU kernel's form)
# --------------------------------------------------------------------------


@dataclasses.dataclass
class BCSR:
    """Block-ELL BCSR: every stored block is a dense ``bs×bs`` tile.

    ``bcols[r, j]`` is the block-column id of block row ``r``'s j-th stored
    block (-1 padding past the row's block count); ``bvals[r, j]`` the dense
    tile (explicit zero fill inside).  Rows/columns are zero-padded up to a
    multiple of ``block_size``; ``shape`` keeps the logical (unpadded)
    extent so round-trips slice the padding back off.
    """

    shape: tuple[int, int]     # logical (unpadded) shape
    block_size: int
    bcols: np.ndarray          # [mb, Kb] int32, -1 pad
    bvals: np.ndarray          # [mb, Kb, bs, bs] float64

    @property
    def n_blocks(self) -> int:
        return int((self.bcols >= 0).sum())

    @property
    def fill(self) -> float:
        """Fraction of stored block entries that are true nonzeros."""
        stored = self.n_blocks * self.block_size ** 2
        return float(np.count_nonzero(self.bvals)) / stored if stored else 0.0

    def to_dense(self) -> np.ndarray:
        bs = self.block_size
        mb, Kb = self.bcols.shape
        nbc = -(-self.shape[1] // bs)
        out = np.zeros((mb * bs, nbc * bs))
        for r in range(mb):
            for j in range(Kb):
                bc = int(self.bcols[r, j])
                if bc < 0:
                    continue
                out[r * bs:(r + 1) * bs, bc * bs:(bc + 1) * bs] = \
                    self.bvals[r, j]
        return out[: self.shape[0], : self.shape[1]]


def csr_to_bcsr(A: CSR, block_size: int) -> BCSR:
    """Lower a CSR matrix to block-ELL BCSR with dense ``bs×bs`` blocks.

    Rows and columns are implicitly padded (with zeros) to multiples of
    ``block_size``; blocks never straddle the padding boundary.  Vectorized:
    one ``np.unique`` over block coordinates, then a scatter of the values
    into their tiles.
    """
    bs = int(block_size)
    if bs <= 0:
        raise ValueError(f"block_size must be positive, got {bs}")
    mb = -(-A.nrows // bs)
    nbc = -(-A.ncols // bs)
    r, c, v = A.rows_expanded(), A.indices, A.data
    if r.size == 0:
        return BCSR(shape=A.shape, block_size=bs,
                    bcols=np.full((mb, 0), -1, dtype=np.int32),
                    bvals=np.zeros((mb, 0, bs, bs)))
    br, bc = r // bs, c // bs
    key = br * nbc + bc
    ukeys, inv = np.unique(key, return_inverse=True)
    ubr = (ukeys // nbc).astype(np.int64)
    ubc = (ukeys % nbc).astype(np.int64)
    # slot of each stored block within its block row (ukeys are sorted, so
    # blocks of one row are contiguous and column-ordered)
    row_starts = np.searchsorted(ubr, np.arange(mb))
    slot = np.arange(ukeys.size, dtype=np.int64) - row_starts[ubr]
    Kb = int(np.bincount(ubr, minlength=mb).max(initial=0))
    bcols = np.full((mb, Kb), -1, dtype=np.int32)
    bcols[ubr, slot] = ubc.astype(np.int32)
    bvals = np.zeros((mb, Kb, bs, bs))
    bvals[ubr[inv], slot[inv], r % bs, c % bs] = v
    return BCSR(shape=A.shape, block_size=bs, bcols=bcols, bvals=bvals)
