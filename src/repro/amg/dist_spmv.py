"""Device-side distributed SpMV: the paper's solve-phase hot loop on a
hierarchical TPU mesh.

Setup (host, once per level and operator — like an MPI communicator build):
  * row-partition the operator over the (pods × lanes) device grid,
  * convert each rank's rows to padded ELL with columns remapped to
    [local | halo] positions,
  * build a :class:`~repro.core.nap_collectives.HaloPlan` for the selected
    strategy (standard / nap2 / nap3).

Operators may be **rectangular**: ``y = M·x`` with the rows of ``M`` (and
``y``) following ``row_part`` while ``x`` follows ``col_part``.  This is what
lets restriction (R: coarse×fine) and interpolation (P: fine×coarse) run as
distributed SpMVs with their own communication graphs and halo plans instead
of host matvecs — each level of the AMG hierarchy gets one
:class:`DistOperator` per {A, P, R}, each with its own model-selected
strategy (see :mod:`repro.amg.dist_solve`).

Execute (device, every smoother sweep / residual / restrict / interpolate):
  shard_map body = halo_exchange → ELL SpMV (inline jnp gather form, or the
  Pallas :func:`repro.kernels.spmv.spmv.ell_spmv` kernel).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..core.comm_graph import CommGraph
from ..core.compat import shard_map
from ..core.nap_collectives import (HaloPlan, build_halo_plan, halo_exchange,
                                    halo_signature)
from ..core.topology import Partition, Topology
from .csr import CSR
from .dist import rect_vector_graph


def _ell_block(M: CSR, row_part: Partition, col_part: Partition, d: int,
               need_sorted: np.ndarray, rows_local: int, x_local: int, K: int):
    """One device's ELL block with columns remapped to [local | halo]."""
    rlo, rhi = row_part.local_range(d)
    clo, chi = col_part.local_range(d)
    sub = M.submatrix_rows(rlo, rhi)
    cols = np.full((rows_local, K), -1, dtype=np.int32)
    vals = np.zeros((rows_local, K), dtype=np.float64)
    if sub.nnz:
        lens = np.diff(sub.indptr)
        rows = np.repeat(np.arange(sub.nrows, dtype=np.int64), lens)
        k = np.arange(sub.nnz, dtype=np.int64) - np.repeat(sub.indptr[:-1], lens)
        c = sub.indices
        local = (c >= clo) & (c < chi)
        halo_pos = np.searchsorted(need_sorted, c)
        pos = np.where(local, c - clo, x_local + halo_pos).astype(np.int32)
        cols[rows, k] = pos
        vals[rows, k] = sub.data
    return cols, vals


def _split_ell_stacked(cols: np.ndarray, vals: np.ndarray, x_local: int):
    """Split fused [D, rows, K] ELL arrays into the on-process part (columns
    < ``x_local``, kept as local ids) and the off-process part (halo columns,
    rebased to index the halo buffer directly).

    Within each row the relative nonzero order is preserved, so
    ``A_on·x + A_off·halo`` partitions the fused contraction term-for-term —
    the property the split-parity suite asserts exactly.
    """
    D, R, K = cols.shape

    def pack(mask, offset):
        m2 = mask.reshape(D * R, K)
        width = int(m2.sum(axis=1).max(initial=0)) or 1
        oc = np.full((D * R, width), -1, dtype=np.int32)
        ov = np.zeros((D * R, width), dtype=vals.dtype)
        rows, _ = np.nonzero(m2)
        if rows.size:
            counts = m2.sum(axis=1)
            starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
            slot = np.arange(rows.size) - np.repeat(starts, counts)
            oc[rows, slot] = cols.reshape(D * R, K)[m2] - offset
            ov[rows, slot] = vals.reshape(D * R, K)[m2]
        return oc.reshape(D, R, width), ov.reshape(D, R, width)

    on = pack((cols >= 0) & (cols < x_local), 0)
    off = pack(cols >= x_local, x_local)
    return on, off


@dataclasses.dataclass
class DistOperator:
    """Host-side container for one distributed (possibly rectangular) operator.

    Device-stacked arrays carry a leading ``n_devices`` dim and are fed to the
    fused shard_map program sharded over the (pod, lane) device axis; the
    :class:`HaloPlan` and partitions are static setup-time metadata.
    """

    strategy: str
    plan: HaloPlan               # halo plan in x-space (col_part layout)
    row_part: Partition          # layout of y (output)
    col_part: Partition          # layout of x (input)
    rows_local: int              # padded local row count per device
    ell_cols: np.ndarray         # [D, rows_local, K] int32 into [local|halo], -1 pad
    ell_vals: np.ndarray         # [D, rows_local, K]
    send_idx: np.ndarray         # per-device slices of the plan arrays
    recv_sel: np.ndarray
    pool_sel: np.ndarray         # zeros placeholder when plan.pool_sel is None
    # on/off split of the same block: A_on holds the halo-free columns (local
    # ids), A_off the halo columns rebased to halo-buffer ids.  The fused
    # arrays above stay authoritative for the serial parity oracle.
    on_cols: np.ndarray | None = None    # [D, rows_local, K_on] int32, -1 pad
    on_vals: np.ndarray | None = None
    off_cols: np.ndarray | None = None   # [D, rows_local, K_off] into halo
    off_vals: np.ndarray | None = None
    # optional BCSR lowering (see lower_bcsr): dense bs×bs blocks feeding the
    # MXU block-contraction kernel instead of the VPU gather
    bcsr_bcols: np.ndarray | None = None   # [D, mb, Kb] int32, -1 pad
    bcsr_bvals: np.ndarray | None = None   # [D, mb, Kb, bs, bs]
    bcsr_on_bcols: np.ndarray | None = None  # on-part lowering (A_off stays ELL)
    bcsr_on_bvals: np.ndarray | None = None
    block_size: int = 0                    # 0 = ELL layout

    @property
    def n_devices(self) -> int:
        return self.plan.n_devices

    @property
    def halo_empty(self) -> bool:
        """True when the plan moves zero entries (halo_len is floored to 1
        for static shapes, so emptiness must be read from total_halo)."""
        return self.plan.total_halo == 0

    @property
    def local_kernel(self) -> str:
        """Layout label for reporting: 'bcsr' once lowered, else 'ell'."""
        return "bcsr" if self.bcsr_bcols is not None else "ell"

    @property
    def expected_signature(self) -> tuple[str, ...]:
        """Ordered collective primitives ONE apply of this operator must
        lower to — the selected strategy's halo signature, empty when the
        halo is (the comm auditor's per-operator contract)."""
        return halo_signature(self.plan)

    def onoff_nnz(self) -> dict[str, int]:
        """Total and per-device-max nnz of the on/off split (for the
        overlap-aware cost model and reporting)."""
        on = (self.on_cols >= 0).sum(axis=(1, 2))
        off = (self.off_cols >= 0).sum(axis=(1, 2))
        return {"on_nnz": int(on.sum()), "off_nnz": int(off.sum()),
                "max_on_nnz": int(on.max(initial=0)),
                "max_off_nnz": int(off.max(initial=0))}

    def device_arrays(self) -> dict[str, np.ndarray]:
        """The sharded inputs the shard_map body needs for one matvec."""
        arrs = {"cols": self.ell_cols, "vals": self.ell_vals,
                "send": self.send_idx, "recv": self.recv_sel,
                "psel": self.pool_sel,
                "on_cols": self.on_cols, "on_vals": self.on_vals,
                "off_cols": self.off_cols, "off_vals": self.off_vals}
        if self.bcsr_bcols is not None:
            arrs["bcols"] = self.bcsr_bcols
            arrs["bvals"] = self.bcsr_bvals
            arrs["on_bcols"] = self.bcsr_on_bcols
            arrs["on_bvals"] = self.bcsr_on_bvals
        return arrs

    def lower_bcsr(self, block_size: int) -> None:
        """Lower this operator's per-device ELL blocks to block-ELL BCSR.

        Each device's (rows_local × [local|halo]) sparse block is re-tiled
        into dense ``bs×bs`` blocks; block-row padding never mixes devices
        because each device is lowered independently.  Once lowered,
        :meth:`apply` routes through the MXU block contraction (kernel or
        inline einsum) instead of the ELL gather.
        """
        from .csr import CSR, csr_to_bcsr
        D = self.n_devices

        def lower(ell_cols, ell_vals, width):
            per = []
            for d in range(D):
                cols = ell_cols[d]
                keep = cols >= 0
                r = np.broadcast_to(
                    np.arange(self.rows_local, dtype=np.int64)[:, None],
                    cols.shape)[keep]
                per.append(csr_to_bcsr(
                    CSR.from_coo(r, cols[keep], ell_vals[d][keep],
                                 (self.rows_local, width)), block_size))
            mb = per[0].bcols.shape[0] if per else 0
            Kb = max((b.bcols.shape[1] for b in per), default=0)
            bcols = np.full((D, mb, Kb), -1, dtype=np.int32)
            bvals = np.zeros((D, mb, Kb, block_size, block_size),
                             dtype=ell_vals.dtype)
            for d, b in enumerate(per):
                kb = b.bcols.shape[1]
                bcols[d, :, :kb] = b.bcols
                bvals[d, :, :kb] = b.bvals
            return bcols, bvals

        xfull_len = self.plan.local_n + self.plan.halo_len
        self.bcsr_bcols, self.bcsr_bvals = lower(
            self.ell_cols, self.ell_vals, xfull_len)
        # on-part only: the off-part stays ELL — its rows are halo-width
        # gathers that would shred into mostly-empty bs×bs blocks.
        self.bcsr_on_bcols, self.bcsr_on_bvals = lower(
            self.on_cols, self.on_vals, self.plan.local_n)
        self.block_size = int(block_size)

    def refresh_values(self, block_of) -> None:
        """Value-only re-lowering onto the frozen layouts.

        ``block_of(d)`` returns the CSR device ``d`` reads its rows from —
        same contract as the build — whose sparsity pattern must match the
        one this operator was lowered from.  The ELL fill order is a pure
        function of ``indptr``/``indices`` (see :func:`_ell_block`), so with
        a frozen pattern the column maps, halo plan and on/off split
        layouts are all reproduced exactly; only the value planes change.
        BCSR lowerings are re-tiled at the same ``block_size``.
        """
        vals = np.zeros(self.ell_cols.shape, dtype=np.float64)
        for d in range(self.n_devices):
            rlo, rhi = self.row_part.local_range(d)
            sub = block_of(d).submatrix_rows(rlo, rhi)
            if sub.nnz:
                lens = np.diff(sub.indptr)
                rows = np.repeat(np.arange(sub.nrows, dtype=np.int64), lens)
                k = np.arange(sub.nnz, dtype=np.int64) \
                    - np.repeat(sub.indptr[:-1], lens)
                vals[d][rows, k] = sub.data
        self.ell_vals = vals.astype(self.ell_vals.dtype)
        (on_cols, on_vals), (off_cols, off_vals) = _split_ell_stacked(
            self.ell_cols, self.ell_vals, self.plan.local_n)
        # the split is deterministic given cols: layouts come back identical
        self.on_cols, self.on_vals = on_cols, on_vals
        self.off_cols, self.off_vals = off_cols, off_vals
        if self.block_size:
            self.lower_bcsr(self.block_size)

    @staticmethod
    def _ell_product(cols, vals, src, use_kernel, interpret):
        """ELL contraction of one split part against ``src`` ([n(,k)])."""
        multi = src.ndim == 2
        if use_kernel:
            from ..kernels.spmv.spmv import ell_spmm, ell_spmv
            if multi:
                return ell_spmm(cols, vals, src, interpret=interpret)
            return ell_spmv(cols, vals, src, interpret=interpret)
        safe = jnp.maximum(cols, 0)
        if multi:
            contrib = jnp.where((cols >= 0)[..., None],
                                vals[..., None] * src[safe], 0.0)
        else:
            contrib = jnp.where(cols >= 0, vals * src[safe], 0.0)
        return contrib.sum(axis=1)

    def _on_product(self, arrs, x_loc, use_kernel, interpret):
        """``A_on · x`` — the halo-free product that overlaps the exchange."""
        if "on_bcols" in arrs:
            bcols, bvals = arrs["on_bcols"], arrs["on_bvals"]
            if use_kernel:
                from ..kernels.spmv.bcsr import bcsr_spmm, bcsr_spmv
                fn = bcsr_spmm if x_loc.ndim == 2 else bcsr_spmv
                y = fn(bcols, bvals, x_loc, interpret=interpret)
            else:
                from ..kernels.spmv.bcsr import bcsr_apply_ref
                y = bcsr_apply_ref(bcols, bvals, x_loc)
            return y[: self.rows_local]
        return self._ell_product(arrs["on_cols"], arrs["on_vals"], x_loc,
                                 use_kernel, interpret)

    def apply(self, arrs: dict[str, jnp.ndarray], x_loc: jnp.ndarray,
              use_kernel: bool = False, interpret: bool = True,
              overlap: bool = True) -> jnp.ndarray:
        """Inside shard_map: halo exchange + local SpMV/SpMM for this device.

        ``arrs`` holds this device's slices of :meth:`device_arrays` (leading
        device dim already squeezed).  ``x_loc`` may be ``[local]`` (one RHS)
        or ``[local, k]`` (multi-RHS): the halo is exchanged once with the
        RHS axis riding along.  Routing: BCSR block contraction when this
        operator was :meth:`lower_bcsr`'d, else the ELL kernel
        (``use_kernel``) or the inline gather form.

        ``overlap=True`` (default) traces the exchange *before* the
        independent ``y_on = A_on·x`` product so XLA's async collectives can
        hide the NAP message latency behind the on-process SpMV; the
        ``A_off·halo`` correction lands after.  ``overlap=False`` keeps the
        original fused serial form (``halo_exchange → A·[x|halo]``) as the
        parity oracle.  Levels whose plan moves zero entries emit no
        collective at all in either mode.
        """
        if self.halo_empty:
            return self._on_product(arrs, x_loc, use_kernel, interpret)
        psel = None if self.plan.pool_sel is None else arrs["psel"]
        if overlap:
            # issue the exchange first: `halo` is not consumed until the
            # off-process correction, so the collective and the on-process
            # product are dataflow-independent and free to overlap.
            halo = halo_exchange(x_loc, self.plan, arrs["send"],
                                 arrs["recv"], psel)
            y = self._on_product(arrs, x_loc, use_kernel, interpret)
            return y + self._ell_product(arrs["off_cols"], arrs["off_vals"],
                                         halo, use_kernel, interpret)
        halo = halo_exchange(x_loc, self.plan, arrs["send"], arrs["recv"], psel)
        xfull = jnp.concatenate([x_loc, halo])    # one buffer for all RHS
        multi = x_loc.ndim == 2
        if "bcols" in arrs:
            bcols, bvals = arrs["bcols"], arrs["bvals"]
            if use_kernel:
                from ..kernels.spmv.bcsr import bcsr_spmm, bcsr_spmv
                fn = bcsr_spmm if multi else bcsr_spmv
                y = fn(bcols, bvals, xfull, interpret=interpret)
            else:
                from ..kernels.spmv.bcsr import bcsr_apply_ref
                y = bcsr_apply_ref(bcols, bvals, xfull)
            return y[: self.rows_local]
        return self._ell_product(arrs["cols"], arrs["vals"], xfull,
                                 use_kernel, interpret)

    # ------------------------------------------------------- host-side layout
    def scatter_x(self, x: np.ndarray, dtype=None) -> np.ndarray:
        """Global x (col_part layout) -> [D, x_local(, k)] device layout.

        ``x`` may be ``[n]`` or ``[n, k]`` (multi-RHS block); the trailing
        RHS axis is carried through unsharded.
        """
        x = np.asarray(x)
        if x.ndim not in (1, 2) or x.shape[0] != self.col_part.n:
            raise ValueError(f"expected x of shape ({self.col_part.n},) or "
                             f"({self.col_part.n}, k), got {x.shape}")
        D = self.n_devices
        dtype = dtype or self.ell_vals.dtype
        out = np.zeros((D, self.plan.local_n) + x.shape[1:], dtype=dtype)
        for d in range(D):
            lo, hi = self.col_part.local_range(d)
            out[d, : hi - lo] = x[lo:hi]
        return out

    def gather_y(self, y_dev: np.ndarray) -> np.ndarray:
        """[D, rows_local(, k)] device layout -> global y (row_part layout)."""
        y_dev = np.asarray(y_dev)
        out = np.zeros((self.row_part.n,) + y_dev.shape[2:], dtype=y_dev.dtype)
        for d in range(self.n_devices):
            lo, hi = self.row_part.local_range(d)
            out[lo:hi] = y_dev[d, : hi - lo]
        return out


def local_square_block(M, part: Partition, d: int) -> CSR:
    """Device d's diagonal square block of ``M`` (rows AND columns in
    ``part.local_range(d)``, columns shifted to local 0-based ids).

    This is the sub-operator the block smoothers factor locally — the
    block-Jacobi diagonal-block inverses and the hybrid-GS (D+L)⁻¹ factor
    are lowered from it alongside the ELL blocks, while couplings outside
    it stay in the halo'd residual.  ``M`` may be a global CSR or a
    born-partitioned BlockMatrix (both expose ``submatrix_rows``).
    """
    lo, hi = part.local_range(d)
    sub = M.submatrix_rows(lo, hi)
    r, c = sub.rows_expanded(), sub.indices
    keep = (c >= lo) & (c < hi)
    return CSR.from_coo(r[keep], c[keep] - lo, sub.data[keep],
                        (hi - lo, hi - lo))


def _assemble_operator(block_of, K: int, n_pods: int, lanes: int,
                       strategy: str, row_part: Partition,
                       col_part: Partition, graph: CommGraph,
                       dtype) -> DistOperator:
    """Shared tail: halo plan + per-device ELL lowering.

    ``block_of(d)`` returns the CSR each device reads its rows from — the
    whole matrix on the from-global path, device d's own row block on the
    from-blocks path.  ``K`` is the global max row length.
    """
    D = n_pods * lanes
    plan = build_halo_plan(graph, n_pods, lanes, strategy)
    need_sorted = [np.sort(graph.need[d]) for d in range(D)]
    rows_local = row_part.max_local_size
    x_local = plan.local_n
    cols = np.zeros((D, rows_local, K), dtype=np.int32)
    vals = np.zeros((D, rows_local, K), dtype=np.float64)
    for d in range(D):
        cols[d], vals[d] = _ell_block(block_of(d), row_part, col_part, d,
                                      need_sorted[d], rows_local, x_local, K)
    psel = plan.pool_sel if plan.pool_sel is not None else np.zeros(
        (D, 1), dtype=np.int32)
    vals = vals.astype(dtype)
    (on_cols, on_vals), (off_cols, off_vals) = _split_ell_stacked(
        cols, vals, x_local)
    return DistOperator(strategy=strategy, plan=plan, row_part=row_part,
                        col_part=col_part, rows_local=rows_local,
                        ell_cols=cols, ell_vals=vals,
                        send_idx=plan.send_idx, recv_sel=plan.recv_sel,
                        pool_sel=psel, on_cols=on_cols, on_vals=on_vals,
                        off_cols=off_cols, off_vals=off_vals)


def build_dist_operator(M: CSR, n_pods: int, lanes: int, strategy: str,
                        row_part: Partition | None = None,
                        col_part: Partition | None = None,
                        graph: CommGraph | None = None,
                        dtype=jnp.float32) -> DistOperator:
    """Build the device form of ``M`` (square or rectangular) for one strategy.

    ``graph`` may be passed in when the caller already built/selected on it
    (the per-level selection path) — it must be ``rect_vector_graph(M, ...)``.
    """
    topo = Topology(n_nodes=n_pods, ppn=lanes)
    row_part = row_part or Partition.balanced(M.nrows, topo)
    col_part = col_part or Partition.balanced(M.ncols, topo)
    if graph is None:
        graph = rect_vector_graph(M, row_part, col_part)
    K = int(np.diff(M.indptr).max(initial=1)) or 1
    return _assemble_operator(lambda d: M, K, n_pods, lanes, strategy,
                              row_part, col_part, graph, dtype)


def build_dist_operator_from_blocks(blocks: list[CSR], n_pods: int,
                                    lanes: int, strategy: str, *,
                                    row_part: Partition,
                                    col_part: Partition,
                                    graph: CommGraph | None = None,
                                    dtype=jnp.float32) -> DistOperator:
    """Device form of an operator that exists only as per-device row blocks.

    ``blocks[d]`` is a *global-shape* CSR holding exactly device d's rows
    (rows outside ``row_part.local_range(d)`` empty, global column ids) —
    the :mod:`repro.amg.dist_setup` representation, where each level is born
    partitioned and no global CSR is ever assembled.
    """
    D = n_pods * lanes
    assert len(blocks) == D, (len(blocks), D)
    if graph is None:
        offp = []
        for p in range(D):
            rlo, rhi = row_part.local_range(p)
            clo, chi = col_part.local_range(p)
            offp.append(blocks[p].offproc_columns(clo, chi, rlo, rhi))
        graph = CommGraph.from_offproc_columns(col_part, offp)
    K = max(int(np.diff(b.indptr).max(initial=0)) for b in blocks) or 1
    return _assemble_operator(lambda d: blocks[d], K, n_pods, lanes, strategy,
                              row_part, col_part, graph, dtype)


# --------------------------------------------------------------------------
# Stand-alone square SpMV (kept for benchmarks/tests of a single operator)
# --------------------------------------------------------------------------


@dataclasses.dataclass
class DistSpMV:
    """Host-side container: device arrays + jitted distributed matvec."""

    plan: HaloPlan
    part: Partition
    mesh: jax.sharding.Mesh
    op: DistOperator
    fn: callable = None      # jitted shard_map spmv

    @property
    def ell_cols(self) -> np.ndarray:
        return self.op.ell_cols

    @property
    def ell_vals(self) -> np.ndarray:
        return self.op.ell_vals

    def scatter_x(self, x: np.ndarray) -> np.ndarray:
        return self.op.scatter_x(x)

    def gather_y(self, y_dev: np.ndarray) -> np.ndarray:
        return self.op.gather_y(y_dev)

    def matvec(self, x: np.ndarray) -> np.ndarray:
        return self.gather_y(self.fn(self.scatter_x(x)))


def build_dist_spmv(A: CSR, n_pods: int, lanes: int, strategy: str,
                    mesh: jax.sharding.Mesh | None = None,
                    dtype=jnp.float32, use_kernel: bool = False) -> DistSpMV:
    op = build_dist_operator(A, n_pods, lanes, strategy, dtype=dtype)
    if mesh is None:
        mesh = jax.make_mesh((n_pods, lanes), ("pod", "lane"))

    P = jax.sharding.PartitionSpec
    dev_spec = P(("pod", "lane"))
    arrs = op.device_arrays()

    def body(x_loc, a):
        # squeeze the per-device leading dim added by shard_map
        x_loc = x_loc[0]
        a = jax.tree.map(lambda v: v[0], a)
        return op.apply(a, x_loc, use_kernel=use_kernel,
                        interpret=jax.default_backend() != "tpu")[None]

    fn = jax.jit(
        shard_map(body, mesh=mesh, in_specs=(dev_spec, dev_spec),
                  out_specs=dev_spec, check_vma=False))

    def matvec_dev(x_dev):
        return fn(jnp.asarray(x_dev, dtype=dtype), arrs)

    return DistSpMV(plan=op.plan, part=op.row_part, mesh=mesh, op=op,
                    fn=matvec_dev)
