"""Device-side distributed SpMV: the paper's solve-phase hot loop on a
hierarchical TPU mesh.

Setup (host, once per level — like an MPI communicator build):
  * row-partition A over the (pods × lanes) device grid,
  * convert each rank's rows to padded ELL with columns remapped to
    [local | halo] positions,
  * build a :class:`~repro.core.nap_collectives.HaloPlan` for the selected
    strategy (standard / nap2 / nap3).

Execute (device, every smoother sweep / residual / restrict):
  shard_map body = halo_exchange → ELL SpMV (optionally the Pallas kernel).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..core.comm_graph import CommGraph
from ..core.nap_collectives import HaloPlan, build_halo_plan, halo_exchange
from ..core.topology import Partition, Topology
from .csr import CSR


@dataclasses.dataclass
class DistSpMV:
    """Host-side container: device arrays + jitted distributed matvec."""

    plan: HaloPlan
    part: Partition
    mesh: jax.sharding.Mesh
    # device-stacked arrays (leading dim = n_devices)
    ell_cols: np.ndarray     # [D, local_n, K] int32 into [local | halo], -1 pad
    ell_vals: np.ndarray     # [D, local_n, K] float32/64
    send_idx: np.ndarray
    recv_sel: np.ndarray
    pool_sel: np.ndarray | None
    fn: callable = None      # jitted shard_map spmv

    def scatter_x(self, x: np.ndarray) -> np.ndarray:
        """Global vector -> [D, local_n] padded device layout."""
        D = self.plan.n_devices
        out = np.zeros((D, self.plan.local_n), dtype=self.ell_vals.dtype)
        for d in range(D):
            lo, hi = self.part.local_range(d)
            out[d, : hi - lo] = x[lo:hi]
        return out

    def gather_y(self, y_dev: np.ndarray) -> np.ndarray:
        D = self.plan.n_devices
        out = np.zeros(self.part.n, dtype=np.asarray(y_dev).dtype)
        for d in range(D):
            lo, hi = self.part.local_range(d)
            out[lo:hi] = np.asarray(y_dev)[d, : hi - lo]
        return out

    def matvec(self, x: np.ndarray) -> np.ndarray:
        return self.gather_y(self.fn(self.scatter_x(x)))


def _ell_local(A: CSR, part: Partition, d: int, need_sorted: np.ndarray,
               local_n: int, K: int):
    lo, hi = part.local_range(d)
    sub = A.submatrix_rows(lo, hi)
    cols = np.full((local_n, K), -1, dtype=np.int32)
    vals = np.zeros((local_n, K), dtype=np.float64)
    halo_pos = {int(g): i for i, g in enumerate(need_sorted)}
    for i in range(sub.nrows):
        s = slice(int(sub.indptr[i]), int(sub.indptr[i + 1]))
        cs, vs = sub.indices[s], sub.data[s]
        for k, (c, v) in enumerate(zip(cs, vs)):
            c = int(c)
            cols[i, k] = (c - lo) if lo <= c < hi else local_n + halo_pos[c]
            vals[i, k] = v
    return cols, vals


def build_dist_spmv(A: CSR, n_pods: int, lanes: int, strategy: str,
                    mesh: jax.sharding.Mesh | None = None,
                    dtype=jnp.float32) -> DistSpMV:
    topo = Topology(n_nodes=n_pods, ppn=lanes)
    part = Partition.balanced(A.nrows, topo)
    D = topo.n_procs
    offp = []
    for p in range(D):
        lo, hi = part.local_range(p)
        offp.append(A.offproc_columns(lo, hi, lo, hi))
    graph = CommGraph.from_offproc_columns(part, offp)
    plan = build_halo_plan(graph, n_pods, lanes, strategy)
    need_sorted = [np.sort(graph.need[d]) for d in range(D)]

    local_n = plan.local_n
    K = int(np.diff(A.indptr).max(initial=1)) or 1
    cols = np.zeros((D, local_n, K), dtype=np.int32)
    vals = np.zeros((D, local_n, K), dtype=np.float64)
    for d in range(D):
        cols[d], vals[d] = _ell_local(A, part, d, need_sorted[d], local_n, K)

    if mesh is None:
        mesh = jax.make_mesh((n_pods, lanes), ("pod", "lane"))

    P = jax.sharding.PartitionSpec
    dev_spec = P(("pod", "lane"))

    def body(x_loc, ecols, evals, sidx, rsel, psel):
        # squeeze the per-device leading dim added by shard_map
        x_loc, ecols, evals = x_loc[0], ecols[0], evals[0]
        sidx, rsel = sidx[0], rsel[0]
        psel = None if plan.pool_sel is None else psel[0]
        halo = halo_exchange(x_loc, plan, sidx, rsel, psel)
        xfull = jnp.concatenate([x_loc, halo])
        safe = jnp.maximum(ecols, 0)
        contrib = jnp.where(ecols >= 0, evals * xfull[safe], 0.0)
        return contrib.sum(axis=1)[None]

    psel_arr = plan.pool_sel if plan.pool_sel is not None else np.zeros(
        (D, 1), dtype=np.int32)
    in_specs = (dev_spec,) * 6
    fn = jax.jit(
        jax.shard_map(
            lambda x, *a: body(x, *a),
            mesh=mesh, in_specs=in_specs, out_specs=dev_spec,
            check_vma=False,
        ),
    )
    ell_vals = vals.astype(dtype)

    def matvec_dev(x_dev):
        return fn(jnp.asarray(x_dev, dtype=dtype), cols, ell_vals,
                  plan.send_idx, plan.recv_sel, psel_arr)

    return DistSpMV(plan=plan, part=part, mesh=mesh, ell_cols=cols,
                    ell_vals=ell_vals, send_idx=plan.send_idx,
                    recv_sel=plan.recv_sel, pool_sel=plan.pool_sel,
                    fn=matvec_dev)
