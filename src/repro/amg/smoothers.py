"""Relaxation methods for the solve phase (Algorithm 2, ``relax``).

Weighted/l1-Jacobi and Chebyshev — the smoothers used at scale in parallel
AMG (SpMV-only, communication pattern identical to A·x, so every sweep uses
the level's selected node-aware strategy).
"""
from __future__ import annotations

import numpy as np

from .csr import CSR
from .interpolation import estimate_rho_DinvA


def jacobi(A: CSR, x: np.ndarray, b: np.ndarray, omega: float = 2.0 / 3.0,
           iterations: int = 1, dinv: np.ndarray | None = None) -> np.ndarray:
    if dinv is None:
        d = A.diagonal()
        dinv = 1.0 / np.where(d == 0, 1.0, d)
    for _ in range(iterations):
        x = x + omega * dinv * (b - A.matvec(x))
    return x


def l1_jacobi(A: CSR, x: np.ndarray, b: np.ndarray, iterations: int = 1) -> np.ndarray:
    """l1-Jacobi: unconditionally convergent for SPD A."""
    l1 = np.zeros(A.nrows)
    np.add.at(l1, A.rows_expanded(), np.abs(A.data))
    dinv = 1.0 / np.where(l1 == 0, 1.0, l1)
    for _ in range(iterations):
        x = x + dinv * (b - A.matvec(x))
    return x


def chebyshev_coeffs(rho: float) -> tuple[float, float, float]:
    """(theta, delta, sigma) for D⁻¹A bounds [ρ/30, 1.1ρ] (hypre-style)."""
    lmax, lmin = 1.1 * rho, rho / 30.0
    theta, delta = 0.5 * (lmax + lmin), 0.5 * (lmax - lmin)
    return theta, delta, theta / delta


def chebyshev_recurrence(matvec, dinv, x, b, degree: int,
                         theta: float, delta: float, sigma: float):
    """The Chebyshev smoothing recurrence, matvec-agnostic.

    Shared by the host backend (numpy ``A.matvec``) and the device backend
    (distributed SpMV inside shard_map, :mod:`repro.amg.dist_solve`) so the
    two can never drift apart; works on any array type supporting ``+``/``*``.
    """
    r = dinv * (b - matvec(x))
    d = r / theta
    x = x + d
    rho_prev = 1.0 / sigma
    for _ in range(degree - 1):
        rho_k = 1.0 / (2.0 * sigma - rho_prev)
        r = r - dinv * matvec(d)
        d = (rho_k * rho_prev) * d + (2.0 * rho_k / delta) * r
        x = x + d
        rho_prev = rho_k
    return x


def chebyshev(A: CSR, x: np.ndarray, b: np.ndarray, degree: int = 3,
              rho: float | None = None, dinv: np.ndarray | None = None) -> np.ndarray:
    """Chebyshev smoothing on D⁻¹A over [ρ/30, 1.1ρ] (hypre-style)."""
    if dinv is None:
        d = A.diagonal()
        dinv = 1.0 / np.where(d == 0, 1.0, d)
    rho = rho or estimate_rho_DinvA(A)
    theta, delta, sigma = chebyshev_coeffs(rho)
    return chebyshev_recurrence(A.matvec, dinv, x, b, degree,
                                theta, delta, sigma)
