"""Relaxation methods for the solve phase (Algorithm 2, ``relax``).

Pointwise smoothers — weighted/l1-Jacobi and Chebyshev — plus the two
*block* smoothers the paper's communication argument extends to:

* :func:`block_jacobi` — per-block diagonal inverses (dense ``bs×bs``
  blocks), same SpMV-shaped communication as Jacobi but a denser local
  update; the block inverses are extracted once at setup and carried on the
  level (:attr:`repro.amg.hierarchy.Level.smoother_cache`).
* :func:`hybrid_gs` — hybrid Gauss-Seidel: exact forward Gauss-Seidel
  *within* each contiguous row part, Jacobi *across* parts, off-part values
  read from the pre-sweep iterate (on the distributed backend those are
  exactly the halo'd off-process values).  This is the processor-block
  Gauss-Seidel of parallel AMG codes: its iteration depends on the row
  partition, so the host reference takes the part boundaries explicitly.
* :func:`hybrid_gs_sym` — the symmetric sweep (forward + backward, each
  against a freshly lagged residual): 2 SpMVs/sweep, but the resulting
  cycle is a symmetric operator, i.e. an SPD preconditioner for PCG.

Every sweep of every smoother is SpMV-based, so the communication pattern
is identical to A·x and every sweep uses the level's selected node-aware
strategy.
"""
from __future__ import annotations

import numpy as np

from .csr import CSR
from .interpolation import estimate_rho_DinvA


def balanced_offsets(n: int, parts: int) -> np.ndarray:
    """Boundaries of a balanced contiguous split of ``n`` rows into
    ``parts`` pieces — the same first-parts-get-the-extra rule as
    :meth:`repro.core.topology.Partition.balanced`, so a host smoother run
    with ``parts == n_devices`` reproduces the device partition exactly."""
    base, extra = divmod(n, parts)
    counts = np.full(parts, base, dtype=np.int64)
    counts[:extra] += 1
    offsets = np.zeros(parts + 1, dtype=np.int64)
    np.cumsum(counts, out=offsets[1:])
    return offsets


def block_partition(n: int, bs: int, parts: int = 1) -> list[tuple[int, int]]:
    """Block-Jacobi block ranges: a ``bs``-grid laid down *within* each of
    ``parts`` balanced row parts (blocks never straddle a part boundary —
    the distributed backend cannot invert across devices, and the host
    reference mirrors that rule so the two iterate identically)."""
    bounds = balanced_offsets(n, parts)
    out = []
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        for s in range(int(lo), int(hi), bs):
            out.append((s, min(s + bs, int(hi))))
    return out


def block_diag_inv(A: CSR, bs: int, parts: int = 1) -> list[tuple[int, np.ndarray]]:
    """Dense inverses of A's block diagonal: ``[(start, inv)]`` per block.

    Entries of A outside a block's row/column range are ignored (they belong
    to the Jacobi coupling handled by the residual); zero diagonals are
    replaced by 1 so padded/empty rows update by exactly zero.
    """
    out = []
    for s, e in block_partition(A.nrows, bs, parts):
        sub = A.submatrix_rows(s, e)
        r, c = sub.rows_expanded(), sub.indices
        keep = (c >= s) & (c < e)
        B = np.zeros((e - s, e - s))
        B[r[keep], c[keep] - s] = sub.data[keep]
        d = np.diagonal(B).copy()
        np.fill_diagonal(B, np.where(d == 0, 1.0, d))
        out.append((s, np.linalg.inv(B)))
    return out


def block_jacobi(A: CSR, x: np.ndarray, b: np.ndarray, block_size: int = 4,
                 omega: float = 2.0 / 3.0, iterations: int = 1,
                 parts: int = 1, binv=None) -> np.ndarray:
    """Weighted block-Jacobi: x += ω · blockdiag(A)⁻¹ (b − A x).

    ``binv`` may carry pre-extracted inverses from :func:`block_diag_inv`
    (the setup-time form carried on the level); it must have been built with
    the same ``block_size``/``parts``.
    """
    if binv is None:
        binv = block_diag_inv(A, block_size, parts)
    for _ in range(iterations):
        r = b - A.matvec(x)
        z = np.zeros_like(x)
        for s, inv in binv:
            z[s: s + inv.shape[0]] = inv @ r[s: s + inv.shape[0]]
        x = x + omega * z
    return x


def _resolve_bounds(n: int, boundaries) -> np.ndarray:
    return (np.array([0, n], dtype=np.int64) if boundaries is None
            else np.asarray(boundaries, dtype=np.int64))


def _hybrid_sweep(A: CSR, x: np.ndarray, b: np.ndarray, bounds: np.ndarray,
                  forward: bool) -> np.ndarray:
    """One directional hybrid sweep: solve ``(D + T_part) z = b − A x`` per
    contiguous row part (T = strictly-lower triangle for a forward sweep,
    strictly-upper for a backward one; couplings to rows outside the part
    enter through the lagged residual) and return ``x + z``."""
    r = b - A.matvec(x)
    z = np.zeros_like(x)
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        lo, hi = int(lo), int(hi)
        order = range(lo, hi) if forward else range(hi - 1, lo - 1, -1)
        for i in order:
            s, e = int(A.indptr[i]), int(A.indptr[i + 1])
            cols, vals = A.indices[s:e], A.data[s:e]
            if forward:
                in_part = (cols >= lo) & (cols < i)
            else:
                in_part = (cols > i) & (cols < hi)
            acc = r[i] - vals[in_part] @ z[cols[in_part]]
            diag = float(vals[cols == i].sum()) or 1.0
            z[i] = acc / diag
    return x + z


def hybrid_gs(A: CSR, x: np.ndarray, b: np.ndarray,
              boundaries: np.ndarray | None = None,
              iterations: int = 1) -> np.ndarray:
    """Hybrid (processor-block) forward Gauss-Seidel.

    One sweep solves ``(D + L_part) z = b − A x`` per contiguous row part
    (forward substitution within the part; couplings to rows outside the
    part — other parts *and* off-process halo values on the distributed
    backend — enter through the lagged residual) and updates ``x += z``.
    With ``boundaries=[0, n]`` (the default) this is exact sequential
    forward Gauss-Seidel; with the device partition's boundaries it is
    bit-for-bit the distributed backend's smoother.
    """
    bounds = _resolve_bounds(A.nrows, boundaries)
    for _ in range(iterations):
        x = _hybrid_sweep(A, x, b, bounds, forward=True)
    return x


def hybrid_gs_sym(A: CSR, x: np.ndarray, b: np.ndarray,
                  boundaries: np.ndarray | None = None,
                  iterations: int = 1) -> np.ndarray:
    """Symmetric-sweep hybrid Gauss-Seidel: one forward hybrid sweep
    followed by one backward hybrid sweep (each with a freshly lagged
    residual, so the backward half costs a second SpMV).

    The symmetric sweep makes the smoother — and hence the whole
    V-cycle — a *symmetric* operator for symmetric A, which is what PCG
    needs from its preconditioner; plain ``hybrid_gs`` is not.  With
    ``boundaries=[0, n]`` this is textbook symmetric Gauss-Seidel; with
    the device partition's boundaries it is bit-for-bit the distributed
    backend's smoother (off-part values halo'd, i.e. lagged).
    """
    bounds = _resolve_bounds(A.nrows, boundaries)
    for _ in range(iterations):
        x = _hybrid_sweep(A, x, b, bounds, forward=True)
        x = _hybrid_sweep(A, x, b, bounds, forward=False)
    return x


def jacobi(A: CSR, x: np.ndarray, b: np.ndarray, omega: float = 2.0 / 3.0,
           iterations: int = 1, dinv: np.ndarray | None = None) -> np.ndarray:
    if dinv is None:
        d = A.diagonal()
        dinv = 1.0 / np.where(d == 0, 1.0, d)
    for _ in range(iterations):
        x = x + omega * dinv * (b - A.matvec(x))
    return x


def l1_jacobi(A: CSR, x: np.ndarray, b: np.ndarray, iterations: int = 1) -> np.ndarray:
    """l1-Jacobi: unconditionally convergent for SPD A."""
    l1 = np.zeros(A.nrows)
    np.add.at(l1, A.rows_expanded(), np.abs(A.data))
    dinv = 1.0 / np.where(l1 == 0, 1.0, l1)
    for _ in range(iterations):
        x = x + dinv * (b - A.matvec(x))
    return x


def chebyshev_coeffs(rho: float) -> tuple[float, float, float]:
    """(theta, delta, sigma) for D⁻¹A bounds [ρ/30, 1.1ρ] (hypre-style)."""
    lmax, lmin = 1.1 * rho, rho / 30.0
    theta, delta = 0.5 * (lmax + lmin), 0.5 * (lmax - lmin)
    return theta, delta, theta / delta


def chebyshev_recurrence(matvec, dinv, x, b, degree: int,
                         theta: float, delta: float, sigma: float):
    """The Chebyshev smoothing recurrence, matvec-agnostic.

    Shared by the host backend (numpy ``A.matvec``) and the device backend
    (distributed SpMV inside shard_map, :mod:`repro.amg.dist_solve`) so the
    two can never drift apart; works on any array type supporting ``+``/``*``.
    """
    r = dinv * (b - matvec(x))
    d = r / theta
    x = x + d
    rho_prev = 1.0 / sigma
    for _ in range(degree - 1):
        rho_k = 1.0 / (2.0 * sigma - rho_prev)
        r = r - dinv * matvec(d)
        d = (rho_k * rho_prev) * d + (2.0 * rho_k / delta) * r
        x = x + d
        rho_prev = rho_k
    return x


def chebyshev(A: CSR, x: np.ndarray, b: np.ndarray, degree: int = 3,
              rho: float | None = None, dinv: np.ndarray | None = None) -> np.ndarray:
    """Chebyshev smoothing on D⁻¹A over [ρ/30, 1.1ρ] (hypre-style)."""
    if dinv is None:
        d = A.diagonal()
        dinv = 1.0 / np.where(d == 0, 1.0, d)
    rho = rho or estimate_rho_DinvA(A)
    theta, delta, sigma = chebyshev_coeffs(rho)
    return chebyshev_recurrence(A.matvec, dinv, x, b, degree,
                                theta, delta, sigma)
