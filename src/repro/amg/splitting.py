"""CF splitting and aggregation (Algorithm 1, ``splitting``).

* :func:`pmis` — PMIS splitting [De Sterck, Yang, Heys 2005]; with
  ``aggressive=True`` it runs on the distance-2 strength graph, giving the
  HMIS-style aggressive coarsening the paper uses for its RS hierarchies.
* :func:`mis2_aggregation` — aggregates from a distance-2 maximal
  independent set (the paper's SA configuration).
"""
from __future__ import annotations

import numpy as np

from .csr import CSR

UNASSIGNED, FPOINT, CPOINT = 0, -1, 1


def _sym_graph(S: CSR) -> CSR:
    """S ∪ Sᵀ with unit weights."""
    return _drop_diag(S.add(S.T))


def _drop_diag(G: CSR) -> CSR:
    r = G.rows_expanded()
    keep = r != G.indices
    indptr = np.zeros(G.nrows + 1, dtype=np.int64)
    np.cumsum(np.bincount(r[keep], minlength=G.nrows), out=indptr[1:])
    return CSR(G.shape, indptr, G.indices[keep], np.ones(int(keep.sum())))


def _row_max(G: CSR, w: np.ndarray, mask: np.ndarray) -> np.ndarray:
    """Per-row max of w over neighbor columns where mask[col] (else -inf)."""
    vals = np.where(mask[G.indices], w[G.indices], -np.inf)
    out = np.full(G.nrows, -np.inf)
    np.maximum.at(out, G.rows_expanded(), vals)
    return out


def pmis(S: CSR, seed: int = 42, aggressive: bool = False) -> np.ndarray:
    """Return status array: CPOINT / FPOINT per node."""
    G = _sym_graph(S)
    if aggressive:
        G = _sym_graph(G.spgemm(G))  # distance-2 coupling (self-loops dropped)
    n = G.nrows
    rng = np.random.default_rng(seed)
    # weight: number of strong transpose connections + tiebreak random
    w = np.diff(S.T.indptr).astype(np.float64) + rng.random(n)
    status = np.full(n, UNASSIGNED, dtype=np.int64)
    # nodes with no strong connections become F (no interpolation needed)
    isolated = np.diff(G.indptr) == 0
    status[isolated] = FPOINT
    while (status == UNASSIGNED).any():
        unass = status == UNASSIGNED
        nb_max = _row_max(G, w, unass)
        new_c = unass & (w > nb_max)
        if not new_c.any():  # numeric tie safety
            idx = np.flatnonzero(unass)
            new_c = np.zeros(n, dtype=bool)
            new_c[idx[np.argmax(w[idx])]] = True
        status[new_c] = CPOINT
        # unassigned strongly influenced by a new C point -> F
        touched = np.zeros(n, dtype=bool)
        r = G.rows_expanded()
        touched[G.indices[new_c[r]]] = True      # neighbors of new C points
        status[(status == UNASSIGNED) & touched] = FPOINT
    return status


def mis2_aggregation(S: CSR, seed: int = 42) -> np.ndarray:
    """Aggregate nodes around a distance-2 MIS of the strength graph.

    Returns ``agg`` with agg[i] = aggregate id (0..n_agg-1).
    """
    G = _sym_graph(S)
    n = G.nrows
    G2 = _sym_graph(G.spgemm(G))
    rng = np.random.default_rng(seed)
    w = np.diff(G.indptr).astype(np.float64) + rng.random(n)
    in_mis = np.zeros(n, dtype=bool)
    killed = np.zeros(n, dtype=bool)
    while (~in_mis & ~killed).any():
        active = ~in_mis & ~killed
        nb_max = _row_max(G2, w, active)
        new = active & (w > nb_max)
        if not new.any():
            idx = np.flatnonzero(active)
            new = np.zeros(n, dtype=bool)
            new[idx[np.argmax(w[idx])]] = True
        in_mis |= new
        r = G2.rows_expanded()
        nb_of_new = np.zeros(n, dtype=bool)
        nb_of_new[G2.indices[new[r]]] = True
        killed |= nb_of_new & ~in_mis
    roots = np.flatnonzero(in_mis)
    agg = np.full(n, -1, dtype=np.int64)
    agg[roots] = np.arange(roots.size)
    # pass 1: unaggregated direct strong neighbors of roots
    r = G.rows_expanded()
    root_rows = in_mis[r]
    cand_nodes = G.indices[root_rows]
    cand_aggs = agg[r[root_rows]]
    free = agg[cand_nodes] == -1
    # first-come assignment
    agg[cand_nodes[free]] = cand_aggs[free]
    # pass 2: join any aggregated strong neighbor (repeat to closure)
    for _ in range(3):
        un = agg == -1
        if not un.any():
            break
        nbr_agg = np.full(n, -1, dtype=np.int64)
        has = agg[G.indices] >= 0
        np.maximum.at(nbr_agg, r[has], agg[G.indices[has]])
        adopt = un & (nbr_agg >= 0)
        agg[adopt] = nbr_agg[adopt]
    # pass 3: leftovers become singletons
    left = np.flatnonzero(agg == -1)
    if left.size:
        agg[left] = int(agg.max(initial=-1)) + 1 + np.arange(left.size)
    # compact ids
    _, agg = np.unique(agg, return_inverse=True)
    return agg.astype(np.int64)
