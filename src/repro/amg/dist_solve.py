"""Device-resident distributed AMG solve phase (paper §4 executed end-to-end).

This is the paper's central claim made runnable: node-aware communication
speeds up *every* component of the AMG solve phase — relaxation, residual,
restriction, interpolation — with the strategy chosen **per level** from the
performance models ("Optimal strategies ... are determined during the
formation of each matrix in the AMG hierarchy").

Per-level strategy-selection flow
---------------------------------
At :meth:`DistHierarchy.build` time, for every level ℓ and every solve-phase
operator — ``A_ℓ`` (smoother sweeps + residual), ``P_ℓ`` (interpolation) and
``R_ℓ`` (restriction) — we:

1. build the operator's vector communication graph
   (:func:`repro.amg.dist.vector_comm_graph` / ``rect_vector_graph``),
2. evaluate the max-rate models of Eqs. (4)–(6) for standard / NAP-2 / NAP-3
   via :func:`repro.core.selector.select`,
3. build a :class:`~repro.amg.dist_spmv.DistOperator` (padded ELL block +
   :class:`~repro.core.nap_collectives.HaloPlan`) for the winning strategy.

The coarsest level stores a dense pseudo-inverse, partitioned by rows so the
direct solve is itself distributed (all-gather of the tiny coarse residual +
a local dense matvec).

Execution
---------
The entire cycle — smoother sweeps, residual, restriction, coarse solve,
interpolation + correction — is traced into ONE jitted ``shard_map``
program (recursion unrolled over levels at trace time; W- and F-cycles
unroll their repeated coarse visits the same way, so a W-cycle is still a
single fused device program, just with 2^ℓ visits of level ℓ inlined).
Each matvec runs halo-exchange collectives for its operator's selected
strategy followed by a local ELL SpMV, optionally through the Pallas
:func:`~repro.kernels.spmv.spmv.ell_spmv` kernel.  The block smoothers
(block-Jacobi, hybrid Gauss-Seidel) apply a per-device dense factor —
block-diagonal inverses / (D+L)⁻¹ of the device's diagonal block, lowered
alongside the ELL arrays — after the same halo'd residual, so their
communication is exactly one SpMV per sweep.  Norms and dot products for
stationary iteration and PCG use :func:`~repro.core.nap_collectives.hier_psum`
(NAP-3 all-reduce).  Only the convergence check touches the host: one scalar
residual norm per outer iteration.
"""
from __future__ import annotations

import dataclasses
from collections import Counter

import jax
import jax.numpy as jnp
import numpy as np

from ..core.compat import shard_map
from ..core.nap_collectives import (gather_signature, halo_signature,
                                    hier_all_gather, hier_psum,
                                    reduce_signature)
from ..core.perf_model import (TPU_V5E, MachineParams, overlap_efficiency,
                               spmv_compute_times)
from ..core.selector import select
from ..core.topology import Partition, Topology
from .dist import rect_vector_graph, schedule_comm_stats
from ..kernels.spmv.ops import select_dist_kernel
from .dist_spmv import (DistOperator, build_dist_operator,
                        build_dist_operator_from_blocks, local_square_block)
from .hierarchy import Hierarchy
from .interpolation import estimate_rho_DinvA
from .smoothers import chebyshev_coeffs, chebyshev_recurrence
from .solve import (CYCLE_CHILDREN, MultiSolveResult, SolveOptions,
                    SolveResult, level_visits)

DEV_AXES = ("pod", "lane")
SOLVE_STRATEGIES = ("standard", "nap2", "nap3")


@dataclasses.dataclass
class DistLevel:
    """Device form of one hierarchy level: operators + smoother data."""

    A: DistOperator
    dinv: np.ndarray                     # [D, rows_local] (0 on padded rows)
    P: DistOperator | None = None        # fine rows × coarse cols
    R: DistOperator | None = None        # coarse rows × fine cols
    rho: float = 1.0                     # ρ(D⁻¹A) for Chebyshev
    coarse_inv: np.ndarray | None = None  # [D, rows_local, D*rows_local]
    strategies: dict[str, str] = dataclasses.field(default_factory=dict)
    modeled: dict[str, dict[str, float]] = dataclasses.field(default_factory=dict)
    # local-kernel layout decision for A (select_dist_kernel dict: kernel,
    # block_size, ell/bcsr cost + fill) — reporting alongside the strategy
    local_kernel: dict = dataclasses.field(default_factory=dict)
    # per-op modeled message/byte counts for the selected strategy
    # (schedule_comm_stats), consumed by cycle_comm_stats
    comm_stats: dict[str, dict] = dataclasses.field(default_factory=dict)
    # on/off-process split of A (nnz counts, modeled t_on/t_off/t_comm and
    # overlap efficiency) — what the overlap-aware selector saw
    onoff: dict = dataclasses.field(default_factory=dict)
    # per-device diagonal square blocks of A (local column ids) — the
    # source the block smoothers' dense factors are lowered from
    local_A: list | None = None
    _minv_cache: dict = dataclasses.field(default_factory=dict, repr=False)

    def smoother_minv(self, kind: str, block_size: int = 0) -> np.ndarray:
        """[D, m, m] dense smoother factor M⁻¹ (m = padded local rows).

        ``kind="bj"``: inverse of the block-diagonal of the local block
        (``block_size`` grid restarting at the device's first row — blocks
        never straddle devices).  ``kind="gs"``: inverse of the local
        (D + L) factor, i.e. hybrid forward Gauss-Seidel; ``kind="gsu"``:
        the (D + U) inverse for the backward half of the symmetric sweep.
        Padded/empty diagonals become 1 so padded rows update by exactly
        zero.
        """
        key = (kind, block_size)
        got = self._minv_cache.get(key)
        if got is not None:
            return got
        assert self.local_A is not None, "no local blocks on this level"
        m = self.A.rows_local
        out = np.zeros((len(self.local_A), m, m))
        idx = np.arange(m)
        for d, blk in enumerate(self.local_A):
            dense = np.zeros((m, m))
            dense[: blk.nrows, : blk.nrows] = blk.to_dense()
            if kind == "bj":
                same = (idx[:, None] // block_size) == (idx[None, :] // block_size)
                dense = np.where(same, dense, 0.0)
            elif kind == "gs":
                dense = np.tril(dense)
            elif kind == "gsu":
                dense = np.triu(dense)
            else:
                raise ValueError(f"unknown smoother factor kind {kind!r}")
            diag = np.diagonal(dense).copy()
            np.fill_diagonal(dense, np.where(diag == 0, 1.0, diag))
            out[d] = np.linalg.inv(dense)
        self._minv_cache[key] = out
        return out


class DistHierarchy:
    """An AMG hierarchy lowered onto a (pods × lanes) device mesh.

    Built once per hierarchy (like the MPI communicator build of a parallel
    AMG code); reusable across any number of :func:`dist_solve` /
    :func:`dist_pcg` calls.  Compiled V-cycle programs are cached per solver
    option set.
    """

    def __init__(self, h: Hierarchy | None, n_pods: int, lanes: int,
                 levels: list[DistLevel], mesh, dtype, use_kernel: bool,
                 interpret: bool, reduce_strategy: str):
        # ``h`` is None when the hierarchy was born partitioned
        # (repro.amg.dist_setup): no host Hierarchy ever existed.
        self.h = h
        self.setup_records: list = []
        self.n_pods, self.lanes = n_pods, lanes
        self.levels = levels
        self.mesh = mesh
        self.dtype = dtype
        self.use_kernel = use_kernel
        self.interpret = interpret
        self.reduce_strategy = reduce_strategy
        # multi-RHS routing: True traces the ``*_m`` programs directly on
        # [local, k] operands (native SpMM — one pass over each operator's
        # nonzeros and ONE halo exchange serve all k columns); False keeps
        # the legacy jax.vmap-over-columns trace, retained as the parity
        # oracle the native path is tested against
        self.native_spmm = True
        # halo-exchange/compute overlap: True (default) traces every apply
        # as exchange‖A_on·x then +A_off·halo; False keeps the fused serial
        # form (halo_exchange → A·[x|halo]) as the parity oracle
        self.overlap = True
        # program key (traced-knob subset of opts) -> (programs dict,
        # run arrays); see :meth:`programs`
        self._programs: dict[tuple, tuple] = {}
        # (smoother kind, block_size) -> level arrays extended with the
        # lowered dense smoother factors ("minv")
        self._arrs_ex: dict[tuple, list] = {}
        spec = jax.sharding.PartitionSpec(DEV_AXES)
        sharding = jax.sharding.NamedSharding(mesh, spec)
        self._dev_spec = spec
        self._sharding = sharding
        # level arrays, transferred (and sharded) once at build time
        self._arrs = jax.device_put(
            [self._level_arrays(lv) for lv in levels], sharding)

    # ------------------------------------------------------------------ build
    @classmethod
    def build(cls, h: Hierarchy, n_pods: int, lanes: int, *,
              params: MachineParams = TPU_V5E,
              strategy: str = "auto",
              strategies: tuple[str, ...] = SOLVE_STRATEGIES,
              dtype=jnp.float32, mesh=None, use_kernel: bool | None = None,
              interpret: bool | None = None,
              reduce_strategy: str = "nap3",
              overlap: bool = True) -> "DistHierarchy":
        """Lower ``h`` onto the mesh, selecting each operator's strategy.

        ``strategy="auto"`` picks per level and per operator from the
        performance models; any explicit strategy name forces it everywhere.
        ``overlap=False`` keeps the serial fused applies (parity oracle).
        """
        mesh, use_kernel, interpret = cls._resolve_mesh(
            n_pods, lanes, mesh, use_kernel, interpret)
        levels = cls._lower_levels(h.levels, n_pods, lanes, params=params,
                                   strategy=strategy, strategies=strategies,
                                   dtype=dtype)
        self = cls(h, n_pods, lanes, levels, mesh, dtype, use_kernel,
                   interpret, reduce_strategy)
        self.overlap = bool(overlap)
        return self

    @classmethod
    def from_partitioned(cls, plevels, n_pods: int, lanes: int, *,
                         setup_records=None,
                         params: MachineParams = TPU_V5E,
                         strategy: str = "auto",
                         strategies: tuple[str, ...] = SOLVE_STRATEGIES,
                         dtype=jnp.float32, mesh=None,
                         use_kernel: bool | None = None,
                         interpret: bool | None = None,
                         reduce_strategy: str = "nap3",
                         overlap: bool = True) -> "DistHierarchy":
        """Lower levels that are **already partitioned** (born on the mesh).

        ``plevels`` mirror :class:`~repro.amg.hierarchy.Level` but each
        operator is a :class:`~repro.amg.dist_setup.BlockMatrix` (per-device
        global-shape row blocks) — the output of the distributed setup
        phase.  No host gather/re-scatter happens between setup and solve;
        ``setup_records`` (per-level SpGEMM strategy selections + measured
        exchange stats) are merged into the selection table.
        """
        mesh, use_kernel, interpret = cls._resolve_mesh(
            n_pods, lanes, mesh, use_kernel, interpret)
        levels = cls._lower_levels(plevels, n_pods, lanes, params=params,
                                   strategy=strategy, strategies=strategies,
                                   dtype=dtype)
        for rec in setup_records or ():
            levels[rec.level].strategies[rec.op] = rec.strategy
            levels[rec.level].modeled[rec.op] = dict(rec.modeled)
        self = cls(None, n_pods, lanes, levels, mesh, dtype, use_kernel,
                   interpret, reduce_strategy)
        self.overlap = bool(overlap)
        self.setup_records = list(setup_records or ())
        return self

    @staticmethod
    def _resolve_mesh(n_pods, lanes, mesh, use_kernel, interpret):
        on_tpu = jax.default_backend() == "tpu"
        if use_kernel is None:
            use_kernel = on_tpu
        if interpret is None:
            interpret = not on_tpu
        if mesh is None:
            mesh = jax.make_mesh((n_pods, lanes), DEV_AXES)
        return mesh, use_kernel, interpret

    @classmethod
    def _lower_levels(cls, src_levels, n_pods: int, lanes: int, *, params,
                      strategy, strategies, dtype) -> list[DistLevel]:
        """Per-level lowering shared by :meth:`build` (host ``Level`` s with
        global CSRs) and :meth:`from_partitioned` (``BlockMatrix`` levels):
        comm graphs, strategy selection, halo plans, ELL blocks."""
        topo = Topology(n_nodes=n_pods, ppn=lanes)
        D = topo.n_procs

        def choose(graph, op_name, compute=(0.0, 0.0)):
            # ``compute=(t_on, t_off)`` makes the ranking overlap-aware:
            # max(T_comm, T_on) + T_off — zero (the default, and always when
            # params.Rf is unset) reduces to the serial comm-only model
            if strategy != "auto":
                return strategy, {}, {}
            sel = select(graph, params, strategies, compute=compute)
            return sel.strategy, dict(sel.times), dict(sel.comm_times)

        def make_op(M, strat, row_part, col_part, graph):
            blocks = getattr(M, "blocks", None)
            if blocks is not None:
                return build_dist_operator_from_blocks(
                    blocks, n_pods, lanes, strat, row_part=row_part,
                    col_part=col_part, graph=graph, dtype=dtype)
            return build_dist_operator(M, n_pods, lanes, strat,
                                       row_part=row_part, col_part=col_part,
                                       graph=graph, dtype=dtype)

        def part_of(lv):
            # a BlockMatrix level carries the partition its blocks were
            # built on — reuse it rather than assuming balanced rows
            p = getattr(lv.A, "part", None)
            if p is not None:
                assert p.topo == topo, (p.topo, topo)
                return p
            return Partition.balanced(lv.A.nrows, topo)

        def onoff_compute(M, row_part, col_part):
            """Per-device max on/off nnz → modeled (t_on, t_off) split.

            Column locality (not the halo plan) decides on vs off, so the
            split is strategy-independent and can feed selection *before*
            any operator is built.
            """
            on_max = off_max = 0
            for q in range(D):
                rlo, rhi = row_part.local_range(q)
                clo, chi = col_part.local_range(q)
                sub = M.submatrix_rows(rlo, rhi)
                on = int(((sub.indices >= clo) & (sub.indices < chi)).sum())
                on_max = max(on_max, on)
                off_max = max(off_max, sub.nnz - on)
            return spmv_compute_times(params, on_max, off_max)

        parts = [part_of(lv) for lv in src_levels]
        levels: list[DistLevel] = []
        for l, lv in enumerate(src_levels):
            part = parts[l]
            gA = rect_vector_graph(lv.A, part, part)
            compA = onoff_compute(lv.A, part, part)
            sA, tA, cA = choose(gA, "spmv_A", compA)
            Aop = make_op(lv.A, sA, part, part, gA)
            # per-level local-kernel layout: ELL gather vs MXU-blocked BCSR
            # (A only — P/R are too rectangular/scattered to block well, and
            # the coarsest A never runs a SpMV, its solve being dense)
            sel = select_dist_kernel(Aop.ell_cols)
            if sel["kernel"] == "bcsr" and l + 1 < len(src_levels):
                Aop.lower_bcsr(sel["block_size"])
            else:
                sel = dict(sel, kernel="ell", block_size=0)
            d = lv.A.diagonal()
            dinv = 1.0 / np.where(d == 0, 1.0, d)
            dinv_dev = np.zeros((D, part.max_local_size), dtype=np.float64)
            for q in range(D):
                lo, hi = part.local_range(q)
                dinv_dev[q, : hi - lo] = dinv[lo:hi]
            dl = DistLevel(A=Aop, dinv=dinv_dev,
                           strategies={"spmv_A": sA},
                           modeled={"spmv_A": tA},
                           local_kernel=sel)
            dl.comm_stats["spmv_A"] = schedule_comm_stats(gA, sA)
            nnz = Aop.onoff_nnz()
            t_on, t_off = compA
            t_comm = cA.get(sA, 0.0)
            dl.onoff = {**nnz, "local_nnz": nnz["on_nnz"] + nnz["off_nnz"],
                        "halo_empty": Aop.halo_empty,
                        "t_on": t_on, "t_off": t_off, "t_comm": t_comm,
                        "eff_modeled": overlap_efficiency(t_comm, t_on, t_off)}
            if lv.P is not None and l + 1 < len(src_levels):
                cpart = parts[l + 1]
                gP = rect_vector_graph(lv.P, part, cpart)
                sP, tP, _ = choose(gP, "interp",
                                   onoff_compute(lv.P, part, cpart))
                dl.P = make_op(lv.P, sP, part, cpart, gP)
                gR = rect_vector_graph(lv.R, cpart, part)
                sR, tR, _ = choose(gR, "restrict",
                                   onoff_compute(lv.R, cpart, part))
                dl.R = make_op(lv.R, sR, cpart, part, gR)
                dl.rho = estimate_rho_DinvA(lv.A)
                dl.strategies.update(interp=sP, restrict=sR)
                dl.modeled.update(interp=tP, restrict=tR)
                dl.comm_stats["interp"] = schedule_comm_stats(gP, sP)
                dl.comm_stats["restrict"] = schedule_comm_stats(gR, sR)
                # diagonal square blocks feed the block smoothers' dense
                # factors (coarsest level never smooths — skip it there)
                dl.local_A = [local_square_block(lv.A, part, q)
                              for q in range(D)]
            else:
                if lv.P is not None:
                    # a stall-pop in setup leaves a dangling P on the last
                    # level; its A is by construction too large to treat as
                    # the coarsest grid, so fail loudly rather than dense-
                    # solving it
                    raise ValueError(
                        f"level {l} has P but no coarser level (coarsening "
                        f"stalled); refusing the dense coarse solve at "
                        f"n={lv.A.nrows}")
                # coarsest: distributed dense pseudo-inverse solve
                pinv = np.linalg.pinv(lv.A.to_dense())
                m = part.max_local_size
                cinv = np.zeros((D, m, D * m), dtype=np.float64)
                for q in range(D):
                    lo, hi = part.local_range(q)
                    for e in range(D):
                        elo, ehi = part.local_range(e)
                        cinv[q, : hi - lo, e * m: e * m + ehi - elo] = \
                            pinv[lo:hi, elo:ehi]
                dl.coarse_inv = cinv
            levels.append(dl)
        return levels

    # ------------------------------------------------------------- reporting
    def selection_table(self) -> list[dict]:
        """One row per (level, op): chosen strategy + modeled seconds."""
        rows = []
        for l, dl in enumerate(self.levels):
            for op, s in dl.strategies.items():
                rows.append({"level": l, "op": op, "strategy": s,
                             "modeled": dict(dl.modeled.get(op, {}))})
        return rows

    def summary(self) -> str:
        out = [f"dist hierarchy: {len(self.levels)} levels on "
               f"{self.n_pods}x{self.lanes} mesh"]
        for row in self.selection_table():
            times = row["modeled"]
            ts = " ".join(f"{k}={v * 1e6:.1f}us" for k, v in times.items())
            out.append(f"  L{row['level']:<2d} {row['op']:<8s} -> "
                       f"{row['strategy']:<8s} {ts}")
        return "\n".join(out)

    def kernel_table(self) -> list[dict]:
        """One row per level: the local-kernel layout decision for A.

        ``kernel`` is what actually runs ('bcsr' only when the operator was
        lowered); the cost/fill columns are the heuristic's inputs
        (:func:`repro.kernels.spmv.ops.select_dist_kernel`), kept so
        reports can show *why* a level picked its layout.
        """
        rows = []
        for l, dl in enumerate(self.levels):
            sel = dl.local_kernel
            oo = dl.onoff
            rows.append({
                "level": l,
                "kernel": dl.A.local_kernel,
                "block_size": dl.A.block_size,
                "rows_local": dl.A.rows_local,
                "ell_fill": sel.get("ell_fill", 0.0),
                "bcsr_fill": sel.get("bcsr_fill", 0.0),
                "ell_cost": sel.get("ell_cost", 0.0),
                "bcsr_cost": sel.get("bcsr_cost", float("inf")),
                "on_nnz": oo.get("on_nnz", 0),
                "off_nnz": oo.get("off_nnz", 0),
                "halo_empty": oo.get("halo_empty", False),
                "overlap_eff_modeled": oo.get("eff_modeled", 0.0),
            })
        return rows

    # ----------------------------------------------------- streaming refresh
    def refresh_values(self, src_levels) -> None:
        """Value-only refresh onto the frozen lowered layouts.

        ``src_levels`` are the refreshed source levels (host ``Level`` s or
        partitioned ``BlockMatrix`` levels — the same two shapes
        :meth:`_lower_levels` accepts) whose sparsity patterns must match
        what this hierarchy was lowered from.  Every structural artifact —
        comm graphs, selected strategies, halo plans, ELL/BCSR column maps,
        shardings — is reused verbatim; only value planes, diagonals,
        smoother factors, Chebyshev bounds and the coarse pseudo-inverse
        are recomputed.  The per-level device dicts are mutated **in
        place** because every cached ``(progs, run_arrs)`` tuple holds
        those same dict objects: compiled programs pick up the new
        operands on their next call without retracing.  Chebyshev programs
        are the one exception — they bake ``chebyshev_coeffs(rho)`` as
        trace-time constants, so their cache entries are dropped.
        """
        def block_of(M):
            blocks = getattr(M, "blocks", None)
            if blocks is not None:
                return lambda d: blocks[d]
            return lambda d: M

        D = self.n_pods * self.lanes
        for lv, dl in zip(src_levels, self.levels):
            part = dl.A.row_part
            dl.A.refresh_values(block_of(lv.A))
            d = lv.A.diagonal()
            dinv = 1.0 / np.where(d == 0, 1.0, d)
            dinv_dev = np.zeros((D, part.max_local_size), dtype=np.float64)
            for q in range(D):
                lo, hi = part.local_range(q)
                dinv_dev[q, : hi - lo] = dinv[lo:hi]
            dl.dinv = dinv_dev
            if dl.P is not None:
                dl.P.refresh_values(block_of(lv.P))
                dl.R.refresh_values(block_of(lv.R))
                dl.rho = estimate_rho_DinvA(lv.A)
                dl.local_A = [local_square_block(lv.A, part, q)
                              for q in range(D)]
                dl._minv_cache.clear()
            else:
                pinv = np.linalg.pinv(lv.A.to_dense())
                m = part.max_local_size
                cinv = np.zeros((D, m, D * m), dtype=np.float64)
                for q in range(D):
                    lo, hi = part.local_range(q)
                    for e in range(D):
                        elo, ehi = part.local_range(e)
                        cinv[q, : hi - lo, e * m: e * m + ehi - elo] = \
                            pinv[lo:hi, elo:ehi]
                dl.coarse_inv = cinv
        placed = jax.device_put(
            [self._level_arrays(dl) for dl in self.levels], self._sharding)
        for old, new in zip(self._arrs, placed):
            old.update(new)
        for key, lst in self._arrs_ex.items():
            for dl, base, a in zip(self.levels, self._arrs, lst):
                a.update(base)
                if dl.coarse_inv is None:
                    for name, kind in self._MINV_ARRS[key[0]]:
                        mv = dl.smoother_minv(kind, key[1]).astype(self.dtype)
                        a[name] = jax.device_put(mv, self._sharding)
        for key in [k for k in self._programs if k[1] == "chebyshev"]:
            del self._programs[key]

    # ----------------------------------------------------------- host layout
    def scatter(self, x: np.ndarray, level: int = 0) -> jnp.ndarray:
        arr = self.levels[level].A.scatter_x(np.asarray(x), dtype=self.dtype)
        return jax.device_put(arr, self._sharding)

    def gather(self, x_dev, level: int = 0) -> np.ndarray:
        return self.levels[level].A.gather_y(np.asarray(x_dev))

    # --------------------------------------------------------- device pieces
    def _level_arrays(self, dl: DistLevel) -> dict:
        a = {"A": dl.A.device_arrays(),
             "dinv": dl.dinv.astype(self.dtype)}
        if dl.P is not None:
            a["P"] = dl.P.device_arrays()
            a["R"] = dl.R.device_arrays()
        if dl.coarse_inv is not None:
            a["cinv"] = dl.coarse_inv.astype(self.dtype)
        return a

    def _spmv(self, op: DistOperator, arrs: dict, x):
        return op.apply(arrs, x, use_kernel=self.use_kernel,
                        interpret=self.interpret, overlap=self.overlap)

    def _pdot(self, a, b):
        part = jnp.sum(a * b)
        if self.reduce_strategy == "flat":
            # scalar all-reduce: flat is the REDUCE_SIGNATURES["flat"]
            # baseline the hierarchical strategy is measured against
            return jax.lax.psum(part, DEV_AXES)  # comm-audit: allow flat-psum
        return hier_psum(part, *DEV_AXES, strategy=self.reduce_strategy)

    def _pnorm(self, r):
        return jnp.sqrt(self._pdot(r, r))

    def _pdot_cols(self, a, b):
        """Per-column dot for [local, k] operands → replicated [k]."""
        part = jnp.sum(a * b, axis=0)
        if self.reduce_strategy == "flat":
            return jax.lax.psum(part, DEV_AXES)  # comm-audit: allow flat-psum
        return hier_psum(part, *DEV_AXES, strategy=self.reduce_strategy)

    def _relax(self, dl: DistLevel, arrs: dict, x, b, opts, sweeps: int):
        if sweeps == 0:
            return x
        aA = arrs["A"]
        # [local, k] operands on the native SpMM path: the elementwise D⁻¹
        # scaling broadcasts over the trailing RHS axis
        dinv = arrs["dinv"]
        if x.ndim == 2:
            dinv = dinv[:, None]
        if opts.smoother == "jacobi":
            for _ in range(sweeps):
                x = x + opts.omega * dinv * (b - self._spmv(dl.A, aA, x))
            return x
        if opts.smoother in ("block_jacobi", "hybrid_gs"):
            # x += w · M⁻¹ (b − A x): the halo'd residual carries every
            # off-device coupling, the dense local factor does the rest
            minv = arrs["minv"]
            w = opts.omega if opts.smoother == "block_jacobi" else 1.0
            for _ in range(sweeps):
                x = x + w * (minv @ (b - self._spmv(dl.A, aA, x)))
            return x
        if opts.smoother == "hybrid_gs_sym":
            # forward (D+L)⁻¹ then backward (D+U)⁻¹ half-sweep, each with a
            # freshly halo'd residual — 2 SpMVs/sweep, symmetric smoother
            minv, minv_u = arrs["minv"], arrs["minv_u"]
            for _ in range(sweeps):
                x = x + (minv @ (b - self._spmv(dl.A, aA, x)))
                x = x + (minv_u @ (b - self._spmv(dl.A, aA, x)))
            return x
        # Chebyshev via the recurrence shared with the host backend, the
        # matvec swapped for the level's distributed SpMV
        degree = opts.cheby_degree * sweeps
        theta, delta, sigma = chebyshev_coeffs(dl.rho)
        return chebyshev_recurrence(
            lambda v: self._spmv(dl.A, aA, v), dinv, x, b, degree,
            theta, delta, sigma)

    def _cycle_dev(self, arrs, b, x, opts, level: int = 0,
                   shape: str | None = None):
        """One cycle, fully on device.  The per-shape coarse revisits of
        :data:`~repro.amg.solve.CYCLE_CHILDREN` are unrolled at trace time,
        so W/F-cycles stay ONE jitted shard_map program."""
        shape = shape or opts.cycle
        dl = self.levels[level]
        a = arrs[level]
        if dl.coarse_inv is not None:                 # coarsest: direct solve
            full = hier_all_gather(b, *DEV_AXES)      # [D * rows_local]
            return a["cinv"] @ full
        if x is None:
            x = jnp.zeros_like(b)
        x = self._relax(dl, a, x, b, opts, opts.presweeps)
        r = b - self._spmv(dl.A, a["A"], x)
        rc = self._spmv(dl.R, a["R"], r)
        ec = None
        for child in CYCLE_CHILDREN[shape]:           # coarse-grid solve(s)
            ec = self._cycle_dev(arrs, rc, ec, opts, level + 1, shape=child)
        x = x + self._spmv(dl.P, a["P"], ec)
        x = self._relax(dl, a, x, b, opts, opts.postsweeps)
        return x

    # ------------------------------------------------------------- programs
    # extra dense factors per smoother: array name -> minv kind
    _MINV_ARRS = {"bj": (("minv", "bj"),),
                  "gs": (("minv", "gs"),),
                  "gs_sym": (("minv", "gs"), ("minv_u", "gsu"))}

    def _smoother_arrs_key(self, opts) -> tuple | None:
        """Key of the extra lowered arrays ``opts``'s smoother needs."""
        if opts.smoother == "block_jacobi":
            return ("bj", opts.block_size)
        if opts.smoother == "hybrid_gs":
            return ("gs", 0)
        if opts.smoother == "hybrid_gs_sym":
            return ("gs_sym", 0)
        return None

    def run_arrays(self, opts) -> list:
        """Per-level device arrays for one option set.

        Jacobi/Chebyshev run on the base arrays; the block smoothers get the
        base dicts extended with their dense local factor (``minv``, lowered
        lazily once per (kind, block_size) and shared across option sets —
        the base ELL/halo arrays are shared by reference, never re-placed).
        """
        key = self._smoother_arrs_key(opts)
        if key is None:
            return self._arrs
        got = self._arrs_ex.get(key)
        if got is None:
            got = []
            for dl, base in zip(self.levels, self._arrs):
                a = dict(base)
                if dl.coarse_inv is None:
                    for name, kind in self._MINV_ARRS[key[0]]:
                        mv = dl.smoother_minv(kind, key[1]).astype(self.dtype)
                        a[name] = jax.device_put(mv, self._sharding)
                got.append(a)
            self._arrs_ex[key] = got
        return got

    def programs(self, opts) -> tuple:
        """``(progs, arrs)`` for one option set (cached per ``opts``).

        ``progs`` holds the jitted shard_map programs — the cycle shape and
        smoother are baked in at trace time — and ``arrs`` the matching
        per-level device arrays to pass them (:meth:`run_arrays`).
        Single-RHS programs take [local] vectors; the ``*_m`` variants take
        [local, k] multi-RHS blocks.  With :attr:`native_spmm` (the
        default) the cycle traces directly on the [local, k] operands —
        every SpMV is a native SpMM reading each operator's nonzeros once
        for all k columns and exchanging ONE fused halo buffer; with it
        off the legacy jax.vmap-over-columns trace is kept as the parity
        oracle.  Either way, norms/dots come back as replicated [k]
        vectors.

        The cache key covers only the knobs the traced program reads —
        host-reference-only knobs (``smoother_parts``; ``block_size`` for
        non-block smoothers) never force a bitwise-identical re-compile.
        """
        key = (opts.cycle, opts.smoother, opts.presweeps, opts.postsweeps,
               opts.omega, opts.cheby_degree, self._smoother_arrs_key(opts),
               self.native_spmm, self.overlap)
        if key in self._programs:
            return self._programs[key]
        run_arrs = self.run_arrays(opts)
        dev = self._dev_spec
        rep = jax.sharding.PartitionSpec()
        mesh = self.mesh

        def squeeze(t):
            return jax.tree_util.tree_map(lambda v: v[0], t)

        def smap(f, in_specs, out_specs):
            return jax.jit(shard_map(f, mesh=mesh, in_specs=in_specs,
                                     out_specs=out_specs, check_vma=False))

        def spmv0(arrs, x):
            return self._spmv(self.levels[0].A, arrs[0]["A"], x)

        def spmv0_m(arrs, x):                       # [local, k] → [local, k]
            if self.native_spmm:
                # native SpMM: one pass over A's nonzeros (and one fused
                # halo exchange) serves all k columns
                return spmv0(arrs, x)
            return jax.vmap(lambda v: spmv0(arrs, v), in_axes=1,
                            out_axes=1)(x)

        def vcycle_m(arrs, b, x):                   # batched V-cycle
            if self.native_spmm:
                # the whole cycle traces on [local, k] operands: every
                # SpMV/restrict/interpolate is a native SpMM, the dense
                # smoother factors and coarse solve are plain matmuls
                return self._cycle_dev(arrs, b, x, opts)
            if x is None:
                return jax.vmap(
                    lambda bc: self._cycle_dev(arrs, bc, None, opts),
                    in_axes=1, out_axes=1)(b)
            return jax.vmap(
                lambda bc, xc: self._cycle_dev(arrs, bc, xc, opts),
                in_axes=1, out_axes=1)(b, x)

        def resid_norm_body(x, b, arrs):
            x, b, arrs = x[0], b[0], squeeze(arrs)
            r = b - spmv0(arrs, x)
            return self._pnorm(r)

        def resid_norm_m_body(x, b, arrs):
            x, b, arrs = x[0], b[0], squeeze(arrs)
            r = b - spmv0_m(arrs, x)
            return jnp.sqrt(self._pdot_cols(r, r))

        def cycle_body(x, b, arrs):
            x, b, arrs = x[0], b[0], squeeze(arrs)
            x = self._cycle_dev(arrs, b, x, opts)
            r = b - spmv0(arrs, x)
            return x[None], self._pnorm(r)

        def cycle_m_body(x, b, arrs):
            x, b, arrs = x[0], b[0], squeeze(arrs)
            x = vcycle_m(arrs, b, x)
            r = b - spmv0_m(arrs, x)
            return x[None], jnp.sqrt(self._pdot_cols(r, r))

        def vcycle_body(b, arrs):
            b, arrs = b[0], squeeze(arrs)
            return self._cycle_dev(arrs, b, None, opts)[None]

        def vcycle_m_body(b, arrs):
            b, arrs = b[0], squeeze(arrs)
            return vcycle_m(arrs, b, None)[None]

        def pcg_init_body(x, b, arrs):
            x, b, arrs = x[0], b[0], squeeze(arrs)
            r = b - spmv0(arrs, x)                  # x0 warm start
            z = self._cycle_dev(arrs, r, None, opts)
            rz = self._pdot(r, z)
            return r[None], z[None], rz, self._pnorm(r)

        def pcg_init_m_body(x, b, arrs):
            x, b, arrs = x[0], b[0], squeeze(arrs)
            r = b - spmv0_m(arrs, x)
            z = vcycle_m(arrs, r, None)
            rz = self._pdot_cols(r, z)
            return r[None], z[None], rz, jnp.sqrt(self._pdot_cols(r, r))

        def pcg_step_body(x, r, p, rz, arrs):
            x, r, p = x[0], r[0], p[0]
            arrs = squeeze(arrs)
            Ap = spmv0(arrs, p)
            alpha = rz / self._pdot(p, Ap)
            x = x + alpha * p
            r = r - alpha * Ap
            rnorm = self._pnorm(r)
            z = self._cycle_dev(arrs, r, None, opts)
            rz_new = self._pdot(r, z)
            p = z + (rz_new / rz) * p
            return x[None], r[None], p[None], rz_new, rnorm

        def pcg_step_m_body(x, r, p, rz, arrs):
            x, r, p = x[0], r[0], p[0]              # [local, k]; rz [k]
            arrs = squeeze(arrs)
            Ap = spmv0_m(arrs, p)
            # columns that already converged exactly (rz = pAp = 0, e.g. a
            # zero RHS) must not poison the batch with 0/0 NaNs: guard the
            # divisions so such columns step by exactly zero
            den = self._pdot_cols(p, Ap)
            alpha = rz / jnp.where(den == 0, 1.0, den)  # [k], bcasts on cols
            x = x + alpha * p
            r = r - alpha * Ap
            rnorm = jnp.sqrt(self._pdot_cols(r, r))
            z = vcycle_m(arrs, r, None)
            rz_new = self._pdot_cols(r, z)
            p = z + (rz_new / jnp.where(rz == 0, 1.0, rz)) * p
            return x[None], r[None], p[None], rz_new, rnorm

        progs = {
            "resid_norm": smap(resid_norm_body, (dev, dev, dev), rep),
            "cycle": smap(cycle_body, (dev, dev, dev), (dev, rep)),
            "vcycle": smap(vcycle_body, (dev, dev), dev),
            "pcg_init": smap(pcg_init_body, (dev, dev, dev),
                             (dev, dev, rep, rep)),
            "pcg_step": smap(pcg_step_body, (dev, dev, dev, rep, dev),
                             (dev, dev, dev, rep, rep)),
            "resid_norm_m": smap(resid_norm_m_body, (dev, dev, dev), rep),
            "cycle_m": smap(cycle_m_body, (dev, dev, dev), (dev, rep)),
            "vcycle_m": smap(vcycle_m_body, (dev, dev), dev),
            "pcg_init_m": smap(pcg_init_m_body, (dev, dev, dev),
                               (dev, dev, rep, rep)),
            "pcg_step_m": smap(pcg_step_m_body, (dev, dev, dev, rep, dev),
                               (dev, dev, dev, rep, rep)),
        }
        self._programs[key] = (progs, run_arrs)
        return self._programs[key]

    # ------------------------------------------------- static-analysis hooks
    # Introspection surface consumed by repro.analysis.comm_audit: trace any
    # compiled program / single apply to its ClosedJaxpr, and state the
    # collective structure the selected strategies predict for it.  Tracing
    # is abstract — nothing runs on devices.

    def expected_apply_signature(self, level: int,
                                 op: str = "A") -> tuple[str, ...]:
        """Ordered collectives ONE apply of ``levels[level].<op>`` must
        lower to (the operator's selected halo-exchange strategy; empty on
        an empty-halo level)."""
        return getattr(self.levels[level], op).expected_signature

    def trace_apply(self, level: int, op: str = "A", *,
                    overlap: bool | None = None, k: int | None = None):
        """ClosedJaxpr of one shard_mapped apply of ``levels[level].<op>``
        (``k`` adds a trailing multi-RHS axis)."""
        overlap = self.overlap if overlap is None else overlap
        dop = getattr(self.levels[level], op)
        arrs = self._arrs[level][op]
        dev = self._dev_spec

        def body(x, a):
            x = x[0]
            a = jax.tree_util.tree_map(lambda v: v[0], a)
            return dop.apply(a, x, use_kernel=self.use_kernel,
                             interpret=self.interpret, overlap=overlap)[None]

        fn = shard_map(body, mesh=self.mesh, in_specs=(dev, dev),
                       out_specs=dev, check_vma=False)
        D = self.n_pods * self.lanes
        shape = (D, dop.plan.local_n) + (() if k is None else (k,))
        return jax.make_jaxpr(fn)(jnp.zeros(shape, self.dtype), arrs)

    def trace_program(self, name: str, opts=None, k: int = 2):
        """ClosedJaxpr of the compiled fused program ``name`` for ``opts``
        (the exact cached callables :meth:`programs` hands the solvers,
        traced on zero operands of the program's shapes; ``k`` is the
        multi-RHS width of the ``*_m`` variants)."""
        opts = opts or SolveOptions()
        progs, arrs = self.programs(opts)
        D = self.n_pods * self.lanes
        n = self.levels[0].A.plan.local_n
        multi = name.endswith("_m")
        vec = jnp.zeros((D, n, k) if multi else (D, n), self.dtype)
        rz = jnp.zeros((k,) if multi else (), self.dtype)
        base = name[:-2] if multi else name
        args = {"resid_norm": (vec, vec, arrs),
                "cycle": (vec, vec, arrs),
                "vcycle": (vec, arrs),
                "pcg_init": (vec, vec, arrs),
                "pcg_step": (vec, vec, vec, rz, arrs)}[base]
        return jax.make_jaxpr(progs[name])(*args)

    def _cycle_collectives(self, opts) -> Counter:
        """Per-primitive collective counts ONE cycle of ``opts`` predicts:
        the same visits × (sweeps + residual + restrict + interpolate)
        arithmetic as :func:`cycle_comm_stats`, but counting each selected
        strategy's lowered primitives instead of modeled messages."""
        visits = level_visits(len(self.levels), opts.cycle)
        sweep_spmvs = opts.spmvs_per_sweep() * (opts.presweeps
                                                + opts.postsweeps)
        cnt: Counter = Counter()

        def add(sig, times=1):
            for p in sig:
                cnt[p] += times

        for l, dl in enumerate(self.levels):
            if dl.coarse_inv is not None:
                # distributed direct solve: hier_all_gather of the coarse
                # residual (default NAP-3 lowering)
                add(gather_signature("nap3"), visits[l])
            else:
                add(halo_signature(dl.A.plan), (sweep_spmvs + 1) * visits[l])
                add(halo_signature(dl.R.plan), visits[l])
                add(halo_signature(dl.P.plan), visits[l])
        return cnt

    def expected_collectives(self, opts=None,
                             name: str = "cycle") -> dict[str, int]:
        """Per-primitive collective counts the lowered fused program
        ``name`` must contain — cycle structure plus the program's own
        top-level SpMV and all-reduce calls.  The ``*_m`` variants are
        identical: a batched collective is still one equation."""
        opts = opts or SolveOptions()
        base = name[:-2] if name.endswith("_m") else name
        total: Counter = Counter()

        def add(sig, times=1):
            for p in sig:
                total[p] += times

        if base in ("cycle", "vcycle", "pcg_init", "pcg_step"):
            total += self._cycle_collectives(opts)
        if base in ("resid_norm", "cycle", "pcg_init", "pcg_step"):
            add(halo_signature(self.levels[0].A.plan))   # top-level residual
        add(reduce_signature(self.reduce_strategy),
            {"resid_norm": 1, "cycle": 1, "vcycle": 0,
             "pcg_init": 2, "pcg_step": 3}[base])
        return {p: c for p, c in total.items() if c}


# --------------------------------------------------------------------------
# Solver drivers (host loop = convergence check only)
# --------------------------------------------------------------------------


# defaults of DistHierarchy.build, used to normalize cache keys so kwargs
# dicts that spell a default explicitly hit the same entry
_BUILD_DEFAULTS = dict(params=TPU_V5E, strategy="auto",
                       strategies=SOLVE_STRATEGIES, dtype=jnp.float32,
                       mesh=None, use_kernel=None, interpret=None,
                       reduce_strategy="nap3", overlap=True)
DIST_CACHE_SIZE = 8


def _freeze_kwargs(kw: dict) -> tuple | None:
    """Hashable cache key for a DistHierarchy.build kwargs dict (normalized
    against the build defaults), or ``None`` when any value is unhashable
    (an explicit mesh, say) — such calls are not cached rather than risking
    a stale hit keyed on a recycled id."""
    items = []
    for k, v in sorted({**_BUILD_DEFAULTS, **kw}.items()):
        try:
            hash(v)
        except TypeError:
            return None
        items.append((k, v))
    return tuple(items)


def _ensure_dist(h, dist, **build_kwargs) -> DistHierarchy:
    """Resolve the legacy ``dist=`` argument to a DistHierarchy.

    A kwargs dict is resolved through the per-hierarchy ``dist_cache`` so
    repeated ``solve(..., backend="dist", dist={...})`` calls reuse ONE
    lowered hierarchy (comm graphs, strategy selection, compiled programs)
    instead of rebuilding it every call.
    """
    if isinstance(h, DistHierarchy):
        return h
    if isinstance(dist, DistHierarchy):
        return dist
    if dist is None:
        raise ValueError(
            "backend='dist' needs dist=: pass a prebuilt DistHierarchy "
            "(reused across calls) or a DistHierarchy.build kwargs dict "
            "with at least n_pods and lanes")
    kw = dict(dist)
    kw.update(build_kwargs)
    key = _freeze_kwargs(kw)
    cache = getattr(h, "dist_cache", None)
    if cache is not None and key is not None and key in cache:
        return cache[key]
    try:
        n_pods, lanes = kw.pop("n_pods"), kw.pop("lanes")
    except KeyError as e:
        raise ValueError(f"dist= kwargs dict must set {e.args[0]!r}") from None
    dh = DistHierarchy.build(h, n_pods, lanes, **kw)
    if cache is not None and key is not None:
        cache[key] = dh
        while len(cache) > DIST_CACHE_SIZE:      # oldest-first eviction
            cache.pop(next(iter(cache)))
    return dh


def _norms(b: np.ndarray):
    """Per-column norms of b as a denominator: [k] for [n, k], scalar else."""
    nb = np.linalg.norm(b, axis=0)
    return np.where(nb == 0, 1.0, nb)


def cycle_comm_stats(dh: DistHierarchy, opts=None) -> dict:
    """Modeled communication of ONE cycle of ``opts``'s shape + smoother.

    Multiplies each level's per-op message/byte counts (the selected
    strategy's :func:`~repro.amg.dist.schedule_comm_stats`) by the number
    of SpMVs a visit costs and by the cycle shape's per-level visit counts
    — the quantity that makes W/F-cycles coarse-level-communication heavy
    and hence where NAP-2/NAP-3 aggregation pays.  ``coarse_*`` totals
    cover levels ≥ 1 (the coarsest direct solve is an all-gather, not a
    halo exchange, and is excluded).
    """
    opts = opts or SolveOptions()
    visits = level_visits(len(dh.levels), opts.cycle)
    sweep_spmvs = opts.spmvs_per_sweep() * (opts.presweeps + opts.postsweeps)
    keys = ("inter_msgs", "inter_bytes", "intra_msgs", "intra_bytes")
    per_level = []
    totals = dict.fromkeys(keys, 0)
    coarse = {"coarse_inter_msgs": 0, "coarse_intra_msgs": 0}
    for l, dl in enumerate(dh.levels):
        row = dict.fromkeys(keys, 0)
        if dl.coarse_inv is None and "spmv_A" in dl.comm_stats:
            n_spmv = sweep_spmvs + 1                  # sweeps + residual
            for k in keys:
                row[k] += n_spmv * dl.comm_stats["spmv_A"][k]
            for op in ("interp", "restrict"):
                if op in dl.comm_stats:
                    for k in keys:
                        row[k] += dl.comm_stats[op][k]
        entry = {"level": l, "visits": visits[l]}
        for k in keys:
            entry[k] = row[k] * visits[l]
            totals[k] += entry[k]
        if l > 0:
            coarse["coarse_inter_msgs"] += entry["inter_msgs"]
            coarse["coarse_intra_msgs"] += entry["intra_msgs"]
        per_level.append(entry)
    return {"cycle": opts.cycle, "smoother": opts.smoother,
            "per_level": per_level, **totals, **coarse}


def dist_vcycle(dh: DistHierarchy, b: np.ndarray, opts=None) -> np.ndarray:
    """One device-resident cycle (``opts.cycle`` shape) from a zero initial
    guess (``b``: [n] or [n, k])."""
    opts = opts or SolveOptions()
    b = np.asarray(b)  # staged by BoundSolver._check_b; keep dtype
    progs, arrs = dh.programs(opts)
    bd = dh.scatter(b)
    prog = progs["vcycle_m" if b.ndim == 2 else "vcycle"]
    return dh.gather(prog(bd, arrs))


def _column_results(dh, x, res, nb, tol):
    """Slice a batched solve into per-column SolveResults.

    Matches the host backend's per-column semantics: each column reports
    the iteration count at which IT first converged (the batch may have
    kept cycling for slower columns) and a residual history truncated
    there, so ``iterations``/``avg_conv_factor`` agree across backends.
    """
    X = dh.gather(x)
    k = X.shape[1]
    cols = []
    for j in range(k):
        hist = [float(r[j]) for r in res]
        nbj = float(nb[j])
        it = next((i for i, r in enumerate(hist) if r / nbj < tol), None)
        if it is None:
            cols.append(SolveResult(X[:, j], hist, len(hist) - 1, False))
        else:
            cols.append(SolveResult(X[:, j], hist[: it + 1], it, True))
    return MultiSolveResult(X, cols)


def dist_solve(dh: DistHierarchy, b: np.ndarray, tol: float = 1e-8,
               maxiter: int = 100, opts=None, x0: np.ndarray | None = None):
    """Stationary AMG iteration x ← x + cycle(b − Ax), fused on device.

    ``b`` may be ``[n]`` or ``[n, k]``; the multi-RHS form batches all k
    systems through one device trace and iterates until every column
    converges.
    """
    opts = opts or SolveOptions()
    b = np.asarray(b)  # staged by BoundSolver._check_b; keep dtype
    multi = b.ndim == 2
    progs, arrs = dh.programs(opts)
    bd = dh.scatter(b)
    x = dh.scatter(np.zeros_like(b) if x0 is None else np.asarray(x0))
    if multi:
        nb = _norms(b)
        res = [np.asarray(progs["resid_norm_m"](x, bd, arrs),
                          dtype=np.float64)]
        for _ in range(maxiter):
            if (res[-1] / nb < tol).all():
                break
            x, rn = progs["cycle_m"](x, bd, arrs)
            res.append(np.asarray(rn, dtype=np.float64))
        return _column_results(dh, x, res, nb, tol)
    nb = float(np.linalg.norm(b)) or 1.0
    res = [float(progs["resid_norm"](x, bd, arrs))]
    for it in range(maxiter):
        if res[-1] / nb < tol:
            return SolveResult(dh.gather(x), res, it, True)
        x, rn = progs["cycle"](x, bd, arrs)
        res.append(float(rn))
    return SolveResult(dh.gather(x), res, maxiter, res[-1] / nb < tol)


def dist_pcg(dh: DistHierarchy, b: np.ndarray, tol: float = 1e-8,
             maxiter: int = 200, opts=None, x0: np.ndarray | None = None):
    """AMG-preconditioned CG, preconditioner + operator fully on device.

    Supports ``x0=`` warm starts and multi-RHS ``b`` of shape ``[n, k]``.
    """
    opts = opts or SolveOptions()
    b = np.asarray(b)  # staged by BoundSolver._check_b; keep dtype
    multi = b.ndim == 2
    progs, arrs = dh.programs(opts)
    bd = dh.scatter(b)
    x = dh.scatter(np.zeros_like(b) if x0 is None else np.asarray(x0))
    suffix = "_m" if multi else ""
    r, z, rz, rnorm = progs["pcg_init" + suffix](x, bd, arrs)
    p = z
    if multi:
        nb = _norms(b)
        res = [np.asarray(rnorm, dtype=np.float64)]
        for _ in range(maxiter):
            if (res[-1] / nb < tol).all():
                break
            x, r, p, rz, rnorm = progs["pcg_step_m"](x, r, p, rz, arrs)
            res.append(np.asarray(rnorm, dtype=np.float64))
        return _column_results(dh, x, res, nb, tol)
    nb = float(np.linalg.norm(b)) or 1.0
    res = [float(rnorm)]
    for it in range(maxiter):
        if res[-1] / nb < tol:
            return SolveResult(dh.gather(x), res, it, True)
        x, r, p, rz, rnorm = progs["pcg_step"](x, r, p, rz, arrs)
        res.append(float(rnorm))
    return SolveResult(dh.gather(x), res, maxiter, res[-1] / nb < tol)
