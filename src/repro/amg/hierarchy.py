"""AMG setup (Algorithm 1) for Ruge-Stüben and smoothed-aggregation solvers."""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from .csr import CSR
from .interpolation import (direct_interpolation, jacobi_smooth_prolongator,
                            tentative_prolongator)
from .splitting import mis2_aggregation, pmis
from .strength import classical_strength, symmetric_strength


@dataclasses.dataclass
class Level:
    A: CSR
    P: CSR | None = None        # to the NEXT (coarser) level
    R: CSR | None = None        # restriction = Pᵀ
    AP: CSR | None = None       # intermediate Galerkin product (Fig. 21 op)
    setup_seconds: float = 0.0


@dataclasses.dataclass
class Hierarchy:
    solver: str
    levels: list[Level]
    theta: float
    # per-hierarchy cache of lowered DistHierarchy objects, keyed by the
    # frozen build kwargs (see repro.amg.dist_solve._ensure_dist) — lives on
    # the hierarchy so its lifetime matches the operators it lowers
    dist_cache: dict = dataclasses.field(default_factory=dict, repr=False,
                                         compare=False)

    @property
    def n_levels(self) -> int:
        return len(self.levels)

    def grid_complexity(self) -> float:
        return sum(l.A.nrows for l in self.levels) / self.levels[0].A.nrows

    def operator_complexity(self) -> float:
        return sum(l.A.nnz for l in self.levels) / self.levels[0].A.nnz

    def summary(self) -> str:
        rows = [f"{self.solver} hierarchy: {self.n_levels} levels, "
                f"oc={self.operator_complexity():.2f} gc={self.grid_complexity():.2f}"]
        for i, l in enumerate(self.levels):
            rows.append(f"  L{i}: n={l.A.nrows:9d} nnz={l.A.nnz:11d} "
                        f"nnz/row={l.A.nnz / max(l.A.nrows, 1):6.1f}")
        return "\n".join(rows)


def setup(A: CSR, solver: str = "rs", theta: float = 0.25,
          max_coarse: int = 100, max_levels: int = 25,
          aggressive: bool = False, prolongation_sweeps: int = 1,
          seed: int = 42) -> Hierarchy:
    """Algorithm 1.  ``solver``: "rs" (Ruge-Stüben/HMIS-style) or
    "sa" (smoothed aggregation, MIS-2 aggregates)."""
    levels = [Level(A=A)]
    l = 0
    while levels[l].A.nrows > max_coarse and l + 1 < max_levels:
        t0 = time.perf_counter()
        Al = levels[l].A
        if solver == "rs":
            S = classical_strength(Al, theta)                    # strength
            status = pmis(S, seed=seed + l, aggressive=aggressive)  # splitting
            if (status == 1).sum() in (0, Al.nrows):
                break  # coarsening stalled
            P = direct_interpolation(Al, S, status)              # interpolation
        elif solver == "sa":
            S = symmetric_strength(Al, theta)
            agg = mis2_aggregation(S, seed=seed + l)             # splitting
            if int(agg.max()) + 1 >= Al.nrows:
                break
            T = tentative_prolongator(agg)                       # interpolation
            P = jacobi_smooth_prolongator(Al, T, sweeps=prolongation_sweeps)
        else:
            raise ValueError(f"unknown solver {solver!r}")
        R = P.T
        AP = Al.spgemm(P)                                        # Galerkin 1/2
        Ac = R.spgemm(AP)                                        # Galerkin 2/2
        Ac = Ac.prune(1e-14)
        levels[l].P, levels[l].R, levels[l].AP = P, R, AP
        levels[l].setup_seconds = time.perf_counter() - t0
        levels.append(Level(A=Ac))
        if Ac.nrows >= Al.nrows:  # no progress
            levels.pop()
            break
        l += 1
    return Hierarchy(solver=solver, levels=levels, theta=theta)
