"""AMG setup (Algorithm 1) for Ruge-Stüben and smoothed-aggregation solvers."""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from .csr import CSR
from .interpolation import (direct_interpolation, jacobi_smooth_prolongator,
                            tentative_prolongator)
from .splitting import mis2_aggregation, pmis
from .strength import classical_strength, symmetric_strength


@dataclasses.dataclass
class Level:
    A: CSR
    P: CSR | None = None        # to the NEXT (coarser) level
    R: CSR | None = None        # restriction = Pᵀ
    AP: CSR | None = None       # intermediate Galerkin product (Fig. 21 op)
    setup_seconds: float = 0.0
    # per-level smoother data extracted once and carried on the level
    # (block-Jacobi diagonal-block inverses, keyed by (kind, block_size,
    # parts)) — the setup-phase half of the block smoothers
    smoother_cache: dict = dataclasses.field(default_factory=dict,
                                             repr=False, compare=False)


@dataclasses.dataclass
class Hierarchy:
    solver: str
    levels: list[Level]
    theta: float
    # per-hierarchy cache of lowered DistHierarchy objects, keyed by the
    # frozen build kwargs (see repro.amg.dist_solve._ensure_dist) — lives on
    # the hierarchy so its lifetime matches the operators it lowers
    dist_cache: dict = dataclasses.field(default_factory=dict, repr=False,
                                         compare=False)

    @property
    def n_levels(self) -> int:
        return len(self.levels)

    def grid_complexity(self) -> float:
        return sum(l.A.nrows for l in self.levels) / self.levels[0].A.nrows

    def operator_complexity(self) -> float:
        return sum(l.A.nnz for l in self.levels) / self.levels[0].A.nnz

    def summary(self) -> str:
        rows = [f"{self.solver} hierarchy: {self.n_levels} levels, "
                f"oc={self.operator_complexity():.2f} gc={self.grid_complexity():.2f}"]
        for i, l in enumerate(self.levels):
            rows.append(f"  L{i}: n={l.A.nrows:9d} nnz={l.A.nnz:11d} "
                        f"nnz/row={l.A.nnz / max(l.A.nrows, 1):6.1f}")
        return "\n".join(rows)


# --------------------------------------------------------------------------
# Setup stages (Algorithm 1, one function per stage)
#
# Each stage is callable on its own so a distributed setup can run it
# per-partition: strength is row-local (a row's pattern depends only on that
# row, so it is exact on a partitioned row block); splitting and
# interpolation need off-process values, which :mod:`repro.amg.dist_setup`
# supplies through halo exchanges while calling the same underlying kernels.
# --------------------------------------------------------------------------


def strength_stage(A: CSR, solver: str = "rs", theta: float = 0.25) -> CSR:
    """Strength-of-connection.  Row-local: exact on a partitioned row block."""
    if solver == "rs":
        return classical_strength(A, theta)
    if solver == "sa":
        return symmetric_strength(A, theta)
    raise ValueError(f"unknown solver {solver!r}")


def splitting_stage(S: CSR, solver: str = "rs", seed: int = 42,
                    aggressive: bool = False) -> np.ndarray:
    """CF splitting (rs → PMIS status) or aggregation (sa → aggregate ids).

    Iterates on the global strength graph; the distributed setup re-runs the
    same PMIS iteration per-partition with halo exchanges of the status and
    weight vectors (:func:`repro.amg.dist_setup._dist_pmis`).
    """
    if solver == "rs":
        return pmis(S, seed=seed, aggressive=aggressive)
    if solver == "sa":
        return mis2_aggregation(S, seed=seed)
    raise ValueError(f"unknown solver {solver!r}")


def splitting_stalled(split: np.ndarray, nrows: int, solver: str = "rs") -> bool:
    """True when the splitting made no coarsening progress."""
    if solver == "rs":
        return int((split == 1).sum()) in (0, nrows)
    return int(split.max()) + 1 >= nrows


def interpolation_stage(A: CSR, S: CSR, split: np.ndarray, solver: str = "rs",
                        prolongation_sweeps: int = 1) -> CSR:
    """Build P from the splitting (direct interpolation / smoothed tentative)."""
    if solver == "rs":
        return direct_interpolation(A, S, split)
    if solver == "sa":
        T = tentative_prolongator(split)
        return jacobi_smooth_prolongator(A, T, sweeps=prolongation_sweeps)
    raise ValueError(f"unknown solver {solver!r}")


def coarsen_level(A: CSR, solver: str = "rs", theta: float = 0.25,
                  aggressive: bool = False, prolongation_sweeps: int = 1,
                  seed: int = 42) -> CSR | None:
    """strength → splitting → interpolation; ``None`` when coarsening stalls."""
    S = strength_stage(A, solver, theta)
    split = splitting_stage(S, solver, seed=seed, aggressive=aggressive)
    if splitting_stalled(split, A.nrows, solver):
        return None
    return interpolation_stage(A, S, split, solver, prolongation_sweeps)


def project_pattern_values(src: CSR, indptr: np.ndarray,
                           indices: np.ndarray, nrows: int,
                           ncols: int) -> np.ndarray:
    """Values of ``src`` gathered at a frozen CSR pattern's positions.

    Entries of the frozen pattern absent from ``src`` read as zero;
    entries of ``src`` outside the pattern are dropped — they are exactly
    the positions ``prune`` removed when the pattern froze, so a
    refreshed Galerkin product lands on the layouts every downstream
    plan/kernel was built for."""
    ncols = int(ncols)
    skey = src.rows_expanded().astype(np.int64) * ncols \
        + src.indices.astype(np.int64)
    order = np.argsort(skey, kind="stable")
    skey = skey[order]
    drows = np.repeat(np.arange(int(nrows), dtype=np.int64),
                      np.diff(indptr).astype(np.int64))
    dkey = drows * ncols + indices.astype(np.int64)
    pos = np.searchsorted(skey, dkey)
    pos_c = np.minimum(pos, max(skey.size - 1, 0))
    hit = skey[pos_c] == dkey if skey.size else np.zeros(dkey.shape, bool)
    vals = np.zeros(dkey.shape)
    vals[hit] = src.data[order][pos_c[hit]]
    return vals


def refresh_values(h: Hierarchy, A_new: CSR) -> None:
    """Value-only refresh: re-run the Galerkin products numerically onto
    the frozen level patterns, leaving every structure — splittings,
    interpolation operators, patterns, and the lowered ``dist_cache``
    hierarchies with their compiled programs — untouched.

    The caller is responsible for having checked that ``A_new`` shares
    the fine level's sparsity pattern (``pattern_fingerprint``)."""
    fine = h.levels[0].A
    if A_new.data.shape != fine.data.shape:
        raise ValueError(f"value refresh needs {fine.data.shape[0]} values, "
                         f"got {A_new.data.shape[0]}")
    # copy-on-write: the fine level usually aliases the caller's matrix
    # (setup never copies), so a refresh must re-point it rather than write
    # through the alias and silently mutate user-owned arrays
    h.levels[0].A = CSR(fine.shape, fine.indptr, fine.indices,
                        np.array(A_new.data, dtype=np.float64))
    for lv, nxt in zip(h.levels[:-1], h.levels[1:]):
        lv.smoother_cache.clear()
        AP = lv.A.spgemm(lv.P)               # P/R frozen: values and pattern
        Ac = lv.R.spgemm(AP)
        lv.AP.data[...] = project_pattern_values(
            AP, lv.AP.indptr, lv.AP.indices, lv.AP.nrows, lv.AP.ncols)
        nxt.A.data[...] = project_pattern_values(
            Ac, nxt.A.indptr, nxt.A.indices, nxt.A.nrows, nxt.A.ncols)
    h.levels[-1].smoother_cache.clear()
    for dh in h.dist_cache.values():
        dh.refresh_values(h.levels)


def setup(A: CSR, solver: str = "rs", theta: float = 0.25,
          max_coarse: int = 100, max_levels: int = 25,
          aggressive: bool = False, prolongation_sweeps: int = 1,
          seed: int = 42) -> Hierarchy:
    """Algorithm 1.  ``solver``: "rs" (Ruge-Stüben/HMIS-style) or
    "sa" (smoothed aggregation, MIS-2 aggregates)."""
    levels = [Level(A=A)]
    l = 0
    while levels[l].A.nrows > max_coarse and l + 1 < max_levels:
        t0 = time.perf_counter()
        Al = levels[l].A
        P = coarsen_level(Al, solver, theta, aggressive,
                          prolongation_sweeps, seed + l)
        if P is None:
            break  # coarsening stalled
        R = P.T
        AP = Al.spgemm(P)                                        # Galerkin 1/2
        Ac = R.spgemm(AP)                                        # Galerkin 2/2
        Ac = Ac.prune(1e-14)
        levels[l].P, levels[l].R, levels[l].AP = P, R, AP
        levels[l].setup_seconds = time.perf_counter() - t0
        levels.append(Level(A=Ac))
        if Ac.nrows >= Al.nrows:  # no progress
            levels.pop()
            break
        l += 1
    return Hierarchy(solver=solver, levels=levels, theta=theta)
