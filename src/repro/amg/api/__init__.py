"""Session + serving API: from one configurable solver session to an
admission-scheduled, wire-addressable solver service.

The expensive parts of a node-aware AMG solve — the host ``Hierarchy``
(setup phase), the lowered :class:`~repro.amg.dist_solve.DistHierarchy`
(comm graphs, per-level strategy selection, halo plans) and its compiled
shard_map programs — are built **once** per (matrix fingerprint, config)
and amortized over many solves, the way a parallel AMG code builds its MPI
communicators once (Bienz et al.'s communicator-reuse argument for
node-aware SpMV).  This package is that amortization made operational,
layered bottom-up:

* :mod:`~repro.amg.api.config` — frozen, hashable :class:`AMGConfig` plus
  the **versioned wire codec**: schema-tagged, unknown-key-rejecting
  payloads for configs, CSR matrices (registered by content fingerprint),
  solve requests and streaming ``A + ΔA`` update requests, so the service
  can be driven over a byte transport.
* :mod:`~repro.amg.api.registry` — :func:`register_backend`; ``"host"``
  (numpy reference) and ``"dist"`` (device-resident fused cycle) ship here
  and future backends plug in without touching call sites.
* :mod:`~repro.amg.api.sessions` — :class:`AMGSolver` /
  :class:`BoundSolver` over an instantiable :class:`SessionStore` with
  pluggable eviction (:class:`LRUPolicy`, :class:`TTLPolicy`, cost-aware
  :class:`BytesBudgetPolicy`) and per-entry setup-cost / hit accounting.
* :mod:`~repro.amg.api.service` — :class:`AMGService`, the serving
  surface: ticketed async admission (``submit() -> Ticket``), cross-burst
  multi-RHS coalescing windows, per-request :class:`RequestOptions`,
  priority classes with starvation-free aging, streaming
  :meth:`~AMGService.update` routing under stable matrix ids, and a
  :class:`ServiceReport` of per-request diagnostics + store counters.

Surface::

    cfg = AMGConfig(solver="rs", backend="dist", n_pods=2, lanes=4)
    bound = AMGSolver(cfg).setup(A)      # cached per (matrix, config)
    res = bound.solve(b)                 # b: [n] or [n, k] (multi-RHS)
    bound.update(A_drifted)              # value-only hierarchy refresh

    svc = AMGService(cfg, coalesce_window=0.05)
    mid = svc.register_wire(csr_to_wire(A))      # by fingerprint
    with svc:                                    # admission worker
        t = svc.submit(mid, b, method="pcg", priority="interactive")
        x = t.result()
    print(svc.report().summary())

The cycle shape and smoother live in ``config.opts``
(:class:`~repro.amg.solve.SolveOptions`) — they are *solve* knobs, so two
configs that differ only there share one hierarchy and one dist lowering.
"""
from .config import (AMGConfig, PatternMismatch, RefreshPolicy,
                     RequestOptions, SUPPORTED_SCHEMAS, WIRE_SCHEMA,
                     WireError, apply_update, array_from_wire, array_to_wire,
                     csr_from_wire, csr_to_wire, matrix_fingerprint,
                     pattern_fingerprint, solve_request_from_wire,
                     solve_request_to_wire, update_request_from_wire,
                     update_request_to_wire)
from .registry import (available_backends, backend_class, bind_hierarchy,
                       register_backend)
from .sessions import (AMGSolver, BoundSolver, BytesBudgetPolicy, CacheEntry,
                       DistBoundSolver, EvictionPolicy, HostBoundSolver,
                       LRUPolicy, SESSION_CACHE_SIZE, SessionStore, TTLPolicy,
                       clear_sessions, session_count, session_nbytes)
from .service import (AMGService, PRIORITY_CLASSES, ServiceClosed,
                      ServiceReport, Ticket)

__all__ = [
    "AMGConfig", "AMGService", "AMGSolver", "BoundSolver",
    "BytesBudgetPolicy", "CacheEntry", "DistBoundSolver", "EvictionPolicy",
    "HostBoundSolver", "LRUPolicy", "PRIORITY_CLASSES", "PatternMismatch",
    "RefreshPolicy", "RequestOptions", "SESSION_CACHE_SIZE",
    "SUPPORTED_SCHEMAS", "ServiceClosed", "ServiceReport", "SessionStore",
    "TTLPolicy", "Ticket", "WIRE_SCHEMA", "WireError", "apply_update",
    "array_from_wire", "array_to_wire", "available_backends",
    "backend_class", "bind_hierarchy", "clear_sessions", "csr_from_wire",
    "csr_to_wire", "matrix_fingerprint", "pattern_fingerprint",
    "register_backend", "session_count", "session_nbytes",
    "solve_request_from_wire", "solve_request_to_wire",
    "update_request_from_wire", "update_request_to_wire",
]
