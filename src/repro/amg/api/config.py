"""Solver-session configuration and the versioned wire codec.

:class:`AMGConfig` is the frozen, hashable description of a full solver
session (setup knobs, solve options, backend/mesh/strategy/kernel knobs) —
hashability is what makes it a cache key for the session store.

The **wire codec** makes the whole serving surface addressable over a
byte-oriented transport: every payload is a plain JSON-serializable dict
tagged with a ``schema`` version and a ``kind``.  Decoders are strict —
a missing/mismatched schema version or any key the decoder does not know
raises :class:`WireError` (corrupt or future-versioned payloads fail loudly
instead of being half-applied):

* ``AMGConfig.to_wire()`` / ``AMGConfig.from_wire()`` — config round-trip.
* :func:`csr_to_wire` / :func:`csr_from_wire` — CSR matrix payloads
  (base64-encoded little-endian arrays) carrying the content
  :func:`matrix_fingerprint`, so a matrix can be registered *by fingerprint*
  and later requests can address it by that id; decode re-verifies the
  fingerprint as an integrity check.
* :func:`solve_request_to_wire` / :func:`solve_request_from_wire` — one
  solve admission (``b`` payload of shape ``[n]`` or ``[n, k]``, per-request
  ``tol``/``maxiter``/``x0``/``priority``), consumed by
  :meth:`~repro.amg.api.service.AMGService.submit_wire`.
"""
from __future__ import annotations

import base64
import dataclasses
import hashlib

import numpy as np

from ..csr import CSR
from ..solve import SolveOptions

_DTYPES = ("float32", "float64", "bfloat16")

WIRE_SCHEMA = 1


class WireError(ValueError):
    """A wire payload failed to decode (bad schema version, unknown key,
    wrong kind, or a corrupt/fingerprint-mismatched body)."""


# --------------------------------------------------------------------------
# Configuration
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AMGConfig:
    """Frozen, hashable description of a full solver session: setup knobs,
    smoother options, iteration defaults, and backend/mesh/strategy/kernel
    knobs.  Hashability is what makes it a cache key — two configs that
    compare equal always produce interchangeable solvers."""

    # -- setup phase (Algorithm 1)
    solver: str = "rs"                   # "rs" | "sa"
    theta: float = 0.25
    max_coarse: int = 100
    max_levels: int = 25
    aggressive: bool = False
    prolongation_sweeps: int = 1
    seed: int = 42
    # "host": serial numpy setup; "dist": the partitioned node-aware setup
    # (repro.amg.dist_setup) — levels are born partitioned and only the
    # "dist" solve backend can consume them
    setup_backend: str = "host"
    # -- solve phase (Algorithm 2): cycle shape, smoother, sweep counts
    # (pure solve knobs — sessions differing only here share setup+lowering)
    opts: SolveOptions = dataclasses.field(default_factory=SolveOptions)
    tol: float = 1e-8
    maxiter: int = 100
    pcg_maxiter: int = 200
    # -- backend + mesh + strategy + kernel knobs
    backend: str = "host"                # registry name: "host" | "dist" | …
    n_pods: int = 1
    lanes: int = 1
    strategy: str = "auto"               # "auto" | "standard" | "nap2" | "nap3"
    machine: str = "tpu_v5e"             # repro.core.MACHINES name
    dtype: str = "float32"
    use_kernel: bool | None = None       # None = auto (Pallas ELL on TPU)
    interpret: bool | None = None        # None = auto (interpret off-TPU)
    reduce_strategy: str = "nap3"        # norms/dots: "nap3" | "flat"
    # halo-exchange/compute overlap in every distributed apply; False keeps
    # the serial fused form (the parity oracle)
    overlap: bool = True

    def __post_init__(self):
        if self.dtype not in _DTYPES:
            raise ValueError(f"dtype must be one of {_DTYPES}, "
                             f"got {self.dtype!r}")
        if self.setup_backend not in ("host", "dist"):
            raise ValueError(f"setup_backend must be 'host' or 'dist', "
                             f"got {self.setup_backend!r}")
        if self.setup_backend == "dist" and self.backend != "dist":
            raise ValueError(
                "setup_backend='dist' births partitioned levels that only "
                f"backend='dist' can consume (got backend={self.backend!r})")
        if self.setup_backend == "dist" and self.solver != "rs":
            raise ValueError(
                "setup_backend='dist' supports solver='rs' only "
                f"(got solver={self.solver!r})")
        from ...core import MACHINES
        if self.machine not in MACHINES:
            raise ValueError(f"unknown machine {self.machine!r}; "
                             f"known: {sorted(MACHINES)}")

    def replace(self, **changes) -> "AMGConfig":
        return dataclasses.replace(self, **changes)

    # ------------------------------------------------------------ round-trip
    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)       # recurses into opts
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "AMGConfig":
        d = dict(d)
        opts = d.pop("opts", None)
        if isinstance(opts, dict):
            opts = SolveOptions(**opts)
        return cls(opts=opts or SolveOptions(), **d)

    # ------------------------------------------------------------------ wire
    def to_wire(self) -> dict:
        """JSON-serializable wire payload (``schema`` + ``kind`` tagged)."""
        return {"schema": WIRE_SCHEMA, "kind": "amg_config", **self.to_dict()}

    @classmethod
    def from_wire(cls, payload: dict) -> "AMGConfig":
        """Strict decode: wrong schema version, wrong ``kind`` or ANY key
        not named by a config / :class:`SolveOptions` field raises
        :class:`WireError`."""
        body = _check_envelope(payload, "amg_config")
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(body) - known
        if unknown:
            raise WireError(f"amg_config payload has unknown key(s) "
                            f"{sorted(unknown)}; known: {sorted(known)}")
        opts = body.get("opts")
        if opts is not None:
            if not isinstance(opts, dict):
                raise WireError(f"amg_config opts must be a dict of "
                                f"SolveOptions fields, got {type(opts)}")
            oknown = {f.name for f in dataclasses.fields(SolveOptions)}
            ounknown = set(opts) - oknown
            if ounknown:
                raise WireError(f"amg_config opts has unknown key(s) "
                                f"{sorted(ounknown)}; known: {sorted(oknown)}")
        try:
            return cls.from_dict(body)
        except (TypeError, ValueError) as e:
            raise WireError(f"amg_config payload rejected: {e}") from e

    # ------------------------------------------------------- derived kwargs
    def setup_kwargs(self) -> dict:
        return dict(solver=self.solver, theta=self.theta,
                    max_coarse=self.max_coarse, max_levels=self.max_levels,
                    aggressive=self.aggressive,
                    prolongation_sweeps=self.prolongation_sweeps,
                    seed=self.seed)

    def dist_build_kwargs(self) -> dict:
        """Kwargs for ``DistHierarchy.build`` (resolves machine + dtype)."""
        import jax.numpy as jnp

        from ...core import MACHINES
        dtype = {"float32": jnp.float32, "float64": jnp.float64,
                 "bfloat16": jnp.bfloat16}[self.dtype]
        return dict(n_pods=self.n_pods, lanes=self.lanes,
                    params=MACHINES[self.machine], strategy=self.strategy,
                    dtype=dtype, use_kernel=self.use_kernel,
                    interpret=self.interpret,
                    reduce_strategy=self.reduce_strategy,
                    overlap=self.overlap)


def matrix_fingerprint(A: CSR) -> str:
    """Content hash of a CSR matrix — the matrix half of the session key,
    and the wire-level matrix id (:func:`csr_to_wire` registration)."""
    h = hashlib.sha1()
    h.update(np.asarray(A.shape, dtype=np.int64).tobytes())
    h.update(np.ascontiguousarray(A.indptr).tobytes())
    h.update(np.ascontiguousarray(A.indices).tobytes())
    h.update(np.ascontiguousarray(A.data).tobytes())
    return h.hexdigest()


# --------------------------------------------------------------------------
# Wire primitives
# --------------------------------------------------------------------------


def _check_envelope(payload, kind: str) -> dict:
    """Validate the ``schema``/``kind`` envelope; return the body (a copy
    of the payload without the envelope keys)."""
    if not isinstance(payload, dict):
        raise WireError(f"wire payload must be a dict, got {type(payload)}")
    schema = payload.get("schema")
    if schema != WIRE_SCHEMA:
        raise WireError(f"wire schema version mismatch: payload has "
                        f"{schema!r}, this codec speaks {WIRE_SCHEMA}")
    got = payload.get("kind")
    if got != kind:
        raise WireError(f"expected a {kind!r} payload, got kind={got!r}")
    body = dict(payload)
    body.pop("schema")
    body.pop("kind")
    return body


# arrays travel as little-endian raw bytes, base64'd for JSON transport
_WIRE_DTYPES = {"int64": "<i8", "float64": "<f8", "float32": "<f4"}


def array_to_wire(a: np.ndarray, dtype: str | None = None) -> dict:
    """Encode an array as ``{dtype, shape, data}`` (base64, little-endian).
    ``dtype`` re-types on the way out (e.g. fp32 payloads for fp64 data —
    half the bytes, the receiver sees the rounded values)."""
    a = np.ascontiguousarray(a)
    name = dtype or str(a.dtype)
    if name not in _WIRE_DTYPES:
        raise WireError(f"unsupported wire array dtype {name!r}; "
                        f"supported: {sorted(_WIRE_DTYPES)}")
    raw = a.astype(_WIRE_DTYPES[name]).tobytes()
    return {"dtype": name, "shape": list(a.shape),
            "data": base64.b64encode(raw).decode("ascii")}


def array_from_wire(d: dict) -> np.ndarray:
    unknown = set(d) - {"dtype", "shape", "data"}
    if unknown:
        raise WireError(f"array payload has unknown key(s) {sorted(unknown)}")
    try:
        wire_dtype = _WIRE_DTYPES[d["dtype"]]
    except KeyError:
        raise WireError(f"unsupported wire array dtype {d.get('dtype')!r}; "
                        f"supported: {sorted(_WIRE_DTYPES)}") from None
    try:
        raw = base64.b64decode(d["data"], validate=True)
        a = np.frombuffer(raw, dtype=wire_dtype)
        return a.reshape(d["shape"]).astype(d["dtype"])
    except (KeyError, ValueError, TypeError) as e:
        raise WireError(f"corrupt array payload: {e}") from e


def csr_to_wire(A: CSR, dtype: str = "float64") -> dict:
    """Encode a CSR matrix for registration over the wire.

    ``dtype`` controls the value payload ("float32" halves it; index arrays
    stay int64).  The embedded ``fingerprint`` is computed over the matrix
    **as the receiver will decode it** (i.e. after any value rounding), so
    :func:`csr_from_wire` can verify integrity and the sender knows the id
    the matrix will be registered under."""
    data = A.data if dtype == "float64" else \
        A.data.astype(dtype).astype(np.float64)
    decoded = CSR(A.shape, np.ascontiguousarray(A.indptr),
                  np.ascontiguousarray(A.indices), data)
    return {"schema": WIRE_SCHEMA, "kind": "csr",
            "shape": [int(A.nrows), int(A.ncols)],
            "indptr": array_to_wire(A.indptr, "int64"),
            "indices": array_to_wire(A.indices, "int64"),
            "data": array_to_wire(A.data, dtype),
            "fingerprint": matrix_fingerprint(decoded)}


def csr_from_wire(payload: dict) -> tuple[CSR, str]:
    """Decode a CSR payload; returns ``(matrix, fingerprint)``.

    The fingerprint is recomputed from the decoded arrays and checked
    against the payload's claim — a mismatch means transport corruption."""
    body = _check_envelope(payload, "csr")
    unknown = set(body) - {"shape", "indptr", "indices", "data",
                           "fingerprint"}
    if unknown:
        raise WireError(f"csr payload has unknown key(s) {sorted(unknown)}")
    try:
        shape = (int(body["shape"][0]), int(body["shape"][1]))
        A = CSR(shape=shape,
                indptr=array_from_wire(body["indptr"]),
                indices=array_from_wire(body["indices"]),
                data=array_from_wire(body["data"]).astype(np.float64))
    except (KeyError, IndexError, TypeError, ValueError) as e:
        raise WireError(f"corrupt csr payload: {e}") from e
    if A.indptr.shape != (shape[0] + 1,) or A.indices.shape != A.data.shape:
        raise WireError(f"inconsistent csr payload: indptr {A.indptr.shape} "
                        f"for {shape[0]} rows, indices {A.indices.shape} vs "
                        f"data {A.data.shape}")
    fp = matrix_fingerprint(A)
    claimed = body.get("fingerprint")
    if claimed is not None and claimed != fp:
        raise WireError(f"csr payload fingerprint mismatch: payload claims "
                        f"{claimed}, decoded content hashes to {fp}")
    return A, fp


_REQUEST_KEYS = {"matrix", "b", "method", "tol", "maxiter", "x0", "priority",
                 "rid"}


def solve_request_to_wire(matrix_id: str, b: np.ndarray, *,
                          method: str = "solve", tol: float | None = None,
                          maxiter: int | None = None,
                          x0: np.ndarray | None = None,
                          priority=None, rid: int | None = None) -> dict:
    """Encode one solve admission (``b``: [n] or [n, k]) for
    :meth:`~repro.amg.api.service.AMGService.submit_wire`."""
    d = {"schema": WIRE_SCHEMA, "kind": "solve_request",
         "matrix": matrix_id, "b": array_to_wire(np.asarray(b)),
         "method": method}
    if tol is not None:
        d["tol"] = float(tol)
    if maxiter is not None:
        d["maxiter"] = int(maxiter)
    if x0 is not None:
        d["x0"] = array_to_wire(np.asarray(x0))
    if priority is not None:
        d["priority"] = priority
    if rid is not None:
        d["rid"] = int(rid)
    return d


def solve_request_from_wire(payload: dict) -> dict:
    """Strict decode of a solve request; returns kwargs for
    :meth:`AMGService.submit` (arrays materialized, unknown keys rejected)."""
    body = _check_envelope(payload, "solve_request")
    unknown = set(body) - _REQUEST_KEYS
    if unknown:
        raise WireError(f"solve_request payload has unknown key(s) "
                        f"{sorted(unknown)}; known: {sorted(_REQUEST_KEYS)}")
    try:
        out = {"matrix_id": body["matrix"],
               "b": array_from_wire(body["b"]),
               "method": body.get("method", "solve")}
    except KeyError as e:
        raise WireError(f"solve_request payload missing {e.args[0]!r}") \
            from None
    if "tol" in body:
        out["tol"] = float(body["tol"])
    if "maxiter" in body:
        out["maxiter"] = int(body["maxiter"])
    if "x0" in body:
        out["x0"] = array_from_wire(body["x0"])
    if "priority" in body:
        out["priority"] = body["priority"]
    if "rid" in body:
        out["rid"] = int(body["rid"])
    return out
