"""Solver-session configuration and the versioned wire codec.

:class:`AMGConfig` is the frozen, hashable description of a full solver
session (setup knobs, solve options, backend/mesh/strategy/kernel knobs) —
hashability is what makes it a cache key for the session store.

The **wire codec** makes the whole serving surface addressable over a
byte-oriented transport: every payload is a plain JSON-serializable dict
tagged with a ``schema`` version and a ``kind``.  Decoders are strict —
a missing/mismatched schema version or any key the decoder does not know
raises :class:`WireError` (corrupt or future-versioned payloads fail loudly
instead of being half-applied):

* ``AMGConfig.to_wire()`` / ``AMGConfig.from_wire()`` — config round-trip.
* :func:`csr_to_wire` / :func:`csr_from_wire` — CSR matrix payloads
  (base64-encoded little-endian arrays) carrying the content
  :func:`matrix_fingerprint`, so a matrix can be registered *by fingerprint*
  and later requests can address it by that id; decode re-verifies the
  fingerprint as an integrity check.
* :func:`solve_request_to_wire` / :func:`solve_request_from_wire` — one
  solve admission (``b`` payload of shape ``[n]`` or ``[n, k]``, per-request
  :class:`RequestOptions` + ``priority``), consumed by
  :meth:`~repro.amg.api.service.AMGService.submit_wire`.
* :func:`update_request_to_wire` / :func:`update_request_from_wire` —
  schema-v2 streaming update: a full replacement CSR, a values-only
  payload, or an additive ``ΔA`` on the registered matrix's frozen
  sparsity pattern, addressed by registered fingerprint.

**Versioning.**  ``WIRE_SCHEMA`` is what this codec *emits*;
``SUPPORTED_SCHEMAS`` is what it *accepts*.  v1 frames still decode —
the v2 additions are purely additive (the ``update`` kind and the nested
``options`` key on solve requests).  A v1-tagged frame carrying a
v2-only key is rejected under strict decode (the default) and tolerated
under ``strict=False`` (a permissive proxy in front of an old client).
"""
from __future__ import annotations

import base64
import dataclasses
import hashlib

import numpy as np

from ..csr import CSR
from ..solve import SolveOptions

_DTYPES = ("float32", "float64", "bfloat16")

#: Schema version this codec emits.
WIRE_SCHEMA = 2
#: Schema versions this codec accepts (v1 frames are a strict subset).
SUPPORTED_SCHEMAS = (1, 2)


class WireError(ValueError):
    """A wire payload failed to decode (bad schema version, unknown key,
    wrong kind, or a corrupt/fingerprint-mismatched body)."""


class PatternMismatch(ValueError):
    """A streaming update's sparsity pattern does not match the session's
    frozen pattern — a value-only refresh is impossible.  Raised instead
    of silently re-running setup; callers escalate explicitly."""


# --------------------------------------------------------------------------
# Configuration
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RefreshPolicy:
    """When does a streamed value update escalate to a full re-setup?

    A session tracks each solve's iteration count against the *baseline*
    (the first solve after the most recent setup or re-setup).  A
    value-only refresh keeps the frozen hierarchy; once convergence has
    regressed past ``regress_ratio × baseline + regress_slack``
    iterations, the next update triggers a full node-aware re-setup
    instead (pattern changes always do)."""

    regress_ratio: float = 1.5
    regress_slack: int = 2

    def __post_init__(self):
        if self.regress_ratio < 1.0:
            raise ValueError(f"regress_ratio must be >= 1, "
                             f"got {self.regress_ratio}")
        if self.regress_slack < 0:
            raise ValueError(f"regress_slack must be >= 0, "
                             f"got {self.regress_slack}")

    def regressed(self, baseline: int | None, iterations: int) -> bool:
        """Has ``iterations`` regressed past the post-setup baseline?"""
        if baseline is None:
            return False
        return iterations > self.regress_ratio * baseline + self.regress_slack


@dataclasses.dataclass(frozen=True, eq=False)
class RequestOptions:
    """Per-request solve knobs, unified across the three call surfaces
    (:meth:`AMGService.submit`, wire solve requests, and the
    ``solve``/``pcg`` free functions).

    ``tol``/``maxiter`` default to ``None`` = "use the session config's
    default" — :meth:`resolve` pins them so equal resolved options mean
    interchangeable requests.  ``x0`` is a warm start and deliberately
    **not** part of :meth:`group_key` (requests with different warm
    starts still coalesce into one multi-RHS batch)."""

    method: str = "solve"
    tol: float | None = None
    maxiter: int | None = None
    x0: np.ndarray | None = None

    def __post_init__(self):
        if self.method not in ("solve", "pcg"):
            raise ValueError(f"unknown method {self.method!r}; "
                             f"must be 'solve' or 'pcg'")

    def resolve(self, config: "AMGConfig") -> "RequestOptions":
        """Pin ``tol``/``maxiter`` from the session config's defaults."""
        tol = config.tol if self.tol is None else float(self.tol)
        maxiter = self.maxiter
        if maxiter is None:
            maxiter = (config.pcg_maxiter if self.method == "pcg"
                       else config.maxiter)
        return dataclasses.replace(self, tol=tol, maxiter=int(maxiter))

    def group_key(self) -> tuple:
        """The coalescing key: requests with equal keys may batch into one
        multi-RHS solve (the warm start rides per-request, not per-key)."""
        return (self.method, self.tol, self.maxiter)

    def to_wire_fields(self) -> dict:
        """The request-payload fields this carries (flat, v1-compatible;
        absent fields mean "config default")."""
        d: dict = {"method": self.method}
        if self.tol is not None:
            d["tol"] = float(self.tol)
        if self.maxiter is not None:
            d["maxiter"] = int(self.maxiter)
        if self.x0 is not None:
            d["x0"] = array_to_wire(np.asarray(self.x0))
        return d


@dataclasses.dataclass(frozen=True)
class AMGConfig:
    """Frozen, hashable description of a full solver session: setup knobs,
    smoother options, iteration defaults, and backend/mesh/strategy/kernel
    knobs.  Hashability is what makes it a cache key — two configs that
    compare equal always produce interchangeable solvers."""

    # -- setup phase (Algorithm 1)
    solver: str = "rs"                   # "rs" | "sa"
    theta: float = 0.25
    max_coarse: int = 100
    max_levels: int = 25
    aggressive: bool = False
    prolongation_sweeps: int = 1
    seed: int = 42
    # "host": serial numpy setup; "dist": the partitioned node-aware setup
    # (repro.amg.dist_setup) — levels are born partitioned and only the
    # "dist" solve backend can consume them
    setup_backend: str = "host"
    # -- solve phase (Algorithm 2): cycle shape, smoother, sweep counts
    # (pure solve knobs — sessions differing only here share setup+lowering)
    opts: SolveOptions = dataclasses.field(default_factory=SolveOptions)
    tol: float = 1e-8
    maxiter: int = 100
    pcg_maxiter: int = 200
    # -- backend + mesh + strategy + kernel knobs
    backend: str = "host"                # registry name: "host" | "dist" | …
    n_pods: int = 1
    lanes: int = 1
    strategy: str = "auto"               # "auto" | "standard" | "nap2" | "nap3"
    machine: str = "tpu_v5e"             # repro.core.MACHINES name
    dtype: str = "float32"
    use_kernel: bool | None = None       # None = auto (Pallas ELL on TPU)
    interpret: bool | None = None        # None = auto (interpret off-TPU)
    reduce_strategy: str = "nap3"        # norms/dots: "nap3" | "flat"
    # halo-exchange/compute overlap in every distributed apply; False keeps
    # the serial fused form (the parity oracle)
    overlap: bool = True
    # streaming sessions: when does an A + ΔA update escalate from a
    # value-only refresh to a full node-aware re-setup
    refresh: RefreshPolicy = dataclasses.field(default_factory=RefreshPolicy)

    def __post_init__(self):
        if self.dtype not in _DTYPES:
            raise ValueError(f"dtype must be one of {_DTYPES}, "
                             f"got {self.dtype!r}")
        if self.setup_backend not in ("host", "dist"):
            raise ValueError(f"setup_backend must be 'host' or 'dist', "
                             f"got {self.setup_backend!r}")
        if self.setup_backend == "dist" and self.backend != "dist":
            raise ValueError(
                "setup_backend='dist' births partitioned levels that only "
                f"backend='dist' can consume (got backend={self.backend!r})")
        if self.setup_backend == "dist" and self.solver != "rs":
            raise ValueError(
                "setup_backend='dist' supports solver='rs' only "
                f"(got solver={self.solver!r})")
        from ...core import MACHINES
        if self.machine not in MACHINES:
            raise ValueError(f"unknown machine {self.machine!r}; "
                             f"known: {sorted(MACHINES)}")

    def replace(self, **changes) -> "AMGConfig":
        return dataclasses.replace(self, **changes)

    # ------------------------------------------------------------ round-trip
    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)       # recurses into opts
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "AMGConfig":
        d = dict(d)
        opts = d.pop("opts", None)
        if isinstance(opts, dict):
            opts = SolveOptions(**opts)
        refresh = d.pop("refresh", None)
        if isinstance(refresh, dict):
            refresh = RefreshPolicy(**refresh)
        return cls(opts=opts or SolveOptions(),
                   refresh=refresh or RefreshPolicy(), **d)

    # ------------------------------------------------------------------ wire
    def to_wire(self) -> dict:
        """JSON-serializable wire payload (``schema`` + ``kind`` tagged)."""
        return {"schema": WIRE_SCHEMA, "kind": "amg_config", **self.to_dict()}

    @classmethod
    def from_wire(cls, payload: dict) -> "AMGConfig":
        """Strict decode: wrong schema version, wrong ``kind`` or ANY key
        not named by a config / :class:`SolveOptions` field raises
        :class:`WireError`."""
        body = _check_envelope(payload, "amg_config")
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(body) - known
        if unknown:
            raise WireError(f"amg_config payload has unknown key(s) "
                            f"{sorted(unknown)}; known: {sorted(known)}")
        for key, klass in (("opts", SolveOptions), ("refresh", RefreshPolicy)):
            nested = body.get(key)
            if nested is None:
                continue
            if not isinstance(nested, dict):
                raise WireError(f"amg_config {key} must be a dict of "
                                f"{klass.__name__} fields, got {type(nested)}")
            nknown = {f.name for f in dataclasses.fields(klass)}
            nunknown = set(nested) - nknown
            if nunknown:
                raise WireError(f"amg_config {key} has unknown key(s) "
                                f"{sorted(nunknown)}; known: {sorted(nknown)}")
        try:
            return cls.from_dict(body)
        except (TypeError, ValueError) as e:
            raise WireError(f"amg_config payload rejected: {e}") from e

    # ------------------------------------------------------- derived kwargs
    def setup_kwargs(self) -> dict:
        return dict(solver=self.solver, theta=self.theta,
                    max_coarse=self.max_coarse, max_levels=self.max_levels,
                    aggressive=self.aggressive,
                    prolongation_sweeps=self.prolongation_sweeps,
                    seed=self.seed)

    def dist_build_kwargs(self) -> dict:
        """Kwargs for ``DistHierarchy.build`` (resolves machine + dtype)."""
        import jax.numpy as jnp

        from ...core import MACHINES
        dtype = {"float32": jnp.float32, "float64": jnp.float64,
                 "bfloat16": jnp.bfloat16}[self.dtype]
        return dict(n_pods=self.n_pods, lanes=self.lanes,
                    params=MACHINES[self.machine], strategy=self.strategy,
                    dtype=dtype, use_kernel=self.use_kernel,
                    interpret=self.interpret,
                    reduce_strategy=self.reduce_strategy,
                    overlap=self.overlap)


def matrix_fingerprint(A: CSR) -> str:
    """Content hash of a CSR matrix — the matrix half of the session key,
    and the wire-level matrix id (:func:`csr_to_wire` registration)."""
    h = hashlib.sha1()
    h.update(np.asarray(A.shape, dtype=np.int64).tobytes())
    h.update(np.ascontiguousarray(A.indptr).tobytes())
    h.update(np.ascontiguousarray(A.indices).tobytes())
    h.update(np.ascontiguousarray(A.data).tobytes())
    return h.hexdigest()


def pattern_fingerprint(A: CSR) -> str:
    """Hash of the sparsity pattern only (shape + indptr + indices, no
    values) — the streaming-session invariant: two matrices with equal
    pattern fingerprints share every comm graph, halo plan, ELL layout
    and compiled program, so updates between them are value-only."""
    h = hashlib.sha1()
    h.update(np.asarray(A.shape, dtype=np.int64).tobytes())
    h.update(np.ascontiguousarray(A.indptr).tobytes())
    h.update(np.ascontiguousarray(A.indices).tobytes())
    return h.hexdigest()


# --------------------------------------------------------------------------
# Wire primitives
# --------------------------------------------------------------------------


def _check_envelope(payload, kind: str, *, min_schema: int = 1) -> dict:
    """Validate the ``schema``/``kind`` envelope; return the body (a copy
    of the payload without the envelope keys).  Any schema version in
    :data:`SUPPORTED_SCHEMAS` is accepted; ``min_schema`` floors kinds
    that did not exist before a given version (e.g. v2 ``update``)."""
    if not isinstance(payload, dict):
        raise WireError(f"wire payload must be a dict, got {type(payload)}")
    schema = payload.get("schema")
    if schema not in SUPPORTED_SCHEMAS:
        raise WireError(f"wire schema version mismatch: payload has "
                        f"{schema!r}, this codec speaks "
                        f"{list(SUPPORTED_SCHEMAS)}")
    if schema < min_schema:
        raise WireError(f"{kind!r} payloads require schema >= {min_schema}, "
                        f"got {schema}")
    got = payload.get("kind")
    if got != kind:
        raise WireError(f"expected a {kind!r} payload, got kind={got!r}")
    body = dict(payload)
    body.pop("schema")
    body.pop("kind")
    return body


# arrays travel as little-endian raw bytes, base64'd for JSON transport
_WIRE_DTYPES = {"int64": "<i8", "float64": "<f8", "float32": "<f4"}


def array_to_wire(a: np.ndarray, dtype: str | None = None) -> dict:
    """Encode an array as ``{dtype, shape, data}`` (base64, little-endian).
    ``dtype`` re-types on the way out (e.g. fp32 payloads for fp64 data —
    half the bytes, the receiver sees the rounded values)."""
    a = np.ascontiguousarray(a)
    name = dtype or str(a.dtype)
    if name not in _WIRE_DTYPES:
        raise WireError(f"unsupported wire array dtype {name!r}; "
                        f"supported: {sorted(_WIRE_DTYPES)}")
    raw = a.astype(_WIRE_DTYPES[name]).tobytes()
    return {"dtype": name, "shape": list(a.shape),
            "data": base64.b64encode(raw).decode("ascii")}


def array_from_wire(d: dict) -> np.ndarray:
    unknown = set(d) - {"dtype", "shape", "data"}
    if unknown:
        raise WireError(f"array payload has unknown key(s) {sorted(unknown)}")
    try:
        wire_dtype = _WIRE_DTYPES[d["dtype"]]
    except KeyError:
        raise WireError(f"unsupported wire array dtype {d.get('dtype')!r}; "
                        f"supported: {sorted(_WIRE_DTYPES)}") from None
    try:
        raw = base64.b64decode(d["data"], validate=True)
        a = np.frombuffer(raw, dtype=wire_dtype)
        return a.reshape(d["shape"]).astype(d["dtype"])
    except (KeyError, ValueError, TypeError) as e:
        raise WireError(f"corrupt array payload: {e}") from e


def csr_to_wire(A: CSR, dtype: str = "float64") -> dict:
    """Encode a CSR matrix for registration over the wire.

    ``dtype`` controls the value payload ("float32" halves it; index arrays
    stay int64).  The embedded ``fingerprint`` is computed over the matrix
    **as the receiver will decode it** (i.e. after any value rounding), so
    :func:`csr_from_wire` can verify integrity and the sender knows the id
    the matrix will be registered under."""
    data = A.data if dtype == "float64" else \
        A.data.astype(dtype).astype(np.float64)
    decoded = CSR(A.shape, np.ascontiguousarray(A.indptr),
                  np.ascontiguousarray(A.indices), data)
    return {"schema": WIRE_SCHEMA, "kind": "csr",
            "shape": [int(A.nrows), int(A.ncols)],
            "indptr": array_to_wire(A.indptr, "int64"),
            "indices": array_to_wire(A.indices, "int64"),
            "data": array_to_wire(A.data, dtype),
            "fingerprint": matrix_fingerprint(decoded)}


def csr_from_wire(payload: dict) -> tuple[CSR, str]:
    """Decode a CSR payload; returns ``(matrix, fingerprint)``.

    The fingerprint is recomputed from the decoded arrays and checked
    against the payload's claim — a mismatch means transport corruption."""
    body = _check_envelope(payload, "csr")
    unknown = set(body) - {"shape", "indptr", "indices", "data",
                           "fingerprint"}
    if unknown:
        raise WireError(f"csr payload has unknown key(s) {sorted(unknown)}")
    try:
        shape = (int(body["shape"][0]), int(body["shape"][1]))
        A = CSR(shape=shape,
                indptr=array_from_wire(body["indptr"]),
                indices=array_from_wire(body["indices"]),
                data=array_from_wire(body["data"]).astype(np.float64))
    except (KeyError, IndexError, TypeError, ValueError) as e:
        raise WireError(f"corrupt csr payload: {e}") from e
    if A.indptr.shape != (shape[0] + 1,) or A.indices.shape != A.data.shape:
        raise WireError(f"inconsistent csr payload: indptr {A.indptr.shape} "
                        f"for {shape[0]} rows, indices {A.indices.shape} vs "
                        f"data {A.data.shape}")
    fp = matrix_fingerprint(A)
    claimed = body.get("fingerprint")
    if claimed is not None and claimed != fp:
        raise WireError(f"csr payload fingerprint mismatch: payload claims "
                        f"{claimed}, decoded content hashes to {fp}")
    return A, fp


# v1 request keys; "options" arrived with schema 2 (a v1-tagged frame
# carrying it is rejected under strict decode, tolerated otherwise)
_REQUEST_KEYS = {"matrix", "b", "method", "tol", "maxiter", "x0", "priority",
                 "rid"}
_V2_REQUEST_KEYS = {"options"}


def solve_request_to_wire(matrix_id: str, b: np.ndarray, *,
                          options: RequestOptions | None = None,
                          method: str | None = None, tol: float | None = None,
                          maxiter: int | None = None,
                          x0: np.ndarray | None = None,
                          priority=None, rid: int | None = None) -> dict:
    """Encode one solve admission (``b``: [n] or [n, k]) for
    :meth:`~repro.amg.api.service.AMGService.submit_wire`.

    The solve knobs travel as the flat v1 field set (``method``/``tol``/
    ``maxiter``/``x0``) so v1 decoders still read v2 frames; pass either
    an ``options`` dataclass or the individual fields, not both."""
    if options is None:
        options = RequestOptions(method=method or "solve", tol=tol,
                                 maxiter=maxiter, x0=x0)
    elif any(v is not None for v in (method, tol, maxiter, x0)):
        raise ValueError("pass options= or individual solve knobs, not both")
    d = {"schema": WIRE_SCHEMA, "kind": "solve_request",
         "matrix": matrix_id, "b": array_to_wire(np.asarray(b)),
         **options.to_wire_fields()}
    if priority is not None:
        d["priority"] = priority
    if rid is not None:
        d["rid"] = int(rid)
    return d


def solve_request_from_wire(payload: dict, *, strict: bool = True) -> dict:
    """Strict decode of a solve request; returns kwargs for
    :meth:`AMGService.submit` — ``{"matrix_id", "b", "options", ...}``
    with the solve knobs folded into one :class:`RequestOptions`.

    Accepts both the flat v1 knob fields and the nested v2 ``options``
    dict.  Under ``strict`` (the default) a v1-tagged frame carrying the
    v2-only ``options`` key is rejected; ``strict=False`` tolerates the
    additive key."""
    body = _check_envelope(payload, "solve_request")
    schema = payload.get("schema")
    unknown = set(body) - _REQUEST_KEYS - _V2_REQUEST_KEYS
    if unknown:
        raise WireError(f"solve_request payload has unknown key(s) "
                        f"{sorted(unknown)}; known: "
                        f"{sorted(_REQUEST_KEYS | _V2_REQUEST_KEYS)}")
    if strict and schema < 2:
        additive = set(body) & _V2_REQUEST_KEYS
        if additive:
            raise WireError(f"schema-{schema} solve_request carries "
                            f"v2-only key(s) {sorted(additive)} "
                            f"(strict decode)")
    try:
        out = {"matrix_id": body["matrix"], "b": array_from_wire(body["b"])}
    except KeyError as e:
        raise WireError(f"solve_request payload missing {e.args[0]!r}") \
            from None
    raw = body.get("options") if (schema >= 2 or not strict) else None
    if raw is not None and not isinstance(raw, dict):
        raise WireError(f"solve_request options must be a dict, "
                        f"got {type(raw)}")
    knobs = dict(raw or {})
    oknown = {"method", "tol", "maxiter", "x0"}
    ounknown = set(knobs) - oknown
    if ounknown:
        raise WireError(f"solve_request options has unknown key(s) "
                        f"{sorted(ounknown)}; known: {sorted(oknown)}")
    for key in oknown:                      # flat v1 fields fill the gaps
        if key in body and key not in knobs:
            knobs[key] = body[key]
    try:
        out["options"] = RequestOptions(
            method=str(knobs.get("method", "solve")),
            tol=float(knobs["tol"]) if "tol" in knobs else None,
            maxiter=int(knobs["maxiter"]) if "maxiter" in knobs else None,
            x0=array_from_wire(knobs["x0"]) if "x0" in knobs else None)
    except ValueError as e:
        raise WireError(f"solve_request options rejected: {e}") from e
    if "priority" in body:
        out["priority"] = body["priority"]
    if "rid" in body:
        out["rid"] = int(body["rid"])
    return out


# --------------------------------------------------------------------------
# Streaming updates (schema v2)
# --------------------------------------------------------------------------

_UPDATE_KEYS = {"matrix", "csr", "data", "delta", "rid"}


def update_request_to_wire(matrix_id: str, A: CSR | None = None, *,
                           data: np.ndarray | None = None,
                           delta: np.ndarray | None = None,
                           dtype: str = "float64",
                           rid: int | None = None) -> dict:
    """Encode a streaming matrix update addressed to a registered matrix.

    Exactly one payload form:

    * ``A`` — a full replacement CSR (the server decides refresh vs
      re-setup by comparing sparsity patterns);
    * ``data`` — new values on the registered matrix's frozen pattern
      (``A_new.data`` in CSR order, ``nnz`` floats);
    * ``delta`` — additive ``ΔA`` values on the frozen pattern
      (``A_new = A_old + ΔA``), the cheapest form for slow drift.
    """
    forms = [A is not None, data is not None, delta is not None]
    if sum(forms) != 1:
        raise ValueError("update needs exactly one of A=, data= or delta=")
    d: dict = {"schema": WIRE_SCHEMA, "kind": "update_request",
               "matrix": matrix_id}
    if A is not None:
        d["csr"] = csr_to_wire(A, dtype)
    elif data is not None:
        d["data"] = array_to_wire(np.asarray(data, dtype=np.float64), dtype)
    else:
        d["delta"] = array_to_wire(np.asarray(delta, dtype=np.float64), dtype)
    if rid is not None:
        d["rid"] = int(rid)
    return d


def update_request_from_wire(payload: dict) -> dict:
    """Strict decode of an update request; returns kwargs for
    :meth:`AMGService.update` (``matrix_id`` + exactly one of
    ``A``/``data``/``delta``).  Requires schema >= 2."""
    body = _check_envelope(payload, "update_request", min_schema=2)
    unknown = set(body) - _UPDATE_KEYS
    if unknown:
        raise WireError(f"update_request payload has unknown key(s) "
                        f"{sorted(unknown)}; known: {sorted(_UPDATE_KEYS)}")
    if "matrix" not in body:
        raise WireError("update_request payload missing 'matrix'")
    forms = [k for k in ("csr", "data", "delta") if k in body]
    if len(forms) != 1:
        raise WireError(f"update_request needs exactly one of "
                        f"csr/data/delta, got {forms or 'none'}")
    out: dict = {"matrix_id": body["matrix"]}
    if "csr" in body:
        out["A"], _ = csr_from_wire(body["csr"])
    elif "data" in body:
        out["data"] = array_from_wire(body["data"]).astype(np.float64)
    else:
        out["delta"] = array_from_wire(body["delta"]).astype(np.float64)
    if "rid" in body:
        out["rid"] = int(body["rid"])
    return out


def apply_update(A: CSR, *, data: np.ndarray | None = None,
                 delta: np.ndarray | None = None) -> CSR:
    """Materialize a values-only update on ``A``'s frozen pattern."""
    if (data is None) == (delta is None):
        raise ValueError("pass exactly one of data= or delta=")
    vals = np.asarray(data if data is not None else delta, dtype=np.float64)
    if vals.shape != A.data.shape:
        raise PatternMismatch(
            f"update carries {vals.shape[0] if vals.ndim else 0} values for "
            f"a pattern with {A.data.shape[0]} nonzeros")
    new = vals if data is not None else A.data + vals
    return CSR(A.shape, np.ascontiguousarray(A.indptr),
               np.ascontiguousarray(A.indices), np.ascontiguousarray(new))
