"""AMGService: admission-scheduled, wire-addressable solver serving.

The paper's economics — build communicators/schedules once, amortize them
over many solves — only pays end-to-end if the *serving* surface can keep
hot sessions pinned and feed the batched device traces.  ``AMGService``
is that surface:

* **Ticketed async admission** — :meth:`submit` returns a :class:`Ticket`
  immediately; ``ticket.result()`` blocks until the scheduler has run the
  solve.  Requests carry per-request ``tol``/``maxiter``/``x0`` warm starts
  and ``b`` payloads of shape ``[n]`` or ``[n, k]``.
* **Cross-burst coalescing** — requests whose
  ``(matrix_id,) + RequestOptions.group_key()`` coalescing keys match and
  that arrive within one ``coalesce_window`` are stacked into ONE
  multi-RHS device trace, even when they were submitted in separate
  bursts.
* **Priority classes with starvation-free scheduling** — ``"interactive"``
  / ``"default"`` / ``"batch"`` (or any int; lower runs first); a waiting
  group's effective priority improves by one class per ``priority_aging``
  seconds, so a steady interactive stream can never starve batch work.
* **Wire addressability** — :meth:`register_wire` / :meth:`submit_wire` /
  :meth:`update_wire` accept the encoded payloads of
  :mod:`repro.amg.api.config`, so the whole service can be driven over a
  byte transport (matrices registered by fingerprint, requests referencing
  them by that id).
* **Streaming updates** — :meth:`update` applies ``A + ΔA`` value drift to
  a registered matrix under a STABLE matrix id: a pattern-matching update
  refreshes the live session's values in place (hierarchy, NAP schedules
  and compiled programs reused), escalating to a full node-aware re-setup
  on convergence regression, a changed pattern, or an evicted session.
* **Accounting** — :meth:`report` returns a :class:`ServiceReport` with
  per-request diagnostics plus the session store's hit/evict/setup-cost
  and refresh/re-setup counters (:meth:`SessionStore.stats`).

Two execution modes share the same scheduler: a background worker thread
(:meth:`start`/:meth:`close`, or the context manager) that honors the
coalescing window in real time, and the synchronous :meth:`drain` (no
thread, window treated as already elapsed) for deterministic callers.
"""
from __future__ import annotations

import dataclasses
import threading
import time

import numpy as np

from ..csr import CSR
from ..solve import MultiSolveResult
from .config import (AMGConfig, PatternMismatch, RequestOptions,
                     apply_update, csr_from_wire, matrix_fingerprint,
                     solve_request_from_wire, update_request_from_wire)
from .sessions import (AMGSolver, BoundSolver, BytesBudgetPolicy, LRUPolicy,
                       SessionStore, _csr_nbytes)

PRIORITY_CLASSES = {"interactive": 0, "default": 1, "batch": 2}


class ServiceClosed(RuntimeError):
    """The service was closed before this request could be executed.

    Raised out of :meth:`Ticket.result` for requests still queued when
    :meth:`AMGService.close` ran (always with ``flush=False``; with the
    default flushing close only requests admitted during the shutdown race
    see it) — a typed, immediate failure instead of a ``result(timeout=)``
    expiry."""


class Ticket:
    """Handle for one admitted request; :meth:`result` blocks until the
    scheduler has executed it (and re-raises any solve-side failure)."""

    def __init__(self, service: "AMGService", rid: int, matrix_id: str):
        self.rid = rid
        self.matrix_id = matrix_id
        self.diagnostics: dict | None = None   # set when the solve lands
        self._service = service
        self._event = threading.Event()
        self._x: np.ndarray | None = None
        self._error: BaseException | None = None
        self._cb_lock = threading.Lock()
        self._callbacks: list = []

    def done(self) -> bool:
        return self._event.is_set()

    def exception(self) -> BaseException | None:
        """The solve-side failure, or None (only meaningful once done)."""
        return self._error

    def add_done_callback(self, fn) -> None:
        """Call ``fn(ticket)`` when the request finishes (success or
        failure).  Runs in the scheduler's thread — or immediately in the
        caller's if the ticket is already done.  This is the hook the async
        serving front-end bridges on (no polling thread per request)."""
        with self._cb_lock:
            if not self._event.is_set():
                self._callbacks.append(fn)
                return
        fn(self)

    def result(self, timeout: float | None = None) -> np.ndarray:
        """The solution ``x`` ([n], or [n, k] for a multi-RHS payload)."""
        if not self._event.is_set() and not self._service.running:
            raise RuntimeError(
                "service worker is not running and the request has not been "
                "drained — call service.start() (or use it as a context "
                "manager) for async admission, or service.drain() for "
                "synchronous processing")
        if not self._event.wait(timeout):
            raise TimeoutError(f"request {self.rid} not finished after "
                               f"{timeout}s")
        if self._error is not None:
            raise self._error
        return self._x

    def _fulfill(self, x, diagnostics: dict) -> None:
        self._x = x
        self.diagnostics = diagnostics
        self._finish()

    def _fail(self, error: BaseException) -> None:
        self._error = error
        self._finish()

    def _finish(self) -> None:
        with self._cb_lock:
            self._event.set()
            callbacks, self._callbacks = self._callbacks, []
        for fn in callbacks:
            fn(self)


@dataclasses.dataclass
class ServiceReport:
    """Snapshot of a service's accounting: admission/batching counters,
    per-request diagnostics, and the session store's stats."""

    stats: dict
    per_request: dict
    store: dict
    matrices: dict = dataclasses.field(default_factory=dict)

    def summary(self) -> str:
        s, st = self.stats, self.store
        lines = [
            f"requests={s['requests']} (wire={s['wire_requests']}) "
            f"batches={s['batches']} batched_rhs={s['batched_rhs']} "
            f"setups={s['setups']} updates={s['updates']} "
            f"unconverged={s['unconverged']} errors={s['errors']}",
            f"store[{st['policy']}]: entries={st['entries']} "
            f"bytes={st['bytes']} hits={st['hits']} misses={st['misses']} "
            f"evictions={st['evictions']} expirations={st['expirations']} "
            f"setup_cost_total={st['setup_cost_total']:.3f}s",
        ]
        if st.get("refreshes") or st.get("resetups"):
            trig = ",".join(f"{k}:{v}" for k, v in
                            sorted(st.get("triggers", {}).items()))
            lines.append(
                f"streaming: refreshes={st['refreshes']} "
                f"resetups={st['resetups']} triggers=[{trig}]")
        if self.matrices:
            m = self.matrices
            lines.append(
                f"matrices[{m['policy']}]: entries={m['entries']} "
                f"bytes={m['bytes']} evictions={m['evictions']}")
        return "\n".join(lines)


@dataclasses.dataclass
class _Pending:
    rid: int
    b: np.ndarray                # [n] or [n, k]
    x0: np.ndarray | None
    priority: int
    submitted: float
    ticket: Ticket

    @property
    def ncols(self) -> int:
        return 1 if self.b.ndim == 1 else int(self.b.shape[1])


@dataclasses.dataclass
class _Group:
    """Requests sharing one ``(matrix_id,) + RequestOptions.group_key()``
    coalescing key; everything in a group can ride the same multi-RHS
    device trace."""

    key: tuple
    created: float
    requests: list[_Pending] = dataclasses.field(default_factory=list)

    @property
    def priority(self) -> int:
        return min(p.priority for p in self.requests)


class AMGService:
    """Admission-scheduled solver service over one :class:`AMGConfig`.

    ``max_rhs`` caps the columns of one device trace; ``coalesce_window``
    (seconds) is how long an open group waits for more same-key right-hand
    sides before the worker launches it; ``store`` defaults to a fresh
    LRU :class:`SessionStore` so eviction budgets and hit counters are
    scoped to this service (pass a shared store to pool sessions);
    ``priority_aging`` is the seconds of waiting that promote a group by
    one priority class (starvation freedom).  ``max_matrices`` /
    ``max_matrix_bytes`` bound the matrix registry (LRU by count; with a
    bytes budget, the cost-aware policy) — counters surface in
    :meth:`report` as ``matrices``.  ``clock`` is injectable for
    deterministic scheduler tests.
    """

    def __init__(self, config: AMGConfig | None = None, *, max_rhs: int = 8,
                 coalesce_window: float = 0.0,
                 store: SessionStore | None = None,
                 priority_aging: float = 0.5,
                 max_matrices: int = 64,
                 max_matrix_bytes: int | None = None,
                 diagnostics_limit: int = 4096, clock=time.monotonic):
        self.config = config or AMGConfig()
        self.max_rhs = max(1, int(max_rhs))
        self.coalesce_window = float(coalesce_window)
        self.priority_aging = max(1e-9, float(priority_aging))
        self.store = store if store is not None else SessionStore(LRUPolicy())
        self.solver = AMGSolver(self.config, store=self.store)
        self._clock = clock
        # the matrix registry is bounded (entry count, optionally bytes)
        # through the same eviction machinery as the session store — a
        # long-lived service whose session store drops cold sessions must
        # not keep every matrix ever registered resident forever
        policy = (BytesBudgetPolicy(max_matrix_bytes,
                                    max_entries=max_matrices)
                  if max_matrix_bytes is not None
                  else LRUPolicy(max_matrices))
        self._matrices: SessionStore = SessionStore(policy, clock=clock)
        self._groups: dict[tuple, _Group] = {}
        self._cond = threading.Condition()
        self._worker: threading.Thread | None = None
        self._stop = False
        self._flush_on_stop = True
        self._next_rid = 0
        self.stats = {"requests": 0, "wire_requests": 0, "batches": 0,
                      "batched_rhs": 0, "setups": 0, "unconverged": 0,
                      "updates": 0, "errors": 0}
        # per-request diagnostics of the most recent `diagnostics_limit`
        # executed solves (bounded so a long-lived service cannot grow
        # without limit; tickets keep their own copy regardless)
        self.diagnostics_limit = max(1, int(diagnostics_limit))
        self.diagnostics: dict[int, dict] = {}

    # ------------------------------------------------------------- lifecycle
    @property
    def running(self) -> bool:
        return self._worker is not None

    def start(self) -> "AMGService":
        """Spawn the admission worker (idempotent)."""
        if self._worker is None:
            self._stop = False
            self._worker = threading.Thread(target=self._worker_loop,
                                            name="amg-service", daemon=True)
            self._worker.start()
        return self

    def close(self, flush: bool = True) -> None:
        """Stop the worker.  ``flush=True`` (default) executes every queued
        group first (window ignored); ``flush=False`` abandons the queue.
        Either way, any request still un-executed when the worker has
        stopped — the whole queue under ``flush=False``, shutdown-race
        admissions under ``flush=True`` — fails immediately with a typed
        :class:`ServiceClosed` instead of hanging until a
        ``result(timeout=...)`` expires."""
        w = self._worker
        if w is not None:
            with self._cond:
                self._stop = True
                self._flush_on_stop = flush
                self._cond.notify_all()
            w.join()
            self._worker = None
            self._stop = False
            self._flush_on_stop = True
        self._fail_queued(ServiceClosed(
            "AMGService was closed before this request was executed"))

    def _fail_queued(self, error: BaseException) -> None:
        with self._cond:
            groups, self._groups = list(self._groups.values()), {}
        for group in groups:
            self.stats["errors"] += len(group.requests)
            for p in group.requests:
                self._record_diag(p.rid, {"error": repr(error)})
                p.ticket._fail(error)

    def __enter__(self) -> "AMGService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # ----------------------------------------------------------- registration
    def register(self, matrix_id: str, A: CSR, *,
                 fingerprint: str | None = None) -> str:
        """Register a matrix under an id; its fingerprint is computed once
        here (or passed in by a caller that already decoded it) and reused
        for every session lookup.  The registry is bounded: the service's
        eviction policy (count, optionally bytes) drops the least-valuable
        registrations once over budget."""
        self._matrices.put(matrix_id, (A, fingerprint or
                                       matrix_fingerprint(A)),
                           nbytes=_csr_nbytes(A))
        return matrix_id

    def register_wire(self, payload: dict) -> str:
        """Register an encoded CSR payload; the matrix id IS its verified
        content fingerprint (so the registration is idempotent and requests
        can address the matrix without any out-of-band id exchange)."""
        A, fp = csr_from_wire(payload)
        return self.register(fp, A, fingerprint=fp)

    def _lookup_matrix(self, matrix_id: str) -> tuple[CSR, str]:
        got = self._matrices.get(matrix_id)
        if got is None:
            raise KeyError(f"unknown matrix_id {matrix_id!r}; registered: "
                           f"{sorted(self._matrices.keys())}")
        return got

    def bound_for(self, matrix_id: str) -> BoundSolver:
        """The session for a registered matrix (setup on first use; later
        calls hit the session store)."""
        A, fp = self._lookup_matrix(matrix_id)
        misses = self.store.stats()["misses"]
        bound = self.solver.setup(A, fingerprint=fp)
        if self.store.stats()["misses"] > misses:
            self.stats["setups"] += 1
        return bound

    # -------------------------------------------------------------- admission
    def submit(self, matrix_id: str, b, *,
               options: RequestOptions | None = None,
               method: str | None = None, tol: float | None = None,
               maxiter: int | None = None, x0=None, priority=None,
               rid: int | None = None) -> Ticket:
        """Admit one solve; returns a :class:`Ticket` immediately.

        Per-request knobs travel as ONE frozen
        :class:`~repro.amg.api.config.RequestOptions` (``options=``); the
        individual ``method``/``tol``/``maxiter``/``x0`` kwargs are sugar
        that constructs it and cannot be mixed with ``options=``.  ``b``
        is ``[n]`` or ``[n, k]``; requests sharing
        ``(matrix_id,) + options.group_key()`` coalesce into one device
        trace when admitted within one window.
        """
        if options is None:
            options = RequestOptions(method=method or "solve", tol=tol,
                                     maxiter=maxiter, x0=x0)
        elif any(v is not None for v in (method, tol, maxiter, x0)):
            raise ValueError("pass options= or individual solve knobs, "
                             "not both")
        A, _ = self._lookup_matrix(matrix_id)
        options = options.resolve(self.config)
        n = A.nrows
        b = np.asarray(b)
        if (b.ndim not in (1, 2) or b.shape[0] != n
                or (b.ndim == 2 and b.shape[1] == 0)):
            raise ValueError(f"b must be [{n}] or [{n}, k] with k >= 1, "
                             f"got shape {b.shape}")
        x0 = options.x0
        if x0 is not None:
            x0 = np.asarray(x0)
            if x0.shape != b.shape:
                raise ValueError(f"x0 must match b's shape {b.shape}, "
                                 f"got {x0.shape}")
            x0 = x0.copy()
        # defensive copy: submit() returns before the solve runs, so a
        # caller reusing its buffer must not corrupt the queued request
        b = b.copy()
        prio = self._resolve_priority(priority)
        key = (matrix_id,) + options.group_key()
        now = self._clock()
        with self._cond:
            if rid is None:
                rid = self._next_rid
            self._next_rid = max(self._next_rid, rid) + 1
            ticket = Ticket(self, rid, matrix_id)
            group = self._groups.get(key)
            if group is None:
                group = self._groups[key] = _Group(key, now)
            group.requests.append(_Pending(rid, b, x0, prio, now, ticket))
            self.stats["requests"] += 1
            self._cond.notify_all()
        return ticket

    def submit_wire(self, payload: dict) -> Ticket:
        """Admit one encoded solve request (see
        :func:`~repro.amg.api.config.solve_request_to_wire`)."""
        kwargs = solve_request_from_wire(payload)
        self.stats["wire_requests"] += 1
        return self.submit(kwargs.pop("matrix_id"), kwargs.pop("b"),
                           **kwargs)

    # ------------------------------------------------------ streaming updates
    def update(self, matrix_id: str, A_new: CSR | None = None, *,
               data=None, delta=None) -> dict:
        """Apply a streaming value update to a registered matrix.

        The matrix id stays STABLE across updates — in-flight and future
        requests keep addressing it.  Exactly one of ``A_new`` (full CSR),
        ``data`` (values on the frozen pattern) or ``delta`` (additive ΔA).
        Routing: a live session with a matching pattern takes the
        value-only refresh (or its policy-escalated re-setup); a changed
        pattern or an evicted session runs a full setup.  Returns
        ``{"matrix": id, "action": "refresh"|"resetup", "reason": ...}``.
        """
        A_old, fp = self._lookup_matrix(matrix_id)
        if A_new is None:
            A_new = apply_update(A_old, data=data, delta=delta)
        elif data is not None or delta is not None:
            raise ValueError("pass A_new or data=/delta=, not both")
        self.stats["updates"] += 1
        bound = self.store.get((fp, self.solver.config))
        if bound is not None:
            try:
                action = bound.update(A_new)
                reason = bound.last_update_reason
                self._matrices.put(matrix_id,
                                   (bound._fine, bound._fingerprint),
                                   nbytes=_csr_nbytes(bound._fine))
                return {"matrix": matrix_id, "action": action,
                        "reason": reason}
            except PatternMismatch:
                # structural change: the session cannot refresh — the
                # service escalates explicitly with a full setup
                reason = "pattern"
        else:
            reason = "evicted"
        fp_new = matrix_fingerprint(A_new)
        self.register(matrix_id, A_new, fingerprint=fp_new)
        self.bound_for(matrix_id)                   # full (re-)setup
        self.store.note_update("resetup", reason)
        return {"matrix": matrix_id, "action": "resetup", "reason": reason}

    def update_wire(self, payload: dict) -> dict:
        """Apply one encoded update request (see
        :func:`~repro.amg.api.config.update_request_to_wire`); returns the
        :meth:`update` result with the request's ``rid`` echoed."""
        kwargs = update_request_from_wire(payload)
        self.stats["wire_requests"] += 1
        rid = kwargs.pop("rid", None)
        out = self.update(kwargs.pop("matrix_id"), kwargs.pop("A", None),
                          **kwargs)
        if rid is not None:
            out["rid"] = rid
        return out

    @staticmethod
    def _resolve_priority(priority) -> int:
        if priority is None:
            return PRIORITY_CLASSES["default"]
        if isinstance(priority, str):
            try:
                return PRIORITY_CLASSES[priority]
            except KeyError:
                raise ValueError(
                    f"unknown priority class {priority!r}; known: "
                    f"{sorted(PRIORITY_CLASSES)} (or any int)") from None
        return int(priority)

    # -------------------------------------------------------------- scheduling
    def _order_key(self, group: _Group, now: float) -> tuple:
        """Scheduling order among ripe groups: effective priority first
        (aged — one class per ``priority_aging`` seconds waited, so low
        priorities cannot starve), then arrival order."""
        aged = group.priority - (now - group.created) / self.priority_aging
        return (aged, group.created)

    def drain(self) -> dict[int, np.ndarray]:
        """Synchronously execute everything queued (the window is treated
        as already elapsed); returns ``{rid: x}``.  Only valid when the
        background worker is not running."""
        if self._worker is not None:
            raise RuntimeError("drain() is for synchronous use; this "
                               "service has a running worker — collect "
                               "results through ticket.result() instead")
        out: dict[int, np.ndarray] = {}
        while True:
            with self._cond:
                if not self._groups:
                    return out
                now = self._clock()
                group = min(self._groups.values(),
                            key=lambda g: self._order_key(g, now))
                del self._groups[group.key]
            out.update(self._execute_group(group))

    def _worker_loop(self) -> None:
        while True:
            with self._cond:
                while not self._groups and not self._stop:
                    self._cond.wait()
                if self._stop and (not self._groups
                                   or not self._flush_on_stop):
                    return
                now = self._clock()
                ripe = [g for g in self._groups.values()
                        if self._stop
                        or now - g.created >= self.coalesce_window]
                if not ripe:
                    deadline = min(g.created + self.coalesce_window
                                   for g in self._groups.values())
                    self._cond.wait(timeout=max(deadline - now, 1e-3))
                    continue
                group = min(ripe, key=lambda g: self._order_key(g, now))
                del self._groups[group.key]
            self._execute_group(group)

    # --------------------------------------------------------------- execution
    def _chunks(self, requests: list[_Pending]):
        """Split a group into device-trace-sized chunks: total columns per
        chunk ≤ ``max_rhs`` (a single over-wide request stays whole)."""
        chunk, cols = [], 0
        for p in requests:
            if chunk and cols + p.ncols > self.max_rhs:
                yield chunk
                chunk, cols = [], 0
            chunk.append(p)
            cols += p.ncols
        if chunk:
            yield chunk

    def _execute_group(self, group: _Group) -> dict[int, np.ndarray]:
        matrix_id, method, tol, maxiter = group.key
        out: dict[int, np.ndarray] = {}
        try:
            bound = self.bound_for(matrix_id)
        except Exception as e:                     # setup failed: fail all
            self.stats["errors"] += len(group.requests)
            for p in group.requests:
                self._record_diag(p.rid, {"error": repr(e)})
                p.ticket._fail(e)
            return out
        fn = bound.solve if method == "solve" else bound.pcg
        now = self._clock()
        for chunk in self._chunks(group.requests):
            batch = self.stats["batches"]
            try:
                out.update(self._run_chunk(fn, chunk, tol, maxiter, batch,
                                           method, now))
            except Exception as e:
                self.stats["errors"] += len(chunk)
                for p in chunk:
                    self._record_diag(p.rid, {"error": repr(e)})
                    p.ticket._fail(e)
                continue
            self.stats["batches"] += 1
        return out

    def _run_chunk(self, fn, chunk: list[_Pending], tol, maxiter,
                   batch: int, method: str, now: float) -> dict:
        out = {}
        ncols = sum(p.ncols for p in chunk)
        n = chunk[0].b.shape[0]
        if len(chunk) == 1 and chunk[0].b.ndim == 1:
            p = chunk[0]
            res = fn(p.b, tol=tol, maxiter=maxiter, x0=p.x0)
            results = [(p, np.asarray(res.x), res)]
        else:
            B = np.concatenate([p.b.reshape(n, -1) for p in chunk], axis=1)
            if any(p.x0 is not None for p in chunk):
                X0 = np.concatenate(
                    [(p.x0.reshape(n, -1) if p.x0 is not None
                      else np.zeros((n, p.ncols))) for p in chunk], axis=1)
            else:
                X0 = None
            mres = fn(B, tol=tol, maxiter=maxiter, x0=X0)
            results, o = [], 0
            for p in chunk:
                block = np.asarray(mres.x[:, o: o + p.ncols])
                x = block[:, 0] if p.b.ndim == 1 else block
                # per-request view over this request's columns — reuses
                # MultiSolveResult's converged/iterations aggregation
                results.append((p, x,
                                MultiSolveResult(block,
                                                 mres.columns[o: o + p.ncols])))
                o += p.ncols
        for p, x, res in results:
            diag = {"converged": bool(res.converged),
                    "iterations": int(res.iterations), "method": method,
                    "batch": batch, "batch_cols": ncols,
                    "wait_s": max(now - p.submitted, 0.0)}
            if not res.converged:
                self.stats["unconverged"] += 1
            self._record_diag(p.rid, diag)
            out[p.rid] = x
            p.ticket._fulfill(x, diag)
        if ncols > 1:
            self.stats["batched_rhs"] += ncols
        return out

    def _record_diag(self, rid: int, diag: dict) -> None:
        self.diagnostics.pop(rid, None)          # re-insert at the tail
        self.diagnostics[rid] = diag
        while len(self.diagnostics) > self.diagnostics_limit:
            del self.diagnostics[next(iter(self.diagnostics))]

    # -------------------------------------------------------------- reporting
    def report(self) -> ServiceReport:
        return ServiceReport(stats=dict(self.stats),
                             per_request={r: dict(d) for r, d in
                                          self.diagnostics.items()},
                             store=self.store.stats(),
                             matrices=self._matrices.stats())
