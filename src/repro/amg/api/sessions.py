"""Bound solvers, the session store, and its eviction policies.

A **session** is one (matrix fingerprint, :class:`AMGConfig`) pair bound to
a backend: the object that owns the expensive state — the host
``Hierarchy``, the lowered ``DistHierarchy`` (comm graphs, per-level
strategy selection, halo plans) and its compiled shard_map programs.
Sessions live in a :class:`SessionStore`, an instantiable cache with a
pluggable :class:`EvictionPolicy` (:class:`LRUPolicy`, :class:`TTLPolicy`,
:class:`BytesBudgetPolicy`) and per-entry setup-cost / hit-count accounting
(:meth:`SessionStore.stats`) — the knobs a serving deployment needs to keep
hot sessions pinned and evict cold ones *deliberately* instead of through a
fixed module-global FIFO.

:class:`AMGSolver` is the session entrypoint (``AMGSolver(cfg).setup(A)``),
defaulting to module-level stores so independent callers share sessions;
:class:`~repro.amg.api.service.AMGService` instantiates its own store so
its eviction budget and counters are service-scoped.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict

import numpy as np

from ..csr import CSR
from ..hierarchy import (Hierarchy, refresh_values as _hierarchy_refresh,
                         setup as _hierarchy_setup)
from ..solve import (MultiSolveResult, SolveOptions, host_pcg, host_solve,
                     host_vcycle)
from .config import (AMGConfig, PatternMismatch, RequestOptions, apply_update,
                     matrix_fingerprint, pattern_fingerprint)
from .registry import backend_class, register_backend


# --------------------------------------------------------------------------
# Session store + eviction policies
# --------------------------------------------------------------------------


@dataclasses.dataclass
class CacheEntry:
    """One stored session with the accounting eviction policies consume."""

    value: object
    nbytes: int = 0
    setup_cost: float = 0.0       # seconds it took to build the value
    hits: int = 0
    created: float = 0.0
    last_used: float = 0.0
    # optional re-measure hook: a dist session lowers its device arrays
    # lazily on first solve, so resident bytes grow after the put — the
    # store refreshes nbytes through this before evicting or reporting
    nbytes_fn: object = dataclasses.field(default=None, repr=False,
                                          compare=False)

    def refresh_nbytes(self) -> None:
        if self.nbytes_fn is not None:
            self.nbytes = int(self.nbytes_fn())


class EvictionPolicy:
    """Decides what a :class:`SessionStore` drops.  Two hooks:

    * :meth:`expired` — per-entry staleness (checked on every access).
    * :meth:`victims` — which keys to evict after an insert (called until
      it yields nothing).
    """

    name = "none"

    def expired(self, entry: CacheEntry, now: float) -> bool:
        return False

    def victims(self, entries: "OrderedDict[object, CacheEntry]",
                now: float) -> list:
        return []


class LRUPolicy(EvictionPolicy):
    """Bounded entry count, least-recently-used first — the behavior of the
    old module-global cache (inserts and hits refresh recency)."""

    name = "lru"

    def __init__(self, max_entries: int = 16):
        self.max_entries = max(1, int(max_entries))

    def victims(self, entries, now):
        n_over = len(entries) - self.max_entries
        return list(entries)[:n_over] if n_over > 0 else []


class TTLPolicy(EvictionPolicy):
    """Idle-time-to-live: an entry not touched for ``ttl`` seconds is
    expired on its next access (plus an optional LRU entry bound)."""

    name = "ttl"

    def __init__(self, ttl: float, max_entries: int | None = None):
        self.ttl = float(ttl)
        self.max_entries = max_entries

    def expired(self, entry, now):
        return now - entry.last_used > self.ttl

    def victims(self, entries, now):
        if self.max_entries is None:
            return []
        n_over = len(entries) - self.max_entries
        return list(entries)[:n_over] if n_over > 0 else []


class BytesBudgetPolicy(EvictionPolicy):
    """Cost-aware bytes budget: while the resident total exceeds
    ``max_bytes``, evict the entry with the lowest *retention value*

        ``setup_cost * (1 + hits) / max(nbytes, 1)``

    — i.e. prefer dropping sessions that are cheap to rebuild, rarely hit,
    or disproportionately large (ties broken least-recently-used)."""

    name = "bytes_budget"

    def __init__(self, max_bytes: int, max_entries: int | None = None):
        self.max_bytes = int(max_bytes)
        self.max_entries = max_entries

    @staticmethod
    def retention_value(entry: CacheEntry) -> float:
        return entry.setup_cost * (1 + entry.hits) / max(entry.nbytes, 1)

    def victims(self, entries, now):
        out = []
        if self.max_entries is not None:
            n_over = len(entries) - self.max_entries
            if n_over > 0:
                out.extend(list(entries)[:n_over])
        # recency-ordered iteration makes the min() tie-break LRU
        live = [(k, e) for k, e in entries.items() if k not in out]
        total = sum(e.nbytes for _, e in live)
        while total > self.max_bytes and live:
            k, e = min(live, key=lambda ke: self.retention_value(ke[1]))
            out.append(k)
            live.remove((k, e))
            total -= e.nbytes
        return out


class SessionStore:
    """Keyed session cache with pluggable eviction and full accounting.

    Thread-safe (the service's admission worker and foreground callers may
    touch it concurrently).  ``clock`` is injectable for deterministic TTL
    tests."""

    def __init__(self, policy: EvictionPolicy | None = None,
                 clock=time.monotonic):
        self.policy = policy or LRUPolicy(SESSION_CACHE_SIZE)
        self._clock = clock
        self._entries: "OrderedDict[object, CacheEntry]" = OrderedDict()
        self._lock = threading.RLock()
        self._counters = {"hits": 0, "misses": 0, "puts": 0, "evictions": 0,
                          "expirations": 0, "setup_cost_evicted": 0.0,
                          "refreshes": 0, "resetups": 0}
        # streaming-update trigger reasons ("drift", "regression",
        # "pattern", "evicted", …) -> count
        self._triggers: dict[str, int] = {}

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __contains__(self, key) -> bool:
        with self._lock:
            return key in self._entries

    def keys(self) -> list:
        with self._lock:
            return list(self._entries)

    def get(self, key, default=None):
        now = self._clock()
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None and self.policy.expired(entry, now):
                self._drop(key, entry, "expirations")
                entry = None
            if entry is None:
                self._counters["misses"] += 1
                return default
            entry.hits += 1
            entry.last_used = now
            self._counters["hits"] += 1
            self._entries.move_to_end(key)
            return entry.value

    def put(self, key, value, *, nbytes: int = 0, setup_cost: float = 0.0,
            nbytes_fn=None) -> None:
        now = self._clock()
        with self._lock:
            self._entries[key] = CacheEntry(value, int(nbytes),
                                            float(setup_cost), 0, now, now,
                                            nbytes_fn)
            self._entries.move_to_end(key)
            self._counters["puts"] += 1
            for e in self._entries.values():     # lazy lowerings may have
                e.refresh_nbytes()               # grown since their put
            for k, e in [(k, e) for k, e in self._entries.items()
                         if self.policy.expired(e, now)]:
                self._drop(k, e, "expirations")
            for k in self.policy.victims(self._entries, now):
                if k in self._entries:
                    self._drop(k, self._entries[k], "evictions")

    def _drop(self, key, entry: CacheEntry, counter: str) -> None:
        del self._entries[key]
        self._counters[counter] += 1
        self._counters["setup_cost_evicted"] += entry.setup_cost

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()

    def rekey(self, old_key, new_key) -> None:
        """Move an entry to a new key without touching its accounting —
        a streamed update changed the value fingerprint, but the session
        object (and its setup cost / hit history) is the same."""
        with self._lock:
            entry = self._entries.pop(old_key, None)
            if entry is not None:
                self._entries[new_key] = entry
                self._entries.move_to_end(new_key)

    def note_update(self, action: str, reason: str) -> None:
        """Record a streaming update: ``action`` is ``"refresh"`` (value-only
        hierarchy reuse) or ``"resetup"`` (full node-aware re-setup),
        ``reason`` the trigger ("drift", "regression", "pattern", …)."""
        if action not in ("refresh", "resetup"):
            raise ValueError(f"unknown update action {action!r}")
        with self._lock:
            self._counters[action + "es" if action == "refresh"
                           else action + "s"] += 1
            self._triggers[reason] = self._triggers.get(reason, 0) + 1

    def stats(self) -> dict:
        """Counters + resident totals (hit/evict/setup-cost accounting)."""
        with self._lock:
            for e in self._entries.values():
                e.refresh_nbytes()
            return {**self._counters, "policy": self.policy.name,
                    "triggers": dict(self._triggers),
                    "entries": len(self._entries),
                    "bytes": sum(e.nbytes for e in self._entries.values()),
                    "setup_cost_total": sum(e.setup_cost for e in
                                            self._entries.values())}

    def entry_table(self) -> list[dict]:
        """Per-entry accounting rows (for reports / the demo's stats table)."""
        now = self._clock()
        with self._lock:
            for e in self._entries.values():
                e.refresh_nbytes()
            return [{"key": k, "nbytes": e.nbytes,
                     "setup_cost": e.setup_cost, "hits": e.hits,
                     "idle_s": now - e.last_used}
                    for k, e in self._entries.items()]


def _csr_nbytes(M) -> int:
    return int(M.indptr.nbytes + M.indices.nbytes + M.data.nbytes)


def session_nbytes(value) -> int:
    """Best-effort resident-bytes estimate for store accounting: CSR bytes
    of a host hierarchy, device-array bytes of a lowered DistHierarchy."""
    if value is None:
        return 0
    if isinstance(value, Hierarchy):
        total = 0
        for lv in value.levels:
            for M in (lv.A, lv.P, lv.R):
                if M is not None:
                    total += _csr_nbytes(M)
        return total
    if isinstance(value, BoundSolver):
        return (session_nbytes(value.hierarchy)
                + session_nbytes(getattr(value, "_dist", None)))
    arrs = getattr(value, "_arrs", None)        # DistHierarchy (duck-typed)
    if arrs is not None:
        try:
            import jax
            return int(sum(getattr(leaf, "nbytes", 0)
                           for leaf in jax.tree_util.tree_leaves(arrs)))
        except Exception:
            return 0
    return int(getattr(value, "nbytes", 0))


# --------------------------------------------------------------------------
# Bound solvers
# --------------------------------------------------------------------------


class BoundSolver:
    """A hierarchy bound to one backend: the object that owns all caching.

    Created by :meth:`AMGSolver.setup` (full session: matrix → hierarchy →
    backend lowering) or :func:`bind_hierarchy` (wrap an existing
    hierarchy).  ``solve``/``pcg`` accept ``b`` of shape ``[n]`` or
    ``[n, k]``; the multi-RHS form returns a
    :class:`~repro.amg.solve.MultiSolveResult`.
    """

    backend_name = "?"
    # ---- streaming-session state, populated by AMGSolver.setup.  A solver
    # made through bind_hierarchy has none of it and cannot stream updates.
    _fine: CSR | None = None          # canonical fine-grid CSR of the session
    pattern_fp: str | None = None     # frozen sparsity-pattern fingerprint
    _fingerprint: str | None = None   # full (values) fingerprint = store key
    _store = None                     # SessionStore holding this session
    _store_key = None
    _plevels = None                   # partitioned levels (dist-born setup)
    # convergence tracking for RefreshPolicy: baseline is the first solve
    # after the most recent (re-)setup, last the most recent solve
    baseline_iterations: int | None = None
    last_iterations: int | None = None
    last_update_reason: str | None = None   # trigger of the latest update()

    def __init__(self, config: AMGConfig, hierarchy: Hierarchy | None):
        # ``hierarchy`` is None on the setup_backend="dist" path: the levels
        # were born partitioned and no host Hierarchy ever existed.
        self.config = config
        self.hierarchy = hierarchy

    @classmethod
    def from_hierarchy(cls, h: Hierarchy, dist=None,
                       opts: SolveOptions | None = None) -> "BoundSolver":
        return cls(AMGConfig(backend=cls.backend_name,
                             opts=opts or SolveOptions()), h)

    # ------------------------------------------------------------ properties
    @property
    def A(self) -> CSR:
        if self.hierarchy is None:
            raise ValueError(
                "this solver was set up with setup_backend='dist': levels "
                "are partitioned across the mesh and no global fine-grid "
                "CSR exists")
        return self.hierarchy.levels[0].A

    @property
    def n(self) -> int:
        return self.A.nrows

    @property
    def opts(self) -> SolveOptions:
        return self.config.opts

    def staging_dtype(self) -> np.dtype:
        """Host dtype right-hand sides are staged in — the single
        conversion point between user arrays and the session's compute
        dtype.  float64 sessions stage in float64; float32/bfloat16
        sessions stage in float32 (numpy has no native bfloat16; the device
        transfer downcasts from fp32)."""
        return np.dtype(np.float64 if self.config.dtype == "float64"
                        else np.float32)

    def _check_b(self, b) -> np.ndarray:
        """Validate shape and convert ``b`` ONCE to :meth:`staging_dtype`
        (an array already in the staging dtype passes through un-copied —
        no silent float64 round-trip for fp32/bf16 sessions)."""
        b = np.asarray(b)
        if b.ndim not in (1, 2) or b.shape[0] != self.n:
            raise ValueError(f"b must be [{self.n}] or [{self.n}, k], "
                             f"got shape {b.shape}")
        return np.asarray(b, dtype=self.staging_dtype())

    # -------------------------------------------------------------- methods
    def solve(self, b, *, tol: float | None = None,
              maxiter: int | None = None, x0=None):
        res = self._solve(b, tol=tol, maxiter=maxiter, x0=x0)
        self._observe(res)
        return res

    def pcg(self, b, *, tol: float | None = None,
            maxiter: int | None = None, x0=None):
        res = self._pcg(b, tol=tol, maxiter=maxiter, x0=x0)
        self._observe(res)
        return res

    def run(self, b, options: RequestOptions | None = None):
        """One request through the unified knob set: dispatches
        ``options.method`` with its ``tol``/``maxiter``/``x0`` (``None``
        knobs resolve to the session config's defaults)."""
        o = (options or RequestOptions()).resolve(self.config)
        fn = self.pcg if o.method == "pcg" else self.solve
        return fn(b, tol=o.tol, maxiter=o.maxiter, x0=o.x0)

    def _solve(self, b, *, tol: float | None = None,
               maxiter: int | None = None, x0=None):
        raise NotImplementedError

    def _pcg(self, b, *, tol: float | None = None,
             maxiter: int | None = None, x0=None):
        raise NotImplementedError

    def vcycle(self, b, x0=None):
        raise NotImplementedError

    def _observe(self, result) -> None:
        """Track iteration counts for the adaptive re-setup policy."""
        it = getattr(result, "iterations", None)
        if it is None:
            return
        self.last_iterations = int(it)
        if self.baseline_iterations is None:
            self.baseline_iterations = int(it)

    # ---------------------------------------------------- streaming updates
    def update(self, A_new: CSR | None = None, *, data=None,
               delta=None) -> str:
        """Streaming matrix update on the session's frozen pattern.

        Exactly one of ``A_new`` (full replacement CSR), ``data`` (new
        values in CSR order) or ``delta`` (additive ΔA values).  On a
        pattern match the session performs a **value-only refresh**: the
        fine values are re-lowered onto the frozen layouts, the Galerkin
        products re-run numerically through the already-selected NAP
        schedules, and smoother factors refreshed in place — compiled
        programs are reused verbatim.  When the config's
        :class:`~repro.amg.api.config.RefreshPolicy` says convergence has
        regressed past the post-setup baseline, the update escalates to a
        full node-aware re-setup instead.  Returns the action taken
        (``"refresh"`` | ``"resetup"``).  A changed sparsity pattern
        raises :class:`~repro.amg.api.config.PatternMismatch` — callers
        escalate explicitly (the service re-runs ``setup``)."""
        if self._fine is None:
            raise ValueError(
                "streaming updates need a session created by "
                "AMGSolver.setup; this solver wraps a bare hierarchy")
        if A_new is None:
            A_new = apply_update(self._fine, data=data, delta=delta)
        elif data is not None or delta is not None:
            raise ValueError("pass A_new or data=/delta=, not both")
        fp_pat = pattern_fingerprint(A_new)
        if fp_pat != self.pattern_fp:
            raise PatternMismatch(
                f"update pattern {fp_pat[:12]} does not match the session's "
                f"frozen pattern {self.pattern_fp[:12]}; a value-only "
                f"refresh is impossible — re-run setup(A_new) for "
                f"structural changes")
        regressed = (self.last_iterations is not None and
                     self.config.refresh.regressed(self.baseline_iterations,
                                                   self.last_iterations))
        if regressed or not self._can_refresh():
            action = "resetup"
            reason = "regression" if regressed else "evicted"
            self._resetup(A_new)
            self.baseline_iterations = None
            self.last_iterations = None
        else:
            action, reason = "refresh", "drift"
            self._refresh(A_new)
        self.last_update_reason = reason
        if self._store is not None:
            self._store.note_update(action, reason)
            self._rekey(A_new)
        return action

    def _rekey(self, A_new: CSR) -> None:
        """Move the store entry onto the updated value fingerprint, so a
        later ``setup(A_new)`` under the same config hits this session."""
        fp = matrix_fingerprint(A_new)
        new_key = (fp,) + tuple(self._store_key[1:])
        self._store.rekey(self._store_key, new_key)
        self._store_key = new_key
        self._fingerprint = fp

    def _can_refresh(self) -> bool:
        return True

    def _refresh(self, A_new: CSR) -> None:
        _hierarchy_refresh(self.hierarchy, A_new)
        self._fine = self.hierarchy.levels[0].A    # re-pointed by refresh

    def _resetup(self, A_new: CSR) -> None:
        self.hierarchy = _hierarchy_setup(A_new,
                                          **self.config.setup_kwargs())
        self._fine = self.hierarchy.levels[0].A


@register_backend("host")
class HostBoundSolver(BoundSolver):
    """Reference numpy backend; multi-RHS runs k independent column solves."""

    def staging_dtype(self) -> np.dtype:
        # the numpy reference always computes in float64 (CSR data is
        # float64) — staging lower would lose precision without saving a
        # conversion, so config.dtype only matters to device backends
        return np.dtype(np.float64)

    def _per_column(self, fn, b, x0):
        cols, xs = [], []
        for j in range(b.shape[1]):
            r = fn(b[:, j], None if x0 is None else x0[:, j])
            cols.append(r)
            xs.append(r.x)
        return MultiSolveResult(np.stack(xs, axis=1), cols)

    def _solve(self, b, *, tol=None, maxiter=None, x0=None):
        b = self._check_b(b)
        tol = self.config.tol if tol is None else tol
        maxiter = self.config.maxiter if maxiter is None else maxiter
        run = lambda bc, xc: host_solve(self.hierarchy, bc, tol=tol,
                                        maxiter=maxiter, opts=self.opts,
                                        x0=xc)
        if b.ndim == 2:
            return self._per_column(run, b, x0)
        return run(b, x0)

    def _pcg(self, b, *, tol=None, maxiter=None, x0=None):
        b = self._check_b(b)
        tol = self.config.tol if tol is None else tol
        maxiter = self.config.pcg_maxiter if maxiter is None else maxiter
        run = lambda bc, xc: host_pcg(self.hierarchy, bc, tol=tol,
                                      maxiter=maxiter, opts=self.opts, x0=xc)
        if b.ndim == 2:
            return self._per_column(run, b, x0)
        return run(b, x0)

    def vcycle(self, b, x0=None):
        b = self._check_b(b)
        if b.ndim == 2:
            x0c = (lambda j: None) if x0 is None else (lambda j: x0[:, j])
            return np.stack([host_vcycle(self.hierarchy, b[:, j], x0c(j),
                                         self.opts)
                             for j in range(b.shape[1])], axis=1)
        return host_vcycle(self.hierarchy, b, x0, self.opts)


@register_backend("dist")
class DistBoundSolver(BoundSolver):
    """Device-resident backend: lazily lowers the hierarchy onto the mesh
    ONCE and reuses the ``DistHierarchy`` (and its compiled programs, cached
    inside it per option set) for every subsequent call."""

    def __init__(self, config: AMGConfig, hierarchy: Hierarchy):
        super().__init__(config, hierarchy)
        self._dist = None

    @classmethod
    def from_hierarchy(cls, h, dist=None, opts=None):
        from ..dist_solve import _ensure_dist
        self = cls(AMGConfig(backend=cls.backend_name,
                             opts=opts or SolveOptions()), h)
        self._dist = _ensure_dist(h, dist)     # raises when dist is missing
        return self

    @classmethod
    def from_dist_setup(cls, config: AMGConfig, dh) -> "DistBoundSolver":
        """Bind a hierarchy that was **born partitioned** (the
        ``setup_backend="dist"`` path): there is no host ``Hierarchy``, only
        the already-lowered ``DistHierarchy``."""
        self = cls(config, None)
        self._dist = dh
        return self

    @property
    def n(self) -> int:
        if self.hierarchy is None:
            return self._dist.levels[0].A.row_part.n
        return self.A.nrows

    def staging_dtype(self) -> np.dtype:
        # an already-lowered hierarchy is the source of truth (the legacy
        # bind_hierarchy path carries a default config whose dtype may not
        # match the prebuilt lowering's)
        if self._dist is not None:
            import jax.numpy as jnp
            return np.dtype(np.float64 if self._dist.dtype == jnp.float64
                            else np.float32)
        return super().staging_dtype()

    @property
    def dist_hierarchy(self):
        """The lowered hierarchy; built on first access, then reused.

        The build goes through the per-hierarchy ``dist_cache``, so bound
        solvers that share a hierarchy (configs differing only in iteration
        defaults, say) also share one lowering.
        """
        if self._dist is None:
            from ..dist_solve import _ensure_dist
            self._dist = _ensure_dist(self.hierarchy,
                                      self.config.dist_build_kwargs())
        return self._dist

    def _solve(self, b, *, tol=None, maxiter=None, x0=None):
        from ..dist_solve import dist_solve
        b = self._check_b(b)
        tol = self.config.tol if tol is None else tol
        maxiter = self.config.maxiter if maxiter is None else maxiter
        return dist_solve(self.dist_hierarchy, b, tol=tol, maxiter=maxiter,
                          opts=self.opts, x0=x0)

    def _pcg(self, b, *, tol=None, maxiter=None, x0=None):
        from ..dist_solve import dist_pcg
        b = self._check_b(b)
        tol = self.config.tol if tol is None else tol
        maxiter = self.config.pcg_maxiter if maxiter is None else maxiter
        return dist_pcg(self.dist_hierarchy, b, tol=tol, maxiter=maxiter,
                        opts=self.opts, x0=x0)

    def vcycle(self, b, x0=None):
        from ..dist_solve import dist_vcycle
        if x0 is not None:
            raise ValueError("dist vcycle starts from x=0; x0= is not "
                             "supported on the dist backend")
        return dist_vcycle(self.dist_hierarchy, self._check_b(b), self.opts)

    # ---------------------------------------------------- streaming updates
    def _can_refresh(self) -> bool:
        # a dist-born session refreshes through its partitioned levels; if
        # they were evicted from the setup store, only a full re-setup can
        # honor the update
        return self.hierarchy is not None or self._plevels is not None

    def _refresh(self, A_new: CSR) -> None:
        if self.hierarchy is not None:
            # refreshes every lowering in the hierarchy's dist_cache; a
            # prebuilt lowering that bypassed the cache (unhashable build
            # kwargs) is refreshed explicitly
            _hierarchy_refresh(self.hierarchy, A_new)
            self._fine = self.hierarchy.levels[0].A
            cached = self.hierarchy.dist_cache.values()
            if self._dist is not None and \
                    all(dh is not self._dist for dh in cached):
                self._dist.refresh_values(self.hierarchy.levels)
            return
        from ..dist_setup import refresh_partitioned_values
        refresh_partitioned_values(self._plevels, A_new)
        if self._dist is not None:
            self._dist.refresh_values(self._plevels)
        # copy-on-write, same as the host path: never mutate the caller's A
        self._fine = CSR(self._fine.shape, self._fine.indptr,
                         self._fine.indices,
                         np.array(A_new.data, dtype=np.float64))

    def _resetup(self, A_new: CSR) -> None:
        if self.hierarchy is not None:
            super()._resetup(A_new)
            self._dist = None            # re-lowered lazily on next solve
            return
        from ...core import MACHINES
        from ..dist_setup import dist_setup_partitioned
        from ..dist_solve import DistHierarchy
        c = self.config
        plevels, records = dist_setup_partitioned(
            A_new, c.n_pods, c.lanes, params=MACHINES[c.machine],
            strategy=c.strategy, **c.setup_kwargs())
        bk = c.dist_build_kwargs()
        self._dist = DistHierarchy.from_partitioned(
            plevels, bk.pop("n_pods"), bk.pop("lanes"),
            setup_records=records, **bk)
        self._plevels = plevels
        self._fine = A_new


# --------------------------------------------------------------------------
# The session object + default stores
# --------------------------------------------------------------------------

SESSION_CACHE_SIZE = 16
# module-level defaults: independent AMGSolver callers share sessions, the
# way the old module-global OrderedDicts did — but these are SessionStores,
# so the same LRU behavior now comes with accounting, and services that
# want their own budget simply instantiate their own store.
_SESSIONS = SessionStore(LRUPolicy(SESSION_CACHE_SIZE))
# hierarchies keyed by (matrix fingerprint, setup kwargs) only, so configs
# that differ in solve/backend knobs share one setup (and, through the
# hierarchy's dist_cache, one lowering).  setup_backend="dist" entries hold
# a born-partitioned DistHierarchy instead of a host Hierarchy (keyed with
# the mesh/strategy/dtype knobs the lowering depends on).
_SETUPS = SessionStore(LRUPolicy(SESSION_CACHE_SIZE))


def clear_sessions() -> None:
    _SESSIONS.clear()
    _SETUPS.clear()


def session_count() -> int:
    return len(_SESSIONS)


class AMGSolver:
    """The session entrypoint: ``AMGSolver(config).setup(A)`` returns a
    :class:`BoundSolver` cached per (matrix fingerprint, config) — repeated
    setup of the same matrix under the same config is free, and every solve
    through the bound object reuses the lowered hierarchy and its compiled
    programs.  Configs that differ only in knobs irrelevant to the setup
    phase (tol/maxiter, backend, mesh, …) get distinct bound solvers that
    share ONE host hierarchy.

    ``store`` / ``setup_store`` override the module-level default
    :class:`SessionStore` s (a :class:`~repro.amg.api.service.AMGService`
    passes its own so eviction budgets and hit counters are
    service-scoped)."""

    def __init__(self, config: AMGConfig | None = None, *,
                 store: SessionStore | None = None,
                 setup_store: SessionStore | None = None, **overrides):
        if config is None:
            config = AMGConfig(**overrides)
        elif overrides:
            config = dataclasses.replace(config, **overrides)
        backend_class(config.backend)        # fail fast on unknown backend
        self.config = config
        self.store = store if store is not None else _SESSIONS
        self.setup_store = (setup_store if setup_store is not None
                            else _SETUPS)

    def setup(self, A: CSR, *, fingerprint: str | None = None) -> BoundSolver:
        """Bind ``A`` under this config (cached).  ``fingerprint=`` skips
        re-hashing when the caller already knows the matrix fingerprint
        (the service computes it once at registration)."""
        fp = fingerprint or matrix_fingerprint(A)
        key = (fp, self.config)
        bound = self.store.get(key)
        if bound is not None:
            return bound
        t0 = time.perf_counter()
        if self.config.setup_backend == "dist":
            bound = self._setup_dist(A, fp)
        else:
            skw = self.config.setup_kwargs()
            skey = (fp, tuple(sorted(skw.items())))
            h = self.setup_store.get(skey)
            if h is None:
                t1 = time.perf_counter()
                h = _hierarchy_setup(A, **skw)
                self.setup_store.put(skey, h,
                                     nbytes=session_nbytes(h),
                                     setup_cost=time.perf_counter() - t1)
            bound = backend_class(self.config.backend)(self.config, h)
        # streaming-session state: the canonical fine CSR (the hierarchy's
        # own level-0 object on host paths, so delta updates compose), the
        # frozen pattern fingerprint and the store linkage update() re-keys
        bound._fine = (bound.hierarchy.levels[0].A
                       if bound.hierarchy is not None else A)
        bound._fingerprint = fp
        bound.pattern_fp = pattern_fingerprint(A)
        bound._store = self.store
        bound._store_key = key
        # nbytes_fn: a dist session's device arrays are lowered lazily on
        # first solve, so resident bytes are re-measured at eviction time
        self.store.put(key, bound, nbytes=session_nbytes(bound),
                       setup_cost=time.perf_counter() - t0,
                       nbytes_fn=lambda: session_nbytes(bound))
        return bound

    def _setup_dist(self, A: CSR, fp: str) -> BoundSolver:
        """The setup_backend="dist" path: run the partitioned node-aware
        setup (NAP SpGEMM Galerkin products) and bind the resulting
        DistHierarchy.  Two cache tiers mirror the host path's setup/lower
        split: the partitioned blocks are keyed by the knobs the setup loop
        depends on (setup kwargs + mesh + strategy + machine), the lowered
        DistHierarchy additionally by the pure lowering knobs — so configs
        differing only in dtype/kernel/reduce knobs re-lower but never
        re-run the setup loop, and solve-knob-only changes share both."""
        c = self.config
        base = (fp, tuple(sorted(c.setup_kwargs().items())),
                c.n_pods, c.lanes, c.strategy, c.machine)
        pkey = base + ("dist_partitioned",)
        skey = base + ("dist_lowered", c.dtype, c.use_kernel, c.interpret,
                       c.reduce_strategy, c.overlap)
        dh = self.setup_store.get(skey)
        if dh is None:
            cached = self.setup_store.get(pkey)
            if cached is None:
                from ...core import MACHINES
                from ..dist_setup import dist_setup_partitioned
                t0 = time.perf_counter()
                plevels, records = dist_setup_partitioned(
                    A, c.n_pods, c.lanes, params=MACHINES[c.machine],
                    strategy=c.strategy, **c.setup_kwargs())
                self.setup_store.put(pkey, (plevels, records),
                                     setup_cost=time.perf_counter() - t0)
            else:
                plevels, records = cached
            from ..dist_solve import DistHierarchy
            bk = c.dist_build_kwargs()
            t0 = time.perf_counter()
            dh = DistHierarchy.from_partitioned(
                plevels, bk.pop("n_pods"), bk.pop("lanes"),
                setup_records=records, **bk)
            self.setup_store.put(skey, dh, nbytes=session_nbytes(dh),
                                 setup_cost=time.perf_counter() - t0)
        bound = backend_class(c.backend).from_dist_setup(c, dh)
        # partitioned blocks are the refresh target for streamed updates;
        # when they were evicted between setup and update, update()
        # escalates to a full re-setup instead
        part_cached = self.setup_store.get(pkey)
        if part_cached is not None:
            bound._plevels = part_cached[0]
        return bound
