"""Backend registry: how solver implementations plug into the session API.

A backend is a :class:`~repro.amg.api.sessions.BoundSolver` subclass
registered under a name; ``AMGConfig(backend=name)``, the free functions
``solve``/``pcg``/``vcycle`` and the serving surface
(:class:`~repro.amg.api.service.AMGService`) all resolve implementations
through this table, so new backends (an SA variant, say) plug in without
touching any call site.
"""
from __future__ import annotations

_BACKENDS: dict[str, type] = {}


def register_backend(name: str):
    """Class decorator: make a :class:`BoundSolver` subclass reachable as
    ``AMGConfig(backend=name)`` / ``solve(..., backend=name)``."""
    def deco(cls):
        cls.backend_name = name
        _BACKENDS[name] = cls
        return cls
    return deco


def available_backends() -> list[str]:
    return sorted(_BACKENDS)


def backend_class(name: str) -> type:
    try:
        return _BACKENDS[name]
    except KeyError:
        raise ValueError(f"unknown backend {name!r}; registered backends: "
                         f"{available_backends()}") from None


def bind_hierarchy(h, backend: str = "host", dist=None, opts=None):
    """Wrap an existing host hierarchy in the named backend's bound solver.

    This is what the free functions ``solve`` / ``pcg`` / ``vcycle`` call;
    ``dist=`` carries the legacy prebuilt-``DistHierarchy``-or-kwargs-dict
    argument (dict kwargs hit the per-hierarchy cache).
    """
    return backend_class(backend).from_hierarchy(h, dist=dist, opts=opts)
