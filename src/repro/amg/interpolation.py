"""Interpolation operators (Algorithm 1, ``interpolation``).

* :func:`direct_interpolation` — classical direct interpolation for CF
  splittings (used with PMIS/HMIS-style coarsening).
* :func:`tentative_prolongator` + :func:`jacobi_smooth_prolongator` — the
  smoothed-aggregation transfer: piecewise-constant tentative operator fit
  to the near-nullspace, then 1..k sweeps of weighted-Jacobi smoothing
  (Fig. 21 studies 1 vs 2 sweeps).
"""
from __future__ import annotations

import numpy as np

from .csr import CSR


def direct_interpolation(A: CSR, S: CSR, status: np.ndarray, *,
                         col_status: np.ndarray | None = None,
                         cmap: np.ndarray | None = None,
                         nc: int | None = None) -> CSR:
    """Classical direct interpolation.

    C-point rows are identity; F-point i interpolates from its strong
    C-neighbors j with  w_ij = -(Σ_{k≠i} a_ik / Σ_{j∈C_i^s} a_ij)·a_ij/a_ii.

    The keyword arguments support partitioned (row-block) callers, where row
    knowledge and column knowledge come from different exchanges: ``status``
    is trusted for the block's *rows* (C rows become identity rows), while
    ``col_status`` / ``cmap`` must be valid at every *column* referenced by
    ``S`` (local + halo) and ``nc`` is the global coarse size.  Defaults
    reproduce the serial single-block behavior exactly.
    """
    n = A.nrows
    is_c = status == 1
    col_c = is_c if col_status is None else col_status == 1
    if cmap is None:
        cmap = np.cumsum(col_c) - 1  # fine -> coarse index
    if nc is None:
        nc = int(col_c.sum())
    r = A.rows_expanded()

    # strong C columns per row (pattern from S, values from A)
    srow = S.rows_expanded()
    strongC = col_c[S.indices]
    # A values at the strong-C positions: build lookup from (row,col) of A
    # via merge: both are row-sorted
    Akey = r * n + A.indices
    Skey = srow[strongC] * n + S.indices[strongC]
    pos = np.searchsorted(Akey, Skey)
    pos = np.clip(pos, 0, Akey.size - 1)
    valid = Akey[pos] == Skey
    a_sc = np.where(valid, A.data[pos], 0.0)

    diag = A.diagonal()
    offsum = np.zeros(n)
    np.add.at(offsum, r, np.where(r != A.indices, A.data, 0.0))
    csum = np.zeros(n)
    np.add.at(csum, srow[strongC], a_sc)

    rows_f = srow[strongC]
    f_ok = (status[rows_f] == -1) & (np.abs(csum[rows_f]) > 1e-300)
    alpha = np.where(np.abs(csum[rows_f]) > 1e-300,
                     offsum[rows_f] / np.where(csum[rows_f] == 0, 1, csum[rows_f]), 0.0)
    w = -alpha * a_sc / diag[rows_f]
    prow = rows_f[f_ok]
    pcol = cmap[S.indices[strongC][f_ok]]
    pval = w[f_ok]
    # C-point identity rows
    crow = np.flatnonzero(is_c)
    return CSR.from_coo(
        np.concatenate([prow, crow]),
        np.concatenate([pcol, cmap[crow]]),
        np.concatenate([pval, np.ones(crow.size)]),
        (n, nc),
    )


def tentative_prolongator(agg: np.ndarray, B: np.ndarray | None = None) -> CSR:
    """Piecewise-constant tentative P (near-nullspace B=1 column-normalized)."""
    n = agg.size
    nc = int(agg.max()) + 1
    vals = np.ones(n) if B is None else np.asarray(B, dtype=np.float64)
    norms = np.sqrt(np.bincount(agg, weights=vals * vals, minlength=nc))
    norms[norms == 0] = 1.0
    return CSR.from_coo(np.arange(n), agg, vals / norms[agg], (n, nc))


def estimate_rho_DinvA(A: CSR, iters: int = 10, seed: int = 0) -> float:
    """Power iteration estimate of ρ(D⁻¹A)."""
    rng = np.random.default_rng(seed)
    dinv = 1.0 / np.where(A.diagonal() == 0, 1.0, A.diagonal())
    x = rng.standard_normal(A.nrows)
    lam = 1.0
    for _ in range(iters):
        y = dinv * A.matvec(x)
        lam = float(np.linalg.norm(y))
        if lam == 0:
            return 1.0
        x = y / lam
    return lam


def jacobi_smooth_prolongator(A: CSR, T: CSR, omega: float = 4.0 / 3.0,
                              sweeps: int = 1, rho: float | None = None) -> CSR:
    """P = (I - ω/ρ(D⁻¹A) · D⁻¹A)^sweeps · T."""
    rho = rho or estimate_rho_DinvA(A)
    dinv = 1.0 / np.where(A.diagonal() == 0, 1.0, A.diagonal())
    DA = A.scale_rows(dinv * (omega / rho))
    P = T
    for _ in range(sweeps):
        P = P.add(DA.spgemm(P), alpha=1.0, beta=-1.0)
    return P
