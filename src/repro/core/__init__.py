"""Core of the paper's contribution: multi-step node-aware communication.

- :mod:`repro.core.topology`      — SMP-node / TPU-pod hierarchical topology
- :mod:`repro.core.comm_graph`    — who needs which values from whom
- :mod:`repro.core.schedules`     — standard / NAP-2 / NAP-3 schedules (§3)
- :mod:`repro.core.perf_model`    — max-rate models, Eqs. (1)–(6) (§3.3)
- :mod:`repro.core.selector`      — per-operation strategy selection (§4)
- :mod:`repro.core.simulator`     — rank-faithful host execution (tests/bench)
- :mod:`repro.core.nap_collectives` — shard_map TPU collectives (flat/NAP)
"""
from .comm_graph import CommGraph, VECTOR_BYTES
from .perf_model import BLUE_WATERS, MACHINES, QUARTZ, TPU_V5E, MachineParams
from .schedules import STRATEGIES, Schedule, ScheduleStats, build
from .selector import Selection, select
from .topology import Partition, Topology

__all__ = [
    "CommGraph", "VECTOR_BYTES", "BLUE_WATERS", "QUARTZ", "TPU_V5E", "MACHINES",
    "MachineParams", "STRATEGIES", "Schedule", "ScheduleStats", "build",
    "Selection", "select", "Partition", "Topology",
]
