"""Rank-faithful execution of communication schedules on one host.

Used by tests (exactly-once delivery, value correctness, stats cross-checks)
and by benchmarks (measured message counts/bytes + modeled times).  Payloads
are entries of a global value array; intermediate ranks (NAP gather/redist
hops) forward values they do not themselves need.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict

import numpy as np

from .comm_graph import CommGraph
from .schedules import Schedule


@dataclasses.dataclass
class SimResult:
    received: list[dict[int, float]]       # per-rank {global index: value}
    delivery_count: dict[tuple[int, int], int]  # (rank, index) -> #final deliveries
    inter_msgs: int
    inter_bytes: float
    intra_msgs: int
    intra_bytes: float


def execute(schedule: Schedule, x: np.ndarray) -> SimResult:
    g: CommGraph = schedule.graph
    topo = g.topo
    part = g.partition
    # store[p]: values rank p can currently serve (owned + received so far)
    store: list[dict[int, float]] = []
    for p in range(topo.n_procs):
        lo, hi = part.local_range(p)
        store.append({int(i): float(x[i]) for i in range(lo, hi)})
    received: list[dict[int, float]] = [dict() for _ in range(topo.n_procs)]
    need_sets = [set(map(int, g.need[q])) for q in range(topo.n_procs)]
    deliveries: dict[tuple[int, int], int] = defaultdict(int)
    inter_msgs = intra_msgs = 0
    inter_bytes = intra_bytes = 0.0

    for phase in schedule.phases:
        # messages within a phase are concurrent: read from pre-phase stores
        staged: list[tuple[int, dict[int, float]]] = []
        for m in phase.messages:
            src_store = store[m.src]
            payload = {}
            for i in m.indices:
                i = int(i)
                if i not in src_store:
                    raise AssertionError(
                        f"rank {m.src} asked to send index {i} it does not hold "
                        f"(phase {phase.kind}, strategy {schedule.strategy})")
                payload[i] = src_store[i]
            staged.append((m.dst, payload))
            b = g.bytes_of(m.indices)
            if topo.on_same_node(m.src, m.dst):
                intra_msgs += 1
                intra_bytes += b
            else:
                inter_msgs += 1
                inter_bytes += b
        for dst, payload in staged:
            store[dst].update(payload)
            if phase.kind == "gather":
                # pure forwarding hop: the aggregation process receives its
                # own needs via the concurrent "local" phase, not here.
                continue
            for i, v in payload.items():
                if i in need_sets[dst]:
                    received[dst][i] = v
                    deliveries[(dst, i)] += 1
    return SimResult(
        received=received,
        delivery_count=dict(deliveries),
        inter_msgs=inter_msgs,
        inter_bytes=inter_bytes,
        intra_msgs=intra_msgs,
        intra_bytes=intra_bytes,
    )


def verify(schedule: Schedule, x: np.ndarray) -> SimResult:
    """Execute and assert the schedule is complete, correct, exactly-once."""
    g = schedule.graph
    res = execute(schedule, x)
    for q in range(g.topo.n_procs):
        for i in map(int, g.need[q]):
            cnt = res.delivery_count.get((q, i), 0)
            if cnt != 1:
                raise AssertionError(
                    f"{schedule.strategy}: rank {q} index {i} delivered {cnt}x")
            if res.received[q][i] != float(x[i]):
                raise AssertionError(
                    f"{schedule.strategy}: rank {q} index {i} wrong value")
    return res
