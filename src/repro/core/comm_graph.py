"""Communication graphs: which global indices each rank must receive.

A :class:`CommGraph` is the abstract object the paper's three strategies
schedule.  For *vector* communication (SpMV), index ``i`` is a vector entry
(8 bytes).  For *matrix* communication (SpGEMM ``A·B``), index ``i`` is a row
of ``B`` and weighs ``12·nnz(row) + 16`` bytes (values + column indices + row
header), matching the paper's observation that matrix comm "retains the same
communication pattern as vectors, but requires entire rows".
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .topology import Partition, Topology

VECTOR_BYTES = 8.0  # one fp64 value per index


@dataclasses.dataclass
class CommGraph:
    """``need[q]`` = sorted unique global indices rank ``q`` must receive.

    ``weights[i]`` = bytes transferred when index ``i`` is communicated once.
    Owned indices are never in ``need`` (no self-communication).
    """

    partition: Partition
    need: list[np.ndarray]
    weights: np.ndarray | None = None  # (n,) bytes per index; None -> VECTOR_BYTES

    def __post_init__(self) -> None:
        if len(self.need) != self.partition.topo.n_procs:
            raise ValueError("need must have one entry per rank")
        for q, idx in enumerate(self.need):
            lo, hi = self.partition.local_range(q)
            if idx.size and ((idx >= lo) & (idx < hi)).any():
                raise ValueError(f"rank {q} 'needs' indices it owns")

    @property
    def topo(self) -> Topology:
        return self.partition.topo

    def bytes_of(self, indices: np.ndarray) -> float:
        if self.weights is None:
            return VECTOR_BYTES * float(indices.size)
        return float(self.weights[indices].sum())

    # ------------------------------------------------------------------ build
    @staticmethod
    def from_offproc_columns(
        partition: Partition,
        offproc_cols: list[np.ndarray],
        weights: np.ndarray | None = None,
    ) -> "CommGraph":
        """Vector/matrix comm pattern from each rank's off-process columns."""
        need = [np.unique(np.asarray(c, dtype=np.int64)) for c in offproc_cols]
        return CommGraph(partition=partition, need=need, weights=weights)

    # ------------------------------------------------------- derived groupings
    def need_by_owner(self, q: int) -> dict[int, np.ndarray]:
        """Split rank ``q``'s needs by owning rank."""
        idx = self.need[q]
        if idx.size == 0:
            return {}
        owners = self.partition.owner_of_rows(idx)
        out: dict[int, np.ndarray] = {}
        for p in np.unique(owners):
            out[int(p)] = idx[owners == p]
        return out

    def recv_pairs(self) -> list[tuple[int, int, np.ndarray]]:
        """All (owner p, receiver q, indices) point-to-point requirements."""
        out = []
        for q in range(self.topo.n_procs):
            for p, idx in self.need_by_owner(q).items():
                out.append((p, q, idx))
        return out
