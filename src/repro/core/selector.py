"""Strategy selection (paper §4, first paragraph).

"Optimal strategies for vector and matrix communication are determined
during the formation of each matrix in the AMG hierarchy.  After a matrix is
created, the performance models in Equations 4, 5, and 6 are calculated and
the strategy with minimum modeled cost is chosen."
"""
from __future__ import annotations

import dataclasses

from .comm_graph import CommGraph
from .perf_model import MachineParams, model_time
from .schedules import STRATEGIES, Schedule, ScheduleStats, build


@dataclasses.dataclass
class Selection:
    strategy: str
    schedule: Schedule
    stats: dict[str, ScheduleStats]     # per strategy
    times: dict[str, float]            # modeled seconds per strategy

    @property
    def modeled_time(self) -> float:
        return self.times[self.strategy]


def select(graph: CommGraph, params: MachineParams,
           strategies: tuple[str, ...] = STRATEGIES) -> Selection:
    schedules = {s: build(s, graph) for s in strategies}
    times = {s: model_time(sch, params) for s, sch in schedules.items()}
    stats = {s: ScheduleStats.of(sch) for s, sch in schedules.items()}
    best = min(times, key=times.get)
    return Selection(strategy=best, schedule=schedules[best], stats=stats, times=times)
