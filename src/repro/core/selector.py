"""Strategy selection (paper §4, first paragraph).

"Optimal strategies for vector and matrix communication are determined
during the formation of each matrix in the AMG hierarchy.  After a matrix is
created, the performance models in Equations 4, 5, and 6 are calculated and
the strategy with minimum modeled cost is chosen."
"""
from __future__ import annotations

import dataclasses

from .comm_graph import CommGraph
from .perf_model import MachineParams, model_time, overlap_time
from .schedules import STRATEGIES, Schedule, ScheduleStats, build


@dataclasses.dataclass
class Selection:
    strategy: str
    schedule: Schedule
    stats: dict[str, ScheduleStats]     # per strategy
    times: dict[str, float]            # modeled phase seconds per strategy
    # raw communication seconds (the pre-overlap model_time); equal to
    # ``times`` when no compute split was supplied
    comm_times: dict[str, float] = dataclasses.field(default_factory=dict)
    compute: tuple[float, float] = (0.0, 0.0)    # (t_on, t_off) seconds

    @property
    def modeled_time(self) -> float:
        return self.times[self.strategy]


def select(graph: CommGraph, params: MachineParams,
           strategies: tuple[str, ...] = STRATEGIES,
           compute: tuple[float, float] = (0.0, 0.0)) -> Selection:
    """Pick the minimum-cost strategy for ``graph`` on ``params``.

    ``compute=(t_on, t_off)`` is the operator's split local-product cost:
    the phase cost becomes ``max(T_comm, T_on) + T_off`` — what the
    overlapped apply actually pays — so a slower-but-hideable exchange can
    beat a nominally cheaper one.  The default (0, 0) reduces exactly to
    the serial comm-only ranking.
    """
    schedules = {s: build(s, graph) for s in strategies}
    comm_times = {s: model_time(sch, params) for s, sch in schedules.items()}
    t_on, t_off = compute
    times = {s: overlap_time(t, t_on, t_off) for s, t in comm_times.items()}
    stats = {s: ScheduleStats.of(sch) for s, sch in schedules.items()}
    best = min(times, key=times.get)
    return Selection(strategy=best, schedule=schedules[best], stats=stats,
                     times=times, comm_times=comm_times, compute=compute)
