"""Max-rate performance models (paper §3.3, Eqs. 1–6).

The paper measures latency/bandwidth separately for the short, eager and
rendezvous MPI protocols and models

  inter-node (Eq. 2):  T = α·n + max(s_node / R_N, s_proc / R_b)
  intra-node (Eq. 3):  T = α_ℓ·n + s / R_bℓ

and per-strategy totals (Eqs. 4–6).  Two evaluation modes are provided:

* :func:`model_time` — message-list evaluation: every message is bucketed
  into its protocol (paper: "latency and bandwidth terms are measured and
  applied separately to short, eager, and rendezvous protocols").  This is
  what the selector uses.
* :func:`model_time_closed` — the literal closed forms (4)–(6), used by the
  model-validation benchmark.

Parameter sets: ``BLUE_WATERS`` (Cray XE6, 16 ppn — values consistent with
the Nodecomm/max-rate measurements in [Gropp, Olson, Samfass 2016] and
[Bienz, Gropp, Olson 2018]) and ``TPU_V5E`` (this framework's target: "node"
= ICI pod, "network" = inter-pod DCI; constants are modeled, documented in
DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .schedules import Schedule, ScheduleStats


@dataclasses.dataclass(frozen=True)
class ProtocolParams:
    alpha: float  # seconds per message
    Rb: float     # bytes / second sustained by one process


@dataclasses.dataclass(frozen=True)
class MachineParams:
    name: str
    ppn: int
    # protocol cutoffs (bytes)
    short_cutoff: float
    eager_cutoff: float
    # per-protocol (short, eager, rend) parameters
    inter: tuple[ProtocolParams, ProtocolParams, ProtocolParams]
    intra: tuple[ProtocolParams, ProtocolParams, ProtocolParams]
    intra_socket: tuple[ProtocolParams, ProtocolParams, ProtocolParams]
    RN: float     # bytes / second a NID injects into the network
    # sustained local SpMV flop rate per process (flop/s).  0 means "not
    # measured": overlap-aware phase costs degrade to the pure comm time, so
    # every documented machine above stays selection-compatible with the
    # pre-overlap models.
    Rf: float = 0.0

    def proto(self, nbytes: float) -> int:
        if nbytes < self.short_cutoff:
            return 0
        if nbytes < self.eager_cutoff:
            return 1
        return 2

    def p_inter(self, nbytes: float) -> ProtocolParams:
        return self.inter[self.proto(nbytes)]

    def p_intra(self, nbytes: float) -> ProtocolParams:
        return self.intra[self.proto(nbytes)]

    @classmethod
    def from_measurements(cls, name: str, ppn: int, *,
                          inter: list[tuple[float, float]],
                          intra: list[tuple[float, float]],
                          intra_socket: list[tuple[float, float]] | None = None,
                          Rf: float = 0.0, RN: float | None = None,
                          short_cutoff: float = 4096,
                          eager_cutoff: float = 131072) -> "MachineParams":
        """Calibrate a parameter set from measured ``(bytes, seconds)``
        ping-pong samples (ROADMAP "measured machine models", first slice).

        Each tier's samples are fit to the postal model t = α + n/R_b by
        linear least squares; one fitted :class:`ProtocolParams` fills all
        three protocol slots (XLA collectives have no MPI-style protocol
        switch — the cutoffs are kept only so :meth:`proto` stays total).
        ``RN`` defaults to the whole node injecting at once (ppn × the
        fitted inter R_b); ``Rf`` is the measured local SpMV flop rate.
        """
        def fit(samples) -> ProtocolParams:
            s = np.asarray(samples, dtype=np.float64)
            if s.ndim != 2 or s.shape[0] < 2:
                raise ValueError(
                    f"need >=2 (bytes, seconds) samples per tier, got {s!r}")
            A = np.stack([np.ones(s.shape[0]), s[:, 0]], axis=1)
            alpha, inv_rb = np.linalg.lstsq(A, s[:, 1], rcond=None)[0]
            # floors keep a noisy fit physical: latency never negative,
            # bandwidth finite and positive
            return ProtocolParams(alpha=float(max(alpha, 1e-9)),
                                  Rb=float(1.0 / max(inv_rb, 1e-15)))

        p_inter = fit(inter)
        p_intra = fit(intra)
        p_sock = fit(intra_socket) if intra_socket is not None else p_intra
        return cls(name=name, ppn=ppn, short_cutoff=short_cutoff,
                   eager_cutoff=eager_cutoff,
                   inter=(p_inter,) * 3, intra=(p_intra,) * 3,
                   intra_socket=(p_sock,) * 3,
                   RN=float(RN) if RN is not None else ppn * p_inter.Rb,
                   Rf=float(Rf))


# --- Blue Waters (Cray XE6, Gemini).  Measured-order-of-magnitude constants:
#     inter-node short latency ~2 µs, rendezvous ~4 µs, per-process stream
#     ~1 GB/s, NID injection ~4.7 GB/s; on-node copies ~0.6–0.9 µs latency at
#     ~3–5 GB/s.  (Consistent with Fig. 8/9 of the paper.)
BLUE_WATERS = MachineParams(
    name="blue_waters",
    ppn=16,
    short_cutoff=512,
    eager_cutoff=8192,
    inter=(
        ProtocolParams(alpha=2.0e-6, Rb=5.0e8),
        ProtocolParams(alpha=3.0e-6, Rb=8.0e8),
        ProtocolParams(alpha=4.5e-6, Rb=1.0e9),
    ),
    intra=(
        ProtocolParams(alpha=9.0e-7, Rb=1.5e9),
        ProtocolParams(alpha=1.0e-6, Rb=2.5e9),
        ProtocolParams(alpha=1.4e-6, Rb=3.5e9),
    ),
    intra_socket=(
        ProtocolParams(alpha=4.0e-7, Rb=2.5e9),
        ProtocolParams(alpha=5.0e-7, Rb=4.0e9),
        ProtocolParams(alpha=7.0e-7, Rb=5.5e9),
    ),
    RN=4.7e9,
)

# --- Quartz (Intel Xeon E5, Omni-Path, 32 ppn) — for the Fig. 19 benchmark.
QUARTZ = MachineParams(
    name="quartz",
    ppn=32,
    short_cutoff=512,
    eager_cutoff=16384,
    inter=(
        ProtocolParams(alpha=1.1e-6, Rb=1.5e9),
        ProtocolParams(alpha=1.8e-6, Rb=2.5e9),
        ProtocolParams(alpha=3.0e-6, Rb=3.0e9),
    ),
    intra=(
        ProtocolParams(alpha=5.0e-7, Rb=4.0e9),
        ProtocolParams(alpha=6.0e-7, Rb=6.0e9),
        ProtocolParams(alpha=9.0e-7, Rb=8.0e9),
    ),
    intra_socket=(
        ProtocolParams(alpha=2.5e-7, Rb=6.0e9),
        ProtocolParams(alpha=3.5e-7, Rb=9.0e9),
        ProtocolParams(alpha=5.0e-7, Rb=1.2e10),
    ),
    RN=1.2e10,
)

# --- TPU v5e mapping: "process"=chip, "node"=256-chip ICI pod, network=DCI.
#     intra  = ICI collectives inside the pod (per-chip aggregate ~1.8e11 B/s,
#              ~1 µs per hop); inter = pod-crossing transfers (per-chip share
#              ~6.4e9 B/s, pod egress aggregate ~8.2e11 B/s, ~5 µs launch).
TPU_V5E = MachineParams(
    name="tpu_v5e",
    ppn=256,
    short_cutoff=4096,
    eager_cutoff=131072,
    inter=(
        ProtocolParams(alpha=5.0e-6, Rb=6.4e9),
        ProtocolParams(alpha=5.0e-6, Rb=6.4e9),
        ProtocolParams(alpha=5.0e-6, Rb=6.4e9),
    ),
    intra=(
        ProtocolParams(alpha=1.0e-6, Rb=1.8e11),
        ProtocolParams(alpha=1.0e-6, Rb=1.8e11),
        ProtocolParams(alpha=1.0e-6, Rb=1.8e11),
    ),
    intra_socket=(
        ProtocolParams(alpha=1.0e-6, Rb=1.8e11),
        ProtocolParams(alpha=1.0e-6, Rb=1.8e11),
        ProtocolParams(alpha=1.0e-6, Rb=1.8e11),
    ),
    RN=8.2e11,
)

MACHINES = {m.name: m for m in (BLUE_WATERS, QUARTZ, TPU_V5E)}


def register_machine(params: MachineParams) -> MachineParams:
    """Make a (typically measured) parameter set addressable by name — e.g.
    ``AMGConfig(machine=...)`` resolves through :data:`MACHINES`."""
    MACHINES[params.name] = params
    return params


# ------------------------------------------------------------- overlap costs
def spmv_compute_times(params: MachineParams, on_nnz: int,
                       off_nnz: int) -> tuple[float, float]:
    """(t_on, t_off) seconds for the split local products of one SpMV
    (2 flops per stored nonzero, worst device).  (0, 0) when the machine has
    no measured flop rate — overlap-unaware selection."""
    if params.Rf <= 0:
        return 0.0, 0.0
    return 2.0 * on_nnz / params.Rf, 2.0 * off_nnz / params.Rf


def overlap_time(t_comm: float, t_on: float, t_off: float) -> float:
    """Overlap-aware phase cost: the exchange hides behind the on-process
    product, the off-process product lands after — max(T_comm, T_on) + T_off
    instead of the serial sum of phases."""
    return max(t_comm, t_on) + t_off


def overlap_efficiency(t_comm: float, t_on: float, t_off: float) -> float:
    """Fraction of the serial phase cost the overlap hides (0 when the
    machine is overlap-unaware or the phase is free)."""
    serial = t_comm + t_on + t_off
    if serial <= 0.0:
        return 0.0
    return 1.0 - overlap_time(t_comm, t_on, t_off) / serial


# ------------------------------------------------------------------ Fig. 8/9 helpers
def single_message_time(params: MachineParams, nbytes: float, location: str) -> float:
    """Postal-model cost of one message (Fig. 8 curves)."""
    tiers = {
        "socket": params.intra_socket,
        "node": params.intra,
        "network": params.inter,
    }
    p = tiers[location][params.proto(nbytes)]
    return p.alpha + nbytes / p.Rb


def maxrate_internode_time(params: MachineParams, total_bytes: float, active: int) -> float:
    """Eq. (1) with ``active`` processes sharing one inter-node transfer
    (Fig. 9: cost falls as data is spread over more processes, floored by R_N)."""
    s_proc = total_bytes / max(active, 1)
    p = params.p_inter(s_proc)
    return p.alpha + max(total_bytes / params.RN, s_proc / p.Rb)


# ------------------------------------------------------------------ schedule models
def model_time(schedule: Schedule, params: MachineParams) -> float:
    """Protocol-bucketed max-rate evaluation of a concrete schedule."""
    g = schedule.graph
    topo = g.topo
    P, N = topo.n_procs, topo.n_nodes
    lat_p = np.zeros(P)        # Σ α over inter-node messages, per src process
    bw_p = np.zeros(P)         # Σ bytes/R_b over inter-node messages, per src
    inj_n = np.zeros(N)        # bytes injected per node
    lat_intra = np.zeros(P)
    bw_intra = np.zeros(P)
    for kind, msg in schedule.all_messages():
        b = g.bytes_of(msg.indices)
        sn, dn = topo.node_of(msg.src), topo.node_of(msg.dst)
        if sn != dn:
            pp = params.p_inter(b)
            lat_p[msg.src] += pp.alpha
            bw_p[msg.src] += b / pp.Rb
            inj_n[sn] += b
        elif kind in ("gather", "redist"):
            pp = params.p_intra(b)
            lat_intra[msg.src] += pp.alpha
            bw_intra[msg.src] += b / pp.Rb
    t_inter = lat_p.max(initial=0.0) + max(inj_n.max(initial=0.0) / params.RN,
                                           bw_p.max(initial=0.0))
    t_intra = lat_intra.max(initial=0.0) + bw_intra.max(initial=0.0)
    return float(t_inter + t_intra)


def model_time_closed(stats: ScheduleStats, params: MachineParams) -> float:
    """Literal Eqs. (4)–(6) from §3.3 (single-protocol, chosen by mean size)."""
    ppn = params.ppn
    mean = stats.inter_bytes_total / max(stats.inter_msg_count, 1)
    pi = params.p_inter(mean)
    pl = params.p_intra(mean)
    bw = max(stats.s_node / params.RN, stats.s_proc / pi.Rb)
    if stats.strategy == "standard":                                   # Eq. (4)
        return pi.alpha * stats.n_proc + bw
    if stats.strategy == "nap2":                                       # Eq. (5)
        return (pi.alpha * stats.n_proc2node + bw
                + pl.alpha * (ppn - 1) + stats.s_proc / pl.Rb)
    if stats.strategy == "nap3":                                       # Eq. (6)
        bw3 = max(stats.s_node / params.RN, stats.s_node2node / pi.Rb)
        return (pi.alpha * stats.n_node2node / ppn + bw3
                + 2.0 * (pl.alpha * (ppn - 1) + stats.s_node2node / pl.Rb))
    raise ValueError(stats.strategy)
