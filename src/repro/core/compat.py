"""JAX version compatibility shims.

The repo targets the modern public API (``jax.shard_map`` with ``check_vma``),
but the container may carry an older JAX where ``shard_map`` still lives in
``jax.experimental.shard_map`` and the replication-check kwarg is named
``check_rep``.  Route every shard_map construction through :func:`shard_map`
so call sites stay on the new spelling.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):                       # jax >= 0.6
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
else:                                               # 0.4.x fallback
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` on any supported JAX version (``check_vma`` spelling)."""
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_CHECK_KW: check_vma})


def axis_size(axis) -> int:
    """Size of a named mesh axis inside a shard_map body."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis)
    # psum of the literal 1 over a named axis folds to the static axis size
    # at trace time — no collective is lowered, so it is exempt from the
    # raw-collective rule
    return jax.lax.psum(1, axis)  # comm-audit: allow axis-size-fold
