"""Node-aware collectives for hierarchical TPU meshes (the paper's NAP-2 /
NAP-3, recast as axis-decomposed XLA collectives — DESIGN.md §2).

"slow" axis = the expensive domain (inter-pod DCI); "fast" axis = the cheap
domain (intra-pod ICI).  All functions are written for use *inside*
``jax.shard_map`` bodies (they operate on per-device shards and named axes).

* :func:`hier_psum`       — NAP-3 all-reduce: reduce-scatter(fast) →
  psum(slow) → all-gather(fast).  Inter-pod bytes drop from s to s/|fast|.
* :func:`hier_all_gather` — all-gather(fast) then all-gather(slow): one large
  slow-axis transfer instead of |mesh| small ones (α·n reduction).
* :func:`hier_all_to_all` — 2-hop all-to-all: regroup(fast) → a2a(slow) →
  a2a(fast); slow axis carries each byte once, aggregated per pod pair.
* :class:`HaloPlan` / :func:`halo_exchange` — the paper's SpMV vector
  communication with selectable strategy (standard / nap2 / nap3), built
  host-side from a :class:`~repro.core.comm_graph.CommGraph` exactly the way
  an MPI AMG code builds its communicators, then executed as static-shape
  collectives.
* :class:`MatrixHaloPlan` / :func:`matrix_halo_exchange` — the paper's
  *matrix* communication (setup-phase SpGEMMs): whole CSR rows of B move
  under the same §3 schedules.  Rows are ragged and the setup phase runs
  once per hierarchy build, so the exchange executes host-side and
  rank-faithfully (phase by phase, message by message) rather than as
  static-shape device collectives.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from .comm_graph import CommGraph
from .compat import axis_size as _axis_size
from .schedules import Schedule, build as build_schedule

# --------------------------------------------------------------------------
# Expected-primitive signatures (the static-analysis contract)
# --------------------------------------------------------------------------
# Ordered canonical collective-primitive names each strategy lowers to, in
# trace order.  :mod:`repro.analysis.comm_audit` walks the jaxpr of every
# compiled program and checks the collectives it finds against these tables
# — change a lowering in this module and the auditor fails until the
# matching signature is updated, which is the point: the schedule the §4
# model *selected* and the schedule the program *contains* can never
# silently diverge.  "psum_scatter" is the canonical name for the jaxpr's
# ``reduce_scatter`` primitive (see repro.analysis.jaxpr_walk.CANONICAL).

# halo_exchange: per executed exchange (a plan with total_halo == 0 skips
# the exchange entirely — see halo_signature)
HALO_SIGNATURES: dict[str, tuple[str, ...]] = {
    "standard": ("all_to_all", "all_to_all"),
    "nap2": ("all_to_all", "all_gather"),
    "nap3": ("all_gather", "all_to_all", "all_gather"),
}
# hier_psum: per all-reduce (the solver's dots and norms)
REDUCE_SIGNATURES: dict[str, tuple[str, ...]] = {
    "flat": ("psum",),
    "nap3": ("psum_scatter", "psum", "all_gather"),
}
# hier_all_gather: per gather (the coarsest-level direct solve)
GATHER_SIGNATURES: dict[str, tuple[str, ...]] = {
    "flat": ("all_gather",),
    "nap3": ("all_gather", "all_gather"),
}
# hier_all_to_all: per shuffle (the MoE dispatch consumer)
ALL_TO_ALL_SIGNATURES: dict[str, tuple[str, ...]] = {
    "flat": ("all_to_all",),
    "nap3": ("all_to_all", "all_to_all"),
}


def halo_signature(plan: "HaloPlan") -> tuple[str, ...]:
    """Collectives ONE :func:`halo_exchange` under ``plan`` must lower to —
    empty when the plan moves nothing (``total_halo == 0``: the apply skips
    the exchange and the program must contain no collective for it)."""
    if plan.total_halo == 0:
        return ()
    return HALO_SIGNATURES[plan.strategy]


def reduce_signature(strategy: str) -> tuple[str, ...]:
    """Collectives one :func:`hier_psum` call with ``strategy`` lowers to."""
    return REDUCE_SIGNATURES[strategy]


def gather_signature(strategy: str = "nap3") -> tuple[str, ...]:
    """Collectives one :func:`hier_all_gather` call lowers to."""
    return GATHER_SIGNATURES[strategy]


# --------------------------------------------------------------------------
# Generic hierarchical collectives (LM training / MoE consumers)
# --------------------------------------------------------------------------


def hier_psum(x: jnp.ndarray, slow_axis: str, fast_axis: str,
              strategy: str = "nap3") -> jnp.ndarray:
    """All-reduce over (slow × fast).  ``nap3`` = RS(fast) → AR(slow) →
    AG(fast): the slow axis carries 1/|fast| of the bytes (paper Fig. 12)."""
    if strategy == "flat":
        return jax.lax.psum(x, (slow_axis, fast_axis))
    if strategy != "nap3":
        raise ValueError(f"hier_psum: unknown strategy {strategy!r}")
    fast = _axis_size(fast_axis)
    shape = x.shape
    flat = x.reshape(-1)
    pad = (-flat.size) % fast
    if pad:
        flat = jnp.pad(flat, (0, pad))
    # 1) gather step: reduce-scatter inside the pod (cheap ICI)
    piece = jax.lax.psum_scatter(flat, fast_axis, scatter_dimension=0, tiled=True)
    # 2) single aggregated inter-pod reduction (expensive axis, 1/|fast| bytes)
    piece = jax.lax.psum(piece, slow_axis)
    # 3) redistribute inside the pod
    full = jax.lax.all_gather(piece, fast_axis, axis=0, tiled=True)
    if pad:
        full = full[:-pad]
    return full.reshape(shape)


def hier_all_gather(x: jnp.ndarray, slow_axis: str, fast_axis: str,
                    strategy: str = "nap3", axis: int = 0) -> jnp.ndarray:
    """All-gather over (slow × fast) with pod-major result layout."""
    if strategy == "flat":
        g = jax.lax.all_gather(x, (slow_axis, fast_axis), axis=axis, tiled=True)
        return g
    # gather the pod's shard first (cheap), then one aggregated slow transfer
    pod = jax.lax.all_gather(x, fast_axis, axis=axis, tiled=True)
    return jax.lax.all_gather(pod, slow_axis, axis=axis, tiled=True)


def hier_all_to_all(x: jnp.ndarray, slow_axis: str, fast_axis: str,
                    strategy: str = "nap3") -> jnp.ndarray:
    """All-to-all over the combined (slow × fast) device axis.

    ``x``: [n_slow * n_fast, ...] — chunk ``d`` goes to combined device ``d``
    (slow-major order).  Returns the received [n_slow * n_fast, ...].

    ``nap3`` routes pod-crossing chunks as ONE aggregated message per pod
    pair (split over lanes), exactly the paper's three-step scheme:
    a2a(fast) regroup → a2a(slow) inter-pod → a2a(fast) redistribute.
    """
    n_slow, n_fast = _axis_size(slow_axis), _axis_size(fast_axis)
    total = n_slow * n_fast
    assert x.shape[0] == total, (x.shape, total)
    if strategy == "flat":
        # one-hop: direct chunks to every device (paper's "standard") — a
        # single all-to-all whose replica groups span the slow axis.
        return jax.lax.all_to_all(x, (slow_axis, fast_axis),
                                  split_axis=0, concat_axis=0, tiled=True)
    if strategy != "nap3":
        raise ValueError(f"hier_all_to_all: unknown strategy {strategy!r}")
    # -- step 1 (intra-pod regroup): lane ℓ collects everyone's chunks for
    #    the pods it will forward to.  [dst_slow, dst_fast, ...] → group by
    #    dst_fast over the fast axis.
    x = x.reshape((n_slow, n_fast) + x.shape[1:])          # [dst_slow, dst_fast, ...]
    x = jnp.swapaxes(x, 0, 1)                               # [dst_fast, dst_slow, ...]
    x = jax.lax.all_to_all(x, fast_axis, split_axis=0, concat_axis=0, tiled=False)
    # now this lane holds, from every lane of its pod, the chunks whose
    # dst_fast == this lane: [src_fast, dst_slow, ...] — aggregated pod-pair
    # payload, 1/|fast| per lane (the paper's balanced NAP-3).
    # -- step 2 (single aggregated inter-pod transfer per pod pair)
    x = jnp.swapaxes(x, 0, 1)                               # [dst_slow, src_fast, ...]
    x = jax.lax.all_to_all(x, slow_axis, split_axis=0, concat_axis=0, tiled=False)
    # [src_slow, src_fast, ...] for traffic destined to this (pod, lane).
    return x.reshape((total,) + x.shape[2:])


# --------------------------------------------------------------------------
# Matrix-row halo exchange for distributed SpGEMM (the paper's matrix
# communication: "retains the same communication pattern as vectors, but
# requires entire rows")
# --------------------------------------------------------------------------


@dataclasses.dataclass
class MatrixHaloPlan:
    """Host-side plan for exchanging off-process CSR **rows**.

    Built from a :class:`~repro.core.comm_graph.CommGraph` whose indices are
    rows of B and whose weights are per-row byte sizes (see
    :func:`repro.amg.dist.matrix_comm_graph`: header + entries).  The
    ``schedule`` is the §3 message list for the chosen strategy — the same
    object the max-rate models price, so what :func:`repro.core.selector.
    select` selects is exactly what executes.
    """

    strategy: str
    graph: CommGraph
    schedule: Schedule

    @property
    def n_ranks(self) -> int:
        return self.graph.topo.n_procs


def build_matrix_halo_plan(graph: CommGraph, strategy: str) -> MatrixHaloPlan:
    return MatrixHaloPlan(strategy, graph, build_schedule(strategy, graph))


@dataclasses.dataclass
class MatrixExchangeResult:
    """Measured outcome of one matrix-row exchange.

    ``halo[q]`` maps each global B-row index rank ``q`` needed to the payload
    the provider returned for it; the message/byte counters are the measured
    counterparts of the modeled :class:`~repro.core.schedules.ScheduleStats`.
    """

    halo: list[dict[int, object]]
    inter_msgs: int
    inter_bytes: float
    intra_msgs: int
    intra_bytes: float
    seconds: float


def matrix_halo_exchange(plan: MatrixHaloPlan, get_row) -> MatrixExchangeResult:
    """Execute the plan rank-faithfully on the host.

    ``get_row(owner_rank, global_row) -> payload`` supplies an owned row
    (payload is opaque — e.g. a ``(cols, vals)`` pair).  Intermediate ranks
    (NAP gather/redist hops) forward rows they do not themselves need, as in
    :mod:`repro.core.simulator`; messages within a phase are concurrent and
    read from pre-phase stores.
    """
    t0 = time.perf_counter()
    g = plan.graph
    topo = g.topo
    part = g.partition
    D = topo.n_procs
    owner_lo = [part.local_range(p)[0] for p in range(D)]
    owner_hi = [part.local_range(p)[1] for p in range(D)]
    store: list[dict[int, object]] = [dict() for _ in range(D)]
    inter_msgs = intra_msgs = 0
    inter_bytes = intra_bytes = 0.0

    def serve(src: int, i: int):
        if owner_lo[src] <= i < owner_hi[src]:
            return get_row(src, i)
        try:
            return store[src][i]
        except KeyError:
            raise AssertionError(
                f"rank {src} asked to send row {i} it does not hold "
                f"(strategy {plan.strategy})") from None

    for phase in plan.schedule.phases:
        staged: list[tuple[int, dict[int, object]]] = []
        for m in phase.messages:
            payload = {int(i): serve(m.src, int(i)) for i in m.indices}
            staged.append((m.dst, payload))
            b = g.bytes_of(m.indices)
            if topo.on_same_node(m.src, m.dst):
                intra_msgs += 1
                intra_bytes += b
            else:
                inter_msgs += 1
                inter_bytes += b
        for dst, payload in staged:
            store[dst].update(payload)

    halo: list[dict[int, object]] = []
    for q in range(D):
        rows = {}
        for i in map(int, g.need[q]):
            if i not in store[q]:
                raise AssertionError(
                    f"{plan.strategy}: rank {q} never received row {i}")
            rows[i] = store[q][i]
        halo.append(rows)
    return MatrixExchangeResult(halo, inter_msgs, inter_bytes, intra_msgs,
                                intra_bytes, time.perf_counter() - t0)


# --------------------------------------------------------------------------
# Halo exchange for distributed SpMV (the paper's vector communication)
# --------------------------------------------------------------------------


def _pad_to(arrs: list[np.ndarray], width: int, fill: int) -> np.ndarray:
    out = np.full((len(arrs), width), fill, dtype=np.int32)
    for i, a in enumerate(arrs):
        out[i, : a.size] = a
    return out


@dataclasses.dataclass
class HaloPlan:
    """Static-shape device plan for one CommGraph + one (pods × lanes) mesh.

    Built on host at setup time (like an MPI communicator build); executed
    inside shard_map.  Device d = pod * lanes + lane owns the row block of
    ``partition`` for rank d; the halo buffer layout is the rank's sorted
    ``need`` array.

    standard : flat all_to_all of per-peer padded buffers (direct sends).
    nap2     : per-(device → dst pod) de-duplicated buffers, a2a over the pod
               axis between lane-peers, then an intra-pod all-gather.
    nap3     : per-(pod → pod) de-duplicated union buffers, split over lanes
               (balanced), a2a over the pod axis, then intra-pod all-gather.
    """

    strategy: str
    n_pods: int
    lanes: int
    local_n: int                 # padded local row count per device
    halo_len: int                # per-device halo width (max over devices)
    # device-stacked numpy index arrays (first dim = n_devices):
    send_idx: np.ndarray         # [D, n_targets, K] local indices to pack (-1 pad)
    recv_sel: np.ndarray         # [D, halo_len] flat index into received pool (-1 pad)
    pool_len: int                # flattened receive-pool length per device
    # nap3 only: pre-a2a lane pool selection
    pool_sel: np.ndarray | None = None   # [D, n_pods, K3] into intra-gathered pool
    contrib_len: int = 0
    # TRUE total halo entries across all devices.  ``halo_len`` is floored
    # to 1 for static shapes, so emptiness must be read here: a plan with
    # ``total_halo == 0`` moves nothing and the overlapped apply skips the
    # exchange (no ppermute/all_to_all emitted at all).
    total_halo: int = 0

    @property
    def n_devices(self) -> int:
        return self.n_pods * self.lanes


def build_halo_plan(graph: CommGraph, n_pods: int, lanes: int,
                    strategy: str) -> HaloPlan:
    topo = graph.topo
    assert topo.n_nodes == n_pods and topo.ppn == lanes, "graph topo must match mesh"
    part = graph.partition
    D = n_pods * lanes
    local_n = part.max_local_size
    need_sorted = [np.sort(graph.need[d]).astype(np.int64) for d in range(D)]
    total_halo = int(sum(n.size for n in need_sorted))
    halo_len = max((n.size for n in need_sorted), default=0) or 1

    def local_of(d, gidx):
        lo, _ = part.local_range(d)
        return (gidx - lo).astype(np.int32)

    owners = [part.owner_of_rows(need_sorted[d]) if need_sorted[d].size else
              np.zeros(0, dtype=np.int64) for d in range(D)]

    if strategy == "standard":
        # per (src d, dst e) message: what e needs from d
        msgs = [[np.zeros(0, dtype=np.int64) for _ in range(D)] for _ in range(D)]
        for e in range(D):
            for d, g in zip(owners[e], need_sorted[e]):
                msgs[int(d)][e] = np.append(msgs[int(d)][e], g)
        K = max((m.size for row in msgs for m in row), default=0) or 1
        send_idx = np.stack([
            _pad_to([local_of(d, m) if m.size else np.zeros(0, np.int64)
                     for m in msgs[d]], K, -1) for d in range(D)])
        # receive pool for device e: [D, K] from each source (flat D*K)
        pool_len = D * K
        recv_sel = np.full((D, halo_len), -1, dtype=np.int32)
        for e in range(D):
            # position of each needed gidx inside msgs[d][e]
            for j, (d, g) in enumerate(zip(owners[e], need_sorted[e])):
                d = int(d)
                k = int(np.searchsorted(msgs[d][e], g))
                recv_sel[e, j] = d * K + k
        return HaloPlan(strategy, n_pods, lanes, local_n, halo_len,
                        send_idx, recv_sel, pool_len, total_halo=total_halo)

    if strategy == "nap2":
        # per (src d, dst pod m): union of what pod m needs from d
        msgs = [[np.zeros(0, dtype=np.int64) for _ in range(n_pods)] for _ in range(D)]
        for e in range(D):
            m = e // lanes
            for d, g in zip(owners[e], need_sorted[e]):
                msgs[int(d)][m] = np.append(msgs[int(d)][m], g)
        msgs = [[np.unique(m) for m in row] for row in msgs]
        K = max((m.size for row in msgs for m in row), default=0) or 1
        send_idx = np.stack([
            _pad_to([local_of(d, m) if m.size else np.zeros(0, np.int64)
                     for m in msgs[d]], K, -1) for d in range(D)])
        # after a2a(pod) lane-peer exchange + all_gather(lane):
        # pool at device e (pod m): for lane ℓ, for src pod n:
        # msgs[n*lanes + ℓ][m]  → flat [lanes, n_pods, K]
        pool_len = lanes * n_pods * K
        recv_sel = np.full((D, halo_len), -1, dtype=np.int32)
        for e in range(D):
            m = e // lanes
            for j, (d, g) in enumerate(zip(owners[e], need_sorted[e])):
                d = int(d)
                n_src, lane_src = d // lanes, d % lanes
                k = int(np.searchsorted(msgs[d][m], g))
                recv_sel[e, j] = (lane_src * n_pods + n_src) * K + k
        return HaloPlan(strategy, n_pods, lanes, local_n, halo_len,
                        send_idx, recv_sel, pool_len, total_halo=total_halo)

    if strategy == "nap3":
        # pod-pair unions, split across lanes (balanced NAP-3)
        pair = [[np.zeros(0, dtype=np.int64) for _ in range(n_pods)]
                for _ in range(n_pods)]
        for e in range(D):
            m = e // lanes
            for d, g in zip(owners[e], need_sorted[e]):
                pair[int(d) // lanes][m] = np.append(pair[int(d) // lanes][m], g)
        pair = [[np.unique(m) for m in row] for row in pair]
        # contribution step: device d provides its owned entries of every
        # union pair[n][*]; all_gather(lane) builds the pod's pool.
        contrib = [[np.zeros(0, dtype=np.int64) for _ in range(n_pods)]
                   for _ in range(D)]
        for n in range(n_pods):
            for m in range(n_pods):
                # n == m included: same-pod traffic rides the a2a self-slab
                # (local, never crosses the network) — the TPU analogue of
                # the paper's on-node direct sends.
                own = part.owner_of_rows(pair[n][m])
                for d in range(n * lanes, (n + 1) * lanes):
                    contrib[d][m] = np.unique(np.append(
                        contrib[d][m], pair[n][m][own == d]))
        Kc = max((c.size for row in contrib for c in row), default=0) or 1
        send_idx = np.stack([
            _pad_to([local_of(d, c) if c.size else np.zeros(0, np.int64)
                     for c in contrib[d]], Kc, -1) for d in range(D)])
        contrib_len = n_pods * Kc
        # lane split of each pod-pair union
        K3 = 0
        shares: dict[tuple[int, int, int], np.ndarray] = {}
        for n in range(n_pods):
            for m in range(n_pods):
                u = pair[n][m]
                for l in range(lanes):
                    sh = u[l::lanes]
                    shares[(n, m, l)] = sh
                    K3 = max(K3, sh.size)
        K3 = K3 or 1
        # pool_sel: device d=(n,l) selects, for each dst pod m, its share out
        # of the intra-gathered pool [lanes, n_pods, Kc] (flat).
        pool_sel = np.full((D, n_pods, K3), -1, dtype=np.int32)
        for n in range(n_pods):
            for l in range(lanes):
                d = n * lanes + l
                for m in range(n_pods):
                    sh = shares[(n, m, l)]
                    own = part.owner_of_rows(sh)
                    for t, (o, g) in enumerate(zip(own, sh)):
                        o = int(o)
                        k = int(np.searchsorted(contrib[o][m], g))
                        pool_sel[d, m, t] = ((o % lanes) * n_pods + m) * Kc + k
        # receive: after a2a(pod) each device (m,l) holds shares[(n,m,l)] for
        # all n → all_gather(lane) → pool [lanes, n_pods, K3] flat.
        pool_len = lanes * n_pods * K3
        recv_sel = np.full((D, halo_len), -1, dtype=np.int32)
        for e in range(D):
            m = e // lanes
            # index of g within shares[(n, m, l)]: g is at position p in
            # pair[n][m] with lane l = p % lanes, slot p // lanes.
            for j, (d, g) in enumerate(zip(owners[e], need_sorted[e])):
                n = int(d) // lanes
                p = int(np.searchsorted(pair[n][m], g))
                l, slot = p % lanes, p // lanes
                recv_sel[e, j] = (l * n_pods + n) * K3 + slot
        return HaloPlan(strategy, n_pods, lanes, local_n, halo_len,
                        send_idx, recv_sel, pool_len,
                        pool_sel=pool_sel, contrib_len=contrib_len,
                        total_halo=total_halo)

    raise ValueError(f"unknown strategy {strategy!r}")


def halo_exchange(x_local: jnp.ndarray, plan: HaloPlan,
                  send_idx: jnp.ndarray, recv_sel: jnp.ndarray,
                  pool_sel: jnp.ndarray | None,
                  pod_axis: str = "pod", lane_axis: str = "lane") -> jnp.ndarray:
    """Inside shard_map: return this device's halo values.

    ``send_idx``/``recv_sel``/``pool_sel`` are the *per-device* slices of the
    plan arrays (sharded over the device axis ahead of time).

    ``x_local`` may carry trailing dimensions — ``[local]`` for one RHS or
    ``[local, k]`` for a multi-RHS batch; the halo is exchanged with the
    trailing dims riding along (shape ``[halo_len] + ext``), so the fused
    SpMM path moves one buffer for all k columns instead of k buffers.
    """
    ext = x_local.shape[1:]

    def _mask(idx):
        return (idx >= 0).reshape(idx.shape + (1,) * len(ext))

    safe = jnp.maximum(send_idx, 0)
    if plan.strategy == "standard":
        buf = jnp.where(_mask(send_idx), x_local[safe], 0.0)   # [D, K] + ext
        n_pods, lanes = plan.n_pods, plan.lanes
        K = send_idx.shape[-1]
        buf = buf.reshape((n_pods, lanes, K) + ext)
        buf = jax.lax.all_to_all(buf, pod_axis, split_axis=0, concat_axis=0)
        buf = jax.lax.all_to_all(buf, lane_axis, split_axis=1, concat_axis=1)
        pool = buf.reshape((plan.pool_len,) + ext)
    elif plan.strategy == "nap2":
        buf = jnp.where(_mask(send_idx), x_local[safe], 0.0)   # [n_pods, K] + ext
        buf = jax.lax.all_to_all(buf, pod_axis, split_axis=0, concat_axis=0)
        # buf now [n_pods(src), K]+ext at the lane-peer; share within the pod
        pool = jax.lax.all_gather(buf, lane_axis, axis=0)      # [lanes, n_pods, K] + ext
        pool = pool.reshape((plan.pool_len,) + ext)
    elif plan.strategy == "nap3":
        contrib = jnp.where(_mask(send_idx), x_local[safe], 0.0)  # [n_pods, Kc] + ext
        pod_pool = jax.lax.all_gather(contrib, lane_axis, axis=0)  # [lanes, n_pods, Kc] + ext
        pod_pool = pod_pool.reshape((-1,) + ext)
        sel_safe = jnp.maximum(pool_sel, 0)
        out_buf = jnp.where(_mask(pool_sel), pod_pool[sel_safe], 0.0)  # [n_pods, K3] + ext
        out_buf = jax.lax.all_to_all(out_buf, pod_axis, split_axis=0, concat_axis=0)
        pool = jax.lax.all_gather(out_buf, lane_axis, axis=0)   # [lanes, n_pods, K3] + ext
        pool = pool.reshape((plan.pool_len,) + ext)
    else:
        raise ValueError(plan.strategy)
    safe_r = jnp.maximum(recv_sel, 0)
    return jnp.where(_mask(recv_sel), pool[safe_r], 0.0)
