"""Standard, NAP-2 and NAP-3 communication schedules (paper §3).

A schedule is an ordered list of *phases*; each phase is a list of messages
``(src, dst, indices)`` that may proceed concurrently.  Phases:

* standard: one phase of direct messages (Fig. 10/11).
* NAP-2 (§3.2, Fig. 13):  ``local`` (on-node direct) → ``inter`` (one
  de-duplicated message from each sender to its lane-peer on every needed
  node) → ``redist`` (on-node redistribution at the receiver).
* NAP-3 (§3.1, Fig. 12):  ``local`` → ``gather`` (collect everything node n
  sends node m onto one process of n) → ``inter`` (single message per node
  pair) → ``redist``.

On-node requirements always use direct messages ("all on-node messages are
communicated with the standard approach").  Destination-node → local-process
assignment is round-robin over lanes so several processes per node stay
active (paper §3.1 last paragraph).
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict

import numpy as np

from .comm_graph import CommGraph

STRATEGIES = ("standard", "nap2", "nap3")


@dataclasses.dataclass(frozen=True)
class Message:
    src: int
    dst: int
    indices: np.ndarray          # global indices carried
    final_dst: tuple | None = None  # for gather phases: ultimate destination node

    def __post_init__(self):
        object.__setattr__(self, "indices", np.asarray(self.indices, dtype=np.int64))


@dataclasses.dataclass
class Phase:
    kind: str                    # "direct" | "local" | "gather" | "inter" | "redist"
    messages: list[Message]


@dataclasses.dataclass
class Schedule:
    strategy: str
    graph: CommGraph
    phases: list[Phase]

    def all_messages(self):
        for ph in self.phases:
            for m in ph.messages:
                yield ph.kind, m


# --------------------------------------------------------------------------- helpers
def _lane_for_peer_node(topo, my_node: int, peer_node: int) -> int:
    """Round-robin lane on ``my_node`` responsible for traffic with ``peer_node``.

    Deterministic and symmetric-free: distributes distinct peer nodes across
    the ppn lanes so several processes per node participate (NAP-3 balance).
    """
    return peer_node % topo.ppn


def _group_by_node(topo, ranks: np.ndarray) -> dict[int, np.ndarray]:
    nodes = ranks // topo.ppn
    return {int(n): ranks[nodes == n] for n in np.unique(nodes)}


# --------------------------------------------------------------------------- builders
def build_standard(graph: CommGraph) -> Schedule:
    msgs = [Message(p, q, idx) for p, q, idx in graph.recv_pairs()]
    return Schedule("standard", graph, [Phase("direct", msgs)])


def _split_onnode(graph: CommGraph):
    """(on-node direct messages, off-node requirements per (p, dst_node))."""
    topo = graph.topo
    local_msgs: list[Message] = []
    # (src_rank p, dst_node m) -> {dst_rank q -> indices}
    offnode: dict[tuple[int, int], dict[int, np.ndarray]] = defaultdict(dict)
    for p, q, idx in graph.recv_pairs():
        if topo.on_same_node(p, q):
            local_msgs.append(Message(p, q, idx))
        else:
            offnode[(p, topo.node_of(q))][q] = idx
    return local_msgs, offnode


def build_nap2(graph: CommGraph) -> Schedule:
    topo = graph.topo
    local_msgs, offnode = _split_onnode(graph)
    inter_msgs: list[Message] = []
    redist: dict[tuple[int, int], dict[int, list[np.ndarray]]] = defaultdict(
        lambda: defaultdict(list)
    )
    for (p, m), per_q in sorted(offnode.items()):
        union = np.unique(np.concatenate(list(per_q.values())))
        # lane-matched corresponding process on node m
        recv = m * topo.ppn + topo.local_rank(p)
        inter_msgs.append(Message(p, recv, union))
        for q, idx in per_q.items():
            if q != recv:
                redist[(m, recv)][q].append(idx)
    redist_msgs = [
        Message(recv, q, np.unique(np.concatenate(chunks)))
        for (m, recv), per_q in sorted(redist.items())
        for q, chunks in sorted(per_q.items())
    ]
    return Schedule(
        "nap2",
        graph,
        [Phase("local", local_msgs), Phase("inter", inter_msgs), Phase("redist", redist_msgs)],
    )


def build_nap3(graph: CommGraph) -> Schedule:
    topo = graph.topo
    local_msgs, offnode = _split_onnode(graph)

    # node pair (n, m) -> {src_rank p -> union of indices for node m}
    pair_src: dict[tuple[int, int], dict[int, np.ndarray]] = defaultdict(dict)
    # node pair (n, m) -> {dst_rank q -> indices}  (for redistribution)
    pair_dst: dict[tuple[int, int], dict[int, list[np.ndarray]]] = defaultdict(
        lambda: defaultdict(list)
    )
    for (p, m), per_q in sorted(offnode.items()):
        n = topo.node_of(p)
        union = np.unique(np.concatenate(list(per_q.values())))
        pair_src[(n, m)][p] = union
        for q, idx in per_q.items():
            pair_dst[(n, m)][q].append(idx)

    gather_msgs: list[Message] = []
    inter_msgs: list[Message] = []
    redist_msgs: list[Message] = []
    for (n, m), per_p in sorted(pair_src.items()):
        agg_src = n * topo.ppn + _lane_for_peer_node(topo, n, m)   # process R on n
        agg_dst = m * topo.ppn + _lane_for_peer_node(topo, m, n)   # process q on m
        union = np.unique(np.concatenate(list(per_p.values())))
        for p, idx in sorted(per_p.items()):
            if p != agg_src:
                gather_msgs.append(Message(p, agg_src, idx, final_dst=(m,)))
        inter_msgs.append(Message(agg_src, agg_dst, union))
        for q, chunks in sorted(pair_dst[(n, m)].items()):
            if q != agg_dst:
                redist_msgs.append(Message(agg_dst, q, np.unique(np.concatenate(chunks))))
    return Schedule(
        "nap3",
        graph,
        [
            Phase("local", local_msgs),
            Phase("gather", gather_msgs),
            Phase("inter", inter_msgs),
            Phase("redist", redist_msgs),
        ],
    )


_BUILDERS = {"standard": build_standard, "nap2": build_nap2, "nap3": build_nap3}


def build(strategy: str, graph: CommGraph) -> Schedule:
    return _BUILDERS[strategy](graph)


# --------------------------------------------------------------------------- stats
@dataclasses.dataclass
class ScheduleStats:
    """Aggregate quantities the max-rate models (Eqs. 4–6) consume.

    Inter-node messages feed Eq. (2)'s terms; intra-node extras feed Eq. (3).
    """

    strategy: str
    # inter-node (network-crossing) messages
    n_proc: int          # max #inter-node messages sent by any process
    n_proc2node: int     # max #distinct destination nodes of any process
    n_node2node: int     # max #inter-node messages sent by any node
    s_proc: float        # max inter-node bytes sent by any process
    s_node: float        # max inter-node bytes injected by any node
    s_node2node: float   # max bytes between any node pair
    inter_msg_count: int
    inter_bytes_total: float
    # additional intra-node traffic introduced by the strategy (gather+redist)
    intra_msg_count: int
    intra_bytes_total: float
    s_proc_intra: float  # max intra bytes handled (sent) by any process
    n_proc_intra: int

    # duplicate-byte diagnostic: bytes saved vs standard by de-duplication
    @staticmethod
    def of(schedule: Schedule) -> "ScheduleStats":
        g = schedule.graph
        topo = g.topo
        P, N = topo.n_procs, topo.n_nodes
        proc_msgs = np.zeros(P, dtype=np.int64)
        proc_bytes = np.zeros(P)
        proc_nodes: list[set] = [set() for _ in range(P)]
        node_msgs = np.zeros(N, dtype=np.int64)
        node_bytes = np.zeros(N)
        pair_bytes: dict[tuple[int, int], float] = defaultdict(float)
        intra_msgs = np.zeros(P, dtype=np.int64)
        intra_bytes = np.zeros(P)
        inter_cnt = 0
        inter_tot = 0.0
        intra_cnt = 0
        intra_tot = 0.0
        for kind, msg in schedule.all_messages():
            b = g.bytes_of(msg.indices)
            sn, dn = topo.node_of(msg.src), topo.node_of(msg.dst)
            if sn != dn:
                proc_msgs[msg.src] += 1
                proc_bytes[msg.src] += b
                proc_nodes[msg.src].add(dn)
                node_msgs[sn] += 1
                node_bytes[sn] += b
                pair_bytes[(sn, dn)] += b
                inter_cnt += 1
                inter_tot += b
            elif kind in ("gather", "redist"):  # strategy-added intra traffic
                intra_msgs[msg.src] += 1
                intra_bytes[msg.src] += b
                intra_cnt += 1
                intra_tot += b
            # kind "local"/"direct" on-node messages are common to all
            # strategies and excluded from the models (paper §3.3).
        return ScheduleStats(
            strategy=schedule.strategy,
            n_proc=int(proc_msgs.max(initial=0)),
            n_proc2node=int(max((len(s) for s in proc_nodes), default=0)),
            n_node2node=int(node_msgs.max(initial=0)),
            s_proc=float(proc_bytes.max(initial=0.0)),
            s_node=float(node_bytes.max(initial=0.0)),
            s_node2node=float(max(pair_bytes.values(), default=0.0)),
            inter_msg_count=inter_cnt,
            inter_bytes_total=inter_tot,
            intra_msg_count=intra_cnt,
            intra_bytes_total=intra_tot,
            s_proc_intra=float(intra_bytes.max(initial=0.0)),
            n_proc_intra=int(intra_msgs.max(initial=0)),
        )
