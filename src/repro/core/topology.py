"""Virtual parallel topology: processes grouped into SMP nodes.

The paper's machine model (Blue Waters: 16 processes per node; Quartz: 32 ppn)
is captured by :class:`Topology`.  On TPU the same object describes the
hierarchical mesh: "node" = ICI pod (or host domain), "process" = chip.

Everything here is plain host-side python/numpy — it is used both by the
rank-faithful simulator (tests/benchmarks) and by the shard_map collective
builders (device path).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Topology:
    """``n_nodes`` SMP nodes with ``ppn`` processes each.

    Processes are ranked ``0 .. n_procs-1`` with node-major contiguous
    placement (rank // ppn == node id), matching the default MPI rank
    placement the paper assumes.
    """

    n_nodes: int
    ppn: int

    def __post_init__(self) -> None:
        if self.n_nodes < 1 or self.ppn < 1:
            raise ValueError("n_nodes and ppn must be positive")

    @property
    def n_procs(self) -> int:
        return self.n_nodes * self.ppn

    def node_of(self, rank: int) -> int:
        return rank // self.ppn

    def local_rank(self, rank: int) -> int:
        return rank % self.ppn

    def ranks_on_node(self, node: int) -> range:
        return range(node * self.ppn, (node + 1) * self.ppn)

    def on_same_node(self, a: int, b: int) -> bool:
        return self.node_of(a) == self.node_of(b)

    def node_array(self) -> np.ndarray:
        """node id of every rank, shape (n_procs,)."""
        return np.repeat(np.arange(self.n_nodes), self.ppn)


@dataclasses.dataclass(frozen=True)
class Partition:
    """Contiguous row-wise partition of ``n`` global rows over ``topo.n_procs``.

    ``offsets[p] .. offsets[p+1]`` are the global rows owned by rank ``p``
    (the row-wise partition of Figure 6 in the paper).
    """

    n: int
    topo: Topology
    offsets: np.ndarray  # (n_procs + 1,)

    @staticmethod
    def balanced(n: int, topo: Topology) -> "Partition":
        P = topo.n_procs
        base, extra = divmod(n, P)
        counts = np.full(P, base, dtype=np.int64)
        counts[:extra] += 1
        offsets = np.zeros(P + 1, dtype=np.int64)
        np.cumsum(counts, out=offsets[1:])
        return Partition(n=n, topo=topo, offsets=offsets)

    def owner_of_rows(self, rows: np.ndarray) -> np.ndarray:
        """Owning rank of each global row (vectorized)."""
        return np.searchsorted(self.offsets, rows, side="right") - 1

    def local_range(self, rank: int) -> tuple[int, int]:
        return int(self.offsets[rank]), int(self.offsets[rank + 1])

    def local_size(self, rank: int) -> int:
        lo, hi = self.local_range(rank)
        return hi - lo

    @property
    def max_local_size(self) -> int:
        return int(np.max(np.diff(self.offsets)))
