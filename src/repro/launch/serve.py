"""Production serving driver: bring up an engine and drain a request file
or a synthetic workload.

Two engines share this entrypoint:

* ``--solver lm`` (default) — the LM generation ``repro.serve.Engine``::

      PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced

* ``--solver amg`` — the :class:`~repro.amg.api.AMGService`: solve
  requests admitted through tickets, same-(matrix, knobs) right-hand
  sides coalesced into one multi-RHS device trace.  ``--coalesce-window``
  (seconds, > 0) runs the background admission worker so requests
  submitted in separate bursts coalesce; ``--wire`` drives the service
  purely through the versioned wire codec — matrices registered by
  fingerprint from encoded CSR payloads, every request an encoded dict
  passed through an actual JSON byte hop (the codec round-trip proven
  end-to-end)::

      PYTHONPATH=src python -m repro.launch.serve --solver amg --requests 16
      PYTHONPATH=src python -m repro.launch.serve --solver amg --wire \\
          --amg-backend dist --n 10 --coalesce-window 0.2
"""
from __future__ import annotations

import argparse
import json
import time


def run_lm(args):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from ..configs import get_arch
    from ..models import init_params
    from ..serve import Engine, Request

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced(n_layers=4, d_model=128, n_heads=4, vocab=1024)
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    eng = Engine(cfg, params, max_batch=args.batch,
                 ctx_len=args.prompt_len + args.new_tokens + 8)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for rid in range(args.requests):
        eng.submit(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab, args.prompt_len,
                                dtype=np.int32),
            max_new_tokens=args.new_tokens,
            temperature=args.temperature))
    out = eng.run()
    dt = time.perf_counter() - t0
    s = eng.stats
    print(f"[serve] {len(out)} requests in {dt:.2f}s; "
          f"decode {s['tokens'] / max(s['decode_s'], 1e-9):.1f} tok/s")


def run_amg(args):
    import numpy as np

    from ..amg.api import (AMGConfig, AMGService, csr_to_wire,
                           solve_request_to_wire)
    from ..amg.problems import laplace_3d

    # the dist backend defaults to fp32, whose residual floor (~1e-7
    # relative) sits above the host default tol — don't let every solve
    # burn maxiter chasing an unreachable tolerance
    tol = args.tol if args.tol is not None else (
        1e-6 if args.amg_backend == "dist" else 1e-8)
    cfg = AMGConfig(backend=args.amg_backend, n_pods=args.n_pods,
                    lanes=args.lanes, tol=tol)
    svc = AMGService(cfg, max_rhs=args.batch,
                     coalesce_window=args.coalesce_window)
    sizes = (args.n, max(4, args.n - 2))
    mats = {}
    for n in sizes:
        A = laplace_3d(n)
        if args.wire:
            # wire-only operation: the matrix id IS the verified content
            # fingerprint of the encoded payload (one real JSON byte hop)
            mid = svc.register_wire(json.loads(json.dumps(csr_to_wire(A))))
        else:
            mid = svc.register(f"laplace3d_n{n}", A)
        mats[mid] = A
    ids = sorted(mats)
    rng = np.random.default_rng(0)

    def admit(rid):
        mid = ids[rid % len(ids)]
        b = rng.standard_normal(mats[mid].nrows)
        if args.wire:
            payload = json.loads(json.dumps(solve_request_to_wire(
                mid, b, method=args.method, rid=rid)))
            ticket = svc.submit_wire(payload)
        else:
            ticket = svc.submit(mid, b, method=args.method, rid=rid)
        return mid, b, ticket

    t0 = time.perf_counter()
    admitted = [admit(rid) for rid in range(args.requests)]
    if args.coalesce_window > 0:
        with svc:                       # background admission worker
            out = {t.rid: t.result(timeout=600) for _, _, t in admitted}
    else:
        out = svc.drain()
    dt = time.perf_counter() - t0
    worst = 0.0
    for mid, b, ticket in admitted:
        A = mats[mid]
        rel = (np.linalg.norm(b - A.matvec(out[ticket.rid]))
               / np.linalg.norm(b))
        worst = max(worst, rel)
    s = svc.stats
    mode = "wire" if args.wire else "direct"
    print(f"[serve/amg] {len(out)} solves ({len(ids)} matrices, "
          f"backend={args.amg_backend}, {mode}, "
          f"window={args.coalesce_window}s) in {dt:.2f}s: "
          f"{len(out) / dt:.1f} solves/s, {s['batches']} batches "
          f"({s['batched_rhs']} RHS batched, {s['wire_requests']} wire), "
          f"{s['setups']} setups, {s['unconverged']} unconverged, "
          f"worst rel residual {worst:.2e}")
    print("[serve/amg] " + svc.report().summary().replace("\n", "\n[serve/amg] "))
    if worst > tol * 100:
        raise SystemExit(f"residual check failed: {worst:.2e}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--solver", choices=("lm", "amg"), default="lm")
    ap.add_argument("--arch", help="LM architecture (required for --solver lm)")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    # lm knobs
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    # amg knobs
    ap.add_argument("--amg-backend", default="host",
                    help="AMG backend registry name (host | dist)")
    ap.add_argument("--n", type=int, default=8,
                    help="largest Laplacian grid size for --solver amg")
    ap.add_argument("--n-pods", type=int, default=1)
    ap.add_argument("--lanes", type=int, default=1)
    ap.add_argument("--tol", type=float, default=None,
                    help="convergence tolerance (default 1e-8 host, "
                         "1e-6 dist/fp32)")
    ap.add_argument("--method", choices=("solve", "pcg"), default="pcg")
    ap.add_argument("--wire", action="store_true",
                    help="drive the AMG service purely through encoded "
                         "wire payloads (matrices registered by "
                         "fingerprint, requests JSON round-tripped)")
    ap.add_argument("--coalesce-window", type=float, default=0.0,
                    help="seconds the admission worker holds a group open "
                         "to coalesce same-matrix RHS across bursts "
                         "(0 = synchronous drain)")
    args = ap.parse_args()

    if args.solver == "amg":
        run_amg(args)
    else:
        if not args.arch:
            raise SystemExit("--solver lm requires --arch")
        run_lm(args)


if __name__ == "__main__":
    main()
