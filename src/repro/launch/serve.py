"""Production serving driver: bring up an engine and drain a request file
or a synthetic workload.

Two engines share this entrypoint:

* ``--solver lm`` (default) — the LM generation ``repro.serve.Engine``::

      PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced

* ``--solver amg`` — the :class:`~repro.amg.api.AMGService`: solve
  requests admitted through tickets, same-(matrix, knobs) right-hand
  sides coalesced into one multi-RHS device trace.  ``--coalesce-window``
  (seconds, > 0) runs the background admission worker so requests
  submitted in separate bursts coalesce; ``--wire`` drives the service
  purely through the versioned wire codec — matrices registered by
  fingerprint from encoded CSR payloads, every request an encoded dict
  passed through an actual JSON byte hop (the codec round-trip proven
  end-to-end)::

      PYTHONPATH=src python -m repro.launch.serve --solver amg --requests 16
      PYTHONPATH=src python -m repro.launch.serve --solver amg --wire \\
          --amg-backend dist --n 10 --coalesce-window 0.2

* ``--solver amg --listen HOST:PORT`` — the AMGWire socket server
  (:class:`~repro.serve.server.AMGWireServer`): multi-tenant admission
  over length-prefixed JSON frames, each ``--tenant
  NAME[:MAX_INFLIGHT[:MAX_MATRIX_BYTES]]`` getting its own service,
  session store and quotas.  Drive it with
  ``benchmarks/serve_load.py``::

      PYTHONPATH=src python -m repro.launch.serve --solver amg \\
          --listen 127.0.0.1:8571 --tenant alpha:32 --tenant beta:2
"""
from __future__ import annotations

import argparse
import time


def run_lm(args):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from ..configs import get_arch
    from ..models import init_params
    from ..serve import Engine, Request

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced(n_layers=4, d_model=128, n_heads=4, vocab=1024)
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    eng = Engine(cfg, params, max_batch=args.batch,
                 ctx_len=args.prompt_len + args.new_tokens + 8)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for rid in range(args.requests):
        eng.submit(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab, args.prompt_len,
                                dtype=np.int32),
            max_new_tokens=args.new_tokens,
            temperature=args.temperature))
    out = eng.run()
    dt = time.perf_counter() - t0
    s = eng.stats
    print(f"[serve] {len(out)} requests in {dt:.2f}s; "
          f"decode {s['tokens'] / max(s['decode_s'], 1e-9):.1f} tok/s")


def run_amg(args):
    import numpy as np

    from ..amg.api import AMGConfig, AMGService
    from ..serve.workload import (build_problems, default_tol, make_request,
                                  matrix_payloads, rel_residual)

    tol = default_tol(args.amg_backend, args.tol)
    cfg = AMGConfig(backend=args.amg_backend, n_pods=args.n_pods,
                    lanes=args.lanes, tol=tol)
    svc = AMGService(cfg, max_rhs=args.batch,
                     coalesce_window=args.coalesce_window)
    # the matrix family and request stream are the same construction the
    # open-loop socket load generator (benchmarks/serve_load.py) drives —
    # the two serving harnesses stay honest against each other
    mats = build_problems(args.n)
    if args.wire:
        # wire-only operation: the matrix id IS the verified content
        # fingerprint of the encoded payload (one real JSON byte hop)
        for payload in matrix_payloads(mats).values():
            svc.register_wire(payload)
    else:
        for mid, A in mats.items():
            svc.register(mid, A)
    ids = sorted(mats)
    rng = np.random.default_rng(0)

    def admit(rid):
        mid = ids[rid % len(ids)]
        b, payload = make_request(rng, mats, mid, method=args.method,
                                  rid=rid)
        ticket = (svc.submit_wire(payload) if args.wire
                  else svc.submit(mid, b, method=args.method, rid=rid))
        return mid, b, ticket

    t0 = time.perf_counter()
    admitted = [admit(rid) for rid in range(args.requests)]
    if args.coalesce_window > 0:
        with svc:                       # background admission worker
            out = {t.rid: t.result(timeout=600) for _, _, t in admitted}
    else:
        out = svc.drain()
    dt = time.perf_counter() - t0
    worst = 0.0
    for mid, b, ticket in admitted:
        worst = max(worst, rel_residual(mats[mid], out[ticket.rid], b))
    s = svc.stats
    mode = "wire" if args.wire else "direct"
    print(f"[serve/amg] {len(out)} solves ({len(ids)} matrices, "
          f"backend={args.amg_backend}, {mode}, "
          f"window={args.coalesce_window}s) in {dt:.2f}s: "
          f"{len(out) / dt:.1f} solves/s, {s['batches']} batches "
          f"({s['batched_rhs']} RHS batched, {s['wire_requests']} wire), "
          f"{s['setups']} setups, {s['unconverged']} unconverged, "
          f"worst rel residual {worst:.2e}")
    print("[serve/amg] " + svc.report().summary().replace("\n", "\n[serve/amg] "))
    if worst > tol * 100:
        raise SystemExit(f"residual check failed: {worst:.2e}")


def parse_tenant_spec(spec: str, config, *, max_rhs: int,
                      coalesce_window: float):
    """``NAME[:MAX_INFLIGHT[:MAX_MATRIX_BYTES]]`` -> (name, TenantSpec)."""
    from ..serve import TenantSpec

    name, _, rest = spec.partition(":")
    if not name:
        raise SystemExit(f"--tenant {spec!r}: empty tenant name")
    parts = rest.split(":") if rest else []
    try:
        max_inflight = int(parts[0]) if parts and parts[0] else 32
        max_bytes = (int(parts[1]) if len(parts) > 1 and parts[1]
                     else None)
    except ValueError:
        raise SystemExit(f"--tenant {spec!r}: quotas must be integers "
                         f"(NAME[:MAX_INFLIGHT[:MAX_MATRIX_BYTES]])")
    return name, TenantSpec(config=config, max_inflight=max_inflight,
                            max_matrix_bytes=max_bytes, max_rhs=max_rhs,
                            coalesce_window=coalesce_window)


def run_listen(args):
    import asyncio

    from ..amg.api import AMGConfig
    from ..serve import AMGWireServer
    from ..serve.workload import default_tol

    tol = default_tol(args.amg_backend, args.tol)
    cfg = AMGConfig(backend=args.amg_backend, n_pods=args.n_pods,
                    lanes=args.lanes, tol=tol)
    tenants = dict(
        parse_tenant_spec(spec, cfg, max_rhs=args.batch,
                          coalesce_window=args.coalesce_window)
        for spec in (args.tenant or ["default"]))
    host, _, port = args.listen.rpartition(":")
    server = AMGWireServer(tenants)

    async def _serve():
        h, p = await server.start(host or "127.0.0.1", int(port or 0))
        print(f"[serve/amg] AMGWire listening on {h}:{p} (backend="
              f"{args.amg_backend}, tenants: "
              + ", ".join(f"{n}[inflight<={t.max_inflight}]"
                          for n, t in sorted(tenants.items()))
              + ")", flush=True)
        try:
            await server.serve_forever()
        except asyncio.CancelledError:
            pass
        finally:
            await server.aclose()

    try:
        asyncio.run(_serve())
    except KeyboardInterrupt:
        pass


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--solver", choices=("lm", "amg"), default="lm")
    ap.add_argument("--arch", help="LM architecture (required for --solver lm)")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    # lm knobs
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    # amg knobs
    ap.add_argument("--amg-backend", default="host",
                    help="AMG backend registry name (host | dist)")
    ap.add_argument("--n", type=int, default=8,
                    help="largest Laplacian grid size for --solver amg")
    ap.add_argument("--n-pods", type=int, default=1)
    ap.add_argument("--lanes", type=int, default=1)
    ap.add_argument("--tol", type=float, default=None,
                    help="convergence tolerance (default 1e-8 host, "
                         "1e-6 dist/fp32)")
    ap.add_argument("--method", choices=("solve", "pcg"), default="pcg")
    ap.add_argument("--wire", action="store_true",
                    help="drive the AMG service purely through encoded "
                         "wire payloads (matrices registered by "
                         "fingerprint, requests JSON round-tripped)")
    ap.add_argument("--coalesce-window", type=float, default=0.0,
                    help="seconds the admission worker holds a group open "
                         "to coalesce same-matrix RHS across bursts "
                         "(0 = synchronous drain)")
    ap.add_argument("--listen", metavar="HOST:PORT",
                    help="run the AMGWire socket server instead of the "
                         "in-process harness (--solver amg only); PORT 0 "
                         "picks a free port")
    ap.add_argument("--tenant", action="append", metavar="SPEC",
                    help="tenant spec NAME[:MAX_INFLIGHT[:MAX_MATRIX_"
                         "BYTES]], repeatable (default: one 'default' "
                         "tenant); only with --listen")
    args = ap.parse_args()

    if args.solver == "amg":
        if args.listen:
            run_listen(args)
            return
        run_amg(args)
    else:
        if not args.arch:
            raise SystemExit("--solver lm requires --arch")
        run_lm(args)


if __name__ == "__main__":
    main()
