"""Production serving driver: bring up an Engine and drain a request file
or a synthetic workload.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from ..configs import get_arch
    from ..models import init_params
    from ..serve import Engine, Request

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced(n_layers=4, d_model=128, n_heads=4, vocab=1024)
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    eng = Engine(cfg, params, max_batch=args.batch,
                 ctx_len=args.prompt_len + args.new_tokens + 8)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for rid in range(args.requests):
        eng.submit(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab, args.prompt_len,
                                dtype=np.int32),
            max_new_tokens=args.new_tokens,
            temperature=args.temperature))
    out = eng.run()
    dt = time.perf_counter() - t0
    s = eng.stats
    print(f"[serve] {len(out)} requests in {dt:.2f}s; "
          f"decode {s['tokens'] / max(s['decode_s'], 1e-9):.1f} tok/s")


if __name__ == "__main__":
    main()
