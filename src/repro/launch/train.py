"""Production training driver.

Single-host CPU: runs the fault-tolerant loop directly.  On a real cluster
each host runs this same entrypoint under `jax.distributed.initialize()`
(TPU runtime wires hosts together); the mesh/shardings are identical to the
dry-run's, so a configuration that passes `dryrun.py` launches unchanged.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --steps 200 --reduced --ckpt /tmp/ck
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced-width config (CPU-friendly)")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    import jax.numpy as jnp

    from ..configs import get_arch
    from ..train import (AdamWConfig, DataConfig, LoopConfig, TrainOptions,
                         train)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced(n_layers=4, d_model=128, n_heads=4, vocab=1024)
    print(f"[train] {cfg.name}: ~{cfg.n_params() / 1e6:.1f}M params")
    acfg = AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 10 + 1),
                       total_steps=args.steps)
    dcfg = DataConfig(seq_len=args.seq, global_batch=args.batch,
                      vocab=cfg.vocab)
    lcfg = LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt,
                      ckpt_every=args.ckpt_every, log_every=10)
    opts = TrainOptions(remat=False, microbatches=args.microbatches)
    _, _, hist = train(cfg, acfg, dcfg, lcfg, opts=opts, dtype=jnp.float32)
    print(f"[train] done: loss {hist[0]:.4f} → {hist[-1]:.4f}")


if __name__ == "__main__":
    main()
