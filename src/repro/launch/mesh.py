"""Production mesh definition (FUNCTION, not module constant — importing
this module never touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; multi-pod = 2 pods = 512 chips.

    Axes: ("data", "model") single-pod; ("pod", "data", "model") multi-pod —
    "pod" is the paper's expensive inter-node domain (DCI), "data"/"model"
    live on intra-pod ICI.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes_of(mesh) -> tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def pod_size_of(mesh) -> int:
    """Devices per pod (for pod-crossing collective classification)."""
    n = mesh.devices.size
    return n // mesh.shape["pod"] if "pod" in mesh.axis_names else n
