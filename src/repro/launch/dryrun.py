"""Multi-pod dry-run: lower + compile every (architecture × input-shape ×
mesh) cell on placeholder devices and extract roofline inputs.

MUST be the very first two lines (before any jax-importing module): the
host-device count locks on first jax init."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from ..configs import cells, get_arch, get_shape  # noqa: E402
from ..models.model import decode_step, forward  # noqa: E402
from ..train.optimizer import AdamWConfig  # noqa: E402
from ..train.sharding import (batch_specs, cache_specs, named,  # noqa: E402
                              param_specs, zero1_opt_specs)
from ..train.train_step import TrainOptions, make_step_fn  # noqa: E402
from .mesh import dp_axes_of, make_production_mesh, pod_size_of  # noqa: E402
from .roofline import collective_bytes_from_text, roofline_terms  # noqa: E402
from .specs import (abstract_opt_state, abstract_params, input_specs,  # noqa: E402
                    model_flops)

# per-arch microbatch counts for train_4k (keep per-device live tokens sane)
TRAIN_MICROBATCHES = {
    "mixtral-8x22b": 8, "qwen3-moe-235b-a22b": 16, "starcoder2-7b": 8,
    "recurrentgemma-9b": 16, "phi-3-vision-4.2b": 4, "musicgen-medium": 4,
    "qwen3-1.7b": 4, "qwen2-0.5b": 4, "qwen1.5-0.5b": 4, "xlstm-125m": 2,
}

# production cell options found by the §Perf hillclimb (EXPERIMENTS.md)
PROD_CELL_OPTS = {
    ("qwen3-moe-235b-a22b", "train_4k"): {
        "extra_opts": {"sp_residual": True, "loss_chunk": 256,
                       "bf16_moments": True}},
    ("qwen3-moe-235b-a22b", "prefill_32k"): {
        "extra_opts": {"sp_residual": True}},
    ("mixtral-8x22b", "train_4k"): {
        "extra_opts": {"sp_residual": True, "loss_chunk": 256,
                       "bf16_moments": True}},
    ("mixtral-8x22b", "prefill_32k"): {
        "extra_opts": {"sp_residual": True}},
    ("qwen2-0.5b", "train_4k"): {"sp_attn": True,
                                 "extra_opts": {"loss_chunk": 256}},
    ("musicgen-medium", "train_4k"): {"sp_attn": True},
    ("starcoder2-7b", "train_4k"): {"sp_attn": True},
}


def _dp_for(shape, mesh):
    dp = dp_axes_of(mesh)
    if shape.global_batch == 1:
        return ()                      # long_500k: nothing to shard on batch
    return dp


def build_cell(arch_name: str, shape_name: str, mesh, *, zero1: bool = True,
               microbatches: int | None = None, use_kernel: bool = False,
               extra_opts: dict | None = None, cfg_override=None,
               unroll: bool = False, sp_attn: bool = False):
    """Returns (lowered, meta) for one cell.  ``sp_attn`` turns on
    sequence-parallel attention (activation-sharding context)."""
    import contextlib

    from ..models.act_sharding import activation_sharding
    cfg = cfg_override if cfg_override is not None else get_arch(arch_name)
    shape = get_shape(shape_name)
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        raise ValueError("cell is skipped per DESIGN.md §5")
    dp = _dp_for(shape, mesh)
    ctx_kw = {}
    extra_opts = dict(extra_opts or {})
    sp_residual = extra_opts.pop("sp_residual", False) and \
        shape.kind in ("train", "prefill")
    if sp_residual:
        ctx_kw.update(residual_spec=P(dp if dp else None, "model", None))
    if sp_attn:
        dpa = dp if dp else None
        ctx_kw.update(qkv_spec=P(dpa, "model", None, None),
                      kv_spec=P(dpa, None, None, None),
                      out_spec=P(dpa, None, None))
    model_size = mesh.shape["model"]
    # shard_map expert parallelism when experts divide the data axis
    ep_axis, ep_size = None, 1
    if cfg.is_moe and cfg.n_experts % mesh.shape["data"] == 0 and not \
            extra_opts.pop("no_moe_ep", False):
        ep_axis, ep_size = "data", mesh.shape["data"]
        ctx_kw.update(moe_ep=dict(
            mesh=mesh, dp_axes=dp, ep_axes=("data",), tp_axis="model",
            nap=False, seq_axis="model" if sp_residual else None))
    elif cfg.is_moe:
        # TP-MoE (mixtral): dispatch buffer capacity dim sharded over dp
        ctx_kw.update(moe_buf_spec=P(None, dp if dp else None, "model"))
    sp_ctx = activation_sharding(**ctx_kw) if ctx_kw else \
        contextlib.nullcontext()
    params_abs = abstract_params(cfg)
    pspecs = param_specs(cfg, params_abs, "model", model_size,
                         ep_axis=ep_axis, ep_size=ep_size)
    p_sh = named(mesh, pspecs)
    meta = {"arch": arch_name, "shape": shape_name,
            "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names),
            "n_devices": int(mesh.devices.size),
            "model_flops": model_flops(cfg, shape)}

    if shape.kind == "train":
        mb = microbatches if microbatches is not None else \
            TRAIN_MICROBATCHES.get(arch_name, 1)
        if len(dp) == 2:
            mb = max(1, mb // 2)       # twice the dp shards in multi-pod
        bf16_mom = extra_opts.pop("bf16_moments", False)
        opts = TrainOptions(remat=True, microbatches=mb, use_kernel=use_kernel,
                            dp_axes=dp, unroll=unroll, zero2=zero1,
                            **(extra_opts or {}))
        acfg = AdamWConfig()
        opt_abs = abstract_opt_state(
            params_abs,
            moment_dtype=jnp.bfloat16 if bf16_mom else jnp.float32)
        o_specs = zero1_opt_specs(pspecs, opt_abs, dp, mesh) if zero1 else \
            {"m": pspecs, "v": pspecs, "count": P()}
        step = make_step_fn(cfg, acfg, opts,
                            grad_spec_tree=o_specs["m"] if zero1 else None)
        o_sh = named(mesh, o_specs)
        b_sh = {k: NamedSharding(mesh, v) for k, v in
                batch_specs(cfg, dp, embeds=not cfg.embed_input).items()}
        batch_abs = input_specs(cfg, shape)
        fn = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                     donate_argnums=(0, 1))
        with mesh, sp_ctx:
            lowered = fn.lower(params_abs, opt_abs, batch_abs)
        meta["microbatches"] = mb
        return lowered, meta

    if shape.kind == "prefill":
        def prefill(params, tokens):
            logits, caches = forward(params, cfg, tokens, return_cache=True,
                                     use_kernel=use_kernel, unroll=unroll)
            return logits[:, -1], caches

        b_in = input_specs(cfg, shape)["inputs"]
        in_sh = NamedSharding(mesh, P(dp if dp else None, *([None] * (len(b_in.shape) - 1))))
        fn = jax.jit(prefill, in_shardings=(p_sh, in_sh))
        with mesh, sp_ctx:
            lowered = fn.lower(params_abs, b_in)
        return lowered, meta

    # decode
    specs = input_specs(cfg, shape)
    g_spec, e_spec = cache_specs(cfg, dp if dp else None, "model")
    cache_sh = (jax.tree.map(lambda s: NamedSharding(mesh, s), g_spec,
                             is_leaf=lambda x: isinstance(x, P)),
                jax.tree.map(lambda s: NamedSharding(mesh, s), e_spec,
                             is_leaf=lambda x: isinstance(x, P)))
    tok_sh = NamedSharding(
        mesh, P(dp if dp else None, *([None] * (len(specs["inputs"].shape) - 1))))

    def serve_step(params, tokens, cache, pos):
        return decode_step(params, cfg, tokens, cache, pos, unroll=unroll)

    fn = jax.jit(serve_step,
                 in_shardings=(p_sh, tok_sh, cache_sh,
                               NamedSharding(mesh, P())),
                 donate_argnums=(2,))
    scores_ctx = activation_sharding(
        scores_spec=P(dp if dp else None, None, None, None, None),
        q5_spec=P(dp if dp else None, None, None, None, "model"))
    with mesh, sp_ctx, scores_ctx:
        lowered = fn.lower(params_abs, specs["inputs"], specs["cache"],
                           specs["pos"])
    return lowered, meta


def _compile_cell(arch_name, shape_name, mesh, pod_size, **kw):
    t0 = time.perf_counter()
    lowered, meta = build_cell(arch_name, shape_name, mesh, **kw)
    t1 = time.perf_counter()
    compiled = lowered.compile()
    t2 = time.perf_counter()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    try:
        mem = compiled.memory_analysis()
        peak = getattr(mem, "temp_size_in_bytes", 0) + \
            getattr(mem, "argument_size_in_bytes", 0) + \
            getattr(mem, "output_size_in_bytes", 0) - \
            getattr(mem, "alias_size_in_bytes", 0)
        memd = {"temp": getattr(mem, "temp_size_in_bytes", None),
                "args": getattr(mem, "argument_size_in_bytes", None),
                "output": getattr(mem, "output_size_in_bytes", None),
                "alias": getattr(mem, "alias_size_in_bytes", None),
                "peak_per_device": peak}
    except Exception as e:  # CPU backend may not support it
        memd = {"error": str(e)}
    coll = collective_bytes_from_text(compiled.as_text(), pod_size=pod_size,
                                      n_devices=int(mesh.devices.size))
    meta.update({"lower_s": round(t1 - t0, 2),
                 "compile_s": round(t2 - t1, 2)})
    return {"cost": cost, "coll": coll, "mem": memd, "meta": meta}


def run_cell(arch_name, shape_name, multi_pod=False, verbose=True,
             zero1=True, microbatches=None, **kw):
    """One cell = production-form compile (memory + proof) + two shallow
    unrolled compiles (1 and 2 pattern-groups) whose exact per-group costs
    extrapolate linearly to full depth (scan bodies are cost-counted once by
    XLA, so the production form cannot be used for FLOP/collective counts)."""
    import dataclasses
    mesh = make_production_mesh(multi_pod=multi_pod)
    pod_size = pod_size_of(mesh)
    cfg = get_arch(arch_name)
    plen = len(cfg.pattern)
    G, extra = cfg.n_layers // plen, cfg.n_layers % plen

    # 1) production scan form — THE dry-run proof + memory analysis
    prod = _compile_cell(arch_name, shape_name, mesh, pod_size, zero1=zero1,
                         microbatches=microbatches, unroll=False, **kw)
    meta = prod["meta"]
    meta["memory_analysis"] = prod["mem"]

    # 2) shallow unrolled cost probes: g=1 and g=2 pattern-groups
    probes = []
    for g in (1, 2):
        sub = dataclasses.replace(cfg, n_layers=g * plen + extra)
        r = _compile_cell(arch_name, shape_name, mesh, pod_size,
                          cfg_override=sub, zero1=zero1, microbatches=1,
                          unroll=True, **kw)
        probes.append(r)

    def xq(f):
        q1, q2 = f(probes[0]), f(probes[1])
        return q1 + (G - 1) * (q2 - q1)

    flops = xq(lambda r: float(r["cost"].get("flops", 0.0)))
    hbytes = xq(lambda r: float(r["cost"].get("bytes accessed", 0.0)))
    cbytes = xq(lambda r: r["coll"]["total_bytes"])
    xbytes = xq(lambda r: r["coll"]["cross_slow_bytes"])
    ncoll = xq(lambda r: r["coll"]["n_collectives"])
    cost = {"flops": flops, "bytes accessed": hbytes}
    # train probes run mb=1 over the full batch: totals already per step
    terms = roofline_terms(cost, "", n_chips=meta["n_devices"],
                           pod_size=pod_size,
                           model_flops=meta["model_flops"])
    terms.coll_bytes = cbytes
    terms.cross_pod_bytes = xbytes
    from .roofline import DCI_BW, ICI_LINKS, ICI_LINK_BW
    terms.collective_s = cbytes / (ICI_LINKS * ICI_LINK_BW)
    terms.cross_pod_s = xbytes / DCI_BW
    from .roofline import HBM_BW
    from .specs import analytic_memory_floor
    floor = analytic_memory_floor(cfg, get_shape(shape_name),
                                  meta["n_devices"])
    meta["memory_floor_bytes_per_dev"] = floor
    meta["memory_floor_s"] = floor / HBM_BW
    meta.update({
        "hlo_flops_per_dev": terms.hlo_flops,
        "hlo_bytes_per_dev": terms.hlo_bytes,
        "coll_bytes_per_dev": cbytes,
        "cross_pod_bytes_per_dev": xbytes,
        "n_collectives": ncoll,
        "compute_s": terms.compute_s, "memory_s": terms.memory_s,
        "collective_s": terms.collective_s, "cross_pod_s": terms.cross_pod_s,
        "dominant": terms.dominant,
        "useful_flops_fraction": terms.useful_flops_fraction,
        "roofline_fraction": terms.roofline_fraction,
        "probe_compile_s": [p["meta"]["compile_s"] for p in probes],
    })
    if verbose:
        peak = meta["memory_analysis"].get("peak_per_device")
        peak_str = f"{peak / 2**30:.2f} GiB" if peak else "n/a"
        print(f"[dryrun] {arch_name} × {shape_name} × {meta['mesh']}: "
              f"compile {meta['compile_s']}s, peak/dev {peak_str}, "
              f"dominant={meta['dominant']}, "
              f"roofline={meta['roofline_fraction']:.3f}", flush=True)
    return meta


def run_amg_cell(multi_pod=True, n=24, strategies=("standard", "nap2", "nap3"),
                 verbose=True):
    """The paper's own workload on the production mesh: distributed SpMV
    halo exchange for a 3D Laplacian, per strategy — lower + compile on
    (2, 256) pods × lanes and report pod-crossing collective bytes."""
    import numpy as np

    from ..amg.dist_spmv import build_dist_spmv
    from ..amg.problems import laplace_3d

    n_pods = 2 if multi_pod else 1
    lanes = 256
    mesh = jax.make_mesh((n_pods, lanes), ("pod", "lane"))
    A = laplace_3d(n)
    out = []
    for strat in strategies:
        t0 = time.perf_counter()
        sp = build_dist_spmv(A, n_pods, lanes, strat, mesh=mesh)
        x = sp.scatter_x(np.ones(A.nrows))
        lowered = jax.jit(sp.fn).lower(x)
        compiled = lowered.compile()
        coll = collective_bytes_from_text(compiled.as_text(), pod_size=lanes,
                                          n_devices=n_pods * lanes)
        meta = {"arch": f"amg_spmv_{strat}", "shape": f"laplace3d_n{n}",
                "mesh": f"{n_pods}x{lanes}", "n_devices": n_pods * lanes,
                "compile_s": round(time.perf_counter() - t0, 2),
                "coll_bytes_per_dev": coll["total_bytes"],
                "cross_pod_bytes_per_dev": coll["cross_slow_bytes"],
                "n_collectives": coll["n_collectives"]}
        out.append(meta)
        if verbose:
            print(f"[dryrun] AMG spmv {strat} × {meta['mesh']}: "
                  f"coll={coll['total_bytes']:.3e} B "
                  f"xpod={coll['cross_slow_bytes']:.3e} B", flush=True)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--no-zero1", action="store_true")
    ap.add_argument("--amg", action="store_true",
                    help="run the AMG distributed-SpMV cell instead")
    args = ap.parse_args()

    if args.amg:
        results = []
        if os.path.exists(args.out):
            results = json.load(open(args.out))
        results = [r for r in results
                   if not str(r.get("arch", "")).startswith("amg_spmv")]
        results.extend(run_amg_cell(multi_pod=True))
        json.dump(results, open(args.out, "w"), indent=1)
        print(f"[dryrun] wrote {args.out}")
        return

    results = []
    if os.path.exists(args.out):
        results = json.load(open(args.out))
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results}

    todo = []
    if args.all:
        for a, s, skip in cells():
            if skip:
                results = [r for r in results if not (
                    r["arch"] == a.name and r["shape"] == s.name)]
                results.append({"arch": a.name, "shape": s.name,
                                "mesh": "all", "skipped": skip})
                continue
            for mp in ((False, True) if args.both_meshes else
                       (args.multi_pod,)):
                todo.append((a.name, s.name, mp))
    else:
        for mp in ((False, True) if args.both_meshes else (args.multi_pod,)):
            todo.append((args.arch, args.shape, mp))

    for a, s, mp in todo:
        mesh_tag = "2x16x16" if mp else "16x16"
        if (a, s, mesh_tag) in done:
            print(f"[dryrun] skip cached {a} × {s} × {mesh_tag}")
            continue
        try:
            kw = {k: (dict(v) if isinstance(v, dict) else v)
                  for k, v in PROD_CELL_OPTS.get((a, s), {}).items()}
            meta = run_cell(a, s, multi_pod=mp, zero1=not args.no_zero1, **kw)
        except Exception as e:
            meta = {"arch": a, "shape": s, "mesh": mesh_tag,
                    "error": f"{type(e).__name__}: {e}"}
            print(f"[dryrun] FAIL {a} × {s} × {mesh_tag}: {meta['error']}")
        results.append(meta)
        json.dump(results, open(args.out, "w"), indent=1)
    print(f"[dryrun] wrote {args.out} ({len(results)} entries)")


if __name__ == "__main__":
    main()
