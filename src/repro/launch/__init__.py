"""Launchers: production mesh, multi-pod dry-run, roofline extraction,
training / serving drivers."""
