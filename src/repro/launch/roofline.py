"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × mesh), in seconds (EXPERIMENTS.md §Roofline):

    compute    = HLO_FLOPs        / (chips × 197e12 bf16 FLOP/s)
    memory     = HLO_bytes        / (chips × 819e9  B/s HBM)
    collective = collective_bytes / (chips × 50e9   B/s per ICI link)

``cost_analysis()`` supplies FLOPs / bytes-accessed of the *per-device*
partitioned module (verified in tests), so the numerators are multiplied by
``chips`` before the division — i.e. terms reduce to per-device work over
per-device rates.  Collective bytes are NOT in cost_analysis: we parse the
compiled HLO and sum result-shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute / ragged-all-to-all op,
classifying pod-crossing groups via the device-id → pod map.
"""
from __future__ import annotations

import dataclasses
import re

import numpy as np

# -------------------------- TPU v5e hardware constants (target machine)
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # B/s per chip
ICI_LINK_BW = 50e9                # B/s per link
ICI_LINKS = 4                     # links per chip available to collectives
DCI_BW = 6.4e9                    # B/s per chip, pod-crossing (modeled)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?P<shapes>\(?[^=]*?\)?)\s*"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute|ragged-all-to-all)"
    r"(?P<suffix>-start|-done)?\(", )

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}\}?")
_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")


def _shape_bytes(shapes_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(shapes_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _parse_groups(line: str) -> list[list[int]] | None:
    m = _IOTA_RE.search(line)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            perm = [int(x) for x in m.group(4).split(",")]
            ids = np.transpose(ids, perm)
        return ids.reshape(g, s).tolist()
    m = _GROUPS_RE.search(line)
    if m:
        body = m.group(1)
        groups = []
        for grp in re.findall(r"\{([\d, ]*)\}", "{" + body + "}"):
            if grp.strip():
                groups.append([int(x) for x in grp.replace(" ", "").split(",")])
        return groups or None
    return None


@dataclasses.dataclass
class CollectiveInfo:
    op: str
    bytes: float            # result-shape bytes (per participating device)
    crosses_pod: bool
    group_size: int


def parse_collectives(hlo_text: str, pod_size: int | None = None,
                      n_devices: int | None = None) -> list[CollectiveInfo]:
    out: list[CollectiveInfo] = []
    for line in hlo_text.splitlines():
        mm = _COLL_RE.search(line)
        if not mm:
            continue
        # avoid double counting async -start/-done pairs: skip -done lines
        if mm.group("suffix") == "-done":
            continue
        b = _shape_bytes(mm.group("shapes"))
        groups = _parse_groups(line)
        cross = False
        gsize = 0
        if groups:
            gsize = max(len(g) for g in groups)
            if pod_size:
                for g in groups:
                    pods = {d // pod_size for d in g}
                    if len(pods) > 1:
                        cross = True
                        break
            else:
                cross = gsize > 1
        else:
            # empty replica_groups == all devices participate
            gsize = n_devices or 0
            cross = bool(pod_size and n_devices and n_devices > pod_size)
        out.append(CollectiveInfo(op=mm.group("op"), bytes=b,
                                  crosses_pod=cross, group_size=gsize))
    return out


def collective_bytes_from_text(hlo_text: str, pod_size: int | None = None,
                               n_devices: int | None = None) -> dict:
    infos = parse_collectives(hlo_text, pod_size=pod_size, n_devices=n_devices)
    return {
        "total_bytes": sum(i.bytes for i in infos),
        "cross_slow_bytes": sum(i.bytes for i in infos if i.crosses_pod),
        "n_collectives": len(infos),
        "n_cross": sum(1 for i in infos if i.crosses_pod),
        "by_op": {op: sum(i.bytes for i in infos if i.op == op)
                  for op in {i.op for i in infos}},
    }


@dataclasses.dataclass
class RooflineTerms:
    """All terms in seconds (per executed step, per device timeline)."""
    compute_s: float
    memory_s: float
    collective_s: float
    cross_pod_s: float
    hlo_flops: float          # per device
    hlo_bytes: float          # per device
    coll_bytes: float         # per device
    cross_pod_bytes: float
    model_flops: float        # 6·N·D (or 6·N_active·D) — global useful FLOPs
    n_chips: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": max(self.collective_s, self.cross_pod_s)}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s,
                   self.cross_pod_s)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs × chips): remat/redundancy waste."""
        tot = self.hlo_flops * self.n_chips
        return self.model_flops / tot if tot else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved if the step runs at its
        bound: (model-useful compute time) / (achievable step time)."""
        ideal = self.model_flops / (self.n_chips * PEAK_FLOPS_BF16)
        return ideal / self.bound_s if self.bound_s else 0.0


def roofline_terms(cost: dict, hlo_text: str, n_chips: int, pod_size: int,
                   model_flops: float) -> RooflineTerms:
    """cost = compiled.cost_analysis() of the per-device module."""
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes_from_text(hlo_text, pod_size=pod_size)
    return RooflineTerms(
        compute_s=flops / PEAK_FLOPS_BF16,
        memory_s=hbm / HBM_BW,
        collective_s=coll["total_bytes"] / (ICI_LINKS * ICI_LINK_BW),
        cross_pod_s=coll["cross_slow_bytes"] / DCI_BW,
        hlo_flops=flops,
        hlo_bytes=hbm,
        coll_bytes=coll["total_bytes"],
        cross_pod_bytes=coll["cross_slow_bytes"],
        model_flops=model_flops,
        n_chips=n_chips,
    )
