"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × mesh), in seconds (EXPERIMENTS.md §Roofline):

    compute    = HLO_FLOPs        / (chips × 197e12 bf16 FLOP/s)
    memory     = HLO_bytes        / (chips × 819e9  B/s HBM)
    collective = collective_bytes / (chips × 50e9   B/s per ICI link)

``cost_analysis()`` supplies FLOPs / bytes-accessed of the *per-device*
partitioned module (verified in tests), so the numerators are multiplied by
``chips`` before the division — i.e. terms reduce to per-device work over
per-device rates.  Collective bytes are NOT in cost_analysis: we parse the
compiled HLO and sum result-shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute / ragged-all-to-all op,
classifying pod-crossing groups via the device-id → pod map.

The documented-peak constants below feed the *modeled* terms; the
ERT-style :func:`ert_sweep` complements them with **measured** ceilings —
streaming bandwidth, random-gather bandwidth and dense FLOP rate swept
over several working-set sizes and FLOP intensities on the actual backend
— which is what ``benchmarks/kernels.py`` reports the SpMV/SpMM kernels
against (achieved bytes/s as a % of the measured, not documented, peak).
"""
from __future__ import annotations

import dataclasses
import functools
import re
import time

import numpy as np

# -------------------------- TPU v5e hardware constants (target machine)
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # B/s per chip
ICI_LINK_BW = 50e9                # B/s per link
ICI_LINKS = 4                     # links per chip available to collectives
DCI_BW = 6.4e9                    # B/s per chip, pod-crossing (modeled)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?P<shapes>\(?[^=]*?\)?)\s*"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute|ragged-all-to-all)"
    r"(?P<suffix>-start|-done)?\(", )

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}\}?")
_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")


def _shape_bytes(shapes_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(shapes_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _parse_groups(line: str) -> list[list[int]] | None:
    m = _IOTA_RE.search(line)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            perm = [int(x) for x in m.group(4).split(",")]
            ids = np.transpose(ids, perm)
        return ids.reshape(g, s).tolist()
    m = _GROUPS_RE.search(line)
    if m:
        body = m.group(1)
        groups = []
        for grp in re.findall(r"\{([\d, ]*)\}", "{" + body + "}"):
            if grp.strip():
                groups.append([int(x) for x in grp.replace(" ", "").split(",")])
        return groups or None
    return None


@dataclasses.dataclass
class CollectiveInfo:
    op: str
    bytes: float            # result-shape bytes (per participating device)
    crosses_pod: bool
    group_size: int


def parse_collectives(hlo_text: str, pod_size: int | None = None,
                      n_devices: int | None = None) -> list[CollectiveInfo]:
    out: list[CollectiveInfo] = []
    for line in hlo_text.splitlines():
        mm = _COLL_RE.search(line)
        if not mm:
            continue
        # avoid double counting async -start/-done pairs: skip -done lines
        if mm.group("suffix") == "-done":
            continue
        b = _shape_bytes(mm.group("shapes"))
        groups = _parse_groups(line)
        cross = False
        gsize = 0
        if groups:
            gsize = max(len(g) for g in groups)
            if pod_size:
                for g in groups:
                    pods = {d // pod_size for d in g}
                    if len(pods) > 1:
                        cross = True
                        break
            else:
                cross = gsize > 1
        else:
            # empty replica_groups == all devices participate
            gsize = n_devices or 0
            cross = bool(pod_size and n_devices and n_devices > pod_size)
        out.append(CollectiveInfo(op=mm.group("op"), bytes=b,
                                  crosses_pod=cross, group_size=gsize))
    return out


def collective_bytes_from_text(hlo_text: str, pod_size: int | None = None,
                               n_devices: int | None = None) -> dict:
    infos = parse_collectives(hlo_text, pod_size=pod_size, n_devices=n_devices)
    return {
        "total_bytes": sum(i.bytes for i in infos),
        "cross_slow_bytes": sum(i.bytes for i in infos if i.crosses_pod),
        "n_collectives": len(infos),
        "n_cross": sum(1 for i in infos if i.crosses_pod),
        "by_op": {op: sum(i.bytes for i in infos if i.op == op)
                  for op in {i.op for i in infos}},
    }


@dataclasses.dataclass
class RooflineTerms:
    """All terms in seconds (per executed step, per device timeline)."""
    compute_s: float
    memory_s: float
    collective_s: float
    cross_pod_s: float
    hlo_flops: float          # per device
    hlo_bytes: float          # per device
    coll_bytes: float         # per device
    cross_pod_bytes: float
    model_flops: float        # 6·N·D (or 6·N_active·D) — global useful FLOPs
    n_chips: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": max(self.collective_s, self.cross_pod_s)}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s,
                   self.cross_pod_s)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs × chips): remat/redundancy waste."""
        tot = self.hlo_flops * self.n_chips
        return self.model_flops / tot if tot else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved if the step runs at its
        bound: (model-useful compute time) / (achievable step time)."""
        ideal = self.model_flops / (self.n_chips * PEAK_FLOPS_BF16)
        return ideal / self.bound_s if self.bound_s else 0.0


# --------------------------------------------------------------------------
# ERT-style empirical roofline: measure the peaks instead of trusting the
# datasheet.  Three micro-kernels swept over working-set sizes (and, for the
# streaming kernel, FLOP intensities, ERT's defining axis):
#
#   stream  — y = a·y + c repeated t times per element: at t = 1 it is the
#             STREAM scale+add bound (2 bytes-moved directions/elem); as t
#             grows it leaves the bandwidth roof and exposes the FLOP peak,
#   gather  — y = x[idx] with uniformly random idx: the access pattern of
#             the ELL SpMV/SpMM kernels (one random read + one stream write
#             + one index read per element),
#
# and the peaks are the best observed rate at each roof.  Everything is
# timed on the current jax backend — CPU in CI, TPU on hardware — so the
# "% of peak" a kernel reports is against what this machine can actually
# do, not against v5e marketing numbers.
# --------------------------------------------------------------------------

ERT_WORKING_SETS = (1 << 16, 1 << 20, 1 << 23)       # elements
ERT_SMOKE_WORKING_SETS = (1 << 13, 1 << 15)
ERT_FLOP_INTENSITIES = (1, 4, 16, 64)                # t: 2t flops/elem
ERT_SMOKE_FLOP_INTENSITIES = (1, 8)


def _time_best(fn, args, reps: int) -> float:
    """Best-of-``reps`` wall-clock seconds of ``fn(*args)`` (one unmeasured
    warm-up call absorbs compilation)."""
    import jax
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def _ert_stream_fn(t: int):
    import jax

    @jax.jit
    def run(x):
        y = x
        for _ in range(t):
            y = y * 1.0000001 + 0.5       # 2 flops per element per pass
        return y

    return run


@functools.lru_cache(maxsize=None)
def _ert_gather_fn():
    import jax
    import jax.numpy as jnp
    return jax.jit(lambda x, idx: jnp.take(x, idx, axis=0))


def ert_sweep(working_sets: tuple[int, ...] | None = None,
              intensities: tuple[int, ...] | None = None,
              reps: int = 3, dtype=np.float32, smoke: bool = False) -> dict:
    """Measure this backend's achievable peaks, ERT style.

    Returns ``{"stream_bw", "gather_bw", "flops", "points", ...}`` —
    bandwidths in B/s, FLOP rate in FLOP/s, ``points`` the raw sweep (one
    dict per (kernel, working set, intensity) cell).  ``smoke=True`` swaps
    in small working sets so the sweep stays in CI budget; peaks are then
    lower than a full sweep would find, which is fine — baselines and
    fresh runs are compared at the same setting.
    """
    import jax
    import jax.numpy as jnp
    if working_sets is None:
        working_sets = ERT_SMOKE_WORKING_SETS if smoke else ERT_WORKING_SETS
    if intensities is None:
        intensities = (ERT_SMOKE_FLOP_INTENSITIES if smoke
                       else ERT_FLOP_INTENSITIES)
    dsize = np.dtype(dtype).itemsize
    rng = np.random.default_rng(0)
    points: list[dict] = []
    stream_bw = gather_bw = flops_peak = 0.0
    t_min = min(intensities)
    for w in working_sets:
        x = jnp.asarray(rng.standard_normal(w), dtype=dtype)
        for t in intensities:
            s = _time_best(_ert_stream_fn(t), (x,), reps)
            byts = 2.0 * w * dsize                   # read x + write y
            fl = 2.0 * t * w
            points.append({"kernel": "stream", "working_set": int(w),
                           "flops_per_elem": 2 * t, "seconds": s,
                           "bytes": byts, "flops": fl,
                           "bw": byts / s, "flop_rate": fl / s})
            if t == t_min:
                stream_bw = max(stream_bw, byts / s)
            flops_peak = max(flops_peak, fl / s)
        idx = jnp.asarray(rng.integers(0, w, size=w), dtype=jnp.int32)
        s = _time_best(_ert_gather_fn(), (x, idx), reps)
        byts = w * (2.0 * dsize + 4.0)   # random read + write + idx read
        points.append({"kernel": "gather", "working_set": int(w),
                       "flops_per_elem": 0, "seconds": s, "bytes": byts,
                       "flops": 0.0, "bw": byts / s, "flop_rate": 0.0})
        gather_bw = max(gather_bw, byts / s)
    return {"backend": jax.default_backend(),
            "dtype": str(np.dtype(dtype)), "smoke": bool(smoke),
            "stream_bw": stream_bw, "gather_bw": gather_bw,
            "flops": flops_peak, "points": points,
            "documented_hbm_bw": HBM_BW,
            "documented_flops": PEAK_FLOPS_BF16}


def roofline_terms(cost: dict, hlo_text: str, n_chips: int, pod_size: int,
                   model_flops: float) -> RooflineTerms:
    """cost = compiled.cost_analysis() of the per-device module."""
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes_from_text(hlo_text, pod_size=pod_size)
    return RooflineTerms(
        compute_s=flops / PEAK_FLOPS_BF16,
        memory_s=hbm / HBM_BW,
        collective_s=coll["total_bytes"] / (ICI_LINKS * ICI_LINK_BW),
        cross_pod_s=coll["cross_slow_bytes"] / DCI_BW,
        hlo_flops=flops,
        hlo_bytes=hbm,
        coll_bytes=coll["total_bytes"],
        cross_pod_bytes=coll["cross_slow_bytes"],
        model_flops=model_flops,
        n_chips=n_chips,
    )
