"""ShapeDtypeStruct stand-ins for every model input (no device allocation)
and MODEL_FLOPS accounting for the roofline."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeConfig
from ..models.model import init_cache, init_params


def abstract_params(cfg: ArchConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0), dtype))


def abstract_opt_state(params_shape, moment_dtype=jnp.float32):
    from ..train.optimizer import init_opt_state
    return jax.eval_shape(
        lambda p: init_opt_state(p, moment_dtype=moment_dtype), params_shape)


def input_specs(cfg: ArchConfig, shape: ShapeConfig, dtype=jnp.bfloat16):
    """Stand-ins for the step inputs of this (arch × shape) cell.

    train   → {"inputs", "targets"} for train_step
    prefill → tokens/embeddings [B, S]
    decode  → (tokens [B,1], cache pytree, pos) for serve_step
    """
    B, S = shape.global_batch, shape.seq_len
    if cfg.embed_input:
        tok = lambda b, s: jax.ShapeDtypeStruct((b, s), jnp.int32)
    else:
        # stub modality frontend: precomputed frame/patch embeddings
        tok = lambda b, s: jax.ShapeDtypeStruct((b, s, cfg.d_model), dtype)
    if shape.kind == "train":
        return {"inputs": tok(B, S),
                "targets": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if shape.kind == "prefill":
        return {"inputs": tok(B, S)}
    if shape.kind == "decode":
        cache = jax.eval_shape(lambda: init_cache(cfg, B, S, dtype))
        return {"inputs": tok(B, 1), "cache": cache,
                "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    raise ValueError(shape.kind)


def analytic_memory_floor(cfg: ArchConfig, shape: ShapeConfig,
                          n_chips: int) -> float:
    """Per-chip HBM bytes a well-fused implementation must move per step —
    a LOWER bound companion to cost_analysis' unfused 'bytes accessed'."""
    P = float(cfg.n_params())
    Pa = float(cfg.n_active_params())
    tokens = shape.global_batch * shape.seq_len
    d, L = cfg.d_model, cfg.n_layers
    if shape.kind == "train":
        # params: bf16 read fwd + bwd-recompute read + f32 grad write +
        # f32 m/v read+write (ZeRO sharded → /chips like params)
        param_traffic = P * (2 + 2 + 4 + 16)
        act = tokens * d * L * 40.0          # ~40B/token/layer fused fwd+bwd
        return (param_traffic + act) / n_chips
    if shape.kind == "prefill":
        return (Pa * 2 + tokens * d * L * 20.0) / n_chips
    # decode: read all active params + the KV cache once per token
    clen = min(shape.seq_len, cfg.window) if cfg.window else shape.seq_len
    kv = (shape.global_batch * clen * cfg.n_kv_heads * cfg.head_dim * 2 * 2
          * sum(1 for k in cfg.pattern if k == "attn") * L // len(cfg.pattern))
    return (Pa * 2 + kv) / n_chips


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """6·N·D (train) / 2·N·D (inference), N = active params, D = tokens."""
    n = cfg.n_active_params()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch           # decode: one token each
