"""Trace-time activation-sharding context (sequence-parallel attention).

For architectures whose head counts don't divide the model axis (qwen2 14H,
musicgen 24H, starcoder2 36H…), attention projections are replicated
(sharding.py) — attention compute/bytes are then duplicated model_size×.
Sequence parallelism fixes this: queries are sharded along S over the model
axis (each device attends its query chunk against the full K/V), and the
block output is resharded back for the TP FFN.

Used as:
    with activation_sharding(qkv_spec=P(dp, "model", None, None),
                             kv_spec=P(dp, None, None, None),
                             out_spec=P(dp, None, None)):
        lowered = jit(step).lower(...)
"""
from __future__ import annotations

import contextlib

import jax

_CTX: dict = {"qkv_spec": None, "kv_spec": None, "out_spec": None,
              "scores_spec": None, "q5_spec": None, "moe_ep": None,
              "residual_spec": None, "moe_buf_spec": None}


@contextlib.contextmanager
def activation_sharding(**kw):
    old = dict(_CTX)
    _CTX.update(kw)
    try:
        yield
    finally:
        _CTX.clear()
        _CTX.update(old)


def constrain_q(q):
    if _CTX["qkv_spec"] is not None:
        return jax.lax.with_sharding_constraint(q, _CTX["qkv_spec"])
    return q


def constrain_kv(k, v):
    if _CTX["kv_spec"] is not None:
        return (jax.lax.with_sharding_constraint(k, _CTX["kv_spec"]),
                jax.lax.with_sharding_constraint(v, _CTX["kv_spec"]))
    return k, v


def constrain_out(x):
    if _CTX["out_spec"] is not None:
        return jax.lax.with_sharding_constraint(x, _CTX["out_spec"])
    return x


def constrain_moe_buf(buf):
    """TP-MoE dispatch buffer [E, cap, d]: shard the capacity dim over dp so
    the buffer never replicates across the data axis."""
    if _CTX["moe_buf_spec"] is not None:
        return jax.lax.with_sharding_constraint(buf, _CTX["moe_buf_spec"])
    return buf


def constrain_residual(x):
    """Residual stream between blocks [B,S,d]: sharding S over the model
    axis (Megatron-SP) shrinks the per-layer remat carries the backward
    scan must store — the dominant memory at large layer counts."""
    if _CTX["residual_spec"] is not None:
        return jax.lax.with_sharding_constraint(x, _CTX["residual_spec"])
    return x


def constrain_q5(q5):
    """Decode query [B,1,Hkv,g,dh]: reshard the (tiny) q to the cache's
    head_dim sharding so the giant cache operand never moves."""
    if _CTX["q5_spec"] is not None:
        return jax.lax.with_sharding_constraint(q5, _CTX["q5_spec"])
    return q5


def constrain_scores(s):
    """Decode attention scores [B,Hkv,g,1,S]: replicate over the model axis
    so the dh contraction completes with a psum instead of SPMD resharding
    (= all-gathering) the cache."""
    if _CTX["scores_spec"] is not None:
        return jax.lax.with_sharding_constraint(s, _CTX["scores_spec"])
    return s
