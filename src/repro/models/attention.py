"""GQA attention block: RoPE, qk-norm, QKV bias, sliding window, KV cache.

Dense einsum path by default (XLA counts its FLOPs in the dry-run); the
Pallas flash kernel is switched in via ``use_kernel`` for TPU runtime.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels.flash_attention.ops import attention as flash_ops
from ..kernels.flash_attention.ref import attention_ref
from .layers import apply_rope, dense_init, rmsnorm, rmsnorm_params


def attn_params(key, cfg, dtype):
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, (d, hq * dh), dtype),
        "wk": dense_init(ks[1], d, (d, hkv * dh), dtype),
        "wv": dense_init(ks[2], d, (d, hkv * dh), dtype),
        "wo": dense_init(ks[3], hq * dh, (hq * dh, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * dh,), dtype)
        p["bk"] = jnp.zeros((hkv * dh,), dtype)
        p["bv"] = jnp.zeros((hkv * dh,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_params(dh, dtype)
        p["k_norm"] = rmsnorm_params(dh, dtype)
    return p


def _project_qkv(p, cfg, x):
    b, s, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    return q, k, v


def chunked_attention(q, k, v, causal=True, window=None, chunk=1024,
                      unroll=False):
    """Flash-style attention in pure XLA: online softmax over KV chunks.

    q,k,v: [B,S,H(q/kv),D] time-major.  Never materializes the S×S score
    matrix — peak transient is [B,H,S,chunk].  ``unroll`` unrolls the chunk
    scan (dry-run cost accounting)."""
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    group = hq // hkv
    ck = min(chunk, s)
    pad = (-s) % ck
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (s + pad) // ck
    scale = d ** -0.5
    qf = q.astype(jnp.float32).transpose(0, 2, 1, 3)          # [B,Hq,S,D]
    kc = k.reshape(b, nc, ck, hkv, d).transpose(1, 0, 3, 2, 4)  # [nc,B,Hkv,ck,D]
    vc = v.reshape(b, nc, ck, hkv, d).transpose(1, 0, 3, 2, 4)
    qpos = jnp.arange(s)[None, None, :, None]                  # [1,1,S,1]

    def step(carry, xs):
        acc, m, l, ci = carry
        kch, vch = xs                                          # [B,Hkv,ck,D]
        kch = jnp.repeat(kch.astype(jnp.float32), group, axis=1)
        vch = jnp.repeat(vch.astype(jnp.float32), group, axis=1)
        sc = jnp.einsum("bhqd,bhkd->bhqk", qf, kch) * scale
        kpos = (ci * ck + jnp.arange(ck))[None, None, None, :]
        mask = kpos < s
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= (qpos - kpos) < window
        sc = jnp.where(mask, sc, -1e30)
        m_new = jnp.maximum(m, sc.max(axis=-1))
        pexp = jnp.exp(sc - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + pexp.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhqk,bhkd->bhqd", pexp, vch)
        return (acc, m_new, l, ci + 1), None

    acc0 = jnp.zeros((b, hq, s, d), jnp.float32)
    m0 = jnp.full((b, hq, s), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hq, s), jnp.float32)
    # checkpoint the chunk step: backward recomputes per-chunk scores
    # (flash-attention backward) instead of saving every [B,H,S,ck] tensor
    (acc, m, l, _), _ = jax.lax.scan(jax.checkpoint(step),
                                     (acc0, m0, l0, jnp.int32(0)),
                                     (kc, vc), unroll=nc if unroll else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)           # [B,S,Hq,D]


def attn_forward(p, cfg, x, positions, window=None, use_kernel: bool = False,
                 unroll: bool = False, chunk: int = 1024):
    """Full-sequence attention (train / prefill).  x: [B, S, d]."""
    from .act_sharding import constrain_kv, constrain_out, constrain_q
    q, k, v = _project_qkv(p, cfg, x)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain_q(q)              # sequence-parallel attention (optional)
    k, v = constrain_kv(k, v)
    win = window if window is not None else cfg.window
    s = x.shape[1]
    if use_kernel:
        out = flash_ops(q, k, v, causal=True, window=win, use_kernel=True)
    elif s > chunk:
        out = chunked_attention(q, k, v, causal=True, window=win,
                                chunk=chunk, unroll=unroll)
    else:
        out = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                            v.transpose(0, 2, 1, 3), causal=True,
                            window=win).transpose(0, 2, 1, 3)
    b, s = x.shape[:2]
    out = constrain_out(out.reshape(b, s, -1))
    return out @ p["wo"], (k, v)


def attn_decode(p, cfg, x, cache_k, cache_v, pos):
    """One-token decode with static-shape KV cache.

    x: [B, 1, d]; cache_k/v: [B, S_max, Hkv, Dh]; pos: [] int32 (tokens so
    far).  Returns (out [B,1,d], new_cache_k, new_cache_v).
    """
    b = x.shape[0]
    q, k, v = _project_qkv(p, cfg, x)
    posn = jnp.full((b, 1), pos, dtype=jnp.int32)
    q = apply_rope(q, posn, cfg.rope_theta)
    k = apply_rope(k, posn, cfg.rope_theta)
    cache_k = jax.lax.dynamic_update_slice(
        cache_k, k.astype(cache_k.dtype), (0, pos, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(
        cache_v, v.astype(cache_v.dtype), (0, pos, 0, 0))
    s_max = cache_k.shape[1]
    group = cfg.n_heads // cfg.n_kv_heads
    kx = jnp.repeat(cache_k, group, axis=2)      # [B, S, Hq, Dh]
    vx = jnp.repeat(cache_v, group, axis=2)
    scale = cfg.head_dim ** -0.5
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kx.astype(jnp.float32)) * scale
    kpos = jnp.arange(s_max)[None, None, None, :]
    mask = kpos <= pos
    if cfg.window is not None:
        mask &= kpos > pos - cfg.window
    s = jnp.where(mask, s, -1e30)
    pr = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", pr, vx.astype(jnp.float32))
    out = out.astype(x.dtype).reshape(b, 1, -1)
    return out @ p["wo"], cache_k, cache_v
