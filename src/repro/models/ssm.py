"""Recurrent blocks: xLSTM (mLSTM + sLSTM) and RG-LRU (RecurrentGemma).

Hardware adaptation notes (DESIGN.md §2):
* mLSTM is implemented as *chunkwise* gated linear attention: quadratic
  within a chunk (MXU-friendly), recurrent matrix-state carry across chunks
  (lax.scan).  Sigmoid input/forget gates replace the paper's exponential
  gating + max-stabilizer — same model class, numerically safe in bf16.
* sLSTM keeps its inherently sequential recurrence (lax.scan over time) with
  per-head recurrent mixing.
* RG-LRU is a diagonal linear recurrence → jax.lax.associative_scan
  (parallel prefix), with the temporal conv1d(4) in front, as in the paper.

All blocks expose (forward over a sequence, single-step decode with carried
state) pairs with identical parameters.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init


# ------------------------------------------------------------------ mLSTM
def mlstm_params(key, cfg, dtype):
    d, h = cfg.d_model, cfg.n_heads
    dh = d // h
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], d, (d, d), dtype),
        "wk": dense_init(ks[1], d, (d, d), dtype),
        "wv": dense_init(ks[2], d, (d, d), dtype),
        "wi": dense_init(ks[3], d, (d, h), dtype),   # input gate (per head)
        "wf": dense_init(ks[4], d, (d, h), dtype),   # forget gate (per head)
        "wo": dense_init(ks[5], d, (d, d), dtype),
        "f_bias": jnp.full((h,), 3.0, dtype),        # start remembering
    }


def _mlstm_chunk(carry, inp, dh):
    """One chunk. carry: (C [B,H,Dk,Dv], n [B,H,Dk]); inp per-chunk tensors."""
    C, n = carry
    q, k, v, logf, i = inp          # q,k,v: [B,L,H,Dh]; logf,i: [B,L,H]
    B, L, H, _ = q.shape
    F = jnp.cumsum(logf, axis=1)                        # [B,L,H]
    Ftot = F[:, -1]                                     # [B,H]
    # decay matrix D[j,i] = exp(F_j - F_i) * gate_i for i<=j
    Dm = F[:, :, None, :] - F[:, None, :, :]            # [B,L(j),L(i),H]
    tri = jnp.tril(jnp.ones((L, L), bool))
    Dm = jnp.where(tri[None, :, :, None], Dm, -jnp.inf)
    w = jnp.exp(Dm) * i[:, None, :, :]                  # [B,j,i,H]
    scale = dh ** -0.5
    s = jnp.einsum("bjhd,bihd->bjih", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    intra = jnp.einsum("bjih,bjih,bihd->bjhd", s, w, v.astype(jnp.float32))
    # contribution of carried state
    inter = jnp.einsum("bjhk,bhkd->bjhd", q.astype(jnp.float32) *
                       jnp.exp(F)[..., None] * scale, C)
    norm = jnp.einsum("bjhk,bhk->bjh", q.astype(jnp.float32) *
                      jnp.exp(F)[..., None] * scale, n)
    norm = norm + jnp.einsum("bjih,bjih->bjh", s, w)
    h_out = (intra + inter) / jnp.maximum(jnp.abs(norm), 1.0)[..., None]
    # state update
    decay_i = jnp.exp(Ftot[:, None, :] - F) * i         # [B,L,H]
    C = jnp.exp(Ftot)[..., None, None] * C + jnp.einsum(
        "bihd,bih,bihe->bhde", k.astype(jnp.float32), decay_i,
        v.astype(jnp.float32))
    n = jnp.exp(Ftot)[..., None] * n + jnp.einsum(
        "bihd,bih->bhd", k.astype(jnp.float32), decay_i)
    return (C, n), h_out


def mlstm_forward(p, cfg, x, chunk: int = 256, state=None):
    """x: [B,S,d] → ([B,S,d], final_state)."""
    B, S, d = x.shape
    H = cfg.n_heads
    dh = d // H
    L = min(chunk, S)
    pad = (-S) % L
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    q = (x @ p["wq"]).reshape(B, Sp, H, dh)
    k = (x @ p["wk"]).reshape(B, Sp, H, dh)
    v = (x @ p["wv"]).reshape(B, Sp, H, dh)
    i = jax.nn.sigmoid((x @ p["wi"]).astype(jnp.float32))
    logf = jax.nn.log_sigmoid((x @ p["wf"]).astype(jnp.float32)
                              + p["f_bias"].astype(jnp.float32))
    nc = Sp // L

    def chunked(t):  # [B,Sp,...] → [nc,B,L,...]
        return t.reshape(B, nc, L, *t.shape[2:]).swapaxes(0, 1)

    if state is None:
        state = (jnp.zeros((B, H, dh, dh), jnp.float32),
                 jnp.zeros((B, H, dh), jnp.float32))
    (Cf, nf), hs = jax.lax.scan(
        lambda c, inp: _mlstm_chunk(c, inp, dh), state,
        tuple(map(chunked, (q, k, v, logf, i))))
    h = hs.swapaxes(0, 1).reshape(B, Sp, d)[:, :S]
    return (h.astype(x.dtype) @ p["wo"]), (Cf, nf)


def mlstm_decode(p, cfg, x, state):
    """x: [B,1,d]; state (C,n) → ([B,1,d], new_state)."""
    B, _, d = x.shape
    H = cfg.n_heads
    dh = d // H
    C, n = state
    q = (x @ p["wq"]).reshape(B, H, dh).astype(jnp.float32)
    k = (x @ p["wk"]).reshape(B, H, dh).astype(jnp.float32)
    v = (x @ p["wv"]).reshape(B, H, dh).astype(jnp.float32)
    i = jax.nn.sigmoid((x @ p["wi"]).astype(jnp.float32)).reshape(B, H)
    f = jax.nn.sigmoid((x @ p["wf"]).astype(jnp.float32)
                       + p["f_bias"].astype(jnp.float32)).reshape(B, H)
    C = f[..., None, None] * C + i[..., None, None] * jnp.einsum(
        "bhd,bhe->bhde", k, v)
    n = f[..., None] * n + i[..., None] * k
    scale = dh ** -0.5
    num = jnp.einsum("bhd,bhde->bhe", q * scale, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q * scale, n)), 1.0)
    h = (num / den[..., None]).reshape(B, 1, d).astype(x.dtype)
    return h @ p["wo"], (C, n)


# ------------------------------------------------------------------ sLSTM
def slstm_params(key, cfg, dtype):
    d, h = cfg.d_model, cfg.n_heads
    dh = d // h
    ks = jax.random.split(key, 3)
    return {
        "wx": dense_init(ks[0], d, (d, 4 * d), dtype),        # i,f,z,o from x
        "rh": dense_init(ks[1], dh, (h, dh, 4 * dh), dtype),  # recurrent, per head
        "bias": jnp.zeros((4 * d,), dtype),
        "out": dense_init(ks[2], d, (d, d), dtype),
    }


def _slstm_step(p, cfg, xt, state):
    """xt: [B,d] pre-projected gates input; state (h, c, n)."""
    B = xt.shape[0]
    H = cfg.n_heads
    dh = cfg.d_model // H
    h_prev, c_prev, n_prev = state
    hx = h_prev.reshape(B, H, dh)
    rec = jnp.einsum("bhd,hde->bhe", hx, p["rh"].astype(jnp.float32))
    gates = xt.astype(jnp.float32).reshape(B, H, 4 * dh) + rec
    i, f, z, o = jnp.split(gates, 4, axis=-1)
    i = jnp.exp(jnp.minimum(i, 0.0))          # bounded exponential gate
    f = jax.nn.sigmoid(f + 3.0)
    z = jnp.tanh(z)
    o = jax.nn.sigmoid(o)
    c = f * c_prev + i * z
    n = f * n_prev + i
    h = o * c / jnp.maximum(n, 1.0)
    return h.reshape(B, -1), c, n


def slstm_forward(p, cfg, x, state=None):
    B, S, d = x.shape
    H = cfg.n_heads
    dh = d // H
    xg = x @ p["wx"] + p["bias"]
    if state is None:
        state = (jnp.zeros((B, d), jnp.float32),
                 jnp.zeros((B, H, dh), jnp.float32),
                 jnp.zeros((B, H, dh), jnp.float32))

    def step(carry, xt):
        h, c, n = _slstm_step(p, cfg, xt, carry)
        return (h, c, n), h

    state, hs = jax.lax.scan(step, state, xg.swapaxes(0, 1))
    h = hs.swapaxes(0, 1).astype(x.dtype)
    return h @ p["out"], state


def slstm_decode(p, cfg, x, state):
    xg = (x @ p["wx"] + p["bias"])[:, 0]
    h, c, n = _slstm_step(p, cfg, xg, state)
    return (h[:, None].astype(x.dtype) @ p["out"]), (h, c, n)


# ------------------------------------------------------------------ RG-LRU
def rglru_params(key, cfg, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    return {
        "in_x": dense_init(ks[0], d, (d, d), dtype),
        "in_gate": dense_init(ks[1], d, (d, d), dtype),
        "conv": (jax.random.normal(ks[2], (4, d), jnp.float32) * 0.1).astype(dtype),
        "wa": dense_init(ks[3], d, (d, d), dtype),   # recurrence gate
        "wi": dense_init(ks[4], d, (d, d), dtype),   # input gate
        "lam": jnp.full((d,), 2.0, jnp.float32),     # a = sigmoid(lam)^(c·r)
        "out": dense_init(ks[5], d, (d, d), dtype),
    }


_RG_C = 8.0


def _rg_gates(p, u):
    r = jax.nn.sigmoid((u @ p["wa"]).astype(jnp.float32))
    i = jax.nn.sigmoid((u @ p["wi"]).astype(jnp.float32))
    log_a = _RG_C * r * jax.nn.log_sigmoid(p["lam"])      # [.., d]
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))
    return a, beta * i * u.astype(jnp.float32)


def _causal_conv(p, u, state=None):
    """Depthwise temporal conv, width 4.  state: last 3 inputs [B,3,d]."""
    w = p["conv"].astype(jnp.float32)    # [4, d]
    if state is None:
        pads = jnp.zeros((u.shape[0], 3, u.shape[2]), u.dtype)
    else:
        pads = state.astype(u.dtype)
    ext = jnp.concatenate([pads, u], axis=1).astype(jnp.float32)
    out = sum(ext[:, 3 - t: ext.shape[1] - t] * w[3 - t] for t in range(4))
    new_state = ext[:, -3:]
    return out[:, : u.shape[1]].astype(u.dtype), new_state


def rglru_forward(p, cfg, x, state=None):
    """Recurrent block: (conv → RG-LRU) ⊙ gelu-gate → out.  x: [B,S,d]."""
    B, S, d = x.shape
    u = x @ p["in_x"]
    gate = jax.nn.gelu((x @ p["in_gate"]).astype(jnp.float32))
    conv_state = None if state is None else state["conv"]
    h0 = None if state is None else state["h"]
    u, conv_state = _causal_conv(p, u, conv_state)
    a, b = _rg_gates(p, u)                                # [B,S,d] each
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)
    # h_t = a_t h_{t-1} + b_t  — parallel prefix over time
    def op(l, r):
        return (l[0] * r[0], r[0] * l[1] + r[1])
    _, h = jax.lax.associative_scan(op, (a, b), axis=1)
    new_state = {"conv": conv_state, "h": h[:, -1]}
    y = (h * gate).astype(x.dtype) @ p["out"]
    return y, new_state


def rglru_decode(p, cfg, x, state):
    u = x @ p["in_x"]
    gate = jax.nn.gelu((x @ p["in_gate"]).astype(jnp.float32))
    u, conv_state = _causal_conv(p, u, state["conv"])
    a, b = _rg_gates(p, u)
    h = a[:, 0] * state["h"] + b[:, 0]
    y = (h[:, None] * gate).astype(x.dtype) @ p["out"]
    return y, {"conv": conv_state, "h": h}
