"""Model assembly: pattern-grouped blocks, scan-over-groups, KV/recurrent
caches, train forward + loss, and single-token decode.

Layer structure = ``cfg.pattern`` repeated; a *group* is one pattern period.
Groups are identical pytrees → stacked and driven by ``lax.scan`` (small HLO,
fast 512-device compiles).  ``n_layers % len(pattern)`` remainder blocks are
applied unrolled after the scan (e.g. recurrentgemma-9b's trailing 2 rec
blocks).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .attention import attn_forward, attn_params
from .layers import make_norm, mlp, mlp_params, normal_init
from .moe import moe_ffn_tp, moe_params
from .ssm import (mlstm_decode, mlstm_forward, mlstm_params, rglru_decode,
                  rglru_forward, rglru_params, slstm_decode, slstm_forward,
                  slstm_params)


def _has_ffn(cfg, kind: str) -> bool:
    return cfg.d_ff > 0 or (cfg.is_moe and kind == "attn")


# ------------------------------------------------------------------ blocks
def block_init(key, cfg, kind: str, dtype):
    norm_params, _ = make_norm(cfg.norm)
    ks = jax.random.split(key, 2)
    core = {"attn": attn_params, "mlstm": mlstm_params, "slstm": slstm_params,
            "rglru": rglru_params}[kind](ks[0], cfg, dtype)
    p = {"ln1": norm_params(cfg.d_model, dtype), "core": core}
    if _has_ffn(cfg, kind):
        p["ln2"] = norm_params(cfg.d_model, dtype)
        if cfg.is_moe:
            p["ffn"] = moe_params(ks[1], cfg, dtype)
        else:
            p["ffn"] = mlp_params(ks[1], cfg.d_model, cfg.d_ff, cfg.act, dtype)
    return p


def _ffn_apply(p, cfg, x):
    if cfg.is_moe:
        from .act_sharding import _CTX
        ep = _CTX.get("moe_ep")
        if ep is not None:
            from .moe import moe_ep_shardmap
            return moe_ep_shardmap(p["ffn"], cfg, x, **ep)
        return moe_ffn_tp(p["ffn"], cfg, x)
    return mlp(p["ffn"], x, cfg.act)


def block_forward(p, cfg, kind, x, positions, use_kernel=False, unroll=False):
    """Full-sequence block.  Returns (x, cache_entry)."""
    _, norm = make_norm(cfg.norm)
    h = norm(p["ln1"], x)
    if kind == "attn":
        out, (k, v) = attn_forward(p["core"], cfg, h, positions,
                                   use_kernel=use_kernel, unroll=unroll)
        cache = {"k": k, "v": v}
    elif kind == "mlstm":
        out, st = mlstm_forward(p["core"], cfg, h)
        cache = {"C": st[0], "n": st[1]}
    elif kind == "slstm":
        out, st = slstm_forward(p["core"], cfg, h)
        cache = {"h": st[0], "c": st[1], "n": st[2]}
    elif kind == "rglru":
        out, st = rglru_forward(p["core"], cfg, h)
        cache = st
    else:
        raise ValueError(kind)
    x = x + out
    if _has_ffn(cfg, kind):
        x = x + _ffn_apply(p, cfg, norm(p["ln2"], x))
    from .act_sharding import constrain_residual
    return constrain_residual(x), cache


def block_decode(p, cfg, kind, x, cache, pos):
    _, norm = make_norm(cfg.norm)
    h = norm(p["ln1"], x)
    if kind == "attn":
        out, ck, cv = attn_decode_cached(p["core"], cfg, h, cache, pos)
        new_cache = {**cache, "k": ck, "v": cv,
                     "slot_pos": cache["slot_pos"].at[pos % cache["k"].shape[1]]
                     .set(pos)}
    elif kind == "mlstm":
        out, st = mlstm_decode(p["core"], cfg, h, (cache["C"], cache["n"]))
        new_cache = {"C": st[0], "n": st[1]}
    elif kind == "slstm":
        out, st = slstm_decode(p["core"], cfg, h,
                               (cache["h"], cache["c"], cache["n"]))
        new_cache = {"h": st[0], "c": st[1], "n": st[2]}
    elif kind == "rglru":
        out, st = rglru_decode(p["core"], cfg, h, cache)
        new_cache = st
    else:
        raise ValueError(kind)
    x = x + out
    if _has_ffn(cfg, kind):
        x = x + _ffn_apply(p, cfg, norm(p["ln2"], x))
    return x, new_cache


def attn_decode_cached(p, cfg, x, cache, pos):
    """Ring-buffer-aware decode: cache slots carry absolute positions."""
    from .layers import apply_rope
    from .attention import _project_qkv
    b = x.shape[0]
    cache_k, cache_v, slot_pos = cache["k"], cache["v"], cache["slot_pos"]
    clen = cache_k.shape[1]
    q, k, v = _project_qkv(p, cfg, x)
    posn = jnp.full((b, 1), pos, dtype=jnp.int32)
    q = apply_rope(q, posn, cfg.rope_theta)
    k = apply_rope(k, posn, cfg.rope_theta)
    slot = pos % clen
    cache_k = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype),
                                           (0, slot, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype),
                                           (0, slot, 0, 0))
    kpos = slot_pos.at[slot].set(pos)            # [clen]
    group = cfg.n_heads // cfg.n_kv_heads
    # GQA without jnp.repeat: repeating a head_dim-sharded cache forces SPMD
    # into an involuntary full rematerialization (all-gather of the entire
    # cache per layer — §Perf B1).  The grouped einsum keeps the contraction
    # sharded; the resulting scores psum is MB-scale instead of GiB-scale.
    from .act_sharding import constrain_q5, constrain_scores
    q5 = q.reshape(b, 1, cfg.n_kv_heads, group, cfg.head_dim)
    q5 = constrain_q5(q5)         # reshard q (tiny), never the cache (§B3)
    # bf16 inputs + f32 accumulation (§Perf B2): .astype(f32) on the cache
    # would materialize a full-cache f32 copy per layer
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q5, cache_k,
                   preferred_element_type=jnp.float32) * cfg.head_dim ** -0.5
    s = constrain_scores(s)       # keep contraction dh-sharded → small psum
    m5 = (kpos <= pos) & (kpos >= 0)
    if cfg.window is not None:
        m5 &= kpos > pos - cfg.window
    s = jnp.where(m5[None, None, None, None, :], s, -1e30)
    pr = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", pr.astype(cache_v.dtype), cache_v,
                     preferred_element_type=jnp.float32)
    out = out.astype(x.dtype).reshape(b, 1, -1)
    return out @ p["wo"], cache_k, cache_v


# ------------------------------------------------------------------- model
def _group_count(cfg):
    gl = len(cfg.pattern)
    return cfg.n_layers // gl, cfg.n_layers % gl


def init_params(cfg, key=None, dtype=jnp.bfloat16):
    key = key if key is not None else jax.random.PRNGKey(0)
    n_groups, n_extra = _group_count(cfg)
    k_embed, k_groups, k_extra, k_head = jax.random.split(key, 4)
    params: dict[str, Any] = {}
    if cfg.embed_input:
        params["embed"] = normal_init(k_embed, (cfg.vocab, cfg.d_model),
                                      0.02, dtype)
    def group_init(k):
        ks = jax.random.split(k, len(cfg.pattern))
        return tuple(block_init(ks[i], cfg, kind, dtype)
                     for i, kind in enumerate(cfg.pattern))

    params["groups"] = jax.vmap(group_init)(
        jax.random.split(k_groups, n_groups))
    if n_extra:
        ks = jax.random.split(k_extra, n_extra)
        params["extra"] = tuple(
            block_init(ks[i], cfg, cfg.pattern[i], dtype)
            for i in range(n_extra))
    norm_params, _ = make_norm(cfg.norm)
    params["final_norm"] = norm_params(cfg.d_model, dtype)
    if not (cfg.tie_embeddings and cfg.embed_input):
        params["lm_head"] = normal_init(k_head, (cfg.d_model, cfg.vocab),
                                        0.02, dtype)
    return params


def embed_inputs(params, cfg, inputs):
    if cfg.embed_input:
        return jnp.take(params["embed"], inputs, axis=0)
    return inputs  # stub frontend already provided [B, S, d] embeddings


def unembed(params, cfg, x):
    if cfg.tie_embeddings and cfg.embed_input:
        return x @ params["embed"].T
    return x @ params["lm_head"]


def forward(params, cfg, inputs, use_kernel: bool = False,
            return_cache: bool = False, remat: bool = False,
            unroll: bool = False, return_hidden: bool = False):
    """Train/prefill forward.  inputs: [B,S] tokens or [B,S,d] embeddings.

    Returns logits [B,S,V] (and stacked caches when return_cache).
    ``unroll`` fully unrolls the group scan — used by the dry-run so XLA's
    cost_analysis sees every layer (scan bodies are otherwise counted once)."""
    x = embed_inputs(params, cfg, inputs)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)

    def group_fn(x, gp):
        caches = []
        for i, kind in enumerate(cfg.pattern):
            x, c = block_forward(gp[i], cfg, kind, x, positions,
                                 use_kernel=use_kernel, unroll=unroll)
            caches.append(c)
        return x, tuple(caches)

    body = jax.checkpoint(group_fn) if remat else group_fn
    n_groups = jax.tree.leaves(params["groups"])[0].shape[0]
    x, caches = jax.lax.scan(body, x, params["groups"],
                             unroll=n_groups if unroll else 1)
    extra_caches = []
    for i, bp in enumerate(params.get("extra", ())):
        fn = jax.checkpoint(block_forward, static_argnums=(1, 2, 5, 6)) \
            if remat else block_forward
        x, c = fn(bp, cfg, cfg.pattern[i], x, positions, use_kernel, unroll)
        extra_caches.append(c)
    _, norm = make_norm(cfg.norm)
    x = norm(params["final_norm"], x)
    if return_hidden:
        return x
    logits = unembed(params, cfg, x)
    if return_cache:
        return logits, (caches, tuple(extra_caches))
    return logits


def loss_fn(params, cfg, batch, use_kernel: bool = False, remat: bool = False,
            unroll: bool = False, loss_chunk: int | None = None):
    """Next-token cross-entropy.  batch: {"inputs": tokens|embeds,
    "targets": [B,S] int32, "mask": [B,S] (optional)}.

    ``loss_chunk``: stream the unembed + logsumexp over sequence chunks —
    the [B,S,V] logits tensor never materializes (peak-memory lever)."""
    tgt = batch["targets"]
    mask = batch.get("mask")
    if loss_chunk is None:
        logits = forward(params, cfg, batch["inputs"], use_kernel=use_kernel,
                         remat=remat, unroll=unroll)
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        gold = jnp.take_along_axis(logits.astype(jnp.float32),
                                   tgt[..., None], axis=-1)[..., 0]
        nll = lse - gold
        if mask is not None:
            return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
        return nll.mean()
    x = forward(params, cfg, batch["inputs"], use_kernel=use_kernel,
                remat=remat, unroll=unroll, return_hidden=True)
    B, S, d = x.shape
    c = min(loss_chunk, S)
    nc = S // c
    assert S % c == 0, "loss_chunk must divide seq_len"
    xs = (x.reshape(B, nc, c, d).swapaxes(0, 1),
          tgt.reshape(B, nc, c).swapaxes(0, 1),
          (mask.reshape(B, nc, c).swapaxes(0, 1) if mask is not None
           else jnp.ones((nc, B, c), jnp.float32)))

    def step(carry, chunk):
        xc, tc, mc = chunk
        logits = unembed(params, cfg, xc).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        s_nll, s_cnt = carry
        return (s_nll + ((lse - gold) * mc).sum(), s_cnt + mc.sum()), None

    (tot, cnt), _ = jax.lax.scan(jax.checkpoint(step), (0.0, 0.0), xs,
                                 unroll=nc if unroll else 1)
    return tot / jnp.maximum(cnt, 1.0)


# -------------------------------------------------------------------- cache
def init_cache(cfg, batch: int, ctx_len: int, dtype=jnp.bfloat16):
    """Stacked decode caches: (groups_cache, extra_cache)."""
    n_groups, n_extra = _group_count(cfg)
    clen = min(ctx_len, cfg.window) if cfg.window else ctx_len

    def one(kind):
        d, H, dh = cfg.d_model, cfg.n_heads, cfg.head_dim
        if kind == "attn":
            return {
                "k": jnp.zeros((batch, clen, cfg.n_kv_heads, dh), dtype),
                "v": jnp.zeros((batch, clen, cfg.n_kv_heads, dh), dtype),
                "slot_pos": jnp.full((clen,), -1, jnp.int32),
            }
        if kind == "mlstm":
            hd = d // H
            return {"C": jnp.zeros((batch, H, hd, hd), jnp.float32),
                    "n": jnp.zeros((batch, H, hd), jnp.float32)}
        if kind == "slstm":
            hd = d // H
            return {"h": jnp.zeros((batch, d), jnp.float32),
                    "c": jnp.zeros((batch, H, hd), jnp.float32),
                    "n": jnp.zeros((batch, H, hd), jnp.float32)}
        if kind == "rglru":
            return {"conv": jnp.zeros((batch, 3, d), jnp.float32),
                    "h": jnp.zeros((batch, d), jnp.float32)}
        raise ValueError(kind)

    group_cache = tuple(
        jax.tree.map(lambda t: jnp.broadcast_to(t, (n_groups,) + t.shape),
                     one(kind)) for kind in cfg.pattern)
    extra_cache = tuple(one(cfg.pattern[i]) for i in range(n_extra))
    return group_cache, extra_cache


def decode_step(params, cfg, inputs, cache, pos, unroll: bool = False):
    """One-token decode.  inputs: [B,1] tokens or [B,1,d] embeddings;
    cache from :func:`init_cache`; pos: [] int32.  Returns (logits [B,V],
    new_cache)."""
    group_cache, extra_cache = cache
    x = embed_inputs(params, cfg, inputs)

    def group_fn(x, scanned):
        gp, gc = scanned
        new = []
        for i, kind in enumerate(cfg.pattern):
            x, c = block_decode(gp[i], cfg, kind, x, gc[i], pos)
            new.append(c)
        return x, tuple(new)

    n_groups = jax.tree.leaves(params["groups"])[0].shape[0]
    x, new_group_cache = jax.lax.scan(group_fn, x,
                                      (params["groups"], group_cache),
                                      unroll=n_groups if unroll else 1)
    new_extra = []
    for i, bp in enumerate(params.get("extra", ())):
        x, c = block_decode(bp, cfg, cfg.pattern[i], x, extra_cache[i], pos)
        new_extra.append(c)
    _, norm = make_norm(cfg.norm)
    x = norm(params["final_norm"], x)
    logits = unembed(params, cfg, x)[:, 0]
    return logits, (new_group_cache, tuple(new_extra))
