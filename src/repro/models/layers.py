"""Shared neural-net layers (functional, pytree params, no framework)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def normal_init(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def dense_init(key, fan_in, shape, dtype):
    return normal_init(key, shape, fan_in ** -0.5, dtype)


# ---------------------------------------------------------------- norms
def rmsnorm_params(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_params(d, dtype):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


def make_norm(kind: str):
    if kind == "rmsnorm":
        return rmsnorm_params, rmsnorm
    return layernorm_params, layernorm


# ----------------------------------------------------------------- rope
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """x: [B, S, H, D]; positions: [B, S] (or [S])."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta), jnp.float32)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B,S,D/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------ mlp
def mlp_params(key, d_model, d_ff, act, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"down": dense_init(k2, d_ff, (d_ff, d_model), dtype)}
    if act in ("silu", "geglu"):   # gated: two up projections
        p["gate"] = dense_init(k1, d_model, (d_model, d_ff), dtype)
        p["up"] = dense_init(k3, d_model, (d_model, d_ff), dtype)
    else:                          # plain gelu MLP
        p["up"] = dense_init(k1, d_model, (d_model, d_ff), dtype)
    return p


def mlp(p, x, act: str):
    if act in ("silu", "geglu"):
        g = x @ p["gate"]
        u = x @ p["up"]
        h = (jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)) * u
    else:
        h = jax.nn.gelu(x @ p["up"])
    return h @ p["down"]
