"""Mixture-of-Experts block with explicit expert-parallel dispatch.

Two sharding regimes, chosen by divisibility (DESIGN.md §7):

* **EP** (E % expert_shards == 0, e.g. qwen3-moe 128e over 16): expert
  weights sharded over the expert axis; tokens dispatched by a capacity-
  bounded all-to-all.  The all-to-all is routed through
  :func:`repro.core.nap_collectives.hier_all_to_all` when the expert shards
  span the pod axis — the paper's NAP-3 applied to MoE dispatch.
* **TP** (otherwise, e.g. mixtral 8e over 16): every expert's d_ff sharded
  over the model axis; tokens stay local; partial sums reduced by the
  standard TP psum (GSPMD inserts it).

Routing: full-softmax → top-k → renormalize (qwen-style); capacity factor
drops overflow tokens (their combine weight is zero), standard for TPU MoE.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from .layers import dense_init


def moe_params(key, cfg, dtype):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff or cfg.d_ff
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], d, (d, e), jnp.float32),
        "gate": dense_init(ks[1], d, (e, d, f), dtype),
        "up": dense_init(ks[2], d, (e, d, f), dtype),
        "down": dense_init(ks[3], f, (e, f, d), dtype),
    }


def _route(x2, router, top_k):
    """x2: [T, d] → (probs [T,k] f32, sel [T,k] i32)."""
    logits = (x2.astype(jnp.float32) @ router).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    pv, sel = jax.lax.top_k(probs, top_k)
    pv = pv / jnp.maximum(pv.sum(-1, keepdims=True), 1e-9)
    return pv, sel


def _dispatch_indices(sel, n_experts, capacity):
    """Per (token, slot): expert id, position within expert (or >=capacity
    if dropped).  Sort-based, no [T, E, C] tensor."""
    T, k = sel.shape
    flat_e = sel.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    # position within expert among sorted entries
    counts = jnp.bincount(sorted_e, length=n_experts)
    starts = jnp.cumsum(counts) - counts
    pos_sorted = jnp.arange(T * k) - starts[sorted_e]
    pos = jnp.zeros_like(pos_sorted).at[order].set(pos_sorted)
    return flat_e.reshape(T, k), pos.reshape(T, k)


def _expert_ffn(w, h, act, tp_axis: str | None = None):
    """Batched expert FFN; ``tp_axis``: d_ff is sharded over this mesh axis
    (inside shard_map) — the down-projection partial sums are psum'd."""
    g = jnp.einsum("ecd,edf->ecf", h, w["gate"])
    u = jnp.einsum("ecd,edf->ecf", h, w["up"])
    gated = (jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)) * u
    out = jnp.einsum("ecf,efd->ecd", gated, w["down"])
    if tp_axis is not None:
        # single-axis TP reduce: no (slow, fast) split exists to aggregate
        # over, so the flat form IS the strategy here
        out = jax.lax.psum(out, tp_axis)  # comm-audit: allow flat-psum
    return out


def moe_ffn_tp(p, cfg, x):
    """TP regime: all experts on every device, d_ff sharded by GSPMD."""
    b, s, d = x.shape
    x2 = x.reshape(-1, d)
    T = x2.shape[0]
    probs, sel = _route(x2, p["router"], cfg.top_k)
    cap = max(int(T * cfg.top_k / cfg.n_experts * cfg.capacity_factor), 1)
    e_id, pos = _dispatch_indices(sel, cfg.n_experts, cap)
    keep = pos < cap
    # scatter tokens into [E, cap, d]
    buf = jnp.zeros((cfg.n_experts, cap, d), x.dtype)
    safe_pos = jnp.where(keep, pos, cap - 1)
    buf = buf.at[e_id.reshape(-1), safe_pos.reshape(-1)].add(
        jnp.where(keep.reshape(-1, 1), jnp.repeat(x2, cfg.top_k, axis=0), 0))
    from .act_sharding import constrain_moe_buf
    buf = constrain_moe_buf(buf)   # keep capacity dim dp-sharded
    out_buf = _expert_ffn(p, buf, cfg.act)
    out_buf = constrain_moe_buf(out_buf)
    # combine
    y = out_buf[e_id.reshape(-1), safe_pos.reshape(-1)]
    y = y * (probs.reshape(-1, 1) * keep.reshape(-1, 1)).astype(y.dtype)
    y = y.reshape(T, cfg.top_k, d).sum(axis=1)
    return y.reshape(b, s, d)


def moe_ep_shardmap(p, cfg, x, mesh, dp_axes=("data",), ep_axes=("data",),
                    tp_axis="model", nap: bool = False, seq_axis=None):
    """Expert-parallel MoE as an explicit shard_map region (production path).

    Experts sharded over ``ep_axes`` (default: the intra-pod "data" axis →
    dispatch all-to-all never crosses pods; expert weights replicated across
    pods, synced by the hierarchical gradient path).  d_ff sharded over
    ``tp_axis``.  x: [B, S, d] (batch over dp_axes)."""
    from jax.sharding import PartitionSpec as P
    B, S, d = x.shape

    def body(xl, router, gate, up, down):
        xl2 = xl.reshape(-1, d)
        pl = {"router": router[0] if router.ndim == 3 else router,
              "gate": gate, "up": up, "down": down}
        out = moe_ffn_ep(pl, cfg, xl2, mesh_axes=ep_axes, nap=nap,
                         tp_axis=tp_axis)
        return out.reshape(xl.shape)

    x_spec = P(dp_axes if dp_axes else None, seq_axis, None)
    w_spec = P(ep_axes if len(ep_axes) > 1 else ep_axes[0], None, None)
    wd_spec = P(ep_axes if len(ep_axes) > 1 else ep_axes[0], None, None)
    # d_ff sharding over tp_axis rides on dims 2 (gate/up) and 1 (down)
    w_spec = P(w_spec[0], None, tp_axis)
    wd_spec = P(wd_spec[0], tp_axis, None)
    from ..core.compat import shard_map
    return shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, P(), w_spec, w_spec, wd_spec),
        out_specs=x_spec, check_vma=False,
    )(x, p["router"], p["gate"], p["up"], p["down"])


def moe_ffn_ep(p, cfg, x, mesh_axes=("model",), nap: bool = False,
               tp_axis: str | None = None):
    """EP regime inside shard_map: dispatch local tokens to expert shards.

    ``x``: the per-device token block [Tloc, d]; ``p`` holds the LOCAL
    expert slab [e_loc, d, f] (already sharded by the caller's in_specs).
    ``mesh_axes``: axes the experts are sharded over; if it includes the pod
    axis and ``nap`` is set, the dispatch uses the NAP-3 two-hop all-to-all.
    """
    T, d = x.shape
    m = 1
    from ..core.compat import axis_size
    for ax in mesh_axes:
        m *= axis_size(ax)
    E = cfg.n_experts
    e_loc = E // m
    probs, sel = _route(x, p["router"], cfg.top_k)
    cap = max(int(T * cfg.top_k / E * cfg.capacity_factor), 1)
    e_id, pos = _dispatch_indices(sel, E, cap)
    keep = pos < cap
    safe_pos = jnp.where(keep, pos, cap - 1)
    send = jnp.zeros((E, cap, d), x.dtype)
    send = send.at[e_id.reshape(-1), safe_pos.reshape(-1)].add(
        jnp.where(keep.reshape(-1, 1), jnp.repeat(x, cfg.top_k, axis=0), 0))
    send = send.reshape(m, e_loc * cap * d)

    def a2a(buf):
        if len(mesh_axes) == 2 and nap:
            from ..core.nap_collectives import hier_all_to_all
            return hier_all_to_all(buf, mesh_axes[0], mesh_axes[1], "nap3")
        if len(mesh_axes) == 2:
            from ..core.nap_collectives import hier_all_to_all
            return hier_all_to_all(buf, mesh_axes[0], mesh_axes[1], "flat")
        # single expert-parallel axis: nothing hierarchical to route
        return jax.lax.all_to_all(buf, mesh_axes[0],  # comm-audit: allow flat-a2a
                                  split_axis=0, concat_axis=0, tiled=True)

    recv = a2a(send).reshape(m, e_loc, cap, d)          # [peers, e_loc, cap, d]
    h = recv.transpose(1, 0, 2, 3).reshape(e_loc, m * cap, d)
    y = _expert_ffn(p, h, cfg.act, tp_axis=tp_axis)      # [e_loc, m*cap, d]
    y = y.reshape(e_loc, m, cap, d).transpose(1, 0, 2, 3).reshape(
        m, e_loc * cap * d)
    back = a2a(y).reshape(E, cap, d)                     # same layout as send
    out = back[e_id.reshape(-1), safe_pos.reshape(-1)]
    out = out * (probs.reshape(-1, 1) * keep.reshape(-1, 1)).astype(out.dtype)
    return out.reshape(T, cfg.top_k, d).sum(axis=1)
