"""LM substrate: layers, attention, MoE, recurrent blocks, model assembly."""
from .model import (decode_step, forward, init_cache, init_params, loss_fn)

__all__ = ["decode_step", "forward", "init_cache", "init_params", "loss_fn"]
