"""Shared workload construction for the AMG serving harnesses.

Both serving drivers — the closed-loop in-process harness
(``repro.launch.serve --solver amg``) and the open-loop socket load
generator (``benchmarks/serve_load.py``) — build the same traffic: a
small family of 3-D Laplacian matrices registered by content
fingerprint, Gaussian right-hand sides encoded through the versioned
wire codec with one real JSON byte hop, and relative-residual
validation of every returned solution.  Factoring the construction here
keeps the two harnesses honest against each other: a load-generator
request is byte-for-byte the closed-loop harness's request.
"""
from __future__ import annotations

import json

import numpy as np

from ..amg.api import (csr_to_wire, matrix_fingerprint,
                       solve_request_to_wire, update_request_to_wire)
from ..amg.problems import laplace_3d


def default_tol(backend: str, tol: float | None = None) -> float:
    """The dist backend defaults to fp32, whose residual floor (~1e-7
    relative) sits above the host default tol — don't let every solve
    burn maxiter chasing an unreachable tolerance."""
    if tol is not None:
        return float(tol)
    return 1e-6 if backend == "dist" else 1e-8


def json_hop(obj: dict) -> dict:
    """One real JSON byte round-trip — proves the payload is what would
    survive an actual transport, not just a dict that happens to work."""
    return json.loads(json.dumps(obj))


def build_problems(n: int, count: int = 2) -> dict:
    """``count`` Laplacian test matrices at descending grid sizes starting
    from ``n`` (floor 4), keyed by content fingerprint — the id they
    register under over the wire."""
    sizes, size = [], max(4, int(n))
    for _ in range(max(1, count)):
        sizes.append(size)
        size = max(4, size - 2)
    out = {}
    for s in dict.fromkeys(sizes):
        A = laplace_3d(s)
        out[matrix_fingerprint(A)] = A
    return out


def matrix_payloads(problems: dict) -> dict:
    """Encoded registration payloads per matrix id (JSON round-tripped)."""
    return {mid: json_hop(csr_to_wire(A)) for mid, A in problems.items()}


def make_request(rng: np.random.Generator, problems: dict, mid: str, *,
                 method: str = "pcg", rid: int | None = None,
                 priority=None) -> tuple[np.ndarray, dict]:
    """One solve admission against ``mid``: a Gaussian right-hand side and
    its encoded (JSON round-tripped) ``solve_request`` payload."""
    b = rng.standard_normal(problems[mid].nrows)
    payload = json_hop(solve_request_to_wire(
        mid, b, method=method, rid=rid, priority=priority))
    return b, payload


def make_update(rng: np.random.Generator, problems: dict, mid: str, *,
                scale: float = 1e-3, rid: int | None = None) -> dict:
    """One streaming value update against ``mid``: a small random additive
    ΔA on the frozen sparsity pattern (symmetrized so pcg's SPD assumption
    survives the drift) as an encoded (JSON round-tripped)
    ``update_request`` payload.  Mutates ``problems[mid]`` to the drifted
    matrix so later residual validation uses the operator the server is
    actually solving with."""
    A = problems[mid]
    delta = scale * np.abs(A.data) * rng.standard_normal(A.nnz)
    # the Laplacian pattern is symmetric, so transposing the delta on the
    # frozen pattern and averaging keeps the drifted operator symmetric
    delta = 0.5 * (delta + A.__class__(A.shape, A.indptr, A.indices,
                                       delta).T.data)
    payload = json_hop(update_request_to_wire(mid, delta=delta, rid=rid))
    problems[mid] = A.__class__(A.shape, A.indptr, A.indices,
                                A.data + delta)
    return payload


def rel_residual(A, x: np.ndarray, b: np.ndarray) -> float:
    """``|b - A x| / |b|`` — the validation every harness applies to every
    returned solution."""
    nb = float(np.linalg.norm(b))
    return float(np.linalg.norm(b - A.matvec(np.asarray(x)))) / (nb or 1.0)


def percentile(sorted_samples: list[float], q: float) -> float:
    """Nearest-rank percentile over an already-sorted sample list."""
    if not sorted_samples:
        return float("nan")
    rank = max(0, min(len(sorted_samples) - 1,
                      int(np.ceil(q / 100.0 * len(sorted_samples))) - 1))
    return float(sorted_samples[rank])


def summarize_latencies(samples_s: list[float]) -> dict:
    """p50/p99/p999 + mean/max latency (milliseconds) of a sample list
    given in seconds; empty dict when there are no samples (a fully-shed
    class has no latency distribution)."""
    if not samples_s:
        return {}
    s = sorted(samples_s)
    return {"p50_ms": percentile(s, 50.0) * 1e3,
            "p99_ms": percentile(s, 99.0) * 1e3,
            "p999_ms": percentile(s, 99.9) * 1e3,
            "mean_ms": float(np.mean(s)) * 1e3,
            "max_ms": s[-1] * 1e3}
