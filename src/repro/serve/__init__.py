from .engine import Engine, Request, prefill_to_decode_cache

__all__ = ["Engine", "Request", "prefill_to_decode_cache"]
