"""Serving front-ends: the LM generation engine and the AMGWire socket
server.

The engine (jax-backed) is imported lazily so the pure-CPython serving
path — :mod:`repro.serve.server` / :mod:`repro.serve.client` /
:mod:`repro.serve.wire` — can run (tests, load generator, CI smoke)
without paying the jax import, and on hosts without an accelerator
runtime at all when the tenant configs stay on the host backend.
"""
from .client import AMGWireClient, Rejected, RemoteError
from .server import (AMGWireServer, ServerThread, TenantSpec,
                     priority_class_name, ticket_future)
from .wire import (BadFrame, FrameTooLarge, MAX_FRAME_BYTES, REQUEST_KINDS,
                   RESPONSE_KINDS, check_request_envelope, encode_frame,
                   error_frame, read_frame, response_frame)

__all__ = [
    "AMGWireClient", "AMGWireServer", "BadFrame", "Engine", "FrameTooLarge",
    "MAX_FRAME_BYTES", "REQUEST_KINDS", "RESPONSE_KINDS", "Rejected",
    "RemoteError", "Request", "ServerThread", "TenantSpec",
    "check_request_envelope", "encode_frame", "error_frame",
    "prefill_to_decode_cache", "priority_class_name", "read_frame",
    "response_frame", "ticket_future",
]

_ENGINE_EXPORTS = ("Engine", "Request", "prefill_to_decode_cache")


def __getattr__(name):
    if name in _ENGINE_EXPORTS:
        from . import engine
        return getattr(engine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
