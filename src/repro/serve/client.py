"""Blocking AMGWire client: one TCP connection, pipelined requests.

The client assigns a monotonically increasing ``seq`` to every request
and a background reader thread routes response frames back to the
waiting caller — so many threads can pipeline solves down one connection
and collect them out of order, exactly the shape the open-loop load
generator needs.  Responses come back as the raw envelope dicts;
:meth:`solve` additionally decodes ``solution`` frames into
``(x, diagnostics)`` and raises typed :class:`Rejected` /
:class:`RemoteError` for the backpressure and error frames, so callers
can tell "shed by admission" from "the solve failed" from "I sent
garbage" without string matching.
"""
from __future__ import annotations

import json
import socket
import struct
import threading
import time

import numpy as np

from ..amg.api import SUPPORTED_SCHEMAS, WIRE_SCHEMA, array_from_wire
from .wire import MAX_FRAME_BYTES, _HEADER


class Rejected(RuntimeError):
    """The server shed this request (429-style ``rejected`` frame)."""

    def __init__(self, frame: dict):
        self.frame = frame
        super().__init__(frame.get("reason", "rejected"))


class RemoteError(RuntimeError):
    """The server answered with a structured ``error`` frame."""

    def __init__(self, frame: dict):
        self.frame = frame
        self.code = frame.get("code")
        self.error = frame.get("error")
        super().__init__(f"[{self.code}] {self.error}: "
                         f"{frame.get('message')}")


class AMGWireClient:
    """``with AMGWireClient.connect(host, port) as c: c.solve(...)``."""

    def __init__(self, sock: socket.socket):
        self._sock = sock
        self._wlock = threading.Lock()
        self._slock = threading.Lock()
        self._next_seq = 0
        self._waiting: dict[int, "_Slot"] = {}
        self._orphans: list[dict] = []
        self._orphans_ready = threading.Event()
        self._closed = False
        self.hello: dict | None = None   # the server's greeting, once seen
        self.schema = WIRE_SCHEMA        # negotiated down on connect()
        self._reader = threading.Thread(target=self._read_loop,
                                        name="amg-wire-client", daemon=True)
        self._reader.start()

    @classmethod
    def connect(cls, host: str, port: int,
                timeout: float = 60.0) -> "AMGWireClient":
        """Connect and negotiate: the server greets with a ``hello`` frame
        advertising its ``supported_schemas``; the client speaks the
        highest version both sides know.  A server that never says hello
        (a pre-v2 server) leaves the client at its own default."""
        sock = socket.create_connection((host, port), timeout=timeout)
        sock.settimeout(None)
        client = cls(sock)
        try:
            frame = client.recv_unmatched(timeout=min(timeout, 5.0))
        except TimeoutError:
            return client
        if frame.get("kind") != "hello":     # not a greeting: put it back
            with client._slock:
                client._orphans.insert(0, frame)
                client._orphans_ready.set()
            return client
        client.hello = frame
        offered = frame.get("supported_schemas") or [WIRE_SCHEMA]
        common = [s for s in offered if s in SUPPORTED_SCHEMAS]
        if not common:
            client.close()
            raise RuntimeError(
                f"no common wire schema: server speaks {offered}, "
                f"client speaks {list(SUPPORTED_SCHEMAS)}")
        client.schema = max(common)
        return client

    # ----------------------------------------------------------- raw framing
    def send(self, kind: str, *, tenant: str | None = None,
             payload: dict | None = None, **extra) -> int:
        """Send one request frame; returns its ``seq`` (await it with
        :meth:`recv`)."""
        with self._slock:
            seq = self._next_seq
            self._next_seq += 1
            self._waiting[seq] = _Slot()
        frame = {"schema": self.schema, "kind": kind, "seq": seq, **extra}
        if tenant is not None:
            frame["tenant"] = tenant
        if payload is not None:
            frame["payload"] = payload
        self.send_raw(json.dumps(frame, separators=(",", ":"))
                      .encode("utf-8"))
        return seq

    def send_raw(self, body: bytes) -> None:
        """Send pre-encoded bytes as one frame (tests use this to send
        deliberately malformed bodies)."""
        with self._wlock:
            self._sock.sendall(_HEADER.pack(len(body)) + body)

    def recv(self, seq: int, timeout: float | None = 60.0) -> dict:
        """Block until the response for ``seq`` arrives; returns the raw
        envelope frame (kind may be solution/registered/rejected/error/...).
        """
        return self.recv_timed(seq, timeout)[0]

    def recv_timed(self, seq: int,
                   timeout: float | None = 60.0) -> tuple[dict, float]:
        """Like :meth:`recv` but also returns the ``perf_counter`` time the
        reader thread saw the response — so an open-loop load generator
        harvesting long after the fact still measures true latency."""
        with self._slock:
            slot = self._waiting[seq]
        if not slot.event.wait(timeout):
            raise TimeoutError(f"no response for seq {seq} "
                               f"after {timeout}s")
        with self._slock:
            self._waiting.pop(seq, None)
        if slot.frame is None:
            raise ConnectionError("connection closed while waiting "
                                  f"for seq {seq}")
        return slot.frame, slot.t_recv

    def recv_unmatched(self, timeout: float | None = 60.0) -> dict:
        """Block until a frame with no registered seq arrives (server
        responses to raw/malformed sends carry ``seq: null``)."""
        if not self._orphans_ready.wait(timeout):
            raise TimeoutError(f"no unmatched frame after {timeout}s")
        with self._slock:
            frame = self._orphans.pop(0)
            if not self._orphans:
                self._orphans_ready.clear()
        return frame

    # --------------------------------------------------------- typed helpers
    def register(self, tenant: str, payload: dict,
                 timeout: float | None = 60.0) -> dict:
        """Register an encoded CSR (``csr_to_wire`` payload); returns the
        ``registered`` frame.  Raises :class:`Rejected` on quota."""
        frame = self.recv(self.send("register", tenant=tenant,
                                    payload=payload), timeout)
        return self._typed(frame, "registered")

    def solve(self, tenant: str, payload: dict,
              timeout: float | None = 60.0) -> tuple[np.ndarray, dict]:
        """Submit an encoded solve request; returns ``(x, diagnostics)``.
        Raises :class:`Rejected` (shed) or :class:`RemoteError`."""
        frame = self.recv(self.send("solve", tenant=tenant,
                                    payload=payload), timeout)
        frame = self._typed(frame, "solution")
        return array_from_wire(frame["x"]), frame.get("diagnostics") or {}

    def update(self, tenant: str, payload: dict,
               timeout: float | None = 60.0) -> dict:
        """Stream a value update (``update_request_to_wire`` payload) into
        a tenant's live session; returns the ``updated`` frame (``action``
        is ``"refresh"`` or ``"resetup"``, ``reason`` the trigger).
        Raises :class:`RemoteError` — 404 for an unregistered matrix."""
        frame = self.recv(self.send("update", tenant=tenant,
                                    payload=payload), timeout)
        return self._typed(frame, "updated")

    def stats(self, tenant: str | None = None,
              timeout: float | None = 60.0) -> dict:
        frame = self.recv(self.send("stats", tenant=tenant), timeout)
        return self._typed(frame, "stats")

    def ping(self, timeout: float | None = 60.0) -> dict:
        return self._typed(self.recv(self.send("ping"), timeout), "pong")

    @staticmethod
    def _typed(frame: dict, want: str) -> dict:
        kind = frame.get("kind")
        if kind == want:
            return frame
        if kind == "rejected":
            raise Rejected(frame)
        if kind == "error":
            raise RemoteError(frame)
        raise RuntimeError(f"expected a {want!r} frame, got {kind!r}: "
                           f"{frame}")

    # ------------------------------------------------------------- lifecycle
    def close(self) -> None:
        self._closed = True
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self._sock.close()
        self._reader.join(timeout=10)

    def __enter__(self) -> "AMGWireClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------ read loop
    def _read_loop(self) -> None:
        try:
            while True:
                frame = self._read_frame()
                if frame is None:
                    break
                seq = frame.get("seq")
                t = time.perf_counter()
                with self._slock:
                    slot = self._waiting.get(seq)
                if slot is not None:
                    slot.frame = frame
                    slot.t_recv = t
                    slot.event.set()
                else:
                    with self._slock:
                        self._orphans.append(frame)
                        self._orphans_ready.set()
        finally:
            # wake every waiter so nobody blocks on a dead connection
            with self._slock:
                slots = list(self._waiting.values())
            for slot in slots:
                slot.event.set()

    def _read_frame(self) -> dict | None:
        header = self._recv_exact(_HEADER.size)
        if header is None:
            return None
        (length,) = struct.unpack(">I", header)
        if length > MAX_FRAME_BYTES:
            return None
        body = self._recv_exact(length)
        if body is None:
            return None
        try:
            obj = json.loads(body)
        except ValueError:
            return None
        return obj if isinstance(obj, dict) else None

    def _recv_exact(self, n: int) -> bytes | None:
        buf = b""
        while len(buf) < n:
            try:
                chunk = self._sock.recv(n - len(buf))
            except OSError:
                return None
            if not chunk:
                return None
            buf += chunk
        return buf


class _Slot:
    __slots__ = ("event", "frame", "t_recv")

    def __init__(self):
        self.event = threading.Event()
        self.frame: dict | None = None
        self.t_recv = 0.0
