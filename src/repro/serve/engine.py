"""Batched serving engine: prefill → decode with jitted steps, FIFO window
batching, greedy/temperature sampling, and prefill-cache conversion into the
ring-buffer decode layout."""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..models.model import decode_step, forward


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [S] tokens (or [S, d] embeddings)
    max_new_tokens: int = 16
    temperature: float = 0.0


def prefill_to_decode_cache(cfg, caches, ctx_len: int, prompt_len: int,
                            dtype=jnp.float32):
    """Convert forward(return_cache=True) output into decode cache layout
    (padded ring buffers + slot positions; recurrent states pass through)."""
    group_caches, extra_caches = caches
    clen = min(ctx_len, cfg.window) if cfg.window else ctx_len

    def conv_attn(c, stacked):
        k, v = c["k"], c["v"]                    # [..., B, S, H, dh]
        S = k.shape[-3]
        take = min(S, clen)
        ksl = k[..., S - take:, :, :]
        vsl = v[..., S - take:, :, :]
        positions = np.arange(S - take, S)
        slots = positions % clen
        pad_shape = list(ksl.shape)
        pad_shape[-3] = clen
        kbuf = jnp.zeros(pad_shape, dtype)
        vbuf = jnp.zeros(pad_shape, dtype)
        kbuf = kbuf.at[..., slots, :, :].set(ksl.astype(dtype))
        vbuf = vbuf.at[..., slots, :, :].set(vsl.astype(dtype))
        slot_pos = np.full((clen,), -1, np.int32)
        slot_pos[slots] = positions
        sp = jnp.asarray(slot_pos)
        if stacked:
            n_groups = k.shape[0]
            sp = jnp.broadcast_to(sp, (n_groups, clen))
        return {"k": kbuf, "v": vbuf, "slot_pos": sp}

    out_groups = []
    for i, kind in enumerate(cfg.pattern):
        c = group_caches[i]
        out_groups.append(conv_attn(c, True) if kind == "attn" else c)
    out_extra = []
    for i, c in enumerate(extra_caches):
        kind = cfg.pattern[i]
        out_extra.append(conv_attn(c, False) if kind == "attn" else c)
    return tuple(out_groups), tuple(out_extra)


class Engine:
    """Simple production-shaped engine: collects requests into a batch
    window, left-pads to a common length bucket, prefills once, then decodes
    in lockstep (continuous batching is a straightforward extension — the
    cache layout already supports per-slot positions)."""

    def __init__(self, cfg, params, max_batch: int = 8, ctx_len: int = 256,
                 dtype=jnp.float32):
        self.cfg, self.params = cfg, params
        self.max_batch, self.ctx_len, self.dtype = max_batch, ctx_len, dtype
        self._prefill = jax.jit(
            lambda p, t: forward(p, cfg, t, return_cache=True))
        self._decode = jax.jit(
            lambda p, t, c, pos: decode_step(p, cfg, t, c, pos))
        self.queue: list[Request] = []
        self.stats = {"prefill_s": 0.0, "decode_s": 0.0, "tokens": 0,
                      "batches": 0}

    def submit(self, req: Request):
        self.queue.append(req)

    def _sample(self, logits, temperatures, key):
        """Per-row sampling for a [B, V] logits batch: rows with
        temperature <= 0 take the greedy argmax, the rest draw from
        logits/T with their OWN temperature (requests in one batch are
        independent — one request's sampling mode must not leak into its
        batchmates')."""
        greedy = jnp.argmax(logits, axis=-1)
        if not np.any(temperatures > 0.0):
            return greedy
        temps = jnp.asarray(temperatures, dtype=logits.dtype)
        scaled = logits / jnp.where(temps > 0.0, temps, 1.0)[:, None]
        sampled = jax.random.categorical(key, scaled, axis=-1)
        return jnp.where(temps > 0.0, sampled, greedy)

    def run(self, key=None) -> dict[int, np.ndarray]:
        """Drain the queue; returns {rid: generated tokens}."""
        key = key if key is not None else jax.random.PRNGKey(0)
        results: dict[int, np.ndarray] = {}
        while self.queue:
            batch = self.queue[: self.max_batch]
            self.queue = self.queue[self.max_batch:]
            results.update(self._run_batch(batch, key))
            self.stats["batches"] += 1
            key = jax.random.fold_in(key, len(results))
        return results

    def _run_batch(self, reqs: list[Request], key) -> dict[int, np.ndarray]:
        cfg = self.cfg
        B = len(reqs)
        S = max(r.prompt.shape[0] for r in reqs)
        if cfg.embed_input:
            prompts = np.zeros((B, S), np.int32)
        else:
            prompts = np.zeros((B, S, cfg.d_model), np.float32)
        for i, r in enumerate(reqs):          # right-align = left-pad
            prompts[i, S - r.prompt.shape[0]:] = r.prompt
        t0 = time.perf_counter()
        logits, caches = self._prefill(self.params, jnp.asarray(prompts))
        cache = prefill_to_decode_cache(cfg, caches, self.ctx_len, S,
                                        self.dtype)
        self.stats["prefill_s"] += time.perf_counter() - t0
        max_new = max(r.max_new_tokens for r in reqs)
        temps = np.array([r.temperature for r in reqs], np.float32)
        toks = self._sample(logits[:, -1], temps, key)
        outs = [toks]
        t0 = time.perf_counter()
        for t in range(max_new - 1):
            step_in = toks[:, None]
            if not cfg.embed_input:   # embedding-input archs: feed embeddings
                step_in = jnp.zeros((B, 1, cfg.d_model), self.dtype)
            lg, cache = self._decode(self.params, step_in, cache,
                                     jnp.int32(S + t))
            key = jax.random.fold_in(key, t)
            toks = self._sample(lg, temps, key)
            outs.append(toks)
        self.stats["decode_s"] += time.perf_counter() - t0
        self.stats["tokens"] += int(max_new) * B
        gen = np.stack([np.asarray(o) for o in outs], axis=1)
        return {r.rid: gen[i, : r.max_new_tokens] for i, r in enumerate(reqs)}
