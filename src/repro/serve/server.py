"""AMGWire: the asyncio socket front-end over the AMG serving stack.

The ROADMAP's "millions of users" story needs real connections before any
of the admission machinery (coalescing windows, priority aging) can be
said to stretch anywhere — this module is that front-end.  One
:class:`AMGWireServer` hosts many named **tenants**; each tenant owns its
own :class:`~repro.amg.api.AMGConfig`, its own
:class:`~repro.amg.api.SessionStore` (eviction budgets scoped per tenant)
and its own quotas:

* ``max_inflight`` — bounded per-tenant admission queue (queued +
  executing).  Overload is shed by **priority class**: batch traffic is
  rejected once the queue is half full, default at three quarters,
  interactive only when completely full — so an overloaded tenant keeps
  serving its latency-critical stream while batch work gets explicit
  429-style ``rejected`` frames (never a dropped connection).
* ``max_matrix_bytes`` / ``max_matrices`` — registration quota: an
  over-quota ``register`` gets a ``rejected`` frame; the service's own
  bounded registry (same eviction machinery as the session store) is the
  backstop underneath.

Connections are plain asyncio streams speaking the length-prefixed JSON
frames of :mod:`repro.serve.wire`; the *content* of every frame is the
existing versioned codec (``csr_to_wire`` payloads register matrices by
verified content fingerprint, ``solve_request_to_wire`` payloads admit
solves, ``update_request_to_wire`` payloads stream ``A + ΔA`` value drift
into a tenant's live sessions — schema-v2 frames; the connection opens
with a ``hello`` frame advertising the schemas the server accepts).
Every decode failure — malformed JSON, schema-version mismatch,
unknown key, unknown matrix id — becomes a structured ``error`` frame and
the connection survives; the server process never dies on a bad payload.

The bridge from async connection handlers to the threaded
:class:`~repro.amg.api.AMGService` is the **awaitable ticket adapter**
(:func:`ticket_future`): ``submit`` returns a ticket immediately, the
ticket's done-callback resolves an asyncio future on the event loop, and
the handler awaits it — no polling thread per request, thousands of
in-flight solves per loop.
"""
from __future__ import annotations

import asyncio
import dataclasses
import math
import threading

from ..amg.api import AMGConfig, WireError
from ..amg.api.config import array_to_wire, csr_from_wire
from ..amg.api.service import AMGService, PRIORITY_CLASSES, ServiceClosed
from ..amg.api.sessions import LRUPolicy, SessionStore, _csr_nbytes
from .wire import (MAX_FRAME_BYTES, check_request_envelope, encode_frame,
                   error_frame, hello_frame, read_frame, response_frame)

# fraction of a tenant's max_inflight each priority class may fill before
# admission sheds it: batch loses half the queue to interactive headroom
SHED_FRACTIONS = {0: 1.0, 1: 0.75, 2: 0.5}
_CLASS_NAMES = {v: k for k, v in PRIORITY_CLASSES.items()}


def priority_class_name(prio: int) -> str:
    return _CLASS_NAMES.get(prio, str(prio))


def ticket_future(ticket, loop: asyncio.AbstractEventLoop) -> asyncio.Future:
    """The awaitable ticket adapter: an asyncio future resolved on ``loop``
    when the threaded scheduler finishes the ticket — ``(x, diagnostics)``
    on success, the solve-side exception (:class:`ServiceClosed` included)
    otherwise."""
    fut = loop.create_future()

    def _done(t):
        def _resolve():
            if fut.cancelled():
                return
            err = t.exception()
            if err is not None:
                fut.set_exception(err)
            else:
                fut.set_result((t.result(timeout=0), t.diagnostics))
        try:
            loop.call_soon_threadsafe(_resolve)
        except RuntimeError:
            pass                       # loop already closed: nobody waiting

    ticket.add_done_callback(_done)
    return fut


@dataclasses.dataclass
class TenantSpec:
    """One tenant's config + quotas (everything per-tenant by design: a
    noisy tenant exhausts its own queue and its own byte budget, never a
    neighbor's)."""

    config: AMGConfig = dataclasses.field(default_factory=AMGConfig)
    max_inflight: int = 32
    max_matrices: int = 64
    max_matrix_bytes: int | None = None
    max_rhs: int = 8
    coalesce_window: float = 0.0


class _Tenant:
    def __init__(self, name: str, spec: TenantSpec):
        self.name = name
        self.spec = spec
        self.service = AMGService(
            spec.config, max_rhs=spec.max_rhs,
            coalesce_window=spec.coalesce_window,
            store=SessionStore(LRUPolicy()),
            max_matrices=spec.max_matrices,
            max_matrix_bytes=spec.max_matrix_bytes)
        self.inflight = 0              # touched only on the event loop
        self.registered_bytes = 0
        self.counters = {"registered": 0, "admitted": 0, "completed": 0,
                         "updated": 0, "rejected": 0, "errors": 0}
        self.rejected_by_class: dict[str, int] = {}

    def admit_limit(self, prio: int) -> int:
        frac = SHED_FRACTIONS.get(max(0, min(int(prio), 2)), 0.5)
        return max(1, math.ceil(self.spec.max_inflight * frac))

    def stats(self) -> dict:
        return {**self.counters, "inflight": self.inflight,
                "max_inflight": self.spec.max_inflight,
                "rejected_by_class": dict(self.rejected_by_class),
                "service": dict(self.service.stats),
                "store": self.service.store.stats(),
                "matrices": self.service._matrices.stats()}


class AMGWireServer:
    """The multi-tenant asyncio front-end; see the module docstring.

    Lifecycle: ``await start(host, port)`` binds the socket and spawns one
    admission worker thread per tenant; ``await aclose()`` stops accepting,
    fails still-queued requests with :class:`ServiceClosed` (typed error
    frames, not hangs) and joins the workers.
    """

    def __init__(self, tenants: dict[str, TenantSpec] | None = None, *,
                 max_frame: int = MAX_FRAME_BYTES):
        self.tenants = {name: _Tenant(name, spec)
                        for name, spec in (tenants or {}).items()}
        self.max_frame = int(max_frame)
        self.connections = 0           # currently open
        self.dropped_connections = 0   # closed by a server-side failure
        self._server: asyncio.AbstractServer | None = None
        self._tasks: set[asyncio.Task] = set()

    # -------------------------------------------------------------- lifecycle
    async def start(self, host: str = "127.0.0.1",
                    port: int = 0) -> tuple[str, int]:
        """Bind and start serving; returns the actual (host, port) —
        ``port=0`` picks a free one."""
        for tenant in self.tenants.values():
            tenant.service.start()
        self._server = await asyncio.start_server(self._handle, host, port)
        sock = self._server.sockets[0].getsockname()
        return sock[0], sock[1]

    async def serve_forever(self) -> None:
        assert self._server is not None, "call start() first"
        await self._server.serve_forever()

    async def aclose(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None
        # flush=False: still-queued work fails typed (ServiceClosed); the
        # completion tasks then flush those as 503 error frames before we
        # return — a client awaiting a response at shutdown gets a frame,
        # never a silent hang
        for tenant in self.tenants.values():
            tenant.service.close(flush=False)
        if self._tasks:
            await asyncio.gather(*list(self._tasks),
                                 return_exceptions=True)

    def stats(self) -> dict:
        return {"connections": self.connections,
                "dropped_connections": self.dropped_connections,
                "tenants": {name: t.stats()
                            for name, t in self.tenants.items()}}

    # ------------------------------------------------------------ connections
    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        self.connections += 1
        lock = asyncio.Lock()          # serializes interleaved responses
        try:
            # unsolicited greeting: advertise the schema versions this
            # server accepts so the client can negotiate before sending
            await self._send(writer, lock, hello_frame(self.tenants))
            while True:
                try:
                    frame = await read_frame(reader, self.max_frame)
                except WireError as e:      # malformed/oversized frame
                    code = 413 if "exceeds" in str(e) else 400
                    await self._send(writer, lock,
                                     error_frame(None, e, code))
                    continue                # the stream stays aligned
                if frame is None:
                    break                   # client closed
                await self._dispatch(frame, writer, lock)
        except (ConnectionResetError, BrokenPipeError):
            pass                            # client vanished mid-write
        except Exception:
            self.dropped_connections += 1   # must stay 0: server-side bug
            raise
        finally:
            self.connections -= 1
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError, OSError):
                pass

    async def _send(self, writer: asyncio.StreamWriter, lock: asyncio.Lock,
                    frame: dict) -> None:
        async with lock:
            try:
                writer.write(encode_frame(frame))
                await writer.drain()
            except (ConnectionResetError, BrokenPipeError, OSError):
                pass                        # receiver gone; solve stands

    # --------------------------------------------------------------- dispatch
    async def _dispatch(self, frame: dict, writer, lock) -> None:
        seq = frame.get("seq")
        try:
            kind = check_request_envelope(frame)
        except WireError as e:
            await self._send(writer, lock, error_frame(seq, e, 400))
            return
        if kind == "ping":
            await self._send(writer, lock, response_frame(
                "pong", seq, tenants=sorted(self.tenants)))
            return
        if kind == "stats":
            name = frame.get("tenant")
            body = (self.stats() if name is None
                    else {"tenants": {name: t.stats()}}
                    if (t := self.tenants.get(name)) is not None else None)
            if body is None:
                await self._send(writer, lock, error_frame(
                    seq, KeyError(f"unknown tenant {name!r}"), 404))
                return
            await self._send(writer, lock,
                             response_frame("stats", seq, **body))
            return
        tenant = self.tenants.get(frame.get("tenant"))
        if tenant is None:
            await self._send(writer, lock, error_frame(
                seq, KeyError(f"unknown tenant {frame.get('tenant')!r}; "
                              f"known: {sorted(self.tenants)}"), 404))
            return
        payload = frame.get("payload")
        try:
            if kind == "register":
                await self._register(tenant, payload, seq, writer, lock)
            elif kind == "update":
                await self._update(tenant, payload, seq, writer, lock)
            else:
                await self._solve(tenant, payload, seq, writer, lock)
        except WireError as e:              # strict codec rejection
            tenant.counters["errors"] += 1
            await self._send(writer, lock, error_frame(seq, e, 400))
        except KeyError as e:               # unknown matrix id
            tenant.counters["errors"] += 1
            await self._send(writer, lock, error_frame(seq, e, 404))
        except ValueError as e:             # bad method/priority/shape
            tenant.counters["errors"] += 1
            await self._send(writer, lock, error_frame(seq, e, 400))
        except Exception as e:              # never take the server down
            tenant.counters["errors"] += 1
            await self._send(writer, lock, error_frame(seq, e, 500))

    async def _register(self, tenant: _Tenant, payload, seq,
                        writer, lock) -> None:
        A, fp = csr_from_wire(payload)      # WireError -> structured frame
        nbytes = _csr_nbytes(A)
        budget = tenant.spec.max_matrix_bytes
        already = fp in tenant.service._matrices
        if (budget is not None and not already
                and tenant.registered_bytes + nbytes > budget):
            tenant.counters["rejected"] += 1
            await self._send(writer, lock, response_frame(
                "rejected", seq, code=429, reason="matrix byte quota",
                tenant=tenant.name, registered_bytes=tenant.registered_bytes,
                matrix_bytes=nbytes, max_matrix_bytes=budget))
            return
        tenant.service.register(fp, A, fingerprint=fp)
        tenant.registered_bytes = tenant.service._matrices.stats()["bytes"]
        tenant.counters["registered"] += 1
        await self._send(writer, lock, response_frame(
            "registered", seq, matrix=fp, bytes=nbytes))

    async def _update(self, tenant: _Tenant, payload, seq,
                      writer, lock) -> None:
        # the refresh/re-setup is synchronous compute — run it off the
        # event loop so concurrent connections keep being served (a KeyError
        # for an unregistered fingerprint maps to a 404 error frame in
        # _dispatch, exactly like an unknown matrix id on the solve path)
        result = await asyncio.to_thread(tenant.service.update_wire, payload)
        tenant.counters["updated"] += 1
        await self._send(writer, lock, response_frame("updated", seq,
                                                      **result))

    async def _solve(self, tenant: _Tenant, payload, seq,
                     writer, lock) -> None:
        from ..amg.api.config import solve_request_from_wire
        kwargs = solve_request_from_wire(payload)   # strict decode first
        prio = AMGService._resolve_priority(kwargs.get("priority"))
        limit = tenant.admit_limit(prio)
        if tenant.inflight >= limit:
            cls = priority_class_name(prio)
            tenant.counters["rejected"] += 1
            tenant.rejected_by_class[cls] = \
                tenant.rejected_by_class.get(cls, 0) + 1
            await self._send(writer, lock, response_frame(
                "rejected", seq, code=429, reason="tenant over capacity",
                tenant=tenant.name, priority=cls,
                inflight=tenant.inflight, limit=limit,
                max_inflight=tenant.spec.max_inflight))
            return
        ticket = tenant.service.submit(**kwargs)    # KeyError/ValueError up
        tenant.service.stats["wire_requests"] += 1
        tenant.counters["admitted"] += 1
        tenant.inflight += 1
        fut = ticket_future(ticket, asyncio.get_running_loop())
        task = asyncio.ensure_future(
            self._complete(tenant, ticket, fut, seq, writer, lock))
        self._tasks.add(task)
        task.add_done_callback(self._tasks.discard)

    async def _complete(self, tenant: _Tenant, ticket, fut, seq,
                        writer, lock) -> None:
        try:
            x, diag = await fut
        except ServiceClosed as e:
            tenant.counters["errors"] += 1
            tenant.inflight -= 1
            await self._send(writer, lock, error_frame(seq, e, 503))
            return
        except asyncio.CancelledError:
            tenant.inflight -= 1
            raise
        except Exception as e:              # solve-side failure
            tenant.counters["errors"] += 1
            tenant.inflight -= 1
            await self._send(writer, lock, error_frame(seq, e, 500))
            return
        tenant.counters["completed"] += 1
        tenant.inflight -= 1
        await self._send(writer, lock, response_frame(
            "solution", seq, rid=ticket.rid, x=array_to_wire(x),
            diagnostics=diag))


class ServerThread:
    """Run an :class:`AMGWireServer` on a background thread with its own
    event loop — the sync-world entrypoint (demo, load-generator
    self-hosting, tests driving blocking clients).  Context manager::

        with ServerThread({"alpha": TenantSpec()}) as srv:
            ...connect to (srv.host, srv.port)...
    """

    def __init__(self, tenants: dict[str, TenantSpec], *,
                 host: str = "127.0.0.1", port: int = 0, **kw):
        self._tenants, self._host, self._port, self._kw = \
            tenants, host, port, kw
        self.server: AMGWireServer | None = None
        self.host: str | None = None
        self.port: int | None = None
        self._ready = threading.Event()
        self._loop: asyncio.AbstractEventLoop | None = None
        self._stop: asyncio.Event | None = None
        self._thread: threading.Thread | None = None
        self._failure: BaseException | None = None

    async def _main(self) -> None:
        self._loop = asyncio.get_running_loop()
        self._stop = asyncio.Event()
        self.server = AMGWireServer(self._tenants, **self._kw)
        try:
            self.host, self.port = await self.server.start(self._host,
                                                           self._port)
        except BaseException as e:
            self._failure = e
            self._ready.set()
            raise
        self._ready.set()
        await self._stop.wait()
        await self.server.aclose()

    def __enter__(self) -> "ServerThread":
        self._thread = threading.Thread(target=lambda: asyncio.run(
            self._main()), name="amg-wire-server", daemon=True)
        self._thread.start()
        self._ready.wait(timeout=60)
        if self._failure is not None:
            raise self._failure
        assert self.port is not None, "server failed to bind"
        return self

    def __exit__(self, *exc) -> None:
        if self._loop is not None and self._stop is not None:
            try:
                self._loop.call_soon_threadsafe(self._stop.set)
            except RuntimeError:
                pass
        if self._thread is not None:
            self._thread.join(timeout=60)
