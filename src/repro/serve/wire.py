"""Length-prefixed JSON framing for the AMGWire protocol.

One frame = a 4-byte big-endian unsigned length followed by that many
bytes of UTF-8 JSON.  The framing is the transport half of the serving
story; the *content* of every frame is the existing versioned wire codec
(:mod:`repro.amg.api.config`) wrapped in a small server envelope:

Client → server frames (``schema`` may be any version the server
supports — v1 frames still decode on a v2 server)::

    {"schema": 2, "kind": "register", "tenant": T, "seq": n,
     "payload": csr_to_wire(A)}
    {"schema": 2, "kind": "solve",    "tenant": T, "seq": n,
     "payload": solve_request_to_wire(...)}
    {"schema": 2, "kind": "update",   "tenant": T, "seq": n,   # schema ≥ 2
     "payload": update_request_to_wire(...)}
    {"schema": 2, "kind": "stats",    "tenant": T?, "seq": n}
    {"schema": 2, "kind": "ping",     "seq": n}

Server → client frames::

    {"schema": 2, "kind": "hello",      "seq": null,           # on connect
     "supported_schemas": [1, 2], "tenants": [...]}
    {"schema": 2, "kind": "registered", "seq": n, "matrix": fp,
     "bytes": nb}
    {"schema": 2, "kind": "solution",   "seq": n, "x": array_to_wire(x),
     "diagnostics": {...}}
    {"schema": 2, "kind": "updated",    "seq": n, "matrix": id,
     "action": "refresh"|"resetup", "reason": ...}
    {"schema": 2, "kind": "rejected",   "seq": n, "code": 429,
     "reason": ..., ...}       # admission backpressure, NEVER a dropped
                               # connection
    {"schema": 2, "kind": "error",      "seq": n?, "code": 4xx/5xx,
     "error": ExcName, "message": ...}
    {"schema": 2, "kind": "stats",      "seq": n, "tenants": {...}}
    {"schema": 2, "kind": "pong",       "seq": n}

``seq`` is a client-chosen correlation id: solves complete out of order,
so responses echo it.  The unsolicited ``hello`` frame (``seq: null``)
advertises the schema versions the server accepts so a client can
negotiate down (or refuse) before sending anything.  Decode failures
never desynchronize the stream — an oversized body is drained and a
too-large/undecodable frame surfaces as a typed :class:`WireError`
subclass the server turns into a structured ``error`` frame while the
connection stays up.
"""
from __future__ import annotations

import asyncio
import json
import struct

from ..amg.api.config import SUPPORTED_SCHEMAS, WIRE_SCHEMA, WireError

MAX_FRAME_BYTES = 1 << 26        # 64 MiB: far beyond any smoke matrix
_HEADER = struct.Struct(">I")

REQUEST_KINDS = ("register", "solve", "update", "stats", "ping")
RESPONSE_KINDS = ("hello", "registered", "solution", "updated", "rejected",
                  "error", "stats", "pong")
# frame kinds that did not exist in a given schema version: a frame
# claiming an older schema must not smuggle in newer kinds
_KIND_MIN_SCHEMA = {"update": 2}


class FrameTooLarge(WireError):
    """A frame's declared length exceeds the limit (body was drained, the
    stream stays aligned on the next frame boundary)."""


class BadFrame(WireError):
    """A frame's body is not a JSON object."""


def encode_frame(obj: dict) -> bytes:
    body = json.dumps(obj, separators=(",", ":")).encode("utf-8")
    if len(body) > MAX_FRAME_BYTES:
        raise FrameTooLarge(f"frame of {len(body)} bytes exceeds the "
                            f"{MAX_FRAME_BYTES}-byte limit")
    return _HEADER.pack(len(body)) + body


async def read_frame(reader: asyncio.StreamReader,
                     max_frame: int = MAX_FRAME_BYTES) -> dict | None:
    """Read one frame; ``None`` on EOF (clean or mid-frame disconnect).

    Raises :class:`FrameTooLarge` (after draining the oversized body) or
    :class:`BadFrame` — both recoverable: the next :func:`read_frame` on
    the same reader starts at the next frame boundary.
    """
    try:
        header = await reader.readexactly(_HEADER.size)
    except (asyncio.IncompleteReadError, ConnectionResetError):
        return None
    (length,) = _HEADER.unpack(header)
    if length > max_frame:
        remaining = length
        while remaining > 0:            # drain: stay frame-aligned
            chunk = await reader.read(min(remaining, 1 << 20))
            if not chunk:
                return None
            remaining -= len(chunk)
        raise FrameTooLarge(f"frame of {length} bytes exceeds the "
                            f"{max_frame}-byte limit")
    try:
        body = await reader.readexactly(length)
    except (asyncio.IncompleteReadError, ConnectionResetError):
        return None
    try:
        obj = json.loads(body)
    except (ValueError, UnicodeDecodeError) as e:
        raise BadFrame(f"frame body is not valid JSON: {e}") from e
    if not isinstance(obj, dict):
        raise BadFrame(f"frame body must be a JSON object, "
                       f"got {type(obj).__name__}")
    return obj


def check_request_envelope(frame: dict) -> str:
    """Validate a client frame's ``schema``/``kind``; returns the kind.
    Any supported schema version is accepted (a v1 client keeps working
    against a v2 server), but a kind introduced by a later version is
    rejected when the frame claims an older schema.  Raises
    :class:`WireError` on version mismatch or unknown kind (the server
    answers with a structured error frame, exactly like the inner codec's
    strict decoders)."""
    schema = frame.get("schema")
    if schema not in SUPPORTED_SCHEMAS:
        raise WireError(f"wire schema version mismatch: frame has "
                        f"{schema!r}, this server speaks "
                        f"{list(SUPPORTED_SCHEMAS)}")
    kind = frame.get("kind")
    if kind not in REQUEST_KINDS:
        raise WireError(f"unknown frame kind {kind!r}; "
                        f"known: {list(REQUEST_KINDS)}")
    if schema < _KIND_MIN_SCHEMA.get(kind, 1):
        raise WireError(f"frame kind {kind!r} needs schema >= "
                        f"{_KIND_MIN_SCHEMA[kind]}, frame has {schema}")
    return kind


def hello_frame(tenants) -> dict:
    """The unsolicited server greeting: advertises the schema versions the
    server accepts (clients negotiate on ``supported_schemas``) and the
    tenant names it hosts."""
    return response_frame("hello", None,
                          supported_schemas=list(SUPPORTED_SCHEMAS),
                          tenants=sorted(tenants))


def response_frame(kind: str, seq, **fields) -> dict:
    assert kind in RESPONSE_KINDS, kind
    return {"schema": WIRE_SCHEMA, "kind": kind, "seq": seq, **fields}


def error_frame(seq, exc: BaseException, code: int) -> dict:
    return response_frame("error", seq, code=code,
                          error=type(exc).__name__, message=str(exc))
