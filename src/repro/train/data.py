"""Data pipeline: deterministic synthetic stream (resumable by construction)
and a memmap-backed token-file reader with shuffled windows + host prefetch.

Fault-tolerance contract: the pipeline is a pure function of (seed, step) —
restoring a checkpointed ``step`` resumes the exact stream, on any number of
hosts (each host slices its data-parallel shard by rank)."""
from __future__ import annotations

import dataclasses
import queue
import threading

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab: int
    seed: int = 1234
    token_file: str | None = None     # None → synthetic
    n_hosts: int = 1
    host_id: int = 0


class TokenPipeline:
    """Yields {"inputs" [B,S+? ...], "targets" [B,S]} per step."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        if cfg.global_batch % cfg.n_hosts:
            raise ValueError("global_batch must divide across hosts")
        self.local_batch = cfg.global_batch // cfg.n_hosts
        self._mm = None
        if cfg.token_file:
            self._mm = np.memmap(cfg.token_file, dtype=np.int32, mode="r")
            if self._mm.size < cfg.seq_len + 1:
                raise ValueError("token file too small")

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        B, S = self.local_batch, cfg.seq_len
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, cfg.host_id]))
        if self._mm is None:
            toks = rng.integers(0, cfg.vocab, size=(B, S + 1), dtype=np.int32)
        else:
            max_start = self._mm.size - (S + 1)
            starts = rng.integers(0, max_start, size=B)
            toks = np.stack([self._mm[s:s + S + 1] for s in starts])
        return {"inputs": toks[:, :-1], "targets": toks[:, 1:].astype(np.int32)}

    # ------------------------------------------------------------- prefetch
    def prefetch(self, start_step: int, depth: int = 2):
        """Background-thread prefetching iterator (overlaps host data work
        with device steps)."""
        q: queue.Queue = queue.Queue(maxsize=depth)
        stop = threading.Event()

        def worker():
            s = start_step
            while not stop.is_set():
                q.put((s, self.batch_at(s)))
                s += 1

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()
            try:
                q.get_nowait()
            except queue.Empty:
                pass


def write_token_file(path: str, tokens: np.ndarray):
    np.asarray(tokens, dtype=np.int32).tofile(path)
