"""Hierarchical (node-aware) gradient synchronization — the paper's NAP-3
applied to data-parallel training, with optional int8 compression + error
feedback on the pod-crossing leg.

Inside shard_map:  reduce-scatter(fast/ICI) → [quantize] all-reduce(slow/DCI)
→ all-gather(fast).  Compared to a flat all-reduce over (pod × data), the
expensive axis carries 1/|fast| of the bytes — and 1/4 of those with int8.

Error feedback keeps the quantization unbiased over time: the residual of
each quantization is added to the next step's gradient (Karimireddy et al.
style), so compression does not change the fixed point.
"""
# comm-audit: allow-file raw-collective — this module IS a hierarchical
# collective implementation (the int8 variant of nap_collectives.hier_psum
# with error feedback); its RS/AR/AG legs are the primitives themselves.
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.compat import axis_size
from ..core.nap_collectives import hier_psum


def quantize_int8(x: jnp.ndarray):
    """Symmetric per-tensor int8.  Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def hier_grad_sync(grads, slow_axis: str, fast_axis: str,
                   strategy: str = "nap3", compress_slow: bool = False,
                   error_feedback=None):
    """Mean-reduce a gradient pytree over (slow × fast) data parallelism.

    Returns (synced_grads, new_error_feedback).  Call inside shard_map with
    per-device grads.  ``error_feedback`` must match ``grads`` (zeros to
    start) when ``compress_slow``.
    """
    n_slow = axis_size(slow_axis)
    n_fast = axis_size(fast_axis)
    denom = float(n_slow * n_fast)

    if strategy == "flat" or not compress_slow:
        synced = jax.tree.map(
            lambda g: hier_psum(g.astype(jnp.float32), slow_axis, fast_axis,
                                strategy) / denom, grads)
        return synced, error_feedback

    # NAP-3 with int8 pod-crossing leg + error feedback
    def one(g, ef):
        g = g.astype(jnp.float32)
        shape = g.shape
        flat = g.reshape(-1)
        pad = (-flat.size) % n_fast
        if pad:
            flat = jnp.pad(flat, (0, pad))
        piece = jax.lax.psum_scatter(flat, fast_axis, scatter_dimension=0,
                                     tiled=True)                # [n/|fast|]
        piece = piece + ef
        q, scale = quantize_int8(piece)
        residual = piece - dequantize_int8(q, scale)            # new EF
        # int8 payload crosses the slow axis (all-gather int8 + local sum —
        # 4× fewer DCI bytes than an f32 ring all-reduce, visible in HLO);
        # per-device scales are one f32 each.
        qg = jax.lax.all_gather(q, slow_axis, axis=0)           # [n_slow, L] i8
        sg = jax.lax.all_gather(scale, slow_axis, axis=0)       # [n_slow]
        summed = jnp.sum(qg.astype(jnp.float32) * sg[:, None], axis=0)
        full = jax.lax.all_gather(summed, fast_axis, axis=0, tiled=True)
        if pad:
            full = full[:-pad]
        return full.reshape(shape) / denom, residual

    leaves_g, treedef = jax.tree.flatten(grads)
    leaves_e = (treedef.flatten_up_to(error_feedback)
                if error_feedback is not None else
                [jnp.zeros(((l.size + (-l.size) % n_fast) // n_fast,),
                           jnp.float32) for l in leaves_g])
    out = [one(g, e) for g, e in zip(leaves_g, leaves_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def init_error_feedback(grads, n_fast: int):
    return jax.tree.map(
        lambda g: jnp.zeros(((g.size + (-g.size) % n_fast) // n_fast,),
                            jnp.float32), grads)
