"""AdamW (+ global-norm clip, warmup-cosine schedule) — self-contained,
f32 master moments regardless of param dtype."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params, moment_dtype=jnp.float32) -> dict[str, Any]:
    """``moment_dtype=bf16`` halves moment memory (large-model option; the
    update math still runs in f32)."""
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def adamw_update(cfg: AdamWConfig, params, grads, state):
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, count)

    def upd(p, g, m, v):
        mdt = m.dtype
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m32 / (1 - cfg.b1 ** count.astype(jnp.float32))
        vhat = v32 / (1 - cfg.b2 ** count.astype(jnp.float32))
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        newp = p.astype(jnp.float32) - lr * (step + decay)
        return newp.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    leaves_p, treedef = jax.tree.flatten(params)
    leaves_g = treedef.flatten_up_to(grads)
    leaves_m = treedef.flatten_up_to(state["m"])
    leaves_v = treedef.flatten_up_to(state["v"])
    res = [upd(p, g, m, v) for p, g, m, v in
           zip(leaves_p, leaves_g, leaves_m, leaves_v)]
    newp = treedef.unflatten([r[0] for r in res])
    newm = treedef.unflatten([r[1] for r in res])
    newv = treedef.unflatten([r[2] for r in res])
    return newp, {"m": newm, "v": newv, "count": count}, {
        "grad_norm": gnorm, "lr": lr}
