"""Parameter/batch sharding rules for the (pod, data, model) meshes.

Canonical tensor-parallel layout (megatron-style) with MoE expert-parallel
placement by divisibility (DESIGN.md §7):

    embed (V, d)        → (model, ∅)          lm_head (d, V) → (∅, model)
    wq/wk/wv (d, H·dh)  → (∅, model)          wo (H·dh, d)   → (model, ∅)
    mlp gate/up (d, f)  → (∅, model)          down (f, d)    → (model, ∅)
    moe E % |model|==0  → experts over model  else d_ff over model
    norms / biases / small recurrent tensors  → replicated

Stacked scan groups carry a leading n_groups dim → specs get a leading ∅.
Anything not matched falls back to "shard the largest divisible dim, else
replicate" (safe for the recurrent-block tensors).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _rule(key_parts, shape, cfg, model_axis, model_size, ep_axis=None,
          ep_size=1):
    name = key_parts[-1]
    nd = len(shape)
    # params under the scan "groups" carry a stacked leading n_groups dim
    lead = 1 if key_parts and key_parts[0] == "groups" else 0
    pre = (None,) * lead

    def ok(dim_size):
        return dim_size % model_size == 0

    if name == "embed":
        return P(model_axis, None) if ok(shape[0]) else P()
    if name == "lm_head":
        return P(None, model_axis) if ok(shape[1]) else P()
    if name in ("wq", "wk", "wv", "wo") and "ffn" not in key_parts:
        # attention/mlstm head sharding: only when the head count divides the
        # axis — otherwise the (B,S,H,dh) reshape cuts across heads and GSPMD
        # inserts giant reshard all-reduces.  Replicated kv projections under
        # GQA (H_kv < tp) is the standard production layout.
        heads = cfg.n_kv_heads if name in ("wk", "wv") else cfg.n_heads
        if heads % model_size != 0:
            return P()
        if name == "wo":
            return P(*pre, model_axis, None) if ok(shape[-2]) else P()
        return P(*pre, None, model_axis) if ok(shape[-1]) else P()
    if name == "wx":      # sLSTM gates reshape per-head → keep replicated
        return P()
    if name in ("in_x", "in_gate", "up", "gate") and "ffn" not in key_parts:
        return P(*pre, None, model_axis) if ok(shape[-1]) else P()
    if name in ("out", "down") and "ffn" not in key_parts:
        return P(*pre, model_axis, None) if ok(shape[-2]) else P()
    if "ffn" in key_parts:
        if name == "router":
            return P()
        if cfg.is_moe:
            e = cfg.n_experts
            if ep_axis is not None and e % ep_size == 0:
                # expert-parallel over the intra-pod ep_axis (replicated
                # across pods) + d_ff TP over model — the shard_map EP path
                if name in ("gate", "up"):
                    return P(*pre, ep_axis, None, model_axis)
                if name == "down":
                    return P(*pre, ep_axis, model_axis, None)
            if name in ("gate", "up"):
                if e % model_size == 0:
                    return P(*pre, model_axis, None, None)
                # FSDP the d_model dim over "data" (ZeRO-3: gathered at use)
                # — without it, non-EP expert weights don't fit HBM
                return P(*pre, None, "data", model_axis) if ok(shape[-1]) \
                    else P()
            if name == "down":
                if e % model_size == 0:
                    return P(*pre, model_axis, None, None)
                return P(*pre, None, model_axis, "data") if ok(shape[-2]) \
                    else P()
        else:
            if name in ("gate", "up"):
                return P(*pre, None, model_axis) if ok(shape[-1]) else P()
            if name == "down":
                return P(*pre, model_axis, None) if ok(shape[-2]) else P()
    # fallback: shard the largest divisible *matrix* dim.  1-D-per-layer
    # params (norm scales, biases — possibly stacked to 2-D by the group
    # scan) stay replicated: sharding them fragments every activation.
    if nd - lead >= 2:
        order = np.argsort(shape)[::-1]
        for dim in order:
            if shape[dim] % model_size == 0 and shape[dim] >= 2 * model_size \
                    and dim >= lead:
                spec = [None] * nd
                spec[dim] = model_axis
                return P(*spec)
    return P()


def param_specs(cfg, params_shape, model_axis="model", model_size=16,
                ep_axis=None, ep_size=1):
    """Pytree of PartitionSpec matching ``params_shape`` (shapes/arrays)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = []
    for path, leaf in flat:
        parts = []
        for e in path:
            parts.append(str(getattr(e, "key", getattr(e, "idx", e))))
        specs.append(_rule(parts, leaf.shape, cfg, model_axis, model_size,
                           ep_axis, ep_size))
    return jax.tree_util.tree_unflatten(treedef, specs)


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def batch_specs(cfg, dp_axes, embeds: bool):
    inp = P(dp_axes, None, None) if embeds else P(dp_axes, None)
    return {"inputs": inp, "targets": P(dp_axes, None)}


def zero1_opt_specs(pspecs, opt_abs, dp_axes, mesh=None):
    """ZeRO-1: shard AdamW moments over the data-parallel axes too, on the
    first dim that is free (unsharded) and divisible — params stay as-is,
    moments stop being replicated across dp."""
    flat_p, treedef = jax.tree_util.tree_flatten(
        pspecs, is_leaf=lambda x: isinstance(x, P))
    flat_m = treedef.flatten_up_to(jax.tree.map(
        lambda l: l, opt_abs["m"]))
    sizes = dict(mesh.shape) if mesh is not None else {"pod": 2, "data": 16}

    def shard_m(spec, leaf):
        if not dp_axes:
            return spec
        # only axes not already used by the param spec (e.g. EP-MoE params
        # are data-sharded already)
        used_axes = set()
        for s in spec:
            for a in (s if isinstance(s, tuple) else (s,)):
                if a is not None:
                    used_axes.add(a)
        free = tuple(a for a in dp_axes if a not in used_axes)
        if not free:
            return spec
        dp_sz = int(np.prod([sizes.get(a, 16) for a in free]))
        dims = leaf.shape
        used = set(i for i, s in enumerate(spec) if s is not None) \
            if len(spec) else set()
        for i, d in enumerate(dims):
            if i in used:
                continue
            if d % dp_sz == 0 and d >= dp_sz:
                new = list(spec) + [None] * (len(dims) - len(spec))
                new[i] = free if len(free) > 1 else free[0]
                return P(*new)
        return spec

    m_specs = treedef.unflatten([shard_m(s, l)
                                 for s, l in zip(flat_p, flat_m)])
    return {"m": m_specs, "v": m_specs, "count": P()}


def cache_specs(cfg, dp_axes, model_axis="model"):
    """Decode-cache sharding: batch over dp; long KV seq over model."""
    def per_kind(kind):
        if kind == "attn":
            # [n_groups, B, S, Hkv, dh]: batch over dp, head_dim over model.
            # S must stay unsharded: the ring-buffer write is a dynamic
            # slice at a runtime position — sharding S forces SPMD full
            # rematerialization.  dh divides the model axis for every
            # assigned arch; the score contraction becomes a psum.
            return {"k": P(None, dp_axes, None, None, model_axis),
                    "v": P(None, dp_axes, None, None, model_axis),
                    "slot_pos": P(None, None)}
        if kind == "mlstm":
            return {"C": P(None, dp_axes, None, None, None),
                    "n": P(None, dp_axes, None, None)}
        if kind == "slstm":
            return {"h": P(None, dp_axes, None),
                    "c": P(None, dp_axes, None, None),
                    "n": P(None, dp_axes, None, None)}
        if kind == "rglru":
            return {"conv": P(None, dp_axes, None, None),
                    "h": P(None, dp_axes, None)}
        raise ValueError(kind)

    group = tuple(per_kind(k) for k in cfg.pattern)
    n_extra = cfg.n_layers % len(cfg.pattern)

    def drop_lead(spec_tree):
        return jax.tree.map(lambda s: P(*s[1:]), spec_tree,
                            is_leaf=lambda x: isinstance(x, P))

    extra = tuple(drop_lead(per_kind(cfg.pattern[i])) for i in range(n_extra))
    return group, extra
