"""Train-step builder: pjit'd loss+grad+AdamW with microbatch accumulation,
remat, and mesh shardings from :mod:`repro.train.sharding`."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models.model import loss_fn
from .optimizer import AdamWConfig, adamw_update
from .sharding import batch_specs, named, param_specs


@dataclasses.dataclass(frozen=True)
class TrainOptions:
    remat: bool = True
    microbatches: int = 1
    use_kernel: bool = False
    dp_axes: tuple[str, ...] = ("data",)
    model_axis: str = "model"
    unroll: bool = False        # dry-run: unroll scans for exact cost_analysis
    zero2: bool = False         # shard grad accumulator over dp axes
    loss_chunk: int | None = None  # stream unembed+xent over seq chunks


def make_step_fn(cfg, acfg: AdamWConfig, opts: TrainOptions,
                 grad_spec_tree=None):
    """The pure step function (jit/pjit applied by callers).
    ``grad_spec_tree``: PartitionSpec tree for ZeRO-2 grad-accumulator
    sharding constraints (opts.zero2)."""

    def loss_of(params, mb):
        return loss_fn(params, cfg, mb, use_kernel=opts.use_kernel,
                       remat=opts.remat, unroll=opts.unroll,
                       loss_chunk=opts.loss_chunk)

    def constrain(tree):
        if not (opts.zero2 and grad_spec_tree is not None):
            return tree
        return jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s),
            tree, grad_spec_tree)

    def step(params, opt_state, batch):
        if opts.microbatches == 1:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
            grads = constrain(grads)
        else:
            mb = opts.microbatches

            def split(x):
                return x.reshape((mb, x.shape[0] // mb) + x.shape[1:])

            batches = jax.tree.map(split, batch)
            zero = constrain(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))

            def acc(carry, mbatch):
                gsum, lsum = carry
                l, g = jax.value_and_grad(loss_of)(params, mbatch)
                gsum = constrain(jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g))
                return (gsum, lsum + l), None

            (gsum, lsum), _ = jax.lax.scan(acc, (zero, 0.0), batches,
                                           unroll=mb if opts.unroll else 1)
            grads = jax.tree.map(lambda g: g / mb, gsum)
            loss = lsum / mb
        params, opt_state, om = adamw_update(acfg, params, grads, opt_state)
        return params, opt_state, {"loss": loss, **om}

    return step


def build_train_step(cfg, acfg: AdamWConfig, opts: TrainOptions,
                     mesh=None, params_shape=None, donate: bool = True):
    """Returns (jitted step, (param_sh, opt_sh, batch_sh)); mesh=None → plain
    single-device jit (CPU smoke/e2e paths)."""
    step = make_step_fn(cfg, acfg, opts)
    if mesh is None:
        return jax.jit(step, donate_argnums=(0, 1) if donate else ()), None
    model_size = mesh.shape[opts.model_axis]
    pspec = param_specs(cfg, params_shape, opts.model_axis, model_size)
    p_sh = named(mesh, pspec)
    o_sh = {"m": p_sh, "v": p_sh,
            "count": NamedSharding(mesh, P())}
    b_spec = batch_specs(cfg, opts.dp_axes, embeds=not cfg.embed_input)
    b_sh = {k: NamedSharding(mesh, v) for k, v in b_spec.items()}
    metrics_sh = {"loss": NamedSharding(mesh, P()),
                  "grad_norm": NamedSharding(mesh, P()),
                  "lr": NamedSharding(mesh, P())}
    fn = jax.jit(step,
                 in_shardings=(p_sh, o_sh, b_sh),
                 out_shardings=(p_sh, o_sh, metrics_sh),
                 donate_argnums=(0, 1) if donate else ())
    return fn, (p_sh, o_sh, b_sh)
