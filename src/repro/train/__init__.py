from .data import DataConfig, TokenPipeline
from .loop import LoopConfig, train
from .optimizer import AdamWConfig, adamw_update, init_opt_state
from .train_step import TrainOptions, build_train_step, make_step_fn

__all__ = ["DataConfig", "TokenPipeline", "LoopConfig", "train",
           "AdamWConfig", "adamw_update", "init_opt_state", "TrainOptions",
           "build_train_step", "make_step_fn"]
