"""Fault-tolerant training loop.

Features (large-scale runnability deliverables):
* auto-resume from the latest atomic checkpoint (params, optimizer, step);
* periodic async checkpointing (host snapshot + background write);
* preemption handling: SIGTERM/SIGINT triggers a final checkpoint and a
  clean exit(0) so the scheduler can reschedule the job;
* straggler watchdog: per-step wall time EMA; steps slower than
  ``straggler_factor``× the EMA are logged with their step index (on a real
  cluster this feeds the controller's replace-node decision);
* elastic restart: checkpoints store *global* arrays; restore re-shards to
  the current mesh (see repro.ckpt.checkpoint.restore).
"""
from __future__ import annotations

import dataclasses
import signal
import time

import jax

from ..ckpt import latest_step, restore, save_async
from ..models.model import init_params
from .data import DataConfig, TokenPipeline
from .optimizer import AdamWConfig, init_opt_state
from .train_step import TrainOptions, build_train_step


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    log_every: int = 10
    straggler_factor: float = 3.0
    seed: int = 0


def train(cfg, acfg: AdamWConfig, dcfg: DataConfig, lcfg: LoopConfig,
          opts: TrainOptions | None = None, mesh=None, dtype=None,
          log=print):
    import jax.numpy as jnp
    dtype = dtype or jnp.float32
    opts = opts or TrainOptions(remat=False)
    params = init_params(cfg, jax.random.PRNGKey(lcfg.seed), dtype)
    opt_state = init_opt_state(params)
    step_fn, _ = build_train_step(cfg, acfg, opts, mesh=mesh,
                                  params_shape=params)
    start = 0
    last = latest_step(lcfg.ckpt_dir)
    if last is not None:
        template = {"params": params, "opt": opt_state}
        restored = restore(lcfg.ckpt_dir, last, template)
        params, opt_state = restored["params"], restored["opt"]
        params = jax.tree.map(jnp.asarray, params)
        opt_state = jax.tree.map(jnp.asarray, opt_state)
        start = last
        log(f"[loop] resumed from step {last}")

    pipe = TokenPipeline(dcfg)
    stop_requested = {"flag": False}

    def _sig(_signum, _frame):
        stop_requested["flag"] = True

    old_handlers = [(s, signal.signal(s, _sig))
                    for s in (signal.SIGTERM, signal.SIGINT)]
    ema = None
    history = []
    try:
        for step in range(start, lcfg.total_steps):
            t0 = time.perf_counter()
            batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(step).items()}
            if not cfg.embed_input:
                pass
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            ema = dt if ema is None else 0.9 * ema + 0.1 * dt
            if dt > lcfg.straggler_factor * ema and step > start + 3:
                log(f"[loop] STRAGGLER step {step}: {dt:.3f}s vs ema {ema:.3f}s")
            history.append(loss)
            if step % lcfg.log_every == 0:
                log(f"[loop] step {step} loss {loss:.4f} "
                    f"gnorm {float(metrics['grad_norm']):.3f} {dt * 1e3:.0f}ms")
            if (step + 1) % lcfg.ckpt_every == 0 or stop_requested["flag"] \
                    or step + 1 == lcfg.total_steps:
                save_async(lcfg.ckpt_dir, step + 1,
                           {"params": params, "opt": opt_state},
                           metadata={"loss": loss})
            if stop_requested["flag"]:
                log(f"[loop] preemption requested; checkpointed at {step + 1}")
                break
    finally:
        for s, h in old_handlers:
            signal.signal(s, h)
        from ..ckpt.checkpoint import _pending
        for t in list(_pending):
            t.join()
    return params, opt_state, history
