"""Pallas TPU kernel: row-blocked ELL SpMV.

TPU adaptation of the paper's SpMV hot loop (DESIGN.md §2).  The sparse
matrix is stored in ELL (fixed K nonzeros per padded row) — the layout the
distributed solve path already uses, and a gather-friendly layout for the
VPU.  Tiling:

  * grid over row blocks; per step the kernel sees a (BLOCK_ROWS, K) tile of
    column ids + values in VMEM,
  * the source vector ``x`` is resident in VMEM for every step (BlockSpec
    with a constant index_map): AMG level vectors after partitioning are
    ≤ a few hundred KB per device, far under the ~16 MB v5e VMEM budget,
  * gather x[cols] + multiply-accumulate over K on the VPU (8×128 lanes);
    rows are padded to a multiple of 8 and K left at its natural size.

An MXU/BCSR variant (dense 128×128 blocks fed to the systolic array) is the
natural next step for matrices with block structure; the AMG stencil
matrices here are scalar, so the VPU gather form is the right first target.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spmv_kernel(cols_ref, vals_ref, x_ref, y_ref):
    cols = cols_ref[...]          # (BLOCK_ROWS, K) int32
    vals = vals_ref[...]          # (BLOCK_ROWS, K)
    x = x_ref[...]                # (m,) resident vector
    safe = jnp.maximum(cols, 0)
    gathered = jnp.take(x, safe, axis=0)          # VPU gather
    contrib = jnp.where(cols >= 0, vals * gathered, 0.0)
    y_ref[...] = contrib.sum(axis=1)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def ell_spmv(cols: jnp.ndarray, vals: jnp.ndarray, x: jnp.ndarray,
             block_rows: int = 256, interpret: bool = True) -> jnp.ndarray:
    """y = A·x with A in padded ELL form (cols==-1 padding)."""
    n, k = cols.shape
    br = min(block_rows, max(8, n))
    pad = (-n) % br
    if pad:
        cols = jnp.pad(cols, ((0, pad), (0, 0)), constant_values=-1)
        vals = jnp.pad(vals, ((0, pad), (0, 0)))
    grid = (cols.shape[0] // br,)
    y = pl.pallas_call(
        _spmv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, k), lambda i: (i, 0)),
            pl.BlockSpec((br, k), lambda i: (i, 0)),
            pl.BlockSpec((x.shape[0],), lambda i: (0,)),  # x resident
        ],
        out_specs=pl.BlockSpec((br,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((cols.shape[0],), vals.dtype),
        interpret=interpret,
    )(cols, vals, x)
    return y[:n]
