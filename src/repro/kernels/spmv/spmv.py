"""Pallas TPU kernels: row-blocked ELL SpMV and native multi-RHS SpMM.

TPU adaptation of the paper's SpMV hot loop (DESIGN.md §2).  The sparse
matrix is stored in ELL (fixed K nonzeros per padded row) — the layout the
distributed solve path already uses, and a gather-friendly layout for the
VPU.  Tiling:

  * grid over row blocks; per step the kernel sees a (BLOCK_ROWS, K) tile of
    column ids + values in VMEM,
  * the source vector ``x`` is resident in VMEM for every step (BlockSpec
    with a constant index_map): AMG level vectors after partitioning are
    ≤ a few hundred KB per device, far under the ~16 MB v5e VMEM budget,
  * gather x[cols] + multiply-accumulate over K on the VPU (8×128 lanes);
    rows are padded to a multiple of 8 and K left at its natural size.

Two batching regimes:

  * :func:`ell_spmv` — one right-hand side, ``x`` of shape ``[m]``.
  * :func:`ell_spmm` — the native multi-RHS form, ``x`` of shape ``[m, k]``:
    the kernel gathers whole *rows* of X and accumulates ``(BLOCK_ROWS, K,
    k)`` contributions, so ONE pass over ``cols``/``vals`` serves all k
    right-hand sides.  This is what coalesced serving batches route through
    instead of ``jax.vmap(ell_spmv)`` (which re-reads A's nonzeros k times).

Degenerate shapes are short-circuited before ``pallas_call``: K == 0 (empty
coarse operator rows) and n == 0 / m == 0 return exact zeros instead of
building a zero-size BlockSpec, and tiny n no longer over-pads past the
``max(8, n)`` block-rows clamp.

The MXU-blocked BCSR variant (dense bs×bs blocks contracted via
``jax.lax.dot_general`` on the systolic array) lives in
:mod:`repro.kernels.spmv.bcsr`; the per-level choice between the two layouts
is :func:`repro.kernels.spmv.ops.select_local_kernel`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spmv_kernel(cols_ref, vals_ref, x_ref, y_ref):
    cols = cols_ref[...]          # (BLOCK_ROWS, K) int32
    vals = vals_ref[...]          # (BLOCK_ROWS, K)
    x = x_ref[...]                # (m,) resident vector
    safe = jnp.maximum(cols, 0)
    gathered = jnp.take(x, safe, axis=0)          # VPU gather
    contrib = jnp.where(cols >= 0, vals * gathered, 0.0)
    y_ref[...] = contrib.sum(axis=1)


def _spmm_kernel(cols_ref, vals_ref, x_ref, y_ref):
    cols = cols_ref[...]          # (BLOCK_ROWS, K) int32
    vals = vals_ref[...]          # (BLOCK_ROWS, K)
    x = x_ref[...]                # (m, k) resident RHS block
    safe = jnp.maximum(cols, 0)
    # gather whole rows of X once per stored nonzero: (BLOCK_ROWS, K, k)
    gathered = jnp.take(x, safe.reshape(-1), axis=0)
    gathered = gathered.reshape(cols.shape + (x.shape[1],))
    contrib = jnp.where((cols >= 0)[..., None],
                        vals[..., None] * gathered, 0.0)
    y_ref[...] = contrib.sum(axis=1)              # (BLOCK_ROWS, k)


def _row_blocking(n: int, block_rows: int) -> tuple[int, int]:
    """(block_rows, row_padding) for an n-row ELL operand: blocks of at
    least 8 rows (VPU sublane), never over-padding tiny n past one block."""
    br = min(block_rows, max(8, n))
    return br, (-n) % br


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def ell_spmv(cols: jnp.ndarray, vals: jnp.ndarray, x: jnp.ndarray,
             block_rows: int = 256, interpret: bool = True) -> jnp.ndarray:
    """y = A·x with A in padded ELL form (cols==-1 padding)."""
    n, k = cols.shape
    if n == 0 or k == 0 or x.shape[0] == 0:
        # empty rows / empty operator / empty source: exact zeros — a
        # (br, 0) BlockSpec or an empty-x gather would crash pallas_call
        return jnp.zeros((n,), dtype=vals.dtype)
    br, pad = _row_blocking(n, block_rows)
    if pad:
        cols = jnp.pad(cols, ((0, pad), (0, 0)), constant_values=-1)
        vals = jnp.pad(vals, ((0, pad), (0, 0)))
    grid = (cols.shape[0] // br,)
    y = pl.pallas_call(
        _spmv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, k), lambda i: (i, 0)),
            pl.BlockSpec((br, k), lambda i: (i, 0)),
            pl.BlockSpec((x.shape[0],), lambda i: (0,)),  # x resident
        ],
        out_specs=pl.BlockSpec((br,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((cols.shape[0],), vals.dtype),
        interpret=interpret,
    )(cols, vals, x)
    return y[:n]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def ell_spmm(cols: jnp.ndarray, vals: jnp.ndarray, x: jnp.ndarray,
             block_rows: int = 256, interpret: bool = True) -> jnp.ndarray:
    """Y = A·X with A in padded ELL form and X of shape ``[m, k]``.

    One pass over ``cols``/``vals`` serves all k columns: the kernel gathers
    rows of X and accumulates a (BLOCK_ROWS, K, k) contribution block, so
    A's nonzeros are read once instead of once per RHS as under
    ``jax.vmap(ell_spmv)``.
    """
    n, K = cols.shape
    m, k = x.shape
    if n == 0 or K == 0 or m == 0 or k == 0:
        return jnp.zeros((n, k), dtype=vals.dtype)
    br, pad = _row_blocking(n, block_rows)
    if pad:
        cols = jnp.pad(cols, ((0, pad), (0, 0)), constant_values=-1)
        vals = jnp.pad(vals, ((0, pad), (0, 0)))
    grid = (cols.shape[0] // br,)
    y = pl.pallas_call(
        _spmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, K), lambda i: (i, 0)),
            pl.BlockSpec((br, K), lambda i: (i, 0)),
            pl.BlockSpec((m, k), lambda i: (0, 0)),       # X resident
        ],
        out_specs=pl.BlockSpec((br, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((cols.shape[0], k), vals.dtype),
        interpret=interpret,
    )(cols, vals, x)
    return y[:n]
