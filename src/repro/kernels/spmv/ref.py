"""Pure-jnp oracles for the ELL SpMV / SpMM kernels."""
import jax.numpy as jnp


def ell_spmv_ref(cols: jnp.ndarray, vals: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """y[i] = Σ_k vals[i,k] · x[cols[i,k]]   (cols == -1 are padding).

    cols: [n, K] int32, vals: [n, K], x: [m] — m covers every valid col id.
    """
    safe = jnp.maximum(cols, 0)
    contrib = jnp.where(cols >= 0, vals * x[safe], 0.0)
    return contrib.sum(axis=1)


def ell_spmm_ref(cols: jnp.ndarray, vals: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Y[i, :] = Σ_k vals[i,k] · X[cols[i,k], :] — the multi-RHS oracle.

    cols: [n, K] int32, vals: [n, K], x: [m, k].  Identical summation order
    to :func:`ell_spmv_ref` per column, so the two agree bit-for-bit.
    """
    if cols.shape[1] == 0:
        return jnp.zeros((cols.shape[0], x.shape[1]), dtype=vals.dtype)
    safe = jnp.maximum(cols, 0)
    contrib = jnp.where((cols >= 0)[..., None], vals[..., None] * x[safe], 0.0)
    return contrib.sum(axis=1)
