"""Pure-jnp oracle for the ELL SpMV kernel."""
import jax.numpy as jnp


def ell_spmv_ref(cols: jnp.ndarray, vals: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """y[i] = Σ_k vals[i,k] · x[cols[i,k]]   (cols == -1 are padding).

    cols: [n, K] int32, vals: [n, K], x: [m] — m covers every valid col id.
    """
    safe = jnp.maximum(cols, 0)
    contrib = jnp.where(cols >= 0, vals * x[safe], 0.0)
    return contrib.sum(axis=1)
