"""Public jit'd wrappers for the sparse local-SpMV kernel tier.

``spmv``/``spmm`` dispatch between the Pallas kernels and the pure-jnp
oracles; :func:`select_local_kernel` is the layout heuristic the
distributed solve phase uses to pick, per level, between the VPU-gather
ELL kernels and the MXU-blocked BCSR kernel.
"""
from __future__ import annotations

import jax
import numpy as np

from .bcsr import BLOCK_SIZES, bcsr_spmm, bcsr_spmv
from .ref import ell_spmm_ref, ell_spmv_ref
from .spmv import ell_spmm, ell_spmv


def spmv(cols, vals, x, *, use_kernel: bool = True, block_rows: int = 256,
         interpret: bool | None = None):
    """ELL SpMV.  ``interpret=None`` → interpret on CPU, compiled on TPU."""
    if not use_kernel:
        return ell_spmv_ref(cols, vals, x)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return ell_spmv(cols, vals, x, block_rows=block_rows, interpret=interpret)


def spmm(cols, vals, x, *, use_kernel: bool = True, block_rows: int = 256,
         interpret: bool | None = None):
    """Native multi-RHS ELL SpMM (``x``: [m, k]) — one pass over A serves
    every column; the fallback oracle keeps identical summation order."""
    if not use_kernel:
        return ell_spmm_ref(cols, vals, x)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return ell_spmm(cols, vals, x, block_rows=block_rows, interpret=interpret)


# --------------------------------------------------------------------------
# Per-level layout selection: ELL (VPU gather) vs BCSR (MXU block contract)
# --------------------------------------------------------------------------

# How many stored-value touches a BCSR lane is worth relative to an ELL
# gather lane: dense bs×bs contractions run on the MXU at matmul rate while
# ELL pays a scalar gather per nonzero, so BCSR can afford this factor of
# explicit-zero fill before it loses.  4 is deliberately conservative for
# the v5e (the MXU:VPU FLOP ratio is far higher, but BCSR still streams the
# zero-filled blocks from HBM — bandwidth, not FLOPs, bounds sparse work).
MXU_ADVANTAGE = 4.0


def _bcsr_stats(cols: np.ndarray, bs: int) -> tuple[int, int]:
    """(n_blocks, Kb) of blocking an ELL block's coordinates at bs."""
    n, _ = cols.shape
    r = np.repeat(np.arange(n, dtype=np.int64), cols.shape[1])
    c = np.asarray(cols, dtype=np.int64).reshape(-1)
    keep = c >= 0
    r, c = r[keep], c[keep]
    if r.size == 0:
        return 0, 0
    keys = np.unique((r // bs) << 32 | (c // bs))
    brows = keys >> 32
    kb = int(np.bincount(brows).max(initial=0))
    return int(keys.size), kb


def select_local_kernel(cols: np.ndarray,
                        block_sizes: tuple[int, ...] = BLOCK_SIZES,
                        mxu_advantage: float = MXU_ADVANTAGE) -> dict:
    """Choose the local-SpMV layout for one ELL block: ``cols`` [n, K].

    Compares the MXU-adjusted stored-value volume of each candidate BCSR
    blocking (``n_blocks·bs² / mxu_advantage`` — explicit-zero fill made
    cheaper by the dense-math rate) against the ELL volume ``n·K``
    (padding waste included).  Returns a dict::

        {"kernel": "ell" | "bcsr", "block_size": 0 | bs,
         "ell_cost": float, "bcsr_cost": float,
         "ell_fill": nnz / (n·K), "bcsr_fill": nnz / (n_blocks·bs²)}

    so callers can log the decision, not just apply it.
    """
    cols = np.asarray(cols)
    n, K = cols.shape
    nnz = int((cols >= 0).sum())
    ell_cost = float(n * max(K, 1))
    best = {"kernel": "ell", "block_size": 0, "ell_cost": ell_cost,
            "bcsr_cost": float("inf"),
            "ell_fill": nnz / ell_cost if ell_cost else 0.0, "bcsr_fill": 0.0}
    if nnz == 0:
        return best
    for bs in block_sizes:
        n_blocks, _ = _bcsr_stats(cols, bs)
        stored = n_blocks * bs * bs
        cost = stored / mxu_advantage
        if cost < best["bcsr_cost"]:
            best["bcsr_cost"] = cost
            best["bcsr_fill"] = nnz / stored if stored else 0.0
            best_bs = bs
    if best["bcsr_cost"] < best["ell_cost"]:
        best["kernel"] = "bcsr"
        best["block_size"] = best_bs
    return best


def select_dist_kernel(cols_stack: np.ndarray,
                       block_sizes: tuple[int, ...] = BLOCK_SIZES,
                       mxu_advantage: float = MXU_ADVANTAGE) -> dict:
    """One layout decision for a device-stacked operator: ``cols_stack``
    [D, n, K].  Costs are summed across devices (each device's block is
    lowered independently, so block rows never straddle devices) and a
    single (kernel, block_size) is returned in the same dict shape as
    :func:`select_local_kernel`.
    """
    cols_stack = np.asarray(cols_stack)
    D, n, K = cols_stack.shape
    nnz = int((cols_stack >= 0).sum())
    ell_cost = float(D * n * max(K, 1))
    best = {"kernel": "ell", "block_size": 0, "ell_cost": ell_cost,
            "bcsr_cost": float("inf"),
            "ell_fill": nnz / ell_cost if ell_cost else 0.0, "bcsr_fill": 0.0}
    if nnz == 0:
        return best
    best_bs = 0
    for bs in block_sizes:
        stored = sum(_bcsr_stats(cols_stack[d], bs)[0]
                     for d in range(D)) * bs * bs
        cost = stored / mxu_advantage
        if cost < best["bcsr_cost"]:
            best["bcsr_cost"] = cost
            best["bcsr_fill"] = nnz / stored if stored else 0.0
            best_bs = bs
    if best["bcsr_cost"] < best["ell_cost"]:
        best["kernel"] = "bcsr"
        best["block_size"] = best_bs
    return best


__all__ = ["spmv", "spmm", "bcsr_spmv", "bcsr_spmm", "select_local_kernel",
           "select_dist_kernel", "MXU_ADVANTAGE"]
