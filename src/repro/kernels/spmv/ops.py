"""Public jit'd wrapper for the ELL SpMV kernel with oracle fallback."""
from __future__ import annotations

import jax

from .ref import ell_spmv_ref
from .spmv import ell_spmv


def spmv(cols, vals, x, *, use_kernel: bool = True, block_rows: int = 256,
         interpret: bool | None = None):
    """ELL SpMV.  ``interpret=None`` → interpret on CPU, compiled on TPU."""
    if not use_kernel:
        return ell_spmv_ref(cols, vals, x)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return ell_spmv(cols, vals, x, block_rows=block_rows, interpret=interpret)
