"""Pallas TPU kernels: MXU-blocked BCSR SpMV / SpMM.

The ELL kernels in :mod:`repro.kernels.spmv.spmv` are VPU-gather bound —
every nonzero costs one scalar gather + one FMA lane.  Operators with block
structure (vector problems from :mod:`repro.amg.problems`, block-Jacobi
levels) can instead store dense ``bs×bs`` blocks (bs ∈ {8, 16}) in a
block-ELL layout and contract each block against a ``bs×k`` slab of the
source with ``jax.lax.dot_general`` — dense math the MXU systolic array
runs at matmul rate, amortizing the gather down to one block-row fetch per
``bs²`` values.

Layout (produced by :func:`repro.amg.csr.csr_to_bcsr`):

  * ``bcols``: [mb, Kb] int32 — block-column ids per padded block row
    (-1 padding), where mb = ceil(n / bs) and Kb is the max number of
    nonzero blocks in any block row,
  * ``bvals``: [mb, Kb, bs, bs] — the dense blocks (explicit zero fill
    inside a stored block),
  * the source ``x`` is reshaped to [nb, bs(, k)] blocks; gathering block
    ``bcols[r, j]`` yields the ``bs(×k)`` slab the block multiplies.

Grid: block rows.  Per step the kernel sees (BLOCK_BROWS, Kb) block ids +
(BLOCK_BROWS, Kb, bs, bs) values in VMEM with the blocked X resident, and
emits (BLOCK_BROWS, bs, k).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_SIZES = (8, 16)


def _bcsr_kernel(bcols_ref, bvals_ref, xb_ref, y_ref):
    bcols = bcols_ref[...]        # (BR, Kb) int32
    bvals = bvals_ref[...]        # (BR, Kb, bs, bs)
    xb = xb_ref[...]              # (nb, bs, k) resident blocked source
    safe = jnp.maximum(bcols, 0)
    g = jnp.take(xb, safe.reshape(-1), axis=0)          # (BR*Kb, bs, k)
    g = g.reshape(bcols.shape + xb.shape[1:])           # (BR, Kb, bs, k)
    g = jnp.where((bcols >= 0)[..., None, None], g, 0.0)
    # (BR, Kb, bs, bs) × (BR, Kb, bs, k) → (BR, Kb, bs, k): batch over the
    # (block-row, slot) dims, contract the trailing bs — the MXU path
    contrib = jax.lax.dot_general(
        bvals, g, (((3,), (2,)), ((0, 1), (0, 1))),
        preferred_element_type=bvals.dtype)
    y_ref[...] = contrib.sum(axis=1)                    # (BR, bs, k)


def _block_x(x: jnp.ndarray, bs: int) -> jnp.ndarray:
    """[m(, k)] → [nb, bs, k] zero-padded blocked source (k=1 for vectors)."""
    if x.ndim == 1:
        x = x[:, None]
    m, k = x.shape
    pad = (-m) % bs
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    return x.reshape(-1, bs, k)


@functools.partial(jax.jit, static_argnames=("block_brows", "interpret"))
def bcsr_spmm(bcols: jnp.ndarray, bvals: jnp.ndarray, x: jnp.ndarray,
              block_brows: int = 32, interpret: bool = True) -> jnp.ndarray:
    """Y = A·X with A in block-ELL BCSR form and X of shape ``[m, k]``.

    ``bcols``: [mb, Kb] int32 (-1 pad), ``bvals``: [mb, Kb, bs, bs].
    Returns [mb * bs, k] — callers slice back to the true row count.
    """
    mb, Kb = bcols.shape
    bs = bvals.shape[-1]
    k = x.shape[1]
    if mb == 0 or Kb == 0 or x.shape[0] == 0 or k == 0:
        return jnp.zeros((mb * bs, k), dtype=bvals.dtype)
    xb = _block_x(x, bs)
    br = min(block_brows, max(1, mb))
    pad = (-mb) % br
    if pad:
        bcols = jnp.pad(bcols, ((0, pad), (0, 0)), constant_values=-1)
        bvals = jnp.pad(bvals, ((0, pad), (0, 0), (0, 0), (0, 0)))
    grid = (bcols.shape[0] // br,)
    y = pl.pallas_call(
        _bcsr_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, Kb), lambda i: (i, 0)),
            pl.BlockSpec((br, Kb, bs, bs), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec(xb.shape, lambda i: (0, 0, 0)),   # X resident
        ],
        out_specs=pl.BlockSpec((br, bs, k), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bcols.shape[0], bs, k), bvals.dtype),
        interpret=interpret,
    )(bcols, bvals, xb)
    return y[:mb].reshape(mb * bs, k)


def bcsr_spmv(bcols: jnp.ndarray, bvals: jnp.ndarray, x: jnp.ndarray,
              block_brows: int = 32, interpret: bool = True) -> jnp.ndarray:
    """y = A·x (single RHS) through the same MXU block contraction."""
    return bcsr_spmm(bcols, bvals, x[:, None], block_brows=block_brows,
                     interpret=interpret)[:, 0]


def bcsr_apply_ref(bcols, bvals, x):
    """Pure-jnp oracle of the block contraction (matches the kernel's
    summation order; [m] or [m, k] source, returns [mb*bs(, k)])."""
    single = x.ndim == 1
    bs = bvals.shape[-1]
    xb = _block_x(x, bs)
    safe = jnp.maximum(bcols, 0)
    g = jnp.take(xb, safe.reshape(-1), axis=0).reshape(
        bcols.shape + xb.shape[1:])
    g = jnp.where((bcols >= 0)[..., None, None], g, 0.0)
    contrib = jnp.einsum("rsij,rsjk->rsik", bvals, g)
    y = contrib.sum(axis=1).reshape(-1, xb.shape[-1])
    return y[:, 0] if single else y
