"""Public jit'd wrapper: layout conversion + kernel/oracle dispatch."""
from __future__ import annotations

import jax

from .flash_attention import flash_attention
from .ref import attention_ref


def attention(q, k, v, *, causal: bool = True, window: int | None = None,
              use_kernel: bool = True, block_q: int = 128, block_k: int = 128,
              interpret: bool | None = None):
    """q: [B, S, Hq, D]; k, v: [B, S, Hkv, D] (time-major like the models).

    Returns [B, S, Hq, D].
    """
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if not use_kernel:
        out = attention_ref(qt, kt, vt, causal=causal, window=window)
    else:
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        out = flash_attention(qt, kt, vt, causal=causal, window=window,
                              block_q=block_q, block_k=block_k,
                              interpret=interpret)
    return out.transpose(0, 2, 1, 3)
