"""Pure-jnp oracle for blockwise causal GQA attention (+ sliding window)."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True, window: int | None = None) -> jnp.ndarray:
    """q: [B, Hq, Sq, D]; k, v: [B, Hkv, Skv, D]; Hq % Hkv == 0.

    Returns [B, Hq, Sq, D].  ``window``: attend only to keys with
    0 <= q_pos - k_pos < window (sliding-window attention).
    """
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    skv = k.shape[2]
    group = hq // hkv
    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kx.astype(jnp.float32)) * scale
    qpos = jnp.arange(sq)[:, None] + (skv - sq)   # right-aligned (decode)
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vx.astype(jnp.float32)).astype(q.dtype)
