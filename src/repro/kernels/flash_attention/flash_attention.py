"""Pallas TPU kernel: blockwise causal GQA flash attention (+ SWA).

Online-softmax accumulation over key/value blocks with VMEM scratch carried
across the innermost ("arbitrary") grid dimension.  Tiling targets the MXU:
block_q × d and block_k × d tiles with d a multiple of 128; fully-masked
key blocks are skipped with ``pl.when`` so causal/sliding-window FLOPs stay
proportional to the visible context.

GQA is expressed in the BlockSpec index maps: query head h reads kv head
h // (Hq // Hkv) — no repeated K/V materialization in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _compiler_params_cls():
    # renamed TPUCompilerParams -> CompilerParams across Pallas releases
    return getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 scale: float, block_q: int, block_k: int, causal: bool,
                 window: int | None, kv_len: int, q_offset: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # absolute positions (q right-aligned at kv_len when decoding)
    q_base = qi * block_q + q_offset
    k_base = ki * block_k
    # skip key blocks that are entirely invisible to this query block
    run = True
    if causal:
        run = k_base <= q_base + block_q - 1
    if window is not None:
        run = jnp.logical_and(run, k_base + block_k > q_base - window + 1)

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)             # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)             # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_base + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_base + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kpos < kv_len
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]                              # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)                  # (bq, 1)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_ref[...]
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, window: int | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    """q: [B, Hq, Sq, D]; k, v: [B, Hkv, Skv, D] → [B, Hq, Sq, D]."""
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    group = hq // hkv
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    pq = (-sq) % bq
    pk = (-skv) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    grid = (b, hq, (sq + pq) // bq, (skv + pk) // bk)
    kernel = functools.partial(
        _attn_kernel, scale=1.0 / (d ** 0.5), block_q=bq, block_k=bk,
        causal=causal, window=window, kv_len=skv, q_offset=skv - sq)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, i, j, g=group: (b_, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, i, j, g=group: (b_, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq + pq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        compiler_params=_compiler_params_cls()(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :sq]
