"""End-to-end training driver: train a ~100M-parameter LM for a few hundred
steps with the full production loop (AdamW, remat, checkpointing, resume,
preemption handling).

Default is a reduced width that finishes quickly on this single CPU core;
``--full`` trains the real xlstm-125m / ~110M-param config (same code path).

    PYTHONPATH=src python examples/train_lm.py --steps 300
    PYTHONPATH=src python examples/train_lm.py --full --arch xlstm-125m
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax.numpy as jnp

from repro.configs import get_arch
from repro.train import (AdamWConfig, DataConfig, LoopConfig, TrainOptions,
                         train)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="train the full assigned config (slow on CPU)")
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if not args.full:
        cfg = cfg.reduced(n_layers=4, d_model=128, n_heads=4, vocab=1024)
    print(f"arch {cfg.name}: ~{cfg.n_params() / 1e6:.1f}M params")

    acfg = AdamWConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps)
    dcfg = DataConfig(seq_len=args.seq, global_batch=args.batch,
                      vocab=cfg.vocab, seed=1)
    lcfg = LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt,
                      ckpt_every=100, log_every=10)
    params, _, hist = train(cfg, acfg, dcfg, lcfg,
                            opts=TrainOptions(remat=False), dtype=jnp.float32)
    print(f"final loss {hist[-1]:.4f} (start {hist[0]:.4f}) over "
          f"{len(hist)} steps")


if __name__ == "__main__":
    main()
