"""Serve a small model with batched requests: prefill + lockstep decode,
FIFO window batching, throughput stats.

    PYTHONPATH=src python examples/serve_lm.py --requests 12 --batch 4
"""
import argparse
import sys
import time

import numpy as np

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models import init_params
from repro.serve import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced(n_layers=4, d_model=128, n_heads=4,
                                      vocab=1024)
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    eng = Engine(cfg, params, max_batch=args.batch, ctx_len=256)

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for rid in range(args.requests):
        plen = int(rng.integers(args.prompt_len // 2, args.prompt_len + 1))
        eng.submit(Request(rid=rid,
                           prompt=rng.integers(0, cfg.vocab, plen,
                                               dtype=np.int32),
                           max_new_tokens=args.new_tokens))
    out = eng.run()
    dt = time.perf_counter() - t0
    for rid in sorted(out)[:3]:
        print(f"req {rid}: {out[rid][:10]}...")
    s = eng.stats
    print(f"\n{len(out)} requests in {dt:.2f}s across {s['batches']} batches "
          f"| prefill {s['prefill_s']:.2f}s decode {s['decode_s']:.2f}s "
          f"| {s['tokens'] / max(s['decode_s'], 1e-9):.1f} tok/s decode")


if __name__ == "__main__":
    main()
