"""Quickstart: the AMGSolver session API on a 3D Laplacian, plus the paper's
node-aware communication selection per level.

    PYTHONPATH=src python examples/quickstart.py [--n 20] [--solver rs]
"""
import argparse
import sys

import numpy as np

sys.path.insert(0, "src")

from repro.amg import AMGConfig, AMGSolver
from repro.amg.dist import analyze_hierarchy
from repro.amg.problems import laplace_3d
from repro.core import BLUE_WATERS, Topology


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20)
    ap.add_argument("--solver", choices=("rs", "sa"), default="rs")
    ap.add_argument("--nodes", type=int, default=16)
    ap.add_argument("--ppn", type=int, default=16)
    args = ap.parse_args()

    A = laplace_3d(args.n)
    print(f"A: {A.nrows} dofs, {A.nnz} nnz")

    # one configurable, cacheable session object: setup once, solve many
    cfg = AMGConfig(solver=args.solver)
    bound = AMGSolver(cfg).setup(A)
    print(bound.hierarchy.summary())

    b = A.matvec(np.ones(A.nrows))
    res = bound.solve(b)
    print(f"solve: {res.iterations} iters, conv factor "
          f"{res.avg_conv_factor:.3f}, ||x-1||∞ = "
          f"{np.abs(res.x - 1).max():.2e}")

    # the session cache: same matrix + same config → the same solver object,
    # no re-setup
    again = AMGSolver(cfg).setup(A)
    print(f"second setup() is a cache hit: {again is bound}")

    # multi-RHS: [n, k] solves k systems through one session
    rng = np.random.default_rng(0)
    B = np.stack([b, rng.standard_normal(A.nrows)], axis=1)
    mres = bound.solve(B)
    print(f"multi-RHS [{A.nrows}, 2] solve: converged={mres.converged}, "
          f"iters per column = {[c.iterations for c in mres.columns]}")

    topo = Topology(n_nodes=args.nodes, ppn=args.ppn)
    ops = analyze_hierarchy(bound.hierarchy, topo, BLUE_WATERS)
    print(f"\nnode-aware strategy selection ({topo.n_procs} ranks, "
          f"{args.nodes} nodes — paper §4):")
    print(f"{'lvl':>3} {'op':>12} {'chosen':>9} {'std(µs)':>9} "
          f"{'nap2(µs)':>9} {'nap3(µs)':>9}")
    for oc in ops:
        t = oc.selection.times
        print(f"{oc.level:>3} {oc.op:>12} {oc.strategy:>9} "
              f"{t['standard'] * 1e6:>9.1f} {t['nap2'] * 1e6:>9.1f} "
              f"{t['nap3'] * 1e6:>9.1f}")


if __name__ == "__main__":
    main()
