"""The paper end-to-end in one script.

Part 1 (host, rank simulator): build AMG hierarchies for the three MFEM-like
systems, execute standard/NAP-2/NAP-3 schedules in the rank simulator, and
print measured message/byte reductions + modeled speedups (Figures 14-17 in
miniature).

Part 2 (device, 8-way host mesh): an ``AMGSolver`` session with
``backend="dist"`` lowers a hierarchy onto a 2x4 (pod x lane) mesh with
**per-level model-selected strategies** and runs the fused PCG solve — the
whole V-cycle device-resident in one jitted shard_map program — checking
its residual history against the host backend, then reuses the same cached
session for a batched multi-RHS solve.

Part 3 (setup phase): the paper's *matrix* communication executed.  The
partitioned setup loop runs the Galerkin SpGEMMs A·P and Pᵀ·(AP) with
model-selected NAP row exchanges (modeled µs vs measured messages/bytes per
level), then the ``setup_backend="dist"`` config knob runs the whole
session — partitioned setup straight into the device-resident solve, no
host assembly in between — and checks PCG parity against part 2's path.

Part 4 (cycle shapes × smoothers): the solve-phase breadth table.  Every
``SolveOptions(cycle=V|W|F, smoother=...)`` pair runs as its own fused
device program on the same lowered hierarchy; the table prints iterations
to tolerance, convergence factor, and the modeled per-cycle coarse-level
message counts — W/F-cycles multiply exactly the small coarse-level
messages the NAP strategies aggregate, which is what makes the cycle shape
a communication-strategy scenario and not just a numerics knob.

Part 5 (serving): the amortization argument end-to-end.  An ``AMGService``
registers matrices from **encoded wire payloads** (id = verified content
fingerprint), admits a multi-tenant burst of ticketed requests — mixed
matrices, priorities, a multi-RHS payload, a per-request tolerance — and
coalesces same-(matrix, knobs) right-hand sides into ONE multi-RHS device
trace per tenant.  The session-store stats table shows what serving reuses
(hits, per-entry setup cost) and what eviction would cost.

Part 6 (kernels): how each level's SpMV actually runs.  The per-level
heuristic (:func:`repro.kernels.spmv.select_dist_kernel`) lowers a level
to MXU-blocked BCSR where the sparsity blocks densely enough that
``bs x bs`` ``dot_general`` contractions beat the gathered ELL form, and
keeps plain ELL elsewhere; the table prints the pick with the fill factors
and modeled cost ratio behind it, plus the measured %-of-ERT-peak each
kernel achieved in the committed BENCH_kernels.json baseline.

Part 7 (wire serving): the serving story over actual sockets.  An
``AMGWireServer`` hosts two tenants ("alpha" roomy, "beta" starved at
``max_inflight=2``) behind length-prefixed JSON frames; the open-loop
Poisson load generator (``benchmarks/serve_load.py``) overloads it
across 32 concurrent connections and the per-(tenant, priority-class)
table shows what admission control did: interactive traffic kept its
p50/p99, batch traffic on the starved tenant was shed with explicit
``rejected`` frames — zero dropped connections, zero unstructured
errors.

Part 8 (overlap): the on/off-process operator split executed.  Every
level's local block is lowered as ``A_on`` (halo-free columns) plus
``A_off`` (halo columns only), the halo exchange is issued *before* the
on-product so XLA's scheduler can hide it, and levels whose halo is empty
skip the exchange entirely.  The machine model is **measured on this host
mesh** (ring ping-pongs fitted to the postal model, a local SpMV flop
rate), the per-level table prints the split with the modeled overlap
efficiency max(T_comm, T_on) + T_off buys, and the same fused V-cycle is
then timed with ``overlap`` on vs off — the serial path is the parity
oracle, bit-identical histories, only the schedule differs.

Part 9 (streaming): evolving matrices without paying setup again.  One
``AMGService`` session takes a sequence of value-only drifts through
``update()`` — each refresh re-lowers the new values onto the frozen NAP
schedules, replays the Galerkin products through the cached halo plans and
reuses the compiled fused programs verbatim — until an injected
convergence regression trips the ``RefreshPolicy`` and the service
escalates to exactly one full node-aware re-setup.  The drift-sweep table
prints per step what the session did (action, trigger, wall clock,
iterations); the refresh must be measurably cheaper than the re-setup.

Part 10 (static analysis): the communication the programs *actually*
compile to.  ``repro.analysis`` walks the jaxpr of every fused program,
counts the collective primitives, and the table prints them next to the
counts the cycle structure + per-level selected strategies predict — the
same cross-check CI runs (``python -m repro.analysis``), which is what
catches a NAP lowering silently regressing to a flat collective.  The
lint pass (raw collectives outside ``core/nap_collectives.py``, blocking
calls in coroutines, host calls inside traced code, frozen-dataclass
mutation) runs over ``src/`` and must come back empty.

    PYTHONPATH=src python examples/amg_nap_demo.py
"""
import os
import sys

# must be set before jax initializes: give the host platform 8 devices
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np

sys.path.insert(0, "src")

from repro.amg import pcg, setup
from repro.amg.dist import row_partition, vector_comm_graph
from repro.amg.problems import dpg_laplace_3d, grad_div_3d, laplace_3d
from repro.core import BLUE_WATERS, Topology, build
from repro.core.perf_model import model_time
from repro.core.simulator import verify


def simulator_study():
    topo = Topology(n_nodes=16, ppn=16)
    systems = {"laplace3d": laplace_3d(16), "graddiv": grad_div_3d(9),
               "dpg": dpg_laplace_3d(8)}
    for name, A in systems.items():
        h = setup(A, solver="rs")
        print(f"\n=== {name}: {A.nrows} dofs, {h.n_levels} levels ===")
        print(f"{'lvl':>3} {'strategy':>20} {'inter-msgs':>10} "
              f"{'inter-bytes':>11} {'model(µs)':>10}")
        for l, lv in enumerate(h.levels):
            part = row_partition(lv.A, topo)
            g = vector_comm_graph(lv.A, part)
            x = np.random.default_rng(l).standard_normal(lv.A.nrows)
            for strat in ("standard", "nap2", "nap3"):
                sch = build(strat, g)
                res = verify(sch, x)          # executes + checks correctness
                t = model_time(sch, BLUE_WATERS)
                print(f"{l:>3} {strat:>20} {res.inter_msgs:>10} "
                      f"{res.inter_bytes:>11.0f} {t * 1e6:>10.1f}")


def dist_solve_demo(n_pods: int = 2, lanes: int = 4):
    from repro.amg import AMGConfig, AMGSolver

    A = laplace_3d(12)
    b = A.matvec(np.ones(A.nrows))
    print(f"\n=== device-resident dist solve: {A.nrows} dofs on a "
          f"{n_pods}x{lanes} host mesh ===")
    # one session object from setup to serving: the DistHierarchy (comm
    # graphs, model-selected strategies, halo plans) and its compiled fused
    # programs are built on first use and reused by every later call
    cfg = AMGConfig(backend="dist", n_pods=n_pods, lanes=lanes,
                    machine="blue_waters")
    bound = AMGSolver(cfg).setup(A)
    h, dh = bound.hierarchy, bound.dist_hierarchy
    print(dh.summary())
    non_std = {r["strategy"] for r in dh.selection_table()} - {"standard"}
    print(f"non-standard strategies selected: {sorted(non_std) or 'NONE'}")

    res_h = pcg(h, b, tol=1e-6, maxiter=40)
    res_d = bound.pcg(b, tol=1e-6, maxiter=40)
    n = min(len(res_h.residuals), len(res_d.residuals))
    r0 = res_h.residuals[0]
    print(f"{'it':>3} {'host ||r||':>12} {'dist ||r||':>12}")
    for i in range(n):
        print(f"{i:>3} {res_h.residuals[i]:>12.4e} {res_d.residuals[i]:>12.4e}")
    diff = max(abs(a - c) / r0 for a, c in
               zip(res_h.residuals[:n], res_d.residuals[:n]))
    print(f"dist PCG converged={res_d.converged} in {res_d.iterations} its; "
          f"max |host-dist|/r0 = {diff:.2e}")
    assert non_std, "expected at least one model-selected non-standard level"
    assert diff < 1e-4, f"residual history mismatch: {diff}"
    print("dist == host to 1e-4 relative: OK")

    # same cached session, batched multi-RHS: k systems, ONE device trace
    assert AMGSolver(cfg).setup(A) is bound          # session-cache hit
    rng = np.random.default_rng(0)
    B = np.stack([b, rng.standard_normal(A.nrows),
                  rng.standard_normal(A.nrows)], axis=1)
    mres = bound.pcg(B, tol=1e-6, maxiter=40)
    rel = [np.linalg.norm(B[:, j] - A.matvec(mres.x[:, j]))
           / np.linalg.norm(B[:, j]) for j in range(B.shape[1])]
    print(f"multi-RHS [{A.nrows}, {B.shape[1]}] dist PCG: "
          f"converged={mres.converged}, max rel residual {max(rel):.2e}")
    assert mres.converged and max(rel) < 1e-5


def dist_setup_demo(n_pods: int = 2, lanes: int = 4):
    from repro.amg import AMGConfig, AMGSolver, pcg, setup
    from repro.amg.dist_setup import dist_setup_partitioned

    A = laplace_3d(10)
    b = A.matvec(np.ones(A.nrows))
    print(f"\n=== distributed NAP setup phase: {A.nrows} dofs on a "
          f"{n_pods}x{lanes} mesh ===")
    # 3a: the partitioned setup loop — every level's Galerkin SpGEMMs move
    # off-process CSR rows under the model-selected §3 schedule
    plevels, records = dist_setup_partitioned(A, n_pods, lanes,
                                              params=BLUE_WATERS)
    print(f"{'lvl':>3} {'op':>12} {'strategy':>9} {'model(µs)':>10} "
          f"{'inter-msgs':>10} {'inter-bytes':>11} {'halo-rows':>9}")
    for r in records:
        print(f"{r.level:>3} {r.op:>12} {r.strategy:>9} "
              f"{r.modeled[r.strategy] * 1e6:>10.1f} {r.inter_msgs:>10} "
              f"{r.inter_bytes:>11.0f} {r.n_halo_rows:>9}")
    print(f"partitioned levels: {len(plevels)} (born partitioned — no "
          "global CSR assembled past the fine grid)")

    # 3b: the setup_backend="dist" knob — one session from partitioned
    # setup to device-resident multi-RHS serving
    cfg = AMGConfig(setup_backend="dist", backend="dist", n_pods=n_pods,
                    lanes=lanes, machine="blue_waters")
    bound = AMGSolver(cfg).setup(A)
    assert bound.hierarchy is None, "levels must be born partitioned"
    res_d = bound.pcg(b, tol=1e-6, maxiter=40)
    h = setup(A, solver="rs")       # reference: host setup → dist solve
    res_h = pcg(h, b, tol=1e-6, maxiter=40, backend="dist",
                dist=dict(n_pods=n_pods, lanes=lanes,
                          params=BLUE_WATERS))
    n = min(len(res_h.residuals), len(res_d.residuals))
    r0 = res_h.residuals[0]
    diff = max(abs(a - c) / r0 for a, c in
               zip(res_h.residuals[:n], res_d.residuals[:n]))
    print(f"dist-setup PCG converged={res_d.converged} in "
          f"{res_d.iterations} its; max |host-setup − dist-setup|/r0 = "
          f"{diff:.2e}")
    assert res_d.converged and diff < 1e-4
    print("dist setup == host setup to 1e-4 relative: OK")


def cycle_smoother_demo(n_pods: int = 2, lanes: int = 4):
    from repro.amg import AMGConfig, AMGSolver, SolveOptions
    from repro.amg.dist_solve import cycle_comm_stats
    from repro.amg.solve import CYCLES, SMOOTHERS

    A = laplace_3d(8)
    b = A.matvec(np.ones(A.nrows))
    print(f"\n=== cycle shapes × smoothers: {A.nrows} dofs on a "
          f"{n_pods}x{lanes} mesh ===")
    base = AMGConfig(backend="dist", n_pods=n_pods, lanes=lanes,
                     machine="blue_waters", max_coarse=30, tol=1e-6)
    print(f"{'cycle':>5} {'smoother':>13} {'iters':>5} {'conv':>6} "
          f"{'coarse inter-msgs/cycle':>23} {'total inter-msgs':>16}")
    for cycle in CYCLES:
        for sm in SMOOTHERS:
            opts = SolveOptions(cycle=cycle, smoother=sm,
                                smoother_parts=n_pods * lanes)
            # solve-knob-only change: every pair below shares ONE cached
            # hierarchy + lowering, only the compiled program differs
            bound = AMGSolver(base.replace(opts=opts)).setup(A)
            res = bound.solve(b, maxiter=40)
            st = cycle_comm_stats(bound.dist_hierarchy, opts)
            print(f"{cycle:>5} {sm:>13} {res.iterations:>5} "
                  f"{res.avg_conv_factor:>6.3f} "
                  f"{st['coarse_inter_msgs']:>23} {st['inter_msgs']:>16}")
            assert res.converged, (cycle, sm)
    print("every (cycle, smoother) pair converged through its own fused "
          "device program: OK")


def serving_demo():
    import json

    from repro.amg import AMGConfig, AMGService
    from repro.amg.api import csr_to_wire, solve_request_to_wire

    systems = {"laplace8": laplace_3d(8), "laplace6": laplace_3d(6)}
    print("\n=== serving: wire-registered matrices, coalesced "
          "multi-tenant drain ===")
    svc = AMGService(AMGConfig(tol=1e-8), max_rhs=8)
    ids = {}
    for name, A in systems.items():
        # registration purely over the wire: one real JSON byte hop, the
        # matrix id is the payload's verified content fingerprint
        payload = json.loads(json.dumps(csr_to_wire(A)))
        ids[name] = svc.register_wire(payload)
        print(f"registered {name} by fingerprint {ids[name][:12]}… "
              f"({A.nrows} dofs)")

    rng = np.random.default_rng(0)
    tickets = {}
    for i in range(3):                       # tenant A: interactive stream
        tickets[f"A{i}"] = svc.submit(ids["laplace8"],
                                      rng.standard_normal(512),
                                      method="pcg", priority="interactive")
    tickets["B0"] = svc.submit(                # tenant B: batch, multi-RHS
        ids["laplace6"], rng.standard_normal((216, 2)), method="pcg",
        priority="batch")
    tickets["B1"] = svc.submit_wire(json.loads(json.dumps(   # wire request
        solve_request_to_wire(ids["laplace6"], rng.standard_normal(216),
                              method="pcg", priority="batch"))))
    tickets["C0"] = svc.submit(ids["laplace8"],   # own tol -> own trace
                               rng.standard_normal(512), method="pcg",
                               tol=1e-4)
    svc.drain()
    for tag, t in sorted(tickets.items()):
        d = t.diagnostics
        print(f"  {tag}: batch={d['batch']} cols_in_trace={d['batch_cols']} "
              f"iters={d['iterations']} converged={d['converged']}")
    s = svc.stats
    print(f"{s['requests']} requests -> {s['batches']} device traces "
          f"({s['batched_rhs']} RHS coalesced, {s['wire_requests']} via "
          f"wire), {s['setups']} setups")
    # the 3 interactive + the 3 batch RHS each shared one trace; the
    # loose-tol request was knob-incompatible and got its own
    assert s["batches"] == 3 and s["batched_rhs"] == 6, s
    assert all(t.diagnostics["converged"] for t in tickets.values())

    print("\nsession-store stats (what serving amortizes):")
    st = svc.store.stats()
    print(f"  policy={st['policy']} entries={st['entries']} "
          f"hits={st['hits']} misses={st['misses']} "
          f"evictions={st['evictions']}")
    print(f"  {'session':>14} {'bytes':>9} {'setup(ms)':>9} {'hits':>4}")
    for row in svc.store.entry_table():
        fp = row["key"][0]              # key = (fingerprint, config)
        print(f"  {fp[:12] + '…':>14} {row['nbytes']:>9} "
              f"{row['setup_cost'] * 1e3:>9.1f} {row['hits']:>4}")
    print("serving demo OK: fingerprint-addressed, coalesced, accounted")


def kernel_selection_demo(n_pods: int = 2, lanes: int = 4):
    import json
    import re

    from repro.amg import AMGConfig, AMGSolver

    A = laplace_3d(10)
    print(f"\n=== per-level SpMV kernel selection: {A.nrows} dofs on a "
          f"{n_pods}x{lanes} mesh ===")
    bound = AMGSolver(AMGConfig(backend="dist", n_pods=n_pods, lanes=lanes,
                                machine="blue_waters")).setup(A)
    print(f"{'lvl':>3} {'kernel':>6} {'bs':>3} {'rows/dev':>8} "
          f"{'ell fill':>8} {'bcsr fill':>9} {'bcsr/ell cost':>13}")
    table = bound.dist_hierarchy.kernel_table()
    for r in table:
        ratio = (r["bcsr_cost"] / r["ell_cost"] if r["ell_cost"]
                 else float("inf"))
        print(f"{r['level']:>3} {r['kernel']:>6} {r['block_size']:>3} "
              f"{r['rows_local']:>8} {r['ell_fill']:>8.2f} "
              f"{r['bcsr_fill']:>9.2f} {ratio:>13.2f}")
    kinds = {r["kernel"] for r in table}
    print(f"layouts in use: {sorted(kinds)} (coarsest level solves dense — "
          "never lowered)")
    assert table[-1]["kernel"] == "ell", "coarsest level must stay ELL"

    # measured achievement from the committed ERT-calibrated baseline: the
    # %-of-peak column is against the bandwidth THIS machine measured in
    # the ert_sweep, not a documented constant
    bench = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_kernels.json")
    if not os.path.exists(bench):
        print("(no BENCH_kernels.json — run: python -m benchmarks.kernels "
              "--smoke --out BENCH_kernels.json)")
        return
    print(f"\n{'kernel':>18} {'µs/call':>8} {'% of measured peak':>18}")
    for r in json.load(open(bench))["rows"]:
        if not r["name"].startswith("kern_"):
            continue
        d = dict(re.findall(r"([A-Za-z_][A-Za-z0-9_]*)=([^;]+)",
                            r["derived"]))
        print(f"{r['name']:>18} {r['us_per_call']:>8.1f} "
              f"{d.get('pct_peak', 'n/a'):>18}")
    print("kernel-selection demo OK: heuristic picks per level, "
          "achievement measured against the ERT roofline")


def wire_serving_demo():
    sys.path.insert(0, ".")                   # benchmarks/ off the repo root
    from benchmarks.serve_load import (aggregate, build_plan, print_table,
                                       run_load)
    from repro.amg.api import AMGConfig
    from repro.serve import ServerThread, TenantSpec
    from repro.serve.workload import build_problems

    print("\n=== wire serving: AMGWire socket server under open-loop "
          "overload ===")
    cfg = AMGConfig(tol=1e-8)
    tenants = {"alpha": TenantSpec(config=cfg, max_inflight=32),
               "beta": TenantSpec(config=cfg, max_inflight=2)}
    problems = build_problems(6)
    plan = build_plan(problems, sorted(tenants), requests=240, rate=300.0,
                      seed=0, method="pcg")
    with ServerThread(tenants) as srv:
        print(f"AMGWire on {srv.host}:{srv.port} — tenants alpha"
              f"[inflight<=32] beta[inflight<=2]; driving "
              f"{len(plan)} Poisson arrivals over 32 connections")
        results, makespan, server_stats = run_load(
            srv.host, srv.port, problems, plan, connections=32)
    classes, unstructured = aggregate(results, problems)
    print_table(classes, makespan)
    rejected = sum(cs["rejected"] for cs in classes.values())
    completed = sum(cs["completed"] for cs in classes.values())
    print(f"{completed} completed ({completed / makespan:.0f} solves/s), "
          f"{rejected} shed as explicit rejected frames, "
          f"{server_stats['dropped_connections']} dropped connections, "
          f"{len(unstructured)} unstructured responses")
    assert server_stats["dropped_connections"] == 0
    assert not unstructured
    assert completed + rejected + sum(
        cs["errors"] for cs in classes.values()) == len(plan)
    print("wire serving demo OK: overload shed by priority class, every "
          "failure a structured frame")


def overlap_demo(n_pods: int = 2, lanes: int = 4):
    import time

    sys.path.insert(0, ".")                   # benchmarks/ off the repo root
    from benchmarks.pingpong_model import measure_machine_params
    from repro.amg import SolveOptions, solve
    from repro.core.perf_model import overlap_time

    from repro.amg.dist_solve import DistHierarchy

    A = laplace_3d(10)
    b = A.matvec(np.ones(A.nrows))
    print(f"\n=== overlapped halo exchange: on/off split, {A.nrows} dofs "
          f"on a {n_pods}x{lanes} mesh ===")
    # postal-model fit + SpMV flop rate measured on THIS mesh, so the
    # overlap-aware selection runs on data rather than documented constants
    params = measure_machine_params("demo_mesh", n_pods=n_pods, lanes=lanes)
    p = params.inter[0]
    print(f"measured: inter alpha={p.alpha * 1e6:.2f}µs Rb={p.Rb:.2e}B/s, "
          f"Rf={params.Rf:.2e} flop/s")
    h = setup(A, solver="rs", max_coarse=30)
    dh = DistHierarchy.build(h, n_pods, lanes, params=params)
    print(f"{'lvl':>3} {'on_nnz':>8} {'off_nnz':>8} {'halo':>5} "
          f"{'strategy':>9} {'overlap(µs)':>11} {'eff':>6}")
    for l, dl in enumerate(dh.levels):
        oo = dl.onoff
        t_ov = overlap_time(oo["t_comm"], oo["t_on"], oo["t_off"])
        halo = "  —  " if oo["halo_empty"] else "yes"
        print(f"{l:>3} {oo['on_nnz']:>8} {oo['off_nnz']:>8} {halo:>5} "
              f"{dl.strategies.get('spmv_A', '?'):>9} {t_ov * 1e6:>11.2f} "
              f"{oo['eff_modeled']:>6.1%}")
        assert oo["on_nnz"] + oo["off_nnz"] == oo["local_nnz"]

    def timed(reps=5):
        opts = SolveOptions(cycle="V")
        solve(h, b, maxiter=1, tol=0.0, opts=opts, backend="dist", dist=dh)
        t0 = time.perf_counter()
        solve(h, b, maxiter=reps, tol=0.0, opts=opts, backend="dist",
              dist=dh)
        return (time.perf_counter() - t0) / reps * 1e6

    t_ov = timed()
    dh.overlap = False                        # the serial parity oracle
    t_ser = timed()
    dh.overlap = True
    print(f"measured V-cycle: overlap {t_ov:.0f}µs vs serial {t_ser:.0f}µs "
          f"({t_ser / max(t_ov, 1e-9):.2f}x)")
    print("overlap demo OK: split partitions every level, exchange hidden "
          "behind the on-product")


def streaming_demo(n_pods: int = 2, lanes: int = 4):
    import time

    from repro.amg import AMGConfig, AMGService
    from repro.amg.api import clear_sessions
    from repro.amg.csr import CSR

    print("\n=== streaming: A + ΔA updates with hierarchy reuse and "
          "adaptive re-setup ===")
    A = laplace_3d(8)
    b = A.matvec(np.ones(A.nrows))
    cfg = AMGConfig(backend="dist", n_pods=n_pods, lanes=lanes,
                    machine="blue_waters", tol=1e-6, maxiter=60)
    clear_sessions()
    svc = AMGService(cfg)
    mid = svc.register("evolving", A)
    rng = np.random.default_rng(11)

    def drift(M, scale=0.02):
        # value-only drift on the frozen pattern, resymmetrized for pcg
        data = M.data * (1.0 + scale * rng.random(M.nnz))
        Mt = CSR(M.shape, M.indptr.copy(), M.indices.copy(), data).T
        return CSR(M.shape, M.indptr.copy(), M.indices.copy(),
                   0.5 * (data + Mt.data))

    def solve_once():
        t = svc.submit(mid, b, method="pcg")
        svc.drain()
        t.result()
        return t.diagnostics["iterations"]

    print(f"registered {mid[:12]}… ({A.nrows} dofs) on a "
          f"{n_pods}x{lanes} mesh")
    it0 = solve_once()
    print(f"\n  {'step':>4} {'action':>8} {'trigger':>10} "
          f"{'update(ms)':>10} {'iters':>5}")
    print(f"  {0:>4} {'—':>8} {'—':>10} {'—':>10} {it0:>5}   "
          f"(post-setup baseline)")
    steps, refresh_ms, resetup_ms = 5, [], []
    for step in range(1, steps + 1):
        A = drift(A)
        if step == steps:
            # inject a convergence regression: the RefreshPolicy must
            # escalate this update to a full node-aware re-setup
            bound = svc.bound_for(mid)
            bound.last_iterations = 10 * (bound.baseline_iterations or 1)
        t0 = time.perf_counter()
        out = svc.update(mid, A)
        svc.bound_for(mid).dist_hierarchy     # charge deferred lowering
        ms = (time.perf_counter() - t0) * 1e3
        (refresh_ms if out["action"] == "refresh" else resetup_ms).append(ms)
        its = solve_once()
        note = "   (regression injected)" if step == steps else ""
        print(f"  {step:>4} {out['action']:>8} {out['reason']:>10} "
              f"{ms:>10.1f} {its:>5}{note}")
    st = svc.store.stats()
    mean_refresh = sum(refresh_ms) / len(refresh_ms)
    print(f"\n  session counters: refreshes={st['refreshes']} "
          f"resetups={st['resetups']} triggers={st['triggers']}")
    print(f"  value-only refresh {mean_refresh:.1f} ms vs full re-setup "
          f"{resetup_ms[0]:.1f} ms "
          f"({resetup_ms[0] / max(mean_refresh, 1e-9):.1f}x)")
    assert st["resetups"] == 1 and st["refreshes"] == steps - 1, st
    assert st["triggers"].get("regression") == 1, st
    clear_sessions()
    print("streaming demo OK: frozen schedules refreshed in place, one "
          "adaptive re-setup on regression")


def static_analysis_demo(n_pods: int = 2, lanes: int = 4):
    import pathlib

    from repro.amg.dist_solve import DistHierarchy
    from repro.analysis import (PROGRAM_NAMES, audit_cycle_stats,
                                audit_program, lint_paths)

    print("\n=== static analysis: traced collectives vs the count model, "
          "plus lint ===")
    A = laplace_3d(8)
    h = setup(A, solver="rs", max_coarse=30)
    dh = DistHierarchy.build(h, n_pods, lanes, params=BLUE_WATERS)
    print(f"auditing {len(PROGRAM_NAMES)} fused programs on the "
          f"{n_pods}x{lanes} mesh ({len(dh.levels)} levels, per-level "
          f"model-selected strategies)")

    def fmt(counts):
        return " ".join(f"{p}={c}" for p, c in sorted(counts.items()))

    print(f"\n  {'program':<14} {'collectives':>11}  counts (traced | model)")
    n_bad = 0
    for name in PROGRAM_NAMES:
        a = audit_program(dh, name)
        n_bad += len(a.violations)
        mark = "" if a.ok else "  <-- VIOLATION"
        print(f"  {a.program:<14} {a.n_collectives:>11}  "
              f"{fmt(a.counts)} | {fmt(a.expected)}{mark}")
    stat_v = audit_cycle_stats(dh)
    src = pathlib.Path(__file__).parents[1] / "src"
    lint_v = lint_paths(src)
    print(f"\n  model-vs-static agreement: {len(stat_v)} violations; "
          f"lint over src/: {len(lint_v)} violations")
    assert n_bad == 0 and not stat_v and not lint_v
    print("static analysis OK: every traced program carries exactly the "
          "strategy-predicted collectives; the tree is lint-clean")


def main():
    simulator_study()
    dist_solve_demo()
    dist_setup_demo()
    cycle_smoother_demo()
    serving_demo()
    kernel_selection_demo()
    wire_serving_demo()
    overlap_demo()
    streaming_demo()
    static_analysis_demo()


if __name__ == "__main__":
    main()
