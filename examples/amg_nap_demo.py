"""The paper end-to-end in one script: build AMG hierarchies for the three
MFEM-like systems, execute standard/NAP-2/NAP-3 schedules in the rank
simulator, and print measured message/byte reductions + modeled speedups
(Figures 14-17 in miniature).

    PYTHONPATH=src python examples/amg_nap_demo.py
"""
import sys

import numpy as np

sys.path.insert(0, "src")

from repro.amg import setup
from repro.amg.dist import row_partition, vector_comm_graph
from repro.amg.problems import dpg_laplace_3d, grad_div_3d, laplace_3d
from repro.core import BLUE_WATERS, Topology, build
from repro.core.perf_model import model_time
from repro.core.schedules import ScheduleStats
from repro.core.simulator import verify


def main():
    topo = Topology(n_nodes=16, ppn=16)
    systems = {"laplace3d": laplace_3d(16), "graddiv": grad_div_3d(9),
               "dpg": dpg_laplace_3d(8)}
    for name, A in systems.items():
        h = setup(A, solver="rs")
        print(f"\n=== {name}: {A.nrows} dofs, {h.n_levels} levels ===")
        print(f"{'lvl':>3} {'strategy':>20} {'inter-msgs':>10} "
              f"{'inter-bytes':>11} {'model(µs)':>10}")
        for l, lv in enumerate(h.levels):
            part = row_partition(lv.A, topo)
            g = vector_comm_graph(lv.A, part)
            x = np.random.default_rng(l).standard_normal(lv.A.nrows)
            for strat in ("standard", "nap2", "nap3"):
                sch = build(strat, g)
                res = verify(sch, x)          # executes + checks correctness
                t = model_time(sch, BLUE_WATERS)
                print(f"{l:>3} {strat:>20} {res.inter_msgs:>10} "
                      f"{res.inter_bytes:>11.0f} {t * 1e6:>10.1f}")


if __name__ == "__main__":
    main()
