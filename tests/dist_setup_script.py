"""Multi-device distributed setup-phase validation — run as a SUBPROCESS by
test_dist_setup.py (device count must be set before jax init).

Asserts that ``AMGConfig(setup_backend="dist", backend="dist")`` produces a
bound solver whose hierarchy was never assembled on the host (levels born
partitioned), that the lowered levels match a host-setup lowering to 1e-12
(sparsity via ELL column maps, values, coarse pseudo-inverse), that the
setup-phase SpGEMM strategy selections land in the selection table, and
that the resulting dist PCG residual history matches the host-setup dist
path at the 1e-7 parity bar.  Prints "OK <check>" per passing check.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)   # fp64 parity checks

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.amg import AMGConfig, AMGSolver, pcg, setup  # noqa: E402
from repro.amg.dist_solve import DistHierarchy  # noqa: E402
from repro.amg.problems import laplace_3d  # noqa: E402
from repro.core import BLUE_WATERS  # noqa: E402

N_PODS, LANES = 2, 4


def main():
    A = laplace_3d(8)
    b = A.matvec(np.ones(A.nrows))
    h = setup(A, solver="rs")

    cfg = AMGConfig(setup_backend="dist", backend="dist", n_pods=N_PODS,
                    lanes=LANES, machine="blue_waters", dtype="float64")
    bound = AMGSolver(cfg).setup(A)
    assert bound.hierarchy is None, "levels must be born partitioned"
    assert bound.n == A.nrows
    dh = bound.dist_hierarchy
    assert dh.h is None
    print("OK born_partitioned")

    # every coarsening level recorded both Galerkin SpGEMM selections
    sel = {(r["level"], r["op"]): r for r in dh.selection_table()}
    for l in range(len(dh.levels) - 1):
        for op in ("spgemm_AP", "spgemm_PtAP"):
            row = sel[(l, op)]
            assert row["strategy"] in ("standard", "nap2", "nap3")
            assert row["modeled"][row["strategy"]] == \
                min(row["modeled"].values())
    assert dh.setup_records, "measured exchange records missing"
    for rec in dh.setup_records:
        assert rec.seconds >= 0 and rec.inter_msgs + rec.intra_msgs >= 0
    print("OK setup_selection")

    # lowered-level parity vs the host-setup path: identical ELL sparsity
    # (column maps), values to 1e-12, identical strategies, same coarse pinv
    dh_host = DistHierarchy.build(h, N_PODS, LANES, params=BLUE_WATERS,
                                  dtype=jnp.float64)
    assert len(dh.levels) == len(dh_host.levels)
    for l, (a, c) in enumerate(zip(dh.levels, dh_host.levels)):
        pairs = [(a.A, c.A)] + ([(a.P, c.P), (a.R, c.R)]
                                if a.P is not None else [])
        for x, y in pairs:
            assert x.strategy == y.strategy, l
            assert np.array_equal(x.ell_cols, y.ell_cols), l
            assert np.abs(x.ell_vals - y.ell_vals).max() <= 1e-12, l
        assert np.abs(a.dinv - c.dinv).max() <= 1e-12, l
        if a.coarse_inv is not None:
            assert np.abs(a.coarse_inv - c.coarse_inv).max() <= 1e-12, l
    print("OK level_parity")

    # solve-phase parity: dist PCG from the partitioned setup matches the
    # host-setup dist path at the existing 1e-7 bar
    res_d = bound.pcg(b, tol=1e-10, maxiter=30)
    res_ref = pcg(h, b, tol=1e-10, maxiter=30, backend="dist", dist=dh_host)
    assert res_d.converged
    n = min(len(res_d.residuals), len(res_ref.residuals))
    r0 = res_ref.residuals[0]
    diff = max(abs(x - y) / r0 for x, y in
               zip(res_d.residuals[:n], res_ref.residuals[:n]))
    assert diff < 1e-7, diff
    print("OK pcg_parity")

    # session cache: same (matrix, config) → same bound solver; a config
    # differing only in solve knobs shares the cached DistHierarchy; one
    # differing only in lowering knobs (dtype) re-lowers but must NOT re-run
    # the partitioned setup loop (two-tier cache)
    assert AMGSolver(cfg).setup(A) is bound
    bound2 = AMGSolver(cfg.replace(maxiter=7)).setup(A)
    assert bound2 is not bound and bound2.dist_hierarchy is dh
    import repro.amg.dist_setup as ds_mod
    calls = []
    orig = ds_mod.dist_setup_partitioned
    ds_mod.dist_setup_partitioned = \
        lambda *a, **k: calls.append(1) or orig(*a, **k)
    bound32 = AMGSolver(cfg.replace(dtype="float32")).setup(A)
    ds_mod.dist_setup_partitioned = orig
    assert bound32.dist_hierarchy is not dh
    assert not calls, "dtype-only change must reuse the partitioned setup"
    print("OK session_cache")

    print("ALL_OK")


if __name__ == "__main__":
    main()
