"""Dry-run machinery test on a small (2,2,2) mesh in a subprocess."""
import os
import pathlib
import subprocess
import sys

import pytest

SCRIPT = pathlib.Path(__file__).parent / "dryrun_small_script.py"


@pytest.mark.slow
def test_dryrun_small_mesh_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(pathlib.Path(__file__).parents[1] / "src") + \
        os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, str(SCRIPT)], capture_output=True,
                         text=True, env=env, timeout=1200)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "ALL_OK" in out.stdout
    assert out.stdout.count("OK ") >= 4
