"""Training substrate tests: optimizer, microbatching, data determinism,
checkpoint/restart fault tolerance, int8 quantization."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import init_params
from repro.train import (AdamWConfig, DataConfig, LoopConfig, TokenPipeline,
                         TrainOptions, build_train_step, init_opt_state, train)
from repro.train.grad_sync import dequantize_int8, quantize_int8
from repro.train.optimizer import global_norm, schedule
from repro.ckpt import latest_step, restore, save


CFG = get_arch("qwen2-0.5b").reduced(n_layers=2, d_model=32, n_heads=4,
                                     vocab=64)


def _mini_batch(seed=0, B=4, S=8):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, CFG.vocab, (B, S + 1))
    return {"inputs": jnp.asarray(toks[:, :-1], jnp.int32),
            "targets": jnp.asarray(toks[:, 1:], jnp.int32)}


def test_adamw_reduces_loss():
    params = init_params(CFG, jax.random.PRNGKey(0), jnp.float32)
    acfg = AdamWConfig(lr=5e-3, warmup_steps=2, total_steps=60)
    step_fn, _ = build_train_step(CFG, acfg, TrainOptions(remat=False),
                                  donate=False)
    opt = init_opt_state(params)
    batch = _mini_batch()
    losses = []
    for _ in range(30):
        params, opt, m = step_fn(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.7, losses[::10]
    assert np.isfinite(losses).all()


def test_microbatch_accumulation_matches_full_batch():
    params = init_params(CFG, jax.random.PRNGKey(1), jnp.float32)
    acfg = AdamWConfig(lr=1e-3)
    batch = _mini_batch(seed=5, B=8)
    f1, _ = build_train_step(CFG, acfg, TrainOptions(remat=False,
                                                     microbatches=1),
                             donate=False)
    f2, _ = build_train_step(CFG, acfg, TrainOptions(remat=False,
                                                     microbatches=4),
                             donate=False)
    opt = init_opt_state(params)
    p1, _, m1 = f1(params, opt, batch)
    opt = init_opt_state(params)
    p2, _, m2 = f2(params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_schedule_and_clip():
    acfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                       min_lr_ratio=0.1)
    assert float(schedule(acfg, 0)) == 0.0
    assert float(schedule(acfg, 10)) == pytest.approx(1.0)
    assert float(schedule(acfg, 100)) == pytest.approx(0.1)
    assert float(schedule(acfg, 55)) < 1.0
    g = {"w": jnp.full((4,), 100.0)}
    assert float(global_norm(g)) == pytest.approx(200.0)


def test_data_pipeline_deterministic_resume():
    d = DataConfig(seq_len=16, global_batch=4, vocab=97, seed=7)
    p1 = TokenPipeline(d)
    b5 = p1.batch_at(5)
    p2 = TokenPipeline(d)           # "restarted job"
    b5b = p2.batch_at(5)
    np.testing.assert_array_equal(b5["inputs"], b5b["inputs"])
    b6 = p1.batch_at(6)
    assert not np.array_equal(b5["inputs"], b6["inputs"])


def test_data_pipeline_memmap(tmp_path):
    from repro.train.data import write_token_file
    toks = np.arange(1000, dtype=np.int32) % 50
    f = str(tmp_path / "tokens.bin")
    write_token_file(f, toks)
    d = DataConfig(seq_len=16, global_batch=2, vocab=50, token_file=f)
    b = TokenPipeline(d).batch_at(0)
    assert b["inputs"].shape == (2, 16)
    # targets are inputs shifted by one in the source stream
    np.testing.assert_array_equal(b["inputs"][:, 1:], b["targets"][:, :-1])


def test_checkpoint_roundtrip_and_gc(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": (jnp.ones(4), {"c": jnp.zeros((2, 2), jnp.bfloat16)})}
    d = str(tmp_path)
    for s in (1, 2, 3, 4):
        save(d, s, tree, keep=2)
    assert latest_step(d) == 4
    assert len([f for f in os.listdir(d) if f.endswith(".npz")]) == 2
    out = restore(d, 4, tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_train_loop_resume_continuity(tmp_path):
    """Fault tolerance e2e: train 6 steps, 'crash', resume to 12 — the
    resumed run must pick up at step 6 with the checkpointed state."""
    acfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=12)
    dcfg = DataConfig(seq_len=8, global_batch=4, vocab=CFG.vocab, seed=3)
    logs = []
    lcfg = LoopConfig(total_steps=6, ckpt_dir=str(tmp_path / "ck"),
                      ckpt_every=3, log_every=1)
    p1, _, h1 = train(CFG, acfg, dcfg, lcfg, log=logs.append)
    assert latest_step(lcfg.ckpt_dir) == 6
    lcfg2 = LoopConfig(total_steps=12, ckpt_dir=str(tmp_path / "ck"),
                       ckpt_every=3, log_every=1)
    p2, _, h2 = train(CFG, acfg, dcfg, lcfg2, log=logs.append)
    assert any("resumed from step 6" in l for l in logs)
    assert len(h2) == 6             # only steps 6..11 in the resumed run
    # uninterrupted reference run
    lcfg3 = LoopConfig(total_steps=12, ckpt_dir=str(tmp_path / "ck2"),
                       ckpt_every=100, log_every=100)
    p3, _, h3 = train(CFG, acfg, dcfg, lcfg3, log=lambda *_: None)
    np.testing.assert_allclose(h1 + h2, h3, rtol=1e-4)
    for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(p3)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_int8_quantization_roundtrip():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1000) * 3, jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) * 0.5 + 1e-6
    assert q.dtype == jnp.int8
