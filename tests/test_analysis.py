"""Tests for the static-analysis subsystem itself (repro.analysis).

Pass 1: golden collective signatures for the hier collectives on a 1×1
mesh, clean program audits, and the two injected regressions the auditor
exists to catch (flat-psum substitution, empty-halo collective).  The 2×4
traced goldens run in the 8-device subprocess (tests/dist_solve_script.py,
"OK comm_audit").  Pass 2: one unit test per lint rule, including the
deliberately bad coroutine and the marker suppressions, plus the
clean-tree gate.
"""
import pathlib
import textwrap

import numpy as np
import pytest

from repro.analysis import audit_apply, audit_program, audit_setup
from repro.analysis import collective_signature
from repro.analysis.lint import lint_paths, lint_source

SRC = pathlib.Path(__file__).parents[1] / "src"


# ---------------------------------------------------------------- fixtures


@pytest.fixture(scope="module")
def dh11():
    """A small lowered hierarchy on the in-process 1×1 mesh (collectives
    still trace — every halo is empty but hier_psum/hier_all_gather keep
    their strategy lowerings)."""
    pytest.importorskip("jax")
    from repro.amg import setup
    from repro.amg.dist_solve import DistHierarchy
    from repro.amg.problems import laplace_3d
    h = setup(laplace_3d(6), solver="rs", max_coarse=30)
    return DistHierarchy.build(h, 1, 1)


# ------------------------------------------------------- pass 1: comm audit


def test_hier_collective_golden_signatures_1x1():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    from repro.core.compat import shard_map
    from repro.core.nap_collectives import (GATHER_SIGNATURES,
                                            REDUCE_SIGNATURES,
                                            hier_all_gather, hier_psum)
    P = jax.sharding.PartitionSpec
    mesh = jax.make_mesh((1, 1), ("pod", "lane"))

    def trace(fn):
        sm = shard_map(fn, mesh=mesh, in_specs=P(("pod", "lane")),
                       out_specs=P(("pod", "lane")), check_vma=False)
        return jax.make_jaxpr(sm)(jnp.zeros((1, 8)))

    for strat, expect in REDUCE_SIGNATURES.items():
        jx = trace(lambda x, s=strat: hier_psum(x[0], "pod", "lane", s)[None])
        assert collective_signature(jx) == expect, strat
    for strat, expect in GATHER_SIGNATURES.items():
        jx = trace(lambda x, s=strat:
                   hier_all_gather(x[0], "pod", "lane", s)[None])
        assert collective_signature(jx) == expect, strat


def test_halo_signature_tables_match_operators():
    """Host-side golden: every strategy's DistOperator states the ordered
    signature of the table (the 2×4 *traced* check runs in the subprocess);
    an empty-halo operator states ()."""
    from repro.amg.csr import CSR
    from repro.amg.dist_spmv import build_dist_operator
    from repro.core.nap_collectives import HALO_SIGNATURES
    rng = np.random.default_rng(0)
    n = 96
    band = np.abs(np.subtract.outer(np.arange(n), np.arange(n))) <= 3
    dense = band * rng.normal(size=(n, n))
    r, c = np.nonzero(dense)
    A = CSR.from_coo(r, c, dense[r, c], (n, n))
    for strat, expect in HALO_SIGNATURES.items():
        op = build_dist_operator(A, 2, 4, strat, dtype=np.float64)
        assert not op.halo_empty
        assert op.expected_signature == expect, strat


def test_program_audits_clean_1x1(dh11):
    from repro.amg.solve import SolveOptions
    from repro.analysis import audit_cycle_stats
    for name in ("resid_norm", "vcycle", "pcg_init", "pcg_step_m"):
        a = audit_program(dh11, name)
        assert a.ok, [str(v) for v in a.violations]
        assert a.counts == a.expected
    for cycle in ("V", "W", "F"):
        a = audit_program(dh11, "vcycle", SolveOptions(cycle=cycle))
        assert a.ok, (cycle, [str(v) for v in a.violations])
    for level in range(len(dh11.levels)):
        for op in ("A", "P", "R"):
            if getattr(dh11.levels[level], op) is not None:
                ap = audit_apply(dh11, level, op)
                assert ap.ok and ap.n_collectives == 0, (level, op)
    assert audit_cycle_stats(dh11) == []


def test_injected_flat_psum_detected(monkeypatch):
    """The regression the auditor exists for: hier_psum silently replaced
    by a flat psum passes every runtime-parity gate (same numbers!) but
    must fail the count cross-check on a freshly built hierarchy."""
    jax = pytest.importorskip("jax")
    import repro.amg.dist_solve as ds
    from repro.amg import setup
    from repro.amg.problems import laplace_3d
    monkeypatch.setattr(
        ds, "hier_psum",
        lambda x, slow, fast, strategy="nap3": jax.lax.psum(x, (slow, fast)))
    h = setup(laplace_3d(6), solver="rs", max_coarse=30)
    dh_bad = ds.DistHierarchy.build(h, 1, 1)
    bad = audit_program(dh_bad, "resid_norm")
    assert not bad.ok
    assert any(v.kind == "count-mismatch" for v in bad.violations)
    assert bad.counts.get("psum_scatter", 0) == 0  # the scatter leg vanished
    assert bad.expected["psum_scatter"] >= 1


def test_injected_empty_halo_collective_detected(dh11, monkeypatch):
    """A collective re-introduced on an empty-halo level must be caught:
    forcing the apply down the exchange path while the plan moves nothing
    violates the zero-collective contract."""
    pytest.importorskip("jax")
    from repro.amg.dist_spmv import DistOperator
    assert dh11.levels[0].A.halo_empty          # 1×1: nothing to exchange
    monkeypatch.setattr(DistOperator, "halo_empty",
                        property(lambda self: False))
    a = audit_apply(dh11, 0, "A")
    assert not a.ok
    assert any(v.kind == "empty-halo-collective" for v in a.violations)
    assert a.n_collectives > 0


def test_overlap_independence_taint_sweep():
    """The dataflow check behind ``overlap=True``: a contraction feeding
    off the collective's output is serialized; one reading only local data
    is overlappable."""
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    from repro.analysis import check_overlap_independence
    from repro.core.compat import shard_map
    P = jax.sharding.PartitionSpec
    mesh = jax.make_mesh((1,), ("ax",))

    def trace(fn):
        sm = shard_map(fn, mesh=mesh, in_specs=P("ax"), out_specs=P(),
                       check_vma=False)
        return jax.make_jaxpr(sm)(jnp.zeros((8,)))

    def serial(x):
        y = jax.lax.psum(x, "ax")          # exchange ...
        return jnp.sum(y * x)              # ... feeds the only contraction

    def overlapped(x):
        local = jnp.sum(x * x)             # collective-independent
        return local + jnp.sum(jax.lax.psum(x, "ax"))

    assert not check_overlap_independence(trace(serial))
    assert check_overlap_independence(trace(overlapped))


def test_setup_audit_clean_and_tampered():
    import dataclasses
    from repro.amg.dist_setup import dist_setup_partitioned
    from repro.amg.problems import laplace_3d
    plv, recs = dist_setup_partitioned(laplace_3d(6), 2, 2)
    rows, vio = audit_setup(plv, recs)
    assert rows and not vio, [str(v) for v in vio]
    for r in rows:
        assert r["static_inter_msgs"] == r["runtime_inter_msgs"]
        assert r["static_intra_msgs"] == r["runtime_intra_msgs"]
    # a measured counter drifting off the selected schedule must be caught
    bad = [dataclasses.replace(recs[0], inter_msgs=recs[0].inter_msgs + 1)]
    _, vio2 = audit_setup(plv, bad + recs[1:])
    assert any(v.kind == "setup-count-mismatch" for v in vio2)
    # ... as must an exchange that ran a different strategy than cached
    other = "nap3" if recs[0].strategy != "nap3" else "nap2"
    bad2 = [dataclasses.replace(recs[0], strategy=other)]
    _, vio3 = audit_setup(plv, bad2 + recs[1:])
    assert any(v.kind == "strategy-mismatch" for v in vio3)


def test_audit_report_roundtrip(dh11):
    import json
    from repro.analysis import build_report
    a = audit_program(dh11, "resid_norm")
    rep = build_report(audits=[a], meta={"pods": 1, "lanes": 1})
    assert rep["summary"]["ok"]
    assert rep["comm_audit"][0]["counts"] == a.counts
    json.dumps(rep)                                 # fully serializable
    for r in rep["comm_audit"][0]["records"]:
        assert r["primitive"] in ("psum", "psum_scatter", "all_gather",
                                  "all_to_all", "ppermute")
        assert r["bytes"] >= 0 and r["axes"]


# ----------------------------------------------------------- pass 2: lint


def _lint(src):
    return lint_source(textwrap.dedent(src), "mod.py")


def test_lint_async_blocking_bad_coroutine():
    vs = _lint("""
        import time

        async def handler(svc, t):
            x = t.result(timeout=5)
            svc.update_wire(x)
            time.sleep(1)
            return x
        """)
    rules = [v.rule for v in vs]
    assert rules.count("async-blocking") == 3, vs


def test_lint_async_blocking_sanctioned_forms_pass():
    vs = _lint("""
        import asyncio

        async def handler(tenant, payload, t, writer):
            await asyncio.to_thread(tenant.service.update_wire, payload)
            await writer.drain()

            def _resolve():                     # sync scope resets the rule
                return t.result(timeout=0)

            fut = asyncio.get_event_loop().create_future()
            fut.set_result(_resolve())          # set_result is not blocking
            return await fut
        """)
    assert vs == []


def test_lint_raw_collective_and_markers():
    bad = _lint("""
        import jax

        def f(x):
            return jax.lax.psum(x, "ax")
        """)
    assert [v.rule for v in bad] == ["raw-collective"]
    allowed = _lint("""
        import jax

        def f(x):
            return jax.lax.psum(x, "ax")  # comm-audit: allow flat-psum
        """)
    assert allowed == []
    filewide = _lint("""
        # comm-audit: allow-file raw-collective
        import jax

        def f(x):
            return jax.lax.all_gather(x, "ax")
        """)
    assert filewide == []


def test_lint_traced_host_call():
    vs = _lint("""
        import time
        import jax

        def body(x):
            return x * time.time()

        prog = jax.jit(body)

        def host_side():                        # not traced: fine
            return time.perf_counter()
        """)
    assert [v.rule for v in vs] == ["traced-host-call"]
    decorated = _lint("""
        import time
        import jax

        @jax.jit
        def body(x):
            return x * time.perf_counter()
        """)
    assert [v.rule for v in decorated] == ["traced-host-call"]


def test_lint_frozen_mutation():
    vs = _lint("""
        import dataclasses

        @dataclasses.dataclass(frozen=True)
        class Cfg:
            a: int = 0

            def __post_init__(self):
                object.__setattr__(self, "a", 1)    # allowed here

        def f(c: Cfg):
            c.a = 2
            object.__setattr__(c, "a", 3)
            return dataclasses.replace(c, a=4)      # the sanctioned route

        def g():
            c = Cfg()
            c.a = 5
            return c
        """)
    assert [v.rule for v in vs] == ["frozen-mutation"] * 3, vs


def test_lint_clean_tree():
    """The repo's own src/ carries zero violations (documented exceptions
    are marker-suppressed) — the CI gate for pass 2."""
    assert lint_paths(SRC) == []
