"""Roofline extraction tests: HLO collective parsing + term math."""
import pytest

from repro.launch.roofline import (collective_bytes_from_text,
                                   parse_collectives, roofline_terms)

HLO = """
HloModule test
%all-reduce.1 = f32[16,4096]{1,0} all-reduce(%x), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
%all-gather.2 = (bf16[8,128]{1,0}, bf16[8,128]{1,0}) all-gather(%a, %b), replica_groups=[4,2]<=[8], dimensions={0}
%all-to-all.3 = f32[2,64]{1,0} all-to-all(%c), replica_groups={{0,4},{1,5},{2,6},{3,7}}
%all-gather-start.4 = f32[100]{0} all-gather-start(%d), replica_groups={{0,1}}
%all-gather-done.5 = f32[100]{0} all-gather-done(%all-gather-start.4)
%reduce-scatter.6 = f32[10]{0} reduce-scatter(%e), replica_groups={}
%get-tuple-element.9 = f32[2,64]{1,0} get-tuple-element(%all-to-all.3), index=0
"""


def test_parse_collectives_ops_and_bytes():
    infos = parse_collectives(HLO, pod_size=4, n_devices=8)
    ops = [i.op for i in infos]
    assert ops.count("all-reduce") == 1
    assert ops.count("all-gather") == 2      # -start counted, -done skipped
    assert ops.count("all-to-all") == 1
    assert ops.count("reduce-scatter") == 1
    by = {i.op: i for i in infos}
    assert by["all-reduce"].bytes == 16 * 4096 * 4
    # tuple result: both elements summed
    assert by["all-gather"].bytes in (8 * 128 * 2 * 2, 100 * 4)
    assert by["all-to-all"].bytes == 2 * 64 * 4


def test_cross_pod_classification():
    infos = parse_collectives(HLO, pod_size=4, n_devices=8)
    by_op = {}
    for i in infos:
        by_op.setdefault(i.op, []).append(i)
    # all-reduce groups {0..3},{4..7} stay inside pods of 4
    assert not by_op["all-reduce"][0].crosses_pod
    # all-to-all groups {0,4} cross pods
    assert by_op["all-to-all"][0].crosses_pod
    # iota [4,2]<=[8]: groups {0,1},{2,3},... stay within pod
    ag = [i for i in by_op["all-gather"] if i.group_size == 2]
    assert any(not i.crosses_pod for i in ag)
    # empty replica_groups = all devices -> crosses (8 devices, pod 4)
    assert by_op["reduce-scatter"][0].crosses_pod


def test_iota_transpose_groups():
    hlo = ('%all-gather.9 = f32[4]{0} all-gather(%x), '
           'replica_groups=[2,4]<=[4,2]T(1,0), dimensions={0}')
    infos = parse_collectives(hlo, pod_size=4, n_devices=8)
    # [4,2]T(1,0) → device order 0,2,4,6,1,3,5,7 → groups {0,2,4,6},{1,3,5,7}
    assert infos[0].crosses_pod
    assert infos[0].group_size == 4


def test_collective_totals():
    d = collective_bytes_from_text(HLO, pod_size=4, n_devices=8)
    assert d["n_collectives"] == 5
    assert d["total_bytes"] == sum(
        [16 * 4096 * 4, 8 * 128 * 2 * 2, 2 * 64 * 4, 100 * 4, 10 * 4])
    assert 0 < d["cross_slow_bytes"] < d["total_bytes"]


def test_roofline_terms_math():
    cost = {"flops": 1.97e14, "bytes accessed": 8.19e11}
    t = roofline_terms(cost, "", n_chips=256, pod_size=256,
                       model_flops=1.97e14 * 256)
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(1.0)
    assert t.useful_flops_fraction == pytest.approx(1.0)
    assert t.dominant in ("compute", "memory")
    # roofline fraction: ideal == 1s, bound == 1s → 1.0
    assert t.roofline_fraction == pytest.approx(1.0)
