"""Property + unit tests for the paper's communication schedules (core/)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (BLUE_WATERS, TPU_V5E, CommGraph, Partition, Topology,
                        build, select)
from repro.core.perf_model import (maxrate_internode_time, model_time,
                                   model_time_closed, single_message_time)
from repro.core.schedules import STRATEGIES, ScheduleStats
from repro.core.simulator import verify


# --------------------------------------------------------------------- helpers
def random_graph(rng, n_nodes, ppn, n, max_need, weights=None):
    topo = Topology(n_nodes=n_nodes, ppn=ppn)
    part = Partition.balanced(n, topo)
    need = []
    for q in range(topo.n_procs):
        lo, hi = part.local_range(q)
        cand = np.setdiff1d(np.arange(n), np.arange(lo, hi))
        k = int(rng.integers(0, min(max_need, cand.size) + 1))
        need.append(rng.choice(cand, size=k, replace=False))
    return CommGraph.from_offproc_columns(part, need, weights=weights)


@st.composite
def graph_params(draw):
    n_nodes = draw(st.integers(2, 6))
    ppn = draw(st.integers(1, 6))
    n = draw(st.integers(n_nodes * ppn, 300))
    max_need = draw(st.integers(0, 40))
    seed = draw(st.integers(0, 2**31 - 1))
    return n_nodes, ppn, n, max_need, seed


# ------------------------------------------------------------ delivery property
@settings(max_examples=60, deadline=None)
@given(graph_params(), st.sampled_from(STRATEGIES))
def test_exactly_once_delivery(params, strategy):
    """Every strategy delivers every needed value exactly once, correctly."""
    n_nodes, ppn, n, max_need, seed = params
    rng = np.random.default_rng(seed)
    g = random_graph(rng, n_nodes, ppn, n, max_need)
    x = rng.standard_normal(n)
    verify(build(strategy, g), x)  # raises on any violation


@settings(max_examples=40, deadline=None)
@given(graph_params())
def test_nap_reduces_internode_traffic(params):
    """NAP-2/3 inter-node bytes <= standard (dedup); NAP-3 message count is
    minimal (<= one per ordered node pair) and <= NAP-2 count."""
    n_nodes, ppn, n, max_need, seed = params
    rng = np.random.default_rng(seed)
    g = random_graph(rng, n_nodes, ppn, n, max_need)
    stats = {s: ScheduleStats.of(build(s, g)) for s in STRATEGIES}
    assert stats["nap2"].inter_bytes_total <= stats["standard"].inter_bytes_total + 1e-9
    assert stats["nap3"].inter_bytes_total <= stats["nap2"].inter_bytes_total + 1e-9
    assert stats["nap3"].inter_msg_count <= n_nodes * (n_nodes - 1)
    assert stats["nap3"].inter_msg_count <= stats["nap2"].inter_msg_count
    assert stats["nap2"].inter_msg_count <= stats["standard"].inter_msg_count


@settings(max_examples=30, deadline=None)
@given(graph_params())
def test_nap2_load_balance_matches_standard_sources(params):
    """NAP-2 keeps every sending process active: the set of ranks sending
    inter-node messages under NAP-2 equals the set under standard (paper §3.2:
    'process loads remain equally balanced to standard')."""
    n_nodes, ppn, n, max_need, seed = params
    rng = np.random.default_rng(seed)
    g = random_graph(rng, n_nodes, ppn, n, max_need)

    def senders(strategy):
        topo = g.topo
        return {m.src for k, m in build(strategy, g).all_messages()
                if not topo.on_same_node(m.src, m.dst)}

    assert senders("nap2") == senders("standard")


def test_weighted_graph_matrix_comm():
    """Matrix rows weigh by nnz; byte accounting follows weights."""
    rng = np.random.default_rng(3)
    weights = rng.integers(1, 50, size=400).astype(np.float64) * 12.0 + 16.0
    g = random_graph(rng, 4, 4, 400, 25, weights=weights)
    for s in STRATEGIES:
        res = verify(build(s, g), rng.standard_normal(400))
        assert res.inter_bytes == pytest.approx(
            ScheduleStats.of(build(s, g)).inter_bytes_total)


# ------------------------------------------------------------------ perf model
def test_closed_model_reduces_to_maxrate_when_balanced():
    """Eq. (2) reduces to Eq. (1) under perfect balance (paper §3.3)."""
    p = BLUE_WATERS
    s_proc = 8192.0
    s_node = p.ppn * s_proc
    # max(s_node/RN, s_proc/Rb) == ppn*s_proc/min(RN, ppn*Rb)
    lhs = max(s_node / p.RN, s_proc / p.inter[2].Rb)
    rhs = p.ppn * s_proc / min(p.RN, p.ppn * p.inter[2].Rb)
    assert lhs == pytest.approx(rhs)


def test_single_message_cost_ordering():
    """Fig. 8: socket < node < network for any size; cost grows with size."""
    for nbytes in (64, 4096, 1 << 20):
        ts = single_message_time(BLUE_WATERS, nbytes, "socket")
        tn = single_message_time(BLUE_WATERS, nbytes, "node")
        tw = single_message_time(BLUE_WATERS, nbytes, "network")
        assert ts < tn < tw
    small = single_message_time(BLUE_WATERS, 64, "network")
    large = single_message_time(BLUE_WATERS, 1 << 22, "network")
    assert large > small


def test_maxrate_more_active_processes_cheaper():
    """Fig. 9: spreading one inter-node transfer over more processes is
    monotonically non-increasing in cost, floored by the NID rate."""
    total = 4 << 20
    times = [maxrate_internode_time(BLUE_WATERS, total, k) for k in (1, 2, 4, 8, 16)]
    assert all(a >= b - 1e-12 for a, b in zip(times, times[1:]))
    floor = total / BLUE_WATERS.RN
    assert times[-1] >= floor


def test_model_prefers_nap_for_many_small_messages():
    """Coarse-level regime: many tiny messages -> node-aware wins (Fig. 14)."""
    rng = np.random.default_rng(7)
    g = random_graph(rng, 8, 16, 4000, 40)
    sel = select(g, BLUE_WATERS)
    assert sel.strategy in ("nap2", "nap3")
    assert sel.times[sel.strategy] <= sel.times["standard"]


def test_model_prefers_standard_for_few_large_messages():
    """Fine-level regime: each rank talks to 1 neighbor with a huge message."""
    topo = Topology(n_nodes=4, ppn=4)
    n = 16 * 4096
    part = Partition.balanced(n, topo)
    need = []
    for q in range(topo.n_procs):
        # needs a large contiguous chunk from one neighbouring rank only
        nb = (q + topo.ppn) % topo.n_procs  # rank on another node
        lo, hi = part.local_range(nb)
        need.append(np.arange(lo, hi))
    g = CommGraph(part, [np.asarray(v) for v in need])
    sel = select(g, BLUE_WATERS)
    # standard has no extra on-node copy; model must not pick NAP-3 here
    assert sel.times["standard"] <= sel.times["nap3"]


@settings(max_examples=25, deadline=None)
@given(graph_params())
def test_models_positive_and_finite(params):
    n_nodes, ppn, n, max_need, seed = params
    rng = np.random.default_rng(seed)
    g = random_graph(rng, n_nodes, ppn, n, max_need)
    for s in STRATEGIES:
        sch = build(s, g)
        t = model_time(sch, TPU_V5E)
        tc = model_time_closed(ScheduleStats.of(sch), TPU_V5E)
        assert np.isfinite(t) and t >= 0
        assert np.isfinite(tc) and tc >= 0


# ------------------------------------------------------------------- topology
def test_topology_basics():
    t = Topology(n_nodes=3, ppn=4)
    assert t.n_procs == 12
    assert t.node_of(7) == 1 and t.local_rank(7) == 3
    assert list(t.ranks_on_node(2)) == [8, 9, 10, 11]
    p = Partition.balanced(10, t)
    assert p.offsets[-1] == 10
    assert p.owner_of_rows(np.array([0, 9])).tolist() == [0, 9]
    with pytest.raises(ValueError):
        Topology(0, 4)
