"""Per-architecture smoke tests (reduced configs, CPU): one forward + one
train step asserting shapes & finiteness, plus decode-vs-forward consistency
(validates every cache/state path)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, cells, get_arch
from repro.models import (decode_step, forward, init_cache, init_params,
                          loss_fn)

ARCH_NAMES = sorted(ARCHS)


def _batch(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.embed_input:
        inputs = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    else:
        inputs = jnp.asarray(rng.standard_normal((B, S, cfg.d_model)),
                             jnp.float32)
    targets = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    return {"inputs": inputs, "targets": targets}


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_and_train_step(name):
    cfg = get_arch(name).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    batch = _batch(cfg)
    logits = forward(params, cfg, batch["inputs"])
    assert logits.shape == (2, 16, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()

    # one SGD train step
    loss, grads = jax.value_and_grad(loss_fn)(params, cfg, batch)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in flat)
    new_params = jax.tree.map(lambda p, g: p - 1e-3 * g, params, grads)
    loss2 = loss_fn(new_params, cfg, batch)
    assert np.isfinite(float(loss2))
    # a tiny step along the negative gradient should not blow up
    assert float(loss2) < float(loss) + 1.0


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_remat_matches_no_remat(name):
    cfg = get_arch(name).reduced()
    params = init_params(cfg, jax.random.PRNGKey(1), jnp.float32)
    batch = _batch(cfg, seed=3)
    l1 = loss_fn(params, cfg, batch, remat=False)
    l2 = loss_fn(params, cfg, batch, remat=True)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_decode_matches_forward(name):
    """Token-by-token decode reproduces the full forward logits — exercises
    KV ring caches and every recurrent state path.  MoE capacity is raised
    so token-drop patterns (legitimately different between prefill batch
    shapes and decode) cannot mask cache bugs."""
    import dataclasses
    cfg = dataclasses.replace(get_arch(name).reduced(), capacity_factor=16.0)
    params = init_params(cfg, jax.random.PRNGKey(2), jnp.float32)
    B, S = 2, 10
    batch = _batch(cfg, B=B, S=S, seed=7)
    ref = np.asarray(forward(params, cfg, batch["inputs"]), np.float32)

    cache = init_cache(cfg, B, ctx_len=S, dtype=jnp.float32)
    for t in range(S):
        tok = batch["inputs"][:, t:t + 1]
        logits, cache = decode_step(params, cfg, tok, cache, jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(logits, np.float32), ref[:, t], rtol=2e-3, atol=2e-3,
            err_msg=f"{name} step {t}")


def test_sliding_window_ring_cache():
    """Decode beyond the window size: ring buffer must evict correctly."""
    import dataclasses
    cfg = dataclasses.replace(get_arch("mixtral-8x22b").reduced(),
                              capacity_factor=16.0)
    assert cfg.window is not None and cfg.window < 40
    params = init_params(cfg, jax.random.PRNGKey(3), jnp.float32)
    B, S = 1, cfg.window + 8
    batch = _batch(cfg, B=B, S=S, seed=11)
    ref = np.asarray(forward(params, cfg, batch["inputs"]), np.float32)
    cache = init_cache(cfg, B, ctx_len=S, dtype=jnp.float32)  # clen == window
    for t in range(S):
        logits, cache = decode_step(params, cfg, batch["inputs"][:, t:t + 1],
                                    cache, jnp.int32(t))
    np.testing.assert_allclose(np.asarray(logits, np.float32), ref[:, -1],
                               rtol=2e-3, atol=2e-3)


def test_cells_cover_assignment():
    cs = cells()
    assert len(cs) == 40
    skips = [c for c in cs if c[2]]
    assert len(skips) == 7          # 7 pure full-attention archs skip long_500k
    assert {a.name for a, s, r in skips} == {
        "musicgen-medium", "qwen3-moe-235b-a22b", "qwen2-0.5b", "qwen3-1.7b",
        "qwen1.5-0.5b", "starcoder2-7b", "phi-3-vision-4.2b"}
    assert all(s.name == "long_500k" for _, s, r in skips)


def test_param_counts_match_published():
    expected = {"musicgen-medium": 1.5e9, "mixtral-8x22b": 141e9,
                "qwen3-moe-235b-a22b": 235e9, "qwen2-0.5b": 0.5e9,
                "qwen3-1.7b": 1.7e9, "qwen1.5-0.5b": 0.5e9,
                "starcoder2-7b": 7e9, "xlstm-125m": 0.125e9,
                "phi-3-vision-4.2b": 4.2e9, "recurrentgemma-9b": 9e9}
    for name, exp in expected.items():
        got = get_arch(name).n_params()
        assert 0.8 < got / exp < 1.25, (name, got, exp)
    # MoE active params
    assert 18e9 < get_arch("qwen3-moe-235b-a22b").n_active_params() < 28e9
    assert 35e9 < get_arch("mixtral-8x22b").n_active_params() < 60e9
