"""Minimal deterministic fallback for the subset of the `hypothesis` API the
test-suite uses (``given``, ``settings``, ``strategies.integers`` /
``sampled_from`` / ``composite``).

Loaded by ``conftest.py`` only when the real `hypothesis` package is missing
(the CI container has no network to install extras).  This is NOT a
property-testing engine: every ``@given`` test runs a capped number of
seeded pseudo-random examples with no shrinking, so failures reproduce
deterministically but exploration is shallower than real hypothesis.
"""
from __future__ import annotations

import functools
import inspect
import types

import numpy as np

MAX_EXAMPLES_CAP = 16


class _Strategy:
    def __init__(self, draw_fn):
        self.draw = draw_fn


def _integers(min_value, max_value):
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def _sampled_from(elements):
    seq = list(elements)
    return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])


def _composite(fn):
    @functools.wraps(fn)
    def build(*args, **kwargs):
        def draw_fn(rng):
            return fn(lambda s: s.draw(rng), *args, **kwargs)
        return _Strategy(draw_fn)
    return build


def settings(max_examples: int = MAX_EXAMPLES_CAP, deadline=None, **_ignored):
    def deco(fn):
        fn._hyp_max_examples = max_examples
        return fn
    return deco


def given(*strats):
    def deco(fn):
        @functools.wraps(fn)
        def run(*args, **kwargs):
            n = min(MAX_EXAMPLES_CAP,
                    getattr(run, "_hyp_max_examples",
                            getattr(fn, "_hyp_max_examples", MAX_EXAMPLES_CAP)))
            for i in range(n):
                rng = np.random.default_rng(0xC0FFEE + i)
                fn(*args, *[s.draw(rng) for s in strats], **kwargs)
        # hide the drawn parameters from pytest's fixture resolution
        del run.__wrapped__
        run.__signature__ = inspect.Signature()
        return run
    return deco


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = _integers
strategies.sampled_from = _sampled_from
strategies.composite = _composite
