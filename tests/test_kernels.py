"""Pallas kernel validation: interpret=True vs pure-jnp oracles, swept over
shapes/dtypes (per-kernel allclose requirement)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ops import attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.spmv.ops import spmv
from repro.kernels.spmv.ref import ell_spmv_ref
from repro.kernels.spmv.spmv import ell_spmv


# ------------------------------------------------------------------- spmv
def _random_ell(rng, n, m, k, dtype):
    cols = rng.integers(0, m, size=(n, k)).astype(np.int32)
    mask = rng.random((n, k)) < 0.3
    cols[mask] = -1
    vals = rng.standard_normal((n, k)).astype(dtype)
    vals[mask] = 0.0
    x = rng.standard_normal(m).astype(dtype)
    return jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(x)


@pytest.mark.parametrize("n,m,k", [(8, 16, 3), (100, 64, 7), (257, 300, 27),
                                   (1024, 512, 9)])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_spmv_kernel_matches_ref(n, m, k, dtype):
    rng = np.random.default_rng(n + k)
    cols, vals, x = _random_ell(rng, n, m, k, np.float32)
    vals = vals.astype(jnp.dtype(dtype))
    x = x.astype(jnp.dtype(dtype))
    ref = ell_spmv_ref(cols, vals, x)
    out = ell_spmv(cols, vals, x, interpret=True)
    tol = 1e-5 if dtype == "float32" else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


def test_spmv_matches_csr_matvec():
    from repro.amg.problems import laplace_3d_7pt
    A = laplace_3d_7pt(8)
    K = int(np.diff(A.indptr).max())
    n = A.nrows
    cols = np.full((n, K), -1, dtype=np.int32)
    vals = np.zeros((n, K), dtype=np.float32)
    for i in range(n):
        s = slice(int(A.indptr[i]), int(A.indptr[i + 1]))
        cols[i, : s.stop - s.start] = A.indices[s]
        vals[i, : s.stop - s.start] = A.data[s]
    x = np.random.default_rng(0).standard_normal(n).astype(np.float32)
    y = spmv(jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(y), A.matvec(x), rtol=2e-4, atol=2e-4)


def test_spmv_block_rows_sweep():
    rng = np.random.default_rng(5)
    cols, vals, x = _random_ell(rng, 200, 128, 5, np.float32)
    ref = ell_spmv_ref(cols, vals, x)
    for br in (8, 32, 64, 512):
        out = ell_spmv(cols, vals, x, block_rows=br, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5,
                                   atol=1e-5)


# -------------------------------------------------------------- attention
@pytest.mark.parametrize("b,hq,hkv,sq,skv,d", [
    (1, 4, 4, 64, 64, 32),       # MHA
    (2, 8, 2, 128, 128, 64),     # GQA 4:1
    (1, 14, 2, 96, 96, 64),      # qwen2-style 7:1, non-pow2 seq
    (1, 4, 1, 64, 64, 128),      # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(b, hq, hkv, sq, skv, d, dtype):
    rng = np.random.default_rng(hq * sq)
    q = jnp.asarray(rng.standard_normal((b, hq, sq, d)), dtype=dtype)
    k = jnp.asarray(rng.standard_normal((b, hkv, skv, d)), dtype=dtype)
    v = jnp.asarray(rng.standard_normal((b, hkv, skv, d)), dtype=dtype)
    ref = attention_ref(q, k, v, causal=True)
    out = flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                          interpret=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [16, 48, 128])
def test_flash_attention_sliding_window(window):
    rng = np.random.default_rng(window)
    q = jnp.asarray(rng.standard_normal((1, 4, 128, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 128, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 128, 32)), jnp.float32)
    ref = attention_ref(q, k, v, causal=True, window=window)
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=32, block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)


def test_flash_attention_decode_alignment():
    """Sq < Skv (queries right-aligned): the KV-cache decode case."""
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.standard_normal((1, 4, 8, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 4, 96, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 4, 96, 32)), jnp.float32)
    ref = attention_ref(q, k, v, causal=True)
    out = flash_attention(q, k, v, causal=True, block_q=8, block_k=32,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)


def test_attention_wrapper_time_major():
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.standard_normal((2, 64, 8, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 64, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 64, 2, 32)), jnp.float32)
    out_k = attention(q, k, v, use_kernel=True, block_q=32, block_k=32)
    out_r = attention(q, k, v, use_kernel=False)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=2e-5, atol=2e-5)
    assert out_k.shape == q.shape
