"""Multi-device distributed solve validation — run as a SUBPROCESS by
test_dist_solve.py (device count must be set before jax init).

Asserts that the device-resident ``backend="dist"`` V-cycle / stationary /
PCG solves reproduce the host backend's residual histories to fp32
tolerance for every halo strategy, that per-level model selection picks a
non-standard strategy somewhere in the hierarchy, and that the Pallas ELL
kernel route agrees with the inline form.  Prints "OK <check>" per passing
check; any exception fails the run.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np  # noqa: E402

from repro.amg import SolveOptions, pcg, setup, solve  # noqa: E402
from repro.amg.dist_solve import DistHierarchy  # noqa: E402
from repro.amg.problems import laplace_3d  # noqa: E402
from repro.core import BLUE_WATERS  # noqa: E402

N_PODS, LANES = 2, 4
TOL = 2e-4   # normalized-by-r0 fp32 tolerance


def history_diff(a, b):
    n = min(len(a), len(b))
    r0 = a[0] or 1.0
    return max(abs(x - y) / r0 for x, y in zip(a[:n], b[:n]))


def main():
    A = laplace_3d(8)
    h = setup(A, solver="rs")
    b = A.matvec(np.ones(A.nrows))
    res_h = solve(h, b, tol=1e-5, maxiter=12)
    pcg_h = pcg(h, b, tol=1e-5, maxiter=12)

    for strat in ("standard", "nap2", "nap3"):
        dh = DistHierarchy.build(h, N_PODS, LANES, strategy=strat)
        res_d = solve(h, b, tol=1e-5, maxiter=12, backend="dist", dist=dh)
        assert history_diff(res_h.residuals, res_d.residuals) < TOL, strat
        print(f"OK solve_{strat}")
        pcg_d = pcg(h, b, tol=1e-5, maxiter=12, backend="dist", dist=dh)
        assert history_diff(pcg_h.residuals, pcg_d.residuals) < TOL, strat
        assert pcg_d.converged
        print(f"OK pcg_{strat}")

    # model-driven per-level selection: coarse levels must go node-aware
    dh = DistHierarchy.build(h, N_PODS, LANES, params=BLUE_WATERS)
    chosen = {r["strategy"] for r in dh.selection_table()}
    assert chosen - {"standard"}, dh.summary()
    res_d = solve(h, b, tol=1e-5, maxiter=12, backend="dist", dist=dh)
    assert history_diff(res_h.residuals, res_d.residuals) < TOL
    print("OK auto_select")

    # Pallas ELL kernel route (interpret mode off-TPU) inside the fused cycle
    dh_k = DistHierarchy.build(h, N_PODS, LANES, strategy="nap3",
                               use_kernel=True, interpret=True)
    pcg_k = pcg(h, b, tol=1e-5, maxiter=12, backend="dist", dist=dh_k)
    assert history_diff(pcg_h.residuals, pcg_k.residuals) < TOL
    print("OK pallas_path")

    # chebyshev smoother parity through the same fused program
    oc = SolveOptions(smoother="chebyshev")
    ch = solve(h, b, tol=1e-5, maxiter=10, opts=oc)
    dh3 = DistHierarchy.build(h, N_PODS, LANES, strategy="nap3")
    cd = solve(h, b, tol=1e-5, maxiter=10, opts=oc, backend="dist", dist=dh3)
    assert history_diff(ch.residuals, cd.residuals) < TOL
    print("OK chebyshev")

    print("ALL_OK")


if __name__ == "__main__":
    main()
