"""Multi-device distributed solve validation — run as a SUBPROCESS by
test_dist_solve.py (device count must be set before jax init).

Asserts that the device-resident ``backend="dist"`` V-cycle / stationary /
PCG solves reproduce the host backend's residual histories to fp32
tolerance for every halo strategy, that per-level model selection picks a
non-standard strategy somewhere in the hierarchy, that the Pallas ELL
kernel route agrees with the inline form, and that an fp64 ``AMGSolver``
session's batched multi-RHS dist solve matches per-column host solves to
1e-7 relative residual on the full 2x4 mesh.  Prints "OK <check>" per
passing check; any exception fails the run.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)   # for the fp64 multi-RHS check

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.amg import AMGConfig, AMGSolver, SolveOptions, pcg, setup, solve  # noqa: E402
from repro.amg.dist_solve import DistHierarchy, cycle_comm_stats  # noqa: E402
from repro.amg.problems import laplace_3d  # noqa: E402
from repro.amg.solve import CYCLES, SMOOTHERS  # noqa: E402
from repro.core import BLUE_WATERS  # noqa: E402

N_PODS, LANES = 2, 4
TOL = 2e-4   # normalized-by-r0 fp32 tolerance


def history_diff(a, b):
    n = min(len(a), len(b))
    r0 = a[0] or 1.0
    return max(abs(x - y) / r0 for x, y in zip(a[:n], b[:n]))


def main():
    A = laplace_3d(8)
    h = setup(A, solver="rs")
    b = A.matvec(np.ones(A.nrows))
    res_h = solve(h, b, tol=1e-5, maxiter=12)
    pcg_h = pcg(h, b, tol=1e-5, maxiter=12)

    for strat in ("standard", "nap2", "nap3"):
        dh = DistHierarchy.build(h, N_PODS, LANES, strategy=strat)
        res_d = solve(h, b, tol=1e-5, maxiter=12, backend="dist", dist=dh)
        assert history_diff(res_h.residuals, res_d.residuals) < TOL, strat
        print(f"OK solve_{strat}")
        pcg_d = pcg(h, b, tol=1e-5, maxiter=12, backend="dist", dist=dh)
        assert history_diff(pcg_h.residuals, pcg_d.residuals) < TOL, strat
        assert pcg_d.converged
        print(f"OK pcg_{strat}")

    # model-driven per-level selection: coarse levels must go node-aware
    dh = DistHierarchy.build(h, N_PODS, LANES, params=BLUE_WATERS)
    chosen = {r["strategy"] for r in dh.selection_table()}
    assert chosen - {"standard"}, dh.summary()
    res_d = solve(h, b, tol=1e-5, maxiter=12, backend="dist", dist=dh)
    assert history_diff(res_h.residuals, res_d.residuals) < TOL
    print("OK auto_select")

    # Pallas ELL kernel route (interpret mode off-TPU) inside the fused cycle
    dh_k = DistHierarchy.build(h, N_PODS, LANES, strategy="nap3",
                               use_kernel=True, interpret=True)
    pcg_k = pcg(h, b, tol=1e-5, maxiter=12, backend="dist", dist=dh_k)
    assert history_diff(pcg_h.residuals, pcg_k.residuals) < TOL
    print("OK pallas_path")

    # chebyshev smoother parity through the same fused program
    oc = SolveOptions(smoother="chebyshev")
    ch = solve(h, b, tol=1e-5, maxiter=10, opts=oc)
    dh3 = DistHierarchy.build(h, N_PODS, LANES, strategy="nap3")
    cd = solve(h, b, tol=1e-5, maxiter=10, opts=oc, backend="dist", dist=dh3)
    assert history_diff(ch.residuals, cd.residuals) < TOL
    print("OK chebyshev")

    # EVERY (cycle, smoother) pair — including the symmetric-sweep hybrid
    # GS — as ONE fused fp64 shard_map program on the 2x4 mesh, ≤1e-7
    # residual parity with the host reference (block smoothers: the host
    # mimics the 8-device partition) and a monotone 5-iteration residual
    # decline — the dist half of the property test
    h3 = setup(A, solver="rs", max_coarse=30)   # ≥3 levels so W/F differ
    assert h3.n_levels >= 3, h3.n_levels
    dh64 = DistHierarchy.build(h3, N_PODS, LANES, params=BLUE_WATERS,
                               dtype=jnp.float64)
    for cycle in CYCLES:
        for sm in SMOOTHERS:
            o = SolveOptions(cycle=cycle, smoother=sm,
                             smoother_parts=N_PODS * LANES)
            rh = solve(h3, b, tol=0.0, maxiter=5, opts=o)
            rd = solve(h3, b, tol=0.0, maxiter=5, opts=o, backend="dist",
                       dist=dh64)
            hd = history_diff(rh.residuals, rd.residuals)
            assert hd < 1e-7, (cycle, sm, hd)
            assert all(rd.residuals[i + 1] < rd.residuals[i]
                       for i in range(5)), (cycle, sm, rd.residuals)
    # W/F multiply exactly the coarse-level messages (modeled counts)
    stV = cycle_comm_stats(dh64, SolveOptions(cycle="V"))
    stW = cycle_comm_stats(dh64, SolveOptions(cycle="W"))
    assert stW["coarse_inter_msgs"] == 2 * stV["coarse_inter_msgs"] > 0, \
        (stV, stW)
    print("OK cycle_smoother_parity")

    # native multi-RHS SpMM routing (the default) vs the legacy
    # vmap-over-columns trace: one batched [n, 4] cycle per (cycle,
    # smoother) pair, ≤1e-7 on the same fp64 2x4 mesh.  The heuristic must
    # have lowered at least one level to BCSR so the block path is covered.
    from repro.amg.dist_solve import dist_vcycle

    assert dh64.native_spmm, "native SpMM routing must be the default"
    assert any(r["kernel"] == "bcsr" for r in dh64.kernel_table()), \
        dh64.kernel_table()
    Bm = np.stack([b] + [np.random.default_rng(3).standard_normal(A.nrows)
                         for _ in range(3)], axis=1)
    for cycle in CYCLES:
        for sm in SMOOTHERS:
            o = SolveOptions(cycle=cycle, smoother=sm,
                             smoother_parts=N_PODS * LANES)
            xn = dist_vcycle(dh64, Bm, o)
            dh64.native_spmm = False
            xv = dist_vcycle(dh64, Bm, o)
            dh64.native_spmm = True
            nd = np.abs(xn - xv).max() / max(np.abs(xv).max(), 1e-30)
            assert nd < 1e-7, (cycle, sm, nd)
    print("OK native_spmm_parity")

    # overlapped on/off-process split vs the fused serial oracle: flipping
    # dh.overlap retraces every (cycle, smoother) pair through the split
    # A_on·x + A_off·halo path (exchange issued before the on-product);
    # ≤1e-7 agreement on the same fp64 2x4 mesh, multi-RHS batched trace
    assert dh64.overlap, "overlapped halo exchange must be the default"
    assert any(not r["halo_empty"] for r in dh64.kernel_table()), \
        "hierarchy must actually communicate somewhere"
    for cycle in CYCLES:
        for sm in SMOOTHERS:
            o = SolveOptions(cycle=cycle, smoother=sm,
                             smoother_parts=N_PODS * LANES)
            xo = dist_vcycle(dh64, Bm, o)
            dh64.overlap = False
            xs = dist_vcycle(dh64, Bm, o)
            dh64.overlap = True
            od = np.abs(xo - xs).max() / max(np.abs(xs).max(), 1e-30)
            assert od < 1e-7, (cycle, sm, od)
    print("OK overlap_parity")

    # 1-device-per-node mesh (8x1): a block-diagonal operator aligned to
    # the partition has an empty halo on every device — the lowered apply
    # must contain NO collective at all, and still match the dense product
    from repro.amg.csr import CSR
    from repro.amg.dist_spmv import build_dist_spmv
    from repro.core.topology import Partition, Topology

    nE = 96
    partE = Partition.balanced(nE, Topology(n_nodes=8, ppn=1))
    rngE = np.random.default_rng(0)
    denseE = np.zeros((nE, nE))
    for d in range(8):
        lo, hi = partE.local_range(d)
        denseE[lo:hi, lo:hi] = rngE.normal(size=(hi - lo, hi - lo))
    rE, cE = np.nonzero(denseE)
    spE = build_dist_spmv(CSR.from_coo(rE, cE, denseE[rE, cE], (nE, nE)),
                          8, 1, "standard", dtype=np.float64)
    assert spE.op.halo_empty and spE.op.onoff_nnz()["off_nnz"] == 0
    from repro.analysis import audit_jaxpr, collect_collectives

    jxp = jax.make_jaxpr(spE.fn)(jnp.zeros((8, spE.op.plan.local_n),
                                           dtype=jnp.float64))
    assert collect_collectives(jxp) == []          # structural, not substring
    assert audit_jaxpr(jxp, "apply_A",
                       expected_signature=spE.op.expected_signature).ok
    xE = rngE.normal(size=nE)
    np.testing.assert_allclose(spE.matvec(xE), denseE @ xE, rtol=0,
                               atol=1e-11)
    print("OK empty_halo")

    # comm audit on the real 2x4 mesh: every fused program of every
    # (cycle, smoother) pair plus PCG and the *_m variants lowers exactly
    # the collectives its selected strategies predict, every per-operator
    # apply matches its ordered halo signature (with the on-process
    # contraction dataflow-independent of the exchange), and the modeled
    # cycle_comm_stats counters agree with the static plans
    from repro.analysis import audit_hierarchy
    from repro.core.nap_collectives import (HALO_SIGNATURES,
                                            REDUCE_SIGNATURES)

    audits, violations = audit_hierarchy(dh64)
    assert not violations, [str(v) for v in violations]
    assert len(audits) >= 15 * 2 + 10, len(audits)
    # golden ordered signatures on the 2x4 mesh: the finest A communicates
    # with its selected strategy's exact lowering
    sigA = [a for a in audits if a.program == "apply_A" and a.level == 0]
    assert sigA and sigA[0].signature() == HALO_SIGNATURES[
        dh64.levels[0].A.strategy]
    # NAP-3 hier_psum shows up in resid_norm as RS(fast)+AR(slow)+AG(fast)
    rn = next(a for a in audits if a.program == "resid_norm")
    assert all(rn.counts.get(p, 0) >= 1
               for p in REDUCE_SIGNATURES[dh64.reduce_strategy]), rn.counts
    # injected regression: silently lowering hier_psum to a flat psum must
    # be caught as a count mismatch on a freshly built hierarchy
    import repro.amg.dist_solve as _ds
    from repro.analysis import audit_program

    orig_hier_psum = _ds.hier_psum
    _ds.hier_psum = lambda x, slow, fast, strategy="nap3": \
        jax.lax.psum(x, (slow, fast))
    try:
        dh_bad = DistHierarchy.build(h3, N_PODS, LANES, params=BLUE_WATERS,
                                     dtype=jnp.float64)
        bad = audit_program(dh_bad, "resid_norm")
        kinds = [v.kind for v in bad.violations]
        assert "count-mismatch" in kinds, (kinds, bad.counts, bad.expected)
    finally:
        _ds.hier_psum = orig_hier_psum
    print("OK comm_audit")

    # the symmetric hybrid GS sweep is an SPD preconditioner: dist PCG with
    # it converges on the 2x4 mesh and matches the host PCG history ≤1e-7
    osym = SolveOptions(smoother="hybrid_gs_sym",
                        smoother_parts=N_PODS * LANES)
    ph = pcg(h3, b, tol=1e-8, maxiter=30, opts=osym)
    pd = pcg(h3, b, tol=1e-8, maxiter=30, opts=osym, backend="dist",
             dist=dh64)
    assert ph.converged and pd.converged, (ph.iterations, pd.iterations)
    assert history_diff(ph.residuals, pd.residuals) < 1e-7
    # 2 SpMVs/sweep lands in the modeled comm counts
    assert (cycle_comm_stats(dh64, osym)["inter_msgs"]
            > cycle_comm_stats(dh64, SolveOptions(smoother="hybrid_gs"))
            ["inter_msgs"])
    print("OK hybrid_gs_sym_pcg")

    # AMGService cross-burst coalescing on the 2x4 mesh: k same-matrix
    # requests submitted in separate bursts inside one window must ride
    # ONE multi-RHS device trace and match per-request host solves ≤1e-7
    import time as _time

    from repro.amg import AMGService

    svc = AMGService(AMGConfig(backend="dist", n_pods=N_PODS, lanes=LANES,
                               machine="blue_waters", dtype="float64"),
                     max_rhs=8, coalesce_window=1.5)
    svc.register("lap", A)
    rng = np.random.default_rng(11)
    bs = [b] + [rng.standard_normal(A.nrows) for _ in range(2)]
    with svc:
        tickets = []
        for bi in bs:                       # three separate bursts
            tickets.append(svc.submit("lap", bi, method="solve", tol=0.0,
                                      maxiter=12))
            _time.sleep(0.05)
        xs = [t.result(timeout=300) for t in tickets]
    assert svc.stats["batches"] == 1, svc.stats     # ONE device trace
    assert svc.stats["batched_rhs"] == len(bs), svc.stats
    for bi, xi, t in zip(bs, xs, tickets):
        href = solve(h, bi, tol=0.0, maxiter=12)
        xd = np.linalg.norm(xi - href.x) / np.linalg.norm(href.x)
        assert xd < 1e-7, (t.rid, xd)
        assert t.diagnostics["batch_cols"] == len(bs)
    print("OK service_cross_burst_coalescing")

    # the setup_backend="dist" session (hierarchy=None, levels born
    # partitioned) drives the same W-cycle + block-Jacobi fused program
    cfg_w = AMGConfig(setup_backend="dist", backend="dist", n_pods=N_PODS,
                      lanes=LANES, machine="blue_waters", dtype="float64",
                      opts=SolveOptions(cycle="W", smoother="block_jacobi",
                                        smoother_parts=N_PODS * LANES))
    bound_w = AMGSolver(cfg_w).setup(A)
    assert bound_w.hierarchy is None
    rw = bound_w.solve(b, tol=0.0, maxiter=5)
    rh = solve(h, b, tol=0.0, maxiter=5, opts=cfg_w.opts)
    assert history_diff(rh.residuals, rw.residuals) < 1e-7
    # the overlap knob threads through the dist-setup session too: the
    # serial-oracle config reproduces the same residual history ≤1e-7
    import dataclasses

    cfg_w_ser = dataclasses.replace(cfg_w, overlap=False)
    rw_ser = AMGSolver(cfg_w_ser).setup(A).solve(b, tol=0.0, maxiter=5)
    assert history_diff(rw.residuals, rw_ser.residuals) < 1e-7
    print("OK dist_setup_cycles")

    # fp64 AMGSolver session: a [n, 4] multi-RHS dist solve batched through
    # one device trace matches 4 independent host solves to 1e-7 relative
    # residual (the PR-1 parity bar), with ONE DistHierarchy build.
    from repro.amg.api import clear_sessions

    clear_sessions()      # the service above shared this config's setup
    builds = []
    orig_build = DistHierarchy.build.__func__
    DistHierarchy.build = classmethod(
        lambda cls, *a, **k: builds.append(1) or orig_build(cls, *a, **k))
    cfg = AMGConfig(backend="dist", n_pods=N_PODS, lanes=LANES,
                    machine="blue_waters", dtype="float64")
    bound = AMGSolver(cfg).setup(A)
    rng = np.random.default_rng(7)
    B = np.stack([b] + [rng.standard_normal(A.nrows) for _ in range(3)],
                 axis=1)
    mres = bound.solve(B, tol=0.0, maxiter=12)
    assert bound.solve(b, tol=1e-5, maxiter=12).converged  # second call
    assert builds == [1], f"expected one DistHierarchy build, got {builds}"
    assert len(bound.dist_hierarchy._programs) == 1
    for j in range(B.shape[1]):
        href = solve(h, B[:, j], tol=0.0, maxiter=12)
        hd = history_diff(href.residuals, mres.columns[j].residuals)
        xd = (np.linalg.norm(mres.x[:, j] - href.x)
              / np.linalg.norm(href.x))
        assert hd < 1e-7 and xd < 1e-7, (j, hd, xd)
    DistHierarchy.build = classmethod(orig_build)
    print("OK multi_rhs")

    # streaming refresh on the fp64 2x4 mesh: bound.update(A2) keeps the
    # SAME lowered DistHierarchy (comm graphs, NAP selections, compiled
    # programs) while the refreshed PCG matches a fresh setup(A2) session
    # ≤1e-7; an injected convergence regression then triggers exactly one
    # adaptive re-setup
    from repro.amg.api import LRUPolicy, SessionStore

    store_s = SessionStore(LRUPolicy())
    cfg_s = AMGConfig(backend="dist", n_pods=N_PODS, lanes=LANES,
                      machine="blue_waters", dtype="float64", tol=1e-9)
    bound_s = AMGSolver(cfg_s, store=store_s).setup(A)
    base_its = bound_s.pcg(b).iterations
    dh_before = bound_s.dist_hierarchy
    progs_before = dict(bound_s.dist_hierarchy._programs)
    rng_s = np.random.default_rng(13)
    d2 = A.data * (1.0 + 0.02 * rng_s.random(A.nnz))
    At = CSR(A.shape, A.indptr.copy(), A.indices.copy(), d2).T
    A2 = CSR(A.shape, A.indptr.copy(), A.indices.copy(),
             0.5 * (d2 + At.data))
    assert bound_s.update(A2) == "refresh"
    assert bound_s.dist_hierarchy is dh_before
    assert all(bound_s.dist_hierarchy._programs.get(k) is v
               for k, v in progs_before.items())   # programs reused verbatim
    x_r = np.asarray(bound_s.pcg(b).x)
    clear_sessions()
    x_f = np.asarray(AMGSolver(cfg_s).setup(A2).pcg(b).x)
    rd = np.abs(x_r - x_f).max() / max(np.abs(x_f).max(), 1e-30)
    assert rd < 1e-7, rd
    assert A.data is not A2.data and bound_s._fine is not A2  # copy-on-write
    bound_s.last_iterations = 10 * base_its + 100  # inject a regression
    assert bound_s.update(A2) == "resetup"
    st_s = store_s.stats()
    assert st_s["resetups"] == 1 and st_s["refreshes"] == 1, st_s
    assert st_s["triggers"] == {"drift": 1, "regression": 1}, st_s
    assert bound_s.pcg(b).converged
    print("OK streaming_refresh")

    print("ALL_OK")


if __name__ == "__main__":
    main()
