"""Wire codec + session-store eviction policy tests.

The codec is the service's outer wall: every payload is schema-versioned,
unknown keys are rejected (a future-versioned or corrupt payload fails
loudly instead of being half-applied), CSR matrices travel with a content
fingerprint that the decoder re-verifies, and every registered backend's
config survives dict ↔ wire ↔ dict unchanged.  Schema v2 adds streaming
``update_request`` payloads and the nested ``options`` dict on solve
requests; v1 frames must keep decoding on a v2 stack (and v1 frames
carrying v2-only keys must fail strict decode).

The eviction policies are the session store's serving knobs: LRU must
reproduce the old module-global cache behavior, TTL must expire idle
entries, and the bytes-budget policy must prefer evicting sessions that
are cheap to rebuild.
"""
import base64
import json

import numpy as np
import pytest

from repro.amg.api import (AMGConfig, BytesBudgetPolicy, LRUPolicy,
                           RequestOptions, SUPPORTED_SCHEMAS, SessionStore,
                           TTLPolicy, WIRE_SCHEMA, WireError,
                           array_from_wire, array_to_wire,
                           available_backends, csr_from_wire, csr_to_wire,
                           matrix_fingerprint, solve_request_from_wire,
                           solve_request_to_wire, update_request_from_wire,
                           update_request_to_wire)
from repro.amg.csr import CSR
from repro.amg.problems import laplace_3d
from repro.amg.solve import SolveOptions


# ----------------------------------------------------------------- schema
def test_schema_version_mismatch_rejected():
    cfg = AMGConfig()
    for payload in (cfg.to_wire(), csr_to_wire(laplace_3d(4)),
                    solve_request_to_wire("m", np.ones(4))):
        bad = {**payload, "schema": WIRE_SCHEMA + 1}
        with pytest.raises(WireError, match="schema version mismatch"):
            (AMGConfig.from_wire if payload["kind"] == "amg_config" else
             csr_from_wire if payload["kind"] == "csr" else
             solve_request_from_wire)(bad)
        missing = dict(payload)
        del missing["schema"]
        with pytest.raises(WireError, match="schema version mismatch"):
            (AMGConfig.from_wire if payload["kind"] == "amg_config" else
             csr_from_wire if payload["kind"] == "csr" else
             solve_request_from_wire)(missing)


def test_wrong_kind_rejected():
    with pytest.raises(WireError, match="expected a 'csr' payload"):
        csr_from_wire(AMGConfig().to_wire())
    with pytest.raises(WireError, match="expected a 'amg_config'"):
        AMGConfig.from_wire(solve_request_to_wire("m", np.ones(3)))


# ------------------------------------------------------------ unknown keys
def test_unknown_key_rejection():
    cfg = AMGConfig()
    with pytest.raises(WireError, match="unknown key.*future_knob"):
        AMGConfig.from_wire({**cfg.to_wire(), "future_knob": 1})
    opts_payload = cfg.to_wire()
    opts_payload["opts"] = {**opts_payload["opts"], "sor_omega": 1.5}
    with pytest.raises(WireError, match="opts has unknown key.*sor_omega"):
        AMGConfig.from_wire(opts_payload)
    with pytest.raises(WireError, match="opts must be a dict"):
        AMGConfig.from_wire({**cfg.to_wire(), "opts": "jacobi"})
    with pytest.raises(WireError, match="unknown key"):
        csr_from_wire({**csr_to_wire(laplace_3d(4)), "colors": "red"})
    with pytest.raises(WireError, match="unknown key"):
        solve_request_from_wire({**solve_request_to_wire("m", np.ones(3)),
                                 "retries": 3})
    with pytest.raises(WireError, match="unknown key"):
        array_from_wire({**array_to_wire(np.ones(3)), "stride": 8})


# ------------------------------------------------------------- csr payloads
def _assert_csr_equal(A, B):
    assert A.shape == B.shape
    np.testing.assert_array_equal(A.indptr, B.indptr)
    np.testing.assert_array_equal(A.indices, B.indices)
    np.testing.assert_array_equal(A.data, B.data)


def test_csr_round_trip_through_json():
    A = laplace_3d(5)
    payload = json.loads(json.dumps(csr_to_wire(A)))   # a real byte hop
    B, fp = csr_from_wire(payload)
    _assert_csr_equal(A, B)
    assert fp == matrix_fingerprint(A) == payload["fingerprint"]


def test_csr_round_trip_empty_and_non_square():
    empty = CSR.from_coo([], [], [], (5, 5))
    B, _ = csr_from_wire(csr_to_wire(empty))
    _assert_csr_equal(empty, B)
    assert B.nnz == 0
    rect = CSR.from_coo([0, 1, 2], [6, 0, 3], [1.0, -2.0, 0.5], (3, 7))
    B, _ = csr_from_wire(json.loads(json.dumps(csr_to_wire(rect))))
    _assert_csr_equal(rect, B)
    assert B.shape == (3, 7)


def test_csr_fp32_payload_rounds_values_and_fingerprints_decoded_form():
    A = laplace_3d(4)
    A.data[:] *= 1 + 1e-12          # not exactly representable in fp32
    payload = csr_to_wire(A, dtype="float32")
    B, fp = csr_from_wire(payload)
    np.testing.assert_array_equal(B.data,
                                  A.data.astype(np.float32).astype(np.float64))
    # fingerprint is of what the receiver decodes, not the sender's fp64 form
    assert fp == payload["fingerprint"] == matrix_fingerprint(B)
    assert fp != matrix_fingerprint(A)
    # and the fp32 payload is about half the bytes of the fp64 one
    assert (len(payload["data"]["data"])
            < 0.6 * len(csr_to_wire(A)["data"]["data"]))


def test_csr_corruption_detected():
    payload = csr_to_wire(laplace_3d(4))
    tampered = json.loads(json.dumps(payload))
    raw = np.frombuffer(base64.b64decode(tampered["data"]["data"]),
                        dtype="<f8").copy()
    raw[0] += 1.0
    tampered["data"]["data"] = base64.b64encode(raw.tobytes()).decode()
    with pytest.raises(WireError, match="fingerprint mismatch"):
        csr_from_wire(tampered)
    broken = json.loads(json.dumps(payload))
    broken["indices"]["data"] = "!!!not-base64!!!"
    with pytest.raises(WireError):
        csr_from_wire(broken)


# ----------------------------------------------------------------- configs
def test_config_wire_identity_for_every_registered_backend():
    """dict -> wire -> dict identity for each backend the registry knows."""
    assert {"host", "dist"} <= set(available_backends())
    for name in available_backends():
        cfg = AMGConfig(backend=name, n_pods=2, lanes=4, theta=0.2,
                        machine="blue_waters", dtype="float64",
                        opts=SolveOptions(cycle="W", smoother="hybrid_gs_sym"))
        payload = json.loads(json.dumps(cfg.to_wire()))
        back = AMGConfig.from_wire(payload)
        assert back == cfg
        assert back.to_dict() == cfg.to_dict()
        assert back.to_wire() == cfg.to_wire()


def test_config_wire_rejects_invalid_values():
    bad = AMGConfig().to_wire()
    bad["dtype"] = "float16"
    with pytest.raises(WireError, match="rejected"):
        AMGConfig.from_wire(bad)


# ---------------------------------------------------------- solve requests
def test_solve_request_round_trip():
    b = np.linspace(0, 1, 12).reshape(6, 2)
    x0 = np.zeros((6, 2))
    payload = json.loads(json.dumps(solve_request_to_wire(
        "abc123", b, method="pcg", tol=1e-5, maxiter=17, x0=x0,
        priority="interactive", rid=9)))
    kw = solve_request_from_wire(payload)
    assert kw["matrix_id"] == "abc123"
    o = kw["options"]
    assert isinstance(o, RequestOptions)
    assert o.method == "pcg" and o.tol == 1e-5 and o.maxiter == 17
    assert kw["rid"] == 9 and kw["priority"] == "interactive"
    np.testing.assert_array_equal(kw["b"], b)
    np.testing.assert_array_equal(o.x0, x0)
    # optional fields stay absent (RequestOptions.resolve applies the
    # service config's defaults later)
    lean = solve_request_from_wire(solve_request_to_wire("m", b[:, 0]))
    assert set(lean) == {"matrix_id", "b", "options"}
    assert lean["options"].tol is None and lean["options"].maxiter is None


def test_solve_request_options_object_round_trips():
    b = np.ones(5)
    opts = RequestOptions(method="pcg", tol=1e-4, maxiter=11)
    payload = json.loads(json.dumps(solve_request_to_wire(
        "m", b, options=opts)))
    kw = solve_request_from_wire(payload)
    back = kw["options"]
    assert (back.method, back.tol, back.maxiter) == ("pcg", 1e-4, 11)
    with pytest.raises(ValueError, match="not both"):
        solve_request_to_wire("m", b, options=opts, tol=1e-3)


def test_v1_solve_request_still_decodes():
    """A v1 frame (flat knob fields, schema tag 1) must decode on the v2
    stack; a v1 frame smuggling the v2-only nested options dict must not
    (strict mode)."""
    assert set(SUPPORTED_SCHEMAS) == {1, 2} and WIRE_SCHEMA == 2
    b = np.linspace(0, 1, 6)
    payload = json.loads(json.dumps(solve_request_to_wire(
        "m", b, method="pcg", tol=1e-5, maxiter=9)))
    v1 = {**payload, "schema": 1}
    kw = solve_request_from_wire(v1)
    o = kw["options"]
    assert (o.method, o.tol, o.maxiter) == ("pcg", 1e-5, 9)
    np.testing.assert_array_equal(kw["b"], b)
    # additive v2 key on a v1-tagged frame: rejected strict, tolerated lax
    v1_plus = {**v1, "options": {"method": "solve"}}
    with pytest.raises(WireError, match="v2-only"):
        solve_request_from_wire(v1_plus)
    lax = solve_request_from_wire(v1_plus, strict=False)
    assert lax["options"].method == "solve"


# --------------------------------------------------------- update requests
def test_update_request_round_trip_all_forms():
    A = laplace_3d(4)
    # full-CSR form
    kw = update_request_from_wire(json.loads(json.dumps(
        update_request_to_wire("mid", A, rid=3))))
    assert kw["matrix_id"] == "mid" and kw["rid"] == 3
    _assert_csr_equal(kw["A"], A)
    # values-on-pattern form
    vals = A.data * 1.5
    kw = update_request_from_wire(json.loads(json.dumps(
        update_request_to_wire("mid", data=vals))))
    np.testing.assert_array_equal(kw["data"], vals)
    assert "A" not in kw and "delta" not in kw
    # additive-delta form
    kw = update_request_from_wire(json.loads(json.dumps(
        update_request_to_wire("mid", delta=0.1 * vals))))
    np.testing.assert_allclose(kw["delta"], 0.1 * vals)
    # exactly one form, encoder side
    with pytest.raises(ValueError, match="exactly one"):
        update_request_to_wire("mid", A, data=vals)
    with pytest.raises(ValueError, match="exactly one"):
        update_request_to_wire("mid")


def test_update_request_is_v2_only_and_strict():
    A = laplace_3d(4)
    payload = json.loads(json.dumps(update_request_to_wire("mid", A)))
    assert payload["schema"] == 2
    with pytest.raises(WireError, match="schema"):
        update_request_from_wire({**payload, "schema": 1})
    with pytest.raises(WireError, match="unknown key"):
        update_request_from_wire({**payload, "hint": "fast"})
    both = dict(payload)
    both["data"] = array_to_wire(A.data)
    with pytest.raises(WireError, match="exactly one"):
        update_request_from_wire(both)


# ----------------------------------------------- framed envelope (serve.wire)
def test_envelope_accepts_every_supported_schema():
    from repro.serve.wire import check_request_envelope, hello_frame
    for schema in SUPPORTED_SCHEMAS:
        assert check_request_envelope(
            {"schema": schema, "kind": "solve", "seq": 0}) == "solve"
    # the update kind is v2-only at the envelope level too
    assert check_request_envelope(
        {"schema": 2, "kind": "update", "seq": 0}) == "update"
    with pytest.raises(WireError, match="needs schema >= 2"):
        check_request_envelope({"schema": 1, "kind": "update", "seq": 0})
    with pytest.raises(WireError, match="schema version mismatch"):
        check_request_envelope({"schema": WIRE_SCHEMA + 1, "kind": "solve"})
    hello = hello_frame(["alpha"])
    assert hello["kind"] == "hello" and hello["seq"] is None
    assert hello["supported_schemas"] == list(SUPPORTED_SCHEMAS)
    assert hello["tenants"] == ["alpha"]


# ------------------------------------------------------- eviction policies
class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_lru_policy_matches_old_cache_behavior():
    """16-entry default, oldest-unused first, gets refresh recency — the
    module-global cache contract the store replaced."""
    store = SessionStore(LRUPolicy(16))
    for i in range(16):
        store.put(i, f"v{i}")
    assert len(store) == 16
    assert store.get(0) == "v0"          # refresh 0's recency
    store.put(16, "v16")                 # evicts 1, the LRU entry
    assert len(store) == 16
    assert 1 not in store and 0 in store and 16 in store
    st = store.stats()
    assert st["evictions"] == 1 and st["policy"] == "lru"
    assert st["hits"] == 1 and st["misses"] == 0


def test_ttl_policy_expires_idle_entries():
    clock = FakeClock()
    store = SessionStore(TTLPolicy(ttl=10.0), clock=clock)
    store.put("a", 1)
    clock.t = 5.0
    assert store.get("a") == 1           # touched at t=5 -> fresh until 15
    clock.t = 14.0
    assert store.get("a") == 1
    clock.t = 25.0
    assert store.get("a") is None        # idle 11s > ttl
    st = store.stats()
    assert st["expirations"] == 1 and st["entries"] == 0
    assert st["misses"] == 1 and st["hits"] == 2


def test_bytes_budget_prefers_cheap_to_rebuild():
    """Same-size entries: the low-setup-cost (cheap to rebuild) session is
    evicted first; hit counts raise retention."""
    store = SessionStore(BytesBudgetPolicy(max_bytes=300))
    store.put("expensive", "E", nbytes=100, setup_cost=10.0)
    store.put("cheap", "C", nbytes=100, setup_cost=0.1)
    store.put("mid", "M", nbytes=100, setup_cost=1.0)
    assert len(store) == 3               # exactly at budget
    store.put("new", "N", nbytes=100, setup_cost=1.0)   # 400 > 300
    assert "cheap" not in store          # lowest setup_cost went first
    assert "expensive" in store and "mid" in store
    st = store.stats()
    assert st["evictions"] == 1
    assert st["setup_cost_evicted"] == pytest.approx(0.1)
    # hits buy retention: heavily-hit cheap entry outlives an unhit one
    store2 = SessionStore(BytesBudgetPolicy(max_bytes=200))
    store2.put("hot_cheap", 1, nbytes=100, setup_cost=0.1)
    store2.put("cold_mid", 2, nbytes=100, setup_cost=0.5)
    for _ in range(20):                  # 0.1 * 21 > 0.5 * 1
        store2.get("hot_cheap")
    store2.put("new", 3, nbytes=100, setup_cost=1.0)
    assert "hot_cheap" in store2 and "cold_mid" not in store2


def test_bytes_budget_eviction_order_is_retention_ranked():
    """Multiple evictions in one put drop entries in ascending retention
    value order until the budget holds."""
    store = SessionStore(BytesBudgetPolicy(max_bytes=300))
    store.put("a", 1, nbytes=100, setup_cost=5.0)
    store.put("b", 2, nbytes=100, setup_cost=0.2)
    store.put("c", 3, nbytes=100, setup_cost=0.4)
    store.put("big", 4, nbytes=200, setup_cost=100.0)   # 500 resident
    # b (0.002/B) then c (0.004/B) go; "big" (0.5/B) and "a" (0.05/B) stay
    assert "b" not in store and "c" not in store
    assert "a" in store and "big" in store
    assert store.stats()["bytes"] == 300
    # entry accounting surfaces per-entry cost/hits for reports
    table = {row["key"]: row for row in store.entry_table()}
    assert table["big"]["setup_cost"] == 100.0
    assert table["a"]["nbytes"] == 100
