"""AMGService tests: ticketed admission, coalescing, per-request knobs,
priority scheduling, wire-only operation, and session-store accounting.

The 8-device cross-burst coalescing + 1e-7 parity acceptance check runs in
the ``dist_solve_script.py`` subprocess; everything here stays on this
process (host backend / 1x1 mesh) where it can be deterministic.
"""
import json
import time

import numpy as np
import pytest

from repro.amg import AMGConfig, AMGService, SolveOptions
from repro.amg.api import (BytesBudgetPolicy, SessionStore, clear_sessions,
                           csr_to_wire, solve_request_to_wire)
from repro.amg.api.service import _Group, _Pending
from repro.amg.problems import laplace_3d


@pytest.fixture(autouse=True)
def _fresh_sessions():
    clear_sessions()
    yield
    clear_sessions()


@pytest.fixture(scope="module")
def problem():
    A = laplace_3d(6)
    b = A.matvec(np.ones(A.nrows))
    return A, b


def _service(config=None, **kw):
    svc = AMGService(config or AMGConfig(), **kw)
    return svc


# ------------------------------------------------------------- admission
def test_submit_validation(problem):
    A, b = problem
    svc = _service()
    svc.register("m", A)
    with pytest.raises(KeyError, match="unknown matrix_id"):
        svc.submit("nope", b)
    with pytest.raises(ValueError, match="unknown method"):
        svc.submit("m", b, method="gmres")
    with pytest.raises(ValueError, match="b must be"):
        svc.submit("m", b[:-1])
    with pytest.raises(ValueError, match="x0 must match"):
        svc.submit("m", b, x0=np.zeros(3))
    with pytest.raises(ValueError, match="unknown priority class"):
        svc.submit("m", b, priority="urgent")


def test_ticket_requires_worker_or_drain(problem):
    A, b = problem
    svc = _service()
    svc.register("m", A)
    t = svc.submit("m", b)
    assert not t.done()
    with pytest.raises(RuntimeError, match="drain"):
        t.result(timeout=0.1)
    out = svc.drain()
    assert t.done()
    np.testing.assert_array_equal(t.result(), out[t.rid])


def test_drain_groups_by_compatible_knobs(problem):
    """Same (matrix, method, tol, maxiter) coalesces into one trace;
    a request with its own tol gets its own group/batch."""
    A, b = problem
    rng = np.random.default_rng(0)
    svc = _service(max_rhs=8)
    svc.register("m", A)
    for _ in range(3):
        svc.submit("m", rng.standard_normal(A.nrows), method="pcg")
    loose = svc.submit("m", rng.standard_normal(A.nrows), method="pcg",
                       tol=1e-3)
    out = svc.drain()
    assert len(out) == 4
    assert svc.stats["batches"] == 2            # 3-wide trace + loner
    assert svc.stats["batched_rhs"] == 3
    assert loose.diagnostics["batch_cols"] == 1
    # per-request tol honored: the loose request converged in fewer iters
    tight_iters = max(svc.diagnostics[r]["iterations"]
                      for r in out if r != loose.rid)
    assert loose.diagnostics["iterations"] < tight_iters


def test_per_request_maxiter_and_x0_warm_start(problem):
    A, b = problem
    svc = _service()
    svc.register("m", A)
    capped = svc.submit("m", b, method="solve", tol=1e-14, maxiter=3)
    svc.drain()
    assert capped.diagnostics["iterations"] == 3
    assert not capped.diagnostics["converged"]
    assert svc.stats["unconverged"] == 1
    # x0 at the solution: zero iterations
    ref = svc.submit("m", b, method="pcg")
    svc.drain()
    warm = svc.submit("m", b, method="pcg", x0=ref.result())
    svc.drain()
    assert warm.diagnostics["iterations"] == 0
    assert warm.diagnostics["converged"]


def test_multi_rhs_payload_and_mixed_batch(problem):
    """[n, k] payloads ride the same trace as [n] requests; each request
    gets back its own columns."""
    A, b = problem
    rng = np.random.default_rng(1)
    B = np.stack([rng.standard_normal(A.nrows) for _ in range(2)], axis=1)
    svc = _service(max_rhs=8)
    svc.register("m", A)
    t_multi = svc.submit("m", B, method="pcg")
    t_single = svc.submit("m", b, method="pcg")
    svc.drain()
    assert svc.stats["batches"] == 1
    assert svc.stats["batched_rhs"] == 3
    assert t_multi.result().shape == B.shape
    assert t_single.result().shape == b.shape
    for j in range(2):
        rel = (np.linalg.norm(B[:, j] - A.matvec(t_multi.result()[:, j]))
               / np.linalg.norm(B[:, j]))
        assert rel < 1e-6
    rel = np.linalg.norm(b - A.matvec(t_single.result())) / np.linalg.norm(b)
    assert rel < 1e-6


def test_max_rhs_chunks_columns(problem):
    A, _ = problem
    rng = np.random.default_rng(2)
    svc = _service(max_rhs=2)
    svc.register("m", A)
    for _ in range(5):
        svc.submit("m", rng.standard_normal(A.nrows))
    svc.drain()
    assert svc.stats["batches"] == 3               # 2 + 2 + 1
    assert svc.stats["batched_rhs"] == 4


# ------------------------------------------------------------- scheduling
def test_priority_classes_order_drain(problem):
    A, _ = problem
    rng = np.random.default_rng(3)
    svc = _service()
    svc.register("m", A)
    batch = svc.submit("m", rng.standard_normal(A.nrows), priority="batch")
    inter = svc.submit("m", rng.standard_normal(A.nrows), tol=1e-7,
                       priority="interactive")
    svc.drain()
    assert inter.diagnostics["batch"] < batch.diagnostics["batch"]


def test_priority_aging_prevents_starvation():
    """A long-waiting batch group outranks a fresh interactive group once
    it has aged past the priority gap (pure scheduler-order check)."""
    svc = _service(priority_aging=0.5)
    old_batch = _Group(("m", "solve", 0.0, 1), created=0.0)
    old_batch.requests.append(_Pending(0, np.ones(2), None, 2, 0.0, None))
    fresh_inter = _Group(("m", "pcg", 0.0, 1), created=10.0)
    fresh_inter.requests.append(_Pending(1, np.ones(2), None, 0, 10.0, None))
    # shortly after arrival the interactive group wins...
    assert (svc._order_key(fresh_inter, 10.1)
            < svc._order_key(old_batch, 10.1 - 10.0 + 0.9))
    # ...but the batch group aged 10s has been promoted past it
    assert svc._order_key(old_batch, 10.1) < svc._order_key(fresh_inter, 10.1)


def test_worker_coalesces_across_bursts(problem):
    """Threaded mode: requests submitted in separate bursts inside one
    window ride ONE multi-RHS trace (host-backend half of acceptance (b);
    the 2x4-mesh fp64 version runs in dist_solve_script.py)."""
    A, _ = problem
    rng = np.random.default_rng(4)
    svc = _service(max_rhs=8, coalesce_window=1.0)
    svc.register("m", A)
    bs = [rng.standard_normal(A.nrows) for _ in range(3)]
    with svc:
        tickets = []
        for bi in bs:
            tickets.append(svc.submit("m", bi, method="pcg"))
            time.sleep(0.02)
        xs = [t.result(timeout=60) for t in tickets]
    assert svc.stats["batches"] == 1
    assert svc.stats["batched_rhs"] == 3
    for bi, xi in zip(bs, xs):
        rel = np.linalg.norm(bi - A.matvec(xi)) / np.linalg.norm(bi)
        assert rel < 1e-6
    with pytest.raises(RuntimeError, match="drain"):
        with svc:
            svc.drain()


def test_worker_close_flushes_queue(problem):
    A, _ = problem
    svc = _service(coalesce_window=30.0)       # window far beyond the test
    svc.register("m", A)
    svc.start()
    t = svc.submit("m", np.ones(A.nrows))
    svc.close()                                # flush ignores the window
    assert t.done()
    assert svc.stats["batches"] == 1


def test_close_fails_queued_tickets_with_service_closed(problem):
    """close(flush=False) must not leave never-executed tickets hanging:
    they fail immediately with the typed ServiceClosed, are counted as
    errors, and leave a diagnostics record."""
    from repro.amg.api import ServiceClosed

    A, _ = problem
    svc = _service(coalesce_window=60.0)
    svc.register("m", A)
    svc.start()
    tickets = [svc.submit("m", np.ones(A.nrows), rid=r) for r in (7, 8)]
    svc.close(flush=False)                     # abandon the queue
    for t in tickets:
        assert t.done()
        assert isinstance(t.exception(), ServiceClosed)
        with pytest.raises(ServiceClosed):
            t.result(timeout=0)
    assert svc.stats["errors"] == 2
    assert svc.stats["batches"] == 0           # nothing executed
    assert "ServiceClosed" in svc.diagnostics[7]["error"]
    # a worker-less service behaves the same (nothing to join, queue
    # still failed typed instead of the old result() timeout hang)
    svc2 = _service()
    svc2.register("m", A)
    t = svc2.submit("m", np.ones(A.nrows))
    svc2.close(flush=False)
    assert isinstance(t.exception(), ServiceClosed)


def test_ticket_done_callbacks_fire_once_each(problem):
    """add_done_callback runs on completion (scheduler thread) or
    immediately when the ticket is already done — the hook the async
    front-end's awaitable adapter bridges on."""
    A, _ = problem
    svc = _service()
    svc.register("m", A)
    seen = []
    t = svc.submit("m", np.ones(A.nrows))
    t.add_done_callback(lambda tk: seen.append(("pre", tk.done())))
    svc.drain()
    assert seen == [("pre", True)]
    t.add_done_callback(lambda tk: seen.append(("post", tk.done())))
    assert seen == [("pre", True), ("post", True)]


def test_matrix_registry_is_bounded(problem):
    """The matrix registry reuses the store eviction machinery: LRU by
    count (max_matrices), or the cost-aware bytes budget — a long-lived
    service cannot grow its registration table without limit."""
    mats = {f"m{i}": laplace_3d(4 + i) for i in range(3)}
    svc = _service(max_matrices=2)
    for mid, M in mats.items():
        svc.register(mid, M)
    assert sorted(svc._matrices.keys()) == ["m1", "m2"]   # m0 evicted LRU
    with pytest.raises(KeyError) as ei:
        svc.submit("m0", np.ones(mats["m0"].nrows))
    assert "m1" in str(ei.value)               # message lists registered ids
    rep = svc.report()
    assert rep.matrices["entries"] == 2
    assert rep.matrices["evictions"] == 1
    assert rep.matrices["bytes"] > 0
    assert "matrices[lru]" in rep.summary()
    # bytes budget variant: the registry sheds down to the budget
    one = svc._matrices.stats()["bytes"] // 2  # fits ~1 of the 2 resident
    svc2 = _service(max_matrix_bytes=int(one * 1.4))
    for mid, M in mats.items():
        svc2.register(mid, M)
    st = svc2._matrices.stats()
    assert st["policy"] == "bytes_budget"
    assert st["bytes"] <= int(one * 1.4)
    assert st["evictions"] >= 1


# ------------------------------------------------------------------- wire
def test_wire_only_operation(problem):
    """Register + solve purely through encoded payloads (host half of
    acceptance (a)): matrices by fingerprint, requests by wire dict, every
    payload passed through an actual json byte hop."""
    A, b = problem
    svc = _service(AMGConfig(tol=1e-8))
    mid = svc.register_wire(json.loads(json.dumps(csr_to_wire(A))))
    rng = np.random.default_rng(5)
    bs = [b] + [rng.standard_normal(A.nrows) for _ in range(2)]
    tickets = [svc.submit_wire(json.loads(json.dumps(
        solve_request_to_wire(mid, bi, method="pcg")))) for bi in bs]
    svc.drain()
    assert svc.stats["wire_requests"] == 3
    assert svc.stats["batches"] == 1               # same-key wire reqs batch
    for bi, t in zip(bs, tickets):
        rel = (np.linalg.norm(bi - A.matvec(t.result()))
               / np.linalg.norm(bi))
        assert rel < 1e-6
    # re-registering the same matrix is idempotent (same fingerprint id)
    assert svc.register_wire(csr_to_wire(A)) == mid


# ------------------------------------------------------------- accounting
def test_store_accounting_hits_evictions_setup_cost(problem):
    """Acceptance (c): store.stats() hit/evict/setup-cost counters through
    real service traffic, with bytes-budget eviction."""
    A, b = problem
    A2 = laplace_3d(5)
    store = SessionStore(BytesBudgetPolicy(max_bytes=1))   # evict eagerly
    svc = _service(AMGConfig(), store=store)
    svc.register("m1", A)
    svc.register("m2", A2)
    svc.submit("m1", b)
    svc.drain()
    st = store.stats()
    assert st["misses"] == 1 and st["puts"] == 1
    assert st["evictions"] == 1                  # budget 1 byte: evicted
    assert st["setup_cost_evicted"] > 0          # real measured seconds
    assert svc.stats["setups"] == 1
    svc.submit("m1", b)                          # must re-setup after evict
    svc.drain()
    assert store.stats()["misses"] == 2
    assert svc.stats["setups"] == 2
    # a roomy store: second drain hits, third matrix counts its own setup
    store2 = SessionStore()
    svc2 = _service(AMGConfig(), store=store2)
    svc2.register("m1", A)
    svc2.register("m2", A2)
    svc2.submit("m1", b)
    svc2.drain()
    svc2.submit("m1", b)
    svc2.submit("m2", np.ones(A2.nrows))
    svc2.drain()
    st2 = store2.stats()
    assert st2["hits"] == 1 and st2["misses"] == 2
    assert st2["entries"] == 2 and st2["evictions"] == 0
    assert st2["bytes"] > 0 and st2["setup_cost_total"] > 0
    assert svc2.stats["setups"] == 2
    rep = svc2.report()
    assert rep.store["hits"] == 1
    assert set(rep.per_request) == set(svc2.diagnostics)
    assert "store[" in rep.summary()


def test_submit_copies_request_buffers(problem):
    """submit() returns before the solve runs — a caller reusing its
    buffer must not corrupt the queued request."""
    A, b = problem
    svc = _service()
    svc.register("m", A)
    buf = b.copy()
    t1 = svc.submit("m", buf, method="pcg")
    buf[:] = 0.0                             # reuse before the drain
    t2 = svc.submit("m", buf + 1.0, method="pcg")
    svc.drain()
    rel = np.linalg.norm(b - A.matvec(t1.result())) / np.linalg.norm(b)
    assert rel < 1e-6                        # solved the ORIGINAL b
    assert t2.diagnostics["converged"]


def test_diagnostics_history_is_bounded(problem):
    A, b = problem
    svc = _service(AMGConfig(tol=1e-2, maxiter=2), diagnostics_limit=3)
    svc.register("m", A)
    for _ in range(5):
        svc.submit("m", b)
        svc.drain()
    assert len(svc.diagnostics) == 3         # only the newest survive
    assert svc.stats["requests"] == 5


def test_bytes_accounting_sees_lazy_dist_lowering(problem):
    """A dist session lowers its device arrays on first solve — the store
    must see the grown footprint, not the at-put host-hierarchy bytes."""
    A, b = problem
    store = SessionStore()
    cfg = AMGConfig(backend="dist", n_pods=1, lanes=1, strategy="standard",
                    tol=1e-4)
    svc = _service(cfg, store=store)
    svc.register("m", A)
    bound = svc.bound_for("m")
    before = store.stats()["bytes"]
    svc.submit("m", b, method="pcg")
    svc.drain()                              # first solve lowers the arrays
    assert bound._dist is not None
    assert store.stats()["bytes"] > before


def test_error_lands_on_ticket(problem, monkeypatch):
    A, b = problem
    svc = _service()
    svc.register("m", A)
    t = svc.submit("m", b)
    monkeypatch.setattr(svc.solver, "setup",
                        lambda *a, **k: (_ for _ in ()).throw(
                            RuntimeError("device fell over")))
    out = svc.drain()
    assert out == {} and svc.stats["errors"] == 1
    assert t.done()
    with pytest.raises(RuntimeError, match="device fell over"):
        t.result()
    assert "error" in svc.diagnostics[t.rid]


def test_dist_backend_through_service(problem):
    """The service drives the dist backend (1x1 mesh) and stages b once in
    the session's staging dtype."""
    A, b = problem
    cfg = AMGConfig(backend="dist", n_pods=1, lanes=1, strategy="standard",
                    tol=1e-5, opts=SolveOptions(smoother="hybrid_gs_sym"))
    svc = _service(cfg)
    svc.register("m", A)
    t = svc.submit("m", b, method="pcg")
    svc.drain()
    assert t.diagnostics["converged"]
    rel = np.linalg.norm(b - A.matvec(t.result())) / np.linalg.norm(b)
    assert rel < 1e-4
    bound = svc.bound_for("m")
    assert bound.staging_dtype() == np.float32      # fp32 session
    assert bound._check_b(b).dtype == np.float32
    staged = bound._check_b(b.astype(np.float32))
    assert staged.dtype == np.float32               # converted exactly once
