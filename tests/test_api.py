"""Session API tests: AMGConfig hashability/round-trip, the backend
registry, session caching, build-once dist solving, multi-RHS parity, pcg
x0 symmetry, and the AMGService synchronous drain surface.

Multi-device fp64 multi-RHS parity runs in the dist_solve subprocess script
(`dist_solve_script.py`); everything here stays on this process's single
CPU device (1x1 mesh for dist paths).
"""
import dataclasses

import numpy as np
import pytest

from repro.amg import (AMGConfig, AMGService, AMGSolver, MultiSolveResult,
                      SolveOptions, available_backends, pcg, setup, solve,
                      vcycle)
from repro.amg.api import clear_sessions, matrix_fingerprint, session_count
from repro.amg.problems import laplace_3d


@pytest.fixture(autouse=True)
def _fresh_sessions():
    clear_sessions()
    yield
    clear_sessions()


@pytest.fixture(scope="module")
def problem():
    A = laplace_3d(8)
    b = A.matvec(np.ones(A.nrows))
    return A, b


# ------------------------------------------------------------------ config
def test_config_is_hashable_and_round_trips():
    cfg = AMGConfig(solver="sa", theta=0.1, backend="dist", n_pods=2,
                    lanes=4, opts=SolveOptions(smoother="chebyshev"),
                    machine="blue_waters", dtype="float64")
    assert isinstance(hash(cfg), int)
    d = {cfg: 1}                                   # usable as a dict key
    assert d[AMGConfig.from_dict(cfg.to_dict())] == 1
    assert AMGConfig.from_dict(cfg.to_dict()) == cfg
    assert cfg.replace(n_pods=4) != cfg
    assert cfg.replace(n_pods=4).lanes == 4


def test_config_validates_machine_and_dtype():
    with pytest.raises(ValueError):
        AMGConfig(machine="cray_xk7")
    with pytest.raises(ValueError):
        AMGConfig(dtype="float16")


def test_solve_options_frozen():
    opts = SolveOptions()
    with pytest.raises(dataclasses.FrozenInstanceError):
        opts.omega = 1.0


# ---------------------------------------------------------------- registry
def test_unknown_backend_errors_name_the_registry(problem):
    A, b = problem
    with pytest.raises(ValueError, match="registered backends"):
        AMGSolver(AMGConfig(backend="quantum"))
    h = setup(A)
    with pytest.raises(ValueError, match="registered backends"):
        solve(h, b, backend="quantum")
    assert {"host", "dist"} <= set(available_backends())


# ----------------------------------------------------------- session cache
def test_session_cache_per_matrix_and_config(problem):
    A, b = problem
    cfg = AMGConfig()
    bound = AMGSolver(cfg).setup(A)
    assert AMGSolver(cfg).setup(A) is bound        # same matrix + config
    assert session_count() == 1
    other = AMGSolver(cfg.replace(theta=0.5)).setup(A)
    assert other is not bound                      # config is half the key
    assert other.hierarchy is not bound.hierarchy  # theta changes the setup
    A2 = laplace_3d(6)
    assert AMGSolver(cfg).setup(A2) is not bound   # matrix is the other half
    assert session_count() == 3
    assert matrix_fingerprint(A) != matrix_fingerprint(A2)
    # configs differing only in solve-phase knobs get their own bound (their
    # own defaults) but share ONE expensive hierarchy setup
    loose = AMGSolver(cfg.replace(tol=1e-4, maxiter=7)).setup(A)
    assert loose is not bound and loose.hierarchy is bound.hierarchy
    assert loose.solve(b).iterations <= 7


# ---------------------------------------------------- dist builds/compiles
def test_dist_bound_builds_and_compiles_once(problem, monkeypatch):
    """Acceptance: two consecutive bound.solve() calls with backend="dist"
    build the DistHierarchy and compile its programs exactly once."""
    import repro.amg.dist_solve as ds
    A, b = problem
    builds = []
    orig = ds.DistHierarchy.build.__func__
    monkeypatch.setattr(
        ds.DistHierarchy, "build",
        classmethod(lambda cls, *a, **k: builds.append(1) or orig(cls, *a, **k)))
    cfg = AMGConfig(backend="dist", n_pods=1, lanes=1, strategy="standard")
    bound = AMGSolver(cfg).setup(A)
    r1 = bound.solve(b, tol=1e-5, maxiter=20)
    r2 = bound.solve(b, tol=1e-5, maxiter=20)
    assert r1.converged and r2.converged
    assert len(builds) == 1                        # lowered exactly once
    assert len(bound.dist_hierarchy._programs) == 1  # one compiled program set
    np.testing.assert_allclose(r1.x, r2.x)


def test_ensure_dist_kwargs_dict_hits_cache(problem, monkeypatch):
    """Regression: repeated solve(..., dist={kwargs}) calls reuse ONE
    DistHierarchy instead of rebuilding it each call."""
    import repro.amg.dist_solve as ds
    A, b = problem
    h = setup(A)
    builds = []
    orig = ds.DistHierarchy.build.__func__
    monkeypatch.setattr(
        ds.DistHierarchy, "build",
        classmethod(lambda cls, *a, **k: builds.append(1) or orig(cls, *a, **k)))
    kw = {"n_pods": 1, "lanes": 1, "strategy": "standard"}
    solve(h, b, tol=1e-5, maxiter=5, backend="dist", dist=dict(kw))
    solve(h, b, tol=1e-5, maxiter=5, backend="dist", dist=dict(kw))
    pcg(h, b, tol=1e-5, maxiter=5, backend="dist", dist=dict(kw))
    assert len(builds) == 1
    assert len(h.dist_cache) == 1
    dh = next(iter(h.dist_cache.values()))
    # a different kwargs dict is a different lowering
    solve(h, b, tol=1e-5, maxiter=5, backend="dist",
          dist={**kw, "strategy": "nap3"})
    assert len(builds) == 2 and len(h.dist_cache) == 2
    assert next(iter(h.dist_cache.values())) is dh


# ---------------------------------------------------------------- multi-RHS
def test_host_multi_rhs_matches_independent_solves(problem):
    A, b = problem
    rng = np.random.default_rng(3)
    B = np.stack([b, rng.standard_normal(A.nrows),
                  rng.standard_normal(A.nrows)], axis=1)
    bound = AMGSolver(AMGConfig()).setup(A)
    mres = bound.solve(B)
    assert isinstance(mres, MultiSolveResult)
    assert mres.x.shape == B.shape and mres.n_rhs == 3
    for j in range(3):
        ref = bound.solve(B[:, j])
        np.testing.assert_allclose(mres.x[:, j], ref.x)
        assert mres.columns[j].iterations == ref.iterations
    # free-function wrapper returns the same thing
    wres = solve(setup(A), B)
    np.testing.assert_allclose(wres.x, mres.x)


def test_dist_multi_rhs_parity_single_device(problem):
    """fp32 1x1-mesh parity of the batched dist solve against per-column
    host solves (the tight fp64 multi-device check lives in
    dist_solve_script.py)."""
    A, b = problem
    rng = np.random.default_rng(5)
    B = np.stack([b, rng.standard_normal(A.nrows)], axis=1)
    h = setup(A)
    cfg = AMGConfig(backend="dist", n_pods=1, lanes=1, strategy="standard")
    bound = AMGSolver(cfg).setup(A)
    mres = bound.solve(B, tol=0.0, maxiter=10)
    for j in range(B.shape[1]):
        ref = solve(h, B[:, j], tol=0.0, maxiter=10)
        r0 = ref.residuals[0]
        for a, c in zip(ref.residuals, mres.columns[j].residuals):
            assert abs(a - c) / r0 < 2e-4
    # per-column iterations match host semantics: the count at which each
    # column first converged, not the batch-wide cycle count
    msol = bound.solve(B, tol=1e-5, maxiter=50)
    for j in range(B.shape[1]):
        ref = solve(h, B[:, j], tol=1e-5, maxiter=50)
        assert abs(msol.columns[j].iterations - ref.iterations) <= 1
        assert len(msol.columns[j].residuals) == \
            msol.columns[j].iterations + 1
    # batched pcg drives every column to convergence
    pres = bound.pcg(B, tol=1e-6, maxiter=40)
    assert pres.converged
    rel = [np.linalg.norm(B[:, j] - A.matvec(pres.x[:, j]))
           / np.linalg.norm(B[:, j]) for j in range(B.shape[1])]
    assert max(rel) < 1e-5
    # vcycle accepts [n, k] too
    y = bound.vcycle(B)
    assert y.shape == B.shape


def test_dist_multi_rhs_zero_column_does_not_poison_batch(problem):
    """A zero RHS column (rz = pAp = 0) must step by zero, not spread NaNs
    to the other columns of the batched PCG."""
    A, b = problem
    B = np.stack([b, np.zeros_like(b)], axis=1)
    cfg = AMGConfig(backend="dist", n_pods=1, lanes=1, strategy="standard")
    res = AMGSolver(cfg).setup(A).pcg(B, tol=1e-6, maxiter=40)
    assert res.converged
    assert np.all(np.isfinite(res.x))
    np.testing.assert_allclose(res.x[:, 1], 0.0)
    rel = (np.linalg.norm(b - A.matvec(res.x[:, 0])) / np.linalg.norm(b))
    assert rel < 1e-5


def test_bad_b_shape_rejected(problem):
    A, b = problem
    bound = AMGSolver(AMGConfig()).setup(A)
    with pytest.raises(ValueError, match="b must be"):
        bound.solve(b[:-1])
    with pytest.raises(ValueError, match="b must be"):
        bound.solve(np.ones((A.nrows, 2, 2)))


# --------------------------------------------------------------- pcg / x0
def test_pcg_x0_symmetry(problem):
    A, b = problem
    h = setup(A)
    ref = pcg(h, b, tol=1e-8)
    warm = pcg(h, b, tol=1e-8, x0=ref.x)
    assert warm.converged and warm.iterations == 0  # already at the solution
    cold = pcg(h, b, tol=1e-8, x0=np.zeros_like(b))
    assert cold.iterations == ref.iterations
    np.testing.assert_allclose(cold.x, ref.x)
    # dist backend takes x0 the same way
    cfg = AMGConfig(backend="dist", n_pods=1, lanes=1, strategy="standard")
    bound = AMGSolver(cfg).setup(A)
    dwarm = bound.pcg(b, tol=1e-5, x0=ref.x)     # fp32 residual floor
    assert dwarm.converged and dwarm.iterations == 0
    # and vcycle rejects x0 cleanly where unsupported (dist starts at 0)
    with pytest.raises(ValueError, match="x0"):
        bound.vcycle(b, x0=b)
    with pytest.raises(ValueError):
        vcycle(h, b, x=b, backend="dist",
               dist={"n_pods": 1, "lanes": 1, "strategy": "standard"})


# ------------------------------------------------------- AMGService drain
def test_service_drain_smoke():
    A1, A2 = laplace_3d(6), laplace_3d(8)
    svc = AMGService(AMGConfig(tol=1e-8), max_rhs=3)
    svc.register("m1", A1)
    svc.register("m2", A2)
    rng = np.random.default_rng(0)
    reqs = []
    for rid in range(7):
        mid = "m1" if rid % 2 == 0 else "m2"
        A = A1 if mid == "m1" else A2
        reqs.append((rid, mid, rng.standard_normal(A.nrows)))
        svc.submit(mid, reqs[-1][2], rid=rid)
    out = svc.drain()
    assert sorted(out) == list(range(7))
    for rid, mid, b in reqs:
        A = A1 if mid == "m1" else A2
        rel = np.linalg.norm(b - A.matvec(out[rid])) / np.linalg.norm(b)
        assert rel < 1e-6, (rid, rel)
    # convergence is surfaced per request, not silently discarded
    assert sorted(svc.diagnostics) == list(range(7))
    assert all(d["converged"] and d["iterations"] > 0
               for d in svc.diagnostics.values())
    assert svc.stats["unconverged"] == 0
    # 4 m1-requests and 3 m2-requests at max_rhs=3 → 2 + 1 batches
    assert svc.stats["batches"] == 3
    assert svc.stats["setups"] == 2
    assert svc.stats["batched_rhs"] == 6        # 3 + 3 (the 1-request tail
    #                                             of m1 runs unbatched)
    # draining again is a no-op; unknown ids are rejected
    assert svc.drain() == {}
    with pytest.raises(KeyError, match="unknown matrix_id"):
        svc.submit("nope", np.ones(3))
    with pytest.raises(ValueError, match="unknown method"):
        svc.submit("m1", np.ones(A1.nrows), method="gmres")
    with pytest.raises(ValueError, match="must be"):
        svc.submit("m1", np.ones(3))
    # same-service re-setup hits the bound cache, not a new hierarchy
    assert svc.bound_for("m1") is svc.bound_for("m1")
    assert svc.stats["setups"] == 2
