"""Serving engine tests: prefill-cache conversion correctness and batched
generation."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import decode_step, forward, init_params
from repro.serve import Engine, Request, prefill_to_decode_cache


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "recurrentgemma-9b",
                                  "xlstm-125m", "mixtral-8x22b"])
def test_prefill_cache_continues_decode(arch):
    """prefill(S tokens) + decode(1) must equal forward(S+1)'s last logits —
    across attention, hybrid, recurrent and MoE archs."""
    cfg = get_arch(arch).reduced()
    if cfg.is_moe:
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(1)
    S = 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, S + 1)), jnp.int32)
    ref = np.asarray(forward(params, cfg, toks), np.float32)[:, -1]
    _, caches = forward(params, cfg, toks[:, :S], return_cache=True)
    cache = prefill_to_decode_cache(cfg, caches, ctx_len=S + 4, prompt_len=S)
    logits, _ = decode_step(params, cfg, toks[:, S:S + 1], cache,
                            jnp.int32(S))
    np.testing.assert_allclose(np.asarray(logits, np.float32), ref,
                               rtol=3e-3, atol=3e-3)


def test_engine_batched_generation():
    cfg = get_arch("qwen2-0.5b").reduced(n_layers=2, d_model=32, n_heads=4,
                                         vocab=128)
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    eng = Engine(cfg, params, max_batch=3, ctx_len=64)
    rng = np.random.default_rng(0)
    for rid in range(7):
        eng.submit(Request(rid=rid,
                           prompt=rng.integers(0, cfg.vocab, 10,
                                               dtype=np.int32),
                           max_new_tokens=5))
    out = eng.run()
    assert sorted(out) == list(range(7))
    assert all(v.shape == (5,) for v in out.values())
    assert eng.stats["batches"] == 3          # 3 + 3 + 1
    # greedy decoding is deterministic
    eng2 = Engine(cfg, params, max_batch=3, ctx_len=64)
    rng = np.random.default_rng(0)
    for rid in range(7):
        eng2.submit(Request(rid=rid,
                            prompt=rng.integers(0, cfg.vocab, 10,
                                                dtype=np.int32),
                            max_new_tokens=5))
    out2 = eng2.run()
    for rid in out:
        np.testing.assert_array_equal(out[rid], out2[rid])


def test_engine_mixed_temperature_batch_honors_each_request():
    """Regression: _run_batch used to apply reqs[0].temperature to the whole
    batch — a greedy request batched after a sampled one came back sampled.
    Greedy requests must decode identically whether batched with sampled
    requests or alone, and the whole mixed batch must be deterministic."""
    cfg = get_arch("qwen2-0.5b").reduced(n_layers=2, d_model=32, n_heads=4,
                                         vocab=128)
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab, 10, dtype=np.int32)
               for _ in range(3)]

    def run(temps, max_batch):
        eng = Engine(cfg, params, max_batch=max_batch, ctx_len=64)
        for rid, (p, t) in enumerate(zip(prompts, temps)):
            eng.submit(Request(rid=rid, prompt=p, max_new_tokens=6,
                               temperature=t))
        return eng.run()

    # sampled request FIRST: under the old bug its temperature leaked onto
    # the greedy batchmates
    mixed = run([1.5, 0.0, 0.0], max_batch=3)
    # reference: the same 3-request batch, all greedy — rows 1 and 2 see
    # bit-identical logits, so their tokens must match exactly
    greedy = run([0.0] * 3, max_batch=3)
    for rid in (1, 2):
        np.testing.assert_array_equal(mixed[rid], greedy[rid])
    # mixed-batch decoding stays deterministic (same PRNG path)
    again = run([1.5, 0.0, 0.0], max_batch=3)
    for rid in range(3):
        np.testing.assert_array_equal(mixed[rid], again[rid])
