"""On/off-process operator splitting + overlap-aware selection (host side).

Everything here runs on the host or a 1×1 mesh — the split itself is pure
numpy lowering, and the overlap-aware cost model is arithmetic.  The
multi-device end-to-end parity (overlap=True vs the serial oracle across
all 15 cycle×smoother pairs) and the 1-device-per-node empty-halo
no-collective check run in the 8-device subprocess
(tests/dist_solve_script.py, "OK overlap_parity" / "OK empty_halo").
"""
import numpy as np
import pytest

from repro.amg.csr import CSR
from repro.amg.dist_spmv import build_dist_operator
from repro.core.perf_model import (BLUE_WATERS, MachineParams,
                                   overlap_efficiency, overlap_time,
                                   spmv_compute_times)
from repro.core.selector import select
from repro.core.topology import Partition, Topology
from repro.amg.dist import rect_vector_graph

N_PODS, LANES = 2, 4


def _random_csr(n=96, seed=0):
    rng = np.random.default_rng(seed)
    band = (np.abs(np.subtract.outer(np.arange(n), np.arange(n))) <= 3)
    dense = band * rng.normal(size=(n, n))
    dense += np.where(rng.random((n, n)) < 0.08, rng.normal(size=(n, n)), 0.0)
    r, c = np.nonzero(dense)
    return CSR.from_coo(r, c, dense[r, c], (n, n)), dense


def _ell_entries(cols, vals):
    """Multiset of (row, col, val) triples of one device's ELL block."""
    keep = cols >= 0
    r = np.broadcast_to(np.arange(cols.shape[0])[:, None], cols.shape)[keep]
    return sorted(zip(r.tolist(), cols[keep].tolist(), vals[keep].tolist()))


@pytest.mark.parametrize("strategy", ["standard", "nap2", "nap3"])
def test_split_partitions_fused_entries_exactly(strategy):
    """A_on (local ids) + A_off (halo ids, rebased) must hold *exactly* the
    fused block's entries: on = fused entries with col < x_local, off = the
    rest shifted by x_local — per device, as multisets."""
    A, _ = _random_csr()
    op = build_dist_operator(A, N_PODS, LANES, strategy, dtype=np.float64)
    x_local = op.plan.local_n
    for d in range(op.n_devices):
        fused = _ell_entries(op.ell_cols[d], op.ell_vals[d])
        want_on = [e for e in fused if e[1] < x_local]
        want_off = [(r, c - x_local, v) for r, c, v in fused if c >= x_local]
        assert _ell_entries(op.on_cols[d], op.on_vals[d]) == want_on
        assert _ell_entries(op.off_cols[d], op.off_vals[d]) == want_off


def test_split_numeric_parity_per_device():
    """A_on·x + A_off·halo == A_local·[x | halo] (host arithmetic, fp64)."""
    A, dense = _random_csr(seed=3)
    op = build_dist_operator(A, N_PODS, LANES, "standard", dtype=np.float64)
    part = op.col_part
    rng = np.random.default_rng(7)
    x = rng.normal(size=A.ncols)

    def ell_apply(cols, vals, src):
        keep = cols >= 0
        return np.where(keep, vals * src[np.maximum(cols, 0)], 0.0).sum(axis=1)

    graph = rect_vector_graph(A, part, part)
    for d in range(op.n_devices):
        lo, hi = part.local_range(d)
        x_loc = np.zeros(op.plan.local_n)
        x_loc[: hi - lo] = x[lo:hi]
        halo = np.zeros(op.plan.halo_len)
        need = np.sort(graph.need[d])
        halo[: need.size] = x[need]
        fused = ell_apply(op.ell_cols[d], op.ell_vals[d],
                          np.concatenate([x_loc, halo]))
        split = (ell_apply(op.on_cols[d], op.on_vals[d], x_loc)
                 + ell_apply(op.off_cols[d], op.off_vals[d], halo))
        np.testing.assert_allclose(split, fused, rtol=0, atol=1e-13)
        # and both match the dense row block
        y = dense[lo:hi] @ x
        np.testing.assert_allclose(split[: hi - lo], y, rtol=0, atol=1e-12)


def test_onoff_nnz_partitions_local_nnz():
    A, _ = _random_csr(seed=5)
    op = build_dist_operator(A, N_PODS, LANES, "nap2", dtype=np.float64)
    stats = op.onoff_nnz()
    assert stats["on_nnz"] + stats["off_nnz"] == int((op.ell_cols >= 0).sum())
    assert stats["on_nnz"] + stats["off_nnz"] == A.nnz


def test_block_diagonal_operator_has_empty_halo():
    """A block-diagonal matrix aligned to the partition moves zero halo
    entries — total_halo records it even though halo_len is floored to 1."""
    topo = Topology(n_nodes=N_PODS, ppn=LANES)
    n = 96
    part = Partition.balanced(n, topo)
    rng = np.random.default_rng(0)
    dense = np.zeros((n, n))
    for d in range(topo.n_procs):
        lo, hi = part.local_range(d)
        dense[lo:hi, lo:hi] = rng.normal(size=(hi - lo, hi - lo))
    r, c = np.nonzero(dense)
    B = CSR.from_coo(r, c, dense[r, c], (n, n))
    op = build_dist_operator(B, N_PODS, LANES, "standard", dtype=np.float64)
    assert op.plan.total_halo == 0
    assert op.halo_empty
    assert op.onoff_nnz()["off_nnz"] == 0
    # a coupled operator is not empty
    A, _ = _random_csr()
    op2 = build_dist_operator(A, N_PODS, LANES, "standard", dtype=np.float64)
    assert op2.plan.total_halo > 0 and not op2.halo_empty


def test_empty_halo_apply_emits_no_collective_1x1():
    """On a 1×1 mesh every operator is halo-free: the jitted apply must
    contain no collective primitive at all — checked structurally with the
    comm-audit walker, not by substring-matching the jaxpr repr.  (The
    8-device 1-device-per-node variant runs in the dist_solve subprocess.)"""
    jax = pytest.importorskip("jax")
    from repro.amg.dist_spmv import build_dist_spmv
    from repro.analysis import audit_jaxpr, collect_collectives
    A, dense = _random_csr(seed=11)
    sp = build_dist_spmv(A, 1, 1, "standard", dtype=np.float64)
    assert sp.op.halo_empty
    assert sp.op.expected_signature == ()
    import jax.numpy as jnp
    jxp = jax.make_jaxpr(sp.fn)(jnp.zeros((1, sp.op.plan.local_n)))
    assert collect_collectives(jxp) == []
    audit = audit_jaxpr(jxp, "apply_A", expected_signature=())
    assert audit.ok and audit.n_collectives == 0
    x = np.random.default_rng(1).normal(size=A.ncols)
    # fp32 on this in-process run (jax x64 stays off in the main pytest
    # process); the fp64 parity lives in the subprocess script
    np.testing.assert_allclose(sp.matvec(x), dense @ x, rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------ cost model


def test_from_measurements_recovers_postal_fit():
    """lstsq on exact postal-model samples recovers alpha and R_b."""
    alpha, rb = 2.5e-6, 8.0e8
    samples = [(n, alpha + n / rb) for n in (1024., 8192., 65536., 524288.)]
    p = MachineParams.from_measurements(
        "fit_test", ppn=4, inter=samples, intra=samples, Rf=1e9)
    got = p.inter[0]
    assert got.alpha == pytest.approx(alpha, rel=1e-6)
    assert got.Rb == pytest.approx(rb, rel=1e-6)
    assert p.Rf == 1e9
    assert p.RN == pytest.approx(4 * rb, rel=1e-6)
    # all three protocol slots share the single fitted curve
    assert p.inter[1] == got and p.inter[2] == got


def test_from_measurements_floors_noisy_fit():
    """A fit driven negative by noise is floored, never unphysical."""
    samples = [(1024., 5e-6), (2048., 1e-6), (4096., 8e-6)]
    p = MachineParams.from_measurements("noisy", ppn=2, inter=samples,
                                        intra=samples)
    assert p.inter[0].alpha >= 1e-9
    assert 0 < p.inter[0].Rb < float("inf")
    with pytest.raises(ValueError):
        MachineParams.from_measurements("bad", ppn=2, inter=[(1., 1.)],
                                        intra=samples)


def test_overlap_time_and_efficiency():
    assert overlap_time(10.0, 4.0, 1.0) == 11.0      # comm dominates
    assert overlap_time(3.0, 4.0, 1.0) == 5.0        # compute hides comm
    assert overlap_efficiency(0.0, 0.0, 0.0) == 0.0
    # fully hidden exchange: serial 3+3+0=6, overlapped max(3,3)+0=3
    assert overlap_efficiency(3.0, 3.0, 0.0) == pytest.approx(0.5)
    # overlap-unaware machines yield zero compute → zero efficiency
    assert spmv_compute_times(BLUE_WATERS, 10**6, 10**6) == (0.0, 0.0)


def test_selection_accounts_for_hidden_latency():
    """With a compute split supplied, select() ranks strategies by
    max(T_comm, T_on) + T_off; a large t_on can erase the comm differences
    so the cheapest-comm strategy no longer wins automatically."""
    A, _ = _random_csr(seed=13)
    topo = Topology(n_nodes=N_PODS, ppn=LANES)
    part = Partition.balanced(A.nrows, topo)
    g = rect_vector_graph(A, part, part)
    base = select(g, BLUE_WATERS)
    assert base.compute == (0.0, 0.0)
    assert base.times == base.comm_times           # serial reduction
    t_on = 10.0 * max(base.comm_times.values())    # compute dwarfs comm
    sel = select(g, BLUE_WATERS, compute=(t_on, 0.0))
    assert sel.compute == (t_on, 0.0)
    for s, t in sel.times.items():
        assert t == pytest.approx(
            overlap_time(sel.comm_times[s], t_on, 0.0))
        assert t == pytest.approx(t_on)            # everything fully hidden
    # comm_times preserve the raw exchange model for reporting
    assert sel.comm_times == base.comm_times
