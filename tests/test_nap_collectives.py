"""Multi-device shard_map tests — executed in a subprocess so this pytest
process keeps a single CPU device (device count locks at first jax init)."""
import os
import pathlib
import subprocess
import sys

import pytest

SCRIPT = pathlib.Path(__file__).parent / "multidev_script.py"
EXPECTED = [
    "OK grad_sync",
    "OK hier_psum",
    "OK hier_all_gather",
    "OK hier_all_to_all",
    "OK halo_exchange",
    "OK dist_spmv",
    "OK collective_bytes_ordering",
    "ALL_OK",
]


@pytest.mark.slow
def test_multidevice_collectives_subprocess():
    env = dict(os.environ)
    root = str(pathlib.Path(__file__).parents[1] / "src")
    env["PYTHONPATH"] = root + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, str(SCRIPT)], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    for marker in EXPECTED:
        assert marker in out.stdout, f"missing {marker!r} in:\n{out.stdout}"
