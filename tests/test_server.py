"""AMGWire server tests: framing, multi-tenant admission, backpressure,
and the end-to-end socket error paths.

Everything here runs real sockets on the loopback against a
:class:`~repro.serve.server.ServerThread` (host backend — deterministic,
no accelerator), driven by the blocking
:class:`~repro.serve.client.AMGWireClient`.  The acceptance property
under test throughout: every failure mode — malformed JSON, schema
mismatch, unknown tenant/matrix, over-quota submission, even server
shutdown with requests queued — surfaces as a *structured* frame on a
*surviving* connection, never a dropped socket or a hang.
"""
import asyncio
import json
import threading
import time

import numpy as np
import pytest

from repro.amg.api import AMGConfig, clear_sessions, csr_to_wire
from repro.amg.api.service import AMGService, ServiceClosed
from repro.serve import (AMGWireClient, BadFrame, FrameTooLarge, Rejected,
                         RemoteError, ServerThread, TenantSpec,
                         encode_frame, read_frame, ticket_future)
from repro.serve.workload import (build_problems, make_request,
                                  rel_residual)


@pytest.fixture(autouse=True)
def _fresh_sessions():
    clear_sessions()
    yield
    clear_sessions()


@pytest.fixture(scope="module")
def problems():
    return build_problems(6, count=1)


def _spec(**kw):
    kw.setdefault("config", AMGConfig())
    return TenantSpec(**kw)


# ---------------------------------------------------------------- framing


def _feed(*chunks: bytes) -> asyncio.StreamReader:
    reader = asyncio.StreamReader()
    for chunk in chunks:
        reader.feed_data(chunk)
    reader.feed_eof()
    return reader


def test_frame_round_trip():
    async def go():
        frames = [{"schema": 1, "kind": "ping", "seq": 0},
                  {"a": [1, 2, 3], "b": None}]
        reader = _feed(b"".join(encode_frame(f) for f in frames))
        assert await read_frame(reader) == frames[0]
        assert await read_frame(reader) == frames[1]
        assert await read_frame(reader) is None          # clean EOF
    asyncio.run(go())


def test_frame_errors_keep_stream_aligned():
    async def go():
        good = encode_frame({"ok": 1})
        # oversized frame (declared length > max) is drained, then the
        # stream picks up the next frame intact
        big = json.dumps({"pad": "x" * 256}).encode()
        import struct
        reader = _feed(struct.pack(">I", len(big)) + big, good)
        with pytest.raises(FrameTooLarge):
            await read_frame(reader, max_frame=64)
        assert await read_frame(reader, max_frame=64) == {"ok": 1}
        # non-JSON body -> BadFrame, next frame still decodes
        bad = b"not json at all"
        reader = _feed(struct.pack(">I", len(bad)) + bad, good)
        with pytest.raises(BadFrame):
            await read_frame(reader)
        assert await read_frame(reader) == {"ok": 1}
        # a JSON body that is not an object is also a BadFrame
        arr = json.dumps([1, 2]).encode()
        reader = _feed(struct.pack(">I", len(arr)) + arr)
        with pytest.raises(BadFrame):
            await read_frame(reader)
        # mid-frame disconnect reads as EOF, not an exception
        reader = _feed(struct.pack(">I", 100) + b"only-ten-b")
        assert await read_frame(reader) is None
    asyncio.run(go())


# ----------------------------------------------------- ticket adapter


def test_ticket_future_resolves_on_loop(problems):
    mid, A = next(iter(problems.items()))
    b = A.matvec(np.ones(A.nrows))

    async def go():
        svc = AMGService(AMGConfig())
        svc.register(mid, A)
        with svc:
            fut = ticket_future(svc.submit(mid, b, method="pcg"),
                                asyncio.get_running_loop())
            x, diag = await asyncio.wait_for(fut, 60)
        assert diag["converged"]
        assert rel_residual(A, x, b) < 1e-6
        # a failed ticket surfaces its exception through the future
        svc2 = AMGService(AMGConfig())
        svc2.register(mid, A)
        t = svc2.submit(mid, b)
        fut2 = ticket_future(t, asyncio.get_running_loop())
        svc2.close(flush=False)              # fails the queued ticket
        with pytest.raises(ServiceClosed):
            await asyncio.wait_for(fut2, 60)
    asyncio.run(go())


# ------------------------------------------------------- happy path


def test_register_solve_round_trip(problems):
    mid, A = next(iter(problems.items()))
    rng = np.random.default_rng(0)
    with ServerThread({"t0": _spec()}) as srv:
        with AMGWireClient.connect(srv.host, srv.port) as c:
            assert c.ping()["tenants"] == ["t0"]
            reg = c.register("t0", csr_to_wire(A))
            assert reg["matrix"] == mid
            b, payload = make_request(rng, problems, mid, method="pcg")
            x, diag = c.solve("t0", payload)
            assert diag["converged"]
            assert rel_residual(A, x, b) < 1e-6
            st = c.stats()["tenants"]["t0"]
            assert st["registered"] == 1
            assert st["admitted"] == st["completed"] == 1
            assert st["rejected"] == st["errors"] == 0


def test_pipelined_out_of_order_completion(problems):
    """Many pipelined solves down one connection, harvested in reverse
    send order — seq correlation matches each response to its request."""
    mid, A = next(iter(problems.items()))
    rng = np.random.default_rng(1)
    with ServerThread({"t0": _spec(max_inflight=64)}) as srv:
        with AMGWireClient.connect(srv.host, srv.port) as c:
            c.register("t0", csr_to_wire(A))
            sent = []
            for _ in range(12):
                b, payload = make_request(rng, problems, mid)
                sent.append((b, c.send("solve", tenant="t0",
                                       payload=payload)))
            for b, seq in reversed(sent):
                frame = c.recv(seq, timeout=120)
                assert frame["kind"] == "solution"
                from repro.amg.api import array_from_wire
                assert rel_residual(A, array_from_wire(frame["x"]), b) < 1e-6


# -------------------------------------------------- wire error paths


def test_malformed_json_yields_error_frame_and_connection_survives(problems):
    mid, A = next(iter(problems.items()))
    with ServerThread({"t0": _spec()}) as srv:
        with AMGWireClient.connect(srv.host, srv.port) as c:
            c.send_raw(b"{this is not json")
            frame = c.recv_unmatched()
            assert frame["kind"] == "error"
            assert frame["code"] == 400
            assert frame["error"] == "BadFrame"
            # same connection keeps working
            assert c.ping()["kind"] == "pong"
            assert c.register("t0", csr_to_wire(A))["matrix"] == mid


def test_schema_version_mismatch_yields_error_frame():
    with ServerThread({"t0": _spec()}) as srv:
        with AMGWireClient.connect(srv.host, srv.port) as c:
            c.send_raw(json.dumps({"schema": 99, "kind": "ping",
                                   "seq": 3}).encode())
            frame = c.recv_unmatched()
            assert frame["kind"] == "error" and frame["code"] == 400
            assert "schema version mismatch" in frame["message"]
            assert frame["seq"] == 3          # correlation id echoed
            # unknown kind is the same class of structured failure
            c.send_raw(json.dumps({"schema": 1, "kind": "nope",
                                   "seq": 4}).encode())
            frame = c.recv_unmatched()
            assert frame["kind"] == "error" and frame["code"] == 400
            assert "unknown frame kind" in frame["message"]
            assert c.ping()["kind"] == "pong"


def test_unknown_tenant_and_matrix_yield_404(problems):
    mid, A = next(iter(problems.items()))
    rng = np.random.default_rng(2)
    with ServerThread({"t0": _spec()}) as srv:
        with AMGWireClient.connect(srv.host, srv.port) as c:
            _, payload = make_request(rng, problems, mid)
            with pytest.raises(RemoteError) as ei:
                c.solve("ghost", payload)
            assert ei.value.code == 404
            # unknown matrix id on a real tenant: the service's KeyError
            # crosses the wire as a structured 404 and is accounted
            with pytest.raises(RemoteError) as ei:
                c.solve("t0", payload)
            assert ei.value.code == 404
            assert ei.value.error == "KeyError"
            st = c.stats()["tenants"]["t0"]
            assert st["errors"] == 1
            assert st["service"]["errors"] == 0   # rejected pre-admission
            assert c.ping()["kind"] == "pong"


def test_strict_codec_rejection_crosses_the_wire(problems):
    """An unknown-key payload is refused by the inner codec's strict
    decoder; the server relays it as a 400 WireError frame."""
    mid, A = next(iter(problems.items()))
    rng = np.random.default_rng(3)
    with ServerThread({"t0": _spec()}) as srv:
        with AMGWireClient.connect(srv.host, srv.port) as c:
            c.register("t0", csr_to_wire(A))
            _, payload = make_request(rng, problems, mid)
            payload["surprise"] = True
            with pytest.raises(RemoteError) as ei:
                c.solve("t0", payload)
            assert ei.value.code == 400
            assert ei.value.error == "WireError"
            assert "unknown key" in str(ei.value)
            # tampered matrix payload: fingerprint verification fails
            bad = csr_to_wire(A)
            bad["fingerprint"] = "0" * 40
            with pytest.raises(RemoteError) as ei:
                c.register("t0", bad)
            assert ei.value.code == 400 and ei.value.error == "WireError"
            assert c.stats()["tenants"]["t0"]["errors"] == 2


# ------------------------------------------------- quotas + shedding


def test_matrix_byte_quota_rejects_with_429(problems):
    mid, A = next(iter(problems.items()))
    with ServerThread({"t0": _spec(max_matrix_bytes=10)}) as srv:
        with AMGWireClient.connect(srv.host, srv.port) as c:
            with pytest.raises(Rejected) as ei:
                c.register("t0", csr_to_wire(A))
            assert ei.value.frame["code"] == 429
            assert ei.value.frame["reason"] == "matrix byte quota"
            st = c.stats()["tenants"]["t0"]
            assert st["rejected"] == 1 and st["registered"] == 0
            assert c.ping()["kind"] == "pong"


def test_overload_sheds_batch_before_interactive(problems):
    """With max_inflight=2 the batch class admits at most 1 in-flight
    request while interactive may fill both slots; a huge coalescing
    window keeps admitted work queued so the counters are deterministic.
    """
    mid, A = next(iter(problems.items()))
    rng = np.random.default_rng(4)
    spec = _spec(max_inflight=2, coalesce_window=120.0)
    with ServerThread({"t0": spec}) as srv:
        with AMGWireClient.connect(srv.host, srv.port) as c:
            c.register("t0", csr_to_wire(A))

            def send(priority):
                _, payload = make_request(rng, problems, mid,
                                          priority=priority)
                return c.send("solve", tenant="t0", payload=payload)

            s1 = send("batch")                    # occupies the 1 batch slot
            time.sleep(0.2)                       # let admission land
            s2 = send("batch")                    # over the batch limit
            frame = c.recv(s2, timeout=60)
            assert frame["kind"] == "rejected" and frame["code"] == 429
            assert frame["priority"] == "batch"
            assert frame["limit"] == 1
            # interactive still has headroom (limit == max_inflight == 2)
            s3 = send("interactive")
            time.sleep(0.2)
            s4 = send("interactive")              # now the tenant is full
            frame = c.recv(s4, timeout=60)
            assert frame["kind"] == "rejected"
            assert frame["priority"] == "interactive"
            assert frame["limit"] == 2
            st = c.stats()["tenants"]["t0"]
            assert st["admitted"] == 2 and st["rejected"] == 2
            assert st["rejected_by_class"] == {"batch": 1,
                                               "interactive": 1}


def test_shutdown_fails_queued_solves_with_structured_503(problems):
    mid, A = next(iter(problems.items()))
    rng = np.random.default_rng(5)
    srv = ServerThread({"t0": _spec(max_inflight=4,
                                    coalesce_window=120.0)})
    srv.__enter__()
    try:
        c = AMGWireClient.connect(srv.host, srv.port)
        c.register("t0", csr_to_wire(A))
        _, payload = make_request(rng, problems, mid)
        seq = c.send("solve", tenant="t0", payload=payload)
        time.sleep(0.2)                           # admission lands
    finally:
        srv.__exit__(None, None, None)            # close with it queued
    frame = c.recv(seq, timeout=60)
    assert frame["kind"] == "error"
    assert frame["code"] == 503
    assert frame["error"] == "ServiceClosed"
    c.close()


# -------------------------------------------------- concurrency scale


def test_32_concurrent_connections_two_tenants(problems):
    """32 live connections across two tenants, all solving at once: every
    response is structured, nothing drops, both tenants' accounting adds
    up."""
    mid, A = next(iter(problems.items()))
    tenants = {"alpha": _spec(max_inflight=64),
               "beta": _spec(max_inflight=64)}
    names = sorted(tenants)
    results, errors = [], []
    with ServerThread(tenants) as srv:
        with AMGWireClient.connect(srv.host, srv.port) as admin:
            for t in names:
                admin.register(t, csr_to_wire(A))

            def worker(i):
                rng = np.random.default_rng(100 + i)
                try:
                    with AMGWireClient.connect(srv.host, srv.port) as c:
                        b, payload = make_request(rng, problems, mid,
                                                  priority="interactive")
                        x, diag = c.solve(names[i % 2], payload,
                                          timeout=120)
                        results.append(rel_residual(A, x, b))
                except Exception as e:            # pragma: no cover
                    errors.append(e)

            threads = [threading.Thread(target=worker, args=(i,))
                       for i in range(32)]
            for t in threads:
                t.start()
            for t in threads:
                t.join(timeout=180)
            assert not errors
            assert len(results) == 32
            assert max(results) < 1e-6
            st = admin.stats()
            assert st["dropped_connections"] == 0
            for name in names:
                ts = st["tenants"][name]
                assert ts["completed"] == 16
                assert ts["errors"] == 0
