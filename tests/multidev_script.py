"""Multi-device shard_map validation — run as a SUBPROCESS by
test_nap_collectives.py (device count must be set before jax init; the main
pytest process keeps 1 device).

Prints "OK <check>" per passing check; any exception fails the run.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.core import CommGraph, Partition, Topology  # noqa: E402
from repro.core.nap_collectives import (build_halo_plan, halo_exchange,  # noqa: E402
                                        hier_all_gather, hier_all_to_all,
                                        hier_psum)
from repro.amg.dist_spmv import build_dist_spmv  # noqa: E402
from repro.amg.problems import laplace_3d_7pt, laplace_3d  # noqa: E402

N_PODS, LANES = 2, 4
mesh = jax.make_mesh((N_PODS, LANES), ("pod", "lane"))
DEV = P(("pod", "lane"))


from repro.core.compat import shard_map  # noqa: E402


def shmap(f, n_in, out_specs=DEV):
    return jax.jit(shard_map(f, mesh=mesh, in_specs=(DEV,) * n_in,
                             out_specs=out_specs, check_vma=False))


def check_hier_psum():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((8, 37)).astype(np.float32)  # odd size -> padding

    for strat in ("flat", "nap3"):
        f = shmap(lambda a, s=strat: hier_psum(a[0], "pod", "lane", s)[None], 1)
        out = np.asarray(f(x))
        expect = x.sum(axis=0)
        for d in range(8):
            np.testing.assert_allclose(out[d], expect, rtol=1e-5)
    print("OK hier_psum")


def check_hier_all_gather():
    rng = np.random.default_rng(1)
    x = rng.standard_normal((8, 5)).astype(np.float32)
    for strat in ("flat", "nap3"):
        f = shmap(lambda a, s=strat: hier_all_gather(a[0], "pod", "lane", s)[None], 1,
                  out_specs=DEV)
        out = np.asarray(f(x))
        for d in range(8):
            np.testing.assert_allclose(out[d], x.reshape(-1), rtol=1e-6)
    print("OK hier_all_gather")


def check_hier_all_to_all():
    # chunk (src d -> dst e) carries value 100*d + e
    D = 8
    x = np.zeros((D, D, 3), dtype=np.float32)
    for d in range(D):
        for e in range(D):
            x[d, e] = 100 * d + e
    for strat in ("flat", "nap3"):
        f = shmap(lambda a, s=strat: hier_all_to_all(a[0], "pod", "lane", s)[None], 1)
        out = np.asarray(f(x))
        for e in range(D):
            for d in range(D):
                assert (out[e, d] == 100 * d + e).all(), (strat, e, d, out[e, d])
    print("OK hier_all_to_all")


def check_halo_exchange():
    rng = np.random.default_rng(2)
    topo = Topology(n_nodes=N_PODS, ppn=LANES)
    n = 103
    part = Partition.balanced(n, topo)
    need = []
    for q in range(topo.n_procs):
        lo, hi = part.local_range(q)
        cand = np.setdiff1d(np.arange(n), np.arange(lo, hi))
        need.append(np.sort(rng.choice(cand, size=17, replace=False)))
    g = CommGraph.from_offproc_columns(part, need)
    x = rng.standard_normal(n).astype(np.float32)
    x_dev = np.zeros((8, part.max_local_size), dtype=np.float32)
    for d in range(8):
        lo, hi = part.local_range(d)
        x_dev[d, : hi - lo] = x[lo:hi]
    for strat in ("standard", "nap2", "nap3"):
        plan = build_halo_plan(g, N_PODS, LANES, strat)
        psel = plan.pool_sel if plan.pool_sel is not None else np.zeros(
            (8, 1), np.int32)

        def body(xl, si, rs, ps, plan=plan):
            ps_ = None if plan.pool_sel is None else ps[0]
            return halo_exchange(xl[0], plan, si[0], rs[0], ps_)[None]

        f = shmap(body, 4)
        halo = np.asarray(f(x_dev, plan.send_idx, plan.recv_sel, psel))
        for d in range(8):
            expect = x[np.sort(need[d])]
            np.testing.assert_allclose(halo[d, : expect.size], expect, rtol=1e-6,
                                       err_msg=f"{strat} dev {d}")
    print("OK halo_exchange")


def check_dist_spmv():
    A = laplace_3d_7pt(6)  # 216 rows over 8 devices
    rng = np.random.default_rng(3)
    x = rng.standard_normal(A.nrows)
    y_ref = A.matvec(x)
    for strat in ("standard", "nap2", "nap3"):
        sp = build_dist_spmv(A, N_PODS, LANES, strat, mesh=mesh)
        y = sp.matvec(x)
        np.testing.assert_allclose(y, y_ref, rtol=2e-4, atol=2e-4)
    print("OK dist_spmv")


def check_collective_bytes_ordering():
    """Lowered HLO: nap3 halo exchange moves fewer bytes across the pod axis
    than standard (dedup), and uses fewer pod-crossing collectives."""
    from repro.launch.roofline import collective_bytes_from_text
    A = laplace_3d(6)
    stats = {}
    for strat in ("standard", "nap2", "nap3"):
        sp = build_dist_spmv(A, N_PODS, LANES, strat, mesh=mesh)
        x = sp.scatter_x(np.ones(A.nrows))
        lowered = jax.jit(sp.fn).lower(x)
        txt = lowered.compile().as_text()
        stats[strat] = collective_bytes_from_text(txt, pod_size=LANES, n_devices=8)
    # cross-pod collective bytes: nap3 <= nap2 <= standard
    s = {k: v["cross_slow_bytes"] for k, v in stats.items()}
    assert s["nap3"] <= s["nap2"] <= s["standard"], s
    print("OK collective_bytes_ordering", s)


def check_grad_sync():
    from repro.train.grad_sync import hier_grad_sync, init_error_feedback
    rng = np.random.default_rng(4)
    # per-device gradient trees (leading dim 8 = device axis)
    g1 = rng.standard_normal((8, 33)).astype(np.float32)
    g2 = rng.standard_normal((8, 5, 7)).astype(np.float32)
    expect1, expect2 = g1.mean(0), g2.mean(0)

    def body(a, b, strat, compress):
        grads = {"a": a[0], "b": b[0]}
        ef = init_error_feedback(grads, LANES) if compress else None
        synced, _ = hier_grad_sync(grads, "pod", "lane", strat,
                                   compress_slow=compress, error_feedback=ef)
        return synced["a"][None], synced["b"][None]

    for strat, compress, tol in (("flat", False, 1e-5), ("nap3", False, 1e-5),
                                 ("nap3", True, 3e-2)):
        f = shmap(lambda a, b, s=strat, c=compress: body(a, b, s, c), 2,
                  out_specs=(DEV, DEV))
        o1, o2 = f(g1, g2)
        for d in range(8):
            np.testing.assert_allclose(np.asarray(o1)[d], expect1, atol=tol)
            np.testing.assert_allclose(np.asarray(o2)[d], expect2, atol=tol)
    # error feedback: repeated syncs of the SAME gradient average out the
    # quantization error (residual is re-injected)
    def body_ef(a):
        grads = {"a": a[0]}
        ef = init_error_feedback(grads, LANES)
        total = jnp.zeros((33,), jnp.float32)
        for _ in range(8):
            synced, ef = hier_grad_sync(grads, "pod", "lane", "nap3",
                                        compress_slow=True, error_feedback=ef)
            total = total + synced["a"]
        return (total / 8.0)[None]
    f = shmap(body_ef, 1)
    avg = np.asarray(f(g1))[0]
    np.testing.assert_allclose(avg, expect1, atol=5e-3)  # tighter than 1 shot
    print("OK grad_sync")


if __name__ == "__main__":
    check_grad_sync()
    check_hier_psum()
    check_hier_all_gather()
    check_hier_all_to_all()
    check_halo_exchange()
    check_dist_spmv()
    try:
        check_collective_bytes_ordering()
    except ImportError:
        print("SKIP collective_bytes_ordering (roofline module not built yet)")
    print("ALL_OK")
