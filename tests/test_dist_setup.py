"""Distributed setup-phase tests.

Host-side: matrix comm-graph semantics, analyze_hierarchy vs select
consistency for the setup SpGEMMs, phase_costs aggregation with partial
strategy sets, the rank-faithful matrix-row halo exchange for all three
schedules, and exact parity of the partitioned setup loop against
``hierarchy.setup``.  The full partitioned-setup → DistHierarchy → PCG
session runs on an 8-device mesh in a subprocess
(``dist_setup_script.py``).
"""
import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

from repro.amg import AMGConfig, setup
from repro.amg.dist import (MATRIX_ENTRY, MATRIX_ROW_HEADER, OpComm,
                            analyze_hierarchy, matrix_comm_graph,
                            phase_costs, row_partition)
from repro.amg.dist_setup import (BlockMatrix, dist_setup_partitioned,
                                  split_rows, transpose_blocks)
from repro.amg.problems import laplace_3d, laplace_3d_7pt
from repro.core import BLUE_WATERS, Partition, Topology, select
from repro.core.nap_collectives import (build_matrix_halo_plan,
                                        matrix_halo_exchange)

SCRIPT = pathlib.Path(__file__).parent / "dist_setup_script.py"
EXPECTED = ["OK born_partitioned", "OK setup_selection", "OK level_parity",
            "OK pcg_parity", "OK session_cache", "ALL_OK"]


def _assemble(bm: BlockMatrix):
    acc = bm.blocks[0]
    for b in bm.blocks[1:]:
        acc = acc.add(b)
    return acc


# --------------------------------------------------------------------------
# matrix_comm_graph semantics + selection consistency (satellite)
# --------------------------------------------------------------------------


def test_matrix_comm_graph_semantics():
    """need[p] = rows of B for rank p's off-process A columns; weights are
    whole-row byte sizes of B."""
    A = laplace_3d_7pt(4)
    h = setup(A, solver="rs", max_coarse=10)
    P = h.levels[0].P
    topo = Topology(n_nodes=2, ppn=2)
    part = row_partition(A, topo)
    g = matrix_comm_graph(A, P, part)
    assert g.partition is part                    # B rows follow A's part
    np.testing.assert_allclose(
        g.weights, np.diff(P.indptr) * MATRIX_ENTRY + MATRIX_ROW_HEADER)
    for p in range(topo.n_procs):
        lo, hi = part.local_range(p)
        sl = slice(int(A.indptr[lo]), int(A.indptr[hi]))
        cols = A.indices[sl]
        expect = np.unique(cols[(cols < lo) | (cols >= hi)])
        np.testing.assert_array_equal(g.need[p], expect)


def test_matrix_comm_graph_rectangular_b_part():
    """Pᵀ·(AP): A=R on the coarse partition, B=AP rows on the fine one."""
    A = laplace_3d(6)
    h = setup(A, solver="rs", max_coarse=30)
    R, AP = h.levels[0].R, h.levels[0].AP
    topo = Topology(n_nodes=2, ppn=2)
    cpart = Partition.balanced(R.nrows, topo)
    fpart = Partition.balanced(AP.nrows, topo)
    g = matrix_comm_graph(R, AP, cpart, b_part=fpart)
    assert g.partition is fpart
    assert g.weights.size == AP.nrows
    for p in range(topo.n_procs):
        rlo, rhi = cpart.local_range(p)
        blo, bhi = fpart.local_range(p)
        np.testing.assert_array_equal(
            g.need[p], R.offproc_columns(blo, bhi, rlo, rhi))


def test_analyze_hierarchy_spgemm_matches_select():
    """analyze_hierarchy's spgemm_AP/spgemm_PtAP rows reproduce a by-hand
    matrix_comm_graph + select on the same level operators."""
    A = laplace_3d(6)
    h = setup(A, solver="rs", max_coarse=30)
    topo = Topology(n_nodes=4, ppn=4)
    ops = {(o.level, o.op): o for o in
           analyze_hierarchy(h, topo, BLUE_WATERS)}
    for l, lv in enumerate(h.levels):
        if lv.P is None:
            continue
        part = row_partition(lv.A, topo)
        cpart = Partition.balanced(lv.P.ncols, topo)
        byhand = {
            "spgemm_AP": matrix_comm_graph(lv.A, lv.P, part),
            "spgemm_PtAP": matrix_comm_graph(lv.R, lv.AP, cpart,
                                             b_part=part),
        }
        for op, g in byhand.items():
            sel = select(g, BLUE_WATERS)
            got = ops[(l, op)].selection
            assert got.strategy == sel.strategy
            assert got.times == pytest.approx(sel.times)


def test_phase_costs_skips_missing_times():
    """An op selected over a strategy subset must not poison the per-level
    table with inf (satellite fix)."""
    A = laplace_3d(6)
    h = setup(A, solver="rs", max_coarse=30)
    topo = Topology(n_nodes=2, ppn=2)
    part = row_partition(h.levels[0].A, topo)
    g = matrix_comm_graph(h.levels[0].A, h.levels[0].P, part)
    partial = OpComm(0, "spgemm_AP",
                     g, select(g, BLUE_WATERS, ("standard", "nap2")))
    full = OpComm(0, "spgemm_PtAP", g, select(g, BLUE_WATERS))
    costs = phase_costs([partial, full], 1)["setup"][0]
    for v in costs.values():
        assert np.isfinite(v)
    # the missing nap3 entry contributes nothing from the partial op
    assert costs["nap3"] == pytest.approx(full.selection.times["nap3"])
    assert costs["standard"] == pytest.approx(
        partial.selection.times["standard"] + full.selection.times["standard"])


# --------------------------------------------------------------------------
# Matrix-row halo exchange (MatrixHaloPlan)
# --------------------------------------------------------------------------


def test_matrix_halo_exchange_all_strategies():
    """Every schedule delivers exactly the needed B rows with exact values;
    node-aware schedules cross the network with no more bytes (de-dup) and
    no more messages than standard."""
    A = laplace_3d(6)
    h = setup(A, solver="rs", max_coarse=30)
    P = h.levels[0].P
    topo = Topology(n_nodes=2, ppn=4)
    part = row_partition(A, topo)
    g = matrix_comm_graph(A, P, part)
    Pb = split_rows(P, part)

    def get_row(rank, i):
        blk = Pb.blocks[rank]
        sl = slice(int(blk.indptr[i]), int(blk.indptr[i + 1]))
        return blk.indices[sl], blk.data[sl]

    measured = {}
    for strat in ("standard", "nap2", "nap3"):
        plan = build_matrix_halo_plan(g, strat)
        res = matrix_halo_exchange(plan, get_row)
        for q in range(topo.n_procs):
            assert set(res.halo[q]) == set(map(int, g.need[q]))
            for i, (cols, vals) in res.halo[q].items():
                sl = slice(int(P.indptr[i]), int(P.indptr[i + 1]))
                np.testing.assert_array_equal(cols, P.indices[sl])
                np.testing.assert_array_equal(vals, P.data[sl])
        measured[strat] = res
    for strat in ("nap2", "nap3"):
        assert measured[strat].inter_bytes <= measured["standard"].inter_bytes
        assert measured[strat].inter_msgs <= measured["standard"].inter_msgs
    assert measured["standard"].seconds >= 0


# --------------------------------------------------------------------------
# Partitioned setup loop: exact parity with hierarchy.setup
# --------------------------------------------------------------------------


@pytest.mark.parametrize("n,npods,lanes,aggressive", [
    (8, 2, 4, False),
    (6, 2, 2, True),
])
def test_dist_setup_partitioned_matches_host(n, npods, lanes, aggressive):
    A = laplace_3d(n)
    h = setup(A, solver="rs", aggressive=aggressive)
    plv, recs = dist_setup_partitioned(A, npods, lanes, params=BLUE_WATERS,
                                       aggressive=aggressive)
    assert len(plv) == h.n_levels
    for l, (lv, pl) in enumerate(zip(h.levels, plv)):
        for name in ("A", "P", "R", "AP"):
            ref, got = getattr(lv, name), getattr(pl, name)
            assert (ref is None) == (got is None), (l, name)
            if ref is None:
                continue
            # each rank's block holds only its own rows — never the level
            assert all(b.nnz < ref.nnz for b in got.blocks)
            asm = _assemble(got)
            assert asm.shape == ref.shape
            np.testing.assert_array_equal(asm.indptr, ref.indptr)
            np.testing.assert_array_equal(asm.indices, ref.indices)
            np.testing.assert_allclose(asm.data, ref.data, atol=1e-12)
    ops = {(r.level, r.op) for r in recs}
    for l in range(len(plv) - 1):
        assert (l, "spgemm_AP") in ops and (l, "spgemm_PtAP") in ops
    for r in recs:
        assert r.strategy in ("standard", "nap2", "nap3")
        assert r.modeled[r.strategy] == min(r.modeled.values())


def test_transpose_blocks_matches_host_transpose():
    A = laplace_3d(6)
    h = setup(A, solver="rs", max_coarse=30)
    P = h.levels[0].P
    topo = Topology(n_nodes=2, ppn=2)
    fpart = Partition.balanced(P.nrows, topo)
    cpart = Partition.balanced(P.ncols, topo)
    Rb = transpose_blocks(split_rows(P, fpart), cpart)
    R = P.T
    asm = _assemble(Rb)
    np.testing.assert_array_equal(asm.indptr, R.indptr)
    np.testing.assert_array_equal(asm.indices, R.indices)
    np.testing.assert_allclose(asm.data, R.data, atol=1e-15)


def test_dist_setup_rejects_sa():
    with pytest.raises(ValueError, match="solver='rs'"):
        dist_setup_partitioned(laplace_3d(4), 2, 2, solver="sa")


# --------------------------------------------------------------------------
# Config knob
# --------------------------------------------------------------------------


def test_setup_backend_config_validation_and_roundtrip():
    cfg = AMGConfig(setup_backend="dist", backend="dist", n_pods=2, lanes=4)
    d = cfg.to_dict()
    assert d["setup_backend"] == "dist"
    assert AMGConfig.from_dict(d) == cfg
    assert cfg.setup_kwargs()["solver"] == "rs"
    assert cfg.dist_build_kwargs()["n_pods"] == 2
    with pytest.raises(ValueError, match="backend"):
        AMGConfig(setup_backend="dist")            # host solve backend
    with pytest.raises(ValueError, match="setup_backend"):
        AMGConfig(setup_backend="bogus")
    with pytest.raises(ValueError, match="solver='rs'"):
        AMGConfig(setup_backend="dist", backend="dist", solver="sa")
    # the function entrypoint lives on the submodule (NOT re-exported from
    # repro.amg — it would collide with the submodule name there)
    import repro.amg
    import repro.amg.dist_setup
    assert callable(repro.amg.dist_setup.dist_setup)
    with pytest.raises(AttributeError):
        repro.amg.no_such_symbol


# --------------------------------------------------------------------------
# Full session on an 8-device mesh (subprocess)
# --------------------------------------------------------------------------


@pytest.mark.slow
def test_multidevice_dist_setup_subprocess():
    env = dict(os.environ)
    root = str(pathlib.Path(__file__).parents[1] / "src")
    env["PYTHONPATH"] = root + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, str(SCRIPT)], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    for marker in EXPECTED:
        assert marker in out.stdout, f"missing {marker!r} in:\n{out.stdout}"


@pytest.mark.slow
def test_benchmark_smoke_mode(tmp_path):
    """benchmarks/dist_setup.py --smoke emits host-vs-dist timings for ≥3
    sizes plus per-level modeled-vs-measured strategy rows, and writes
    BENCH_dist_setup.json."""
    env = dict(os.environ)
    root = pathlib.Path(__file__).parents[1]
    env["PYTHONPATH"] = str(root / "src") + os.pathsep + env.get("PYTHONPATH", "")
    out_json = tmp_path / "BENCH_dist_setup.json"
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.dist_setup", "--smoke",
         "--out", str(out_json)],
        capture_output=True, text=True, env=env, cwd=root, timeout=600)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    import json
    data = json.loads(out_json.read_text())
    assert data["benchmark"] == "dist_setup"
    names = [r["name"] for r in data["rows"]]
    assert sum(n.startswith("host_setup_n") for n in names) >= 3
    assert sum(n.startswith("dist_setup_n") for n in names) >= 3
    spg = [r for r in data["rows"] if "_spgemm_" in r["name"]]
    assert spg, names
    for r in spg:
        assert "strategy=" in r["derived"] and "modeled_us=" in r["derived"]
