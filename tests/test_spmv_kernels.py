"""Kernel-tier validation for the multi-RHS SpMM and BCSR paths.

Covers what test_kernels.py's single-RHS checks do not: the native ELL
SpMM kernel against both the vmapped single-RHS kernel and the host CSR
oracle (fp32/fp64, ragged K, padded rows), BCSR round-trips and the block
contraction's dense equivalence, the degenerate shapes that used to crash
``ell_spmv`` (K == 0, n == 0, empty x, k == 0), and hypothesis-style
random-sparsity sweeps under the deterministic stub."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.amg.csr import CSR, csr_to_bcsr
from repro.amg.problems import laplace_3d, laplace_3d_7pt
from repro.kernels.spmv.bcsr import (BLOCK_SIZES, bcsr_apply_ref, bcsr_spmm,
                                     bcsr_spmv)
from repro.kernels.spmv.ops import (select_dist_kernel, select_local_kernel,
                                    spmm)
from repro.kernels.spmv.ref import ell_spmm_ref, ell_spmv_ref
from repro.kernels.spmv.spmv import ell_spmm, ell_spmv


def _random_ell(rng, n, m, K, dtype, pad_rows=0):
    """Random ELL block; ``pad_rows`` trailing rows are all-padding."""
    cols = rng.integers(0, m, size=(n, K)).astype(np.int32)
    mask = rng.random((n, K)) < 0.3
    cols[mask] = -1
    if pad_rows:
        cols[n - pad_rows:] = -1
    vals = rng.standard_normal((n, K)).astype(dtype)
    vals[cols == -1] = 0.0
    return jnp.asarray(cols), jnp.asarray(vals)


def _ell_to_csr(cols, vals, m):
    cols = np.asarray(cols)
    vals = np.asarray(vals, dtype=np.float64)
    keep = cols >= 0
    r = np.broadcast_to(np.arange(cols.shape[0])[:, None], cols.shape)[keep]
    return CSR.from_coo(r, cols[keep], vals[keep], (cols.shape[0], m))


# ---------------------------------------------------------------- ELL SpMM
@pytest.mark.parametrize("n,m,K,k", [(8, 16, 3, 2), (100, 64, 7, 4),
                                     (257, 300, 27, 8), (64, 64, 1, 5)])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_ell_spmm_matches_vmapped_spmv_and_csr(n, m, K, k, dtype):
    if dtype == np.float64 and not jax.config.jax_enable_x64:
        dtype = np.float32     # x64 disabled in-process: still run the shape
    rng = np.random.default_rng(n * K + k)
    cols, vals = _random_ell(rng, n, m, K, dtype, pad_rows=3)
    X = jnp.asarray(rng.standard_normal((m, k)).astype(dtype))
    out = ell_spmm(cols, vals, X, interpret=True)
    assert out.shape == (n, k)
    # bit-for-bit vs the vmapped single-RHS kernel — the parity the native
    # multi-RHS routing in dist_solve relies on
    vmapped = jax.vmap(lambda xc: ell_spmv(cols, vals, xc, interpret=True),
                       in_axes=1, out_axes=1)(X)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(vmapped))
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ell_spmm_ref(cols, vals, X)))
    # vs the host CSR oracle, column by column
    Acsr = _ell_to_csr(cols, vals, m)
    ref = np.stack([Acsr.matvec(np.asarray(X[:, j], dtype=np.float64))
                    for j in range(k)], axis=1)
    tol = 1e-5 if np.dtype(dtype) == np.float32 else 1e-12
    np.testing.assert_allclose(np.asarray(out, np.float64), ref,
                               rtol=tol, atol=tol)


def test_ell_spmm_ragged_k_and_block_rows_sweep():
    rng = np.random.default_rng(11)
    cols, vals = _random_ell(rng, 203, 150, 13, np.float32, pad_rows=7)
    X = jnp.asarray(rng.standard_normal((150, 6)).astype(np.float32))
    ref = ell_spmm_ref(cols, vals, X)
    for br in (8, 32, 64, 512):
        out = ell_spmm(cols, vals, X, block_rows=br, interpret=True)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_spmm_dispatch_matches_kernel():
    rng = np.random.default_rng(2)
    cols, vals = _random_ell(rng, 40, 32, 5, np.float32)
    X = jnp.asarray(rng.standard_normal((32, 3)).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(spmm(cols, vals, X, use_kernel=True, interpret=True)),
        np.asarray(spmm(cols, vals, X, use_kernel=False)))


# --------------------------------------------------------- degenerate shapes
def test_ell_spmv_degenerate_shapes():
    """K == 0 / n == 0 / empty x used to crash pallas_call; now exact zeros."""
    f32 = jnp.float32
    y = ell_spmv(jnp.zeros((5, 0), jnp.int32), jnp.zeros((5, 0), f32),
                 jnp.ones((7,), f32), interpret=True)
    np.testing.assert_array_equal(np.asarray(y), np.zeros(5))
    y = ell_spmv(jnp.zeros((0, 3), jnp.int32), jnp.zeros((0, 3), f32),
                 jnp.ones((7,), f32), interpret=True)
    assert y.shape == (0,)
    y = ell_spmv(jnp.full((4, 2), -1, jnp.int32), jnp.zeros((4, 2), f32),
                 jnp.zeros((0,), f32), interpret=True)
    np.testing.assert_array_equal(np.asarray(y), np.zeros(4))


def test_ell_spmm_degenerate_shapes():
    f32 = jnp.float32
    for cols_s, x_s, out_s in [((5, 0), (7, 3), (5, 3)),   # K == 0
                               ((0, 3), (7, 2), (0, 2)),   # n == 0
                               ((4, 2), (0, 3), (4, 3)),   # empty x
                               ((4, 2), (7, 0), (4, 0))]:  # k == 0
        y = ell_spmm(jnp.zeros(cols_s, jnp.int32) - 1,
                     jnp.zeros(cols_s, f32), jnp.zeros(x_s, f32),
                     interpret=True)
        assert y.shape == out_s
        np.testing.assert_array_equal(np.asarray(y), np.zeros(out_s))


def test_ell_spmv_tiny_n_no_overpadding():
    """n < 8 rows must not crash nor over-pad past one block."""
    rng = np.random.default_rng(0)
    for n in (1, 3, 7):
        cols, vals = _random_ell(rng, n, 10, 4, np.float32)
        x = jnp.asarray(rng.standard_normal(10).astype(np.float32))
        np.testing.assert_array_equal(
            np.asarray(ell_spmv(cols, vals, x, interpret=True)),
            np.asarray(ell_spmv_ref(cols, vals, x)))


# -------------------------------------------------------------------- BCSR
@pytest.mark.parametrize("bs", BLOCK_SIZES)
def test_csr_to_bcsr_round_trip(bs):
    A = laplace_3d(5)
    B = csr_to_bcsr(A, bs)
    dense = A.to_dense()
    np.testing.assert_array_equal(B.to_dense(), dense)
    assert B.bcols.shape[0] == -(-A.nrows // bs)
    assert 0.0 < B.fill <= 1.0
    # every stored block id in range, padding all -1-terminated per row
    assert B.bcols.max() < -(-A.ncols // bs)


def test_csr_to_bcsr_empty():
    B = csr_to_bcsr(CSR.from_coo([], [], [], (10, 10)), 8)
    assert B.bcols.shape == (2, 0)
    np.testing.assert_array_equal(B.to_dense(), np.zeros((10, 10)))


@pytest.mark.parametrize("bs", BLOCK_SIZES)
def test_bcsr_spmm_matches_dense(bs):
    A = laplace_3d(5)
    B = csr_to_bcsr(A, bs)
    rng = np.random.default_rng(bs)
    X = rng.standard_normal((A.ncols, 4)).astype(np.float32)
    bcols = jnp.asarray(B.bcols)
    bvals = jnp.asarray(B.bvals, dtype=jnp.float32)
    out = bcsr_spmm(bcols, bvals, jnp.asarray(X), interpret=True)
    ref = A.to_dense().astype(np.float32) @ X
    np.testing.assert_allclose(np.asarray(out)[: A.nrows], ref,
                               rtol=2e-5, atol=2e-5)
    # the pure-jnp oracle matches the kernel's summation order exactly
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(bcsr_apply_ref(bcols, bvals,
                                                   jnp.asarray(X))))
    # single-RHS wrapper
    y = bcsr_spmv(bcols, bvals, jnp.asarray(X[:, 0]), interpret=True)
    np.testing.assert_allclose(np.asarray(y)[: A.nrows], ref[:, 0],
                               rtol=2e-5, atol=2e-5)


# ------------------------------------------------------ layout heuristic
def test_select_local_kernel_shapes():
    A = laplace_3d(5)
    K = int(np.diff(A.indptr).max())
    cols = np.full((A.nrows, K), -1, dtype=np.int32)
    lens = np.diff(A.indptr)
    r = A.rows_expanded()
    slot = np.arange(A.nnz) - np.repeat(A.indptr[:-1], lens)
    cols[r, slot] = A.indices
    sel = select_local_kernel(cols)
    assert sel["kernel"] in ("ell", "bcsr")
    assert 0.0 < sel["ell_fill"] <= 1.0
    if sel["kernel"] == "bcsr":
        assert sel["block_size"] in BLOCK_SIZES
        assert sel["bcsr_cost"] < sel["ell_cost"]
    # empty block → ELL trivially
    assert select_local_kernel(
        np.full((4, 2), -1, np.int32))["kernel"] == "ell"
    # the stacked form agrees with per-device aggregation
    sel_d = select_dist_kernel(cols[None])
    assert sel_d["kernel"] == sel["kernel"]


# --------------------------------- hypothesis-style random sparsity sweeps
@settings(max_examples=12, deadline=None)
@given(st.integers(1, 120), st.integers(1, 90), st.integers(1, 12),
       st.integers(1, 6), st.integers(0, 10 ** 6))
def test_ell_spmm_random_sparsity(n, m, K, k, seed):
    rng = np.random.default_rng(seed)
    cols, vals = _random_ell(rng, n, m, K, np.float32,
                             pad_rows=int(rng.integers(0, n)))
    X = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    out = ell_spmm(cols, vals, X, interpret=True)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ell_spmm_ref(cols, vals, X)))


@settings(max_examples=8, deadline=None)
@given(st.integers(6, 40), st.sampled_from(list(BLOCK_SIZES)),
       st.integers(0, 10 ** 6))
def test_bcsr_random_round_trip(n, bs, seed):
    rng = np.random.default_rng(seed)
    dense = np.where(rng.random((n, n)) < 0.15,
                     rng.standard_normal((n, n)), 0.0)
    A = CSR.from_dense(dense)
    B = csr_to_bcsr(A, bs)
    np.testing.assert_array_equal(B.to_dense(), dense)
    X = rng.standard_normal((n, 3))
    out = np.asarray(bcsr_apply_ref(jnp.asarray(B.bcols),
                                    jnp.asarray(B.bvals),
                                    jnp.asarray(X, dtype=jnp.float64)
                                    if jax.config.jax_enable_x64
                                    else jnp.asarray(X,
                                                     dtype=jnp.float32)))
    ref = dense @ X
    np.testing.assert_allclose(out[:n], ref, rtol=2e-4, atol=2e-4)


def test_spmv_kernel_on_7pt_operator():
    """The laplace_3d_7pt path of test_kernels extended to the SpMM form."""
    A = laplace_3d_7pt(6)
    K = int(np.diff(A.indptr).max())
    cols = np.full((A.nrows, K), -1, dtype=np.int32)
    vals = np.zeros((A.nrows, K), dtype=np.float32)
    lens = np.diff(A.indptr)
    r = A.rows_expanded()
    slot = np.arange(A.nnz) - np.repeat(A.indptr[:-1], lens)
    cols[r, slot] = A.indices
    vals[r, slot] = A.data
    X = np.random.default_rng(0).standard_normal(
        (A.ncols, 4)).astype(np.float32)
    out = ell_spmm(jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(X),
                   interpret=True)
    ref = np.stack([A.matvec(X[:, j].astype(np.float64)) for j in range(4)],
                   axis=1)
    np.testing.assert_allclose(np.asarray(out, np.float64), ref,
                               rtol=2e-4, atol=2e-4)
