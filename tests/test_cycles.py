"""Cycle-shape + smoother breadth tests (host reference path).

Property-style coverage through the hypothesis API (the deterministic stub
of ``_hypothesis_stub.py`` when the real package is absent): ANY
(cycle, smoother) combination on a randomly perturbed SPD Poisson problem
must monotonically reduce the residual over 5 stationary iterations.  The
multi-device distributed counterpart (all 12 pairs at 1e-7 host↔dist
parity) runs in the ``dist_solve_script.py`` subprocess test.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.amg import SolveOptions, setup, solve
from repro.amg.csr import CSR
from repro.amg.problems import laplace_3d_7pt
from repro.amg.smoothers import (balanced_offsets, block_diag_inv,
                                 block_jacobi, block_partition, hybrid_gs,
                                 hybrid_gs_sym)
from repro.amg.solve import (CYCLE_CHILDREN, CYCLES, SMOOTHERS, host_cycle,
                             host_pcg, level_visits)


def random_spd_poisson(rng: np.random.Generator) -> CSR:
    """A randomly perturbed SPD Poisson problem: the 7-point Laplacian with
    a random positive diagonal shift (keeps SPD + diagonal dominance)."""
    n = int(rng.integers(4, 7))
    A = laplace_3d_7pt(n)
    shift = rng.uniform(0.0, 0.3, size=A.nrows)
    return A.add(CSR.from_diag(shift))


@settings(max_examples=12, deadline=None)
@given(st.sampled_from(CYCLES), st.sampled_from(SMOOTHERS),
       st.integers(1, 4), st.integers(1, 8))
def test_any_cycle_smoother_monotone_on_random_spd(cycle, smoother,
                                                   block_size, parts):
    import zlib
    seed = zlib.crc32(f"{cycle}/{smoother}/{block_size}/{parts}".encode())
    rng = np.random.default_rng(seed)
    A = random_spd_poisson(rng)
    h = setup(A, solver="rs", max_coarse=20)
    b = rng.standard_normal(A.nrows)
    opts = SolveOptions(cycle=cycle, smoother=smoother,
                        block_size=block_size, smoother_parts=parts)
    res = solve(h, b, tol=0.0, maxiter=5, opts=opts)
    r = res.residuals
    assert len(r) == 6
    for i in range(5):
        assert r[i + 1] < r[i] or r[i + 1] < 1e-12, \
            (cycle, smoother, i, r)


def test_cycle_children_and_visits():
    """W visits level ℓ 2^ℓ times, F visits it ℓ+1 times, V once."""
    assert level_visits(4, "V") == [1, 1, 1, 1]
    assert level_visits(4, "W") == [1, 2, 4, 8]
    assert level_visits(4, "F") == [1, 2, 3, 4]
    assert set(CYCLE_CHILDREN) == set(CYCLES)


def test_solve_options_validation():
    with pytest.raises(ValueError):
        SolveOptions(cycle="X")
    with pytest.raises(ValueError):
        SolveOptions(smoother="sor")
    with pytest.raises(ValueError):
        SolveOptions(block_size=0)
    with pytest.raises(ValueError):
        SolveOptions(smoother_parts=0)


def test_w_and_f_cycles_beat_or_match_v_per_iteration():
    """On a 3+ level hierarchy the extra coarse visits must not hurt:
    W/F convergence factors stay within a whisker of V's."""
    A = laplace_3d_7pt(8)
    h = setup(A, solver="rs", max_coarse=20)
    assert h.n_levels >= 3
    b = A.matvec(np.ones(A.nrows))
    conv = {}
    for cycle in CYCLES:
        res = solve(h, b, tol=0.0, maxiter=6,
                    opts=SolveOptions(cycle=cycle))
        conv[cycle] = res.avg_conv_factor
    assert conv["W"] < conv["V"] * 1.5 + 0.05
    assert conv["F"] < conv["V"] * 1.5 + 0.05


def test_block_jacobi_reduces_to_jacobi_at_block_size_one():
    A = laplace_3d_7pt(5)
    rng = np.random.default_rng(3)
    b = rng.standard_normal(A.nrows)
    x0 = np.zeros_like(b)
    xj = A.diagonal()                      # jacobi reference
    dinv = 1.0 / xj
    x_jac = x0 + (2.0 / 3.0) * dinv * b
    x_bj = block_jacobi(A, x0, b, block_size=1)
    np.testing.assert_allclose(x_bj, x_jac, rtol=1e-13)


def test_block_partition_respects_parts():
    """Blocks never straddle a part boundary; sizes cover all rows."""
    blocks = block_partition(90, 4, parts=8)
    offsets = balanced_offsets(90, 8)
    covered = []
    for s, e in blocks:
        assert e - s <= 4
        part = np.searchsorted(offsets, s, side="right") - 1
        assert offsets[part] <= s < e <= offsets[part + 1]
        covered.extend(range(s, e))
    assert covered == list(range(90))


def test_block_diag_inv_inverts_diag_blocks():
    A = laplace_3d_7pt(4)
    binv = block_diag_inv(A, 4)
    dense = A.to_dense()
    for s, inv in binv:
        e = s + inv.shape[0]
        np.testing.assert_allclose(inv @ dense[s:e, s:e], np.eye(e - s),
                                   atol=1e-10)


def test_hybrid_gs_single_part_is_exact_forward_gs():
    """boundaries=[0,n] must reproduce textbook sequential forward GS."""
    A = laplace_3d_7pt(4)
    rng = np.random.default_rng(11)
    b = rng.standard_normal(A.nrows)
    x = hybrid_gs(A, np.zeros_like(b), b)
    dense = A.to_dense()
    ref = np.zeros_like(b)
    for i in range(A.nrows):              # textbook forward substitution
        ref[i] = (b[i] - dense[i, :i] @ ref[:i]) / dense[i, i]
    np.testing.assert_allclose(x, ref, rtol=1e-12)


def test_hybrid_gs_parts_match_blockwise_solve():
    """With k parts, one sweep equals x + blockdiag(D+L)⁻¹ (b − A x)."""
    A = laplace_3d_7pt(4)
    rng = np.random.default_rng(12)
    b = rng.standard_normal(A.nrows)
    bounds = balanced_offsets(A.nrows, 3)
    x = hybrid_gs(A, np.zeros_like(b), b, boundaries=bounds)
    dense = A.to_dense()
    ref = np.zeros_like(b)
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        M = np.tril(dense[lo:hi, lo:hi])
        ref[lo:hi] = np.linalg.solve(M, b[lo:hi])
    np.testing.assert_allclose(x, ref, rtol=1e-11)


def test_hybrid_gs_sym_single_part_is_textbook_sgs():
    """boundaries=[0,n]: one sweep must equal forward GS then backward GS,
    each against a freshly recomputed residual."""
    A = laplace_3d_7pt(4)
    rng = np.random.default_rng(13)
    b = rng.standard_normal(A.nrows)
    x = hybrid_gs_sym(A, np.zeros_like(b), b)
    dense = A.to_dense()
    L = np.tril(dense)                     # D + strictly lower
    U = np.triu(dense)                     # D + strictly upper
    ref = np.linalg.solve(L, b)            # forward half from x=0
    ref = ref + np.linalg.solve(U, b - dense @ ref)   # backward half
    np.testing.assert_allclose(x, ref, rtol=1e-11)


def test_hybrid_gs_sym_parts_match_blockwise_tri_solves():
    """With k parts each half-sweep equals x + blockdiag(D+T)⁻¹ (b − A x)."""
    A = laplace_3d_7pt(4)
    rng = np.random.default_rng(14)
    b = rng.standard_normal(A.nrows)
    bounds = balanced_offsets(A.nrows, 3)
    x = hybrid_gs_sym(A, np.zeros_like(b), b, boundaries=bounds)
    dense = A.to_dense()
    ref = np.zeros_like(b)
    for tri in (np.tril, np.triu):
        r = b - dense @ ref
        z = np.zeros_like(b)
        for lo, hi in zip(bounds[:-1], bounds[1:]):
            M = tri(dense[lo:hi, lo:hi])
            z[lo:hi] = np.linalg.solve(M, r[lo:hi])
        ref = ref + z
    np.testing.assert_allclose(x, ref, rtol=1e-11)


def test_hybrid_gs_sym_cycle_is_spd_preconditioner():
    """The cycle with the symmetric smoother is a symmetric positive
    definite operator (what PCG requires); the forward-only hybrid GS
    cycle is not symmetric — that asymmetry is the gap this smoother
    closes."""
    A = laplace_3d_7pt(4)
    h = setup(A, solver="rs", max_coarse=20)
    n = A.nrows

    def cycle_matrix(opts):
        M = np.zeros((n, n))
        for i in range(n):
            e = np.zeros(n)
            e[i] = 1.0
            M[:, i] = host_cycle(h, e, None, opts)
        return M

    Msym = cycle_matrix(SolveOptions(smoother="hybrid_gs_sym"))
    scale = np.abs(Msym).max()
    assert np.abs(Msym - Msym.T).max() < 1e-12 * scale
    assert np.linalg.eigvalsh(0.5 * (Msym + Msym.T)).min() > 0
    Mfwd = cycle_matrix(SolveOptions(smoother="hybrid_gs"))
    assert np.abs(Mfwd - Mfwd.T).max() > 1e-6 * np.abs(Mfwd).max()
    # and PCG with the SPD preconditioner converges cleanly
    b = A.matvec(np.ones(n))
    res = host_pcg(h, b, tol=1e-10, maxiter=40,
                   opts=SolveOptions(smoother="hybrid_gs_sym"))
    assert res.converged


def test_hybrid_gs_sym_costs_two_spmvs_per_sweep():
    assert SolveOptions(smoother="hybrid_gs_sym").spmvs_per_sweep() == 2
    assert SolveOptions(smoother="hybrid_gs").spmvs_per_sweep() == 1


def test_host_pcg_refactor_matches_reference_history():
    """The deduplicated host_pcg loop reproduces the classic CG recurrence
    (checked against an inline reference implementation)."""
    A = laplace_3d_7pt(5)
    h = setup(A, solver="rs", max_coarse=20)
    b = A.matvec(np.ones(A.nrows))
    opts = SolveOptions()
    res = host_pcg(h, b, tol=1e-10, maxiter=60, opts=opts)
    # inline reference: the pre-refactor duplicated-body formulation
    x = np.zeros_like(b)
    r = b.copy()
    z = host_cycle(h, r, None, opts)
    p = z.copy()
    rz = float(r @ z)
    ref = [float(np.linalg.norm(r))]
    for _ in range(res.iterations):
        Ap = A.matvec(p)
        alpha = rz / float(p @ Ap)
        x += alpha * p
        r -= alpha * Ap
        ref.append(float(np.linalg.norm(r)))
        z = host_cycle(h, r, None, opts)
        rz_new = float(r @ z)
        p = z + (rz_new / rz) * p
        rz = rz_new
    np.testing.assert_allclose(res.residuals, ref, rtol=1e-10)
    assert res.converged


def test_solve_knob_only_configs_share_setup_and_lowering():
    """Session cache: configs differing only in cycle/smoother share ONE
    hierarchy (and one dist lowering through its dist_cache)."""
    from repro.amg.api import AMGConfig, AMGSolver, clear_sessions

    clear_sessions()
    A = laplace_3d_7pt(5)
    cfgs = [AMGConfig(opts=SolveOptions(cycle=c, smoother=s))
            for c, s in (("V", "jacobi"), ("W", "jacobi"),
                         ("F", "block_jacobi"), ("V", "hybrid_gs"))]
    bounds = [AMGSolver(c).setup(A) for c in cfgs]
    assert len({id(b) for b in bounds}) == 4      # distinct bound solvers
    assert len({id(b.hierarchy) for b in bounds}) == 1  # ONE hierarchy
    # dist flavor: one DistHierarchy shared through the hierarchy dist_cache
    dcfgs = [c.replace(backend="dist") for c in cfgs[:2]]
    dbounds = [AMGSolver(c).setup(A) for c in dcfgs]
    dhs = [b.dist_hierarchy for b in dbounds]
    assert dhs[0] is dhs[1]
    # and the two option sets got their own compiled program entries
    b0 = A.matvec(np.ones(A.nrows))
    for db in dbounds:
        assert db.solve(b0, tol=0.0, maxiter=2).iterations >= 0
    assert len(dhs[0]._programs) == 2
    clear_sessions()
