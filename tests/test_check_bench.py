"""Tests for the CI benchmark regression gate (scripts/check_bench.py)."""
import importlib.util
import json
import pathlib

SCRIPT = pathlib.Path(__file__).parents[1] / "scripts" / "check_bench.py"
_spec = importlib.util.spec_from_file_location("check_bench", SCRIPT)
check_bench = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_bench)


def write(path, rows):
    path.write_text(json.dumps({"benchmark": "t", "rows": rows}))
    return str(path)


def row(name, derived):
    return {"name": name, "us_per_call": 1.0, "derived": derived}


def run(tmp_path, base_rows, new_rows, max_ratio=2.0):
    base = write(tmp_path / "base.json", base_rows)
    new = write(tmp_path / "new.json", new_rows)
    return check_bench.main(["--baseline", base, "--new", new,
                             "--max-ratio", str(max_ratio)])


def test_identical_passes(tmp_path):
    rows = [row("a", "iters=10;conv=0.25;levels=3")]
    assert run(tmp_path, rows, rows) == 0


def test_wallclock_is_not_gated(tmp_path):
    base = [row("a", "iters=10;conv=0.25")]
    new = [{"name": "a", "us_per_call": 1e9,
            "derived": "iters=10;conv=0.25"}]
    assert run(tmp_path, base, new) == 0


def test_iteration_regression_fails(tmp_path):
    base = [row("a", "iters=10;conv=0.25")]
    assert run(tmp_path, base, [row("a", "iters=22;conv=0.25")]) == 1
    # within 2x (+1 slack) passes
    assert run(tmp_path, base, [row("a", "iters=20;conv=0.25")]) == 0


def test_conv_regression_and_divergence_fail(tmp_path):
    base = [row("a", "iters=10;conv=0.25")]
    assert run(tmp_path, base, [row("a", "iters=10;conv=0.60")]) == 1
    base2 = [row("a", "conv=0.80")]
    assert run(tmp_path, base2, [row("a", "conv=1.10")]) == 1
    assert run(tmp_path, base2, [row("a", "conv=0.90")]) == 0


def test_missing_row_and_error_rows_fail(tmp_path):
    base = [row("a", "conv=0.25"), row("b", "conv=0.30")]
    assert run(tmp_path, base, [row("a", "conv=0.25")]) == 1
    new = base + [row("dist_solve_ERROR", "boom")]
    assert run(tmp_path, base, new) == 1


def test_levels_mismatch_fails(tmp_path):
    base = [row("a", "levels=3;conv=0.2")]
    assert run(tmp_path, base, [row("a", "levels=2;conv=0.2")]) == 1


def test_serving_rows_gate_presence_and_divergence_only(tmp_path):
    """Serving rows: throughput (solves_per_s) may move freely — only a
    missing row, a diverged worst_rel, or new unconverged solves fail."""
    base = [row("serve_coalesced_host",
                "backend=host;requests=4;solves_per_s=120.0;batches=1;"
                "worst_rel=3.1e-09;unconverged=0")]
    # 100x slower serving still passes (wall-clock derived, not gated)
    ok = [row("serve_coalesced_host",
              "backend=host;requests=4;solves_per_s=1.2;batches=1;"
              "worst_rel=8.0e-07;unconverged=0")]
    assert run(tmp_path, base, ok) == 0
    # a diverged residual fails
    bad = [row("serve_coalesced_host",
               "backend=host;requests=4;solves_per_s=120.0;batches=1;"
               "worst_rel=2.5e+00;unconverged=0")]
    assert run(tmp_path, base, bad) == 1
    # fresh unconverged solves fail when the baseline had none
    unc = [row("serve_coalesced_host",
               "backend=host;requests=4;solves_per_s=120.0;batches=1;"
               "worst_rel=3.1e-09;unconverged=2")]
    assert run(tmp_path, base, unc) == 1
    # a missing serving row fails (presence)
    assert run(tmp_path, base, [row("other", "conv=0.2")]) == 1
    # a NaN residual must parse and fail — it cannot hide from the gate
    nan = [row("serve_coalesced_host",
               "backend=host;requests=4;solves_per_s=120.0;batches=1;"
               "worst_rel=nan;unconverged=0")]
    assert check_bench.parse_derived(nan[0]["derived"])["worst_rel"] != \
        check_bench.parse_derived(nan[0]["derived"])["worst_rel"]  # is NaN
    assert run(tmp_path, base, nan) == 1


def test_no_overlap_fails(tmp_path):
    base = [row("a_n4096", "conv=0.25")]
    assert run(tmp_path, base, [row("a_n512", "conv=0.25")]) == 1


def test_parse_derived_skips_non_numeric():
    d = check_bench.parse_derived(
        "n=512;mesh=2x4;conv=0.166;strategy=nap2;speedup=45.9x;iters=7")
    assert d["n"] == 512 and d["conv"] == 0.166 and d["iters"] == 7
    assert "mesh" not in d and "strategy" not in d and "speedup" not in d


def test_overlap_level_rows_gate_split_exactness(tmp_path):
    """dist_overlap_L* rows: on+off must equal local nnz, fields finite."""
    good = row("dist_overlap_L0",
               "on_nnz=3872;off_nnz=6776;local_nnz=10648;halo_empty=0;"
               "eff_modeled=0.0004;strategy=standard")
    assert run(tmp_path, [good], [good]) == 0
    # split that does not partition the local block fails
    bad = [row("dist_overlap_L0",
               "on_nnz=3872;off_nnz=6776;local_nnz=10000;halo_empty=0;"
               "eff_modeled=0.0004;strategy=standard")]
    assert run(tmp_path, [good], bad) == 1
    # a missing split field fails
    missing = [row("dist_overlap_L0",
                   "on_nnz=3872;local_nnz=10648;eff_modeled=0.1")]
    assert run(tmp_path, [good], missing) == 1
    # non-finite efficiency fails
    nan_eff = [row("dist_overlap_L0",
                   "on_nnz=1;off_nnz=1;local_nnz=2;eff_modeled=nan")]
    assert run(tmp_path, [good], nan_eff) == 1


def test_overlap_cycle_rows_gate_structure_not_magnitude(tmp_path):
    """dist_overlap_cycle_*: timings finite+positive, speedup recorded;
    the speedup magnitude itself may move freely."""
    base = [row("dist_overlap_cycle_V",
                "serial_us=2879.68;overlap_us=2408.04;speedup=1.196;"
                "mesh=2x4;n=512")]
    slower = [row("dist_overlap_cycle_V",
                  "serial_us=100.0;overlap_us=900.0;speedup=0.111;"
                  "mesh=2x4;n=512")]
    assert run(tmp_path, base, slower) == 0     # magnitude ungated
    no_speedup = [row("dist_overlap_cycle_V",
                      "serial_us=100.0;overlap_us=90.0;mesh=2x4;n=512")]
    assert run(tmp_path, base, no_speedup) == 1
    bad_t = [row("dist_overlap_cycle_V",
                 "serial_us=inf;overlap_us=90.0;speedup=1.0;mesh=2x4")]
    assert run(tmp_path, base, bad_t) == 1


STREAMING_DERIVED = ("n=512;mesh=2x4;steps=4;solves=5;refreshes=3;"
                     "resetups=1;cached=1;max_iters=7;iters=7:7:7:7:7;"
                     "triggers=drift:3,regression:1;refresh_us=16000.0;"
                     "resetup_us=38000.0;speedup=2.4")


COMM_AUDIT_DERIVED = ("mesh=2x4;collectives=24;expected=24;bytes=15480;"
                      "agree=1;violations=0")
COMM_AUDIT_SETUP_DERIVED = ("strategy=standard;static_inter_msgs=2;"
                            "runtime_inter_msgs=2;static_intra_msgs=12;"
                            "runtime_intra_msgs=12;violations=0")


def test_overlap_rows_required_with_cycle_sweep(tmp_path):
    """A run with the dist-solve cycle sweep but no overlap (or streaming,
    or comm-audit) rows fails."""
    cyc = row("dist_cycle_V_jacobi", "iters=7;conv=0.17;inter_msgs=10")
    ovl = row("dist_overlap_L0",
              "on_nnz=1;off_nnz=1;local_nnz=2;eff_modeled=0.0")
    ovc = row("dist_overlap_cycle_V",
              "serial_us=10.0;overlap_us=9.0;speedup=1.1")
    stm = row("streaming_refresh", STREAMING_DERIVED)
    aud = row("comm_audit_V_jacobi", COMM_AUDIT_DERIVED)
    aus = row("comm_audit_setup_L0_spgemm_AP", COMM_AUDIT_SETUP_DERIVED)
    assert run(tmp_path, [cyc], [cyc]) == 1              # all missing
    assert run(tmp_path, [cyc], [cyc, ovl]) == 1         # cycle row missing
    assert run(tmp_path, [cyc], [cyc, ovl, ovc]) == 1    # streaming missing
    assert run(tmp_path, [cyc], [cyc, ovl, ovc, stm]) == 1   # audit missing
    assert run(tmp_path, [cyc],
               [cyc, ovl, ovc, stm, aud]) == 1       # setup audit missing
    assert run(tmp_path, [cyc], [cyc, ovl, ovc, stm, aud, aus]) == 0


def test_comm_audit_rows_gate_model_agreement(tmp_path):
    """comm_audit_* rows: traced collective counts must equal the model's
    predicted counts with zero violations; comm_audit_setup_L* rows must
    show measured == static exchange counters."""
    good = row("comm_audit_V_jacobi", COMM_AUDIT_DERIVED)
    assert run(tmp_path, [good], [good]) == 0
    drift = [row("comm_audit_V_jacobi",
                 COMM_AUDIT_DERIVED.replace("expected=24", "expected=23"))]
    assert run(tmp_path, [good], drift) == 1
    disagree = [row("comm_audit_V_jacobi",
                    COMM_AUDIT_DERIVED.replace("agree=1", "agree=0"))]
    assert run(tmp_path, [good], disagree) == 1
    vio = [row("comm_audit_V_jacobi",
               COMM_AUDIT_DERIVED.replace("violations=0", "violations=2"))]
    assert run(tmp_path, [good], vio) == 1
    nan_c = [row("comm_audit_V_jacobi",
                 COMM_AUDIT_DERIVED.replace("collectives=24",
                                            "collectives=nan"))]
    assert run(tmp_path, [good], nan_c) == 1
    setup_good = row("comm_audit_setup_L0_spgemm_AP",
                     COMM_AUDIT_SETUP_DERIVED)
    assert run(tmp_path, [setup_good], [setup_good]) == 0
    setup_bad = [row("comm_audit_setup_L0_spgemm_AP",
                     COMM_AUDIT_SETUP_DERIVED.replace(
                         "runtime_intra_msgs=12", "runtime_intra_msgs=11"))]
    assert run(tmp_path, [setup_good], setup_bad) == 1
    setup_short = [row("comm_audit_setup_L0_spgemm_AP",
                       "strategy=standard;static_inter_msgs=2;violations=0")]
    assert run(tmp_path, [setup_good], setup_short) == 1


def test_streaming_rows_gate_refresh_beats_resetup(tmp_path):
    """streaming_* rows: refresh_us < resetup_us is the one gated timing
    ordering; counters must balance; iteration counts must stay finite."""
    good = row("streaming_refresh", STREAMING_DERIVED)
    assert run(tmp_path, [good], [good]) == 0
    # a refresh that costs as much as (or more than) the re-setup fails
    slow = [row("streaming_refresh",
                STREAMING_DERIVED.replace("refresh_us=16000.0",
                                          "refresh_us=99000.0"))]
    assert run(tmp_path, [good], slow) == 1
    # unbalanced solve accounting fails
    unbal = [row("streaming_refresh",
                 STREAMING_DERIVED.replace("cached=1", "cached=3"))]
    assert run(tmp_path, [good], unbal) == 1
    # a missing counter field fails
    short = [row("streaming_refresh",
                 "refresh_us=1.0;resetup_us=2.0;max_iters=7")]
    assert run(tmp_path, [good], short) == 1
    # non-finite iteration trajectory fails
    nan_it = [row("streaming_refresh",
                  STREAMING_DERIVED.replace("max_iters=7", "max_iters=nan"))]
    assert run(tmp_path, [good], nan_it) == 1


def test_modeled_us_must_be_finite(tmp_path):
    base = [row("dist_solve_auto_L0_spmv_A",
                "strategy=nap2;modeled_us=12.3;level=0;op=spmv_A")]
    assert run(tmp_path, base, base) == 0
    bad = [row("dist_solve_auto_L0_spmv_A",
               "strategy=nap2;modeled_us=nan;level=0;op=spmv_A")]
    assert run(tmp_path, base, bad) == 1


def test_committed_baselines_pass_against_themselves():
    root = pathlib.Path(__file__).parents[1]
    for name in ("BENCH_dist_solve.json", "BENCH_dist_setup.json"):
        path = root / name
        assert check_bench.main(["--baseline", str(path),
                                 "--new", str(path)]) == 0
