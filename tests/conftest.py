"""Shared test config.

Registers a deterministic fallback for `hypothesis` when the real package is
not installed (the container image carries no test extras), so the
property-test modules collect and run everywhere.
"""
import importlib.util
import pathlib
import sys

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _path = pathlib.Path(__file__).with_name("_hypothesis_stub.py")
    _spec = importlib.util.spec_from_file_location("hypothesis", _path)
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _mod.strategies
