"""Small-mesh dry-run validation (subprocess): build_cell must lower+compile
train/prefill/decode for representative archs on a (2,2,2) pod mesh with 8
placeholder devices — the same code path as the 512-device production run."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses  # noqa: E402

import jax  # noqa: E402

from repro.configs import get_arch  # noqa: E402
from repro.launch.dryrun import build_cell  # noqa: E402
from repro.launch.roofline import collective_bytes_from_text  # noqa: E402

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))

CASES = [
    ("qwen2-0.5b", "train_4k"),
    ("mixtral-8x22b", "decode_32k"),
    ("xlstm-125m", "long_500k"),
    ("recurrentgemma-9b", "prefill_32k"),
]

for arch, shape in CASES:
    cfg = get_arch(arch).reduced(n_layers=len(get_arch(arch).pattern),
                                 d_model=64, n_heads=4, vocab=256)
    cfg = dataclasses.replace(cfg, name=arch)
    lowered, meta = build_cell(arch, shape, mesh, cfg_override=cfg,
                               microbatches=2 if shape == "train_4k" else None,
                               unroll=True)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, list) else cost
    assert cost.get("flops", 0) > 0, (arch, shape)
    coll = collective_bytes_from_text(compiled.as_text(), pod_size=4,
                                      n_devices=8)
    print(f"OK {arch} {shape} flops={cost['flops']:.2e} "
          f"coll={coll['total_bytes']:.2e} xpod={coll['cross_slow_bytes']:.2e}")
print("ALL_OK")
