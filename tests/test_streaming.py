"""Streaming matrix sessions: A + ΔA updates with hierarchy reuse.

The tentpole contract under test: a value-only drift
(``bound.update(A_new)`` / ``AMGService.update``) refreshes the live
session in place — frozen splittings, interpolation patterns, NAP
schedules, compiled programs — and the refreshed solver is numerically
indistinguishable (≤ 1e-7) from a fresh ``setup(A_new)``.  Escalation is
exact and observable: a changed sparsity pattern raises the typed
:class:`PatternMismatch` (404-style over the wire for an unregistered
id), an injected convergence regression triggers exactly ONE adaptive
re-setup, an evicted session re-runs the full setup — every path
accounted in ``SessionStore.stats()`` under its trigger reason.

Multi-device (2×4 mesh, fp64) refresh parity runs in the dist_solve
subprocess script; everything here stays on a single CPU device.
"""
import numpy as np
import pytest

from repro.amg import (AMGConfig, AMGService, AMGSolver, PatternMismatch,
                       RefreshPolicy, setup, solve)
from repro.amg.api import (LRUPolicy, SessionStore, clear_sessions,
                           csr_to_wire, matrix_fingerprint,
                           pattern_fingerprint, update_request_to_wire)
from repro.amg.api.registry import bind_hierarchy
from repro.amg.csr import CSR
from repro.amg.hierarchy import refresh_values
from repro.amg.problems import laplace_3d


@pytest.fixture(autouse=True)
def _fresh_sessions():
    clear_sessions()
    yield
    clear_sessions()


@pytest.fixture(scope="module")
def problem():
    A = laplace_3d(8)
    rng = np.random.default_rng(7)
    b = rng.standard_normal(A.nrows)
    return A, b


def _drift(A, scale=0.03, seed=1):
    """A value-only drift on A's frozen pattern (SPD-safe: scales data)."""
    rng = np.random.default_rng(seed)
    data = A.data * (1.0 + scale * rng.random(A.nnz))
    # resymmetrize so pcg's SPD assumption holds after the perturbation
    At = CSR(A.shape, A.indptr.copy(), A.indices.copy(), data).T
    return CSR(A.shape, A.indptr.copy(), A.indices.copy(),
               0.5 * (data + At.data))


# ------------------------------------------------------- hierarchy refresh
def test_hierarchy_refresh_replays_galerkin_on_frozen_operators(problem):
    """The refresh contract: coarse values equal R·(A_new·P) computed with
    the FROZEN interpolation operators, projected onto the frozen coarse
    patterns (a fresh setup would re-run strength/splitting on the drifted
    values and may pick different operators — that is the re-setup path,
    not the refresh path)."""
    A, _ = problem
    h = setup(A)
    frozen_P = [lv.P for lv in h.levels[:-1]]
    A2 = _drift(A)
    refresh_values(h, A2)
    np.testing.assert_array_equal(h.levels[0].A.data, A2.data)
    Al = A2
    for lv, nxt, P in zip(h.levels[:-1], h.levels[1:], frozen_P):
        assert lv.P is P                        # structure untouched
        Ac = P.T.spgemm(Al.spgemm(P))
        got = {(int(r), int(c)): v for r, c, v in
               zip(nxt.A.rows_expanded(), nxt.A.indices, nxt.A.data)}
        want = {(int(r), int(c)): v for r, c, v in
                zip(Ac.rows_expanded(), Ac.indices, Ac.data)}
        for key, v in got.items():
            assert abs(v - want.get(key, 0.0)) < 1e-12, key
        Al = nxt.A
    # the caller's matrix is never written through (copy-on-write)
    assert h.levels[0].A is not A2


def test_uniform_scaling_refresh_matches_fresh_setup(problem):
    """Uniform scaling preserves strength ratios, so here — and only here
    — a fresh setup reproduces the refreshed hierarchy exactly."""
    A, _ = problem
    h = setup(A)
    A2 = CSR(A.shape, A.indptr.copy(), A.indices.copy(), 2.5 * A.data)
    refresh_values(h, A2)
    fresh = setup(A2)
    assert h.n_levels == fresh.n_levels
    for lv, flv in zip(h.levels, fresh.levels):
        np.testing.assert_array_equal(lv.A.indptr, flv.A.indptr)
        np.testing.assert_allclose(lv.A.data, flv.A.data,
                                   rtol=1e-12, atol=1e-12)


def test_refresh_preserves_caller_matrix(problem):
    A, _ = problem
    before = A.data.copy()
    bound = AMGSolver(AMGConfig(tol=1e-10)).setup(A)
    bound.update(_drift(A))
    np.testing.assert_array_equal(A.data, before)


# -------------------------------------------------------- session updates
def test_refresh_parity_vs_fresh_setup(problem):
    A, b = problem
    cfg = AMGConfig(tol=1e-10)
    bound = AMGSolver(cfg).setup(A)
    bound.pcg(b)
    A2 = _drift(A)
    h_before = bound.hierarchy
    assert bound.update(A2) == "refresh"
    assert bound.hierarchy is h_before          # structure reused
    x_ref = np.asarray(bound.pcg(b).x)
    clear_sessions()
    x_fresh = np.asarray(AMGSolver(cfg).setup(A2).pcg(b).x)
    assert np.max(np.abs(x_ref - x_fresh)) <= 1e-7
    # the refreshed session answers for A2's fingerprint now: a fresh
    # setup(A2) under an equal config is a cache hit, not a rebuild
    clear_sessions()
    cfg2 = AMGConfig(tol=1e-10)
    s = AMGSolver(cfg2)
    bound2 = s.setup(A)
    bound2.update(A2)
    assert s.setup(A2) is bound2


def test_pattern_mismatch_is_typed_and_refuses_refresh(problem):
    A, _ = problem
    bound = AMGSolver(AMGConfig()).setup(A)
    A_diag = A.prune(2.0)                       # off-diagonals dropped
    assert pattern_fingerprint(A_diag) != bound.pattern_fp
    with pytest.raises(PatternMismatch):
        bound.update(A_diag)
    assert isinstance(PatternMismatch("x"), ValueError)
    # wrong value count through the data= form is the same typed error
    with pytest.raises(PatternMismatch):
        bound.update(data=np.ones(3))


def test_injected_regression_triggers_exactly_one_resetup(problem):
    A, b = problem
    store = SessionStore(LRUPolicy())
    cfg = AMGConfig(tol=1e-10,
                    refresh=RefreshPolicy(regress_ratio=1.5, regress_slack=2))
    solver = AMGSolver(cfg, store=store)
    bound = solver.setup(A)
    base = bound.pcg(b).iterations
    assert bound.baseline_iterations == base
    # drift within policy: refresh
    assert bound.update(_drift(A, seed=2)) == "refresh"
    assert bound.baseline_iterations == base    # baseline survives refresh
    # inject a regression past ratio*baseline + slack
    bound.last_iterations = int(1.5 * base + 3)
    assert bound.update(_drift(A, seed=3)) == "resetup"
    assert bound.baseline_iterations is None    # re-baselined after resetup
    st = store.stats()
    assert st["resetups"] == 1 and st["refreshes"] == 1
    assert st["triggers"] == {"drift": 1, "regression": 1}
    # the very next drift refreshes again — exactly one re-setup fired
    assert bound.update(_drift(A, seed=4)) == "refresh"
    assert store.stats()["resetups"] == 1


def test_refresh_policy_thresholds():
    pol = RefreshPolicy(regress_ratio=2.0, regress_slack=1)
    assert not pol.regressed(None, 50)          # no baseline yet
    assert not pol.regressed(10, 21)            # 21 <= 2*10 + 1
    assert pol.regressed(10, 22)
    cfg = AMGConfig(refresh=pol)
    assert isinstance(hash(cfg), int)           # stays hashable


def test_update_needs_a_streaming_session(problem):
    A, _ = problem
    bound = bind_hierarchy(setup(A))            # bare hierarchy, no session
    with pytest.raises(ValueError, match="streaming updates"):
        bound.update(_drift(A))


# -------------------------------------------------------- service routing
def test_service_update_keeps_matrix_id_stable(problem):
    A, b = problem
    svc = AMGService(AMGConfig(tol=1e-10))
    svc.register("m", A)
    t0 = svc.submit("m", b, method="pcg")
    svc.drain()
    A2 = _drift(A)
    out = svc.update("m", A2)
    assert out == {"matrix": "m", "action": "refresh", "reason": "drift"}
    # same id now solves against the drifted operator
    t1 = svc.submit("m", b, method="pcg")
    x = svc.drain()[t1.rid]
    res = np.linalg.norm(b - A2.matvec(x)) / np.linalg.norm(b)
    assert res < 1e-8
    assert t0.done() and svc.stats["updates"] == 1
    # counter consistency: every solve after an update is a session hit
    st = svc.store.stats()
    assert st["refreshes"] == 1 and st["resetups"] == 0


def test_service_update_escalates_on_pattern_change(problem):
    A, _ = problem
    svc = AMGService(AMGConfig())
    svc.register("m", A)
    svc.bound_for("m")
    A_diag = A.prune(2.0)
    out = svc.update("m", A_diag)
    assert out["action"] == "resetup" and out["reason"] == "pattern"
    # the registry now serves the new matrix under the same id
    got, fp = svc._lookup_matrix("m")
    assert fp == matrix_fingerprint(A_diag)
    assert svc.store.stats()["triggers"]["pattern"] == 1


def test_update_after_eviction_runs_full_setup(problem):
    A, b = problem
    store = SessionStore(LRUPolicy(1))          # room for ONE session
    svc = AMGService(AMGConfig(tol=1e-10), store=store)
    svc.register("m", A)
    svc.register("other", laplace_3d(6))
    svc.bound_for("m")
    svc.bound_for("other")                      # evicts m's session
    out = svc.update("m", _drift(A))
    assert out["action"] == "resetup" and out["reason"] == "evicted"
    assert store.stats()["triggers"] == {"evicted": 1}
    t = svc.submit("m", b, method="pcg")
    assert svc.drain()[t.rid].shape == b.shape


def test_delta_and_data_forms_compose(problem):
    A, b = problem
    svc = AMGService(AMGConfig(tol=1e-10))
    svc.register("m", A)
    svc.bound_for("m")
    delta = np.zeros(A.nnz)
    delta[0] = 0.25
    assert svc.update("m", delta=delta)["action"] == "refresh"
    vals = A.data + delta
    assert svc.update("m", data=vals)["action"] == "refresh"
    got, _ = svc._lookup_matrix("m")
    np.testing.assert_array_equal(got.data, vals)
    with pytest.raises(ValueError, match="not both"):
        svc.update("m", A, delta=delta)


# --------------------------------------------------------------- wire path
def test_update_over_the_wire_and_404(problem):
    from repro.serve import (AMGWireClient, RemoteError, ServerThread,
                             TenantSpec)
    from repro.serve.workload import json_hop
    A, b = problem
    with ServerThread({"t": TenantSpec(config=AMGConfig(tol=1e-8))}) as srv:
        with AMGWireClient.connect(srv.host, srv.port) as c:
            # hello negotiation advertised both schema versions
            assert c.hello["supported_schemas"] == [1, 2] and c.schema == 2
            mid = c.register("t", json_hop(csr_to_wire(A)))["matrix"]
            from repro.amg.api import solve_request_to_wire
            c.solve("t", json_hop(solve_request_to_wire(mid, b,
                                                        method="pcg")))
            A2 = _drift(A)
            up = c.update("t", json_hop(update_request_to_wire(mid, A2)))
            assert up["action"] == "refresh" and up["reason"] == "drift"
            x, diag = c.solve("t", json_hop(
                solve_request_to_wire(mid, b, method="pcg")))
            res = (np.linalg.norm(b - A2.matvec(np.asarray(x)))
                   / np.linalg.norm(b))
            assert diag["converged"] and res < 1e-6
            # ΔA addressed to an unregistered fingerprint: 404 error frame
            with pytest.raises(RemoteError) as exc:
                c.update("t", json_hop(update_request_to_wire(
                    "deadbeef", delta=np.zeros(A.nnz))))
            assert exc.value.code == 404
            # a v1 client cannot send update frames at all
            c.schema = 1
            with pytest.raises(RemoteError) as exc:
                c.update("t", json_hop(update_request_to_wire(mid, A2)))
            assert exc.value.code == 400
            c.schema = 2
            stats = c.stats("t")["tenants"]["t"]
            assert stats["updated"] == 1
            assert stats["store"]["refreshes"] == 1


# ------------------------------------------------------- store accounting
def test_session_store_update_counters():
    store = SessionStore(LRUPolicy())
    store.note_update("refresh", "drift")
    store.note_update("resetup", "regression")
    store.note_update("resetup", "pattern")
    st = store.stats()
    assert st["refreshes"] == 1 and st["resetups"] == 2
    assert st["triggers"] == {"drift": 1, "regression": 1, "pattern": 1}
    with pytest.raises(ValueError, match="unknown update action"):
        store.note_update("rebuild", "drift")


def test_free_function_solve_unaffected_by_refresh(problem):
    """The classic free-function path still works after a hierarchy-level
    refresh (it has no session, so no policy machinery engages)."""
    A, b = problem
    h = setup(A)
    A2 = _drift(A)
    refresh_values(h, A2)
    res = solve(h, b, tol=1e-10)
    r = np.linalg.norm(b - A2.matvec(np.asarray(res.x))) / np.linalg.norm(b)
    assert r < 1e-8
