"""AMG substrate tests: CSR kernels vs dense oracles, setup invariants,
convergence, and the distributed comm analysis."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.amg import setup, solve, pcg, vcycle, SolveOptions
from repro.amg.csr import CSR
from repro.amg.dist import (analyze_hierarchy, matrix_comm_graph,
                            phase_costs, row_partition, vector_comm_graph)
from repro.amg.problems import (dpg_laplace_3d, grad_div_3d, laplace_3d,
                                laplace_3d_7pt, rotated_anisotropic_2d)
from repro.amg.splitting import mis2_aggregation, pmis
from repro.amg.strength import classical_strength, symmetric_strength
from repro.core import BLUE_WATERS, Topology


# ---------------------------------------------------------------------- CSR
@st.composite
def dense_pair(draw):
    n = draw(st.integers(1, 12))
    m = draw(st.integers(1, 12))
    k = draw(st.integers(1, 12))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    A = rng.standard_normal((n, m)) * (rng.random((n, m)) < 0.4)
    B = rng.standard_normal((m, k)) * (rng.random((m, k)) < 0.4)
    return A, B


@settings(max_examples=80, deadline=None)
@given(dense_pair())
def test_csr_matches_dense_oracle(pair):
    Ad, Bd = pair
    A, B = CSR.from_dense(Ad), CSR.from_dense(Bd)
    np.testing.assert_allclose(A.to_dense(), Ad)
    np.testing.assert_allclose(A.spgemm(B).to_dense(), Ad @ Bd, atol=1e-12)
    np.testing.assert_allclose(A.T.to_dense(), Ad.T)
    x = np.random.default_rng(0).standard_normal(Ad.shape[1])
    np.testing.assert_allclose(A.matvec(x), Ad @ x, atol=1e-12)


def test_csr_add_scale_prune():
    rng = np.random.default_rng(5)
    Ad = rng.standard_normal((9, 9)) * (rng.random((9, 9)) < 0.5)
    A = CSR.from_dense(Ad)
    np.testing.assert_allclose(A.add(A, alpha=2.0, beta=-1.0).to_dense(), Ad)
    d = rng.standard_normal(9)
    np.testing.assert_allclose(A.scale_rows(d).to_dense(), Ad * d[:, None])
    np.testing.assert_allclose(A.scale_cols(d).to_dense(), Ad * d[None, :])
    small = A.prune(0.5)
    dd = small.to_dense()
    off = ~np.eye(9, dtype=bool)
    assert (np.abs(dd[off][dd[off] != 0]) > 0.5).all()
    np.testing.assert_allclose(A.diagonal(), np.diag(Ad))


def test_csr_from_coo_coalesces_duplicates():
    A = CSR.from_coo([0, 0, 1], [1, 1, 0], [2.0, 3.0, 1.0], (2, 2))
    assert A.nnz == 2
    assert A.to_dense()[0, 1] == 5.0


# ---------------------------------------------------------------- splitting
def test_pmis_is_valid_cf_splitting():
    A = laplace_3d_7pt(10)
    S = classical_strength(A, 0.25)
    status = pmis(S)
    assert set(np.unique(status)) <= {-1, 1}
    # C points form an independent set in S ∪ Sᵀ
    G = S.add(S.T)
    r = G.rows_expanded()
    cc = (status[r] == 1) & (status[G.indices] == 1) & (r != G.indices)
    assert not cc.any()
    # every F point has at least one strong C neighbour (7-pt Laplacian)
    f_has_c = np.zeros(A.nrows, dtype=bool)
    hit = status[G.indices] == 1
    np.logical_or.at(f_has_c, r[hit], True)
    assert f_has_c[status == -1].all()


def test_mis2_aggregation_covers_all_nodes():
    A = laplace_3d(10)
    S = symmetric_strength(A, 0.25)
    agg = mis2_aggregation(S)
    assert agg.min() == 0
    n_agg = int(agg.max()) + 1
    assert 1 < n_agg < A.nrows / 3          # real coarsening
    assert np.bincount(agg).min() >= 1


# --------------------------------------------------------------- convergence
@pytest.mark.parametrize("solver,cf_bound", [("rs", 0.65), ("sa", 0.75)])
def test_amg_converges_laplace3d(solver, cf_bound):
    A = laplace_3d(12)
    h = setup(A, solver=solver)
    assert h.n_levels >= 2
    b = A.matvec(np.ones(A.nrows))
    res = solve(h, b, tol=1e-8, maxiter=60)
    assert res.converged
    assert res.avg_conv_factor < cf_bound
    np.testing.assert_allclose(res.x, np.ones(A.nrows), atol=1e-5)


def test_amg_galerkin_matches_dense():
    A = laplace_3d_7pt(6)
    h = setup(A, solver="rs", max_coarse=20)
    l0 = h.levels[0]
    Ac = h.levels[1].A
    dense = l0.P.to_dense().T @ A.to_dense() @ l0.P.to_dense()
    np.testing.assert_allclose(Ac.to_dense(), dense, atol=1e-10)


def test_amg_pcg_hard_problem():
    A = rotated_anisotropic_2d(32)
    h = setup(A, solver="sa")
    b = A.matvec(np.random.default_rng(0).standard_normal(A.nrows))
    res = pcg(h, b, tol=1e-8, maxiter=120)
    assert res.converged


@pytest.mark.parametrize("prob", [grad_div_3d, dpg_laplace_3d])
def test_amg_other_systems(prob):
    A = prob(7)
    h = setup(A, solver="rs")
    b = A.matvec(np.ones(A.nrows))
    res = solve(h, b, tol=1e-8, maxiter=80)
    assert res.converged


def test_vcycle_reduces_residual_every_level_count():
    A = laplace_3d(10)
    h = setup(A, solver="rs")
    b = np.random.default_rng(2).standard_normal(A.nrows)
    x = vcycle(h, b, None, SolveOptions(smoother="chebyshev"))
    r1 = np.linalg.norm(b - A.matvec(x))
    assert r1 < np.linalg.norm(b)


# ------------------------------------------------------------- dist analysis
def test_vector_comm_graph_is_offproc_pattern():
    A = laplace_3d_7pt(8)
    topo = Topology(n_nodes=4, ppn=4)
    part = row_partition(A, topo)
    g = vector_comm_graph(A, part)
    # brute force: needed = union of columns of my rows outside my range
    Ad = A.to_dense()
    for p in range(topo.n_procs):
        lo, hi = part.local_range(p)
        cols = np.unique(np.nonzero(Ad[lo:hi])[1])
        expected = cols[(cols < lo) | (cols >= hi)]
        np.testing.assert_array_equal(g.need[p], expected)


def test_matrix_comm_weights_are_row_bytes():
    A = laplace_3d_7pt(8)
    topo = Topology(n_nodes=2, ppn=4)
    part = row_partition(A, topo)
    g = matrix_comm_graph(A, A, part)
    lens = np.diff(A.indptr)
    assert g.weights[5] == lens[5] * 12.0 + 16.0


def test_analyze_hierarchy_selects_per_level():
    A = laplace_3d(12)
    h = setup(A, solver="rs")
    topo = Topology(n_nodes=8, ppn=8)
    ops = analyze_hierarchy(h, topo, BLUE_WATERS)
    assert any(o.op == "spmv_A" for o in ops)
    assert any(o.op == "spgemm_PtAP" for o in ops)
    for o in ops:
        assert o.strategy in ("standard", "nap2", "nap3")
        assert o.selection.times[o.strategy] == min(o.selection.times.values())
    costs = phase_costs(ops, h.n_levels)
    assert set(costs) == {"solve", "setup"}
    # selected mix is never worse than any single pure strategy
    for phase in costs.values():
        for row in phase.values():
            assert row["selected"] <= min(row["standard"], row["nap2"], row["nap3"]) + 1e-12
