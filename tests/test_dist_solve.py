"""Distributed solve-phase tests.

Host-side (no extra devices): rectangular halo-plan/ELL correctness, the
per-level strategy-selection table, and backend dispatch on a 1x1 mesh.
Multi-device parity for all three strategies runs in a subprocess
(``dist_solve_script.py``) so this pytest process keeps one CPU device.
"""
import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

from repro.amg import SolveOptions, pcg, setup, solve
from repro.amg.problems import laplace_3d, laplace_3d_7pt
from repro.core import BLUE_WATERS
from repro.core.topology import Partition, Topology

SCRIPT = pathlib.Path(__file__).parent / "dist_solve_script.py"
EXPECTED = [
    "OK solve_standard", "OK pcg_standard",
    "OK solve_nap2", "OK pcg_nap2",
    "OK solve_nap3", "OK pcg_nap3",
    "OK auto_select", "OK pallas_path", "OK chebyshev",
    "OK cycle_smoother_parity", "OK overlap_parity", "OK empty_halo",
    "OK comm_audit", "OK dist_setup_cycles", "OK multi_rhs",
    "OK streaming_refresh",
    "ALL_OK",
]


@pytest.fixture(scope="module")
def rect_ops():
    """P and R DistOperators (all strategies) for a small RS hierarchy."""
    from repro.amg.dist import rect_vector_graph
    from repro.amg.dist_spmv import build_dist_operator

    A = laplace_3d_7pt(6)
    h = setup(A, solver="rs", max_coarse=30)
    P, R = h.levels[0].P, h.levels[0].R
    topo = Topology(n_nodes=2, ppn=2)
    fp = Partition.balanced(P.nrows, topo)
    cp = Partition.balanced(P.ncols, topo)
    out = []
    for M, rp_, cp_ in ((P, fp, cp), (R, cp, fp)):
        g = rect_vector_graph(M, rp_, cp_)
        for strat in ("standard", "nap2", "nap3"):
            op = build_dist_operator(M, 2, 2, strat, row_part=rp_,
                                     col_part=cp_, dtype=np.float64)
            out.append((M, g, op, strat))
    return out


def test_rect_halo_plan_and_ell_reconstruction(rect_ops):
    """The rectangular lowering is lossless: per-device ELL blocks with
    [local | halo] column remapping reassemble to the exact operator, and
    every halo slot maps to an owned entry of some other device."""
    for M, g, op, strat in rect_ops:
        dense = np.zeros(M.shape)
        x_local = op.plan.local_n
        for d in range(op.n_devices):
            rlo, rhi = op.row_part.local_range(d)
            clo, chi = op.col_part.local_range(d)
            need = np.sort(g.need[d])
            cols, vals = op.ell_cols[d], op.ell_vals[d]
            local = (cols >= 0) & (cols < x_local)
            halo = cols >= x_local
            # halo indices must be in range of this device's need array
            assert cols[halo].max(initial=0) - x_local < need.size + 1
            for i in range(rhi - rlo):
                for c, v in zip(cols[i], vals[i]):
                    if c < 0:
                        continue
                    gcol = clo + c if c < x_local else need[c - x_local]
                    dense[rlo + i, gcol] += v
        np.testing.assert_allclose(dense, M.to_dense(), atol=1e-12,
                                   err_msg=strat)


def test_rect_plan_halo_slots_are_offproc(rect_ops):
    """No device 'needs' x-entries it owns (the paper's no-self-comm rule)."""
    for M, g, op, strat in rect_ops:
        for d in range(op.n_devices):
            clo, chi = op.col_part.local_range(d)
            need = g.need[d]
            assert not ((need >= clo) & (need < chi)).any()


def test_dist_hierarchy_selection_table():
    """Every (level, op) row carries a chosen strategy + modeled times."""
    A = laplace_3d(8)
    h = setup(A, solver="rs")
    from repro.amg.dist_solve import DistHierarchy
    dh = DistHierarchy.build(h, 1, 1, params=BLUE_WATERS)
    rows = dh.selection_table()
    ops = {(r["level"], r["op"]) for r in rows}
    assert (0, "spmv_A") in ops
    for l in range(len(dh.levels) - 1):
        assert (l, "interp") in ops and (l, "restrict") in ops
    for r in rows:
        assert r["strategy"] in ("standard", "nap2", "nap3")
        if r["modeled"]:
            assert r["modeled"][r["strategy"]] == min(r["modeled"].values())
    assert "dist hierarchy" in dh.summary()


def test_backend_dispatch_single_device():
    """backend="dist" on a 1x1 mesh matches the host solver bit-for-fp32."""
    A = laplace_3d(8)
    h = setup(A, solver="rs")
    b = A.matvec(np.ones(A.nrows))
    from repro.amg.dist_solve import DistHierarchy
    dh = DistHierarchy.build(h, 1, 1, strategy="standard")
    res_h = pcg(h, b, tol=1e-5, maxiter=12)
    res_d = pcg(h, b, tol=1e-5, maxiter=12, backend="dist", dist=dh)
    assert res_d.converged
    n = min(len(res_h.residuals), len(res_d.residuals))
    r0 = res_h.residuals[0]
    for a, c in zip(res_h.residuals[:n], res_d.residuals[:n]):
        assert abs(a - c) / r0 < 2e-4
    with pytest.raises(ValueError):
        solve(h, b, backend="bogus")
    with pytest.raises(ValueError):
        pcg(h, b, backend="bogus")
    with pytest.raises(ValueError):
        solve(h, b, backend="dist")            # dist= is required
    with pytest.raises(ValueError):
        pcg(h, b, backend="dist", dist={"n_pods": 1})  # lanes missing


def test_cycle_comm_stats_counts_and_smoothers():
    """cycle_comm_stats: W doubles the coarse-visit message counts vs V on
    a ≥3-level hierarchy, chebyshev multiplies the per-sweep SpMVs, and the
    block smoothers compile + run through the 1x1 fused program."""
    A = laplace_3d(8)
    h = setup(A, solver="rs", max_coarse=30)
    assert h.n_levels >= 3
    from repro.amg.dist_solve import DistHierarchy, cycle_comm_stats
    dh = DistHierarchy.build(h, 1, 1, params=BLUE_WATERS)
    stV = cycle_comm_stats(dh, SolveOptions(cycle="V"))
    stW = cycle_comm_stats(dh, SolveOptions(cycle="W"))
    stF = cycle_comm_stats(dh, SolveOptions(cycle="F"))
    assert [e["visits"] for e in stV["per_level"]] == [1, 1, 1]
    assert [e["visits"] for e in stW["per_level"]] == [1, 2, 4]
    assert [e["visits"] for e in stF["per_level"]] == [1, 2, 3]
    # a 1x1 mesh communicates nothing; the structure must still be there
    assert stW["coarse_inter_msgs"] == 2 * stV["coarse_inter_msgs"]
    cheb = cycle_comm_stats(dh, SolveOptions(smoother="chebyshev",
                                             cheby_degree=3))
    assert cheb["cycle"] == "V" and cheb["smoother"] == "chebyshev"
    # block smoothers run end-to-end on the single-device mesh and the
    # two option sets share the lowered dense factors via _arrs_ex
    b = A.matvec(np.ones(A.nrows))
    for sm in ("block_jacobi", "hybrid_gs"):
        res = solve(h, b, tol=0.0, maxiter=3,
                    opts=SolveOptions(cycle="F", smoother=sm),
                    backend="dist", dist=dh)
        assert res.residuals[-1] < res.residuals[0]
    assert set(dh._arrs_ex) == {("bj", 4), ("gs", 0)}


@pytest.mark.slow
def test_benchmark_smoke_mode(tmp_path):
    """benchmarks/dist_solve.py --smoke runs in seconds and emits both the
    CSV rows and the BENCH_dist_solve.json record file."""
    env = dict(os.environ)
    root = pathlib.Path(__file__).parents[1]
    env["PYTHONPATH"] = str(root / "src") + os.pathsep + env.get("PYTHONPATH", "")
    out_json = tmp_path / "BENCH_dist_solve.json"
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.dist_solve", "--smoke",
         "--out", str(out_json)],
        capture_output=True, text=True, env=env, cwd=root, timeout=600)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    for strat in ("standard", "nap2", "nap3", "auto"):
        assert f"dist_solve_{strat}," in out.stdout
    # cycle×smoother sweep rows with coarse-level message counts
    for cycle in ("V", "W", "F"):
        for sm in ("jacobi", "chebyshev", "block_jacobi", "hybrid_gs"):
            assert f"dist_cycle_{cycle}_{sm}," in out.stdout
    assert "coarse_inter_msgs=" in out.stdout
    import json
    data = json.loads(out_json.read_text())
    assert data["benchmark"] == "dist_solve"
    assert any(r["name"].startswith("dist_solve_auto_L") for r in data["rows"])
    # weak-scaling sweep: ≥3 problem sizes recorded
    assert sum(r["name"].startswith("dist_weak_n") for r in data["rows"]) >= 3
    # cached-vs-cold AMGSolver sessions: the cached call must not pay the
    # DistHierarchy rebuild + recompile
    by_name = {r["name"]: r for r in data["rows"]}
    assert by_name["amg_solver_cached"]["us_per_call"] < \
        by_name["amg_solver_cold"]["us_per_call"]
    # streaming drift sweep: the value-only refresh must beat the full
    # re-setup the injected regression triggers, and the solve accounting
    # must land in the derived string for the check_bench gate
    assert by_name["streaming_refresh"]["us_per_call"] < \
        by_name["streaming_resetup"]["us_per_call"]
    for field in ("solves=", "refreshes=", "resetups=", "cached=",
                  "max_iters=", "triggers="):
        assert field in by_name["streaming_refresh"]["derived"]


@pytest.mark.slow
def test_multidevice_dist_solve_subprocess():
    env = dict(os.environ)
    root = str(pathlib.Path(__file__).parents[1] / "src")
    env["PYTHONPATH"] = root + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, str(SCRIPT)], capture_output=True,
                         text=True, env=env, timeout=1800)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    for marker in EXPECTED:
        assert marker in out.stdout, f"missing {marker!r} in:\n{out.stdout}"
