"""Solve-phase benchmark: the device-resident fused cycle, standard vs
NAP-2 vs NAP-3 vs model-selected per-level strategies (paper Figs. 16/17's
solve-phase claim, executed rather than simulated), plus a cycle-shape ×
smoother sweep with per-cycle coarse-level message counts
(``cycle_smoother_rows`` — the rows the CI regression gate vets), a
weak-scaling sweep over ≥3 problem sizes (``weak_rows``) and a
cached-vs-cold ``AMGSolver`` session comparison (``session_rows``) showing
the per-call rebuild cost the session API eliminates.  A streaming drift
sweep (``streaming_rows``) pits value-only refreshes against the adaptive
full re-setup that one injected convergence regression triggers.

Emits the ``name,us_per_call,derived`` rows used by :mod:`benchmarks.run`,
and — when run standalone — a ``BENCH_dist_solve.json`` file with the same
rows as structured records:

    PYTHONPATH=src python -m benchmarks.dist_solve [--smoke] [--out PATH]

``--smoke`` (or ``REPRO_BENCH_SMOKE=1``) shrinks the problem and iteration
count so the whole benchmark runs in seconds (the tier-1 smoke test uses it).
Heavy imports are deferred so the standalone entrypoint can force an 8-way
host mesh before JAX initializes.
"""
from __future__ import annotations

import json
import os
import time

STRATEGIES = ("standard", "nap2", "nap3", "auto")


def _mesh_shape(n_devices: int) -> tuple[int, int]:
    if n_devices >= 4 and n_devices % 2 == 0:
        return 2, n_devices // 2
    return 1, n_devices


def rows(smoke: bool | None = None, cycles: int | None = None):
    if smoke is None:
        smoke = os.environ.get("REPRO_BENCH_SMOKE", "0") == "1"
    import jax

    from repro.amg import setup, solve
    from repro.amg.dist_solve import DistHierarchy
    from repro.amg.problems import laplace_3d
    from repro.core import BLUE_WATERS
    import numpy as np

    n = 8 if smoke else 12
    cycles = cycles or (3 if smoke else 10)
    n_pods, lanes = _mesh_shape(jax.device_count())
    A = laplace_3d(n)
    h = setup(A, solver="rs")
    b = A.matvec(np.ones(A.nrows))
    out = []
    for strat in STRATEGIES:
        kw = ({"params": BLUE_WATERS} if strat == "auto"
              else {"strategy": strat})
        dh = DistHierarchy.build(h, n_pods, lanes, **kw)
        solve(h, b, maxiter=1, tol=0.0, backend="dist", dist=dh)  # compile
        t0 = time.perf_counter()
        res = solve(h, b, maxiter=cycles, tol=0.0, backend="dist", dist=dh)
        dt = time.perf_counter() - t0
        per_level = ";".join(
            f"L{r['level']}.{r['op']}={r['strategy']}"
            for r in dh.selection_table())
        out.append((f"dist_solve_{strat}", dt / cycles * 1e6,
                    f"n={A.nrows};mesh={n_pods}x{lanes};cycles={cycles};"
                    f"conv={res.avg_conv_factor:.3f};{per_level}"))
        if strat == "auto":
            # one row per (level, op): the model-selected strategy + its
            # modeled comm seconds (the quantity the paper's Figs. 14/15
            # plot).  ``us_per_call`` stays a wall-clock-style column (here
            # the modeled phase time, honestly labeled in ``derived`` as
            # modeled_us) so check_bench can gate the field structurally
            # without special-casing these rows.
            for r in dh.selection_table():
                modeled = r["modeled"].get(r["strategy"], 0.0)
                out.append((f"dist_solve_auto_L{r['level']}_{r['op']}",
                            modeled * 1e6,
                            f"strategy={r['strategy']};"
                            f"modeled_us={modeled * 1e6:.3f};"
                            f"level={r['level']};op={r['op']}"))
    return out


def overlap_rows(smoke: bool | None = None, cycles: int | None = None):
    """Per-level on/off operator splits + serial-vs-overlapped cycle timings.

    The hierarchy is lowered with the *measured* machine parameters
    (:func:`benchmarks.pingpong_model.measure_machine_params`), so the
    overlap-aware selection — max(T_comm, T_on) + T_off — runs on data.
    One ``dist_overlap_L{l}`` row per level records the on/off nnz split and
    the modeled overlap efficiency; one ``dist_overlap_cycle_{V,W}`` row per
    cycle shape times the same fused program with ``overlap`` on vs off
    (wall clock — check_bench gates these structurally, never by magnitude).
    """
    if smoke is None:
        smoke = os.environ.get("REPRO_BENCH_SMOKE", "0") == "1"
    import jax

    if jax.device_count() < 2:      # nothing to overlap on one device;
        return []                   # the standalone entrypoint has 8
    import numpy as np

    from benchmarks.pingpong_model import measure_machine_params
    from repro.amg import SolveOptions, setup, solve
    from repro.amg.dist_solve import DistHierarchy
    from repro.amg.problems import laplace_3d
    from repro.core.perf_model import overlap_time

    n = 8 if smoke else 12
    cycles = cycles or (3 if smoke else 10)
    n_pods, lanes = _mesh_shape(jax.device_count())
    params = measure_machine_params(n_pods=n_pods, lanes=lanes)
    A = laplace_3d(n)
    h = setup(A, solver="rs", max_coarse=30)   # ≥3 levels so W revisits
    b = A.matvec(np.ones(A.nrows))
    dh = DistHierarchy.build(h, n_pods, lanes, params=params)
    out = []
    for l, dl in enumerate(dh.levels):
        oo = dl.onoff
        t_ov = overlap_time(oo["t_comm"], oo["t_on"], oo["t_off"])
        out.append((
            f"dist_overlap_L{l}", t_ov * 1e6,
            f"on_nnz={oo['on_nnz']};off_nnz={oo['off_nnz']};"
            f"local_nnz={oo['local_nnz']};"
            f"halo_empty={int(oo['halo_empty'])};"
            f"eff_modeled={oo['eff_modeled']:.4f};"
            f"strategy={dl.strategies.get('spmv_A', '?')};"
            f"machine={params.name}"))

    def timed(opts):
        solve(h, b, maxiter=1, tol=0.0, opts=opts, backend="dist", dist=dh)
        t0 = time.perf_counter()
        solve(h, b, maxiter=cycles, tol=0.0, opts=opts, backend="dist",
              dist=dh)
        return (time.perf_counter() - t0) / cycles * 1e6

    for cycle in ("V", "W"):
        opts = SolveOptions(cycle=cycle)
        dh.overlap = True
        t_overlap = timed(opts)
        dh.overlap = False
        t_serial = timed(opts)
        dh.overlap = True
        out.append((
            f"dist_overlap_cycle_{cycle}", t_overlap,
            f"serial_us={t_serial:.2f};overlap_us={t_overlap:.2f};"
            f"speedup={t_serial / max(t_overlap, 1e-9):.3f};"
            f"mesh={n_pods}x{lanes};n={A.nrows};cycles={cycles}"))
    return out


def cycle_smoother_rows(smoke: bool | None = None):
    """Cycle-shape × smoother sweep through the fused device program.

    One row per (cycle, smoother) pair on a ≥3-level hierarchy (so W/F
    actually revisit coarse levels): iteration count to tol, convergence
    factor, µs/cycle, and the *modeled per-cycle message counts* split into
    total and coarse-level (ℓ ≥ 1) — the quantity W/F-cycles multiply and
    where the paper's NAP strategies aggregate small inter-node messages.
    ``iters``/``conv`` feed the CI regression gate (scripts/check_bench.py).
    """
    if smoke is None:
        smoke = os.environ.get("REPRO_BENCH_SMOKE", "0") == "1"
    import jax
    import numpy as np

    from repro.amg import SolveOptions, setup, solve
    from repro.amg.dist_solve import DistHierarchy, cycle_comm_stats
    from repro.amg.problems import laplace_3d
    from repro.amg.solve import CYCLES, SMOOTHERS
    from repro.core import BLUE_WATERS

    n = 8 if smoke else 12
    n_pods, lanes = _mesh_shape(jax.device_count())
    A = laplace_3d(n)
    h = setup(A, solver="rs", max_coarse=30)   # deepen: W/F need ≥3 levels
    b = A.matvec(np.ones(A.nrows))
    dh = DistHierarchy.build(h, n_pods, lanes, params=BLUE_WATERS)
    out = []
    for cycle in CYCLES:
        for sm in SMOOTHERS:
            opts = SolveOptions(cycle=cycle, smoother=sm)
            solve(h, b, maxiter=1, tol=0.0, opts=opts, backend="dist",
                  dist=dh)                     # compile
            t0 = time.perf_counter()
            res = solve(h, b, tol=1e-6, maxiter=40, opts=opts,
                        backend="dist", dist=dh)
            dt = time.perf_counter() - t0
            st = cycle_comm_stats(dh, opts)
            out.append((
                f"dist_cycle_{cycle}_{sm}",
                dt / max(res.iterations, 1) * 1e6,
                f"n={A.nrows};mesh={n_pods}x{lanes};levels={h.n_levels};"
                f"iters={res.iterations};conv={res.avg_conv_factor:.3f};"
                f"inter_msgs={st['inter_msgs']};"
                f"coarse_inter_msgs={st['coarse_inter_msgs']};"
                f"coarse_intra_msgs={st['coarse_intra_msgs']}"))
    return out


def comm_audit_rows(smoke: bool | None = None):
    """Static comm-audit rows: the traced collective counts of the fused
    vcycle per (cycle, smoother) pair vs the counts the cycle structure +
    selected strategies predict, plus the setup-phase static-vs-measured
    SpGEMM exchange counters.  ``us_per_call`` is the audit's own tracing
    wall clock (never gated); the derived fields are what
    ``scripts/check_bench.py`` gates structurally: ``collectives`` ==
    ``expected`` with ``agree=1`` and ``violations=0``, and — for the
    ``comm_audit_setup_L*`` rows — static == runtime message counts."""
    if smoke is None:
        smoke = os.environ.get("REPRO_BENCH_SMOKE", "0") == "1"
    import jax

    from repro.amg import SolveOptions, setup
    from repro.amg.dist_setup import dist_setup_partitioned
    from repro.amg.dist_solve import DistHierarchy
    from repro.amg.problems import laplace_3d
    from repro.amg.solve import CYCLES, SMOOTHERS
    from repro.analysis import audit_cycle_stats, audit_program, audit_setup
    from repro.core import BLUE_WATERS

    n = 8 if smoke else 12
    n_pods, lanes = _mesh_shape(jax.device_count())
    A = laplace_3d(n)
    h = setup(A, solver="rs", max_coarse=30)
    dh = DistHierarchy.build(h, n_pods, lanes, params=BLUE_WATERS)
    out = []
    for cycle in CYCLES:
        for sm in SMOOTHERS:
            opts = SolveOptions(cycle=cycle, smoother=sm)
            t0 = time.perf_counter()
            a = audit_program(dh, "vcycle", opts)
            stat_v = audit_cycle_stats(dh, opts)
            dt = time.perf_counter() - t0
            n_vio = len(a.violations) + len(stat_v)
            expected = sum((a.expected or {}).values())
            out.append((
                f"comm_audit_{cycle}_{sm}", dt * 1e6,
                f"mesh={n_pods}x{lanes};collectives={a.n_collectives};"
                f"expected={expected};bytes={a.total_bytes};"
                f"agree={int(a.counts == a.expected)};violations={n_vio}"))
    plv, recs = dist_setup_partitioned(A, n_pods, lanes, solver="rs",
                                       max_coarse=30)
    t0 = time.perf_counter()
    audit_rows, vio = audit_setup(plv, recs)
    dt = time.perf_counter() - t0
    for r in audit_rows:
        out.append((
            f"comm_audit_setup_L{r['level']}_{r['op']}",
            dt / max(len(audit_rows), 1) * 1e6,
            f"strategy={r['strategy']};"
            f"static_inter_msgs={r['static_inter_msgs']};"
            f"runtime_inter_msgs={r['runtime_inter_msgs']};"
            f"static_intra_msgs={r['static_intra_msgs']};"
            f"runtime_intra_msgs={r['runtime_intra_msgs']};"
            f"violations={len(vio)}"))
    return out


def weak_rows(smoke: bool | None = None, cycles: int | None = None):
    """Weak-scaling sweep: ≥3 problem sizes through the model-selected
    fused cycle on the same mesh — µs/cycle as DOFs/device grows."""
    if smoke is None:
        smoke = os.environ.get("REPRO_BENCH_SMOKE", "0") == "1"
    import jax
    import numpy as np

    from repro.amg import setup, solve
    from repro.amg.dist_solve import DistHierarchy
    from repro.amg.problems import laplace_3d
    from repro.core import BLUE_WATERS

    sizes = (6, 8, 10) if smoke else (8, 12, 16)
    cycles = cycles or (3 if smoke else 10)
    n_pods, lanes = _mesh_shape(jax.device_count())
    n_dev = n_pods * lanes
    out = []
    for n in sizes:
        A = laplace_3d(n)
        h = setup(A, solver="rs")
        b = A.matvec(np.ones(A.nrows))
        dh = DistHierarchy.build(h, n_pods, lanes, params=BLUE_WATERS)
        solve(h, b, maxiter=1, tol=0.0, backend="dist", dist=dh)  # compile
        t0 = time.perf_counter()
        res = solve(h, b, maxiter=cycles, tol=0.0, backend="dist", dist=dh)
        dt = time.perf_counter() - t0
        out.append((f"dist_weak_n{A.nrows}", dt / cycles * 1e6,
                    f"mesh={n_pods}x{lanes};dofs_per_dev={A.nrows // n_dev};"
                    f"levels={h.n_levels};conv={res.avg_conv_factor:.3f}"))
    return out


def session_rows(smoke: bool | None = None):
    """Cached vs cold AMGSolver sessions: the cold row pays setup +
    DistHierarchy lowering + program compilation; the cached row shows the
    per-call rebuild cost the session API eliminates."""
    if smoke is None:
        smoke = os.environ.get("REPRO_BENCH_SMOKE", "0") == "1"
    import jax
    import numpy as np

    from repro.amg.api import AMGConfig, AMGSolver, clear_sessions
    from repro.amg.problems import laplace_3d

    n = 8 if smoke else 12
    cycles = 3 if smoke else 10
    n_pods, lanes = _mesh_shape(jax.device_count())
    A = laplace_3d(n)
    b = A.matvec(np.ones(A.nrows))
    cfg = AMGConfig(backend="dist", n_pods=n_pods, lanes=lanes,
                    machine="blue_waters", tol=0.0, maxiter=cycles)
    clear_sessions()
    t0 = time.perf_counter()
    bound = AMGSolver(cfg).setup(A)       # hierarchy + lowering
    bound.solve(b)                        # + compile + solve
    cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    bound2 = AMGSolver(cfg).setup(A)      # session-cache hit
    bound2.solve(b)                       # reuses compiled programs
    cached = time.perf_counter() - t0
    assert bound2 is bound, "session cache must return the same bound solver"
    derived = f"n={A.nrows};mesh={n_pods}x{lanes};cycles={cycles}"
    return [("amg_solver_cold", cold * 1e6, derived),
            ("amg_solver_cached", cached * 1e6,
             derived + f";speedup={cold / max(cached, 1e-12):.1f}x")]


def streaming_rows(smoke: bool | None = None):
    """Drift sweep through ONE streaming session: A₀ is solved once (the
    session-cache hit), then a sequence of value-only drifts flows through
    :meth:`AMGService.update` — each refresh replays the Galerkin products
    on the frozen NAP schedules and reuses the compiled fused programs —
    and the final step injects a convergence regression so the adaptive
    full re-setup path is exercised (and timed) deterministically.

    ``streaming_refresh`` records the mean value-only refresh wall clock
    and ``streaming_resetup`` the escalated re-setup wall clock; both carry
    the session counters (``solves == refreshes + resetups + cached``),
    the per-step iteration trajectory and the trigger tallies that
    scripts/check_bench.py gates structurally (refresh must be cheaper
    than re-setup; iteration counts must stay finite)."""
    if smoke is None:
        smoke = os.environ.get("REPRO_BENCH_SMOKE", "0") == "1"
    import jax
    import numpy as np

    from repro.amg.api import AMGConfig, AMGService, clear_sessions
    from repro.amg.csr import CSR
    from repro.amg.problems import laplace_3d

    n = 8 if smoke else 12
    steps = 4 if smoke else 8
    n_pods, lanes = _mesh_shape(jax.device_count())
    A = laplace_3d(n)
    b = A.matvec(np.ones(A.nrows))
    cfg = AMGConfig(backend="dist", n_pods=n_pods, lanes=lanes,
                    machine="blue_waters", tol=1e-6, maxiter=60)
    clear_sessions()
    svc = AMGService(cfg)
    svc.register("m", A)
    rng = np.random.default_rng(7)

    def drifted(M, scale=0.02):
        # value-only drift on the frozen pattern, resymmetrized so pcg's
        # SPD assumption survives the perturbation
        data = M.data * (1.0 + scale * rng.random(M.nnz))
        Mt = CSR(M.shape, M.indptr.copy(), M.indices.copy(), data).T
        return CSR(M.shape, M.indptr.copy(), M.indices.copy(),
                   0.5 * (data + Mt.data))

    def solve_once() -> int:
        t = svc.submit("m", b, method="pcg")
        svc.drain()
        t.result()
        return int(t.diagnostics["iterations"])

    iters = [solve_once()]      # baseline solve: no update preceded it
    refresh_us: list[float] = []
    resetup_us: list[float] = []
    for step in range(steps):
        A = drifted(A)
        if step == steps - 1:
            # inject a convergence regression: the next update must
            # escalate to a full node-aware re-setup, not a refresh
            bound = svc.bound_for("m")
            bound.last_iterations = 10 * (bound.baseline_iterations or 1) + 100
        t0 = time.perf_counter()
        out = svc.update("m", A)
        # a refresh re-lowers values in-band; a re-setup defers the
        # DistHierarchy lowering to first use — materialize it so both
        # actions are charged their full pre-solve cost
        svc.bound_for("m").dist_hierarchy
        dt = (time.perf_counter() - t0) * 1e6
        (refresh_us if out["action"] == "refresh" else resetup_us).append(dt)
        iters.append(solve_once())
    st = svc.store.stats()
    assert st["refreshes"] == steps - 1 and st["resetups"] == 1, st
    assert all(np.isfinite(i) and 0 <= i <= cfg.maxiter for i in iters), iters
    solves = len(iters)
    cached = solves - st["refreshes"] - st["resetups"]
    mean_refresh = sum(refresh_us) / len(refresh_us)
    triggers = ",".join(f"{k}:{v}" for k, v in sorted(st["triggers"].items()))
    counters = (f"solves={solves};refreshes={st['refreshes']};"
                f"resetups={st['resetups']};cached={cached};"
                f"max_iters={max(iters)};iters={':'.join(map(str, iters))};"
                f"triggers={triggers}")
    timing = (f"refresh_us={mean_refresh:.2f};resetup_us={resetup_us[0]:.2f};"
              f"speedup={resetup_us[0] / max(mean_refresh, 1e-9):.2f}")
    shape = f"n={A.nrows};mesh={n_pods}x{lanes};steps={steps}"
    clear_sessions()
    return [
        ("streaming_refresh", mean_refresh, f"{shape};{counters};{timing}"),
        ("streaming_resetup", resetup_us[0],
         f"{shape};{counters};{timing};trigger=regression(injected)"),
    ]


def serving_rows(smoke: bool | None = None):
    """Serving throughput through :class:`~repro.amg.api.AMGService`:
    solves/s cold (setup + lowering + compile in-band), hot (session-store
    hit, one request per drain) and coalesced (k requests stacked into ONE
    multi-RHS trace), on the host and dist backends.  The ``worst_rel`` /
    ``unconverged`` fields feed the CI gate's presence + divergence check
    (wall-clock derived solves/s stays ungated); ``kernel=`` records which
    local kernel served the row — ``host_csr``, the fine level's layout
    (``ell``/``bcsr``) for single-request dist rows, or the native
    multi-RHS SpMM label (``ell_spmm``/``bcsr_spmm``, ``ell_vmap`` when the
    legacy vmap trace is forced) for coalesced batches."""
    if smoke is None:
        smoke = os.environ.get("REPRO_BENCH_SMOKE", "0") == "1"
    import jax
    import numpy as np

    from repro.amg.api import AMGConfig, AMGService, AMGSolver, clear_sessions
    from repro.amg.problems import laplace_3d

    n = 8 if smoke else 12
    k = 4 if smoke else 8
    n_pods, lanes = _mesh_shape(jax.device_count())
    A = laplace_3d(n)
    rng = np.random.default_rng(0)
    bs = [rng.standard_normal(A.nrows) for _ in range(k)]
    out = []
    for backend in ("host", "dist"):
        tol = 1e-6 if backend == "dist" else 1e-8
        cfg = AMGConfig(backend=backend,
                        n_pods=n_pods if backend == "dist" else 1,
                        lanes=lanes if backend == "dist" else 1,
                        machine="blue_waters", tol=tol)
        clear_sessions()
        svc = AMGService(cfg, max_rhs=k)
        svc.register("m", A)

        def serving_kernel(multi: bool) -> str:
            """Which local kernel serves a batch on this backend."""
            if backend == "host":
                return "host_csr"
            # session-cache hit: the same bound solver the service drains use
            dh = AMGSolver(cfg).setup(A).dist_hierarchy
            fine = dh.kernel_table()[0]["kernel"]      # 'ell' | 'bcsr'
            if not multi:
                return fine
            if not dh.native_spmm:
                return "ell_vmap"
            return f"{fine}_spmm" if fine == "bcsr" else "ell_spmm"

        def measure(tag, reqs, one_per_drain):
            t0 = time.perf_counter()
            tickets = []
            if one_per_drain:
                for b in reqs:
                    tickets.append(svc.submit("m", b, method="pcg"))
                    svc.drain()
            else:
                tickets = [svc.submit("m", b, method="pcg") for b in reqs]
                svc.drain()
            dt = time.perf_counter() - t0
            worst = max(
                np.linalg.norm(b - A.matvec(t.result())) / np.linalg.norm(b)
                for b, t in zip(reqs, tickets))
            unconv = sum(not t.diagnostics["converged"] for t in tickets)
            kern = serving_kernel(multi=not one_per_drain and len(reqs) > 1)
            return (f"serve_{tag}_{backend}", dt / len(reqs) * 1e6,
                    f"backend={backend};requests={len(reqs)};"
                    f"solves_per_s={len(reqs) / dt:.2f};"
                    f"batches={svc.stats['batches']};kernel={kern};"
                    f"worst_rel={worst:.3e};unconverged={unconv}")

        # cold: ONE request paying setup + lowering + compile in-band
        out.append(measure("cold", bs[:1], one_per_drain=True))
        # hot: k sequential single-request drains against the warm session
        out.append(measure("hot", bs, one_per_drain=True))
        base_batches = svc.stats["batches"]
        # coalesced: the same k requests stacked into ONE multi-RHS trace
        row = measure("coalesced", bs, one_per_drain=False)
        assert svc.stats["batches"] == base_batches + 1, svc.stats
        out.append(row)
    clear_sessions()
    return out


def main(argv=None) -> None:
    import argparse

    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--smoke", action="store_true")
    parser.add_argument("--out", default="BENCH_dist_solve.json")
    args = parser.parse_args(argv)
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")
    try:
        from benchmarks.serve_load import serving_latency_rows
    except ImportError:
        from serve_load import serving_latency_rows
    data = (rows(smoke=args.smoke) + cycle_smoother_rows(smoke=args.smoke)
            + overlap_rows(smoke=args.smoke)
            + comm_audit_rows(smoke=args.smoke)
            + weak_rows(smoke=args.smoke) + session_rows(smoke=args.smoke)
            + streaming_rows(smoke=args.smoke)
            + serving_rows(smoke=args.smoke)
            + serving_latency_rows(smoke=args.smoke))
    print("name,us_per_call,derived")
    for name, us, derived in data:
        print(f"{name},{us:.2f},{derived}")
    with open(args.out, "w") as f:
        json.dump({"benchmark": "dist_solve",
                   "rows": [{"name": n, "us_per_call": u, "derived": d}
                            for n, u, d in data]}, f, indent=2)
    print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
