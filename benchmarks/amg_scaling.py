"""Figs. 1/3/5/16-20: strong/weak scaling of AMG setup+solve with standard
vs node-aware (model-selected) communication.

Local compute is measured once on this core and divided by the process
count (perfect-local-scaling assumption); communication is modeled per
topology with the paper's Blue Waters max-rate constants — reproducing the
paper's *relative* claims (comm share grows with scale; NAP extends strong
scaling; ~2-4× total speedups near the scaling limit)."""
import time

import numpy as np

from repro.amg import setup, vcycle
from repro.amg.dist import analyze_hierarchy
from repro.amg.problems import grad_div_3d, laplace_3d
from repro.core import BLUE_WATERS, Topology

SOLVE_OPS = ("spmv_A", "restrict", "interp")
SETUP_OPS = ("spgemm_AP", "spgemm_PtAP")
N_CYCLES = 20  # solve iterations counted (typical for these systems)


def _phase_times(ops, phase_ops, pure: str):
    sel = 0.0
    std = 0.0
    for oc in ops:
        if oc.op not in phase_ops:
            continue
        sel += oc.selection.modeled_time
        std += oc.selection.times[pure]
    return std, sel


def _measure_local(A, h):
    b = A.matvec(np.ones(A.nrows))
    t0 = time.perf_counter()
    vcycle(h, b)
    solve_local = time.perf_counter() - t0
    setup_local = sum(l.setup_seconds for l in h.levels)
    return setup_local, solve_local


def rows(system="graddiv", machine=BLUE_WATERS, weak=False):
    out = []
    A = grad_div_3d(10) if system == "graddiv" else laplace_3d(18)
    h = setup(A, solver="rs")
    setup_local, solve_local = _measure_local(A, h)
    procs_list = (256, 512, 1024, 2048, 4096)
    for p in procs_list:
        topo = Topology(n_nodes=p // machine.ppn, ppn=machine.ppn)
        ops = analyze_hierarchy(h, topo, machine)
        std_setup, sel_setup = _phase_times(ops, SETUP_OPS, "standard")
        std_solve, sel_solve = _phase_times(ops, SOLVE_OPS, "standard")
        std_solve *= N_CYCLES
        sel_solve *= N_CYCLES
        # weak scaling: constant local work per core (paper Fig. 20 keeps
        # ~10k dofs/core); strong scaling: local work divided across cores
        local_div = procs_list[0] if weak else p
        tag = "fig20" if weak else "fig16"
        for phase, std, sel, local in (
                ("setup", std_setup, sel_setup, setup_local),
                ("solve", std_solve, sel_solve, solve_local * N_CYCLES)):
            t_std = local / local_div + std
            t_nap = local / local_div + sel
            out.append((f"{tag}_{system}_{machine.name}_{phase}_p{p}_std",
                        t_std * 1e6, f"comm_share={std / t_std:.2f}"))
            out.append((f"{tag}_{system}_{machine.name}_{phase}_p{p}_nap",
                        t_nap * 1e6, f"speedup={t_std / t_nap:.2f}x"))
        loc_tot = (setup_local + solve_local * N_CYCLES) / local_div
        std_tot = loc_tot + std_setup + std_solve
        sel_tot = loc_tot + sel_setup + sel_solve
        out.append((f"fig17_{system}_{machine.name}_total_p{p}",
                    sel_tot * 1e6, f"speedup={std_tot / sel_tot:.2f}x"))
    return out
