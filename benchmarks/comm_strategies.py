"""Fig. 14/15: per-level cost of standard/NAP-2/NAP-3 for the SpMV (A·x) and
SpGEMM (A·P) operations, plus the model's choice.  Times are modeled
(max-rate, Blue Waters constants); message counts/bytes come from actually
executing the schedules in the rank simulator."""
import time

import numpy as np

from repro.amg import setup
from repro.amg.dist import (matrix_comm_graph, row_partition,
                            vector_comm_graph)
from repro.amg.problems import laplace_3d
from repro.core import BLUE_WATERS, Topology, build
from repro.core.perf_model import model_time
from repro.core.simulator import verify


def rows(n=16, n_nodes=16, ppn=16):
    topo = Topology(n_nodes=n_nodes, ppn=ppn)
    A = laplace_3d(n)
    h = setup(A, solver="rs")
    out = []
    for l, lv in enumerate(h.levels):
        part = row_partition(lv.A, topo)
        graphs = {"spmv_Ax": vector_comm_graph(lv.A, part)}
        if lv.P is not None:
            graphs["spgemm_AP"] = matrix_comm_graph(lv.A, lv.P, part)
        for op, g in graphs.items():
            times = {}
            for strat in ("standard", "nap2", "nap3"):
                sch = build(strat, g)
                t0 = time.perf_counter()
                res = verify(sch, np.random.default_rng(l).standard_normal(
                    g.partition.n))
                sim_us = (time.perf_counter() - t0) * 1e6
                t = model_time(sch, BLUE_WATERS)
                times[strat] = t
                out.append((f"fig14_L{l}_{op}_{strat}", t * 1e6,
                            f"inter_msgs={res.inter_msgs};"
                            f"inter_KB={res.inter_bytes / 1024:.1f};"
                            f"sim_us={sim_us:.0f}"))
            best = min(times, key=times.get)
            out.append((f"fig15_L{l}_{op}_chosen", times[best] * 1e6, best))
    return out
