"""Fig. 8/9 model curves + measured machine calibration.

Two halves:

* :func:`rows` — the original *modeled* curves: single-message cost by
  locality and inter-node max-rate vs active process count, evaluated from
  the documented ``BLUE_WATERS`` constants.
* :func:`measure_machine_params` — the ROADMAP "measured machine models"
  slice: time real ppermute ping-pongs over the mesh's pod (inter) and lane
  (intra) axes across a size sweep, time a local ELL SpMV for the sustained
  flop rate, and calibrate a :class:`~repro.core.perf_model.MachineParams`
  via :meth:`from_measurements`.  The result is registered in
  ``repro.core.MACHINES`` so the overlap-aware selector can run on data
  instead of the documented ``TPU_V5E`` constants
  (:func:`benchmarks.dist_solve.overlap_rows` consumes it).
"""
from __future__ import annotations

import time

from repro.core.perf_model import (BLUE_WATERS, maxrate_internode_time,
                                   single_message_time)


def rows():
    out = []
    for nbytes in (64, 1024, 16384, 262144, 4 << 20):
        for loc in ("socket", "node", "network"):
            t = single_message_time(BLUE_WATERS, nbytes, loc)
            out.append((f"fig8_pingpong_{loc}_{nbytes}B", t * 1e6,
                        f"bytes={nbytes}"))
    total = 4 << 20
    for k in (1, 2, 4, 8, 16):
        t = maxrate_internode_time(BLUE_WATERS, total, k)
        out.append((f"fig9_maxrate_active{k}", t * 1e6,
                    f"total=4MiB,procs={k}"))
    return out


# --------------------------------------------------------------- measurement

_SIZES = (1024, 8192, 65536, 524288)      # bytes per ping-pong message


def _time_fn(fn, *args, reps: int = 5) -> float:
    """Median-of-reps wall time of an already-compiled jitted call."""
    fn(*args)                             # warm (compile outside the clock)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        r = fn(*args)
        try:
            r.block_until_ready()
        except AttributeError:
            pass
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def measure_machine_params(name: str = "measured_mesh",
                           n_pods: int | None = None,
                           lanes: int | None = None,
                           sizes: tuple[int, ...] = _SIZES,
                           reps: int = 5):
    """Measure (bytes, seconds) ping-pong samples per mesh axis + the local
    SpMV flop rate, fit them through ``MachineParams.from_measurements`` and
    register the result under ``name``.

    ``pod``-axis ppermutes cross the slower tier (inter-node in the paper's
    vocabulary, inter-pod DCI on TPU), ``lane``-axis ppermutes stay inside a
    node — the same two tiers the Eq. (2)/(3) models price.  On a
    host-platform mesh both axes ride the same memory fabric, so the fitted
    tiers come out nearly equal; the *shape* of the calibration (postal-model
    lstsq per tier, flop rate for the overlap split) is what the selector
    consumes either way.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.compat import shard_map
    from repro.core.perf_model import MachineParams, register_machine

    if n_pods is None or lanes is None:
        nd = jax.device_count()
        n_pods, lanes = (2, nd // 2) if nd >= 4 and nd % 2 == 0 else (1, nd)
    mesh = jax.make_mesh((n_pods, lanes), ("pod", "lane"))
    spec = jax.sharding.PartitionSpec(("pod", "lane"))
    D = n_pods * lanes

    def axis_samples(axis: str, size: int):
        samples = []
        for nbytes in sizes:
            n = max(nbytes // 4, 1)       # float32 payload

            def body(x):
                perm = [(i, (i + 1) % size) for i in range(size)]
                return jax.lax.ppermute(x[0], axis, perm)[None]

            fn = jax.jit(shard_map(body, mesh=mesh, in_specs=spec,
                                   out_specs=spec, check_vma=False))
            x = jnp.zeros((D, n), jnp.float32)
            samples.append((float(nbytes), _time_fn(fn, x, reps=reps)))
        return samples

    inter = axis_samples("pod", n_pods) if n_pods > 1 else None
    intra = axis_samples("lane", lanes) if lanes > 1 else None
    # degenerate axes (1 pod / 1 lane) borrow the other tier's samples so
    # the fit stays well-posed on any mesh shape
    inter = inter or intra
    intra = intra or inter
    if inter is None:
        raise RuntimeError("mesh has a single device; nothing to measure")

    # local SpMV flop rate: the inline ELL gather product apply() runs
    rows_l, K = 4096, 16
    rng = np.random.default_rng(0)
    cols = jnp.asarray(rng.integers(0, rows_l, size=(rows_l, K)),
                       dtype=jnp.int32)
    vals = jnp.asarray(rng.standard_normal((rows_l, K)), dtype=jnp.float32)
    xv = jnp.asarray(rng.standard_normal(rows_l), dtype=jnp.float32)

    @jax.jit
    def ell(cols, vals, x):
        return (vals * x[cols]).sum(axis=1)

    t_spmv = _time_fn(ell, cols, vals, xv, reps=reps)
    Rf = 2.0 * rows_l * K / max(t_spmv, 1e-12)

    return register_machine(MachineParams.from_measurements(
        name, ppn=lanes, inter=inter, intra=intra, Rf=Rf))


def measured_rows(smoke: bool | None = None):
    """Bench rows for the calibrated machine: fitted α / R_b per tier and
    the measured flop rate (wall-clock-derived — structurally gated only).

    Skipped (empty) on a single-device process — there is no exchange to
    time; the standalone ``benchmarks.dist_solve`` entrypoint forces the
    8-way host mesh and emits the real rows into the committed baseline.
    """
    import jax

    if jax.device_count() < 2:
        return []
    params = measure_machine_params()
    p_i, p_l = params.inter[0], params.intra[0]
    return [
        ("machine_measured_inter", p_i.alpha * 1e6,
         f"machine={params.name};Rb={p_i.Rb:.3e};tier=inter"),
        ("machine_measured_intra", p_l.alpha * 1e6,
         f"machine={params.name};Rb={p_l.Rb:.3e};tier=intra"),
        ("machine_measured_flops", 2.0 / max(params.Rf, 1e-12) * 1e6,
         f"machine={params.name};Rf={params.Rf:.3e}"),
    ]
