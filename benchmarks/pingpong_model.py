"""Fig. 8/9: single-message cost by locality, and inter-node max-rate vs
active process count."""
from repro.core.perf_model import (BLUE_WATERS, maxrate_internode_time,
                                   single_message_time)


def rows():
    out = []
    for nbytes in (64, 1024, 16384, 262144, 4 << 20):
        for loc in ("socket", "node", "network"):
            t = single_message_time(BLUE_WATERS, nbytes, loc)
            out.append((f"fig8_pingpong_{loc}_{nbytes}B", t * 1e6,
                        f"bytes={nbytes}"))
    total = 4 << 20
    for k in (1, 2, 4, 8, 16):
        t = maxrate_internode_time(BLUE_WATERS, total, k)
        out.append((f"fig9_maxrate_active{k}", t * 1e6,
                    f"total=4MiB,procs={k}"))
    return out
