"""Fig. 2/4: per-level setup and solve cost, split into measured local
compute (this CPU) and modeled communication, for RS and SA hierarchies."""
import time

import numpy as np

from repro.amg import setup, vcycle
from repro.amg.dist import analyze_hierarchy, phase_costs
from repro.amg.problems import laplace_3d
from repro.core import BLUE_WATERS, Topology


def rows(n=16, n_nodes=16, ppn=16):
    A = laplace_3d(n)
    topo = Topology(n_nodes=n_nodes, ppn=ppn)
    out = []
    for solver in ("rs", "sa"):
        h = setup(A, solver=solver)
        ops = analyze_hierarchy(h, topo, BLUE_WATERS)
        costs = phase_costs(ops, h.n_levels)
        for l in range(h.n_levels):
            local_us = h.levels[l].setup_seconds * 1e6 / topo.n_procs
            comm_us = costs["setup"][l]["selected"] * 1e6
            out.append((f"fig2_{solver}_setup_L{l}",
                        local_us + comm_us,
                        f"local={local_us:.0f};comm={comm_us:.0f};"
                        f"n={h.levels[l].A.nrows}"))
            comm_us = costs["solve"][l]["selected"] * 1e6
            out.append((f"fig4_{solver}_solve_L{l}", comm_us,
                        f"comm_per_cycle={comm_us:.0f}"))
        # one measured V-cycle (local compute on this core)
        b = A.matvec(np.ones(A.nrows))
        t0 = time.perf_counter()
        vcycle(h, b)
        out.append((f"fig4_{solver}_vcycle_local", (time.perf_counter() - t0)
                    * 1e6, "measured 1-core"))
    return out
