"""Benchmark driver — one module per paper table/figure (DESIGN.md §6).
Prints ``name,us_per_call,derived`` CSV."""
import sys
import time

from . import (amg_levels, amg_scaling, comm_strategies, dist_setup,
               dist_solve, kernels, lm_roofline, pingpong_model, ptap_sweeps)
from repro.core.perf_model import BLUE_WATERS, QUARTZ

MODULES = [
    ("fig8_9", lambda: pingpong_model.rows()),
    ("machine_measured", lambda: pingpong_model.measured_rows(smoke=True)),
    ("fig14_15", lambda: comm_strategies.rows()),
    ("fig2_4", lambda: amg_levels.rows()),
    ("fig16_17_bw", lambda: amg_scaling.rows("graddiv", BLUE_WATERS)),
    ("fig18", lambda: amg_scaling.rows("laplace", BLUE_WATERS)),
    ("fig19_quartz", lambda: amg_scaling.rows("graddiv", QUARTZ)),
    ("fig20_weak", lambda: amg_scaling.rows("graddiv", BLUE_WATERS,
                                            weak=True)),
    ("fig21", lambda: ptap_sweeps.rows()),
    ("dist_solve", lambda: dist_solve.rows(smoke=True)),
    ("dist_solve_cycles", lambda: dist_solve.cycle_smoother_rows(smoke=True)),
    ("dist_solve_overlap", lambda: dist_solve.overlap_rows(smoke=True)),
    ("dist_solve_weak", lambda: dist_solve.weak_rows(smoke=True)),
    ("dist_solve_session", lambda: dist_solve.session_rows(smoke=True)),
    ("dist_solve_streaming", lambda: dist_solve.streaming_rows(smoke=True)),
    ("dist_solve_serving", lambda: dist_solve.serving_rows(smoke=True)),
    ("dist_setup", lambda: dist_setup.rows(smoke=True)),
    ("kernels", lambda: kernels.rows(smoke=True)),
    ("roofline", lambda: lm_roofline.rows()),
]


def main() -> None:
    print("name,us_per_call,derived")
    only = sys.argv[1] if len(sys.argv) > 1 else None
    for tag, fn in MODULES:
        if only and only not in tag:
            continue
        t0 = time.perf_counter()
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.2f},{derived}")
        except Exception as e:  # keep the harness running
            print(f"{tag}_ERROR,0.0,{type(e).__name__}:{e}")
        print(f"# {tag} done in {time.perf_counter() - t0:.1f}s",
              file=sys.stderr)


if __name__ == '__main__':
    main()
