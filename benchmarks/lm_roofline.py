"""Roofline table (EXPERIMENTS.md §Roofline source): reads the dry-run
sweep JSON and prints per-(arch × shape × mesh) terms, followed by the
*measured* ERT peaks (from ``BENCH_kernels.json``, i.e.
:func:`repro.launch.roofline.ert_sweep`) so the modeled documented-constant
terms sit next to what the current backend was actually measured to do."""
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..",
                       "dryrun_results.json")
BENCH_KERNELS = os.path.join(os.path.dirname(__file__), "..",
                             "BENCH_kernels.json")


def measured_rows(path=BENCH_KERNELS):
    """ERT measured-peak rows: one per swept micro-kernel, with the
    documented-constant ratio where one exists."""
    if not os.path.exists(path):
        return [("roofline_measured_missing", 0.0,
                 "run: python -m benchmarks.kernels --smoke")]
    out = []
    for r in json.load(open(path))["rows"]:
        if r["name"].startswith(("ert_", "kern_")):
            out.append((f"roofline_measured_{r['name']}",
                        r["us_per_call"], r["derived"]))
    return out


def rows(path=RESULTS):
    if not os.path.exists(path):
        return ([("lm_roofline_missing", 0.0,
                  "run: python -m repro.launch.dryrun --all --both-meshes")]
                + measured_rows())
    out = []
    for r in json.load(open(path)):
        name = f"roofline_{r['arch']}_{r['shape']}_{r.get('mesh', '?')}"
        if r.get("skipped"):
            out.append((name, 0.0, "skipped:" + r["skipped"][:40]))
            continue
        if "error" in r:
            out.append((name, 0.0, "ERROR:" + r["error"][:60]))
            continue
        if "compute_s" not in r:   # AMG spmv entries: collective bytes only
            out.append((name, 0.0,
                        f"coll_B={r.get('coll_bytes_per_dev', 0):.3g};"
                        f"xpod_B={r.get('cross_pod_bytes_per_dev', 0):.3g}"))
            continue
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"],
                    r["cross_pod_s"])
        out.append((name, bound * 1e6,
                    f"dom={r['dominant']};roofline={r['roofline_fraction']:.4f};"
                    f"compute_s={r['compute_s']:.3f};memory_s={r['memory_s']:.3f};"
                    f"coll_s={r['collective_s']:.3f};xpod_s={r['cross_pod_s']:.3f}"))
    return out + measured_rows()
