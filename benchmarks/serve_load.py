"""Open-loop load generator for the AMGWire socket server.

Closed-loop harnesses (``repro.launch.serve --solver amg``) measure the
service at its own pace — every in-flight request throttles the next, so
overload never happens and tail latency is flattered.  This generator is
**open-loop**: arrivals are a Poisson process at a target rate
(exponential inter-arrival draws), fired down N concurrent connections
whether or not earlier requests have completed — the only regime where
admission control, per-tenant quotas and priority-class shedding
actually get exercised.

Every request is built by :mod:`repro.serve.workload` (the same
construction the closed-loop harness uses), tagged (tenant, priority
class) round-robin, and every response is accounted: ``solution`` frames
are residual-validated, ``rejected`` frames counted as shed load,
``error`` frames as failures — anything else is an *unstructured*
response, which ``--check`` treats as fatal.  Latency is measured from
socket send to the client reader thread seeing the response (harvesting
later does not inflate it).

After the load drains, a **streaming epilogue** (:func:`update_round`)
sends one ΔA ``update`` frame per (tenant, matrix) into the sessions the
load left warm — exercising the schema-v2 value-refresh path over real
sockets — and verifies each with a solve that must track the drifted
operator; ``--check`` fails on any update error or stale residual.

Emits ``serving_latency_{tenant}_{class}`` rows (p50/p99/p999 ms,
solves/s, reject rate, accounting) that ``benchmarks/dist_solve.py``
folds into ``BENCH_dist_solve.json`` and ``scripts/check_bench.py``
gates.  Standalone::

    PYTHONPATH=src python -m benchmarks.serve_load --smoke          # self-host
    PYTHONPATH=src python -m benchmarks.serve_load \\
        --connect 127.0.0.1:8571 --tenants alpha,beta --check --expect-reject
"""
from __future__ import annotations

import argparse
import json
import os
import time

PRIORITIES = ("interactive", "batch")
DEFAULT_TENANTS = (("alpha", 32), ("beta", 2))


def build_plan(problems, tenants, requests: int, rate: float, seed: int,
               method: str):
    """The full open-loop schedule, precomputed so the dispatch loop does
    nothing but sleep-and-send: per request an arrival offset (cumulative
    exponential inter-arrivals at ``rate``/s), a (tenant, priority) tag
    (round-robin over the cross product) and an encoded payload."""
    import numpy as np

    from repro.serve.workload import make_request

    rng = np.random.default_rng(seed)
    ids = sorted(problems)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=requests))
    plan = []
    for i in range(requests):
        tenant = tenants[i % len(tenants)]
        prio = PRIORITIES[(i // len(tenants)) % len(PRIORITIES)]
        b, payload = make_request(rng, problems, ids[i % len(ids)],
                                  method=method, priority=prio)
        plan.append({"t": float(arrivals[i]), "tenant": tenant,
                     "priority": prio, "mid": ids[i % len(ids)],
                     "b": b, "payload": payload})
    return plan


def connect_clients(host: str, port: int, count: int, *,
                    retry_s: float = 30.0):
    """N connections, retrying while the server boots (CI starts it in the
    background and races us to the socket)."""
    from repro.serve import AMGWireClient

    clients, deadline = [], time.perf_counter() + retry_s
    while len(clients) < count:
        try:
            clients.append(AMGWireClient.connect(host, port))
        except OSError:
            if time.perf_counter() > deadline:
                for c in clients:
                    c.close()
                raise
            time.sleep(0.2)
    return clients


def run_load(host: str, port: int, problems, plan, connections: int,
             timeout: float = 300.0):
    """Drive the schedule; returns ``(results, makespan_s)`` where each
    result is ``(request, response_frame, latency_s)`` and makespan spans
    first send to last response seen."""
    from repro.serve.workload import matrix_payloads

    clients = connect_clients(host, port, connections)
    try:
        payloads = matrix_payloads(problems)
        for tenant in sorted({p["tenant"] for p in plan}):
            for payload in payloads.values():
                clients[0].register(tenant, payload)
        sent = []
        t0 = time.perf_counter()
        for i, req in enumerate(plan):
            delay = req["t"] - (time.perf_counter() - t0)
            if delay > 0:
                time.sleep(delay)
            c = clients[i % len(clients)]
            seq = c.send("solve", tenant=req["tenant"],
                         payload=req["payload"])
            sent.append((c, seq, time.perf_counter(), req))
        results, t_last = [], t0
        for c, seq, t_send, req in sent:
            frame, t_recv = c.recv_timed(seq, timeout)
            results.append((req, frame, t_recv - t_send))
            t_last = max(t_last, t_recv)
        server_stats = clients[0].stats()
    finally:
        for c in clients:
            c.close()
    return results, max(t_last - t0, 1e-9), server_stats


def update_round(host: str, port: int, problems, tenants, *,
                 method: str = "pcg", seed: int = 1):
    """Streaming epilogue to the load: one ΔA ``update`` frame per
    (tenant, matrix) against the sessions the load left warm, each followed
    by a verification solve that must land on the drifted operator.
    Tenants drift independently (each holds its own registered copy of the
    matrix), so validation tracks a per-tenant view of ``problems`` and the
    caller's dict is never mutated.  Returns accounting for ``--check``:
    every update must come back ``updated`` with a refresh or re-setup
    action and every verification residual must track the new values."""
    import numpy as np

    from repro.serve.workload import make_request, make_update, rel_residual

    acct = {"updates": 0, "refresh": 0, "resetup": 0, "failures": []}
    client = connect_clients(host, port, 1)[0]
    try:
        for tenant in tenants:
            rng = np.random.default_rng(seed)
            live = dict(problems)          # this tenant's drifted view
            for mid in sorted(live):
                payload = make_update(rng, live, mid)
                try:
                    frame = client.update(tenant, payload)
                except Exception as exc:
                    acct["failures"].append(
                        f"{tenant}/{mid[:12]}: update frame failed: {exc}")
                    continue
                acct["updates"] += 1
                action = frame.get("action")
                if action in ("refresh", "resetup"):
                    acct[action] += 1
                else:
                    acct["failures"].append(
                        f"{tenant}/{mid[:12]}: unexpected update action "
                        f"{action!r} in {frame}")
                b, spay = make_request(rng, live, mid, method=method)
                try:
                    x, _diag = client.solve(tenant, spay)
                except Exception as exc:
                    acct["failures"].append(
                        f"{tenant}/{mid[:12]}: post-update solve failed: "
                        f"{exc}")
                    continue
                rel = rel_residual(live[mid], x, b)
                if not (np.isfinite(rel) and rel < 1e-4):
                    acct["failures"].append(
                        f"{tenant}/{mid[:12]}: post-update residual "
                        f"{rel:.3e} does not track the drifted operator")
    finally:
        client.close()
    return acct


def aggregate(results, problems, validate: bool = True):
    """Per-(tenant, priority) accounting; ``unstructured`` collects any
    response that is not a solution/rejected/error frame (must stay
    empty)."""
    from repro.amg.api import array_from_wire
    from repro.serve.workload import rel_residual

    classes, unstructured = {}, []
    for req, frame, lat in results:
        key = (req["tenant"], req["priority"])
        cs = classes.setdefault(key, {
            "offered": 0, "completed": 0, "rejected": 0, "errors": 0,
            "unconverged": 0, "latencies": [], "worst_rel": 0.0})
        cs["offered"] += 1
        kind = frame.get("kind")
        if kind == "solution":
            cs["completed"] += 1
            cs["latencies"].append(lat)
            diag = frame.get("diagnostics") or {}
            if not diag.get("converged", True):
                cs["unconverged"] += 1
            if validate:
                x = array_from_wire(frame["x"])
                cs["worst_rel"] = max(cs["worst_rel"], rel_residual(
                    problems[req["mid"]], x, req["b"]))
        elif kind == "rejected":
            cs["rejected"] += 1
        elif kind == "error":
            cs["errors"] += 1
        else:
            unstructured.append(frame)
    return classes, unstructured


def _class_row(name: str, cs: dict, makespan: float):
    from repro.serve.workload import summarize_latencies

    lat = summarize_latencies(cs["latencies"])
    reject_rate = cs["rejected"] / max(cs["offered"], 1)
    derived = (f"offered={cs['offered']};completed={cs['completed']};"
               f"rejected={cs['rejected']};errors={cs['errors']};"
               f"reject_rate={reject_rate:.4f};"
               f"solves_per_s={cs['completed'] / makespan:.2f}")
    if lat:
        derived += (f";p50_ms={lat['p50_ms']:.3f}"
                    f";p99_ms={lat['p99_ms']:.3f}"
                    f";p999_ms={lat['p999_ms']:.3f}")
    if cs["completed"]:
        derived += (f";worst_rel={cs['worst_rel']:.3e}"
                    f";unconverged={cs['unconverged']}")
    return (name, lat.get("p50_ms", 0.0) * 1e3, derived)


def rows_from_results(results, problems, makespan: float,
                      validate: bool = True):
    """BENCH rows: one ``serving_latency_{tenant}_{priority}`` per class
    plus the ``serving_latency_total`` aggregate.  ``us_per_call`` is the
    class's p50 latency (0 for a fully-shed class, which has no latency
    distribution)."""
    classes, unstructured = aggregate(results, problems, validate)
    rows = []
    total = {"offered": 0, "completed": 0, "rejected": 0, "errors": 0,
             "unconverged": 0, "latencies": [], "worst_rel": 0.0}
    for (tenant, prio) in sorted(classes):
        cs = classes[(tenant, prio)]
        for k in ("offered", "completed", "rejected", "errors",
                  "unconverged"):
            total[k] += cs[k]
        total["latencies"] += cs["latencies"]
        total["worst_rel"] = max(total["worst_rel"], cs["worst_rel"])
        rows.append(_class_row(f"serving_latency_{tenant}_{prio}", cs,
                               makespan))
    rows.append(_class_row("serving_latency_total", total, makespan))
    return rows, classes, unstructured


def print_table(classes, makespan: float) -> None:
    from repro.serve.workload import summarize_latencies

    head = (f"{'tenant':<8} {'class':<12} {'offered':>7} {'ok':>6} "
            f"{'rej':>6} {'err':>5} {'rej%':>6} {'sol/s':>8} "
            f"{'p50ms':>8} {'p99ms':>8} {'p999ms':>8}")
    print(head)
    print("-" * len(head))
    for (tenant, prio) in sorted(classes):
        cs = classes[(tenant, prio)]
        lat = summarize_latencies(cs["latencies"])
        print(f"{tenant:<8} {prio:<12} {cs['offered']:>7} "
              f"{cs['completed']:>6} {cs['rejected']:>6} "
              f"{cs['errors']:>5} "
              f"{100 * cs['rejected'] / max(cs['offered'], 1):>5.1f}% "
              f"{cs['completed'] / makespan:>8.1f} "
              f"{lat.get('p50_ms', float('nan')):>8.2f} "
              f"{lat.get('p99_ms', float('nan')):>8.2f} "
              f"{lat.get('p999_ms', float('nan')):>8.2f}")


def serving_latency_rows(smoke: bool | None = None):
    """Self-hosted load run for the BENCH baseline: two tenants ("alpha"
    roomy, "beta" starved at ``max_inflight=2`` so overload sheds its
    batch class first), Poisson arrivals over 32 connections, host
    backend (deterministic, no accelerator dependency)."""
    if smoke is None:
        smoke = os.environ.get("REPRO_BENCH_SMOKE", "0") == "1"
    from repro.amg.api import AMGConfig
    from repro.serve import ServerThread, TenantSpec
    from repro.serve.workload import build_problems, default_tol

    n = 6 if smoke else 8
    requests = 240 if smoke else 2000
    rate = 300.0 if smoke else 600.0
    cfg = AMGConfig(backend="host", tol=default_tol("host"))
    tenants = {name: TenantSpec(config=cfg, max_inflight=quota)
               for name, quota in DEFAULT_TENANTS}
    problems = build_problems(n)
    plan = build_plan(problems, [t for t, _ in DEFAULT_TENANTS], requests,
                      rate, seed=0, method="pcg")
    with ServerThread(tenants) as srv:
        results, makespan, server_stats = run_load(
            srv.host, srv.port, problems, plan, connections=32)
    rows, classes, unstructured = rows_from_results(results, problems,
                                                    makespan)
    if unstructured:
        rows.append(("serving_latency_ERROR", 0.0,
                     f"unstructured_responses={len(unstructured)}"))
    dropped = server_stats.get("dropped_connections", 0)
    if dropped:
        rows.append(("serving_latency_ERROR", 0.0,
                     f"dropped_connections={dropped}"))
    return rows


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--connect", metavar="HOST:PORT",
                        help="target an already-running AMGWire server "
                             "(default: self-host one on a free port)")
    parser.add_argument("--tenants", default="alpha:32,beta:2",
                        help="comma-separated NAME[:MAX_INFLIGHT] list "
                             "(quotas apply when self-hosting)")
    parser.add_argument("--requests", type=int, default=None)
    parser.add_argument("--rate", type=float, default=None,
                        help="target Poisson arrival rate, requests/s")
    parser.add_argument("--connections", type=int, default=32)
    parser.add_argument("--n", type=int, default=None,
                        help="largest Laplacian grid size")
    parser.add_argument("--method", choices=("solve", "pcg"),
                        default="pcg")
    parser.add_argument("--seed", type=int, default=0)
    parser.add_argument("--smoke", action="store_true",
                        help="small problem + short schedule")
    parser.add_argument("--out", help="write BENCH-style json rows here")
    parser.add_argument("--check", action="store_true",
                        help="exit non-zero on unstructured responses, "
                             "dropped connections or inconsistent "
                             "accounting (CI smoke gate)")
    parser.add_argument("--expect-reject", action="store_true",
                        help="with --check: require at least one "
                             "rejected frame (proves shedding engaged)")
    args = parser.parse_args(argv)

    from repro.amg.api import AMGConfig
    from repro.serve.workload import build_problems, default_tol

    smoke = args.smoke or os.environ.get("REPRO_BENCH_SMOKE", "0") == "1"
    n = args.n if args.n is not None else (6 if smoke else 8)
    requests = args.requests if args.requests is not None else (
        240 if smoke else 2000)
    rate = args.rate if args.rate is not None else (
        300.0 if smoke else 600.0)
    tenant_specs = []
    for part in args.tenants.split(","):
        name, _, quota = part.strip().partition(":")
        tenant_specs.append((name, int(quota) if quota else 32))
    problems = build_problems(n)
    plan = build_plan(problems, [t for t, _ in tenant_specs], requests,
                      rate, args.seed, args.method)

    srv_cm = None
    if args.connect:
        host, _, port = args.connect.rpartition(":")
        host, port = host or "127.0.0.1", int(port)
    else:
        from repro.serve import ServerThread, TenantSpec

        cfg = AMGConfig(backend="host", tol=default_tol("host"))
        srv_cm = ServerThread({name: TenantSpec(config=cfg,
                                                max_inflight=quota)
                               for name, quota in tenant_specs})
        srv_cm.__enter__()
        host, port = srv_cm.host, srv_cm.port
    try:
        results, makespan, server_stats = run_load(
            host, port, problems, plan, connections=args.connections)
        # streaming epilogue: ΔA update frames against the warm sessions,
        # each verified by a solve on the drifted operator (never mutates
        # ``problems`` — the main load's validation below stays exact)
        upd = update_round(host, port, problems,
                           [t for t, _ in tenant_specs],
                           method=args.method, seed=args.seed + 1)
    finally:
        if srv_cm is not None:
            srv_cm.__exit__(None, None, None)

    rows, classes, unstructured = rows_from_results(results, problems,
                                                    makespan)
    total = sum(cs["completed"] for cs in classes.values())
    rejected = sum(cs["rejected"] for cs in classes.values())
    print(f"[serve_load] {len(plan)} requests over "
          f"{args.connections} connections at {rate:.0f}/s target: "
          f"{total} completed ({total / makespan:.1f} solves/s), "
          f"{rejected} rejected, makespan {makespan:.2f}s")
    print(f"[serve_load] streaming epilogue: {upd['updates']} update "
          f"frames ({upd['refresh']} refresh, {upd['resetup']} resetup), "
          f"{len(upd['failures'])} failures")
    print_table(classes, makespan)
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"benchmark": "serve_load",
                       "rows": [{"name": nm, "us_per_call": us,
                                 "derived": d} for nm, us, d in rows]},
                      f, indent=2)
        print(f"# wrote {args.out}")

    failures = []
    failures.extend(upd["failures"])
    if upd["updates"] == 0:
        failures.append("streaming epilogue sent no update frames")
    if unstructured:
        failures.append(f"{len(unstructured)} unstructured responses: "
                        f"{unstructured[:3]}")
    dropped = server_stats.get("dropped_connections")
    if dropped:
        failures.append(f"{dropped} server-side dropped connections")
    for key, cs in sorted(classes.items()):
        if cs["completed"] + cs["rejected"] + cs["errors"] != cs["offered"]:
            failures.append(f"{key}: accounting mismatch {cs}")
        if cs["errors"]:
            failures.append(f"{key}: {cs['errors']} error frames")
        if cs["completed"] and cs["worst_rel"] > 1e-4:
            failures.append(f"{key}: worst rel residual "
                            f"{cs['worst_rel']:.3e}")
    if args.expect_reject and rejected == 0:
        failures.append("expected at least one rejected frame; the "
                        "schedule never overloaded admission")
    if args.check and failures:
        for fail in failures:
            print(f"[serve_load] CHECK FAILED: {fail}")
        return 1
    if failures:
        for fail in failures:
            print(f"[serve_load] warning: {fail}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
